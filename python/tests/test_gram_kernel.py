"""L1 correctness: the Pallas matmul_nt (Gram) kernel vs jnp."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.matmul_nt import matmul_nt_pallas

DIMS = st.sampled_from([32, 64, 96, 128, 256])


@settings(max_examples=12, deadline=None)
@given(m=DIMS, n=DIMS, p=st.sampled_from([32, 128, 512]), seed=st.integers(0, 2**31 - 1))
def test_matmul_nt_matches_ref(m, n, p, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, p)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(n, p)), jnp.float32)
    got = matmul_nt_pallas(x, y)
    want = ref.matmul_nt_ref(x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-3, rtol=1e-4)


def test_gram_is_symmetric_psd():
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(64, 256)), jnp.float32)
    a = np.asarray(matmul_nt_pallas(x, x))
    np.testing.assert_allclose(a, a.T, atol=1e-3)
    eig = np.linalg.eigvalsh(a.astype(np.float64))
    assert eig.min() > -1e-2 * max(eig.max(), 1.0)


def test_zero_padding_is_exact():
    # zero columns must not change the Gram product (the chunking invariant
    # the rust coordinator relies on)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(32, 96)), jnp.float32)
    xp = jnp.concatenate([x, jnp.zeros((32, 32), jnp.float32)], axis=1)
    a = matmul_nt_pallas(x, x)
    ap = matmul_nt_pallas(xp, xp)
    # tolerance: the padded call uses one more k-panel, so f32 accumulation
    # order differs; zero columns add exactly 0 but rounding shifts slightly
    np.testing.assert_allclose(np.asarray(a), np.asarray(ap), atol=1e-3, rtol=1e-5)

"""L2 correctness: transformer capture/score/train graphs."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model as M
from compile.shapes import load_presets, model_cfg, layer_param_specs, model_param_specs


def _init(specs, seed=0, std=0.05):
    rng = np.random.default_rng(seed)
    out = []
    for sp in specs:
        if sp.name.endswith("_g"):
            out.append(jnp.ones(sp.shape, jnp.float32))
        elif ".b" in sp.name or sp.name.endswith("_b"):
            out.append(jnp.zeros(sp.shape, jnp.float32))
        else:
            out.append(jnp.asarray(rng.normal(size=sp.shape) * std, jnp.float32))
    return out


@pytest.fixture(scope="module")
def presets():
    return load_presets()


@pytest.mark.parametrize("family,size", [("topt", "s1"), ("tllama", "s1")])
def test_capture_shapes(presets, family, size):
    cfg = model_cfg(presets, family, size)
    capture, specs = M.make_layer_capture(cfg)
    flat = _init(specs, 1)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(2, cfg.seq, cfg.d)), jnp.float32)
    attn_in, o_in, mlp_in, mlp2_in, y = jax.jit(capture)(x, *flat)
    assert attn_in.shape == (2, cfg.seq, cfg.d)
    assert o_in.shape == (2, cfg.seq, cfg.d)
    assert mlp_in.shape == (2, cfg.seq, cfg.d)
    assert mlp2_in.shape == (2, cfg.seq, cfg.ffn)
    assert y.shape == (2, cfg.seq, cfg.d)
    for t in (attn_in, o_in, mlp_in, mlp2_in, y):
        assert bool(jnp.all(jnp.isfinite(t)))


@pytest.mark.parametrize("family,size", [("topt", "s1"), ("tllama", "s1")])
def test_causality(presets, family, size):
    """Perturbing a future token must not change earlier positions."""
    cfg = model_cfg(presets, family, size)
    capture, specs = M.make_layer_capture(cfg)
    flat = _init(specs, 3)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.normal(size=(1, cfg.seq, cfg.d)), jnp.float32)
    y1 = jax.jit(capture)(x, *flat)[-1]
    x2 = x.at[0, cfg.seq - 1].add(5.0)  # perturb the LAST position only
    y2 = jax.jit(capture)(x2, *flat)[-1]
    np.testing.assert_allclose(
        np.asarray(y1[0, : cfg.seq - 1]), np.asarray(y2[0, : cfg.seq - 1]), atol=1e-5
    )
    assert not np.allclose(np.asarray(y1[0, -1]), np.asarray(y2[0, -1]))


def test_score_matches_manual_nll(presets):
    cfg = model_cfg(presets, "topt", "s1")
    score, specs = M.make_score(cfg)
    flat = _init(specs, 5)
    rng = np.random.default_rng(6)
    b = presets["capture_batch"]
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, size=(b, cfg.seq + 1)), jnp.int32)
    mask = jnp.ones((b, cfg.seq), jnp.float32)
    nll = jax.jit(score)(*flat, tokens, mask)
    assert nll.shape == (b,)
    # manual: rebuild logits through the private apply
    p = {sp.name: t for sp, t in zip(specs, flat)}
    logits = M._model_apply(cfg, p, tokens[:, :-1])
    logp = jax.nn.log_softmax(logits, axis=-1)
    want = -jnp.take_along_axis(logp, tokens[:, 1:][..., None], axis=-1)[..., 0].sum(axis=-1)
    np.testing.assert_allclose(np.asarray(nll), np.asarray(want), rtol=1e-4, atol=1e-3)
    # masked variant scores fewer tokens
    mask2 = mask.at[:, : cfg.seq // 2].set(0.0)
    nll2 = jax.jit(score)(*flat, tokens, mask2)
    assert bool(jnp.all(nll2 < nll))


def test_train_step_decreases_loss_on_repeated_batch(presets):
    cfg = model_cfg(presets, "topt", "s1")
    train, specs = M.make_train_step(cfg)
    flat = _init(specs, 7)
    n = len(specs)
    m = [jnp.zeros(sp.shape, jnp.float32) for sp in specs]
    v = [jnp.zeros(sp.shape, jnp.float32) for sp in specs]
    rng = np.random.default_rng(8)
    tb = presets["train_batch"]
    tokens = jnp.asarray(rng.integers(0, 30, size=(tb, cfg.seq + 1)), jnp.int32)
    step = jax.jit(train)
    losses = []
    for t in range(8):
        out = step(*flat, *m, *v, jnp.float32(t + 1), jnp.float32(3e-3), tokens)
        flat, m, v = list(out[:n]), list(out[n : 2 * n]), list(out[2 * n : 3 * n])
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] - 0.2, losses
    assert np.isfinite(losses).all()


def test_weight_decay_only_on_decay_params(presets):
    cfg = model_cfg(presets, "topt", "s1")
    specs = model_param_specs(cfg)
    decayed = {sp.name for sp in specs if sp.decay}
    assert "l0.wq" in decayed and "embed" not in decayed and "l0.bq" not in decayed


def test_rope_rotation_preserves_norm():
    x = jnp.asarray(np.random.default_rng(9).normal(size=(1, 2, 8, 16)), jnp.float32)
    r = M._rope(x)
    np.testing.assert_allclose(
        np.asarray(jnp.linalg.norm(x, axis=-1)), np.asarray(jnp.linalg.norm(r, axis=-1)), rtol=1e-4
    )


def test_layer_param_specs_match_between_generic_and_indexed(presets):
    cfg = model_cfg(presets, "tllama", "s2")
    generic = layer_param_specs(cfg, None)
    indexed = layer_param_specs(cfg, 3)
    assert [f"l3.{s.name}" for s in generic] == [s.name for s in indexed]
    assert [s.shape for s in generic] == [s.shape for s in indexed]

"""L2 correctness: fista_solve / power_l / gram_chunk / quad_obj / prep_op."""

import numpy as np
import jax
import jax.numpy as jnp

from compile import model as M
from compile.kernels import ref


def _gram_setup(seed, m, n, p):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(m, n)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(n, p)), jnp.float32) * 0.5
    a = x @ x.T
    b = w @ a
    return w, x, a, b


def test_fista_solve_matches_ref_loop():
    w, _x, a, b = _gram_setup(0, 32, 32, 128)
    l = float(np.linalg.eigvalsh(np.asarray(a, np.float64)).max()) * 1.02
    solve = M.make_fista_solve(iters=20, tol=1e-6)
    w0 = jnp.zeros_like(w)
    got, k = jax.jit(solve)(a, b, w0, jnp.float32(0.05), jnp.float32(l))
    want = ref.fista_solve_ref(a, b, w0, 0.05, l, iters=20, tol=1e-6)
    assert int(k) > 0
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=1e-3)


def test_fista_solve_lambda_zero_recovers_w():
    # dims must be multiples of 32 (Pallas block constraint)
    w, _x, a, b = _gram_setup(1, 32, 32, 256)
    l = float(np.linalg.eigvalsh(np.asarray(a, np.float64)).max()) * 1.02
    solve = M.make_fista_solve(iters=400, tol=1e-9)
    got, _ = jax.jit(solve)(a, b, jnp.zeros_like(w), jnp.float32(0.0), jnp.float32(l))
    rel = float(jnp.linalg.norm(got - w) / jnp.linalg.norm(w))
    assert rel < 0.05, rel


def test_power_l_matches_numpy():
    _w, _x, a, _b = _gram_setup(2, 8, 48, 200)
    got = float(jax.jit(lambda a: M.power_l(a, iters=128, safety=1.0))(a))
    want = float(np.linalg.eigvalsh(np.asarray(a, np.float64)).max())
    assert abs(got - want) < 0.01 * want


def test_gram_chunk_outputs():
    rng = np.random.default_rng(3)
    xd = jnp.asarray(rng.normal(size=(64, 512)), jnp.float32)
    xs = jnp.asarray(rng.normal(size=(64, 512)), jnp.float32)
    a, c, d = jax.jit(M.gram_chunk)(xd, xs)
    np.testing.assert_allclose(np.asarray(a), np.asarray(xs @ xs.T), atol=1e-2, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(c), np.asarray(xd @ xs.T), atol=1e-2, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(d), np.asarray(xd @ xd.T), atol=1e-2, rtol=1e-4)


def test_quad_obj_and_prep_complete_the_square():
    # quad(A,B,W*) + tr(W D Wᵀ) == ‖W* X − W X‖² when X* = X
    w, x, a, b = _gram_setup(4, 8, 16, 128)
    rng = np.random.default_rng(5)
    cand = jnp.asarray(rng.normal(size=w.shape), jnp.float32)
    b_prep, c_norm = jax.jit(M.prep_op)(w, a, a)  # C = D = A when X* = X
    np.testing.assert_allclose(np.asarray(b_prep), np.asarray(b), atol=1e-2, rtol=1e-4)
    quad = float(jax.jit(M.quad_obj)(a, b, cand))
    direct = float(jnp.sum((cand @ x - w @ x) ** 2))
    assert abs(quad + float(c_norm) - direct) < 2e-2 * max(direct, 1.0)

"""The cross-language contract: manifest.json must agree with shapes.py and
every referenced HLO file must exist after `make artifacts`."""

import json
import os

import pytest

from compile.shapes import (
    all_model_cfgs,
    fista_shapes,
    gram_dims,
    load_presets,
    model_param_specs,
    pruned_ops,
)

ART = os.path.normpath(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))


@pytest.fixture(scope="module")
def manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        return json.load(f)


def test_every_artifact_file_exists(manifest):
    missing = [
        name
        for name, a in manifest["artifacts"].items()
        if not os.path.exists(os.path.join(ART, a["file"]))
    ]
    assert not missing, f"missing HLO files: {missing}"


def test_solver_artifacts_cover_all_shapes(manifest):
    presets = load_presets()
    arts = manifest["artifacts"]
    for m, n in fista_shapes(presets):
        for kind in ("fista", "obj", "prep"):
            assert f"{kind}_{m}x{n}" in arts
    for n in gram_dims(presets):
        assert f"gram_{n}" in arts
        assert f"power_{n}" in arts


def test_model_params_match_manifest_order(manifest):
    presets = load_presets()
    for cfg in all_model_cfgs(presets):
        specs = model_param_specs(cfg)
        rec = manifest["models"][cfg.name]["params"]
        assert [r["name"] for r in rec] == [s.name for s in specs]
        assert [tuple(r["dims"]) for r in rec] == [s.shape for s in specs]
        # score artifact's leading inputs are exactly the param specs
        score = manifest["artifacts"][f"score_{cfg.name}"]
        lead = score["inputs"][: len(specs)]
        assert [i["name"] for i in lead] == [s.name for s in specs]


def test_ops_capture_keys_recorded(manifest):
    presets = load_presets()
    for cfg in all_model_cfgs(presets):
        ops = manifest["models"][cfg.name]["ops"]
        assert [o["name"] for o in ops] == [nm for nm, _ in pruned_ops(cfg)]
        for o in ops:
            assert o["capture"] in ("attn_in", "o_in", "mlp_in", "mlp2_in")


def test_train_artifact_arity(manifest):
    presets = load_presets()
    cfg = all_model_cfgs(presets)[0]
    n = len(model_param_specs(cfg))
    train = manifest["artifacts"][f"train_{cfg.name}"]
    assert len(train["inputs"]) == 3 * n + 3
    assert train["outputs"] == 3 * n + 1

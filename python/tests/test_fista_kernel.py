"""L1 correctness: the Pallas fista_step kernel vs the pure-jnp oracle,
swept over shapes and inputs with hypothesis (the CORE kernel signal)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fista_step import fista_step_pallas, pick_block, vmem_footprint_bytes

DIMS = st.sampled_from([32, 64, 96, 128, 160, 256])


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


@settings(max_examples=12, deadline=None)
@given(m=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1),
       inv_l=st.floats(1e-4, 1.0), thresh=st.floats(0.0, 0.5), coef=st.floats(0.0, 0.99))
def test_fista_step_matches_ref(m, n, seed, inv_l, thresh, coef):
    rng = np.random.default_rng(seed)
    w = rand(rng, m, n)
    x = rand(rng, n, 128)
    a = x @ x.T / 128.0
    b = rand(rng, m, n)
    w23, wn = fista_step_pallas(w, a, b, inv_l, thresh, coef)
    r23, rn = ref.fista_step_ref(w, a, b, inv_l, thresh, coef)
    np.testing.assert_allclose(np.asarray(w23), np.asarray(r23), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(wn), np.asarray(rn), atol=1e-4, rtol=1e-4)


def test_kernel_produces_exact_zeros():
    # soft shrinkage must emit exact zeros (the sparsity mechanism)
    rng = np.random.default_rng(0)
    w = rand(rng, 32, 32) * 0.01
    a = jnp.eye(32, dtype=jnp.float32)
    b = jnp.zeros((32, 32), jnp.float32)
    w23, _ = fista_step_pallas(w, a, b, 1.0, 0.5, 0.0)
    assert np.count_nonzero(np.asarray(w23)) == 0


def test_pick_block():
    assert pick_block(128) == 128
    assert pick_block(96) == 32
    assert pick_block(256) == 128
    assert pick_block(160) == 32
    with pytest.raises(ValueError):
        pick_block(48)


def test_vmem_footprint_under_budget():
    # All artifact shapes must fit the 8 MiB VMEM budget (pick_blocks_3d),
    # comfortably inside a TPU core's ~16 MiB.
    for m, n in [(768, 192), (192, 768), (640, 160), (128, 512)]:
        assert vmem_footprint_bytes(m, n) <= (4 * 2 * 1024 * 1024) + 64, (m, n)


def test_nesterov_coefficient_path():
    # coef=0 reduces to plain ISTA: w_next == w23
    rng = np.random.default_rng(1)
    w = rand(rng, 64, 64)
    x = rand(rng, 64, 128)
    a = x @ x.T
    b = rand(rng, 64, 64)
    w23, wn = fista_step_pallas(w, a, b, 1e-3, 1e-2, 0.0)
    np.testing.assert_allclose(np.asarray(w23), np.asarray(wn), atol=1e-6)

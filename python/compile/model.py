"""L2: JAX compute graphs, AOT-lowered to HLO text by aot.py.

Every public builder here returns a plain jax function over f32/i32 arrays
whose *positional* argument order is recorded in artifacts/manifest.json —
the rust runtime binds literals by that order. Python never runs at request
time; these graphs execute inside the rust coordinator via PJRT.

Graph inventory (DESIGN.md §3):
  fista_solve   — K FISTA iterations (lax.while_loop) over the Pallas kernel
  power_l       — L = lambda_max(A) by power iteration (paper step size 1/L)
  gram_chunk    — A/C/D Gram accumulation for one activation chunk
  quad_obj      — tr(W A W^T) − 2<W,B>  (Gram form of the output error)
  layer capture — one decoder layer forward returning all operator inputs
                  (the intra-layer error-correction replay, paper §3.1)
  score         — full forward → per-sequence masked NLL (perplexity, probes)
  train_step    — AdamW causal-LM step (substrate: models are trained in-repo)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels.fista_step import fista_step_pallas
from .kernels.matmul_nt import matmul_nt_pallas
from .shapes import ModelCfg, ParamSpec, layer_param_specs, model_param_specs


# --------------------------------------------------------------------------
# Pruning-solver graphs
# --------------------------------------------------------------------------

def make_fista_solve(iters: int = 20, tol: float = 1e-6):
    """FISTA on the Gram form (paper eqs. 5a–5d, stop eq. 7).

    Args (runtime): A[n,n], B[m,n], W0[m,n], lam[], l_max[].
    Returns W_K = the last proximal point W_{k+2/3} (the sparse candidate
    that Algorithm 1 rounds), plus the number of iterations actually run.
    """

    def fista_solve(a, b, w0, lam, l_max):
        inv_l = 1.0 / l_max
        thresh = lam * inv_l

        def cond(state):
            k, _wk, _w23, _t, diff = state
            return jnp.logical_and(k < iters, diff >= tol)

        def body(state):
            k, w_k, _w23, t, _diff = state
            t_next = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
            coef = (t - 1.0) / t_next
            w23, w_next = fista_step_pallas(w_k, a, b, inv_l, thresh, coef)
            diff = jnp.linalg.norm(w_next - w_k)
            return k + 1, w_next, w23, t_next, diff

        init = (
            jnp.asarray(0, jnp.int32),
            w0,
            w0,
            jnp.asarray(1.0, jnp.float32),
            jnp.asarray(jnp.inf, jnp.float32),
        )
        k, _wk, w23, _t, _diff = jax.lax.while_loop(cond, body, init)
        return w23, k

    return fista_solve


def power_l(a, iters: int = 64, safety: float = 1.02):
    """Step-size constant L = lambda_max(A) (power method + Rayleigh).

    Power iteration lower-bounds lambda_max; the small safety factor keeps
    1/L a valid (slightly conservative) FISTA step size.
    """
    n = a.shape[0]
    v0 = jnp.ones((n,), jnp.float32) / jnp.sqrt(jnp.asarray(float(n), jnp.float32))

    def body(_, v):
        av = a @ v
        return av / jnp.maximum(jnp.linalg.norm(av), 1e-30)

    v = jax.lax.fori_loop(0, iters, body, v0)
    return jnp.maximum(v @ (a @ v), 1e-12) * safety


def gram_chunk(xd, xs):
    """One chunk of Gram accumulation (DESIGN.md §3.1).

    xd, xs : [n, chunk] dense / pruned-path activations (zero-padded tails
    are exact no-ops). Returns (A_c, C_c, D_c) = (Xs Xs^T, Xd Xs^T, Xd Xd^T).
    """
    a_c = matmul_nt_pallas(xs, xs)
    c_c = matmul_nt_pallas(xd, xs)
    d_c = matmul_nt_pallas(xd, xd)
    return a_c, c_c, d_c


def quad_obj(a, b, w):
    """tr(W A W^T) − 2<W, B>; add ||WX||² (from D) to get ||W X* − WX||²."""
    return jnp.sum((w @ a) * w) - 2.0 * jnp.sum(w * b)


def prep_op(w, c, d):
    """Per-operator solver prep, fused into one artifact call:

    B = W·C (the FISTA linear term, paper eq. 5a with C = X X*^T) and
    c_norm = tr(W D W^T) = ||W X||² (the constant completing the error).
    """
    b = w @ c
    c_norm = jnp.sum((w @ d) * w)
    return b, c_norm


# --------------------------------------------------------------------------
# Transformer substrate (topt = OPT-style, tllama = LLaMA-style)
# --------------------------------------------------------------------------

def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _rmsnorm(x, g, eps=1e-5):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x / jnp.sqrt(ms + eps) * g


def _rope(x, base=10000.0):
    """Rotary embeddings over [b, h, s, hd] (hd even)."""
    b, h, s, hd = x.shape
    half = hd // 2
    freqs = base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    pos = jnp.arange(s, dtype=jnp.float32)
    ang = pos[:, None] * freqs[None, :]          # [s, half]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(q, k, v, heads):
    """Causal multi-head attention. q/k/v: [b, s, d] already projected."""
    bsz, s, d = q.shape
    hd = d // heads

    def split(t):
        return t.reshape(bsz, s, heads, hd).transpose(0, 2, 1, 3)

    return split(q), split(k), split(v), hd


def _attn_merge(ctx):
    bsz, h, s, hd = ctx.shape
    return ctx.transpose(0, 2, 1, 3).reshape(bsz, s, h * hd)


def _causal_softmax(scores):
    s = scores.shape[-1]
    mask = jnp.tril(jnp.ones((s, s), jnp.bool_))
    scores = jnp.where(mask[None, None, :, :], scores, -1e30)
    return jax.nn.softmax(scores, axis=-1)


def _pdict(specs: list[ParamSpec], flat):
    assert len(specs) == len(flat), (len(specs), len(flat))
    return {sp.name: t for sp, t in zip(specs, flat)}


def _layer_fwd(cfg: ModelCfg, x, p, prefix=""):
    """One decoder layer. Returns (y, captures) where captures holds the
    input activation of every pruned operator (paper Fig. 2 replay points).

    capture keys: attn_in (input of wq/wk/wv), o_in (input of wo),
    mlp_in (input of w1 / wg+wu), mlp2_in (input of w2 / wd).
    """
    g = lambda nm: p[prefix + nm]  # noqa: E731
    if cfg.norm == "layernorm":
        h = _layernorm(x, g("ln1_g"), g("ln1_b"))
    else:
        h = _rmsnorm(x, g("rms1_g"))
    attn_in = h

    def lin(t, wname):
        y = t @ g(wname).T
        if cfg.bias:
            y = y + g("b" + wname[1])
        return y

    q, k, v = lin(h, "wq"), lin(h, "wk"), lin(h, "wv")
    qh, kh, vh, hd = _attention(q, k, v, cfg.heads)
    if cfg.pos == "rope":
        qh, kh = _rope(qh), _rope(kh)
    scores = jnp.einsum("bhsd,bhtd->bhst", qh, kh) / jnp.sqrt(jnp.asarray(float(hd), jnp.float32))
    ctx = jnp.einsum("bhst,bhtd->bhsd", _causal_softmax(scores), vh)
    o_in = _attn_merge(ctx)
    x = x + lin(o_in, "wo")

    if cfg.norm == "layernorm":
        h2 = _layernorm(x, g("ln2_g"), g("ln2_b"))
    else:
        h2 = _rmsnorm(x, g("rms2_g"))
    mlp_in = h2
    if cfg.mlp == "gelu4x":
        f1 = jax.nn.gelu(lin(h2, "w1"))
        mlp2_in = f1
        x = x + lin(f1, "w2")
    else:  # swiglu
        gate = jax.nn.silu(h2 @ g("wg").T)
        up = h2 @ g("wu").T
        mlp2_in = gate * up
        x = x + mlp2_in @ g("wd").T
    captures = {"attn_in": attn_in, "o_in": o_in, "mlp_in": mlp_in, "mlp2_in": mlp2_in}
    return x, captures


def make_layer_capture(cfg: ModelCfg):
    """Layer-generic capture artifact: (x, *layer_params) →
    (attn_in, o_in, mlp_in, mlp2_in, y). Used by the rust pruning unit to
    replay a layer under dense or partially-pruned weights (paper §3.1)."""
    specs = layer_param_specs(cfg, None)

    def capture(x, *flat):
        p = _pdict(specs, flat)
        y, c = _layer_fwd(cfg, x, p)
        return c["attn_in"], c["o_in"], c["mlp_in"], c["mlp2_in"], y

    return capture, specs


def _model_apply(cfg: ModelCfg, p, tokens):
    """Full forward: tokens [b, s] (int32) → logits [b, s, vocab]."""
    x = p["embed"][tokens]
    if cfg.pos == "learned":
        x = x + p["pos"][None, : tokens.shape[1], :]
    for li in range(cfg.layers):
        x, _ = _layer_fwd(cfg, x, p, prefix=f"l{li}.")
    if cfg.norm == "layernorm":
        x = _layernorm(x, p["lnf_g"], p["lnf_b"])
    else:
        x = _rmsnorm(x, p["rmsf_g"])
    return x @ p["embed"].T  # tied unembedding (paper: head never pruned)


def make_score(cfg: ModelCfg):
    """Score artifact: (*params, tokens[b,s+1], mask[b,s]) → nll[b].

    nll[b] = sum_t mask[b,t] * −log p(tokens[b,t+1] | tokens[b,:t+1]).
    Perplexity and the zero-shot probes are both computed from this in rust.
    """
    specs = model_param_specs(cfg)

    def score(*args):
        flat, tokens, mask = args[:-2], args[-2], args[-1]
        p = _pdict(specs, flat)
        logits = _model_apply(cfg, p, tokens[:, :-1])
        logp = jax.nn.log_softmax(logits, axis=-1)
        tgt = tokens[:, 1:]
        nll_tok = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        return jnp.sum(nll_tok * mask, axis=-1)

    return score, specs


def make_train_step(cfg: ModelCfg, beta1=0.9, beta2=0.95, eps=1e-8, wd=0.01):
    """AdamW causal-LM training step (the in-repo substrate trainer).

    Args: (*params, *m, *v, t[], lr[], tokens[B, s+1])
    Returns: (*params', *m', *v', loss[]).
    Weight decay applies only to ParamSpec.decay (2-D matmul weights).
    """
    specs = model_param_specs(cfg)
    n = len(specs)

    def loss_fn(flat, tokens):
        p = _pdict(specs, flat)
        logits = _model_apply(cfg, p, tokens[:, :-1])
        logp = jax.nn.log_softmax(logits, axis=-1)
        tgt = tokens[:, 1:]
        nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
        return jnp.mean(nll)

    def train_step(*args):
        flat = list(args[:n])
        m = list(args[n : 2 * n])
        v = list(args[2 * n : 3 * n])
        t, lr, tokens = args[3 * n], args[3 * n + 1], args[3 * n + 2]
        loss, grads = jax.value_and_grad(loss_fn)(flat, tokens)
        bc1 = 1.0 - beta1 ** t
        bc2 = 1.0 - beta2 ** t
        out_p, out_m, out_v = [], [], []
        for sp, pi, mi, vi, gi in zip(specs, flat, m, v, grads):
            mi = beta1 * mi + (1.0 - beta1) * gi
            vi = beta2 * vi + (1.0 - beta2) * gi * gi
            upd = (mi / bc1) / (jnp.sqrt(vi / bc2) + eps)
            if sp.decay:
                upd = upd + wd * pi
            out_p.append(pi - lr * upd)
            out_m.append(mi)
            out_v.append(vi)
        return (*out_p, *out_m, *out_v, loss)

    return train_step, specs

"""Build-time compile package: JAX/Pallas → HLO-text artifacts.

Never imported by the runtime — rust loads artifacts/*.hlo.txt via PJRT.
"""

"""Pure-jnp oracles for the Pallas kernels.

These are the correctness ground truth: python/tests sweeps shapes and
random inputs (hypothesis) and asserts the Pallas kernels match these to
float32 tolerance. They are also small enough to audit against the paper's
equations by eye.
"""

import jax.numpy as jnp


def softshrink_ref(x, rho):
    """Elementwise SoftShrinkage_rho (paper eq. after 5d)."""
    return jnp.sign(x) * jnp.maximum(jnp.abs(x) - rho, 0.0)


def fista_step_ref(w, a, b, inv_l, thresh, coef):
    """One fused FISTA iteration (paper eqs. 5a, 5b, 5d).

    w      : current iterate W_k (the extrapolated point)          [m, n]
    a      : Gram matrix A = X* (X*)^T                              [n, n]
    b      : B = W X (X*)^T                                        [m, n]
    inv_l  : 1/L, step size (L = lambda_max(A))
    thresh : lambda / L, shrinkage threshold
    coef   : (t_k - 1) / t_{k+1}, Nesterov combination weight

    Returns (W_{k+2/3}, W_{k+1}).
    """
    grad = w @ a - b                       # ∇f(W_k) = W_k A − B   (5a)
    w13 = w - inv_l * grad                 # gradient step          (5a)
    w23 = softshrink_ref(w13, thresh)      # proximal step          (5b)
    w_next = w23 + coef * (w23 - w)        # Nesterov combination   (5d)
    return w23, w_next


def matmul_nt_ref(x, y):
    """out = X @ Y^T — the Gram building block (A, C, D accumulation)."""
    return x @ y.T


def fista_solve_ref(a, b, w0, lam, l_max, iters=20, tol=1e-6):
    """Reference FISTA loop on the Gram form (paper eqs. 5a-5d + eq. 7 stop).

    Minimizes  ½ tr(W A W^T) − ⟨W, B⟩ + λ Σ_i ||W_i,:||_1 ,
    which equals ½||W X* − W_dense X||_F² + λΣ||·||₁ up to a constant.
    """
    inv_l = 1.0 / l_max
    thresh = lam * inv_l
    w_k = w0
    w23 = w0
    t = 1.0
    for _ in range(iters):
        t_next = 0.5 * (1.0 + (1.0 + 4.0 * t * t) ** 0.5)
        coef = (t - 1.0) / t_next
        w23, w_next = fista_step_ref(w_k, a, b, inv_l, thresh, coef)
        diff = jnp.linalg.norm(w_next - w_k)
        w_k = w_next
        t = t_next
        if float(diff) < tol:
            break
    return w23


def quad_obj_ref(a, b, w):
    """tr(W A W^T) − 2⟨W, B⟩ — the Gram form of ||W X* − WX||² − ||WX||²."""
    return jnp.sum((w @ a) * w) - 2.0 * jnp.sum(w * b)


def power_iter_ref(a, iters=64, safety=1.02):
    """Largest eigenvalue of PSD matrix A via power iteration + Rayleigh."""
    n = a.shape[0]
    v = jnp.ones((n,), a.dtype) / jnp.sqrt(jnp.asarray(float(n), a.dtype))
    for _ in range(iters):
        av = a @ v
        v = av / jnp.maximum(jnp.linalg.norm(av), 1e-30)
    return jnp.maximum(v @ (a @ v), 1e-12) * safety

"""Tiled X @ Y^T Pallas kernel — the Gram accumulation building block.

Used by the L2 `gram_chunk` graph to form A = X* X*^T, C = X X*^T and
D = X X^T from fixed-width activation chunks (DESIGN.md §3.1): zero-padded
columns contribute nothing to a Gram product, so the rust coordinator can
stream any calibration-set size through one compiled shape.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .fista_step import pick_blocks_3d


def _matmul_nt_kernel(x_ref, y_ref, o_ref, acc_ref):
    """Grid point (i, j, k): o[i,j] += x[i,k] @ y[j,k]^T."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...].T, preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _store():
        o_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def matmul_nt_pallas(x, y, interpret=True):
    """out[m, n] = x[m, p] @ y[n, p]^T with (bm, bn, bk) VMEM tiling."""
    m, p = x.shape
    n, p2 = y.shape
    assert p == p2, (x.shape, y.shape)
    # out + acc = 2 (m,n)-sized buffers in VMEM (§Perf: see pick_blocks_3d)
    bm, bn, bk = pick_blocks_3d(m, n, p, weight_bufs=2)
    return pl.pallas_call(
        _matmul_nt_kernel,
        grid=(m // bm, n // bn, p // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bn, bk), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, y)

"""Fused FISTA iteration as a Pallas kernel (the paper's compute hot-spot).

One iteration of paper eqs. (5a), (5b), (5d) on the Gram form:

    grad   = W_k A − B                     (5a, gradient of ½||W X* − WX||²)
    W_13   = W_k − (1/L) grad              (5a, gradient step)
    W_23   = SoftShrink_{λ/L}(W_13)        (5b, proximal step)
    W_next = W_23 + coef (W_23 − W_k)      (5d, Nesterov combination)

Hardware adaptation (DESIGN.md §6): the paper runs these as separate cuBLAS/
elementwise CUDA launches on A100s, round-tripping W through HBM three times
per iteration. On a TPU-shaped memory hierarchy we instead tile W into
(bm × bn) VMEM-resident blocks, stream A through the grid's contraction
dimension so each partial product is an MXU-shaped matmul, and apply the
shrinkage + Nesterov epilogue in-register on the final contraction step —
one HBM read and one HBM write of W per iteration.

interpret=True throughout: the CPU PJRT plugin cannot execute Mosaic
custom-calls; correctness is validated against kernels/ref.py and real-TPU
efficiency is estimated analytically in EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def pick_block(dim: int, preferred=(128, 64, 32)) -> int:
    """Largest MXU-friendly block size that divides `dim`.

    All operator dims in configs/presets.json are multiples of 32; on a real
    TPU the 128-lane choice maps a block row/column onto full MXU tiles.
    """
    for b in preferred:
        if dim % b == 0:
            return b
    raise ValueError(f"dimension {dim} is not a multiple of 32")


# §Perf budget: stay well under a TPU core's ~16 MiB of VMEM so weights,
# Gram panel, outputs and the accumulator co-reside with double-buffering
# headroom. 2 MiF (f32 words) ≈ 8 MiB.
VMEM_BUDGET_F32 = 2 * 1024 * 1024


def _divisor_blocks(dim: int):
    """Divisors of dim that are multiples of 32, descending."""
    return [b for b in range(dim, 31, -32) if dim % b == 0]


def pick_blocks_3d(m: int, n: int, k: int, weight_bufs: int = 5) -> tuple:
    """(bm, bn, bk) maximizing block volume under the VMEM budget.

    §Perf L1 optimization (EXPERIMENTS.md): the original fixed 32–128
    blocks produced O(100) grid steps per FISTA iteration; on CPU-interpret
    the per-step overhead dominated, and on a real TPU small blocks
    under-fill the MXU pipeline. Larger blocks shrink the grid — often to a
    single step for our operator shapes — while the VMEM estimate
    (`bm·bk + bk·bn + weight_bufs·bm·bn`) stays inside the budget.
    """
    best = None
    for bm in _divisor_blocks(m):
        for bn in _divisor_blocks(n):
            for bk in _divisor_blocks(k):
                vmem = bm * bk + bk * bn + weight_bufs * bm * bn
                if vmem > VMEM_BUDGET_F32:
                    continue
                # minimize grid steps; tiebreak toward larger k-panels
                steps = (m // bm) * (n // bn) * (k // bk)
                key = (steps, -bk, -(bm * bn))
                if best is None or key < best[0]:
                    best = (key, (bm, bn, bk))
    if best is None:
        raise ValueError(f"no feasible blocks for {m}x{n}x{k}")
    return best[1]


def _fista_kernel(w_mm_ref, a_ref, w_el_ref, b_ref, s_ref, w23_ref, wnext_ref, acc_ref):
    """Grid point (i, j, k): accumulate block (i,j) of W_k @ A over k panels.

    w_mm_ref : W_k block (bm, bk) at (i, k)   — matmul operand
    a_ref    : A   block (bk, bn) at (k, j)
    w_el_ref : W_k block (bm, bn) at (i, j)   — elementwise operand
    b_ref    : B   block (bm, bn) at (i, j)
    s_ref    : scalars [inv_l, thresh, coef]
    acc_ref  : VMEM scratch accumulator (bm, bn)
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(w_mm_ref[...], a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        inv_l = s_ref[0]
        thresh = s_ref[1]
        coef = s_ref[2]
        w_blk = w_el_ref[...]
        w13 = w_blk - inv_l * (acc_ref[...] - b_ref[...])
        w23 = jnp.sign(w13) * jnp.maximum(jnp.abs(w13) - thresh, 0.0)
        w23_ref[...] = w23
        wnext_ref[...] = w23 + coef * (w23 - w_blk)


@functools.partial(jax.jit, static_argnames=("interpret",))
def fista_step_pallas(w, a, b, inv_l, thresh, coef, interpret=True):
    """Fused FISTA step. Returns (W_{k+2/3}, W_{k+1}). See module docstring."""
    m, n = w.shape
    assert a.shape == (n, n) and b.shape == (m, n)
    bm, bn, bk = pick_blocks_3d(m, n, n)
    scalars = jnp.stack(
        [jnp.asarray(inv_l, jnp.float32), jnp.asarray(thresh, jnp.float32), jnp.asarray(coef, jnp.float32)]
    )
    grid = (m // bm, n // bn, n // bk)
    out_shape = [
        jax.ShapeDtypeStruct((m, n), jnp.float32),
        jax.ShapeDtypeStruct((m, n), jnp.float32),
    ]
    return tuple(
        pl.pallas_call(
            _fista_kernel,
            grid=grid,
            in_specs=[
                pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),  # W for matmul
                pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),  # A panel
                pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),  # W for epilogue
                pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),  # B
                pl.BlockSpec((3,), lambda i, j, k: (0,)),        # scalars
            ],
            out_specs=[
                pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
                pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
            ],
            out_shape=out_shape,
            scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
            interpret=interpret,
        )(w, a, w, b, scalars)
    )


def vmem_footprint_bytes(m: int, n: int) -> int:
    """Analytic VMEM working set of one grid step (EXPERIMENTS.md §Perf)."""
    bm, bn, bk = pick_blocks_3d(m, n, n)
    blocks = bm * bk + bk * bn + 3 * (bm * bn) + bm * bn  # inputs + outputs + acc
    return 4 * blocks + 12  # f32 + 3 scalars

"""L1 Pallas kernels (interpret=True) + their pure-jnp oracles."""

from .fista_step import fista_step_pallas  # noqa: F401
from .matmul_nt import matmul_nt_pallas  # noqa: F401
from . import ref  # noqa: F401

"""Shape manifest shared between the python compile path and the rust runtime.

Everything is derived from configs/presets.json — the single source of truth.
The rust side reads the same file through its own JSON parser; the two sides
meet at artifacts/manifest.json, which records the exact input order, shapes
and dtypes of every lowered artifact.
"""

from __future__ import annotations

import json
import os
from dataclasses import dataclass, field

_HERE = os.path.dirname(os.path.abspath(__file__))
PRESETS_PATH = os.path.normpath(os.path.join(_HERE, "..", "..", "configs", "presets.json"))


def load_presets(path: str = PRESETS_PATH) -> dict:
    with open(path) as f:
        return json.load(f)


@dataclass(frozen=True)
class ModelCfg:
    """Resolved configuration for one (family, size) model."""

    family: str
    size: str
    d: int
    layers: int
    heads: int
    ffn: int
    vocab: int
    seq: int
    norm: str
    mlp: str
    pos: str
    bias: bool

    @property
    def head_dim(self) -> int:
        return self.d // self.heads

    @property
    def name(self) -> str:
        return f"{self.family}-{self.size}"


def model_cfg(presets: dict, family: str, size: str) -> ModelCfg:
    fam = presets["families"][family]
    sz = fam["sizes"][size]
    return ModelCfg(
        family=family,
        size=size,
        d=sz["d"],
        layers=sz["layers"],
        heads=sz["heads"],
        ffn=sz["ffn"],
        vocab=presets["vocab_size"],
        seq=presets["seq_len"],
        norm=fam["norm"],
        mlp=fam["mlp"],
        pos=fam["pos"],
        bias=fam["bias"],
    )


def all_model_cfgs(presets: dict) -> list[ModelCfg]:
    out = []
    for family, fam in presets["families"].items():
        for size in fam["sizes"]:
            out.append(model_cfg(presets, family, size))
    return out


@dataclass(frozen=True)
class ParamSpec:
    """One model parameter: name, shape, and whether weight decay applies."""

    name: str
    shape: tuple
    decay: bool = False


def layer_param_specs(cfg: ModelCfg, li: int | None = None) -> list[ParamSpec]:
    """Parameters of one decoder layer, in canonical order.

    `li` prefixes names with the layer index (None for the layer-generic
    capture artifact).
    """
    p = f"l{li}." if li is not None else ""
    d, ffn = cfg.d, cfg.ffn
    specs: list[ParamSpec] = []
    if cfg.norm == "layernorm":
        specs += [ParamSpec(p + "ln1_g", (d,)), ParamSpec(p + "ln1_b", (d,))]
    else:
        specs += [ParamSpec(p + "rms1_g", (d,))]
    for nm in ("wq", "wk", "wv", "wo"):
        specs.append(ParamSpec(p + nm, (d, d), decay=True))
        if cfg.bias:
            specs.append(ParamSpec(p + "b" + nm[1], (d,)))
    if cfg.norm == "layernorm":
        specs += [ParamSpec(p + "ln2_g", (d,)), ParamSpec(p + "ln2_b", (d,))]
    else:
        specs += [ParamSpec(p + "rms2_g", (d,))]
    if cfg.mlp == "gelu4x":
        specs.append(ParamSpec(p + "w1", (ffn, d), decay=True))
        if cfg.bias:
            specs.append(ParamSpec(p + "b1", (ffn,)))
        specs.append(ParamSpec(p + "w2", (d, ffn), decay=True))
        if cfg.bias:
            specs.append(ParamSpec(p + "b2", (d,)))
    else:  # swiglu
        specs.append(ParamSpec(p + "wg", (ffn, d), decay=True))
        specs.append(ParamSpec(p + "wu", (ffn, d), decay=True))
        specs.append(ParamSpec(p + "wd", (d, ffn), decay=True))
    return specs


def model_param_specs(cfg: ModelCfg) -> list[ParamSpec]:
    """All parameters of the model, in the canonical (manifest) order."""
    specs = [ParamSpec("embed", (cfg.vocab, cfg.d), decay=False)]
    if cfg.pos == "learned":
        specs.append(ParamSpec("pos", (cfg.seq, cfg.d)))
    for li in range(cfg.layers):
        specs += layer_param_specs(cfg, li)
    if cfg.norm == "layernorm":
        specs += [ParamSpec("lnf_g", (cfg.d,)), ParamSpec("lnf_b", (cfg.d,))]
    else:
        specs += [ParamSpec("rmsf_g", (cfg.d,))]
    return specs


# Linear operators pruned per layer, in the paper's sequential order
# (q,k,v share an input; o follows attention; then the MLP pair/triple).
def pruned_ops(cfg: ModelCfg) -> list[tuple]:
    """(op name, (m, n)) in intra-layer pruning order."""
    d, ffn = cfg.d, cfg.ffn
    ops = [("wq", (d, d)), ("wk", (d, d)), ("wv", (d, d)), ("wo", (d, d))]
    if cfg.mlp == "gelu4x":
        ops += [("w1", (ffn, d)), ("w2", (d, ffn))]
    else:
        ops += [("wg", (ffn, d)), ("wu", (ffn, d)), ("wd", (d, ffn))]
    return ops


def fista_shapes(presets: dict) -> list[tuple]:
    """Distinct (m, n) shapes across all pruned operators of all models."""
    seen = set()
    for cfg in all_model_cfgs(presets):
        for _, mn in pruned_ops(cfg):
            seen.add(mn)
    return sorted(seen)


def gram_dims(presets: dict) -> list[int]:
    """Distinct operator-input dims n (Gram matrices are n×n)."""
    return sorted({mn[1] for mn in fista_shapes(presets)})

"""AOT compile path: lower every L2 graph to HLO text + write the manifest.

Run once via `make artifacts` (python -m compile.aot). The rust runtime is
self-contained afterwards: it loads artifacts/*.hlo.txt through
HloModuleProto::from_text_file and binds inputs by the order recorded in
artifacts/manifest.json.

HLO *text* is the interchange format (NOT serialized protos): jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .shapes import (
    PRESETS_PATH,
    all_model_cfgs,
    fista_shapes,
    gram_dims,
    load_presets,
    model_param_specs,
    layer_param_specs,
    pruned_ops,
)

F32 = "f32"
I32 = "i32"
_DTYPES = {F32: jnp.float32, I32: jnp.int32}

# Which capture output feeds which pruned operator (paper Fig. 2 topology).
CAPTURE_KEY = {
    "wq": "attn_in", "wk": "attn_in", "wv": "attn_in", "wo": "o_in",
    "w1": "mlp_in", "w2": "mlp2_in",
    "wg": "mlp_in", "wu": "mlp_in", "wd": "mlp2_in",
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(dims, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(dims), _DTYPES[dtype])


class Builder:
    def __init__(self, out_dir: str, only: str | None = None, force: bool = False):
        self.out_dir = out_dir
        self.only = only
        self.force = force
        self.manifest_artifacts: dict = {}
        os.makedirs(out_dir, exist_ok=True)

    def emit(self, name: str, fn, inputs: list, n_outputs: int, meta: dict | None = None):
        """Lower fn over `inputs` = [(arg name, dims, dtype)] and record it."""
        entry = {
            "file": f"{name}.hlo.txt",
            "inputs": [{"name": nm, "dims": list(dims), "dtype": dt} for nm, dims, dt in inputs],
            "outputs": n_outputs,
            "meta": meta or {},
        }
        self.manifest_artifacts[name] = entry
        if self.only and self.only not in name:
            return
        path = os.path.join(self.out_dir, entry["file"])
        if not self.force and os.path.exists(path) and os.path.getmtime(path) > os.path.getmtime(PRESETS_PATH):
            return
        t0 = time.time()
        specs = [_spec(dims, dt) for _, dims, dt in inputs]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  {name}: {len(text)} chars in {time.time() - t0:.1f}s", flush=True)


def build_all(out_dir: str, only: str | None = None, force: bool = False) -> dict:
    presets = load_presets()
    fista_cfg = presets["fista"]
    chunk = presets["gram_chunk"]
    b = Builder(out_dir, only=only, force=force)

    # ---- solver artifacts (shape-specialized, model-agnostic) ----
    solve = M.make_fista_solve(iters=fista_cfg["max_iters"], tol=fista_cfg["stop_tol"])
    for m, n in fista_shapes(presets):
        b.emit(
            f"fista_{m}x{n}",
            solve,
            [("a", (n, n), F32), ("b", (m, n), F32), ("w0", (m, n), F32), ("lam", (), F32), ("l_max", (), F32)],
            2,
            meta={"kind": "fista", "m": m, "n": n, "iters": fista_cfg["max_iters"]},
        )
        b.emit(
            f"obj_{m}x{n}",
            M.quad_obj,
            [("a", (n, n), F32), ("b", (m, n), F32), ("w", (m, n), F32)],
            1,
            meta={"kind": "obj", "m": m, "n": n},
        )
        b.emit(
            f"prep_{m}x{n}",
            M.prep_op,
            [("w", (m, n), F32), ("c", (n, n), F32), ("d", (n, n), F32)],
            2,
            meta={"kind": "prep", "m": m, "n": n},
        )
    for n in gram_dims(presets):
        b.emit(
            f"gram_{n}",
            M.gram_chunk,
            [("xd", (n, chunk), F32), ("xs", (n, chunk), F32)],
            3,
            meta={"kind": "gram", "n": n, "chunk": chunk},
        )
        b.emit(
            f"power_{n}",
            lambda a: M.power_l(a, iters=fista_cfg["power_iters"], safety=fista_cfg["power_safety"]),
            [("a", (n, n), F32)],
            1,
            meta={"kind": "power", "n": n},
        )

    # ---- per-model artifacts ----
    cb = presets["capture_batch"]
    tb = presets["train_batch"]
    seq = presets["seq_len"]
    td = presets["train_defaults"]
    models_meta = {}
    for cfg in all_model_cfgs(presets):
        lspecs = layer_param_specs(cfg, None)
        capture, _ = M.make_layer_capture(cfg)
        b.emit(
            f"capture_{cfg.name}",
            capture,
            [("x", (cb, seq, cfg.d), F32)] + [(sp.name, sp.shape, F32) for sp in lspecs],
            5,
            meta={"kind": "capture", "model": cfg.name, "captures": ["attn_in", "o_in", "mlp_in", "mlp2_in", "y"]},
        )
        score, mspecs = M.make_score(cfg)
        b.emit(
            f"score_{cfg.name}",
            score,
            [(sp.name, sp.shape, F32) for sp in mspecs]
            + [("tokens", (cb, seq + 1), I32), ("mask", (cb, seq), F32)],
            1,
            meta={"kind": "score", "model": cfg.name},
        )
        train, _ = M.make_train_step(
            cfg, beta1=td["beta1"], beta2=td["beta2"], wd=td["weight_decay"]
        )
        b.emit(
            f"train_{cfg.name}",
            train,
            [(sp.name, sp.shape, F32) for sp in mspecs]
            + [("m." + sp.name, sp.shape, F32) for sp in mspecs]
            + [("v." + sp.name, sp.shape, F32) for sp in mspecs]
            + [("t", (), F32), ("lr", (), F32), ("tokens", (tb, seq + 1), I32)],
            3 * len(mspecs) + 1,
            meta={"kind": "train", "model": cfg.name},
        )
        models_meta[cfg.name] = {
            "family": cfg.family,
            "size": cfg.size,
            "d": cfg.d,
            "layers": cfg.layers,
            "heads": cfg.heads,
            "ffn": cfg.ffn,
            "params": [
                {"name": sp.name, "dims": list(sp.shape), "decay": sp.decay}
                for sp in model_param_specs(cfg)
            ],
            "layer_params": [
                {"name": sp.name, "dims": list(sp.shape), "decay": sp.decay} for sp in lspecs
            ],
            "ops": [
                {"name": nm, "m": mn[0], "n": mn[1], "capture": CAPTURE_KEY[nm]}
                for nm, mn in pruned_ops(cfg)
            ],
        }

    manifest = {
        "seq_len": seq,
        "vocab_size": presets["vocab_size"],
        "capture_batch": cb,
        "train_batch": tb,
        "gram_chunk": chunk,
        "fista": fista_cfg,
        "models": models_meta,
        "artifacts": b.manifest_artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    print(f"manifest: {len(b.manifest_artifacts)} artifacts")
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None, help="output dir (default <repo>/artifacts)")
    ap.add_argument("--only", default=None, help="substring filter: only lower matching artifacts")
    ap.add_argument("--force", action="store_true", help="re-lower even if up to date")
    args = ap.parse_args()
    out = args.out or os.path.normpath(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    build_all(out, only=args.only, force=args.force)


if __name__ == "__main__":
    main()

//! Quickstart: train a tiny model, prune it with FISTAPruner at 50%
//! unstructured sparsity, and compare held-out perplexity.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the smallest preset (topt-s1) and short training so it finishes in
//! about a minute on CPU. See prune_pipeline.rs for the full experiment.

use fistapruner::bench_support::Lab;
use fistapruner::config::PruneOptions;
use fistapruner::pruner::scheduler::Method;

fn main() -> anyhow::Result<()> {
    let mut lab = Lab::new()?;
    let (model, corpus) = ("topt-s1", "wikitext-syn");

    println!("== FISTAPruner quickstart: {model} on {corpus} ==");
    println!("[1/4] train (or load cached checkpoint)");
    let dense = lab.trained(model, corpus)?;

    println!("[2/4] sample calibration data ({} sequences)", lab.calib_samples());
    let calib = lab.calib(corpus, lab.calib_samples(), 0)?;

    println!("[3/4] prune with FISTAPruner (Algorithm 1, 50% unstructured)");
    let opts = PruneOptions::default();
    let (pruned, report) = lab.prune(model, &dense, &calib, Method::Fista, &opts)?;
    println!("      {}", report.summary());

    println!("[4/4] evaluate");
    let ppl_dense = lab.ppl(model, &dense, corpus)?;
    let ppl_pruned = lab.ppl(model, &pruned, corpus)?;
    println!();
    println!("held-out perplexity: dense {ppl_dense:.2} → 50% sparse {ppl_pruned:.2}");
    println!("achieved weight sparsity: {:.1}%", pruned.weight_sparsity() * 100.0);
    Ok(())
}

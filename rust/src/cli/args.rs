//! Minimal CLI argument parser (clap substrate): `fistapruner <cmd>
//! [--flag value]... [--switch]...`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Parsed command line.
#[derive(Debug, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        match it.next() {
            Some(cmd) if !cmd.starts_with("--") => out.command = cmd.clone(),
            Some(cmd) => bail!("expected a subcommand before '{cmd}'"),
            None => out.command = "help".to_string(),
        }
        while let Some(arg) = it.next() {
            let Some(name) = arg.strip_prefix("--") else {
                bail!("unexpected positional argument '{arg}'");
            };
            match it.peek() {
                Some(v) if !v.starts_with("--") => {
                    out.flags.insert(name.to_string(), it.next().unwrap().clone());
                }
                _ => out.switches.push(name.to_string()),
            }
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn req(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow::anyhow!("missing required flag --{name}"))
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            Some(v) => Ok(v.parse()?),
            None => Ok(default),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn parses_flags_and_switches() {
        let a = parse(&["prune", "--model", "topt-s1", "--sparsity", "2:4", "--no-correction"]);
        assert_eq!(a.command, "prune");
        assert_eq!(a.get("model"), Some("topt-s1"));
        assert_eq!(a.get("sparsity"), Some("2:4"));
        assert!(a.has("no-correction"));
        assert!(!a.has("workers"));
        assert_eq!(a.usize_or("workers", 2).unwrap(), 2);
    }

    #[test]
    fn rejects_positional_after_command() {
        let argv: Vec<String> = vec!["prune".into(), "stray".into()];
        assert!(Args::parse(&argv).is_err());
    }

    #[test]
    fn missing_required_flag_errors() {
        let a = parse(&["train"]);
        assert!(a.req("model").is_err());
    }
}

//! CLI: `fistapruner <command>`.
//!
//! Commands:
//!   info                         — model/corpus/artifact inventory
//!   train     --model --corpus [--steps --seed]
//!   prune     --model --corpus [--method --sparsity --mode --workers ...]
//!   eval      --model --corpus [--ckpt]
//!   zeroshot  --model --corpus [--ckpt --items]
//!   serve     --model --corpus [--batch --queue --format csr|nm|auto ...]
//!   serve-bench [--model --smoke --format csr|nm|auto --json path ...]
//!   trace     --in capture.jsonl [--csv path --fail-on-drops]
//!   pipeline  --model --corpus [--sparsity ...]   (train→prune×methods→eval)

pub mod args;
mod commands;

use anyhow::{bail, Result};

use args::Args;

pub fn main() -> Result<()> {
    crate::util::logging::init();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv)?;
    match args.command.as_str() {
        "info" => commands::info(&args),
        "train" => commands::train(&args),
        "prune" => commands::prune(&args),
        "eval" => commands::eval(&args),
        "zeroshot" => commands::zeroshot(&args),
        "generate" => commands::generate(&args),
        "serve" => commands::serve(&args),
        "serve-bench" => commands::serve_bench(&args),
        "trace" => commands::trace(&args),
        "pipeline" => commands::pipeline(&args),
        "help" | "--help" | "-h" => {
            print!("{}", USAGE);
            Ok(())
        }
        other => bail!("unknown command '{other}'\n{USAGE}"),
    }
}

const USAGE: &str = "\
fistapruner — convex-optimization-based layer-wise post-training pruning

USAGE: fistapruner <command> [flags]

COMMANDS:
  info                              inventory of models, corpora, artifacts
  train     --model M --corpus C    train a substrate model
            [--steps N --seed S]
  prune     --model M --corpus C    prune a trained model
            [--method dense|fista|admm|fw|sparsegpt|wanda|magnitude]
            [--solver fista|admm|fw] Algorithm-1 layer solver (algorithm
                                    axis; orthogonal to --engine, the
                                    execution axis)
            [--sparsity 0.5|50%|2:4] [--mode sequential|parallel]
            [--workers N] [--threads N] [--engine xla|native]
            [--no-correction] [--calib N --seed S] [--out path.fpt]
            [--trace-out t.jsonl]   one solver_round event per tuning
                                    round (inspect with `trace`)
            [--emit-sparse [path.fsa] --format csr|nm|auto]
            [--quant none|f16|int8]  quantize the compressed values once
                                    at compile time (int8 = per-row
                                    absmax scales); the artifact then
                                    serves quantized end to end
            (--emit-sparse compiles the pruned weights once and writes
             the compressed artifact + .meta.json sidecar — no dense
             round-trip; default path under artifacts/sparse/)
  eval      --model M --corpus C    held-out perplexity
            [--ckpt path.fpt]
            [--artifact path.fsa]   score a sparse artifact directly
                                    (dense operators never materialized)
  zeroshot  --model M --corpus C    the 7 synthetic probe tasks
            [--ckpt path.fpt --items N]
  generate  --model M --corpus C    sample text from a (pruned) model
            [--ckpt path.fpt --prompt STR --tokens N --temp T]
  serve     --model M --corpus C    continuous-batching JSONL server
            [--ckpt path.fpt --format csr|nm|auto --sparsity S]
            [--artifact path.fsa]   serve a sparse artifact: compressed
                                    weights are the only copy in memory
            [--weights dense|csr --batch N --queue N]
            [--kernel scalar|simd]  kernel variant for every decode
                                    matmul (simd needs a build with
                                    --features simd; quantization is
                                    auto-detected from the artifact)
            [--kv-page N]           positions per KV page (default 16)
            [--kv-pages N]          KV page budget (default: full context
                                    for every slot; shrink to backpressure)
            [--prefill-chunk N]     prefill tokens per step (default 16):
                                    long prompts warm up chunk by chunk,
                                    interleaved with the decode batch
            [--transcript out.jsonl --synthetic N --tokens N --temp T]
            [--listen ADDR]         TCP front-end (e.g. 127.0.0.1:7433):
                                    many concurrent JSONL connections on
                                    one engine; responses routed per conn
            [--max-conns N]         concurrent connection cap (default 64)
            [--conn-timeout MS]     idle + per-line (slowloris) timeout,
                                    ms (default 30000)
            [--max-line N]          per-line byte cap, stdin and socket
                                    (default 1 MiB)
            [--write-buf N]         response lines buffered per conn
                                    before a non-reading client is
                                    dropped (default 64)
            [--event-log out.jsonl] raw tee of every in/out line with
                                    conn id + seq, for offline replay
            [--trace-out t.jsonl]   structured trace: request lifecycle
                                    spans, per-step engine gauges, conn
                                    spans (inspect with `trace`); served
                                    bytes stay bitwise identical
            (reads one JSON request per stdin line unless
             --synthetic/--listen; a `{\"type\":\"stats\"}` line on a
             --listen conn returns a live counters/gauges/histograms
             snapshot without perturbing in-flight streams)
  serve-bench                       tokens/s + p50/p99: full recompute vs
            [--model M --smoke]     KV-cached vs compressed decode (csr,
            [--format csr|nm|auto]  plus packed n:m side by side), parity
            [--artifact path.fsa]   artifact path: load ms + on-disk and
                                    resident bytes vs the dense ckpt
            [--paged]               paged-KV axis: resident KV bytes vs
                                    monolithic + prefill-stall p99 with
                                    vs without chunking
            [--net]                 network axis: sustained req/s + stream
                                    p99 with N loopback clients, churn and
                                    a mid-stream disconnect, through the
                                    real --listen front-end (parity-gated)
            [--clients N --reqs-per-client N --no-churn]
            [--kernel scalar,simd]  kernel axis: tokens/s, resident bytes
                                    and effective GB/s per kernel ×
                                    quant cell (BENCH_kernel.json)
            [--quant none,f16,int8] quant modes for the kernel axis
                                    (default: all three)
            [--kv-page N --prefill-chunk N]
            [--tokens N --batch N --requests N --sparsity S --json path]
            [--trace-out t.jsonl]   trace every measured engine run
  trace     --in capture.jsonl      analyze a --trace-out capture:
            [--csv path]            request waterfalls, phase totals,
            [--fail-on-drops]       per-solver convergence tables and
                                    iteration counts; exits non-zero on
                                    dropped events with --fail-on-drops
  pipeline  --model M --corpus C    end-to-end: train → prune (all
            [--sparsity S]          methods) → perplexity table

ENV: FISTAPRUNER_LOG=debug|info|warn|error, FP_TRAIN_STEPS, FP_CALIB,
     FP_EVAL_WINDOWS, FP_BENCH_FAST=1, FP_THREADS=N (kernel threads)

Without artifacts/ (clean checkout) everything except `train` runs on the
native multithreaded kernels; `--engine` defaults to what is available.
";

//! CLI command implementations, all built on `bench_support::Lab`.

use anyhow::{bail, Result};

use crate::bench_support::Lab;
use crate::config::{
    Engine, PruneMode, PruneOptions, SparseFormat, Sparsity, TrainOptions, WarmStart,
};
use crate::metrics::TableBuilder;
use crate::model::spec::param_count;
use crate::pruner::scheduler::Method;
use crate::ser::checkpoint::{self, CheckpointMeta};

use super::args::Args;

pub fn info(_args: &Args) -> Result<()> {
    let lab = Lab::new()?;
    let mut t = TableBuilder::new("Models", &["name", "d", "layers", "heads", "ffn", "params"]);
    for (name, spec) in &lab.presets.models {
        t.row(vec![
            name.clone(),
            spec.d.to_string(),
            spec.layers.to_string(),
            spec.heads.to_string(),
            spec.ffn.to_string(),
            format!("{:.2}M", param_count(spec) as f64 / 1e6),
        ]);
    }
    t.print();
    let mut c = TableBuilder::new("Corpora", &["name", "word vocab", "zipf", "noise", "chars"]);
    for (name, cfg) in &lab.presets.corpora {
        c.row(vec![
            name.clone(),
            cfg.word_vocab.to_string(),
            format!("{:.2}", cfg.zipf_s),
            format!("{:.2}", cfg.noise),
            cfg.chars.to_string(),
        ]);
    }
    c.print();
    match lab.session() {
        Some(s) => println!(
            "artifacts: {} in manifest; session compiled {}; kernel threads: {}",
            s.manifest().artifacts.len(),
            s.compiled_count(),
            crate::tensor::par::effective_threads(),
        ),
        None => println!(
            "artifacts: unavailable (native-only mode); kernel threads: {}",
            crate::tensor::par::effective_threads()
        ),
    }
    Ok(())
}

fn prune_options(lab: &Lab, args: &Args) -> Result<PruneOptions> {
    let engine = match args.get("engine") {
        Some(s) => Engine::parse(s)?,
        None => lab.default_engine(),
    };
    Ok(PruneOptions {
        sparsity: Sparsity::parse(args.get_or("sparsity", "0.5"))?,
        engine,
        mode: PruneMode::parse(args.get_or("mode", "sequential"))?,
        warm_start: WarmStart::parse(args.get_or("warm-start", "auto"))?,
        error_correction: !args.has("no-correction"),
        workers: args.usize_or("workers", 2)?,
        threads: args.usize_or("threads", 0)?,
        max_rounds: args.get("max-rounds").map(|v| v.parse()).transpose()?,
        seed: args.u64_or("seed", 0)?,
        solver: crate::config::SolverKind::Fista,
    })
}

fn train_options(lab: &Lab, args: &Args) -> Result<TrainOptions> {
    let steps = args.usize_or("steps", lab.train_steps())?;
    Ok(TrainOptions {
        steps,
        lr: args.f64_or("lr", lab.presets.train.lr)?,
        warmup: lab.presets.train.warmup.min(steps / 4),
        seed: args.u64_or("seed", lab.presets.train.seed)?,
    })
}

pub fn train(args: &Args) -> Result<()> {
    let mut lab = Lab::new()?;
    let model = args.req("model")?.to_string();
    let corpus = args.req("corpus")?.to_string();
    let opts = train_options(&lab, args)?;
    let spec = lab.presets.model(&model)?.clone();
    lab.corpus(&corpus)?;
    let c = crate::data::Corpus::generate(lab.presets.corpus(&corpus)?);
    let res = crate::train::train(lab.require_session()?, &lab.presets, &spec, &c, &opts)?;
    println!("final loss: {:.4}", res.final_loss);
    let path = args
        .get("out")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| {
            checkpoint::default_path(&lab.root.join("artifacts"), &model, &corpus, opts.steps, opts.seed)
        });
    checkpoint::save(
        &path,
        &res.params,
        &CheckpointMeta {
            model: model.clone(),
            corpus,
            steps: opts.steps,
            final_loss: res.final_loss,
            seed: opts.seed,
        },
    )?;
    println!("saved: {}", path.display());
    Ok(())
}

fn load_or_train(lab: &mut Lab, args: &Args, model: &str, corpus: &str) -> Result<crate::model::ModelParams> {
    if let Some(ckpt) = args.get("ckpt") {
        let (params, meta) = checkpoint::load(std::path::Path::new(ckpt))?;
        checkpoint::check_model(&meta, model)?;
        return Ok(params);
    }
    // Without train artifacts this falls back to deterministic init
    // weights (with a logged warning) so every command still runs on a
    // clean checkout.
    lab.trained_or_init(model, corpus)
}

pub fn prune(args: &Args) -> Result<()> {
    let mut lab = Lab::new()?;
    let model = args.req("model")?.to_string();
    let corpus = args.req("corpus")?.to_string();
    let mut method = Method::parse(args.get_or("method", "fista"))?;
    // --solver selects the Algorithm-1 layer solver; it composes with (and
    // overrides) the solver implied by --method, but cannot turn a
    // baseline/dense run into a solver run.
    if let Some(s) = args.get("solver") {
        let kind = crate::config::SolverKind::parse(s)?;
        match method {
            Method::Solver(k) => {
                if args.get("method").is_some() && k != kind {
                    bail!(
                        "--method {} conflicts with --solver {}; drop one",
                        method.name(),
                        kind.name()
                    );
                }
                method = Method::Solver(kind);
            }
            Method::Dense | Method::Baseline(_) => {
                bail!("--solver only applies to solver methods, not --method {}", method.name())
            }
        }
    }
    let mut opts = prune_options(&lab, args)?;
    if let Method::Solver(k) = method {
        opts.solver = k;
    }
    let calib_n = args.usize_or("calib", lab.calib_samples())?;
    let dense = load_or_train(&mut lab, args, &model, &corpus)?;
    let calib = lab.calib(&corpus, calib_n, opts.seed)?;
    let (pruned, report) = lab.prune(&model, &dense, &calib, method, &opts)?;
    println!("{}", report.summary());
    let ppl_dense = lab.ppl(&model, &dense, &corpus)?;
    let ppl_pruned = lab.ppl(&model, &pruned, &corpus)?;
    println!("perplexity: dense {ppl_dense:.2} → pruned {ppl_pruned:.2}");
    // --trace-out: one `solver_round` point per tuning round, replayed
    // from the report's convergence history (the pruner itself stays
    // recorder-free — worker threads carry plain data, not channels).
    if let Some(path) = args.get("trace-out") {
        use crate::ser::Json;
        let (rec, writer) = crate::obs::Recorder::to_file(
            std::path::Path::new(path),
            crate::obs::SharedClock::default(),
        )?;
        for layer in &report.layers {
            for op in &layer.ops {
                let id = format!("L{}:{}", op.layer, op.op);
                for rs in &op.rounds_detail {
                    rec.point(
                        "solver_round",
                        &id,
                        vec![
                            ("solver", Json::Str(op.solver.clone())),
                            ("round", Json::Num(rs.round as f64)),
                            ("lambda", Json::Num(rs.lambda)),
                            ("objective", Json::Num(rs.objective)),
                            ("residual", Json::Num(rs.residual)),
                            ("support", Json::Num(rs.support as f64)),
                            ("iters", Json::Num(rs.iters as f64)),
                            ("primal", Json::Num(rs.primal)),
                            ("dual", Json::Num(rs.dual)),
                            ("gap", Json::Num(rs.gap)),
                        ],
                    );
                }
            }
        }
        drop(rec);
        let stats = writer.finish()?;
        println!("trace: {path} ({} events written, {} dropped)", stats.written, stats.dropped);
    }
    if let Some(out) = args.get("out") {
        checkpoint::save(
            std::path::Path::new(out),
            &pruned,
            &CheckpointMeta {
                model: model.clone(),
                corpus: corpus.clone(),
                steps: 0,
                final_loss: ppl_pruned.ln(),
                seed: opts.seed,
            },
        )?;
        println!("saved: {out}");
    }
    // --emit-sparse [path]: compile the pruner's output once and write
    // the compressed artifact straight from memory — no dense
    // checkpoint round-trip, no recompress-at-serve-time.
    let emit = args.get("emit-sparse").map(std::path::PathBuf::from).or_else(|| {
        args.has("emit-sparse").then(|| {
            crate::config::paths::sparse_artifacts_dir(&lab.root).join(format!(
                "{model}_{corpus}_{}_{}.fsa",
                opts.sparsity.label().replace(':', "-"),
                opts.seed
            ))
        })
    });
    if let Some(path) = emit {
        let fmt = SparseFormat::parse(args.get_or("format", "auto"))?;
        // --quant int8|f16: quantize the compressed values once at
        // compile time; the artifact then serves quantized end to end.
        let quant = crate::config::QuantMode::parse(args.get_or("quant", "none"))?;
        let spec = lab.presets.model(&model)?.clone();
        let compiled = crate::sparse::CompiledLayers::compress_quantized(
            &spec,
            &pruned,
            fmt,
            Some(opts.sparsity),
            quant,
        )?;
        let meta = crate::ser::artifact::ArtifactMeta {
            model,
            corpus,
            method: method.name().to_string(),
            sparsity: opts.sparsity.label(),
            format: fmt.label().to_string(),
            quant: quant.label().to_string(),
            seed: opts.seed,
            prune: Some(report.provenance_json()),
        };
        crate::ser::artifact::save(&path, &compiled, &meta)?;
        println!(
            "sparse artifact: {} ({} ops as {}, values {}, {} B resident, {:.3}x dense)",
            path.display(),
            compiled.op_count(),
            compiled.format_label(),
            compiled.quant.label(),
            compiled.resident_bytes(),
            compiled.resident_bytes() as f64
                / (4 * crate::model::spec::param_count(&spec)) as f64
        );
    }
    Ok(())
}

pub fn eval(args: &Args) -> Result<()> {
    let mut lab = Lab::new()?;
    // --artifact: score the compressed operators directly — the dense
    // pruned weights are never materialized.
    if let Some(path) = args.get("artifact") {
        if args.has("ckpt") || args.get("ckpt").is_some() {
            anyhow::bail!("--artifact and --ckpt are different weight sources; pass one");
        }
        let (compiled, meta) = crate::ser::artifact::load(std::path::Path::new(path))?;
        crate::ser::artifact::check_model(&meta, args.get("model"))?;
        let corpus = args.get("corpus").unwrap_or(&meta.corpus).to_string();
        let windows = lab.eval_windows();
        let c = crate::data::Corpus::generate(lab.presets.corpus(&corpus)?);
        let ppl = crate::eval::perplexity::perplexity_compiled(&compiled, &c, windows)?;
        println!(
            "{} on {corpus} via artifact ({} @ {}): perplexity {ppl:.3}",
            meta.model,
            compiled.format_label(),
            meta.sparsity
        );
        return Ok(());
    }
    let model = args.req("model")?.to_string();
    let corpus = args.req("corpus")?.to_string();
    let params = load_or_train(&mut lab, args, &model, &corpus)?;
    let ppl = lab.ppl(&model, &params, &corpus)?;
    println!("{model} on {corpus}: perplexity {ppl:.3}");
    Ok(())
}

pub fn zeroshot(args: &Args) -> Result<()> {
    let mut lab = Lab::new()?;
    let model = args.req("model")?.to_string();
    let corpus = args.req("corpus")?.to_string();
    let items = args.usize_or("items", 100)?;
    let params = load_or_train(&mut lab, args, &model, &corpus)?;
    let (results, mean) =
        lab.zeroshot(&model, &params, &corpus, items, args.u64_or("seed", 1)?)?;
    let mut t = TableBuilder::new("Zero-shot probes", &["task", "accuracy", "items"]);
    for r in &results {
        t.row(vec![r.name.to_string(), TableBuilder::acc(r.accuracy), r.items.to_string()]);
    }
    t.row(vec!["MEAN".into(), TableBuilder::acc(mean), String::new()]);
    t.print();
    Ok(())
}

pub fn generate(args: &Args) -> Result<()> {
    let mut lab = Lab::new()?;
    let model = args.req("model")?.to_string();
    let corpus = args.req("corpus")?.to_string();
    let params = load_or_train(&mut lab, args, &model, &corpus)?;
    let spec = lab.presets.model(&model)?.clone();
    let opts = crate::eval::generate::GenOptions {
        max_tokens: args.usize_or("tokens", 200)?,
        temperature: args.f64_or("temp", 0.8)?,
        seed: args.u64_or("seed", 0)?,
    };
    let prompt = args.get_or("prompt", "the ").to_string();
    let out = crate::eval::generate::generate(&spec, &params, &prompt, &opts);
    println!("{prompt}{out}");
    if params.weight_sparsity() > 0.0 {
        println!("\n(weight sparsity: {:.1}%)", params.weight_sparsity() * 100.0);
    }
    Ok(())
}

/// `serve`: continuous-batching JSONL server over stdin, or a
/// self-driving synthetic load with `--synthetic N`.
pub fn serve(args: &Args) -> Result<()> {
    let mut lab = Lab::new()?;
    // Weight sources, mutually exclusive:
    //   --artifact path.fsa     compiled sparse artifact (the production
    //                           path: compressed operators are the only
    //                           copy of the pruned weights in memory)
    //   [--ckpt] + --format     dense checkpoint, optionally compressed
    //                           at startup (csr|nm|auto); --weights
    //                           dense|csr is the older spelling
    //                           (csr ≡ --format csr)
    // nm/auto check weights against --sparsity (default 2:4, the paper's
    // hardware pattern). Unknown values and contradictory combinations
    // are rejected, never silently resolved.
    let artifact = args.get("artifact");
    if artifact.is_some() {
        for flag in ["ckpt", "format", "weights", "sparsity"] {
            if args.get(flag).is_some() {
                anyhow::bail!(
                    "--artifact carries its own weights, format and sparsity; drop --{flag}"
                );
            }
        }
    }
    let weights = args.get("weights");
    if let Some(w) = weights {
        if w != "dense" && w != "csr" {
            anyhow::bail!("unknown --weights '{w}' (dense|csr, or --format)");
        }
    }
    // --kernel scalar|simd: select the process-wide kernel variant before
    // any weights load; a simd request on a scalar-only build is rejected
    // here with a clear error, never silently downgraded.
    let kernel = crate::config::KernelVariant::parse(args.get_or("kernel", "scalar"))?;
    crate::tensor::par::set_kernel_variant(kernel)?;
    // dense params are only loaded on the checkpoint path; the artifact
    // path never materializes them, and the compress-at-startup path
    // drops them before serving begins
    let (model, mut params): (String, Option<crate::model::ModelParams>) = match artifact {
        Some(_) => (String::new(), None),
        None => {
            let model = args.req("model")?.to_string();
            let corpus = args.req("corpus")?.to_string();
            let params = load_or_train(&mut lab, args, &model, &corpus)?;
            (model, Some(params))
        }
    };
    let serve_model = if let Some(path) = artifact {
        let (compiled, meta) = crate::ser::artifact::load(std::path::Path::new(path))?;
        crate::ser::artifact::check_model(&meta, args.get("model"))?;
        eprintln!(
            "loaded artifact {path}: {} @ {} ({} ops, values {}, {} B resident)",
            compiled.format_label(),
            meta.sparsity,
            compiled.op_count(),
            compiled.quant.label(),
            compiled.resident_bytes()
        );
        crate::serve::ServeModel::from_compiled(compiled)
    } else {
        let spec = lab.presets.model(&model)?.clone();
        let format = match (args.get("format"), weights) {
            (Some(f), Some("dense")) => {
                anyhow::bail!("--weights dense conflicts with --format {f}; drop one of the two")
            }
            (Some(f), Some("csr")) if f != "csr" => {
                anyhow::bail!("--weights csr conflicts with --format {f}; drop one of the two")
            }
            (Some(f), _) => Some(SparseFormat::parse(f)?),
            (None, Some("csr")) => Some(SparseFormat::Csr),
            (None, _) => None,
        };
        match format {
            None => crate::serve::ServeModel::dense(
                &spec,
                params.as_ref().expect("checkpoint path loads params"),
            )?,
            Some(f) => {
                let sp_hint = match (args.get("sparsity"), f) {
                    (Some(s), _) => Some(Sparsity::parse(s)?),
                    (None, SparseFormat::Csr) => None,
                    (None, _) => Some(Sparsity::Semi(2, 4)),
                };
                // take ownership so the dense weights are freed before
                // serving: the compiled model is self-contained
                let dense_params = params.take().expect("checkpoint path loads params");
                let m = crate::serve::ServeModel::sparse_as(&spec, &dense_params, f, sp_hint)?;
                match m.density() {
                    Some(d) if d > 0.999 => crate::log_warn!(
                        "serving {} over dense weights (density {d:.3}); pass a pruned --ckpt",
                        m.format_label()
                    ),
                    Some(d) => eprintln!("serving {} weights, density {d:.3}", m.format_label()),
                    None => {}
                }
                m
            }
        }
    };
    let model_name = serve_model.spec.name();
    // --trace-out: structured JSONL trace of the whole run (request
    // lifecycles, engine gauges, connection spans), on the same clock as
    // every latency field. Tracing observes, never gates: served bytes
    // are bitwise identical with it on (rust/tests/trace_parity.rs).
    let clock = crate::obs::SharedClock::default();
    let mut tracing = None;
    let mut recorder = None;
    if let Some(path) = args.get("trace-out") {
        let (rec, writer) =
            crate::obs::Recorder::to_file(std::path::Path::new(path), clock.clone())?;
        recorder = Some(rec);
        tracing = Some((writer, path.to_string()));
    }
    let cfg = crate::serve::EngineConfig {
        max_batch: args.usize_or("batch", 4)?,
        queue_cap: args.usize_or("queue", 64)?,
        kv_page: args.usize_or("kv-page", 16)?,
        kv_pages: args.get("kv-pages").map(|v| v.parse()).transpose()?,
        prefill_chunk: args.usize_or("prefill-chunk", 16)?,
        transcript: args.get("transcript").map(std::path::PathBuf::from),
        clock: Some(clock),
        recorder,
    };
    // --listen: the TCP front-end. Same engine, same JSONL protocol —
    // but many concurrent connections, bounded framing, timeouts, and an
    // optional raw event-log tee for offline replay (serve::net).
    if let Some(addr) = args.get("listen") {
        if args.get("synthetic").is_some() {
            anyhow::bail!("--listen serves sockets; drop --synthetic");
        }
        let max_conns = args.usize_or("max-conns", 64)?;
        let conn_timeout_ms = args.u64_or("conn-timeout", 30_000)?;
        let ncfg = crate::serve::NetConfig {
            max_conns,
            conn_timeout: std::time::Duration::from_millis(conn_timeout_ms),
            max_line: args.usize_or("max-line", crate::serve::net::DEFAULT_MAX_LINE)?,
            write_buf: args.usize_or("write-buf", 64)?,
            event_log: args.get("event-log").map(std::path::PathBuf::from),
            ..Default::default()
        };
        let server = crate::serve::NetServer::bind(addr, ncfg)?;
        eprintln!(
            "serving {model_name} on {} — {} slots, queue {}, max {} conns, \
             conn timeout {} ms, kernel {}, values {}",
            server.local_addr()?,
            cfg.max_batch,
            cfg.queue_cap,
            max_conns,
            conn_timeout_ms,
            crate::tensor::par::kernel_variant().label(),
            serve_model.quant().label()
        );
        let stop = std::sync::Arc::new(std::sync::atomic::AtomicBool::new(false));
        let report = server.run(&serve_model, &cfg, stop)?;
        eprintln!("net serve done: {}", report.summary());
        eprintln!("stats: {}", report.snapshot.summary());
        finish_trace(tracing)?;
        return Ok(());
    }

    let mut engine = crate::serve::Engine::new(&serve_model, &cfg)?;
    let (_, _, budget_pages) = engine.kv_pages();
    eprintln!(
        "serving {model_name} — {} slots, queue {}, KV {} pages × {} positions \
         (cap {:.1} KiB, paged on demand), prefill chunk {}, resident weights {:.1} KiB, \
         kernel {}, values {}",
        cfg.max_batch,
        cfg.queue_cap,
        budget_pages,
        engine.kv_page_positions(),
        engine.kv_capacity_bytes() as f64 / 1024.0,
        cfg.prefill_chunk,
        serve_model.resident_weight_bytes() as f64 / 1024.0,
        crate::tensor::par::kernel_variant().label(),
        serve_model.quant().label()
    );

    // Stream responses as requests retire. Intake interleaves with engine
    // steps: whenever the queue is at capacity the engine decodes until
    // room opens up, so a long request stream is served continuously
    // (join-on-arrival) instead of rejected while slots sit idle.
    fn emit(engine: &mut crate::serve::Engine<'_>) {
        for r in engine.take_responses() {
            println!("{}", r.to_json_line());
        }
    }
    let take =
        |engine: &mut crate::serve::Engine<'_>, req: crate::serve::ServeRequest| -> Result<()> {
            while engine.queued() >= cfg.queue_cap {
                engine.step()?;
                emit(engine);
            }
            engine.submit_or_reject(req);
            emit(engine);
            Ok(())
        };

    let mut next_id = 0usize;
    if let Some(n) = args.get("synthetic") {
        let n: usize = n.parse()?;
        let tokens = args.usize_or("tokens", 32)?;
        let temp = args.f64_or("temp", 0.0)?;
        for i in 0..n {
            let req = crate::serve::ServeRequest {
                id: format!("syn-{i}"),
                prompt: format!("req {i}: the "),
                max_tokens: tokens,
                temperature: temp,
                seed: i as u64,
                stop: None,
            };
            take(&mut engine, req)?;
        }
    } else {
        // Bounded framing on stdin too: a hostile 100 MB line costs at
        // most max_line bytes of buffer and one typed error, exactly as
        // on the socket path.
        use crate::serve::net::{BoundedLineReader, LineOutcome, DEFAULT_MAX_LINE};
        let max_line = args.usize_or("max-line", DEFAULT_MAX_LINE)?;
        let stdin = std::io::stdin();
        let mut lock = stdin.lock();
        let mut frame = BoundedLineReader::new(max_line);
        loop {
            match frame.read_line(&mut lock)? {
                LineOutcome::Eof => break,
                LineOutcome::Line(line) => {
                    if line.trim().is_empty() {
                        continue;
                    }
                    match crate::serve::ServeRequest::from_json_line_checked(&line, max_line) {
                        Ok(mut req) => {
                            if req.id.is_empty() {
                                req.id = format!("req-{next_id}");
                                next_id += 1;
                            }
                            take(&mut engine, req)?;
                        }
                        Err(e) => eprintln!("bad request line: {e:#}"),
                    }
                }
                LineOutcome::Oversized { limit, read } => {
                    eprintln!("bad request line: exceeds the {limit} byte cap ({read} bytes); discarded")
                }
                LineOutcome::NotUtf8 => eprintln!("bad request line: not valid UTF-8"),
                // no per-line deadline is configured on stdin
                LineOutcome::TimedOut { .. } => {}
            }
        }
    }
    while !engine.is_idle() {
        engine.step()?;
        emit(&mut engine);
    }
    emit(&mut engine);
    let s = engine.stats;
    eprintln!(
        "served {} requests: {} decode steps, {} tokens ({} prefill in {} chunks), \
         KV resident {:.1} KiB of {:.1} KiB cap",
        s.retired,
        s.steps,
        s.decoded_tokens,
        s.prefill_tokens,
        s.prefill_chunks,
        engine.kv_resident_bytes() as f64 / 1024.0,
        engine.kv_capacity_bytes() as f64 / 1024.0
    );
    eprintln!("stats: {}", engine.snapshot().summary());
    finish_trace(tracing)?;
    Ok(())
}

/// Close a `--trace-out` writer and report the final event accounting.
fn finish_trace(tracing: Option<(crate::obs::TraceWriter, String)>) -> Result<()> {
    if let Some((writer, path)) = tracing {
        let stats = writer.finish()?;
        println!("trace: {path} ({} events written, {} dropped)", stats.written, stats.dropped);
    }
    Ok(())
}

/// `serve-bench`: tokens/s + latency for recompute vs KV-cached vs
/// compressed decode (CSR, plus packed n:m side by side under
/// `--format nm|auto`), with greedy parity checked against
/// `eval::generate`. `--paged` measures the paged-KV axis instead:
/// resident KV bytes vs the monolithic preallocation and the
/// prefill-stall p99 with vs without chunking (BENCH_paged.json).
/// `--kernel scalar,simd` measures the kernel-variant × quantization
/// grid over compiled operators (`--quant none,f16,int8` —
/// BENCH_kernel.json): tokens/s, resident weight bytes and effective
/// GB/s per cell.
pub fn serve_bench(args: &Args) -> Result<()> {
    let mut lab = Lab::new()?;
    let smoke = args.has("smoke");
    let fast = smoke || crate::bench_support::fast_mode();
    let format = SparseFormat::parse(args.get_or("format", "csr"))?;
    // the nm axis needs an n:m pattern; 2:4 is the paper's hardware mode
    let default_sparsity = if format == SparseFormat::Csr { "0.5" } else { "2:4" };
    // --trace-out: every engine the bench spins up shares one recorder
    // and one clock, so the capture holds all measured paths end to end.
    let mut tracing = None;
    let mut obs = crate::serve::BenchObs::default();
    if let Some(path) = args.get("trace-out") {
        let clock = crate::obs::SharedClock::default();
        let (rec, writer) =
            crate::obs::Recorder::to_file(std::path::Path::new(path), clock.clone())?;
        obs = crate::serve::BenchObs { clock: Some(clock), recorder: Some(rec) };
        tracing = Some((writer, path.to_string()));
    }
    let cfg = crate::serve::ServeBenchConfig {
        tokens: args.usize_or("tokens", if smoke { 16 } else { 32 })?,
        batch: args.usize_or("batch", 4)?,
        requests: args.usize_or("requests", if smoke { 4 } else { 8 })?,
        sparsity: Sparsity::parse(args.get_or("sparsity", default_sparsity))?,
        format,
        kv_page: args.usize_or("kv-page", 16)?,
        prefill_chunk: args.usize_or("prefill-chunk", 16)?,
        obs,
    };
    let res = serve_bench_axes(&mut lab, args, &cfg, fast, smoke);
    // the writer closes even when a parity gate bails, so a failing run
    // still leaves a complete capture to debug from
    finish_trace(tracing)?;
    res
}

/// The axis dispatch behind [`serve_bench`] (split out so `--trace-out`
/// can close its writer on every early-return path).
fn serve_bench_axes(
    lab: &mut Lab,
    args: &Args,
    cfg: &crate::serve::ServeBenchConfig,
    fast: bool,
    smoke: bool,
) -> Result<()> {
    // --kernel: the kernel-variant × quantization grid over compiled
    // operators (BENCH_kernel.json). Comma-separated lists grid out,
    // e.g. --kernel scalar,simd --quant none,int8; each cell is
    // parity-gated against the compiled recompute under its own kernels.
    if let Some(kernel_list) = args.get("kernel") {
        if args.get("artifact").is_some() || args.has("paged") || args.has("net") {
            anyhow::bail!(
                "--kernel measures the compiled kernel axis; drop --artifact/--paged/--net"
            );
        }
        let kernels = kernel_list
            .split(',')
            .map(crate::config::KernelVariant::parse)
            .collect::<Result<Vec<_>>>()?;
        let quants = args
            .get_or("quant", "none,f16,int8")
            .split(',')
            .map(crate::config::QuantMode::parse)
            .collect::<Result<Vec<_>>>()?;
        let default_model = if fast { "topt-s1" } else { "topt-s3" };
        let model = args.get_or("model", default_model).to_string();
        let corpus = args.get_or("corpus", "c4-syn").to_string();
        let params = load_or_train(lab, args, &model, &corpus)?;
        let spec = lab.presets.model(&model)?.clone();
        let report = crate::serve::run_kernel_bench(&spec, &params, cfg, &kernels, &quants)?;
        report.print();
        write_json_report(args, report.to_json())?;
        if !report.parity_ok {
            anyhow::bail!("kernel-bench parity failed: served output != compiled forward");
        }
        return Ok(());
    }
    // --net: the socket-concurrency axis — sustained req/s and stream
    // p99 with N loopback clients, connection churn and one mid-stream
    // disconnect, through the real `serve --listen` front-end
    // (BENCH_net.json). Parity-gated like every other axis.
    if args.has("net") {
        if args.get("artifact").is_some() || args.has("paged") {
            anyhow::bail!("--net measures the dense network axis; drop --artifact/--paged");
        }
        let default_model = if fast { "topt-s1" } else { "topt-s3" };
        let model = args.get_or("model", default_model).to_string();
        let corpus = args.get_or("corpus", "c4-syn").to_string();
        let params = load_or_train(lab, args, &model, &corpus)?;
        let spec = lab.presets.model(&model)?.clone();
        let net = crate::serve::NetBenchConfig {
            clients: args.usize_or("clients", 8)?,
            requests_per_client: args.usize_or("reqs-per-client", if smoke { 2 } else { 4 })?,
            churn: !args.has("no-churn"),
        };
        let report = crate::serve::run_net_bench(&spec, &params, cfg, &net)?;
        report.print();
        write_json_report(args, report.to_json())?;
        if !report.parity_ok {
            anyhow::bail!("net-bench parity failed: served streams != eval::generate");
        }
        return Ok(());
    }
    // --paged: the KV memory / prefill-stall axis over dense weights
    if args.has("paged") {
        if args.get("artifact").is_some() {
            anyhow::bail!("--paged measures the dense KV axis; drop --artifact");
        }
        let default_model = if fast { "topt-s1" } else { "topt-s3" };
        let model = args.get_or("model", default_model).to_string();
        let corpus = args.get_or("corpus", "c4-syn").to_string();
        let params = load_or_train(lab, args, &model, &corpus)?;
        let spec = lab.presets.model(&model)?.clone();
        let report = crate::serve::run_paged_bench(&spec, &params, cfg)?;
        report.print();
        write_json_report(args, report.to_json())?;
        if !report.parity_ok {
            anyhow::bail!("paged-bench parity failed: served output != eval::generate");
        }
        return Ok(());
    }
    // --artifact: measure the disk → serve path of a compiled artifact
    // (load ms, on-disk and resident bytes vs the dense checkpoint)
    // instead of the in-memory compression axes.
    if let Some(path) = args.get("artifact") {
        let report =
            crate::serve::run_artifact_bench(std::path::Path::new(path), cfg, args.get("model"))?;
        report.print();
        write_json_report(args, report.to_json())?;
        if !report.parity_ok {
            anyhow::bail!("artifact-bench parity failed: served output != compiled forward");
        }
        return Ok(());
    }
    let default_model = if fast { "topt-s1" } else { "topt-s3" };
    let model = args.get_or("model", default_model).to_string();
    let corpus = args.get_or("corpus", "c4-syn").to_string();
    let params = load_or_train(lab, args, &model, &corpus)?;
    let spec = lab.presets.model(&model)?.clone();
    let report = crate::serve::run_serve_bench(&spec, &params, cfg)?;
    report.print();
    write_json_report(args, report.to_json())?;
    if !report.parity_ok {
        anyhow::bail!("serve-bench parity check failed: served output != eval::generate");
    }
    Ok(())
}

/// `--json path`: write a bench report next to the table output.
fn write_json_report(args: &Args, json: crate::ser::Json) -> Result<()> {
    if let Some(path) = args.get("json") {
        let path = std::path::Path::new(path);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, json.to_string_compact() + "\n")?;
        println!("wrote {}", path.display());
    }
    Ok(())
}

pub fn pipeline(args: &Args) -> Result<()> {
    let mut lab = Lab::new()?;
    let model = args.req("model")?.to_string();
    let corpus = args.req("corpus")?.to_string();
    let sparsity = Sparsity::parse(args.get_or("sparsity", "0.5"))?;
    let opts = PruneOptions { sparsity, ..prune_options(&lab, args)? };
    let calib_n = args.usize_or("calib", lab.calib_samples())?;

    println!("[1/3] train/load {model} on {corpus}");
    let dense = lab.trained_or_init(&model, &corpus)?;
    let calib = lab.calib(&corpus, calib_n, opts.seed)?;

    println!("[2/3] prune with all methods at {}", sparsity.label());
    use crate::baselines::BaselineKind::*;
    let methods =
        [Method::Baseline(Magnitude), Method::Baseline(Wanda), Method::Baseline(SparseGpt), Method::fista()];
    let mut t = TableBuilder::new(
        &format!("{model} on {corpus} @ {}", sparsity.label()),
        &["Method", "PPL", "rel err", "prune s"],
    );
    let ppl_dense = lab.ppl(&model, &dense, &corpus)?;
    t.row(vec!["Dense".into(), TableBuilder::f(ppl_dense), "-".into(), "-".into()]);
    for method in methods {
        let (pruned, report) = lab.prune(&model, &dense, &calib, method, &opts)?;
        let ppl = lab.ppl(&model, &pruned, &corpus)?;
        t.row(vec![
            method.name().to_string(),
            TableBuilder::f(ppl),
            format!("{:.4}", report.mean_rel_error()),
            format!("{:.1}", report.elapsed.as_secs_f64()),
        ]);
    }
    println!("[3/3] results");
    t.print();
    Ok(())
}

/// `trace --in capture.jsonl`: offline analysis of a `--trace-out`
/// capture — per-request waterfalls, per-phase time totals, and the
/// per-operator solver convergence tables (one per solver label) — plus
/// the dropped-event gate CI runs (`--fail-on-drops`).
pub fn trace(args: &Args) -> Result<()> {
    use crate::obs::trace as tr;
    let path = std::path::PathBuf::from(args.req("in")?);
    let events = tr::load_trace(&path)?;
    println!("{}: {} events", path.display(), events.len());

    let requests = tr::request_waterfalls(&events);
    if !requests.is_empty() {
        let mut t = TableBuilder::new(
            "requests",
            &["id", "queued ms", "service ms", "total ms", "chunks", "tokens", "finish"],
        );
        for r in &requests {
            t.row(vec![
                r.id.clone(),
                format!("{:.3}", r.queued_ms),
                format!("{:.3}", r.service_ms),
                format!("{:.3}", r.total_ms),
                r.prefill_chunks.to_string(),
                r.completion_tokens.to_string(),
                r.finish.clone(),
            ]);
        }
        t.print();
    }

    let phases = tr::phase_breakdown(&events);
    if !phases.is_empty() {
        let mut t = TableBuilder::new("phases", &["name", "count", "total ms"]);
        for p in &phases {
            t.row(vec![p.name.clone(), p.count.to_string(), format!("{:.3}", p.total_ms)]);
        }
        t.print();
    }

    let conv = tr::convergence_rows(&events);
    if !conv.is_empty() {
        // One convergence table per solver label, so a mixed capture
        // (e.g. an ablation run) stays readable.
        let totals = tr::solver_totals(&conv);
        for (solver, _, _) in &totals {
            let mut t = TableBuilder::new(
                &format!("{solver} convergence (final round per operator)"),
                &["op", "rounds", "iters", "lambda", "objective", "residual", "support"],
            );
            for c in conv.iter().filter(|c| &c.solver == solver) {
                t.row(vec![
                    c.id.clone(),
                    c.rounds.to_string(),
                    c.iters.to_string(),
                    format!("{:.2e}", c.lambda),
                    format!("{:.4}", c.objective),
                    format!("{:.4}", c.residual),
                    c.support.to_string(),
                ]);
            }
            t.print();
        }
        for (solver, ops, iters) in &totals {
            println!("solver {solver}: {ops} operators, {iters} total iterations");
        }
    }

    // --csv path: the waterfall rows, machine-readable.
    if let Some(csv_path) = args.get("csv") {
        let mut csv = crate::metrics::csv::CsvWriter::create(
            std::path::Path::new(csv_path),
            &[
                "id",
                "queued_ms",
                "service_ms",
                "total_ms",
                "prefill_chunks",
                "completion_tokens",
                "finish",
            ],
        )?;
        for r in &requests {
            csv.write_row(&[
                r.id.clone(),
                format!("{:.4}", r.queued_ms),
                format!("{:.4}", r.service_ms),
                format!("{:.4}", r.total_ms),
                r.prefill_chunks.to_string(),
                r.completion_tokens.to_string(),
                r.finish.clone(),
            ])?;
        }
        println!("csv: {csv_path}");
    }

    let counts = tr::trace_end_counts(&events);
    match counts {
        Some((written, dropped)) => println!("dropped_events: {dropped} ({written} written)"),
        None => println!("dropped_events: unknown (no trace_end line; capture closed uncleanly)"),
    }
    if args.has("fail-on-drops") {
        match counts {
            None => anyhow::bail!("no trace_end summary line in {}", path.display()),
            Some((_, dropped)) if dropped > 0 => {
                anyhow::bail!("{dropped} trace events were dropped (bounded channel overflow)")
            }
            _ => {}
        }
    }
    Ok(())
}

//! Native embedding lookup — the only piece of the forward pass the
//! coordinator computes itself (a table gather; everything downstream runs
//! in the capture/score artifacts).

use anyhow::Result;

use crate::config::{FamilyKind, ModelSpec};
use crate::tensor::Tensor;

use super::params::ModelParams;

/// Embed token windows into capture-batch inputs.
///
/// Returns ([num_batches] of [cb, seq, d] tensors, valid rows per batch).
/// Windows shorter than a full batch are zero-padded; callers must harvest
/// activations only from the first `valid` rows.
pub fn embed_windows(
    spec: &ModelSpec,
    params: &ModelParams,
    windows: &[Vec<i32>],
    cb: usize,
) -> Result<(Vec<Tensor>, Vec<usize>)> {
    let (seq, d) = (spec.seq, spec.d);
    let embed = params.req("embed")?;
    let pos = match spec.family {
        FamilyKind::Topt => Some(params.req("pos")?),
        FamilyKind::Tllama => None,
    };
    let mut batches = Vec::new();
    let mut valids = Vec::new();
    for chunk in windows.chunks(cb) {
        let mut buf = vec![0f32; cb * seq * d];
        for (r, w) in chunk.iter().enumerate() {
            assert!(w.len() >= seq, "window shorter than seq");
            for t in 0..seq {
                let tok = w[t] as usize;
                assert!(tok < spec.vocab, "token {tok} out of vocab");
                let dst = &mut buf[(r * seq + t) * d..(r * seq + t + 1) * d];
                dst.copy_from_slice(&embed.data()[tok * d..(tok + 1) * d]);
                if let Some(p) = pos {
                    for (x, &pv) in dst.iter_mut().zip(&p.data()[t * d..(t + 1) * d]) {
                        *x += pv;
                    }
                }
            }
        }
        batches.push(Tensor::from_vec(vec![cb, seq, d], buf));
        valids.push(chunk.len());
    }
    Ok((batches, valids))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{repo_root, Presets};
    use crate::model::init::init_params;

    #[test]
    fn shapes_and_padding() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 1);
        let windows: Vec<Vec<i32>> = (0..10).map(|i| vec![(i % 96) as i32; spec.seq]).collect();
        let (batches, valids) = embed_windows(spec, &params, &windows, 8).unwrap();
        assert_eq!(batches.len(), 2);
        assert_eq!(valids, vec![8, 2]);
        assert_eq!(batches[0].shape(), &[8, spec.seq, spec.d]);
        // padded rows are zero
        let b1 = &batches[1];
        let row3 = &b1.data()[3 * spec.seq * spec.d..4 * spec.seq * spec.d];
        assert!(row3.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn topt_adds_positions() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 2);
        // same token at two positions must embed differently (pos added)
        let windows = vec![vec![5i32; spec.seq]];
        let (batches, _) = embed_windows(spec, &params, &windows, 8).unwrap();
        let d = spec.d;
        let t0 = &batches[0].data()[0..d];
        let t1 = &batches[0].data()[d..2 * d];
        assert_ne!(t0, t1);
        // tllama does not add positions
        let lspec = presets.model("tllama-s1").unwrap();
        let lparams = init_params(lspec, 2);
        let (lb, _) = embed_windows(lspec, &lparams, &vec![vec![5i32; lspec.seq]], 8).unwrap();
        let ld = lspec.d;
        assert_eq!(&lb[0].data()[0..ld], &lb[0].data()[ld..2 * ld]);
    }
}

//! Enumeration of the pruned linear operators, in the paper's intra-layer
//! sequential order (Fig. 2: q,k,v → o → MLP in → MLP out).
//!
//! Mirrors python/compile/shapes.py::pruned_ops + aot.py::CAPTURE_KEY;
//! checked against artifacts/manifest.json in rust/tests/manifest_parity.rs.

use crate::config::{FamilyKind, ModelSpec};

/// Which capture-artifact output feeds an operator (paper Fig. 2 topology).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CaptureKey {
    /// Post-norm input of wq/wk/wv.
    AttnIn = 0,
    /// Merged attention context — input of wo.
    OIn = 1,
    /// Post-norm input of the MLP first matmuls (w1 / wg+wu).
    MlpIn = 2,
    /// Hidden MLP activation — input of w2 / wd.
    Mlp2In = 3,
}

impl CaptureKey {
    /// Index into the capture artifact's output tuple.
    pub fn output_index(&self) -> usize {
        *self as usize
    }

    pub fn parse(s: &str) -> Option<CaptureKey> {
        match s {
            "attn_in" => Some(CaptureKey::AttnIn),
            "o_in" => Some(CaptureKey::OIn),
            "mlp_in" => Some(CaptureKey::MlpIn),
            "mlp2_in" => Some(CaptureKey::Mlp2In),
            _ => None,
        }
    }
}

/// One pruned linear operator within a decoder layer.
#[derive(Clone, Debug)]
pub struct PrunedOp {
    /// Bare name within the layer, e.g. "wq" (parameter is `l{i}.wq`).
    pub name: &'static str,
    pub m: usize,
    pub n: usize,
    pub capture: CaptureKey,
}

/// Pruned operators in the sequential intra-layer order.
pub fn pruned_ops(spec: &ModelSpec) -> Vec<PrunedOp> {
    let (d, ffn) = (spec.d, spec.ffn);
    let mut ops = vec![
        PrunedOp { name: "wq", m: d, n: d, capture: CaptureKey::AttnIn },
        PrunedOp { name: "wk", m: d, n: d, capture: CaptureKey::AttnIn },
        PrunedOp { name: "wv", m: d, n: d, capture: CaptureKey::AttnIn },
        PrunedOp { name: "wo", m: d, n: d, capture: CaptureKey::OIn },
    ];
    match spec.family {
        FamilyKind::Topt => {
            ops.push(PrunedOp { name: "w1", m: ffn, n: d, capture: CaptureKey::MlpIn });
            ops.push(PrunedOp { name: "w2", m: d, n: ffn, capture: CaptureKey::Mlp2In });
        }
        FamilyKind::Tllama => {
            ops.push(PrunedOp { name: "wg", m: ffn, n: d, capture: CaptureKey::MlpIn });
            ops.push(PrunedOp { name: "wu", m: ffn, n: d, capture: CaptureKey::MlpIn });
            ops.push(PrunedOp { name: "wd", m: d, n: ffn, capture: CaptureKey::Mlp2In });
        }
    }
    ops
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{repo_root, Presets};

    #[test]
    fn op_sets_per_family() {
        let p = Presets::load(&repo_root().unwrap()).unwrap();
        let t = pruned_ops(p.model("topt-s3").unwrap());
        assert_eq!(t.len(), 6);
        assert_eq!(t[5].name, "w2");
        assert_eq!(t[5].n, 512);
        let l = pruned_ops(p.model("tllama-s3").unwrap());
        assert_eq!(l.len(), 7);
        assert_eq!(l[4].name, "wg");
        assert_eq!(l[4].capture, CaptureKey::MlpIn);
    }

    #[test]
    fn capture_ordering_is_topological() {
        // Operators must appear after the capture point they consume.
        let p = Presets::load(&repo_root().unwrap()).unwrap();
        for m in ["topt-s1", "tllama-s1"] {
            let ops = pruned_ops(p.model(m).unwrap());
            let mut max_seen = 0usize;
            for op in &ops {
                assert!(op.capture.output_index() >= max_seen.saturating_sub(1));
                max_seen = max_seen.max(op.capture.output_index());
            }
        }
    }

    #[test]
    fn capture_key_parse() {
        assert_eq!(CaptureKey::parse("attn_in"), Some(CaptureKey::AttnIn));
        assert_eq!(CaptureKey::parse("mlp2_in"), Some(CaptureKey::Mlp2In));
        assert_eq!(CaptureKey::parse("bogus"), None);
    }
}

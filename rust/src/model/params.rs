//! Parameter container: named tensors in canonical spec order.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::config::{ModelSpec, Presets};
use crate::tensor::Tensor;

use super::spec::{model_param_specs, ParamSpec};

/// A model's parameters, stored in the canonical artifact-input order.
#[derive(Clone)]
pub struct ModelParams {
    model: String,
    specs: Vec<ParamSpec>,
    tensors: Vec<Tensor>,
    index: BTreeMap<String, usize>,
}

impl ModelParams {
    /// Build from spec + per-parameter constructor.
    pub fn build(spec: &ModelSpec, mut f: impl FnMut(&ParamSpec) -> Tensor) -> Self {
        let specs = model_param_specs(spec);
        let tensors: Vec<Tensor> = specs
            .iter()
            .map(|s| {
                let t = f(s);
                assert_eq!(t.shape(), s.shape.as_slice(), "init shape mismatch for {}", s.name);
                t
            })
            .collect();
        let index = specs.iter().enumerate().map(|(i, s)| (s.name.clone(), i)).collect();
        ModelParams { model: spec.name(), specs, tensors, index }
    }

    /// Reassemble from a name→tensor map (checkpoint load); validates the
    /// tensor set exactly matches the model spec.
    pub fn from_map(model: &str, mut map: BTreeMap<String, Tensor>) -> Result<Self> {
        let root = crate::config::repo_root()?;
        let presets = Presets::load(&root)?;
        let spec = presets.model(model)?;
        let specs = model_param_specs(spec);
        let mut tensors = Vec::with_capacity(specs.len());
        for s in &specs {
            let t = map
                .remove(&s.name)
                .with_context(|| format!("checkpoint missing parameter '{}'", s.name))?;
            if t.shape() != s.shape.as_slice() {
                bail!("parameter '{}' has shape {:?}, expected {:?}", s.name, t.shape(), s.shape);
            }
            tensors.push(t);
        }
        if !map.is_empty() {
            bail!("checkpoint has unexpected tensors: {:?}", map.keys().collect::<Vec<_>>());
        }
        let index = specs.iter().enumerate().map(|(i, s)| (s.name.clone(), i)).collect();
        Ok(ModelParams { model: model.to_string(), specs, tensors, index })
    }

    pub fn model_name(&self) -> &str {
        &self.model
    }

    pub fn specs(&self) -> &[ParamSpec] {
        &self.specs
    }

    pub fn tensors(&self) -> &[Tensor] {
        &self.tensors
    }

    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.index.get(name).map(|&i| &self.tensors[i])
    }

    pub fn req(&self, name: &str) -> Result<&Tensor> {
        self.get(name).ok_or_else(|| anyhow::anyhow!("no parameter '{name}' in {}", self.model))
    }

    pub fn set(&mut self, name: &str, t: Tensor) -> Result<()> {
        let &i = self
            .index
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no parameter '{name}' in {}", self.model))?;
        if t.shape() != self.specs[i].shape.as_slice() {
            bail!("set('{name}'): shape {:?} != spec {:?}", t.shape(), self.specs[i].shape);
        }
        self.tensors[i] = t;
        Ok(())
    }

    /// Replace all tensors (e.g. after a train step); shapes are checked.
    pub fn replace_all(&mut self, tensors: Vec<Tensor>) -> Result<()> {
        if tensors.len() != self.specs.len() {
            bail!("replace_all: {} tensors for {} specs", tensors.len(), self.specs.len());
        }
        for (s, t) in self.specs.iter().zip(&tensors) {
            if t.shape() != s.shape.as_slice() {
                bail!("replace_all('{}'): shape {:?} != {:?}", s.name, t.shape(), s.shape);
            }
        }
        self.tensors = tensors;
        Ok(())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Tensor)> {
        self.specs.iter().zip(&self.tensors).map(|(s, t)| (s.name.as_str(), t))
    }

    /// The tensors of one decoder layer, in capture-artifact order.
    pub fn layer_tensors(&self, spec: &ModelSpec, layer: usize) -> Vec<&Tensor> {
        super::spec::layer_param_specs(spec, Some(layer))
            .iter()
            .map(|s| self.get(&s.name).expect("layer param must exist"))
            .collect()
    }

    /// Overall sparsity of the pruned (2-D, decaying) weights.
    pub fn weight_sparsity(&self) -> f64 {
        let (mut zeros, mut total) = (0usize, 0usize);
        for (s, t) in self.specs.iter().zip(&self.tensors) {
            if s.decay {
                zeros += t.data().iter().filter(|&&x| x == 0.0).count();
                total += t.len();
            }
        }
        if total == 0 {
            0.0
        } else {
            zeros as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::repo_root;
    use crate::model::init;

    #[test]
    fn build_get_set_roundtrip() {
        let root = repo_root().unwrap();
        let presets = Presets::load(&root).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let mut p = init::init_params(spec, 3);
        assert_eq!(p.model_name(), "topt-s1");
        let w = p.req("l0.wq").unwrap().clone();
        assert_eq!(w.shape(), &[64, 64]);
        let z = Tensor::zeros(vec![64, 64]);
        p.set("l0.wq", z.clone()).unwrap();
        assert_eq!(p.req("l0.wq").unwrap(), &z);
        assert!(p.set("l0.wq", Tensor::zeros(vec![2, 2])).is_err());
        assert!(p.set("nope", z).is_err());
    }

    #[test]
    fn from_map_validates() {
        let root = repo_root().unwrap();
        let presets = Presets::load(&root).unwrap();
        let spec = presets.model("tllama-s1").unwrap();
        let p = init::init_params(spec, 1);
        let map: BTreeMap<String, Tensor> =
            p.iter().map(|(n, t)| (n.to_string(), t.clone())).collect();
        let q = ModelParams::from_map("tllama-s1", map.clone()).unwrap();
        assert_eq!(q.tensors().len(), p.tensors().len());
        // missing tensor
        let mut bad = map.clone();
        bad.remove("l0.wq");
        assert!(ModelParams::from_map("tllama-s1", bad).is_err());
        // extra tensor
        let mut extra = map;
        extra.insert("bogus".into(), Tensor::zeros(vec![1]));
        assert!(ModelParams::from_map("tllama-s1", extra).is_err());
    }
}

//! Parameter specifications — the rust mirror of
//! python/compile/shapes.py::{layer_param_specs, model_param_specs}.
//!
//! The artifact input order is THE contract between the two languages:
//! rust/tests/manifest_parity.rs asserts this module agrees with
//! artifacts/manifest.json name-for-name and shape-for-shape.

use crate::config::{FamilyKind, ModelSpec};

/// One model parameter: canonical name, shape, weight-decay flag.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub decay: bool,
}

impl ParamSpec {
    fn new(name: impl Into<String>, shape: Vec<usize>, decay: bool) -> Self {
        ParamSpec { name: name.into(), shape, decay }
    }
}

/// Parameters of one decoder layer in canonical order.
/// `layer` = Some(i) prefixes names with `l{i}.` (full model); None is the
/// layer-generic order used by the capture artifact.
pub fn layer_param_specs(spec: &ModelSpec, layer: Option<usize>) -> Vec<ParamSpec> {
    let p = layer.map(|i| format!("l{i}.")).unwrap_or_default();
    let (d, ffn) = (spec.d, spec.ffn);
    let mut out = Vec::new();
    match spec.family {
        FamilyKind::Topt => {
            out.push(ParamSpec::new(format!("{p}ln1_g"), vec![d], false));
            out.push(ParamSpec::new(format!("{p}ln1_b"), vec![d], false));
            for nm in ["wq", "wk", "wv", "wo"] {
                out.push(ParamSpec::new(format!("{p}{nm}"), vec![d, d], true));
                if spec.bias {
                    out.push(ParamSpec::new(format!("{p}b{}", &nm[1..2]), vec![d], false));
                }
            }
            out.push(ParamSpec::new(format!("{p}ln2_g"), vec![d], false));
            out.push(ParamSpec::new(format!("{p}ln2_b"), vec![d], false));
            out.push(ParamSpec::new(format!("{p}w1"), vec![ffn, d], true));
            if spec.bias {
                out.push(ParamSpec::new(format!("{p}b1"), vec![ffn], false));
            }
            out.push(ParamSpec::new(format!("{p}w2"), vec![d, ffn], true));
            if spec.bias {
                out.push(ParamSpec::new(format!("{p}b2"), vec![d], false));
            }
        }
        FamilyKind::Tllama => {
            out.push(ParamSpec::new(format!("{p}rms1_g"), vec![d], false));
            for nm in ["wq", "wk", "wv", "wo"] {
                out.push(ParamSpec::new(format!("{p}{nm}"), vec![d, d], true));
            }
            out.push(ParamSpec::new(format!("{p}rms2_g"), vec![d], false));
            out.push(ParamSpec::new(format!("{p}wg"), vec![ffn, d], true));
            out.push(ParamSpec::new(format!("{p}wu"), vec![ffn, d], true));
            out.push(ParamSpec::new(format!("{p}wd"), vec![d, ffn], true));
        }
    }
    out
}

/// All model parameters in the canonical (manifest) order.
pub fn model_param_specs(spec: &ModelSpec) -> Vec<ParamSpec> {
    let mut out = vec![ParamSpec::new("embed", vec![spec.vocab, spec.d], false)];
    if spec.family == FamilyKind::Topt {
        out.push(ParamSpec::new("pos", vec![spec.seq, spec.d], false));
    }
    for li in 0..spec.layers {
        out.extend(layer_param_specs(spec, Some(li)));
    }
    match spec.family {
        FamilyKind::Topt => {
            out.push(ParamSpec::new("lnf_g", vec![spec.d], false));
            out.push(ParamSpec::new("lnf_b", vec![spec.d], false));
        }
        FamilyKind::Tllama => {
            out.push(ParamSpec::new("rmsf_g", vec![spec.d], false));
        }
    }
    out
}

/// Total parameter count of the model.
pub fn param_count(spec: &ModelSpec) -> usize {
    model_param_specs(spec).iter().map(|s| s.shape.iter().product::<usize>()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{repo_root, Presets};

    #[test]
    fn topt_has_biases_tllama_does_not() {
        let p = Presets::load(&repo_root().unwrap()).unwrap();
        let t = model_param_specs(p.model("topt-s1").unwrap());
        assert!(t.iter().any(|s| s.name == "l0.bq"));
        assert!(t.iter().any(|s| s.name == "pos"));
        let l = model_param_specs(p.model("tllama-s1").unwrap());
        assert!(l.iter().all(|s| !s.name.contains(".b")));
        assert!(l.iter().any(|s| s.name == "l1.wg"));
        assert!(!l.iter().any(|s| s.name == "pos"));
    }

    #[test]
    fn decay_only_on_matrices() {
        let p = Presets::load(&repo_root().unwrap()).unwrap();
        for m in ["topt-s2", "tllama-s2"] {
            for s in model_param_specs(p.model(m).unwrap()) {
                assert_eq!(s.decay, s.shape.len() == 2 && s.name != "embed" && s.name != "pos", "{}", s.name);
            }
        }
    }

    #[test]
    fn param_counts_scale_with_size() {
        let p = Presets::load(&repo_root().unwrap()).unwrap();
        let c1 = param_count(p.model("topt-s1").unwrap());
        let c5 = param_count(p.model("topt-s5").unwrap());
        assert!(c5 > 5 * c1, "s5 ({c5}) should dwarf s1 ({c1})");
    }
}

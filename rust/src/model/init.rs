//! Deterministic parameter initialization (GPT-2-style scheme).

use crate::config::ModelSpec;
use crate::tensor::Tensor;
use crate::util::Pcg64;

use super::params::ModelParams;

const INIT_STD: f32 = 0.02;

/// Initialize: N(0, 0.02²) for matrices/embeddings, 1 for norm gains,
/// 0 for biases. Residual-output projections (wo, w2/wd) are scaled by
/// 1/√(2·layers) per GPT-2 to keep the residual stream variance flat.
pub fn init_params(spec: &ModelSpec, seed: u64) -> ModelParams {
    let mut rng = Pcg64::new(seed, 31);
    let resid_scale = 1.0 / ((2 * spec.layers) as f32).sqrt();
    ModelParams::build(spec, |ps| {
        let len: usize = ps.shape.iter().product();
        let is_gain = ps.name.ends_with("_g");
        let is_bias = ps.name.contains(".b") || ps.name.ends_with("_b");
        if is_gain {
            Tensor::from_vec(ps.shape.clone(), vec![1.0; len])
        } else if is_bias {
            Tensor::zeros(ps.shape.clone())
        } else {
            let mut std = INIT_STD;
            if ps.name.ends_with("wo") || ps.name.ends_with("w2") || ps.name.ends_with("wd") {
                std *= resid_scale;
            }
            Tensor::from_vec(ps.shape.clone(), rng.normal_vec(len, std))
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{repo_root, Presets};

    #[test]
    fn deterministic_and_structured() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let a = init_params(spec, 1);
        let b = init_params(spec, 1);
        for ((_, x), (_, y)) in a.iter().zip(b.iter()) {
            assert_eq!(x, y);
        }
        let c = init_params(spec, 2);
        assert_ne!(a.req("embed").unwrap(), c.req("embed").unwrap());
        // gains are ones, biases zeros
        assert!(a.req("l0.ln1_g").unwrap().data().iter().all(|&v| v == 1.0));
        assert!(a.req("l0.bq").unwrap().data().iter().all(|&v| v == 0.0));
        // weights have roughly the right std
        let w = a.req("l0.wq").unwrap();
        let std = (w.data().iter().map(|&x| (x as f64).powi(2)).sum::<f64>() / w.len() as f64).sqrt();
        assert!((std - 0.02).abs() < 0.005, "std {std}");
    }
}

//! Model substrate: parameter specifications (mirroring
//! python/compile/shapes.py exactly — validated against
//! artifacts/manifest.json in tests), parameter containers, initialization,
//! and the pruned-operator enumeration the coordinator iterates over.

pub mod embed;
pub mod forward;
pub mod init;
pub mod ops;
pub mod params;
pub mod spec;

pub use ops::{pruned_ops, CaptureKey, PrunedOp};
pub use params::ModelParams;
pub use spec::{layer_param_specs, model_param_specs, ParamSpec};

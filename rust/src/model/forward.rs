//! Native rust forward pass — a from-scratch mirror of the L2 JAX graphs.
//!
//! Two jobs:
//! 1. **Differential oracle**: rust/tests/forward_parity.rs checks this
//!    implementation against the `score_{model}` artifact token-for-token,
//!    which pins down the cross-language semantics of every architectural
//!    detail (pre-LN placement, RoPE convention, SwiGLU order, tied head).
//! 2. **Artifact-free inference**: text generation (`eval::generate`) and
//!    the sparse-inference demo (`sparse::forward`) run on this path.

use std::collections::BTreeMap;

use crate::config::{FamilyKind, ModelSpec};
use crate::tensor::Tensor;

use super::params::ModelParams;

const EPS: f32 = 1e-5;

/// Forward one sequence of token ids; returns logits [len, vocab].
pub fn logits(spec: &ModelSpec, params: &ModelParams, tokens: &[i32]) -> Tensor {
    let s = tokens.len();
    assert!(s <= spec.seq, "sequence longer than model context");
    let d = spec.d;
    let embed = params.req("embed").expect("embed");
    // x: [s, d]
    let mut x = Tensor::zeros(vec![s, d]);
    for (t, &tok) in tokens.iter().enumerate() {
        let row = &embed.data()[tok as usize * d..(tok as usize + 1) * d];
        x.row_mut(t).copy_from_slice(row);
    }
    if spec.family == FamilyKind::Topt {
        let pos = params.req("pos").expect("pos");
        for t in 0..s {
            for (xi, &pv) in x.row_mut(t).iter_mut().zip(pos.row(t)) {
                *xi += pv;
            }
        }
    }
    for li in 0..spec.layers {
        x = layer_forward(spec, params, li, &x, |_name, w, input| {
            crate::tensor::ops::matmul_nt(input, w)
        });
    }
    x = logits_final_norm(spec, params, &x);
    // tied unembedding: logits = x @ embedᵀ
    crate::tensor::ops::matmul_nt(&x, embed)
}

/// One decoder layer over x [s, d]. `linop(name, W, input) → input @ Wᵀ`
/// is pluggable so the sparse path can substitute CSR matmuls.
pub fn layer_forward<F>(
    spec: &ModelSpec,
    params: &ModelParams,
    layer: usize,
    x: &Tensor,
    linop: F,
) -> Tensor
where
    F: FnMut(&str, &Tensor, &Tensor) -> Tensor,
{
    let specs = super::spec::layer_param_specs(spec, None);
    let map: BTreeMap<&str, &Tensor> = specs
        .iter()
        .map(|sp| {
            let t = params.req(&format!("l{layer}.{}", sp.name)).expect("layer param");
            (sp.name.as_str(), t)
        })
        .collect();
    layer_forward_mapped(spec, &map, x, |name, w, input| {
        linop(name, w.unwrap_or_else(|| panic!("layer param '{name}'")), input)
    })
}

/// Layer-generic variant of [`layer_forward`]: parameters are supplied as
/// a bare-name → tensor map (the capture-artifact order, no `l{i}.`
/// prefix). This is what the native capture path in the pruning unit runs
/// on — it holds a layer's tensors without a full `ModelParams`.
///
/// The pruned linear operators may be *absent* from the map: `linop`
/// receives the dense weight as an `Option` and the compiled sparse path
/// (`sparse::compile`) substitutes its compressed operator instead of a
/// dense tensor it never materializes. Norms and biases must be present.
pub fn layer_forward_mapped<F>(
    spec: &ModelSpec,
    params: &BTreeMap<&str, &Tensor>,
    x: &Tensor,
    mut linop: F,
) -> Tensor
where
    F: FnMut(&str, Option<&Tensor>, &Tensor) -> Tensor,
{
    let p = |n: &str| *params.get(n).unwrap_or_else(|| panic!("layer param '{n}'"));
    let w = |n: &str| params.get(n).copied();
    let (s, d) = (x.rows(), spec.d);
    let h = match spec.family {
        FamilyKind::Topt => layernorm(x, p("ln1_g"), p("ln1_b")),
        FamilyKind::Tllama => rmsnorm(x, p("rms1_g")),
    };
    let mut q = linop("wq", w("wq"), &h);
    let mut k = linop("wk", w("wk"), &h);
    let v = {
        let mut v = linop("wv", w("wv"), &h);
        if spec.bias {
            add_bias(&mut v, p("bv"));
        }
        v
    };
    if spec.bias {
        add_bias(&mut q, p("bq"));
        add_bias(&mut k, p("bk"));
    }
    if spec.family == FamilyKind::Tllama {
        rope_inplace(&mut q, spec.heads);
        rope_inplace(&mut k, spec.heads);
    }
    let ctx = causal_attention(&q, &k, &v, spec.heads);
    let mut attn_out = linop("wo", w("wo"), &ctx);
    if spec.bias {
        add_bias(&mut attn_out, p("bo"));
    }
    let mut x1 = x.clone();
    for (a, b) in x1.data_mut().iter_mut().zip(attn_out.data()) {
        *a += b;
    }

    let h2 = match spec.family {
        FamilyKind::Topt => layernorm(&x1, p("ln2_g"), p("ln2_b")),
        FamilyKind::Tllama => rmsnorm(&x1, p("rms2_g")),
    };
    let mlp_out = match spec.family {
        FamilyKind::Topt => {
            let mut f1 = linop("w1", w("w1"), &h2);
            if spec.bias {
                add_bias(&mut f1, p("b1"));
            }
            for v in f1.data_mut() {
                *v = gelu(*v);
            }
            let mut f2 = linop("w2", w("w2"), &f1);
            if spec.bias {
                add_bias(&mut f2, p("b2"));
            }
            f2
        }
        FamilyKind::Tllama => {
            let gate = linop("wg", w("wg"), &h2);
            let up = linop("wu", w("wu"), &h2);
            let mut hidden = Tensor::zeros(vec![s, spec.ffn]);
            for ((h, &g), &u) in hidden.data_mut().iter_mut().zip(gate.data()).zip(up.data()) {
                *h = silu(g) * u;
            }
            linop("wd", w("wd"), &hidden)
        }
    };
    for (a, b) in x1.data_mut().iter_mut().zip(mlp_out.data()) {
        *a += b;
    }
    let _ = d;
    x1
}

/// Final pre-head norm (public so the sparse path can reuse it).
pub fn logits_final_norm(spec: &ModelSpec, params: &ModelParams, x: &Tensor) -> Tensor {
    final_norm_with(spec, |n| params.req(n).expect("final-norm param"), x)
}

/// Final pre-head norm with a pluggable parameter lookup — the single
/// home of the family → final-norm-parameter dispatch, shared by the
/// dense path ([`logits_final_norm`]), the compiled sparse forward
/// (`sparse::compiled_logits`) and the serving stack
/// (`serve::batch::ServeModel`), so the three cannot drift apart.
pub fn final_norm_with<'t, F>(spec: &ModelSpec, p: F, x: &Tensor) -> Tensor
where
    F: Fn(&str) -> &'t Tensor,
{
    match spec.family {
        FamilyKind::Topt => layernorm(x, p("lnf_g"), p("lnf_b")),
        FamilyKind::Tllama => rmsnorm(x, p("rmsf_g")),
    }
}

/// Fallible twin of [`final_norm_with`] for callers whose lookup reports
/// checked errors instead of panicking (the serving hot path, where a
/// missing parameter must retire a request, not the process).
pub fn try_final_norm_with<'t, F>(
    spec: &ModelSpec,
    p: F,
    x: &Tensor,
) -> anyhow::Result<Tensor>
where
    F: Fn(&str) -> anyhow::Result<&'t Tensor>,
{
    Ok(match spec.family {
        FamilyKind::Topt => layernorm(x, p("lnf_g")?, p("lnf_b")?),
        FamilyKind::Tllama => rmsnorm(x, p("rmsf_g")?),
    })
}

pub(crate) fn layernorm(x: &Tensor, g: &Tensor, b: &Tensor) -> Tensor {
    let (s, d) = (x.rows(), x.cols());
    let mut out = Tensor::zeros(vec![s, d]);
    for t in 0..s {
        let row = x.row(t);
        let mean: f32 = row.iter().sum::<f32>() / d as f32;
        let var: f32 = row.iter().map(|&v| (v - mean) * (v - mean)).sum::<f32>() / d as f32;
        let inv = 1.0 / (var + EPS).sqrt();
        for (j, o) in out.row_mut(t).iter_mut().enumerate() {
            *o = (row[j] - mean) * inv * g.data()[j] + b.data()[j];
        }
    }
    out
}

pub(crate) fn rmsnorm(x: &Tensor, g: &Tensor) -> Tensor {
    let (s, d) = (x.rows(), x.cols());
    let mut out = Tensor::zeros(vec![s, d]);
    for t in 0..s {
        let row = x.row(t);
        let ms: f32 = row.iter().map(|&v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + EPS).sqrt();
        for (j, o) in out.row_mut(t).iter_mut().enumerate() {
            *o = row[j] * inv * g.data()[j];
        }
    }
    out
}

pub(crate) fn add_bias(x: &mut Tensor, b: &Tensor) {
    let n = x.cols();
    for row in x.data_mut().chunks_mut(n) {
        for (v, &bv) in row.iter_mut().zip(b.data()) {
            *v += bv;
        }
    }
}

pub(crate) fn gelu(x: f32) -> f32 {
    // tanh approximation — matches jax.nn.gelu's default
    0.5 * x * (1.0 + ((2.0 / std::f32::consts::PI).sqrt() * (x + 0.044715 * x * x * x)).tanh())
}

pub(crate) fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

/// RoPE over [s, d] with `heads` heads (first/second half pairing, matching
/// python/compile/model.py::_rope).
fn rope_inplace(x: &mut Tensor, heads: usize) {
    let s = x.rows();
    for t in 0..s {
        rope_row(x.row_mut(t), heads, t);
    }
}

/// RoPE over one projection row at absolute position `pos` — the
/// incremental-decode form of [`rope_inplace`], arithmetic identical so a
/// cached K row is bitwise equal to the same row of a full-sequence pass.
pub fn rope_row(row: &mut [f32], heads: usize, pos: usize) {
    let d = row.len();
    let hd = d / heads;
    let half = hd / 2;
    for h in 0..heads {
        let base = h * hd;
        for i in 0..half {
            let freq = (10000f32).powf(-(i as f32) / half as f32);
            let ang = pos as f32 * freq;
            let (sin, cos) = ang.sin_cos();
            let a = row[base + i];
            let b = row[base + half + i];
            row[base + i] = a * cos - b * sin;
            row[base + half + i] = a * sin + b * cos;
        }
    }
}

/// Causal multi-head attention over [s, d] projections.
fn causal_attention(q: &Tensor, k: &Tensor, v: &Tensor, heads: usize) -> Tensor {
    let (s, d) = (q.rows(), q.cols());
    let hd = d / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    let mut out = Tensor::zeros(vec![s, d]);
    let mut scores = vec![0f32; s];
    for h in 0..heads {
        let base = h * hd;
        for t in 0..s {
            // scores over positions 0..=t
            let qrow = &q.row(t)[base..base + hd];
            let mut max = f32::NEG_INFINITY;
            for (u, sc) in scores.iter_mut().enumerate().take(t + 1) {
                let krow = &k.row(u)[base..base + hd];
                let dot: f32 = qrow.iter().zip(krow).map(|(&a, &b)| a * b).sum();
                *sc = dot * scale;
                max = max.max(*sc);
            }
            let mut z = 0f32;
            for sc in scores.iter_mut().take(t + 1) {
                *sc = (*sc - max).exp();
                z += *sc;
            }
            let orow = &mut out.row_mut(t)[base..base + hd];
            for (u, &w) in scores.iter().enumerate().take(t + 1) {
                let vrow = &v.row(u)[base..base + hd];
                let wn = w / z;
                for (o, &vv) in orow.iter_mut().zip(vrow) {
                    *o += wn * vv;
                }
            }
        }
    }
    out
}

// ---------------------------------------------------------------------
// Incremental (KV-cached) decode
// ---------------------------------------------------------------------

/// One decoder layer's key/value cache for incremental decode: up to
/// `capacity` rows of projected K and V, appended one position at a time.
///
/// The serving stack (`serve::kv`) stacks one of these per layer per
/// request slot. Rows are stored exactly as the full-sequence forward
/// computes them (bias and RoPE already applied), so attention against the
/// cache reproduces `causal_attention` bitwise — see [`attend_one`].
#[derive(Clone, Debug)]
pub struct KvLayer {
    k: Vec<f32>,
    v: Vec<f32>,
    d: usize,
    len: usize,
}

impl KvLayer {
    /// Empty cache with room for `capacity` positions of width `d`.
    pub fn new(capacity: usize, d: usize) -> KvLayer {
        KvLayer { k: vec![0.0; capacity * d], v: vec![0.0; capacity * d], d, len: 0 }
    }

    /// Cached positions so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Maximum positions this cache can hold.
    pub fn capacity(&self) -> usize {
        self.k.len() / self.d.max(1)
    }

    /// Forget all cached positions (the buffers are reused).
    pub fn clear(&mut self) {
        self.len = 0;
    }

    /// Heap bytes held by the K and V buffers.
    pub fn bytes(&self) -> usize {
        4 * (self.k.len() + self.v.len())
    }

    /// Append the K/V projection rows of the next position.
    pub fn push(&mut self, k_row: &[f32], v_row: &[f32]) {
        assert_eq!(k_row.len(), self.d, "K row width");
        assert_eq!(v_row.len(), self.d, "V row width");
        assert!(self.len < self.capacity(), "KV cache overflow (capacity {})", self.capacity());
        let at = self.len * self.d;
        self.k[at..at + self.d].copy_from_slice(k_row);
        self.v[at..at + self.d].copy_from_slice(v_row);
        self.len += 1;
    }

    /// Cached K row for position `t`.
    pub fn k_row(&self, t: usize) -> &[f32] {
        debug_assert!(t < self.len);
        &self.k[t * self.d..(t + 1) * self.d]
    }

    /// Cached V row for position `t`.
    pub fn v_row(&self, t: usize) -> &[f32] {
        debug_assert!(t < self.len);
        &self.v[t * self.d..(t + 1) * self.d]
    }
}

/// Read access to one layer's cached K/V rows. Attention only needs
/// per-position row lookups, so the storage layout behind the cache is
/// pluggable: [`KvLayer`] keeps one contiguous full-context buffer (the
/// eval-path cache), while the serving stack's paged cache
/// (`serve::kv::PagedKvLayer`) resolves `t` through a block table of
/// fixed-size position pages. Row values are identical either way, so
/// [`attend_one`] / [`attend_prefix`] are bitwise independent of the
/// layout.
pub trait KvRead {
    /// Cached positions so far.
    fn len(&self) -> usize;
    /// Cached K row for position `t` (< `len`).
    fn k_row(&self, t: usize) -> &[f32];
    /// Cached V row for position `t` (< `len`).
    fn v_row(&self, t: usize) -> &[f32];
}

impl KvRead for KvLayer {
    fn len(&self) -> usize {
        KvLayer::len(self)
    }
    fn k_row(&self, t: usize) -> &[f32] {
        KvLayer::k_row(self, t)
    }
    fn v_row(&self, t: usize) -> &[f32] {
        KvLayer::v_row(self, t)
    }
}

/// Single-query causal attention of `q` (the latest position) against a
/// KV cache that already contains that position's K/V rows.
///
/// Arithmetic is a line-for-line mirror of the last row of
/// [`causal_attention`] — same score order, same softmax, same
/// value-accumulation order — so the result is bitwise identical to the
/// full-recompute path.
pub fn attend_one<K: KvRead + ?Sized>(q: &[f32], kv: &K, heads: usize) -> Vec<f32> {
    attend_prefix(q, kv, heads, kv.len())
}

/// [`attend_one`] over only the first `len` cached positions — the
/// batched-prefill form: prompt row t attends over rows 0..len (len =
/// t + 1) of a cache that already holds the whole prompt (or, chunked,
/// at least the first `len` positions of it).
pub fn attend_prefix<K: KvRead + ?Sized>(q: &[f32], kv: &K, heads: usize, len: usize) -> Vec<f32> {
    let d = q.len();
    let hd = d / heads;
    let scale = 1.0 / (hd as f32).sqrt();
    assert!(len > 0, "attention needs at least the query's own K/V row");
    assert!(len <= kv.len(), "prefix {len} beyond cached {}", kv.len());
    let mut out = vec![0f32; d];
    let mut scores = vec![0f32; len];
    for h in 0..heads {
        let base = h * hd;
        let qrow = &q[base..base + hd];
        let mut max = f32::NEG_INFINITY;
        for (u, sc) in scores.iter_mut().enumerate() {
            let krow = &kv.k_row(u)[base..base + hd];
            let dot: f32 = qrow.iter().zip(krow).map(|(&a, &b)| a * b).sum();
            *sc = dot * scale;
            max = max.max(*sc);
        }
        let mut z = 0f32;
        for sc in scores.iter_mut() {
            *sc = (*sc - max).exp();
            z += *sc;
        }
        let orow = &mut out[base..base + hd];
        for (u, &w) in scores.iter().enumerate() {
            let vrow = &kv.v_row(u)[base..base + hd];
            let wn = w / z;
            for (o, &vv) in orow.iter_mut().zip(vrow) {
                *o += wn * vv;
            }
        }
    }
    out
}

/// One decoder layer advanced by a single token. `x` is the [1, d] hidden
/// row at absolute position `pos`; the layer's K/V rows are appended to
/// `kv`. Same `linop` contract as [`layer_forward`].
pub fn layer_decode<F>(
    spec: &ModelSpec,
    params: &BTreeMap<&str, &Tensor>,
    kv: &mut KvLayer,
    x: &Tensor,
    pos: usize,
    mut linop: F,
) -> Tensor
where
    F: FnMut(&str, &Tensor, &Tensor) -> Tensor,
{
    let p = |n: &str| *params.get(n).unwrap_or_else(|| panic!("layer param '{n}'"));
    let h = match spec.family {
        FamilyKind::Topt => layernorm(x, p("ln1_g"), p("ln1_b")),
        FamilyKind::Tllama => rmsnorm(x, p("rms1_g")),
    };
    let mut q = linop("wq", p("wq"), &h);
    let mut k = linop("wk", p("wk"), &h);
    let v = {
        let mut v = linop("wv", p("wv"), &h);
        if spec.bias {
            add_bias(&mut v, p("bv"));
        }
        v
    };
    if spec.bias {
        add_bias(&mut q, p("bq"));
        add_bias(&mut k, p("bk"));
    }
    if spec.family == FamilyKind::Tllama {
        rope_row(q.row_mut(0), spec.heads, pos);
        rope_row(k.row_mut(0), spec.heads, pos);
    }
    kv.push(k.row(0), v.row(0));
    let ctx = Tensor::from_vec(vec![1, spec.d], attend_one(q.row(0), kv, spec.heads));
    let mut attn_out = linop("wo", p("wo"), &ctx);
    if spec.bias {
        add_bias(&mut attn_out, p("bo"));
    }
    let mut x1 = x.clone();
    for (a, b) in x1.data_mut().iter_mut().zip(attn_out.data()) {
        *a += b;
    }

    let h2 = match spec.family {
        FamilyKind::Topt => layernorm(&x1, p("ln2_g"), p("ln2_b")),
        FamilyKind::Tllama => rmsnorm(&x1, p("rms2_g")),
    };
    let mlp_out = match spec.family {
        FamilyKind::Topt => {
            let mut f1 = linop("w1", p("w1"), &h2);
            if spec.bias {
                add_bias(&mut f1, p("b1"));
            }
            for v in f1.data_mut() {
                *v = gelu(*v);
            }
            let mut f2 = linop("w2", p("w2"), &f1);
            if spec.bias {
                add_bias(&mut f2, p("b2"));
            }
            f2
        }
        FamilyKind::Tllama => {
            let gate = linop("wg", p("wg"), &h2);
            let up = linop("wu", p("wu"), &h2);
            let mut hidden = Tensor::zeros(vec![1, spec.ffn]);
            for ((h, &g), &u) in hidden.data_mut().iter_mut().zip(gate.data()).zip(up.data()) {
                *h = silu(g) * u;
            }
            linop("wd", p("wd"), &hidden)
        }
    };
    for (a, b) in x1.data_mut().iter_mut().zip(mlp_out.data()) {
        *a += b;
    }
    x1
}

/// Feed one token through the model with per-layer KV caches and return
/// its logits row — the O(1)-layer-forwards incremental decode step.
///
/// With a cache warmed on `tokens[..pos]`, the result equals row `pos` of
/// `logits(spec, params, &tokens[..pos + 1])` bitwise: every per-row
/// operation (norms, projections, RoPE, attention against cached rows)
/// performs the identical arithmetic in the identical order.
pub fn decode_next(
    spec: &ModelSpec,
    params: &ModelParams,
    cache: &mut [KvLayer],
    token: i32,
    pos: usize,
) -> Vec<f32> {
    decode_next_with(spec, params, cache, token, pos, |_layer, _name, w, input| {
        crate::tensor::ops::matmul_nt(input, w)
    })
}

/// [`decode_next`] with a pluggable per-layer linear operator, so the
/// sparse serving path can substitute CSR kernels.
pub fn decode_next_with<F>(
    spec: &ModelSpec,
    params: &ModelParams,
    cache: &mut [KvLayer],
    token: i32,
    pos: usize,
    mut linop: F,
) -> Vec<f32>
where
    F: FnMut(usize, &str, &Tensor, &Tensor) -> Tensor,
{
    assert_eq!(cache.len(), spec.layers, "one KvLayer per decoder layer");
    assert!(pos < spec.seq, "position {pos} outside model context {}", spec.seq);
    assert_eq!(cache[0].len(), pos, "cache must hold exactly the {pos}-token prefix");
    let d = spec.d;
    let embed = params.req("embed").expect("embed");
    let mut x = Tensor::zeros(vec![1, d]);
    x.row_mut(0).copy_from_slice(&embed.data()[token as usize * d..(token as usize + 1) * d]);
    if spec.family == FamilyKind::Topt {
        let pos_t = params.req("pos").expect("pos");
        for (xi, &pv) in x.row_mut(0).iter_mut().zip(pos_t.row(pos)) {
            *xi += pv;
        }
    }
    let specs = super::spec::layer_param_specs(spec, None);
    for li in 0..spec.layers {
        let map: BTreeMap<&str, &Tensor> = specs
            .iter()
            .map(|sp| {
                let t = params.req(&format!("l{li}.{}", sp.name)).expect("layer param");
                (sp.name.as_str(), t)
            })
            .collect();
        x = layer_decode(spec, &map, &mut cache[li], &x, pos, |name, w, input| {
            linop(li, name, w, input)
        });
    }
    let x = logits_final_norm(spec, params, &x);
    crate::tensor::ops::matmul_nt(&x, embed).into_vec()
}

/// Per-token NLL of `tokens[1..]` given the prefix (native mirror of the
/// score artifact).
pub fn nll(spec: &ModelSpec, params: &ModelParams, tokens: &[i32]) -> f64 {
    nll_from(spec, params, tokens, 0)
}

/// NLL of `tokens[t0+1..]` given the prefix — the native mirror of the
/// score artifact's suffix mask (zero-shot probes score only the
/// continuation region).
pub fn nll_from(spec: &ModelSpec, params: &ModelParams, tokens: &[i32], t0: usize) -> f64 {
    let lg = logits(spec, params, &tokens[..tokens.len() - 1]);
    let vocab = spec.vocab;
    let mut total = 0f64;
    for t in t0..lg.rows() {
        let row = lg.row(t);
        let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let z: f64 = row.iter().map(|&v| ((v - max) as f64).exp()).sum();
        let tgt = tokens[t + 1] as usize;
        assert!(tgt < vocab);
        total += -((row[tgt] - max) as f64 - z.ln());
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{repo_root, Presets};
    use crate::model::init::init_params;

    #[test]
    fn logits_shapes_and_finite() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        for m in ["topt-s1", "tllama-s1"] {
            let spec = presets.model(m).unwrap();
            let params = init_params(spec, 3);
            let tokens: Vec<i32> = (0..16).map(|i| (i * 5) % 96).collect();
            let lg = logits(spec, &params, &tokens);
            assert_eq!(lg.shape(), &[16, 96]);
            assert!(lg.data().iter().all(|v| v.is_finite()), "{m}");
        }
    }

    #[test]
    fn causality_native() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("tllama-s1").unwrap();
        let params = init_params(spec, 5);
        let a: Vec<i32> = (0..12).map(|i| i % 96).collect();
        let mut b = a.clone();
        *b.last_mut().unwrap() = 77;
        let la = logits(spec, &params, &a);
        let lb = logits(spec, &params, &b);
        for t in 0..11 {
            assert_eq!(la.row(t), lb.row(t), "position {t} changed");
        }
        assert_ne!(la.row(11), lb.row(11));
    }

    #[test]
    fn incremental_decode_matches_full_forward_bitwise() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        for m in ["topt-s1", "tllama-s1"] {
            let spec = presets.model(m).unwrap();
            let params = init_params(spec, 11);
            let tokens: Vec<i32> = (0..20).map(|i| (i * 7 + 3) % 96).collect();
            let mut cache: Vec<KvLayer> =
                (0..spec.layers).map(|_| KvLayer::new(spec.seq, spec.d)).collect();
            for (pos, &tok) in tokens.iter().enumerate() {
                let inc = decode_next(spec, &params, &mut cache, tok, pos);
                let full = logits(spec, &params, &tokens[..pos + 1]);
                let want = full.row(pos);
                assert_eq!(inc.len(), want.len());
                for (j, (&a, &b)) in inc.iter().zip(want).enumerate() {
                    assert_eq!(a.to_bits(), b.to_bits(), "{m} pos {pos} logit {j}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn kv_layer_push_and_overflow() {
        let mut kv = KvLayer::new(3, 4);
        assert!(kv.is_empty());
        assert_eq!(kv.capacity(), 3);
        kv.push(&[1., 2., 3., 4.], &[5., 6., 7., 8.]);
        assert_eq!(kv.len(), 1);
        assert_eq!(kv.k_row(0), &[1., 2., 3., 4.]);
        assert_eq!(kv.v_row(0), &[5., 6., 7., 8.]);
        kv.clear();
        assert!(kv.is_empty());
        for _ in 0..3 {
            kv.push(&[0.; 4], &[0.; 4]);
        }
        let full = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            kv.push(&[0.; 4], &[0.; 4]);
        }));
        assert!(full.is_err(), "push past capacity must panic");
    }

    #[test]
    fn nll_near_uniform_for_random_model() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 7);
        let tokens: Vec<i32> = (0..33).map(|i| (i * 7) % 96).collect();
        let per_tok = nll(spec, &params, &tokens) / 32.0;
        let uniform = (96f64).ln();
        assert!((per_tok - uniform).abs() < 1.0, "per-token nll {per_tok} vs ln96 {uniform}");
    }
}

//! artifacts/manifest.json — the contract between aot.py and the runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::ser::json::Json;

/// Element type of an artifact argument.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unknown dtype '{other}' in manifest"),
        }
    }
}

/// One artifact input: name, dims, dtype (in positional order).
#[derive(Clone, Debug)]
pub struct ArgSpec {
    pub name: String,
    pub dims: Vec<usize>,
    pub dtype: DType,
}

/// One lowered artifact.
#[derive(Clone, Debug)]
pub struct ArtifactInfo {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<ArgSpec>,
    pub outputs: usize,
}

/// The whole manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub seq_len: usize,
    pub capture_batch: usize,
    pub train_batch: usize,
    pub gram_chunk: usize,
    pub artifacts: BTreeMap<String, ArtifactInfo>,
    /// Raw model metadata (params/ops) for cross-language parity tests.
    pub models_json: Json,
}

impl Manifest {
    /// Load from `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let v = Json::parse_file(&dir.join("manifest.json"))
            .context("manifest.json missing — run `make artifacts` first")?;
        let mut artifacts = BTreeMap::new();
        for (name, av) in v.req("artifacts")?.as_obj().context("artifacts")? {
            let mut inputs = Vec::new();
            for iv in av.req("inputs")?.as_arr().context("inputs")? {
                inputs.push(ArgSpec {
                    name: iv.req("name")?.as_str().context("input name")?.to_string(),
                    dims: iv
                        .req("dims")?
                        .as_arr()
                        .context("dims")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<_>>()?,
                    dtype: DType::parse(iv.req("dtype")?.as_str().context("dtype")?)?,
                });
            }
            artifacts.insert(
                name.clone(),
                ArtifactInfo {
                    name: name.clone(),
                    file: dir.join(av.req("file")?.as_str().context("file")?),
                    inputs,
                    outputs: av.req("outputs")?.as_usize().context("outputs")?,
                },
            );
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            seq_len: v.req("seq_len")?.as_usize().context("seq_len")?,
            capture_batch: v.req("capture_batch")?.as_usize().context("capture_batch")?,
            train_batch: v.req("train_batch")?.as_usize().context("train_batch")?,
            gram_chunk: v.req("gram_chunk")?.as_usize().context("gram_chunk")?,
            artifacts,
            models_json: v.req("models")?.clone(),
        })
    }

    /// Load from the repository's default artifacts directory.
    pub fn load_default() -> Result<Manifest> {
        let root = crate::config::repo_root()?;
        Self::load(&crate::config::paths::artifacts_dir(&root))
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactInfo> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow::anyhow!("artifact '{name}' not in manifest (run `make artifacts`?)")
        })
    }

    /// True if the HLO file for `name` exists on disk.
    pub fn available(&self, name: &str) -> bool {
        self.artifacts.get(name).map(|a| a.file.exists()).unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_manifest_and_artifacts_exist() {
        let Some(m) = crate::testing::try_manifest() else { return };
        assert_eq!(m.seq_len, 64);
        assert!(m.artifacts.len() >= 70, "expected ~74 artifacts, got {}", m.artifacts.len());
        for key in ["fista_64x64", "gram_64", "power_64", "capture_topt-s1", "score_topt-s1", "train_topt-s1"] {
            let a = m.artifact(key).unwrap();
            assert!(a.file.exists(), "{} missing on disk", a.file.display());
        }
        let f = m.artifact("fista_64x64").unwrap();
        assert_eq!(f.inputs.len(), 5);
        assert_eq!(f.inputs[0].name, "a");
        assert_eq!(f.inputs[0].dims, vec![64, 64]);
        assert_eq!(f.outputs, 2);
        assert!(m.artifact("nope").is_err());
    }

    #[test]
    fn score_has_i32_tokens() {
        let Some(m) = crate::testing::try_manifest() else { return };
        let s = m.artifact("score_tllama-s1").unwrap();
        let tok = s.inputs.iter().find(|i| i.name == "tokens").unwrap();
        assert_eq!(tok.dtype, DType::I32);
        assert_eq!(tok.dims, vec![m.capture_batch, m.seq_len + 1]);
    }
}

//! The device fleet: N worker threads, each owning a `Session`.
//!
//! The paper's parallel pruning (§3.4) treats each decoder layer as an
//! independent unit schedulable on its own device. Here a "device" is one
//! worker thread with its own PJRT CPU client (the client is not `Send`,
//! so sessions cannot be shared). Jobs are `FnOnce(&Session)` closures
//! pulled from a shared FIFO queue; results flow back through per-caller
//! channels embedded in the closures.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::manifest::Manifest;
use super::session::Session;

type Job = Box<dyn FnOnce(&Session) + Send + 'static>;

struct Queue {
    jobs: Mutex<(VecDeque<Job>, bool)>, // (queue, shutdown)
    cv: Condvar,
}

/// A pool of PJRT worker threads.
pub struct ExecutorPool {
    queue: Arc<Queue>,
    workers: Vec<JoinHandle<()>>,
}

impl ExecutorPool {
    /// Spawn `n` workers, each with its own `Session` over `manifest`.
    ///
    /// Fails fast if any worker cannot create its session (PJRT backend
    /// not built, artifacts missing): a pool whose workers died at startup
    /// would otherwise strand every submitted job and deadlock callers
    /// blocked on result channels.
    pub fn new(manifest: Arc<Manifest>, n: usize) -> Result<ExecutorPool> {
        assert!(n > 0);
        let queue = Arc::new(Queue { jobs: Mutex::new((VecDeque::new(), false)), cv: Condvar::new() });
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
        let mut workers = Vec::with_capacity(n);
        for wid in 0..n {
            let q = queue.clone();
            let m = manifest.clone();
            let ready = ready_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("pjrt-worker-{wid}"))
                    // fp-lint: allow(det-spawn) — pool workers pull an indexed queue; results re-ordered
                    .spawn(move || worker_loop(q, m, ready))
                    .expect("spawn worker"),
            );
        }
        drop(ready_tx);
        let pool = ExecutorPool { queue, workers };
        for _ in 0..n {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => return Err(e.context("executor pool worker startup")),
                Err(_) => anyhow::bail!("executor pool worker died during startup"),
            }
        }
        Ok(pool)
    }

    /// Enqueue a job; it will run on some worker's session.
    pub fn submit(&self, job: impl FnOnce(&Session) + Send + 'static) {
        let mut guard = self.queue.jobs.lock().unwrap();
        guard.0.push_back(Box::new(job));
        drop(guard);
        self.queue.cv.notify_one();
    }

    /// Convenience: run `f` on a worker and block for its value.
    pub fn run_blocking<T: Send + 'static>(
        &self,
        f: impl FnOnce(&Session) -> T + Send + 'static,
    ) -> T {
        let (tx, rx) = std::sync::mpsc::channel();
        self.submit(move |s| {
            let _ = tx.send(f(s));
        });
        rx.recv().expect("worker dropped result (panicked?)")
    }

    pub fn workers(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        {
            let mut guard = self.queue.jobs.lock().unwrap();
            guard.1 = true;
        }
        self.queue.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    queue: Arc<Queue>,
    manifest: Arc<Manifest>,
    ready: std::sync::mpsc::Sender<Result<()>>,
) {
    let session = match Session::new(manifest) {
        Ok(s) => {
            let _ = ready.send(Ok(()));
            s
        }
        Err(e) => {
            crate::log_error!("worker failed to create PJRT session: {e}");
            let _ = ready.send(Err(e));
            return;
        }
    };
    drop(ready);
    loop {
        let job = {
            let mut guard = queue.jobs.lock().unwrap();
            loop {
                if let Some(job) = guard.0.pop_front() {
                    break job;
                }
                if guard.1 {
                    return;
                }
                guard = queue.cv.wait(guard).unwrap();
            }
        };
        job(&session);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::session::Arg;
    use crate::tensor::Tensor;

    fn try_pool(n: usize) -> Option<(Arc<Manifest>, ExecutorPool)> {
        let manifest = Arc::new(crate::testing::try_manifest()?);
        match ExecutorPool::new(manifest.clone(), n) {
            Ok(pool) => Some((manifest, pool)),
            Err(e) => {
                eprintln!("skipping pool test (no PJRT backend): {e:#}");
                None
            }
        }
    }

    #[test]
    fn pool_runs_jobs_on_all_workers() {
        let Some((manifest, pool)) = try_pool(2) else { return };
        let chunk = manifest.gram_chunk;
        let (tx, rx) = std::sync::mpsc::channel();
        for i in 0..4 {
            let tx = tx.clone();
            pool.submit(move |s| {
                let x = Tensor::from_vec(vec![64, chunk], vec![1.0; 64 * chunk]);
                let out = s.run("gram_64", &[Arg::T(&x), Arg::T(&x)]).unwrap();
                tx.send((i, out[0].first())).unwrap();
            });
        }
        drop(tx);
        let results: Vec<_> = rx.iter().collect();
        assert_eq!(results.len(), 4);
        for (_, v) in results {
            assert_eq!(v, chunk as f32); // row of ones dotted with itself
        }
    }

    #[test]
    fn run_blocking_returns_value() {
        let Some((_manifest, pool)) = try_pool(1) else { return };
        let x = pool.run_blocking(|_s| 41 + 1);
        assert_eq!(x, 42);
    }

    #[test]
    fn startup_failure_is_an_error_not_a_hang() {
        // A manifest pointing at an empty directory (or the stub backend)
        // must fail pool construction instead of stranding jobs.
        let dir = std::env::temp_dir().join(format!("fp_pool_{}", std::process::id()));
        std::fs::create_dir_all(&dir).ok();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"seq_len": 64, "capture_batch": 8, "train_batch": 8, "gram_chunk": 256,
                "artifacts": {}, "models": {}}"#,
        )
        .unwrap();
        let manifest = Arc::new(Manifest::load(&dir).unwrap());
        if cfg!(feature = "xla-pjrt") {
            // real backend: sessions start fine over an empty manifest
            let _ = ExecutorPool::new(manifest, 1);
        } else {
            assert!(ExecutorPool::new(manifest, 1).is_err());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! A PJRT CPU session: one client + lazily-compiled executables.
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos use 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The real backend requires the vendored `xla` crate and is compiled only
//! under the `xla-pjrt` feature (see rust/Cargo.toml). Without it this
//! module provides a stub with the identical API whose constructor fails —
//! callers that can run natively (`Engine::Native`, the whole pruning and
//! evaluation stack) are unaffected; callers that genuinely need artifacts
//! get a clear error instead of a link failure.

use std::sync::Arc;

use anyhow::Result;

use crate::tensor::Tensor;

use super::manifest::Manifest;

/// An argument to an artifact execution.
pub enum Arg<'a> {
    /// f32 tensor (shape checked against the manifest).
    T(&'a Tensor),
    /// f32 scalar (rank-0).
    Scalar(f32),
    /// i32 buffer with explicit dims (token batches).
    I32(&'a [i32], &'a [usize]),
}

pub use backend::Session;

#[cfg(not(feature = "xla-pjrt"))]
mod backend {
    use super::*;
    use anyhow::bail;

    /// Stub session for builds without the PJRT backend. `new` and `run`
    /// fail with an explanatory error; `Engine::Native` never needs one.
    pub struct Session {
        manifest: Arc<Manifest>,
    }

    const UNAVAILABLE: &str = "PJRT backend not built: enable the `xla-pjrt` cargo feature \
         (requires the vendored `xla` crate) or run with the native engine";

    impl Session {
        pub fn new(manifest: Arc<Manifest>) -> Result<Session> {
            let _ = &manifest;
            bail!("{UNAVAILABLE}")
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Number of executables compiled so far (always 0 in the stub).
        pub fn compiled_count(&self) -> usize {
            0
        }

        /// Execute artifact `name` — always an error in the stub.
        pub fn run(&self, name: &str, _args: &[Arg<'_>]) -> Result<Vec<Tensor>> {
            bail!("cannot execute artifact '{name}': {UNAVAILABLE}")
        }
    }
}

#[cfg(feature = "xla-pjrt")]
mod backend {
    use std::cell::RefCell;
    use std::collections::BTreeMap;

    use anyhow::{bail, Context};

    use super::*;
    use crate::runtime::manifest::{ArtifactInfo, DType};

    /// One PJRT client + compiled-executable cache. Not `Send` (the client
    /// is `Rc`-backed); each pool worker owns its own session.
    pub struct Session {
        client: xla::PjRtClient,
        manifest: Arc<Manifest>,
        exes: RefCell<BTreeMap<String, xla::PjRtLoadedExecutable>>,
    }

    impl Session {
        pub fn new(manifest: Arc<Manifest>) -> Result<Session> {
            let client =
                xla::PjRtClient::cpu().map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e}"))?;
            Ok(Session { client, manifest, exes: RefCell::new(BTreeMap::new()) })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        /// Compile (or fetch from cache) the executable for `name`.
        fn executable(&self, name: &str) -> Result<()> {
            if self.exes.borrow().contains_key(name) {
                return Ok(());
            }
            let info = self.manifest.artifact(name)?;
            let path = info
                .file
                .to_str()
                .with_context(|| format!("non-utf8 path {:?}", info.file))?;
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?;
            self.exes.borrow_mut().insert(name.to_string(), exe);
            Ok(())
        }

        /// Number of executables compiled so far (perf introspection).
        pub fn compiled_count(&self) -> usize {
            self.exes.borrow().len()
        }

        /// Execute artifact `name` with positional `args`; returns the output
        /// tuple as f32 tensors (i32 outputs are widened to f32).
        ///
        /// Inputs go through `buffer_from_host_buffer` + `execute_b`, NOT
        /// `execute(&[Literal])`: the crate's literal-execute path leaks the
        /// device buffers it creates per call (~input size per execution,
        /// found via OOM during training); `PjRtBuffer`s we own are freed on
        /// drop.
        pub fn run(&self, name: &str, args: &[Arg<'_>]) -> Result<Vec<Tensor>> {
            let info = self.manifest.artifact(name)?;
            validate_args(info, args)?;
            self.executable(name)?;
            let buffers: Vec<xla::PjRtBuffer> =
                args.iter().map(|a| self.to_buffer(a)).collect::<Result<_>>()?;
            let exes = self.exes.borrow();
            let exe = exes.get(name).expect("compiled above");
            let outputs = exe
                .execute_b::<xla::PjRtBuffer>(&buffers)
                .map_err(|e| anyhow::anyhow!("executing {name}: {e}"))?;
            drop(buffers);
            let lit = outputs
                .first()
                .and_then(|d| d.first())
                .context("no output buffer")?
                .to_literal_sync()
                .map_err(|e| anyhow::anyhow!("fetching {name} output: {e}"))?;
            // aot.py lowers with return_tuple=True: the single output is a tuple.
            let parts = lit
                .to_tuple()
                .map_err(|e| anyhow::anyhow!("untupling {name} output: {e}"))?;
            if parts.len() != info.outputs {
                bail!("{name}: got {} outputs, manifest says {}", parts.len(), info.outputs);
            }
            parts.into_iter().map(|l| literal_to_tensor(&l)).collect()
        }

        fn to_buffer(&self, arg: &Arg<'_>) -> Result<xla::PjRtBuffer> {
            match arg {
                Arg::T(t) => self
                    .client
                    .buffer_from_host_buffer::<f32>(t.data(), t.shape(), None)
                    .map_err(|e| anyhow::anyhow!("f32 buffer: {e}")),
                Arg::Scalar(x) => self
                    .client
                    .buffer_from_host_buffer::<f32>(&[*x], &[], None)
                    .map_err(|e| anyhow::anyhow!("scalar buffer: {e}")),
                Arg::I32(data, dims) => self
                    .client
                    .buffer_from_host_buffer::<i32>(data, dims, None)
                    .map_err(|e| anyhow::anyhow!("i32 buffer: {e}")),
            }
        }
    }

    fn validate_args(info: &ArtifactInfo, args: &[Arg<'_>]) -> Result<()> {
        if args.len() != info.inputs.len() {
            bail!("{}: {} args given, {} expected", info.name, args.len(), info.inputs.len());
        }
        for (i, (arg, spec)) in args.iter().zip(&info.inputs).enumerate() {
            let (dims, dtype): (Vec<usize>, DType) = match arg {
                Arg::T(t) => (t.shape().to_vec(), DType::F32),
                Arg::Scalar(_) => (vec![], DType::F32),
                Arg::I32(data, dims) => {
                    if data.len() != dims.iter().product::<usize>() {
                        bail!("{} arg {i} ({}): i32 data/dims mismatch", info.name, spec.name);
                    }
                    (dims.to_vec(), DType::I32)
                }
            };
            if dims != spec.dims || dtype != spec.dtype {
                bail!(
                    "{} arg {i} ({}): got {:?}/{:?}, expected {:?}/{:?}",
                    info.name, spec.name, dims, dtype, spec.dims, spec.dtype
                );
            }
        }
        Ok(())
    }

    fn literal_to_tensor(lit: &xla::Literal) -> Result<Tensor> {
        let shape = lit.array_shape().map_err(|e| anyhow::anyhow!("output shape: {e}"))?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let ty = lit.ty().map_err(|e| anyhow::anyhow!("output ty: {e}"))?;
        let data: Vec<f32> = match ty {
            xla::ElementType::F32 => {
                lit.to_vec::<f32>().map_err(|e| anyhow::anyhow!("to_vec f32: {e}"))?
            }
            xla::ElementType::S32 => lit
                .to_vec::<i32>()
                .map_err(|e| anyhow::anyhow!("to_vec i32: {e}"))?
                .into_iter()
                .map(|x| x as f32)
                .collect(),
            other => bail!("unsupported output element type {other:?}"),
        };
        Ok(Tensor::from_vec(dims, data))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops as tops;
    use crate::tensor::Tensor;
    use crate::util::Pcg64;

    #[test]
    fn gram_artifact_matches_native() {
        let Some(s) = crate::testing::try_session() else { return };
        let chunk = s.manifest().gram_chunk;
        let mut rng = Pcg64::seeded(1);
        let xd = Tensor::from_vec(vec![64, chunk], rng.normal_vec(64 * chunk, 1.0));
        let xs = Tensor::from_vec(vec![64, chunk], rng.normal_vec(64 * chunk, 1.0));
        let out = s.run("gram_64", &[Arg::T(&xd), Arg::T(&xs)]).unwrap();
        assert_eq!(out.len(), 3);
        let a_native = tops::matmul_nt(&xs, &xs);
        let c_native = tops::matmul_nt(&xd, &xs);
        let d_native = tops::matmul_nt(&xd, &xd);
        assert!(tops::frob_dist(&out[0], &a_native) < 1e-2 * a_native.frob_norm());
        assert!(tops::frob_dist(&out[1], &c_native) < 1e-2 * c_native.frob_norm());
        assert!(tops::frob_dist(&out[2], &d_native) < 1e-2 * d_native.frob_norm());
    }

    #[test]
    fn power_artifact_matches_native() {
        let Some(s) = crate::testing::try_session() else { return };
        let mut rng = Pcg64::seeded(2);
        let x = Tensor::from_vec(vec![64, 200], rng.normal_vec(64 * 200, 1.0));
        let a = tops::matmul_nt(&x, &x);
        let out = s.run("power_64", &[Arg::T(&a)]).unwrap();
        let l_xla = out[0].first() as f64;
        let l_native = crate::linalg::power_iteration(&a, 64, 1.02);
        assert!((l_xla - l_native).abs() < 0.02 * l_native, "{l_xla} vs {l_native}");
    }

    #[test]
    fn arg_validation_rejects_bad_shapes() {
        let Some(s) = crate::testing::try_session() else { return };
        let t = Tensor::zeros(vec![3, 3]);
        assert!(s.run("gram_64", &[Arg::T(&t), Arg::T(&t)]).is_err());
        let good = Tensor::zeros(vec![64, s.manifest().gram_chunk]);
        assert!(s.run("gram_64", &[Arg::T(&good)]).is_err(), "arity check");
    }

    #[test]
    fn stub_backend_reports_unavailable() {
        // Without the xla-pjrt feature Session::new must fail loudly, not
        // hang or panic — the native engine is the supported path then.
        if cfg!(feature = "xla-pjrt") {
            return;
        }
        if let Some(m) = crate::testing::try_manifest() {
            let err = Session::new(Arc::new(m));
            assert!(err.is_err());
        }
    }
}

//! PJRT runtime: loads the AOT artifacts (HLO text) produced by
//! `make artifacts` and executes them from the coordinator hot path.
//!
//! * `manifest`  — typed view of artifacts/manifest.json (the cross-language
//!   contract: artifact → input order/shapes/dtypes → output arity).
//! * `session`   — one PJRT CPU client + a lazily-compiled executable cache.
//!   `PjRtClient` is `Rc`-backed (not `Send`), so a `Session` is pinned to
//!   its thread.
//! * `pool`      — the "device fleet": N worker threads, each owning its own
//!   `Session`, pulling prune-unit jobs from a shared queue (the paper's
//!   parallel layer-wise pruning, §3.4).

pub mod manifest;
pub mod pool;
pub mod session;

pub use manifest::{ArgSpec, ArtifactInfo, DType, Manifest};
pub use pool::ExecutorPool;
pub use session::{Arg, Session};

//! Char-level tokenizer over printable ASCII.
//!
//! Token id = byte − 32, covering 0x20..0x7F (96 symbols — exactly
//! `vocab_size` in configs/presets.json). Unknown bytes map to '?'.

pub const VOCAB_SIZE: usize = 96;
const BASE: u8 = 0x20;

/// Encode text to token ids.
pub fn encode(text: &str) -> Vec<i32> {
    text.bytes()
        .map(|b| {
            if (BASE..BASE + VOCAB_SIZE as u8).contains(&b) {
                (b - BASE) as i32
            } else {
                (b'?' - BASE) as i32
            }
        })
        .collect()
}

/// Decode token ids back to text.
pub fn decode(ids: &[i32]) -> String {
    ids.iter()
        .map(|&t| {
            let t = t.clamp(0, VOCAB_SIZE as i32 - 1) as u8;
            (t + BASE) as char
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_printable() {
        let s = "the quick brown fox! 123 (etc.)";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn unknown_maps_to_question_mark() {
        let ids = encode("a\nb");
        assert_eq!(decode(&ids), "a?b");
    }

    #[test]
    fn ids_in_range() {
        for t in encode("any ascii text ~") {
            assert!((0..VOCAB_SIZE as i32).contains(&t));
        }
    }
}

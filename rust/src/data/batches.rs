//! Batching helpers: pack token windows into fixed-size model batches,
//! zero-padding and masking the tail.

/// A fixed-shape batch for the score/capture artifacts.
pub struct Batch {
    /// [batch, seq+1] flattened row-major.
    pub tokens: Vec<i32>,
    /// [batch, seq] flattened; 1.0 = real token position, 0.0 = padding.
    pub mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
    /// Number of real (unpadded) rows.
    pub rows: usize,
}

/// Pack `windows` (each seq+1 tokens) into batches of exactly `batch` rows.
/// The final batch is padded with zero rows whose mask is all-zero.
pub fn pack(windows: &[Vec<i32>], batch: usize, seq: usize) -> Vec<Batch> {
    assert!(windows.iter().all(|w| w.len() == seq + 1), "window length must be seq+1");
    let mut out = Vec::new();
    for chunk in windows.chunks(batch) {
        let mut tokens = vec![0i32; batch * (seq + 1)];
        let mut mask = vec![0f32; batch * seq];
        for (r, w) in chunk.iter().enumerate() {
            tokens[r * (seq + 1)..(r + 1) * (seq + 1)].copy_from_slice(w);
            for m in &mut mask[r * seq..(r + 1) * seq] {
                *m = 1.0;
            }
        }
        out.push(Batch { tokens, mask, batch, seq, rows: chunk.len() });
    }
    out
}

/// Training batches: sample `batch` windows per step from a token stream.
pub fn train_batch(
    train: &[i32],
    batch: usize,
    seq: usize,
    rng: &mut crate::util::Pcg64,
) -> Vec<i32> {
    let mut tokens = vec![0i32; batch * (seq + 1)];
    for r in 0..batch {
        let start = rng.below((train.len() - seq - 1) as u64) as usize;
        tokens[r * (seq + 1)..(r + 1) * (seq + 1)].copy_from_slice(&train[start..start + seq + 1]);
    }
    tokens
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pads_last_batch() {
        let windows: Vec<Vec<i32>> = (0..5).map(|i| vec![i; 9]).collect();
        let batches = pack(&windows, 4, 8);
        assert_eq!(batches.len(), 2);
        assert_eq!(batches[0].rows, 4);
        assert_eq!(batches[1].rows, 1);
        // padded row mask is zero
        let m = &batches[1].mask;
        assert!(m[8..].iter().all(|&x| x == 0.0));
        assert!(m[..8].iter().all(|&x| x == 1.0));
    }

    #[test]
    fn mask_token_counts() {
        let windows: Vec<Vec<i32>> = (0..3).map(|_| vec![1; 9]).collect();
        let batches = pack(&windows, 4, 8);
        let total_mask: f32 = batches.iter().flat_map(|b| b.mask.iter()).sum();
        assert_eq!(total_mask, 24.0); // 3 rows × 8 positions
    }

    #[test]
    fn train_batch_shape() {
        let mut rng = crate::util::Pcg64::seeded(1);
        let stream: Vec<i32> = (0..1000).map(|i| i % 96).collect();
        let b = train_batch(&stream, 4, 16, &mut rng);
        assert_eq!(b.len(), 4 * 17);
    }
}

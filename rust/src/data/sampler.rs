//! Calibration sampling (paper §4.1: random sequences from the train
//! shard; §4.4 varies the count and the sampling seed).

use crate::util::Pcg64;

use super::Corpus;

/// Sample `n` random windows of `len` tokens from the corpus train split.
pub fn calibration_windows(corpus: &Corpus, n: usize, len: usize, seed: u64) -> Vec<Vec<i32>> {
    let train = corpus.train_slice();
    assert!(train.len() > len, "corpus too small for window length {len}");
    let mut rng = Pcg64::new(seed, 23);
    (0..n)
        .map(|_| {
            let start = rng.below((train.len() - len) as u64) as usize;
            train[start..start + len].to_vec()
        })
        .collect()
}

/// Non-overlapping evaluation windows from the held-out split
/// (`len` includes the shifted target, i.e. seq_len + 1).
pub fn eval_windows(corpus: &Corpus, len: usize, max_windows: usize) -> Vec<Vec<i32>> {
    let held = corpus.heldout_slice();
    held.chunks_exact(len).take(max_windows).map(|c| c.to_vec()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CorpusCfg;

    fn corpus() -> Corpus {
        Corpus::generate(&CorpusCfg {
            name: "t".into(),
            seed: 7,
            word_vocab: 100,
            zipf_s: 1.0,
            noise: 0.0,
            sentence_len: (3, 6),
            chars: 50_000,
        })
    }

    #[test]
    fn calibration_shapes_and_determinism() {
        let c = corpus();
        let a = calibration_windows(&c, 8, 65, 42);
        let b = calibration_windows(&c, 8, 65, 42);
        assert_eq!(a, b);
        assert_eq!(a.len(), 8);
        assert!(a.iter().all(|w| w.len() == 65));
        let d = calibration_windows(&c, 8, 65, 43);
        assert_ne!(a, d, "different seed must sample differently");
    }

    #[test]
    fn eval_windows_nonoverlapping() {
        let c = corpus();
        let w = eval_windows(&c, 65, 1_000);
        assert!(w.len() > 10);
        assert!(w.iter().all(|x| x.len() == 65));
        // windows tile the held-out split
        let held = c.heldout_slice();
        assert_eq!(&held[..65], w[0].as_slice());
        assert_eq!(&held[65..130], w[1].as_slice());
    }

    #[test]
    fn calibration_only_from_train_split() {
        let c = corpus();
        let train = c.train_slice();
        for w in calibration_windows(&c, 16, 65, 1) {
            // every window must be a subslice of train
            assert!(train.windows(65).any(|t| t == w.as_slice()));
        }
    }
}

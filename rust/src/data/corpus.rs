//! Synthetic corpus generators — the WikiText/PTB/C4 analogs.
//!
//! Each corpus is produced by a seeded stochastic grammar:
//!   * a word vocabulary of pronounceable words (CV-alternating) with a
//!     Zipf(s) frequency law — like natural-language unigram statistics;
//!   * first-order word-level Markov structure: every word prefers a
//!     small successor set, so there are bigram regularities for the model
//!     to learn (perplexity gaps between pruning methods need a model that
//!     has learned *something* beyond letter frequencies);
//!   * sentence segmentation and optional character noise (the "c4-syn"
//!     web-crawl analog is noisier than the "ptb-syn" newswire analog).
//!
//! Token stream = char-level ids (see tokenizer.rs). Train split = first
//! 90%, held-out split = last 10% (perplexity windows never overlap the
//! calibration source).

use crate::config::CorpusCfg;
use crate::util::Pcg64;

use super::tokenizer;

/// A generated corpus: token ids plus the train/held-out boundary.
pub struct Corpus {
    pub name: String,
    pub tokens: Vec<i32>,
    pub train_end: usize,
}

impl Corpus {
    /// Generate deterministically from presets.
    pub fn generate(cfg: &CorpusCfg) -> Corpus {
        let mut rng = Pcg64::new(cfg.seed, 17);
        let vocab = WordVocab::build(&mut rng, cfg.word_vocab, cfg.zipf_s);
        let mut text = String::with_capacity(cfg.chars + 64);
        let mut prev_word: Option<usize> = None;
        while text.len() < cfg.chars {
            let len = cfg.sentence_len.0
                + rng.below((cfg.sentence_len.1 - cfg.sentence_len.0 + 1) as u64) as usize;
            for i in 0..len {
                let w = vocab.next_word(&mut rng, prev_word);
                prev_word = Some(w);
                if i > 0 {
                    text.push(' ');
                }
                text.push_str(&vocab.words[w]);
            }
            text.push_str(". ");
        }
        let mut tokens = tokenizer::encode(&text);
        // Character-level noise: random printable substitutions.
        if cfg.noise > 0.0 {
            let n = tokens.len();
            let flips = (n as f64 * cfg.noise) as usize;
            for _ in 0..flips {
                let i = rng.below(n as u64) as usize;
                tokens[i] = rng.below(tokenizer::VOCAB_SIZE as u64) as i32;
            }
        }
        let train_end = tokens.len() * 9 / 10;
        Corpus { name: cfg.name.clone(), tokens, train_end }
    }

    pub fn train_slice(&self) -> &[i32] {
        &self.tokens[..self.train_end]
    }

    pub fn heldout_slice(&self) -> &[i32] {
        &self.tokens[self.train_end..]
    }
}

/// Zipf-weighted word vocabulary with Markov successor structure.
struct WordVocab {
    words: Vec<String>,
    zipf: Vec<f64>,
    /// Per word: preferred successors (first-order structure).
    successors: Vec<Vec<usize>>,
}

const SUCCESSORS_PER_WORD: usize = 12;
/// Probability of following the Markov preference vs a fresh Zipf draw.
const MARKOV_P: f64 = 0.7;

impl WordVocab {
    fn build(rng: &mut Pcg64, n_words: usize, zipf_s: f64) -> WordVocab {
        let consonants = b"bcdfghjklmnpqrstvwz";
        let vowels = b"aeiou";
        let mut words = Vec::with_capacity(n_words);
        let mut seen = std::collections::BTreeSet::new();
        while words.len() < n_words {
            let syllables = 1 + rng.below(3) as usize;
            let mut w = String::new();
            for _ in 0..syllables {
                w.push(consonants[rng.below(consonants.len() as u64) as usize] as char);
                w.push(vowels[rng.below(vowels.len() as u64) as usize] as char);
                if rng.next_f64() < 0.3 {
                    w.push(consonants[rng.below(consonants.len() as u64) as usize] as char);
                }
            }
            if seen.insert(w.clone()) {
                words.push(w);
            }
        }
        let zipf: Vec<f64> = (0..n_words).map(|r| 1.0 / ((r + 1) as f64).powf(zipf_s)).collect();
        let successors = (0..n_words)
            .map(|_| (0..SUCCESSORS_PER_WORD).map(|_| rng.below(n_words as u64) as usize).collect())
            .collect();
        WordVocab { words, zipf, successors }
    }

    fn next_word(&self, rng: &mut Pcg64, prev: Option<usize>) -> usize {
        if let Some(p) = prev {
            if rng.next_f64() < MARKOV_P {
                let succ = &self.successors[p];
                return succ[rng.below(succ.len() as u64) as usize];
            }
        }
        rng.sample_weighted(&self.zipf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_cfg(seed: u64, noise: f64) -> CorpusCfg {
        CorpusCfg {
            name: "test".into(),
            seed,
            word_vocab: 200,
            zipf_s: 1.05,
            noise,
            sentence_len: (3, 8),
            chars: 20_000,
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Corpus::generate(&test_cfg(1, 0.0));
        let b = Corpus::generate(&test_cfg(1, 0.0));
        assert_eq!(a.tokens, b.tokens);
        let c = Corpus::generate(&test_cfg(2, 0.0));
        assert_ne!(a.tokens, c.tokens);
    }

    #[test]
    fn split_boundaries() {
        let c = Corpus::generate(&test_cfg(3, 0.0));
        assert!(c.tokens.len() >= 20_000);
        assert_eq!(c.train_slice().len() + c.heldout_slice().len(), c.tokens.len());
        assert!(c.train_slice().len() > 8 * c.heldout_slice().len());
    }

    #[test]
    fn tokens_in_vocab() {
        let c = Corpus::generate(&test_cfg(4, 0.05));
        for &t in &c.tokens {
            assert!((0..tokenizer::VOCAB_SIZE as i32).contains(&t));
        }
    }

    #[test]
    fn has_word_structure() {
        // Spaces and periods must appear with reasonable frequency.
        let c = Corpus::generate(&test_cfg(5, 0.0));
        let space = tokenizer::encode(" ")[0];
        let spaces = c.tokens.iter().filter(|&&t| t == space).count();
        assert!(spaces * 12 > c.tokens.len(), "too few spaces");
    }

    #[test]
    fn markov_structure_is_learnable() {
        // Bigram entropy over words should be clearly below unigram entropy:
        // the successor preference makes some transitions much likelier.
        let c = Corpus::generate(&test_cfg(6, 0.0));
        let text = tokenizer::decode(&c.tokens);
        let words: Vec<&str> = text.split_whitespace().take(2000).collect();
        let mut uni = std::collections::HashMap::new();
        let mut bi = std::collections::HashMap::new();
        for w in words.windows(2) {
            *uni.entry(w[0]).or_insert(0usize) += 1;
            *bi.entry((w[0], w[1])).or_insert(0usize) += 1;
        }
        // The top-12 successors of the most frequent word should carry most
        // of its transition mass (MARKOV_P = 0.7 over 12 successors), far
        // more than the unigram-independence baseline would give 12 words.
        let (&w1, &c1) = uni.iter().max_by_key(|(_, &c)| c).unwrap();
        let mut succ: Vec<usize> =
            bi.iter().filter(|((a, _), _)| *a == w1).map(|(_, &c)| c).collect();
        succ.sort_unstable_by(|a, b| b.cmp(a));
        let top12: usize = succ.iter().take(12).sum();
        assert!(
            top12 * 2 > c1,
            "top-12 successors carry {top12}/{c1} transitions — no Markov structure"
        );
    }
}

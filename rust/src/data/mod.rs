//! Data substrate: synthetic corpora (WikiText/PTB/C4 analogs), the
//! char-level tokenizer, calibration sampling and evaluation batching.
//!
//! The paper calibrates on 128 sequences from the first shard of C4 and
//! evaluates perplexity on WikiText-2/PTB/C4. Those datasets are not
//! available offline, so `corpus` generates three *distinct, learnable*
//! text distributions (Zipfian word vocabularies + first-order word Markov
//! structure + per-corpus noise) — see DESIGN.md §2 for why this preserves
//! the behaviour the experiments measure.

pub mod batches;
pub mod corpus;
pub mod sampler;
pub mod tokenizer;

pub use corpus::Corpus;
pub use tokenizer::{decode, encode, VOCAB_SIZE};

//! Observability: deterministic, zero-overhead-when-off tracing for the
//! prune and serve stacks.
//!
//! Three pieces (docs/ARCHITECTURE.md §Observability):
//!
//! * [`clock`] — the injectable [`Clock`] trait behind every timestamp
//!   and every `latency_ms`: [`MonotonicClock`] in production, a
//!   [`FakeClock`] in tests so timelines (and therefore served bytes,
//!   including latency fields) are bit-reproducible.
//! * [`event`] + [`recorder`] — typed span/point/gauge events pushed
//!   through a bounded never-blocking channel onto a JSONL writer
//!   thread (`--trace-out`). Overflow drops and counts
//!   (`dropped_events`); it never stalls a hot path. With no recorder
//!   installed the instrumentation sites cost nothing.
//! * [`trace`] — the offline side: load a capture, fold it into
//!   per-request waterfalls, per-phase breakdowns, and per-operator
//!   FISTA convergence tables (the `trace` CLI subcommand).
//!
//! The serve determinism contract survives tracing by construction:
//! instrumentation only *observes* engine state — it never gates
//! admission, scheduling, or sampling — which
//! `rust/tests/trace_parity.rs` pins bit-for-bit.

pub mod clock;
pub mod event;
pub mod recorder;
pub mod trace;

pub use clock::{Clock, FakeClock, MonotonicClock, SharedClock};
pub use event::{Event, Phase};
pub use recorder::{Recorder, TraceStats, TraceWriter};

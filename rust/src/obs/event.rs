//! The typed trace-event schema.
//!
//! One event per JSONL line. Reserved keys: `ph` (phase), `name`, `id`,
//! `t_ms`; everything else on the line is a free-form attribute. Span
//! `Begin`/`End` pairs share a `(name, id)` key; `Point` marks an
//! instant; `Gauge` samples a level (queue depth, KV pages). Attribute
//! keys must avoid the reserved names — [`Event::to_json`] asserts this
//! in debug builds.

use std::collections::BTreeMap;

use crate::ser::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    Begin,
    End,
    Point,
    Gauge,
}

impl Phase {
    pub fn label(&self) -> &'static str {
        match self {
            Phase::Begin => "B",
            Phase::End => "E",
            Phase::Point => "P",
            Phase::Gauge => "G",
        }
    }

    pub fn parse(s: &str) -> Option<Phase> {
        match s {
            "B" => Some(Phase::Begin),
            "E" => Some(Phase::End),
            "P" => Some(Phase::Point),
            "G" => Some(Phase::Gauge),
            _ => None,
        }
    }
}

/// One structured trace event, stamped by the emitting [`super::Recorder`].
#[derive(Clone, Debug)]
pub struct Event {
    pub phase: Phase,
    /// Span/event name from the fixed taxonomy (docs/ARCHITECTURE.md
    /// §Observability): "request", "conn", "queued", "prefill_chunk",
    /// "engine_step", "fista_round", ...
    pub name: &'static str,
    /// Correlation id: request id, `c{conn}`, `L{layer}:{op}`; empty for
    /// process-wide events.
    pub id: String,
    /// Clock timestamp, milliseconds since the recorder's clock epoch.
    pub t_ms: f64,
    pub attrs: Vec<(&'static str, Json)>,
}

const RESERVED: [&str; 4] = ["ph", "name", "id", "t_ms"];

impl Event {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("ph".to_string(), Json::Str(self.phase.label().to_string()));
        m.insert("name".to_string(), Json::Str(self.name.to_string()));
        if !self.id.is_empty() {
            m.insert("id".to_string(), Json::Str(self.id.clone()));
        }
        // 1µs granularity keeps lines short and exceeds clock precision
        m.insert("t_ms".to_string(), Json::Num((self.t_ms * 1e3).round() / 1e3));
        for (k, v) in &self.attrs {
            debug_assert!(!RESERVED.contains(k), "attr key '{k}' shadows a reserved field");
            m.insert((*k).to_string(), v.clone());
        }
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serializes_with_stable_keys() {
        let ev = Event {
            phase: Phase::Begin,
            name: "request",
            id: "r1".to_string(),
            t_ms: 1.23456,
            attrs: vec![("slot", Json::Num(2.0)), ("pages", Json::Num(3.0))],
        };
        assert_eq!(
            ev.to_json().to_string_compact(),
            "{\"id\":\"r1\",\"name\":\"request\",\"pages\":3,\"ph\":\"B\",\"slot\":2,\"t_ms\":1.235}"
        );
    }

    #[test]
    fn empty_id_is_omitted() {
        let ev = Event {
            phase: Phase::Gauge,
            name: "engine_step",
            id: String::new(),
            t_ms: 0.0,
            attrs: vec![],
        };
        let j = ev.to_json();
        assert!(j.get("id").is_none());
        assert_eq!(j.get("ph").and_then(|v| v.as_str()), Some("G"));
    }

    #[test]
    fn phase_labels_round_trip() {
        for ph in [Phase::Begin, Phase::End, Phase::Point, Phase::Gauge] {
            assert_eq!(Phase::parse(ph.label()), Some(ph));
        }
        assert_eq!(Phase::parse("X"), None);
    }
}

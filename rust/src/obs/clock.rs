//! Injectable time source for every serving/tracing timestamp.
//!
//! All latency accounting and trace timestamps route through [`Clock`]
//! instead of calling `std::time::Instant` at the use site. Production
//! code runs on [`MonotonicClock`]; tests inject a [`FakeClock`] to pin
//! the timeline, which makes `latency_ms` — historically the one
//! wall-clock field the event-log replay had to canonicalize away —
//! bit-reproducible (see `serve::net::replay`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Monotonic milliseconds since the clock's own epoch. Implementations
/// must be non-decreasing and cheap: `now_ms` sits on the serve hot
/// path (one read per request submit/retire and per engine step).
pub trait Clock: Send + Sync {
    fn now_ms(&self) -> f64;
}

/// The production clock: `Instant`-backed, epoch = construction time.
pub struct MonotonicClock {
    epoch: Instant,
}

impl MonotonicClock {
    // the one sanctioned monotonic read: everything else derives its
    // time from this clock through the Clock trait
    #[allow(clippy::disallowed_methods)]
    pub fn new() -> Self {
        MonotonicClock { epoch: Instant::now() }
    }
}

impl Default for MonotonicClock {
    fn default() -> Self {
        MonotonicClock::new()
    }
}

impl Clock for MonotonicClock {
    fn now_ms(&self) -> f64 {
        self.epoch.elapsed().as_secs_f64() * 1e3
    }
}

/// Test clock: time stands still until a test advances it, in integer
/// microseconds so repeated reads are exact.
#[derive(Default)]
pub struct FakeClock {
    micros: AtomicU64,
}

impl FakeClock {
    pub fn new() -> Self {
        FakeClock::default()
    }

    pub fn advance_ms(&self, ms: f64) {
        self.micros.fetch_add((ms * 1e3).round() as u64, Ordering::SeqCst);
    }

    pub fn set_ms(&self, ms: f64) {
        self.micros.store((ms * 1e3).round() as u64, Ordering::SeqCst);
    }
}

impl Clock for FakeClock {
    fn now_ms(&self) -> f64 {
        self.micros.load(Ordering::SeqCst) as f64 / 1e3
    }
}

/// Cloneable clock handle — what configs carry. `Default` is the real
/// monotonic clock, so `..Config::default()` call sites keep today's
/// behavior.
#[derive(Clone)]
pub struct SharedClock(Arc<dyn Clock>);

impl SharedClock {
    pub fn new(clock: Arc<dyn Clock>) -> Self {
        SharedClock(clock)
    }

    pub fn monotonic() -> Self {
        SharedClock(Arc::new(MonotonicClock::new()))
    }

    /// A fake clock plus the handle tests use to advance it.
    pub fn fake() -> (Self, Arc<FakeClock>) {
        let f = Arc::new(FakeClock::new());
        (SharedClock(f.clone()), f)
    }

    pub fn now_ms(&self) -> f64 {
        self.0.now_ms()
    }
}

impl Default for SharedClock {
    fn default() -> Self {
        SharedClock::monotonic()
    }
}

impl std::fmt::Debug for SharedClock {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("SharedClock")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn monotonic_clock_never_goes_back() {
        let c = MonotonicClock::new();
        let a = c.now_ms();
        let b = c.now_ms();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn fake_clock_moves_only_when_told() {
        let (clock, fake) = SharedClock::fake();
        assert_eq!(clock.now_ms(), 0.0);
        assert_eq!(clock.now_ms(), 0.0, "reads do not advance time");
        fake.advance_ms(2.5);
        assert_eq!(clock.now_ms(), 2.5);
        fake.set_ms(1.0);
        assert_eq!(clock.now_ms(), 1.0);
        let other = clock.clone();
        fake.advance_ms(1.0);
        assert_eq!(other.now_ms(), 2.0, "clones share the timeline");
    }
}

//! Offline trace analysis: load a `--trace-out` JSONL capture and fold
//! it into the tables the `trace` CLI prints — per-request waterfalls,
//! per-phase time breakdowns, and per-(layer, op) solver convergence.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::ser::json::Json;

use super::event::Phase;

/// One parsed trace line (the read-side mirror of [`super::Event`],
/// with owned names and free-form attributes).
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub phase: Phase,
    pub name: String,
    pub id: String,
    pub t_ms: f64,
    pub attrs: BTreeMap<String, Json>,
}

impl TraceEvent {
    pub fn num(&self, key: &str) -> Option<f64> {
        self.attrs.get(key).and_then(|v| v.as_f64())
    }

    pub fn str_attr(&self, key: &str) -> Option<&str> {
        self.attrs.get(key).and_then(|v| v.as_str())
    }
}

/// Parse a JSONL trace capture. Unparseable lines fail loudly — a trace
/// is machine-written, so corruption means a real bug.
pub fn load_trace(path: &Path) -> Result<Vec<TraceEvent>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading trace {}", path.display()))?;
    let mut events = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let v = Json::parse(line).with_context(|| format!("trace line {}", i + 1))?;
        let phase = v
            .get("ph")
            .and_then(|p| p.as_str())
            .and_then(Phase::parse)
            .with_context(|| format!("trace line {}: bad or missing ph", i + 1))?;
        let name = v
            .get("name")
            .and_then(|n| n.as_str())
            .with_context(|| format!("trace line {}: missing name", i + 1))?
            .to_string();
        let id = v.get("id").and_then(|s| s.as_str()).unwrap_or("").to_string();
        let t_ms = v.get("t_ms").and_then(|t| t.as_f64()).unwrap_or(0.0);
        let mut attrs = BTreeMap::new();
        if let Json::Obj(m) = &v {
            for (k, val) in m {
                if !matches!(k.as_str(), "ph" | "name" | "id" | "t_ms") {
                    attrs.insert(k.clone(), val.clone());
                }
            }
        }
        events.push(TraceEvent { phase, name, id, t_ms, attrs });
    }
    Ok(events)
}

/// One serve request reconstructed from its lifecycle events.
#[derive(Clone, Debug)]
pub struct RequestRow {
    pub id: String,
    /// submit (`queued` point) → admit (`request` Begin).
    pub queued_ms: f64,
    /// admit → retire (`request` End).
    pub service_ms: f64,
    pub total_ms: f64,
    pub prefill_chunks: usize,
    pub completion_tokens: usize,
    pub finish: String,
}

/// Fold lifecycle events into per-request waterfall rows (sorted by id).
/// Requests missing their admit or retire event (still in flight when
/// the trace closed) are skipped.
pub fn request_waterfalls(events: &[TraceEvent]) -> Vec<RequestRow> {
    #[derive(Default)]
    struct Acc {
        queued: Option<f64>,
        begin: Option<f64>,
        end: Option<f64>,
        chunks: usize,
        tokens: usize,
        finish: String,
    }
    let mut acc: BTreeMap<String, Acc> = BTreeMap::new();
    for ev in events {
        if ev.id.is_empty() {
            continue;
        }
        let a = acc.entry(ev.id.clone()).or_default();
        match (ev.name.as_str(), ev.phase) {
            ("queued", Phase::Point) => a.queued = Some(ev.t_ms),
            ("request", Phase::Begin) => a.begin = Some(ev.t_ms),
            ("prefill_chunk", Phase::Point) => a.chunks += 1,
            ("request", Phase::End) => {
                a.end = Some(ev.t_ms);
                a.tokens = ev.num("completion_tokens").unwrap_or(0.0) as usize;
                a.finish = ev.str_attr("finish").unwrap_or("?").to_string();
            }
            _ => {}
        }
    }
    acc.into_iter()
        .filter_map(|(id, a)| {
            let (begin, end) = (a.begin?, a.end?);
            let queued = a.queued.unwrap_or(begin);
            Some(RequestRow {
                id,
                queued_ms: begin - queued,
                service_ms: end - begin,
                total_ms: end - queued,
                prefill_chunks: a.chunks,
                completion_tokens: a.tokens,
                finish: a.finish,
            })
        })
        .collect()
}

/// Aggregate per span/event name: how many, and (for Begin/End pairs)
/// how much total time.
#[derive(Clone, Debug)]
pub struct PhaseRow {
    pub name: String,
    pub count: usize,
    pub total_ms: f64,
}

/// Per-phase breakdown: Begin/End pairs matched per `(name, id)` (LIFO
/// for nesting); points and gauges count with zero duration.
pub fn phase_breakdown(events: &[TraceEvent]) -> Vec<PhaseRow> {
    let mut open: BTreeMap<(String, String), Vec<f64>> = BTreeMap::new();
    let mut rows: BTreeMap<String, (usize, f64)> = BTreeMap::new();
    for ev in events {
        match ev.phase {
            Phase::Begin => {
                open.entry((ev.name.clone(), ev.id.clone())).or_default().push(ev.t_ms);
            }
            Phase::End => {
                let started =
                    open.get_mut(&(ev.name.clone(), ev.id.clone())).and_then(|v| v.pop());
                let r = rows.entry(ev.name.clone()).or_default();
                r.0 += 1;
                if let Some(t0) = started {
                    r.1 += (ev.t_ms - t0).max(0.0);
                }
            }
            Phase::Point | Phase::Gauge => {
                rows.entry(ev.name.clone()).or_default().0 += 1;
            }
        }
    }
    rows.into_iter().map(|(name, (count, total_ms))| PhaseRow { name, count, total_ms }).collect()
}

/// Final convergence state of one pruned operator, folded from its
/// `solver_round` points.
#[derive(Clone, Debug)]
pub struct ConvRow {
    /// `L{layer}:{op}`.
    pub id: String,
    /// Layer-solver label ("fista"/"admm"/"fw"; traces written before the
    /// solver axis existed carry `fista_round` events without a solver
    /// attribute and default to "fista").
    pub solver: String,
    pub rounds: usize,
    /// Total inner solver iterations across rounds.
    pub iters: usize,
    /// Final round's λ / objective / primal residual / support size.
    pub lambda: f64,
    pub objective: f64,
    pub residual: f64,
    pub support: usize,
}

/// Per-operator convergence table from `solver_round` events (the legacy
/// `fista_round` name is accepted for old captures), sorted by operator id.
pub fn convergence_rows(events: &[TraceEvent]) -> Vec<ConvRow> {
    let mut rows: BTreeMap<String, ConvRow> = BTreeMap::new();
    for ev in events {
        if !matches!(ev.name.as_str(), "solver_round" | "fista_round")
            || ev.phase != Phase::Point
        {
            continue;
        }
        let r = rows.entry(ev.id.clone()).or_insert_with(|| ConvRow {
            id: ev.id.clone(),
            solver: String::new(),
            rounds: 0,
            iters: 0,
            lambda: 0.0,
            objective: 0.0,
            residual: 0.0,
            support: 0,
        });
        r.solver = ev.str_attr("solver").unwrap_or("fista").to_string();
        r.rounds += 1;
        r.iters += ev.num("iters").unwrap_or(0.0) as usize;
        r.lambda = ev.num("lambda").unwrap_or(r.lambda);
        r.objective = ev.num("objective").unwrap_or(r.objective);
        r.residual = ev.num("residual").unwrap_or(r.residual);
        r.support = ev.num("support").unwrap_or(r.support as f64) as usize;
    }
    rows.into_values().collect()
}

/// Per-solver rollup over convergence rows: (solver label, operator
/// count, total inner iterations), sorted by label.
pub fn solver_totals(rows: &[ConvRow]) -> Vec<(String, usize, usize)> {
    let mut acc: BTreeMap<String, (usize, usize)> = BTreeMap::new();
    for r in rows {
        let e = acc.entry(r.solver.clone()).or_default();
        e.0 += 1;
        e.1 += r.iters;
    }
    acc.into_iter().map(|(solver, (ops, iters))| (solver, ops, iters)).collect()
}

/// (written, dropped) from the `trace_end` summary line, if present.
pub fn trace_end_counts(events: &[TraceEvent]) -> Option<(u64, u64)> {
    events.iter().rev().find(|e| e.name == "trace_end").map(|e| {
        (e.num("written").unwrap_or(0.0) as u64, e.num("dropped").unwrap_or(0.0) as u64)
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(ph: Phase, name: &str, id: &str, t: f64, attrs: &[(&str, f64)]) -> TraceEvent {
        TraceEvent {
            phase: ph,
            name: name.to_string(),
            id: id.to_string(),
            t_ms: t,
            attrs: attrs.iter().map(|(k, v)| (k.to_string(), Json::Num(*v))).collect(),
        }
    }

    #[test]
    fn waterfall_reconstructs_queue_and_service_time() {
        let mut events = vec![
            ev(Phase::Point, "queued", "a", 1.0, &[]),
            ev(Phase::Begin, "request", "a", 3.0, &[]),
            ev(Phase::Point, "prefill_chunk", "a", 3.5, &[]),
            ev(Phase::Point, "prefill_chunk", "a", 4.0, &[]),
            ev(Phase::End, "request", "a", 9.0, &[("completion_tokens", 6.0)]),
            // still in flight: no End — must be skipped, not crash
            ev(Phase::Begin, "request", "b", 5.0, &[]),
        ];
        events[4].attrs.insert("finish".to_string(), Json::Str("length".to_string()));
        let rows = request_waterfalls(&events);
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!(r.id, "a");
        assert_eq!(r.queued_ms, 2.0);
        assert_eq!(r.service_ms, 6.0);
        assert_eq!(r.total_ms, 8.0);
        assert_eq!(r.prefill_chunks, 2);
        assert_eq!(r.completion_tokens, 6);
        assert_eq!(r.finish, "length");
    }

    #[test]
    fn phase_breakdown_pairs_spans_and_counts_points() {
        let events = vec![
            ev(Phase::Begin, "conn", "c1", 0.0, &[]),
            ev(Phase::Begin, "conn", "c2", 1.0, &[]),
            ev(Phase::Gauge, "engine_step", "", 1.5, &[]),
            ev(Phase::End, "conn", "c1", 4.0, &[]),
            ev(Phase::End, "conn", "c2", 5.0, &[]),
        ];
        let rows = phase_breakdown(&events);
        let conn = rows.iter().find(|r| r.name == "conn").unwrap();
        assert_eq!(conn.count, 2);
        assert_eq!(conn.total_ms, 8.0);
        let step = rows.iter().find(|r| r.name == "engine_step").unwrap();
        assert_eq!(step.count, 1);
        assert_eq!(step.total_ms, 0.0);
    }

    #[test]
    fn convergence_keeps_last_round_and_sums_iters() {
        let mut events = vec![
            ev(
                Phase::Point,
                "solver_round",
                "L0:wq",
                0.0,
                &[
                    ("round", 1.0),
                    ("lambda", 1e-5),
                    ("objective", 2.0),
                    ("iters", 20.0),
                    ("support", 64.0),
                    ("residual", 0.5),
                ],
            ),
            ev(
                Phase::Point,
                "solver_round",
                "L0:wq",
                1.0,
                &[
                    ("round", 2.0),
                    ("lambda", 3e-3),
                    ("objective", 1.5),
                    ("iters", 12.0),
                    ("support", 60.0),
                    ("residual", 0.2),
                ],
            ),
        ];
        for e in &mut events {
            e.attrs.insert("solver".to_string(), Json::Str("admm".to_string()));
        }
        let rows = convergence_rows(&events);
        assert_eq!(rows.len(), 1);
        let r = &rows[0];
        assert_eq!(r.solver, "admm");
        assert_eq!(r.rounds, 2);
        assert_eq!(r.iters, 32);
        assert_eq!(r.lambda, 3e-3);
        assert_eq!(r.objective, 1.5);
        assert_eq!(r.residual, 0.2);
        assert_eq!(r.support, 60);
    }

    #[test]
    fn legacy_fista_round_events_still_fold_and_default_solver() {
        let events = vec![ev(
            Phase::Point,
            "fista_round",
            "L0:wq",
            0.0,
            &[("round", 1.0), ("iters", 7.0)],
        )];
        let rows = convergence_rows(&events);
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].solver, "fista");
        assert_eq!(rows[0].iters, 7);
    }

    #[test]
    fn solver_totals_groups_by_label() {
        let mk = |id: &str, solver: &str, iters: f64| {
            let mut e = ev(Phase::Point, "solver_round", id, 0.0, &[("iters", iters)]);
            e.attrs.insert("solver".to_string(), Json::Str(solver.to_string()));
            e
        };
        let events = vec![
            mk("L0:wq", "fista", 10.0),
            mk("L0:wk", "fista", 5.0),
            mk("L1:wq", "admm", 30.0),
        ];
        let rows = convergence_rows(&events);
        let totals = solver_totals(&rows);
        assert_eq!(totals, vec![("admm".to_string(), 1, 30), ("fista".to_string(), 2, 15)]);
    }
}

//! The trace recorder: a bounded, never-blocking event channel feeding
//! a JSONL writer thread.
//!
//! Hot paths call [`Recorder::point`]/[`begin`](Recorder::begin)/... —
//! each is one clock read plus one `try_send`. The channel is bounded;
//! when the writer falls behind, events are *dropped and counted*
//! (`dropped_events`), never queued unboundedly and never awaited, so
//! tracing can never stall the serve loop or perturb scheduling. When no
//! recorder is installed (`Option<Recorder>` = `None` everywhere), the
//! instrumentation sites skip even the clock read and the id clone —
//! the overhead-when-off guarantee documented in ARCHITECTURE.md.

use std::fs::{File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::ser::json::Json;

use super::clock::SharedClock;
use super::event::{Event, Phase};

/// Default event-channel capacity. Sized so a bursty engine step never
/// hits it unless the disk genuinely cannot keep up.
const CHANNEL_CAP: usize = 65_536;

/// Cloneable emit handle. Cheap to clone (two `Arc`s + a channel
/// sender); every instrumented subsystem holds its own clone.
#[derive(Clone)]
pub struct Recorder {
    tx: SyncSender<Event>,
    dropped: Arc<AtomicU64>,
    clock: SharedClock,
}

impl std::fmt::Debug for Recorder {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Recorder(dropped={})", self.dropped.load(Ordering::Relaxed))
    }
}

/// Final accounting from [`TraceWriter::finish`].
#[derive(Clone, Copy, Debug)]
pub struct TraceStats {
    /// Events written to the file (excluding the trailing summary line).
    pub written: u64,
    /// Events dropped because the bounded channel was full.
    pub dropped: u64,
}

/// Owns the writer thread. Call [`finish`](TraceWriter::finish) after
/// the traced workload completes: it drains everything already emitted,
/// appends a `trace_end` summary line, and returns the final counts.
pub struct TraceWriter {
    handle: JoinHandle<u64>,
    stop: Arc<AtomicBool>,
    dropped: Arc<AtomicU64>,
    path: PathBuf,
}

impl Recorder {
    /// JSONL recorder writing to `path` (parent dirs created). Returns
    /// the emit handle and the writer to `finish` afterwards.
    pub fn to_file(path: &Path, clock: SharedClock) -> Result<(Recorder, TraceWriter)> {
        Recorder::to_file_with_cap(path, clock, CHANNEL_CAP)
    }

    /// [`to_file`](Recorder::to_file) with an explicit channel bound
    /// (tests shrink it to exercise the drop path).
    pub fn to_file_with_cap(
        path: &Path,
        clock: SharedClock,
        cap: usize,
    ) -> Result<(Recorder, TraceWriter)> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {}", parent.display()))?;
            }
        }
        let file = File::create(path).with_context(|| format!("creating {}", path.display()))?;
        let (tx, rx) = sync_channel(cap.max(1));
        let stop = Arc::new(AtomicBool::new(false));
        let dropped = Arc::new(AtomicU64::new(0));
        let handle = {
            let stop = stop.clone();
            std::thread::spawn(move || writer_loop(file, rx, stop))
        };
        let rec = Recorder { tx, dropped: dropped.clone(), clock };
        let writer = TraceWriter { handle, stop, dropped, path: path.to_path_buf() };
        Ok((rec, writer))
    }

    /// The recorder's timestamp source (shared with the instrumented
    /// engine so spans and latency accounting agree).
    pub fn now_ms(&self) -> f64 {
        self.clock.now_ms()
    }

    pub fn dropped_events(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    pub fn begin(&self, name: &'static str, id: &str, attrs: Vec<(&'static str, Json)>) {
        self.emit(Phase::Begin, name, id, attrs);
    }

    pub fn end(&self, name: &'static str, id: &str, attrs: Vec<(&'static str, Json)>) {
        self.emit(Phase::End, name, id, attrs);
    }

    pub fn point(&self, name: &'static str, id: &str, attrs: Vec<(&'static str, Json)>) {
        self.emit(Phase::Point, name, id, attrs);
    }

    pub fn gauge(&self, name: &'static str, id: &str, attrs: Vec<(&'static str, Json)>) {
        self.emit(Phase::Gauge, name, id, attrs);
    }

    fn emit(&self, phase: Phase, name: &'static str, id: &str, attrs: Vec<(&'static str, Json)>) {
        let ev = Event { phase, name, id: id.to_string(), t_ms: self.clock.now_ms(), attrs };
        match self.tx.try_send(ev) {
            Ok(()) => {}
            // full or writer gone: count and move on, never block
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

fn writer_loop(file: File, rx: Receiver<Event>, stop: Arc<AtomicBool>) -> u64 {
    let mut out = BufWriter::new(file);
    let mut written = 0u64;
    let mut write = |out: &mut BufWriter<File>, ev: Event| {
        if writeln!(out, "{}", ev.to_json().to_string_compact()).is_ok() {
            written += 1;
        }
    };
    loop {
        match rx.recv_timeout(Duration::from_millis(25)) {
            Ok(ev) => write(&mut out, ev),
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break,
        }
    }
    // drain whatever raced in before the stop flag was observed
    while let Ok(ev) = rx.try_recv() {
        write(&mut out, ev);
    }
    let _ = out.flush();
    written
}

impl TraceWriter {
    /// Drain and join the writer, then append the `trace_end` summary
    /// line (`written` / `dropped`) the `trace` CLI and CI gate read.
    /// Events emitted after this call are dropped (and counted on the
    /// recorder, but no longer reflected in the file).
    pub fn finish(self) -> Result<TraceStats> {
        self.stop.store(true, Ordering::Relaxed);
        let written = match self.handle.join() {
            Ok(n) => n,
            Err(_) => anyhow::bail!("trace writer thread panicked"),
        };
        let dropped = self.dropped.load(Ordering::Relaxed);
        let mut tail = std::collections::BTreeMap::new();
        tail.insert("ph".to_string(), Json::Str("P".to_string()));
        tail.insert("name".to_string(), Json::Str("trace_end".to_string()));
        tail.insert("written".to_string(), Json::Num(written as f64));
        tail.insert("dropped".to_string(), Json::Num(dropped as f64));
        let mut f = OpenOptions::new()
            .append(true)
            .open(&self.path)
            .with_context(|| format!("reopening {}", self.path.display()))?;
        writeln!(f, "{}", Json::Obj(tail).to_string_compact())?;
        Ok(TraceStats { written, dropped })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("fp_obs_{}_{name}.jsonl", std::process::id()))
    }

    #[test]
    fn events_land_in_order_with_summary_line() {
        let path = tmp("order");
        let (clock, fake) = SharedClock::fake();
        let (rec, writer) = Recorder::to_file(&path, clock).unwrap();
        rec.begin("request", "r0", vec![("slot", Json::Num(0.0))]);
        fake.advance_ms(3.0);
        rec.point("prefill_chunk", "r0", vec![("tokens", Json::Num(4.0))]);
        fake.advance_ms(1.0);
        rec.end("request", "r0", vec![]);
        let stats = writer.finish().unwrap();
        assert_eq!(stats.written, 3);
        assert_eq!(stats.dropped, 0);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 4, "3 events + trace_end: {text}");
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("ph").and_then(|v| v.as_str()), Some("B"));
        assert_eq!(first.get("t_ms").and_then(|v| v.as_f64()), Some(0.0));
        let second = Json::parse(lines[1]).unwrap();
        assert_eq!(second.get("t_ms").and_then(|v| v.as_f64()), Some(3.0));
        let tail = Json::parse(lines[3]).unwrap();
        assert_eq!(tail.get("name").and_then(|v| v.as_str()), Some("trace_end"));
        assert_eq!(tail.get("written").and_then(|v| v.as_f64()), Some(3.0));
        assert_eq!(tail.get("dropped").and_then(|v| v.as_f64()), Some(0.0));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn overflow_drops_and_counts_instead_of_blocking() {
        let path = tmp("overflow");
        let (clock, _fake) = SharedClock::fake();
        // cap 1 and a writer that cannot drain faster than we emit: some
        // events must drop, none may block, and the books must balance
        let (rec, writer) = Recorder::to_file_with_cap(&path, clock, 1).unwrap();
        const N: u64 = 500;
        for i in 0..N {
            rec.point("spin", "x", vec![("i", Json::Num(i as f64))]);
        }
        let dropped_live = rec.dropped_events();
        drop(rec);
        let stats = writer.finish().unwrap();
        assert_eq!(stats.written + stats.dropped, N, "every event is written or counted");
        assert!(stats.dropped >= dropped_live);
        std::fs::remove_file(&path).ok();
    }
}

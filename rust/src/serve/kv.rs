//! Per-request KV state: one fixed-capacity block per decode slot, handed
//! out by a pool so serving never allocates on the request path.
//!
//! Layout: a [`KvBlock`] stacks one [`KvLayer`] (see `model::forward`) per
//! decoder layer, each sized for the model's full context (`spec.seq`
//! positions × `spec.d` floats for K and again for V). The [`KvPool`]
//! preallocates `slots` such blocks up front; admission takes a block,
//! retirement clears and returns it. A cleared block keeps its buffers, so
//! steady-state serving is allocation-free apart from per-step activation
//! tensors.

use crate::config::ModelSpec;
use crate::model::forward::KvLayer;

/// The KV state of one in-flight request: a cache per decoder layer.
pub struct KvBlock {
    layers: Vec<KvLayer>,
}

impl KvBlock {
    /// Empty block sized for the model's full context.
    pub fn new(spec: &ModelSpec) -> KvBlock {
        KvBlock { layers: (0..spec.layers).map(|_| KvLayer::new(spec.seq, spec.d)).collect() }
    }

    /// Cache of decoder layer `li`.
    pub fn layer(&self, li: usize) -> &KvLayer {
        &self.layers[li]
    }

    /// Mutable cache of decoder layer `li`.
    pub fn layer_mut(&mut self, li: usize) -> &mut KvLayer {
        &mut self.layers[li]
    }

    /// Cached positions (identical across layers by construction).
    pub fn len(&self) -> usize {
        self.layers.first().map(|l| l.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Forget all cached positions; buffers are retained for reuse.
    pub fn clear(&mut self) {
        for l in &mut self.layers {
            l.clear();
        }
    }

    /// Heap bytes held by this block's K/V buffers.
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|l| l.bytes()).sum()
    }
}

/// Fixed pool of KV blocks, one per concurrent decode slot.
pub struct KvPool {
    blocks: Vec<KvBlock>,
    free: Vec<usize>,
}

impl KvPool {
    /// Preallocate `slots` blocks for `spec`.
    pub fn new(spec: &ModelSpec, slots: usize) -> KvPool {
        KvPool {
            blocks: (0..slots).map(|_| KvBlock::new(spec)).collect(),
            // reversed so alloc() hands out ids 0, 1, 2, … initially
            free: (0..slots).rev().collect(),
        }
    }

    /// Take a cleared block; `None` when every slot is in flight.
    pub fn alloc(&mut self) -> Option<usize> {
        let id = self.free.pop()?;
        self.blocks[id].clear();
        Some(id)
    }

    /// Return a block to the pool (retire-on-EOS / abort path).
    pub fn free(&mut self, id: usize) {
        debug_assert!(!self.free.contains(&id), "double free of KV block {id}");
        self.blocks[id].clear();
        self.free.push(id);
    }

    /// Blocks currently available for admission.
    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    /// Total slots.
    pub fn capacity(&self) -> usize {
        self.blocks.len()
    }

    pub fn block(&self, id: usize) -> &KvBlock {
        &self.blocks[id]
    }

    pub fn block_mut(&mut self, id: usize) -> &mut KvBlock {
        &mut self.blocks[id]
    }

    /// Mutable references to several distinct blocks at once (the batched
    /// decode step needs every active slot's cache simultaneously).
    /// Returned in the order of `ids`; panics on out-of-range or duplicate
    /// ids — both are scheduler bugs.
    pub fn blocks_mut(&mut self, ids: &[usize]) -> Vec<&mut KvBlock> {
        let mut picked: Vec<Option<&mut KvBlock>> = ids.iter().map(|_| None).collect();
        for (i, b) in self.blocks.iter_mut().enumerate() {
            if let Some(p) = ids.iter().position(|&want| want == i) {
                debug_assert!(
                    ids.iter().filter(|&&want| want == i).count() == 1,
                    "duplicate KV block id {i}"
                );
                picked[p] = Some(b);
            }
        }
        picked
            .into_iter()
            .enumerate()
            .map(|(p, b)| b.unwrap_or_else(|| panic!("KV block id {} out of range", ids[p])))
            .collect()
    }

    /// Heap bytes across all blocks (capacity planning / `info`).
    pub fn bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{repo_root, Presets};

    fn spec() -> crate::config::ModelSpec {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        presets.model("topt-s1").unwrap().clone()
    }

    #[test]
    fn alloc_free_cycle() {
        let spec = spec();
        let mut pool = KvPool::new(&spec, 2);
        assert_eq!(pool.free_count(), 2);
        let a = pool.alloc().unwrap();
        let b = pool.alloc().unwrap();
        assert_ne!(a, b);
        assert!(pool.alloc().is_none());
        pool.free(a);
        assert_eq!(pool.free_count(), 1);
        let c = pool.alloc().unwrap();
        assert_eq!(c, a, "freed block is reused");
    }

    #[test]
    fn freed_blocks_come_back_cleared() {
        let spec = spec();
        let mut pool = KvPool::new(&spec, 1);
        let id = pool.alloc().unwrap();
        let row = vec![1.0f32; spec.d];
        pool.block_mut(id).layer_mut(0).push(&row, &row);
        assert_eq!(pool.block(id).layer(0).len(), 1);
        pool.free(id);
        let id2 = pool.alloc().unwrap();
        assert!(pool.block(id2).is_empty());
    }

    #[test]
    fn blocks_mut_preserves_requested_order() {
        let spec = spec();
        let mut pool = KvPool::new(&spec, 3);
        let row = vec![2.0f32; spec.d];
        pool.block_mut(2).layer_mut(0).push(&row, &row);
        let picked = pool.blocks_mut(&[2, 0]);
        assert_eq!(picked.len(), 2);
        assert_eq!(picked[0].len(), 1, "first pick is block 2");
        assert_eq!(picked[1].len(), 0, "second pick is block 0");
    }

    #[test]
    fn block_bytes_match_geometry() {
        let spec = spec();
        let block = KvBlock::new(&spec);
        assert_eq!(block.bytes(), spec.layers * 2 * 4 * spec.seq * spec.d);
    }
}

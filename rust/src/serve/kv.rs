//! Paged per-request KV state: K/V storage is handed out in fixed-size
//! *position pages* instead of full-context slot buffers.
//!
//! Layout: a [`KvPage`] holds `page` positions × `d` floats of K and again
//! of V. A [`KvBlock`] (one per in-flight request) stacks one
//! [`PagedKvLayer`] per decoder layer; each layer resolves position `t`
//! through its page table (`pages[t / page]`, offset `t % page`), so a
//! request only ever holds the pages its actual length needs — a
//! half-full batch of short requests stays far below the old monolithic
//! `slots × seq` footprint.
//!
//! The [`KvPool`] owns the page economy:
//!
//! * **budget** — a hard cap on pages in flight (defaults to the full
//!   monolithic capacity, `ceil(seq/page) × layers × slots`, so default
//!   serving can never admit less than before);
//! * **reservations** — admission reserves every page a request could
//!   need at its projected maximum length (prompt + max_tokens), so an
//!   admitted request can always grow: backpressure is *eviction-free*
//!   and deterministic (FIFO queue until pages free, never mid-stream
//!   preemption);
//! * **lazy allocation + recycling** — page buffers are allocated on
//!   first use and recycled on retire, so `resident_bytes` tracks what
//!   requests actually touched, not the worst case.
//!
//! Pages *move*: [`KvPool::take`] hands an owned page to a block,
//! [`KvBlock::release`] moves them back. Blocks therefore own their
//! storage outright while in flight — the batched decode step can hold
//! every active block mutably with no aliasing into a shared arena — and
//! page identity can never leak between requests.
//!
//! Every growth path is checked: [`KvPool::take`] and
//! [`KvBlock::grow_to`] return errors instead of panicking, so a serving
//! accounting slip retires one request instead of killing the process
//! (see `engine`).

use anyhow::{bail, ensure, Result};

use crate::config::ModelSpec;
use crate::model::forward::KvRead;

/// One fixed-size page of K/V storage: `page` positions × `d` floats
/// each for K and V.
pub struct KvPage {
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvPage {
    fn new(page: usize, d: usize) -> KvPage {
        KvPage { k: vec![0.0; page * d], v: vec![0.0; page * d] }
    }

    /// Heap bytes of one page for the given geometry.
    pub fn bytes_for(page: usize, d: usize) -> usize {
        2 * 4 * page * d
    }
}

/// One decoder layer's cache for one request: a page table over
/// [`KvPage`]s. Position `t` lives in `pages[t / page]` at row offset
/// `t % page` — rows never span pages, so attention reads a position as
/// one contiguous slice exactly like the monolithic cache.
pub struct PagedKvLayer {
    pages: Vec<KvPage>,
    d: usize,
    /// Positions per page.
    page: usize,
    len: usize,
}

impl PagedKvLayer {
    fn new(page: usize, d: usize) -> PagedKvLayer {
        PagedKvLayer { pages: Vec::new(), d, page, len: 0 }
    }

    /// Cached positions so far.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Positions the currently-held pages can store.
    pub fn capacity(&self) -> usize {
        self.pages.len() * self.page
    }

    /// Append the K/V projection rows of the next position. Checked: a
    /// position beyond the held pages is an error, not a panic — the
    /// serve path retires the offending request and keeps the rest of
    /// the batch alive.
    pub fn push(&mut self, k_row: &[f32], v_row: &[f32]) -> Result<()> {
        ensure!(k_row.len() == self.d, "K row width {} != d {}", k_row.len(), self.d);
        ensure!(v_row.len() == self.d, "V row width {} != d {}", v_row.len(), self.d);
        if self.len >= self.capacity() {
            bail!(
                "paged KV overflow: position {} beyond {} held pages ({} positions)",
                self.len,
                self.pages.len(),
                self.capacity()
            );
        }
        let (pi, off) = (self.len / self.page, (self.len % self.page) * self.d);
        self.pages[pi].k[off..off + self.d].copy_from_slice(k_row);
        self.pages[pi].v[off..off + self.d].copy_from_slice(v_row);
        self.len += 1;
        Ok(())
    }

    /// Cached K row for position `t`.
    pub fn k_row(&self, t: usize) -> &[f32] {
        debug_assert!(t < self.len);
        let off = (t % self.page) * self.d;
        &self.pages[t / self.page].k[off..off + self.d]
    }

    /// Cached V row for position `t`.
    pub fn v_row(&self, t: usize) -> &[f32] {
        debug_assert!(t < self.len);
        let off = (t % self.page) * self.d;
        &self.pages[t / self.page].v[off..off + self.d]
    }

    /// Heap bytes of the held pages.
    pub fn bytes(&self) -> usize {
        self.pages.len() * KvPage::bytes_for(self.page, self.d)
    }
}

/// Attention reads through the page table; see `model::forward::KvRead`.
impl KvRead for PagedKvLayer {
    fn len(&self) -> usize {
        PagedKvLayer::len(self)
    }
    fn k_row(&self, t: usize) -> &[f32] {
        PagedKvLayer::k_row(self, t)
    }
    fn v_row(&self, t: usize) -> &[f32] {
        PagedKvLayer::v_row(self, t)
    }
}

/// The KV state of one in-flight request: one paged cache per decoder
/// layer. Created empty (no pages); the engine grows it ahead of each
/// append via [`KvBlock::grow_to`] and returns the pages on retire via
/// [`KvBlock::release`].
pub struct KvBlock {
    layers: Vec<PagedKvLayer>,
}

impl KvBlock {
    /// Empty block for `spec` with `page` positions per page. Holds no
    /// pages until grown.
    pub fn new(spec: &ModelSpec, page: usize) -> KvBlock {
        assert!(page >= 1, "page size must be at least 1 position");
        KvBlock { layers: (0..spec.layers).map(|_| PagedKvLayer::new(page, spec.d)).collect() }
    }

    /// Cache of decoder layer `li`.
    pub fn layer(&self, li: usize) -> &PagedKvLayer {
        &self.layers[li]
    }

    /// Mutable cache of decoder layer `li`.
    pub fn layer_mut(&mut self, li: usize) -> &mut PagedKvLayer {
        &mut self.layers[li]
    }

    /// Cached positions (identical across layers by construction).
    pub fn len(&self) -> usize {
        self.layers.first().map(|l| l.len()).unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Pages currently held across all layers.
    pub fn held_pages(&self) -> usize {
        self.layers.iter().map(|l| l.pages.len()).sum()
    }

    /// Ensure every layer can store `positions` positions, taking pages
    /// from `pool` on demand. Checked: pool exhaustion (an accounting
    /// slip — reservations should always cover growth) is an error that
    /// the engine turns into a single-request retire. Partially-attached
    /// pages stay with the block and return to the pool on release.
    pub fn grow_to(&mut self, positions: usize, pool: &mut KvPool) -> Result<()> {
        for (li, layer) in self.layers.iter_mut().enumerate() {
            while layer.capacity() < positions {
                let page = pool.take().map_err(|e| {
                    e.context(format!("growing layer {li} to {positions} positions"))
                })?;
                layer.pages.push(page);
            }
        }
        Ok(())
    }

    /// Move every held page back to the pool and reset the block to
    /// empty (retire / abort path).
    pub fn release(&mut self, pool: &mut KvPool) {
        for layer in &mut self.layers {
            for page in layer.pages.drain(..) {
                pool.give(page);
            }
            layer.len = 0;
        }
    }

    /// Heap bytes held by this block's pages.
    pub fn bytes(&self) -> usize {
        self.layers.iter().map(|l| l.bytes()).sum()
    }
}

/// The page economy for one engine: a budget of pages, admission
/// reservations against it, and a recycle list so steady-state serving
/// allocates nothing.
pub struct KvPool {
    d: usize,
    layers: usize,
    /// Positions per page.
    page: usize,
    /// Hard cap on pages in flight.
    budget: usize,
    /// Pages reserved by admitted requests (≥ `in_use`, ≤ `budget`).
    reserved: usize,
    /// Pages currently held by blocks.
    in_use: usize,
    /// Page buffers alive (held by blocks or recycled) — the resident
    /// footprint.
    allocated: usize,
    recycled: Vec<KvPage>,
}

impl KvPool {
    /// Pool for `spec` with `page` positions per page and a hard budget
    /// of `budget` pages.
    pub fn new(spec: &ModelSpec, page: usize, budget: usize) -> KvPool {
        assert!(page >= 1, "page size must be at least 1 position");
        KvPool {
            d: spec.d,
            layers: spec.layers,
            page,
            budget,
            reserved: 0,
            in_use: 0,
            allocated: 0,
            recycled: Vec::new(),
        }
    }

    /// The budget that exactly matches the old monolithic pool: every
    /// one of `slots` requests can hold the full model context.
    pub fn full_context_budget(spec: &ModelSpec, page: usize, slots: usize) -> usize {
        spec.seq.div_ceil(page) * spec.layers * slots
    }

    /// Positions per page.
    pub fn page_positions(&self) -> usize {
        self.page
    }

    /// Pages a request caching up to `positions` positions needs across
    /// all layers.
    pub fn pages_for(&self, positions: usize) -> usize {
        positions.div_ceil(self.page) * self.layers
    }

    /// Admission: reserve `pages` against the budget. Returns false
    /// (leaving the pool untouched) when they don't fit — the request
    /// queues until retirements release reservations.
    pub fn try_reserve(&mut self, pages: usize) -> bool {
        if self.reserved + pages > self.budget {
            return false;
        }
        self.reserved += pages;
        true
    }

    /// Release an admission reservation (retire path).
    pub fn release_reservation(&mut self, pages: usize) {
        debug_assert!(pages <= self.reserved, "reservation underflow");
        self.reserved = self.reserved.saturating_sub(pages);
    }

    /// Take one page, recycling a retired buffer when one exists.
    /// Checked: exhaustion beyond the budget is an error (growth is
    /// always covered by a reservation unless accounting slipped).
    pub fn take(&mut self) -> Result<KvPage> {
        if self.in_use >= self.budget {
            bail!(
                "KV page pool exhausted: {} pages in use of {} budgeted ({} reserved)",
                self.in_use,
                self.budget,
                self.reserved
            );
        }
        self.in_use += 1;
        Ok(match self.recycled.pop() {
            Some(p) => p,
            None => {
                self.allocated += 1;
                KvPage::new(self.page, self.d)
            }
        })
    }

    /// Return a page (retire / abort path); the buffer is recycled.
    pub fn give(&mut self, page: KvPage) {
        debug_assert!(self.in_use > 0, "page given back with none outstanding (double give?)");
        self.in_use = self.in_use.saturating_sub(1);
        self.recycled.push(page);
    }

    /// Pages the budget still admits against (budget − reserved;
    /// saturating, since the failure-injection hook can push the budget
    /// below outstanding reservations).
    pub fn available_pages(&self) -> usize {
        self.budget.saturating_sub(self.reserved)
    }

    pub fn budget_pages(&self) -> usize {
        self.budget
    }

    pub fn reserved_pages(&self) -> usize {
        self.reserved
    }

    pub fn in_use_pages(&self) -> usize {
        self.in_use
    }

    /// Heap bytes of every page buffer alive (in blocks or recycled) —
    /// what the pool actually costs, as opposed to the worst-case
    /// [`KvPool::capacity_bytes`].
    pub fn resident_bytes(&self) -> usize {
        self.allocated * KvPage::bytes_for(self.page, self.d)
    }

    /// Worst-case bytes if the whole budget were allocated.
    pub fn capacity_bytes(&self) -> usize {
        self.budget * KvPage::bytes_for(self.page, self.d)
    }

    /// Test / failure-injection hook: shrink (or grow) the budget in
    /// flight. Shrinking below the pages in use makes the next growth
    /// fail with the checked exhaustion error.
    #[doc(hidden)]
    pub fn debug_set_budget(&mut self, pages: usize) {
        self.budget = pages;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{repo_root, Presets};

    fn spec() -> crate::config::ModelSpec {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        presets.model("topt-s1").unwrap().clone()
    }

    #[test]
    fn pages_are_taken_lazily_and_recycled() {
        let spec = spec();
        let mut pool = KvPool::new(&spec, 16, KvPool::full_context_budget(&spec, 16, 2));
        assert_eq!(pool.budget_pages(), spec.seq.div_ceil(16) * spec.layers * 2);
        assert_eq!(pool.resident_bytes(), 0, "nothing allocated up front");

        let mut block = KvBlock::new(&spec, 16);
        assert_eq!(block.held_pages(), 0);
        block.grow_to(1, &mut pool).unwrap();
        assert_eq!(block.held_pages(), spec.layers, "one page per layer");
        assert_eq!(pool.in_use_pages(), spec.layers);
        assert_eq!(pool.resident_bytes(), spec.layers * KvPage::bytes_for(16, spec.d));
        // growing within the page takes nothing new
        block.grow_to(16, &mut pool).unwrap();
        assert_eq!(block.held_pages(), spec.layers);
        // crossing the boundary takes one more per layer
        block.grow_to(17, &mut pool).unwrap();
        assert_eq!(block.held_pages(), 2 * spec.layers);

        let resident = pool.resident_bytes();
        block.release(&mut pool);
        assert_eq!(pool.in_use_pages(), 0);
        assert_eq!(pool.resident_bytes(), resident, "buffers are recycled, not freed");
        // a new block reuses the recycled buffers: resident stays flat
        let mut b2 = KvBlock::new(&spec, 16);
        b2.grow_to(17, &mut pool).unwrap();
        assert_eq!(pool.resident_bytes(), resident);
    }

    #[test]
    fn reservation_accounting_gates_admission() {
        let spec = spec();
        let mut pool = KvPool::new(&spec, 16, spec.layers * 4);
        let per_req = pool.pages_for(40); // 3 pages × layers
        assert_eq!(per_req, 3 * spec.layers);
        assert!(pool.try_reserve(per_req));
        assert_eq!(pool.available_pages(), spec.layers);
        assert!(!pool.try_reserve(per_req), "second request must queue");
        assert!(pool.try_reserve(pool.pages_for(5)), "a short request still fits");
        pool.release_reservation(per_req);
        assert!(pool.try_reserve(per_req), "retire frees the reservation");
    }

    #[test]
    fn exhaustion_is_a_checked_error() {
        let spec = spec();
        let mut pool = KvPool::new(&spec, 4, spec.layers);
        let mut block = KvBlock::new(&spec, 4);
        block.grow_to(4, &mut pool).unwrap();
        let err = format!("{:#}", block.grow_to(5, &mut pool).unwrap_err());
        assert!(err.contains("exhausted"), "{err}");
        // the failed grow left the first layer's pages attached; release
        // returns everything
        block.release(&mut pool);
        assert_eq!(pool.in_use_pages(), 0);
    }

    #[test]
    fn push_beyond_held_pages_is_a_checked_error() {
        let spec = spec();
        let mut pool = KvPool::new(&spec, 4, KvPool::full_context_budget(&spec, 4, 1));
        let mut block = KvBlock::new(&spec, 4);
        let row = vec![1.0f32; spec.d];
        assert!(block.layer_mut(0).push(&row, &row).is_err(), "no pages attached yet");
        block.grow_to(4, &mut pool).unwrap();
        for _ in 0..4 {
            block.layer_mut(0).push(&row, &row).unwrap();
        }
        let err = block.layer_mut(0).push(&row, &row).unwrap_err().to_string();
        assert!(err.contains("overflow"), "{err}");
        assert_eq!(block.layer(0).len(), 4, "failed push must not corrupt the cache");
    }

    #[test]
    fn paged_rows_match_what_was_pushed() {
        let spec = spec();
        let mut pool = KvPool::new(&spec, 4, KvPool::full_context_budget(&spec, 4, 1));
        let mut block = KvBlock::new(&spec, 4);
        block.grow_to(10, &mut pool).unwrap();
        for t in 0..10 {
            let k: Vec<f32> = (0..spec.d).map(|j| (t * spec.d + j) as f32).collect();
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            block.layer_mut(0).push(&k, &v).unwrap();
        }
        assert_eq!(block.len(), 10);
        for t in 0..10 {
            assert_eq!(block.layer(0).k_row(t)[0], (t * spec.d) as f32, "k row {t}");
            assert_eq!(block.layer(0).v_row(t)[1], -((t * spec.d + 1) as f32), "v row {t}");
        }
        // capacity is page-quantized
        assert_eq!(block.layer(0).capacity(), 12);
    }

    #[test]
    fn block_bytes_track_held_pages_only() {
        let spec = spec();
        let mut pool = KvPool::new(&spec, 16, KvPool::full_context_budget(&spec, 16, 1));
        let mut block = KvBlock::new(&spec, 16);
        assert_eq!(block.bytes(), 0);
        block.grow_to(3, &mut pool).unwrap();
        assert_eq!(block.bytes(), spec.layers * 2 * 4 * 16 * spec.d);
        let monolithic = spec.layers * 2 * 4 * spec.seq * spec.d;
        assert!(block.bytes() < monolithic, "short request beats the monolithic block");
    }
}

//! Typed serve requests/responses, the JSONL wire codec, and the
//! transcript tee.
//!
//! The wire format is one JSON object per line. Requests:
//!
//! ```json
//! {"id": "r1", "prompt": "the ", "max_tokens": 32, "temperature": 0.0, "seed": 7}
//! ```
//!
//! `prompt` is required; everything else defaults (`id` is assigned by the
//! front end when absent). Responses mirror back the id plus the decoded
//! text, token counts, finish reason and latency. Unknown request keys are
//! rejected — admission control starts at the parser.

use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::ser::json::Json;

/// One generation request.
#[derive(Clone, Debug)]
pub struct ServeRequest {
    pub id: String,
    pub prompt: String,
    /// Decode budget; generation retires with `FinishReason::Length` when
    /// this many tokens have been produced.
    pub max_tokens: usize,
    /// 0 = greedy; otherwise softmax temperature (matches `eval::generate`).
    pub temperature: f64,
    /// Per-request sampling seed (stream 61, like `eval::generate`).
    pub seed: u64,
    /// Optional single-character stop text: sampling this token retires
    /// the request early with `FinishReason::Stop` (token not emitted).
    pub stop: Option<String>,
}

impl Default for ServeRequest {
    fn default() -> Self {
        ServeRequest {
            id: String::new(),
            prompt: String::new(),
            max_tokens: 32,
            temperature: 0.0,
            seed: 0,
            stop: None,
        }
    }
}

const REQUEST_KEYS: &[&str] = &["id", "prompt", "max_tokens", "temperature", "seed", "stop"];

impl ServeRequest {
    /// Parse one JSONL line.
    pub fn from_json_line(line: &str) -> Result<ServeRequest> {
        let v = Json::parse(line.trim()).map_err(|e| anyhow::anyhow!("request line: {e}"))?;
        let obj = v.as_obj().context("request must be a JSON object")?;
        for k in obj.keys() {
            if !REQUEST_KEYS.contains(&k.as_str()) {
                bail!("unknown request key '{k}' (known: {})", REQUEST_KEYS.join(", "));
            }
        }
        let mut req = ServeRequest::default();
        if let Some(id) = v.get("id") {
            req.id = id.as_str().context("'id' must be a string")?.to_string();
        }
        req.prompt = v
            .req("prompt")?
            .as_str()
            .context("'prompt' must be a string")?
            .to_string();
        if let Some(m) = v.get("max_tokens") {
            req.max_tokens = m.as_usize().context("'max_tokens' must be a number")?;
        }
        if let Some(t) = v.get("temperature") {
            req.temperature = t.as_f64().context("'temperature' must be a number")?;
        }
        if let Some(s) = v.get("seed") {
            req.seed = s.as_f64().context("'seed' must be a number")? as u64;
        }
        if let Some(s) = v.get("stop") {
            let s = s.as_str().context("'stop' must be a string")?;
            if s.chars().count() != 1 {
                bail!("'stop' must be a single character, got {s:?}");
            }
            req.stop = Some(s.to_string());
        }
        Ok(req)
    }

    /// Parse one JSONL line, rejecting lines over `max_len` bytes before
    /// touching the JSON parser. The socket path bounds lines during
    /// framing already (`serve::net::BoundedLineReader`); this is the
    /// codec-level backstop for any path that hands the codec a
    /// pre-assembled string.
    pub fn from_json_line_checked(line: &str, max_len: usize) -> Result<ServeRequest> {
        if line.len() > max_len {
            bail!(
                "request line is {} bytes, over the {} byte cap",
                line.len(),
                max_len
            );
        }
        Self::from_json_line(line)
    }

    /// Serialize back to one JSON line (synthetic-load generation, tests).
    pub fn to_json_line(&self) -> String {
        let mut m = std::collections::BTreeMap::new();
        m.insert("id".to_string(), Json::Str(self.id.clone()));
        m.insert("prompt".to_string(), Json::Str(self.prompt.clone()));
        m.insert("max_tokens".to_string(), Json::Num(self.max_tokens as f64));
        m.insert("temperature".to_string(), Json::Num(self.temperature));
        m.insert("seed".to_string(), Json::Num(self.seed as f64));
        if let Some(s) = &self.stop {
            m.insert("stop".to_string(), Json::Str(s.clone()));
        }
        Json::Obj(m).to_string_compact()
    }
}

/// Why a request left the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Produced `max_tokens` tokens.
    Length,
    /// Sampled the request's stop token.
    Stop,
    /// Aborted mid-stream by the client.
    Aborted,
    /// Never admitted (admission control / validation failure).
    Rejected,
    /// Retired mid-stream by a serving-side error (e.g. a KV page
    /// accounting slip): the partial text is returned and `error` says
    /// what failed. Only the offending request retires — co-batched
    /// streams are unaffected.
    Error,
}

impl FinishReason {
    pub fn label(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
            FinishReason::Aborted => "aborted",
            FinishReason::Rejected => "rejected",
            FinishReason::Error => "error",
        }
    }
}

/// One completed (or rejected) request.
#[derive(Clone, Debug)]
pub struct ServeResponse {
    pub id: String,
    /// Decoded continuation (prompt excluded). Partial on abort.
    pub text: String,
    pub prompt_tokens: usize,
    pub completion_tokens: usize,
    pub finish: FinishReason,
    /// Submit-to-retire wall time.
    pub latency_ms: f64,
    /// Validation message for `FinishReason::Rejected`.
    pub error: Option<String>,
}

impl ServeResponse {
    /// Serialize to one JSON line.
    pub fn to_json_line(&self) -> String {
        let mut m = std::collections::BTreeMap::new();
        m.insert("id".to_string(), Json::Str(self.id.clone()));
        m.insert("text".to_string(), Json::Str(self.text.clone()));
        m.insert("prompt_tokens".to_string(), Json::Num(self.prompt_tokens as f64));
        m.insert("completion_tokens".to_string(), Json::Num(self.completion_tokens as f64));
        m.insert("finish".to_string(), Json::Str(self.finish.label().to_string()));
        m.insert("latency_ms".to_string(), Json::Num((self.latency_ms * 1e3).round() / 1e3));
        if let Some(e) = &self.error {
            m.insert("error".to_string(), Json::Str(e.clone()));
        }
        Json::Obj(m).to_string_compact()
    }
}

/// Appends one JSON line per retired request to a file — the transcript
/// tee behind `serve --transcript`.
pub struct TranscriptTee {
    file: std::fs::File,
}

impl TranscriptTee {
    pub fn create(path: &Path) -> Result<TranscriptTee> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        Ok(TranscriptTee {
            file: std::fs::File::create(path)
                .with_context(|| format!("creating transcript {}", path.display()))?,
        })
    }

    pub fn write(&mut self, resp: &ServeResponse) -> Result<()> {
        writeln!(self.file, "{}", resp.to_json_line())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let line = r#"{"id":"r1","prompt":"the ","max_tokens":8,"temperature":0.5,"seed":3}"#;
        let req = ServeRequest::from_json_line(line).unwrap();
        assert_eq!(req.id, "r1");
        assert_eq!(req.prompt, "the ");
        assert_eq!(req.max_tokens, 8);
        assert_eq!(req.temperature, 0.5);
        assert_eq!(req.seed, 3);
        let back = ServeRequest::from_json_line(&req.to_json_line()).unwrap();
        assert_eq!(back.prompt, req.prompt);
        assert_eq!(back.max_tokens, req.max_tokens);
    }

    #[test]
    fn request_defaults_and_errors() {
        let req = ServeRequest::from_json_line(r#"{"prompt":"x"}"#).unwrap();
        assert_eq!(req.max_tokens, 32);
        assert_eq!(req.temperature, 0.0);
        assert!(req.id.is_empty());
        assert!(ServeRequest::from_json_line("not json").is_err());
        assert!(ServeRequest::from_json_line(r#"{"max_tokens":4}"#).is_err(), "prompt required");
        assert!(ServeRequest::from_json_line(r#"{"prompt":"x","bogus":1}"#).is_err());
        assert!(ServeRequest::from_json_line(r#"{"prompt":"x","stop":"ab"}"#).is_err());
    }

    #[test]
    fn hundred_megabyte_line_is_rejected_by_the_checked_codec() {
        // Regression: the codec must refuse a 100 MB line with a typed
        // error before the JSON parser ever sees it. (The streaming-side
        // regression — never even *buffering* such a line — lives in
        // serve::net::framing.)
        let mut line = String::with_capacity(100_000_016);
        line.push_str("{\"prompt\":\"");
        line.push_str(&"a".repeat(100_000_000));
        line.push_str("\"}");
        let err = ServeRequest::from_json_line_checked(&line, crate::serve::net::DEFAULT_MAX_LINE)
            .unwrap_err()
            .to_string();
        assert!(err.contains("byte cap"), "{err}");
        // under the cap, checked == unchecked
        let ok = ServeRequest::from_json_line_checked(r#"{"prompt":"x"}"#, 1 << 20).unwrap();
        assert_eq!(ok.prompt, "x");
    }

    #[test]
    fn response_line_is_valid_json() {
        let resp = ServeResponse {
            id: "r9".into(),
            text: "a \"quoted\" bit".into(),
            prompt_tokens: 4,
            completion_tokens: 2,
            finish: FinishReason::Length,
            latency_ms: 1.23456,
            error: None,
        };
        let line = resp.to_json_line();
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("id").unwrap().as_str(), Some("r9"));
        assert_eq!(v.get("finish").unwrap().as_str(), Some("length"));
        assert_eq!(v.get("completion_tokens").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn transcript_tee_appends_lines() {
        let path = std::env::temp_dir().join(format!("fp_tee_{}.jsonl", std::process::id()));
        {
            let mut tee = TranscriptTee::create(&path).unwrap();
            for id in ["a", "b"] {
                tee.write(&ServeResponse {
                    id: id.into(),
                    text: String::new(),
                    prompt_tokens: 1,
                    completion_tokens: 0,
                    finish: FinishReason::Aborted,
                    latency_ms: 0.0,
                    error: None,
                })
                .unwrap();
            }
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        assert!(text.lines().all(|l| Json::parse(l).is_ok()));
        std::fs::remove_file(&path).ok();
    }
}

//! The continuous-batching serving engine over the paged KV pool.
//!
//! State machine per request:
//!
//! ```text
//!   submit ──(admission control)──▶ queued ──(slot + page reservation)──▶ prefilling(chunk k/N)
//!       ▲                              │                                        │
//!       └── rejected (error response)  └── aborted                              ▼
//!                                                                            decoding ──▶ retired
//!                                                               (length | stop | abort | kv error)
//! ```
//!
//! Scheduling is *continuous* and *chunk-interleaved*: every
//! [`Engine::step`] first spends a bounded prefill-token budget
//! (`prefill_chunk`) on slots still warming their prompt caches, then
//! advances every decoding slot by one token in a single batched forward
//! (`batch::decode_step`), retires finished slots, and admits queued
//! requests into the freed capacity — new arrivals join the batch
//! mid-flight (join-on-arrival / retire-on-EOS), and a 1k-token prompt
//! costs each step at most `prefill_chunk` positions instead of stalling
//! every co-batched stream for a whole prefill pass.
//!
//! Admission is page-accounted: a request is admitted when a slot is
//! free AND the paged pool can *reserve* every page its projected
//! maximum length could need (`serve::kv`). Reservation makes
//! backpressure eviction-free and deterministic — admission is strictly
//! FIFO (head-of-line blocking, never best-fit reordering), and an
//! admitted request can always grow to its projected length. Actual
//! pages are taken lazily as the cache grows; a growth that the
//! accounting cannot cover (an internal slip, or an injected budget
//! shrink) is a *checked* error that retires only the offending request
//! — every other in-flight stream continues byte-identical.
//!
//! Determinism contract: a request's token stream depends only on the
//! model weights, its own prompt/seed/temperature, and the kernel
//! determinism guarantees of `tensor::par` — never on batch composition,
//! admission order, page size or page assignment, prefill chunk
//! boundaries, worker thread count, or other requests' lifecycles
//! (including mid-stream aborts). `rust/tests/serve_parity.rs`,
//! `rust/tests/paged_kv_parity.rs` and the abort/exhaustion cases in
//! `rust/tests/failure_injection.rs` pin this down against
//! `eval::generate`. Tracing (`obs`) only observes this machine, never
//! gates it: `rust/tests/trace_parity.rs` pins that a traced run's
//! served bytes equal the untraced run's, bitwise.

use std::collections::{BTreeSet, VecDeque};

use anyhow::{bail, Context, Result};

use crate::config::{KernelVariant, QuantMode};
use crate::data::tokenizer;
use crate::eval::generate::next_token;
use crate::metrics::{Histogram, Snapshot};
use crate::obs::{Recorder, SharedClock};
use crate::ser::json::Json;
use crate::tensor::par;
use crate::util::Pcg64;

use super::batch::{decode_step, prefill_extend, ServeModel};
use super::kv::{KvBlock, KvPool};
use super::request::{FinishReason, ServeRequest, ServeResponse, TranscriptTee};

/// Engine sizing and output knobs.
pub struct EngineConfig {
    /// Concurrent decode slots (the continuous-batch width).
    pub max_batch: usize,
    /// Waiting-line bound; submissions beyond it are rejected.
    pub queue_cap: usize,
    /// Positions per KV page (`--kv-page`).
    pub kv_page: usize,
    /// KV page budget; `None` sizes the pool so every slot can hold the
    /// full model context (the old monolithic capacity — default
    /// workloads admit exactly as before, they just stop paying for
    /// context they never touch).
    pub kv_pages: Option<usize>,
    /// Prefill-token budget per engine step (`--prefill-chunk`): long
    /// prompts warm up `prefill_chunk` positions at a time, interleaved
    /// with decode steps of the other slots.
    pub prefill_chunk: usize,
    /// Tee every retired request to this JSONL file.
    pub transcript: Option<std::path::PathBuf>,
    /// Timestamp source for queueing/latency accounting and trace
    /// events; `None` uses a process-monotonic clock. Injectable so
    /// tests and `replay` can pin every timestamp (`obs::FakeClock`).
    pub clock: Option<SharedClock>,
    /// Structured trace sink; `None` (the default) makes every
    /// instrumentation site a skipped branch — tracing only observes,
    /// it never gates scheduling.
    pub recorder: Option<Recorder>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            max_batch: 4,
            queue_cap: 64,
            kv_page: 16,
            kv_pages: None,
            prefill_chunk: 16,
            transcript: None,
            clock: None,
            recorder: None,
        }
    }
}

/// A validated submission waiting for capacity. The prompt is tokenized
/// exactly once, at submission; admission and prefill reuse these ids,
/// so the counts admission checked are the counts prefill feeds.
struct QueuedReq {
    req: ServeRequest,
    tokens: Vec<i32>,
    /// Submission timestamp in engine-clock milliseconds.
    submitted: f64,
}

/// One in-flight request: its token tail, paged KV block, reservation,
/// and sampling state.
struct Slot {
    req: ServeRequest,
    /// Prompt + generated token ids (encoded once at submission).
    tokens: Vec<i32>,
    prompt_len: usize,
    /// Tokens already fed to the model (== KV cache length). While
    /// `fed < prompt_len - 1` the slot is *prefilling*; once the prompt
    /// (minus its last token) is cached it decodes: the pending token
    /// `tokens[fed]` is fed next and its logits sample `tokens[fed+1]`.
    fed: usize,
    block: KvBlock,
    /// Pages reserved at admission for the projected maximum length.
    reserved_pages: usize,
    rng: Pcg64,
    stop_id: Option<i32>,
    /// Submission timestamp in engine-clock milliseconds.
    submitted: f64,
}

impl Slot {
    /// Prompt positions still to cache before decoding can start.
    fn prefill_remaining(&self) -> usize {
        (self.prompt_len - 1).saturating_sub(self.fed)
    }
}

/// Aggregate engine counters (the serving metrics source).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Batched decode steps executed.
    pub steps: u64,
    /// Tokens decoded across all requests (prefill excluded).
    pub decoded_tokens: u64,
    /// Prompt tokens prefilled across all requests.
    pub prefill_tokens: u64,
    /// Prefill chunks executed (> requests admitted ⇒ chunking engaged).
    pub prefill_chunks: u64,
    /// Requests retired (any finish reason, rejections included).
    pub retired: u64,
}

/// The continuous-batching engine over a borrowed [`ServeModel`] (the
/// model is shared so several engines — e.g. serve-bench's batch-width
/// sweeps — reuse one weight resolution / compression).
pub struct Engine<'m> {
    model: &'m ServeModel<'m>,
    cfg_queue_cap: usize,
    prefill_chunk: usize,
    pool: KvPool,
    slots: Vec<Option<Slot>>,
    queue: VecDeque<QueuedReq>,
    aborts: BTreeSet<String>,
    responses: Vec<ServeResponse>,
    tee: Option<TranscriptTee>,
    pub stats: EngineStats,
    clock: SharedClock,
    rec: Option<Recorder>,
    /// Wall time per scheduler step (always on; one clock read per
    /// step, no allocation).
    step_ms: Histogram,
    /// Decode-batch width per step with decoded tokens.
    decode_batch: Histogram,
}

impl<'m> Engine<'m> {
    pub fn new(model: &'m ServeModel<'m>, cfg: &EngineConfig) -> Result<Engine<'m>> {
        if cfg.max_batch == 0 {
            bail!("max_batch must be at least 1");
        }
        if cfg.queue_cap == 0 {
            bail!("queue_cap must be at least 1");
        }
        if cfg.kv_page == 0 {
            bail!("kv_page must be at least 1 position");
        }
        if cfg.prefill_chunk == 0 {
            bail!("prefill_chunk must be at least 1 token");
        }
        let budget = cfg.kv_pages.unwrap_or_else(|| {
            KvPool::full_context_budget(&model.spec, cfg.kv_page, cfg.max_batch)
        });
        let pool = KvPool::new(&model.spec, cfg.kv_page, budget);
        if budget < pool.pages_for(1) {
            bail!(
                "kv page budget {budget} cannot hold even one position ({} layers need {} pages)",
                model.spec.layers,
                pool.pages_for(1)
            );
        }
        let tee = match &cfg.transcript {
            Some(p) => Some(TranscriptTee::create(p)?),
            None => None,
        };
        if let Some(r) = &cfg.recorder {
            // one startup trace point recording which kernel family this
            // engine's decode steps will run through
            r.gauge(
                "kernel_config",
                "",
                vec![
                    ("kernel", Json::Str(par::kernel_variant().label().to_string())),
                    ("quant", Json::Str(model.quant().label().to_string())),
                    ("format", Json::Str(model.format_label().to_string())),
                ],
            );
        }
        Ok(Engine {
            model,
            cfg_queue_cap: cfg.queue_cap,
            prefill_chunk: cfg.prefill_chunk,
            pool,
            slots: (0..cfg.max_batch).map(|_| None).collect(),
            queue: VecDeque::new(),
            aborts: BTreeSet::new(),
            responses: Vec::new(),
            tee,
            stats: EngineStats::default(),
            clock: cfg.clock.clone().unwrap_or_default(),
            rec: cfg.recorder.clone(),
            step_ms: Histogram::new(),
            decode_batch: Histogram::new(),
        })
    }

    /// KV rows a request will cache at its projected maximum length: the
    /// prompt minus its final token (which is the first decode input)
    /// plus every decode step.
    fn projected_kv(prompt_len: usize, max_tokens: usize) -> usize {
        (prompt_len - 1 + max_tokens).max(1)
    }

    /// Admission control over an already-encoded prompt. Errors name the
    /// request and the violated bound; nothing is partially admitted.
    /// Page *shortage* is deliberately not checked here — a request that
    /// could ever fit queues until retirements free pages (deterministic
    /// backpressure), only an impossible request is rejected.
    fn admission_check(&self, req: &ServeRequest, prompt: &[i32]) -> Result<()> {
        let spec = &self.model.spec;
        if prompt.is_empty() {
            bail!("request '{}': empty prompt", req.id);
        }
        if req.max_tokens == 0 {
            bail!("request '{}': max_tokens must be at least 1", req.id);
        }
        if prompt.len() + req.max_tokens > spec.seq {
            bail!(
                "request '{}': prompt ({}) + max_tokens ({}) exceeds the model context ({})",
                req.id,
                prompt.len(),
                req.max_tokens,
                spec.seq
            );
        }
        let pages = self.pool.pages_for(Self::projected_kv(prompt.len(), req.max_tokens));
        if pages > self.pool.budget_pages() {
            bail!(
                "request '{}': needs {pages} KV pages but the pool budget is {}",
                req.id,
                self.pool.budget_pages()
            );
        }
        if self.has_id(&req.id) {
            bail!(
                "request '{}': duplicate id (a queued or active request already holds it)",
                req.id
            );
        }
        if self.queue.len() >= self.cfg_queue_cap {
            bail!("request '{}': queue full ({} waiting)", req.id, self.queue.len());
        }
        Ok(())
    }

    /// True when `id` names a queued or active request (duplicate ids
    /// would alias `abort` and interleave transcripts under one key).
    fn has_id(&self, id: &str) -> bool {
        self.queue.iter().any(|q| q.req.id == id)
            || self.slots.iter().flatten().any(|s| s.req.id == id)
    }

    /// Admission control: validate and enqueue. The prompt is tokenized
    /// here, once; the queue and the slot carry the ids from then on.
    pub fn submit(&mut self, req: ServeRequest) -> Result<()> {
        let tokens = tokenizer::encode(&req.prompt);
        self.admission_check(&req, &tokens)?;
        if let Some(r) = &self.rec {
            r.point("queued", &req.id, vec![("prompt_tokens", Json::Num(tokens.len() as f64))]);
        }
        self.queue.push_back(QueuedReq { req, tokens, submitted: self.clock.now_ms() });
        Ok(())
    }

    /// [`Engine::submit`], turning a rejection into an error response so a
    /// JSONL front end keeps serving. Returns whether it was admitted.
    pub fn submit_or_reject(&mut self, req: ServeRequest) -> bool {
        let tokens = tokenizer::encode(&req.prompt);
        match self.admission_check(&req, &tokens) {
            Ok(()) => {
                if let Some(r) = &self.rec {
                    r.point(
                        "queued",
                        &req.id,
                        vec![("prompt_tokens", Json::Num(tokens.len() as f64))],
                    );
                }
                self.queue.push_back(QueuedReq { req, tokens, submitted: self.clock.now_ms() });
                true
            }
            Err(e) => {
                if let Some(r) = &self.rec {
                    r.point("rejected", &req.id, vec![]);
                }
                self.push_response(ServeResponse {
                    id: req.id,
                    text: String::new(),
                    prompt_tokens: tokens.len(),
                    completion_tokens: 0,
                    finish: FinishReason::Rejected,
                    latency_ms: 0.0,
                    error: Some(format!("{e:#}")),
                });
                false
            }
        }
    }

    /// Mark a request for mid-stream abort; it retires (with its partial
    /// text) at the start of the next step, freeing its slot, pages and
    /// reservation.
    pub fn abort(&mut self, id: &str) {
        self.aborts.insert(id.to_string());
    }

    /// Requests waiting for a slot or for KV pages.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Requests currently prefilling or decoding.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// Decode slots without an assigned request.
    pub fn free_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.is_none()).count()
    }

    /// KV bytes actually allocated (pages touched so far; the paged
    /// pool's memory-conservation number — compare
    /// [`Engine::kv_capacity_bytes`]).
    pub fn kv_resident_bytes(&self) -> usize {
        self.pool.resident_bytes()
    }

    /// Worst-case KV bytes if the whole page budget were in use (what
    /// the old monolithic pool preallocated up front).
    pub fn kv_capacity_bytes(&self) -> usize {
        self.pool.capacity_bytes()
    }

    /// Positions per KV page.
    pub fn kv_page_positions(&self) -> usize {
        self.pool.page_positions()
    }

    /// (in use, reserved, budget) KV pages — the admission accounting.
    pub fn kv_pages(&self) -> (usize, usize, usize) {
        (self.pool.in_use_pages(), self.pool.reserved_pages(), self.pool.budget_pages())
    }

    /// Failure-injection hook: shrink the page budget in flight so the
    /// next growth hits the checked exhaustion path.
    #[doc(hidden)]
    pub fn debug_set_page_budget(&mut self, pages: usize) {
        self.pool.debug_set_budget(pages);
    }

    /// Failure-injection hook: overwrite request `id`'s pending feed token
    /// with an out-of-vocab id, as if the stream were corrupted in flight.
    /// The next decode step must retire only that request with
    /// `FinishReason::Error` while co-batched streams stay bitwise intact.
    /// Returns false when the request is not in a slot with a pending
    /// token.
    #[doc(hidden)]
    pub fn debug_poison_pending_token(&mut self, id: &str) -> bool {
        for slot in self.slots.iter_mut().flatten() {
            if slot.req.id == id {
                if let Some(t) = slot.tokens.get_mut(slot.fed) {
                    *t = i32::MAX;
                    return true;
                }
            }
        }
        false
    }

    /// Trace events dropped by the recorder's bounded channel (0 when
    /// none is installed).
    pub fn dropped_events(&self) -> u64 {
        self.rec.as_ref().map_or(0, |r| r.dropped_events())
    }

    /// The live stats surface: engine counters, occupancy/KV gauges,
    /// and the always-on step/decode-batch histograms, as one mergeable
    /// [`Snapshot`] (the `{"type":"stats"}` control response body and
    /// the exit dump in serve/bench reports).
    pub fn snapshot(&self) -> Snapshot {
        let mut s = Snapshot::new();
        s.counters.add("steps", self.stats.steps);
        s.counters.add("decoded_tokens", self.stats.decoded_tokens);
        s.counters.add("prefill_tokens", self.stats.prefill_tokens);
        s.counters.add("prefill_chunks", self.stats.prefill_chunks);
        s.counters.add("retired", self.stats.retired);
        s.gauge("queued", self.queued() as f64);
        s.gauge("active", self.active() as f64);
        s.gauge("free_slots", self.free_slots() as f64);
        let (in_use, reserved, budget) = self.kv_pages();
        s.gauge("kv_in_use_pages", in_use as f64);
        s.gauge("kv_reserved_pages", reserved as f64);
        s.gauge("kv_budget_pages", budget as f64);
        s.gauge("kv_resident_bytes", self.kv_resident_bytes() as f64);
        // which kernel family decode steps run through: variant
        // (0 = scalar, 1 = simd) and quant (0 = none, 1 = f16, 2 = int8)
        let kv = match par::kernel_variant() {
            KernelVariant::Scalar => 0.0,
            KernelVariant::Simd => 1.0,
        };
        s.gauge("kernel_variant", kv);
        let q = match self.model.quant() {
            QuantMode::None => 0.0,
            QuantMode::F16 => 1.0,
            QuantMode::Int8 => 2.0,
        };
        s.gauge("quant", q);
        s.hist("step_ms", self.step_ms.clone());
        s.hist("decode_batch", self.decode_batch.clone());
        s
    }

    /// True when no request is queued or in flight.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active() == 0
    }

    /// Drain completed responses (retire order).
    pub fn take_responses(&mut self) -> Vec<ServeResponse> {
        std::mem::take(&mut self.responses)
    }

    /// One scheduler tick: apply aborts, admit, spend the prefill budget,
    /// then advance every decoding slot by one token. Returns the number
    /// of tokens decoded this step — 0 with [`Engine::is_idle`] false
    /// means the step went to prefill (or everything retired).
    pub fn step(&mut self) -> Result<usize> {
        let busy = !self.is_idle();
        let t0 = self.clock.now_ms();
        self.apply_aborts()?;
        self.admit()?;
        self.prefill_phase()?;
        let decoded = self.decode_phase()?;
        if busy {
            let dt = self.clock.now_ms() - t0;
            self.step_ms.record(dt);
            if decoded > 0 {
                self.decode_batch.record(decoded as f64);
            }
            if let Some(r) = &self.rec {
                let (in_use, reserved, budget) = self.kv_pages();
                r.gauge(
                    "engine_step",
                    "",
                    vec![
                        ("queued", Json::Num(self.queue.len() as f64)),
                        ("active", Json::Num(self.active() as f64)),
                        ("decoded", Json::Num(decoded as f64)),
                        ("kv_in_use_pages", Json::Num(in_use as f64)),
                        ("kv_reserved_pages", Json::Num(reserved as f64)),
                        ("kv_budget_pages", Json::Num(budget as f64)),
                        ("step_ms", Json::Num(dt)),
                    ],
                );
            }
        }
        Ok(decoded)
    }

    /// Run until idle; drain the responses.
    pub fn run(&mut self) -> Result<Vec<ServeResponse>> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(self.take_responses())
    }

    /// Retire aborted requests, both queued and mid-stream.
    fn apply_aborts(&mut self) -> Result<()> {
        if self.aborts.is_empty() {
            return Ok(());
        }
        // queued: respond without ever admitting
        let aborts = std::mem::take(&mut self.aborts);
        let mut remaining = VecDeque::new();
        let now = self.clock.now_ms();
        for q in std::mem::take(&mut self.queue) {
            if aborts.contains(&q.req.id) {
                if let Some(r) = &self.rec {
                    r.point("aborted", &q.req.id, vec![("queued", Json::Bool(true))]);
                }
                self.push_response(ServeResponse {
                    id: q.req.id,
                    text: String::new(),
                    prompt_tokens: q.tokens.len(),
                    completion_tokens: 0,
                    finish: FinishReason::Aborted,
                    latency_ms: now - q.submitted,
                    error: None,
                });
            } else {
                remaining.push_back(q);
            }
        }
        self.queue = remaining;
        // mid-stream: retire with partial text, freeing slot + pages
        for si in 0..self.slots.len() {
            let hit = self.slots[si].as_ref().is_some_and(|s| aborts.contains(&s.req.id));
            if hit {
                self.retire(si, FinishReason::Aborted, None)?;
            }
        }
        Ok(())
    }

    /// Join-on-arrival admission, strictly FIFO: the head of the queue is
    /// admitted when a slot is free and its full projected page need can
    /// be reserved; otherwise admission stops (head-of-line blocking
    /// keeps the order — and therefore every stream — deterministic).
    /// No prefill work happens here; the slot starts in the prefilling
    /// state and the per-step budget takes it from there.
    fn admit(&mut self) -> Result<()> {
        loop {
            let Some(head) = self.queue.front() else { break };
            let Some(si) = self.slots.iter().position(|s| s.is_none()) else { break };
            let pages =
                self.pool.pages_for(Self::projected_kv(head.tokens.len(), head.req.max_tokens));
            if !self.pool.try_reserve(pages) {
                break;
            }
            let Some(QueuedReq { req, tokens, submitted }) = self.queue.pop_front() else {
                break;
            };
            let prompt_len = tokens.len();
            let rng = Pcg64::new(req.seed, 61);
            let stop_id = req
                .stop
                .as_ref()
                .and_then(|s| tokenizer::encode(s).first().copied());
            self.slots[si] = Some(Slot {
                req,
                tokens,
                prompt_len,
                fed: 0,
                block: KvBlock::new(&self.model.spec, self.pool.page_positions()),
                reserved_pages: pages,
                rng,
                stop_id,
                submitted,
            });
            if let (Some(r), Some(slot)) = (&self.rec, self.slots[si].as_ref()) {
                r.begin(
                    "request",
                    &slot.req.id,
                    vec![
                        ("slot", Json::Num(si as f64)),
                        ("reserved_pages", Json::Num(pages as f64)),
                        ("prompt_tokens", Json::Num(slot.prompt_len as f64)),
                    ],
                );
            }
        }
        Ok(())
    }

    /// Grow slot `si`'s block to `target` cached positions, first
    /// checking the growth stays inside the slot's admission
    /// reservation. The single home of the checked-growth path shared by
    /// prefill and decode; a failure message becomes that slot's
    /// `FinishReason::Error` retire (`verb` names the failing phase).
    fn grow_slot(&mut self, si: usize, target: usize, verb: &str) -> Result<(), String> {
        let Some(slot) = self.slots[si].as_mut() else {
            return Err("internal: growing an empty slot".to_string());
        };
        let needed_pages = self.pool.pages_for(target);
        if needed_pages > slot.reserved_pages {
            return Err(format!(
                "{verb} to {target} positions needs {needed_pages} pages, \
                 over the {} reserved at admission",
                slot.reserved_pages
            ));
        }
        slot.block.grow_to(target, &mut self.pool).map_err(|e| format!("{e:#}"))
    }

    /// Spend up to `prefill_chunk` prompt tokens across prefilling slots
    /// (slot order — deterministic), growing each block's page table
    /// ahead of the chunk. A growth the accounting cannot cover retires
    /// only that slot with a checked error.
    fn prefill_phase(&mut self) -> anyhow::Result<()> {
        let mut budget = self.prefill_chunk;
        let mut failed: Vec<(usize, String)> = Vec::new();
        for si in 0..self.slots.len() {
            if budget == 0 {
                break;
            }
            let Some(slot) = self.slots[si].as_ref() else { continue };
            let need = slot.prefill_remaining();
            if need == 0 {
                continue;
            }
            let c = need.min(budget);
            let (fed, target) = (slot.fed, slot.fed + c);
            if let Err(msg) = self.grow_slot(si, target, "prefill") {
                failed.push((si, msg));
                continue;
            }
            let Some(slot) = self.slots[si].as_mut() else { continue };
            prefill_extend(self.model, &mut slot.block, &slot.tokens[fed..target], fed)?;
            slot.fed = target;
            budget -= c;
            self.stats.prefill_tokens += c as u64;
            self.stats.prefill_chunks += 1;
            if let (Some(r), Some(slot)) = (&self.rec, self.slots[si].as_ref()) {
                r.point(
                    "prefill_chunk",
                    &slot.req.id,
                    vec![("tokens", Json::Num(c as f64)), ("fed", Json::Num(target as f64))],
                );
            }
        }
        for (si, msg) in failed {
            self.retire(si, FinishReason::Error, Some(msg))?;
        }
        Ok(())
    }

    /// Advance every decoding slot by one token in a single batched
    /// forward. Blocks are grown before the batch is built; a slot whose
    /// growth fails retires alone, the rest of the batch decodes exactly
    /// as it would have without it.
    fn decode_phase(&mut self) -> anyhow::Result<usize> {
        let mut failed: Vec<(usize, String)> = Vec::new();
        let mut active: Vec<usize> = Vec::new();
        for si in 0..self.slots.len() {
            let Some(slot) = self.slots[si].as_ref() else { continue };
            if slot.prefill_remaining() > 0 {
                continue; // still prefilling; this step's budget ran out
            }
            let target = slot.fed + 1;
            match self.grow_slot(si, target, "decode") {
                Ok(()) => active.push(si),
                Err(msg) => failed.push((si, msg)),
            }
        }
        for (si, msg) in failed {
            self.retire(si, FinishReason::Error, Some(msg))?;
        }
        if active.is_empty() {
            return Ok(0);
        }
        // Per-slot pending-token validation: an out-of-range id (corrupted
        // in flight, or injected by the failure tests) retires only its own
        // request; the rest of the batch decodes exactly as it would have
        // without it. `decode_step` re-checks the same bound, but by then a
        // failure is batch-fatal — this is the per-request gate.
        let vocab = self.model.spec.vocab;
        let mut batch = Vec::with_capacity(active.len());
        let mut feed = Vec::with_capacity(active.len());
        let mut pos = Vec::with_capacity(active.len());
        let mut invalid: Vec<(usize, String)> = Vec::new();
        for &si in &active {
            let Some(slot) = self.slots[si].as_ref() else { continue };
            match slot.tokens.get(slot.fed).copied() {
                Some(t) if usize::try_from(t).is_ok_and(|t| t < vocab) => {
                    batch.push(si);
                    feed.push(t);
                    pos.push(slot.fed);
                }
                Some(t) => invalid.push((si, format!("token id {t} outside vocab 0..{vocab}"))),
                None => invalid.push((
                    si,
                    format!(
                        "internal: feed index {} past the {}-token buffer",
                        slot.fed,
                        slot.tokens.len()
                    ),
                )),
            }
        }
        for (si, msg) in invalid {
            self.retire(si, FinishReason::Error, Some(msg))?;
        }
        if batch.is_empty() {
            return Ok(0);
        }
        let active = batch;
        let logits = {
            // gather the active blocks mutably, in slot order (disjoint
            // slots ⇒ disjoint borrows)
            let mut want = active.iter().peekable();
            let mut blocks: Vec<&mut KvBlock> = Vec::with_capacity(active.len());
            for (si, s) in self.slots.iter_mut().enumerate() {
                if want.peek() == Some(&&si) {
                    if let Some(s) = s.as_mut() {
                        blocks.push(&mut s.block);
                    }
                    want.next();
                }
            }
            decode_step(self.model, &mut blocks, &feed, &pos)?
        };
        self.stats.steps += 1;
        for (bi, &si) in active.iter().enumerate() {
            let row = logits.row(bi);
            let mut finish = None;
            {
                let Some(slot) = self.slots[si].as_mut() else { continue };
                let next = next_token(row, slot.req.temperature, &mut slot.rng) as i32;
                slot.fed += 1;
                if slot.stop_id == Some(next) {
                    finish = Some(FinishReason::Stop);
                } else {
                    slot.tokens.push(next);
                    if slot.tokens.len() - slot.prompt_len >= slot.req.max_tokens {
                        finish = Some(FinishReason::Length);
                    }
                }
            }
            self.stats.decoded_tokens += 1;
            if let Some(reason) = finish {
                self.retire(si, reason, None)?;
            }
        }
        Ok(active.len())
    }

    /// Retire slot `si`: build the response, tee it, return the pages and
    /// the reservation to the pool.
    fn retire(&mut self, si: usize, finish: FinishReason, error: Option<String>) -> Result<()> {
        let mut slot = self.slots[si].take().context("retiring an empty slot")?;
        slot.block.release(&mut self.pool);
        self.pool.release_reservation(slot.reserved_pages);
        let completion_tokens = slot.tokens.len() - slot.prompt_len;
        let latency_ms = self.clock.now_ms() - slot.submitted;
        if let Some(r) = &self.rec {
            r.end(
                "request",
                &slot.req.id,
                vec![
                    ("finish", Json::Str(finish.label().to_string())),
                    ("completion_tokens", Json::Num(completion_tokens as f64)),
                    ("latency_ms", Json::Num(latency_ms)),
                ],
            );
        }
        let resp = ServeResponse {
            id: slot.req.id.clone(),
            text: tokenizer::decode(&slot.tokens[slot.prompt_len..]),
            prompt_tokens: slot.prompt_len,
            completion_tokens,
            finish,
            latency_ms,
            error,
        };
        self.push_response(resp);
        Ok(())
    }

    fn push_response(&mut self, resp: ServeResponse) {
        self.stats.retired += 1;
        if let Some(tee) = &mut self.tee {
            if let Err(e) = tee.write(&resp) {
                crate::log_warn!("transcript tee failed: {e:#}");
            }
        }
        self.responses.push(resp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{repo_root, Presets};
    use crate::eval::generate::{generate, GenOptions};
    use crate::model::init::init_params;

    fn setup() -> (crate::config::ModelSpec, crate::model::params::ModelParams) {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap().clone();
        let params = init_params(&spec, 23);
        (spec, params)
    }

    fn req(id: &str, prompt: &str, max_tokens: usize, temperature: f64, seed: u64) -> ServeRequest {
        ServeRequest {
            id: id.into(),
            prompt: prompt.into(),
            max_tokens,
            temperature,
            seed,
            stop: None,
        }
    }

    #[test]
    fn greedy_single_request_matches_generate() {
        let (spec, params) = setup();
        let model = ServeModel::dense(&spec, &params).unwrap();
        let mut eng = Engine::new(&model, &EngineConfig::default()).unwrap();
        eng.submit(req("r1", "abc", 12, 0.0, 1)).unwrap();
        let out = eng.run().unwrap();
        assert_eq!(out.len(), 1);
        let want = generate(
            &spec,
            &params,
            "abc",
            &GenOptions { max_tokens: 12, temperature: 0.0, seed: 1 },
        );
        assert_eq!(out[0].text, want);
        assert_eq!(out[0].completion_tokens, 12);
        assert_eq!(out[0].finish, FinishReason::Length);
        assert!(eng.is_idle());
        assert_eq!(eng.free_slots(), 4);
        let (in_use, reserved, _) = eng.kv_pages();
        assert_eq!((in_use, reserved), (0, 0), "retire must release pages and reservations");
    }

    #[test]
    fn sampled_request_matches_generate_stream() {
        let (spec, params) = setup();
        let model = ServeModel::dense(&spec, &params).unwrap();
        let mut eng = Engine::new(&model, &EngineConfig::default()).unwrap();
        eng.submit(req("r1", "xy", 16, 1.2, 9)).unwrap();
        let out = eng.run().unwrap();
        let want = generate(
            &spec,
            &params,
            "xy",
            &GenOptions { max_tokens: 16, temperature: 1.2, seed: 9 },
        );
        assert_eq!(out[0].text, want, "seeded sampling must match eval::generate");
    }

    #[test]
    fn queue_overflow_and_context_overflow_are_rejected() {
        let (spec, params) = setup();
        let model = ServeModel::dense(&spec, &params).unwrap();
        let cfg = EngineConfig { max_batch: 1, queue_cap: 2, ..EngineConfig::default() };
        let mut eng = Engine::new(&model, &cfg).unwrap();
        assert!(eng.submit(req("e", "", 4, 0.0, 0)).is_err(), "empty prompt");
        assert!(eng.submit(req("z", "ab", 0, 0.0, 0)).is_err(), "zero budget");
        let too_long = eng.submit(req("l", "abcd", spec.seq, 0.0, 0)).unwrap_err().to_string();
        assert!(too_long.contains("context"), "{too_long}");
        eng.submit(req("a", "ab", 2, 0.0, 0)).unwrap();
        eng.submit(req("b", "ab", 2, 0.0, 0)).unwrap();
        assert!(eng.submit(req("c", "ab", 2, 0.0, 0)).is_err(), "queue full");
        assert!(!eng.submit_or_reject(req("d", "ab", 2, 0.0, 0)));
        let rejected: Vec<_> = eng
            .take_responses()
            .into_iter()
            .filter(|r| r.finish == FinishReason::Rejected)
            .collect();
        assert_eq!(rejected.len(), 1);
        assert!(rejected[0].error.as_ref().unwrap().contains("queue full"));
        assert_eq!(rejected[0].prompt_tokens, 2, "rejection reports the encoded length");
        // the two admitted requests still complete
        assert_eq!(eng.run().unwrap().len(), 2);
    }

    #[test]
    fn duplicate_ids_are_rejected_while_queued_or_active() {
        let (spec, params) = setup();
        let model = ServeModel::dense(&spec, &params).unwrap();
        let cfg = EngineConfig { max_batch: 1, queue_cap: 8, ..EngineConfig::default() };
        let mut eng = Engine::new(&model, &cfg).unwrap();
        eng.submit(req("dup", "ab", 4, 0.0, 0)).unwrap();
        // still queued: same id rejected
        let err = eng.submit(req("dup", "cd", 4, 0.0, 1)).unwrap_err().to_string();
        assert!(err.contains("duplicate"), "{err}");
        // active (admitted into the slot): still rejected
        eng.step().unwrap();
        assert_eq!(eng.active(), 1);
        assert!(!eng.submit_or_reject(req("dup", "cd", 4, 0.0, 1)));
        let resp = eng.take_responses();
        assert_eq!(resp.len(), 1);
        assert!(resp[0].error.as_ref().unwrap().contains("duplicate"));
        // after the holder retires the id is free again
        eng.run().unwrap();
        eng.submit(req("dup", "ef", 2, 0.0, 2)).unwrap();
        assert_eq!(eng.run().unwrap().len(), 1);
        let _ = spec;
    }

    #[test]
    fn continuous_batching_joins_waiting_requests() {
        let (spec, params) = setup();
        let model = ServeModel::dense(&spec, &params).unwrap();
        let cfg = EngineConfig { max_batch: 2, queue_cap: 16, ..EngineConfig::default() };
        let mut eng = Engine::new(&model, &cfg).unwrap();
        for i in 0..5 {
            eng.submit(req(&format!("r{i}"), "the ", 6, 0.0, i)).unwrap();
        }
        // two slots, five requests: the later ones join as earlier retire
        let mut seen_join = false;
        while !eng.is_idle() {
            let before = eng.active();
            eng.step().unwrap();
            if before > 0 && eng.active() > 0 && eng.queued() < 3 {
                seen_join = true;
            }
        }
        assert!(seen_join);
        let out = eng.take_responses();
        assert_eq!(out.len(), 5);
        let want = generate(
            &spec,
            &params,
            "the ",
            &GenOptions { max_tokens: 6, temperature: 0.0, seed: 0 },
        );
        for r in &out {
            assert_eq!(r.text, want, "{}: batch composition must not change output", r.id);
        }
        assert_eq!(eng.stats.retired, 5);
        assert_eq!(eng.stats.decoded_tokens, 30);
    }

    #[test]
    fn stop_token_retires_early() {
        let (spec, params) = setup();
        let model = ServeModel::dense(&spec, &params).unwrap();
        let mut eng = Engine::new(&model, &EngineConfig::default()).unwrap();
        // find what greedy emits first, then use it as the stop char
        let first = generate(
            &spec,
            &params,
            "abc",
            &GenOptions { max_tokens: 1, temperature: 0.0, seed: 0 },
        );
        let mut r = req("s", "abc", 10, 0.0, 0);
        r.stop = Some(first.clone());
        eng.submit(r).unwrap();
        let out = eng.run().unwrap();
        assert_eq!(out[0].finish, FinishReason::Stop);
        assert_eq!(out[0].completion_tokens, 0, "stop token is not emitted");
        assert!(out[0].text.is_empty());
    }

    #[test]
    fn page_backpressure_queues_until_pages_free() {
        let (spec, params) = setup();
        let model = ServeModel::dense(&spec, &params).unwrap();
        // budget for exactly one request's projection: 4 slots, but the
        // page accounting only admits one at a time
        let max_tokens = 8usize;
        let prompt = "abcdefgh"; // 8 tokens → projected 15 positions
        let probe = Engine::new(&model, &EngineConfig::default()).unwrap();
        let one = probe.pool.pages_for(Engine::projected_kv(8, max_tokens));
        let cfg = EngineConfig { kv_pages: Some(one), queue_cap: 8, ..EngineConfig::default() };
        let mut eng = Engine::new(&model, &cfg).unwrap();
        for i in 0..3 {
            eng.submit(req(&format!("r{i}"), prompt, max_tokens, 0.0, i)).unwrap();
        }
        eng.step().unwrap();
        assert_eq!(eng.active(), 1, "page budget admits exactly one");
        assert_eq!(eng.queued(), 2, "the rest queue — never rejected, never evicted");
        let mut out = eng.run().unwrap();
        out.sort_by(|a, b| a.id.cmp(&b.id));
        assert_eq!(out.len(), 3);
        let want = generate(
            &spec,
            &params,
            prompt,
            &GenOptions { max_tokens, temperature: 0.0, seed: 0 },
        );
        assert_eq!(out[0].text, want, "backpressure must not change the stream");
        for r in &out {
            assert_eq!(r.finish, FinishReason::Length, "{}", r.id);
        }
        // a request that can never fit is rejected up front, not queued
        let cfg = EngineConfig { kv_pages: Some(spec.layers), ..EngineConfig::default() };
        let mut tiny = Engine::new(&model, &cfg).unwrap();
        let err = tiny.submit(req("big", prompt, 40, 0.0, 0)).unwrap_err().to_string();
        assert!(err.contains("pages"), "{err}");
    }

    #[test]
    fn chunked_prefill_interleaves_with_decode() {
        let (spec, params) = setup();
        let model = ServeModel::dense(&spec, &params).unwrap();
        // short request decoding; long prompt joins and prefills in
        // 4-token chunks without stalling the short one
        let cfg = EngineConfig { max_batch: 2, prefill_chunk: 4, ..EngineConfig::default() };
        let mut eng = Engine::new(&model, &cfg).unwrap();
        eng.submit(req("short", "ab", 10, 0.0, 1)).unwrap();
        eng.step().unwrap();
        let long_prompt = "abcdefghijklmnopqrstuvwxyz"; // 26 tokens, 7 chunks of ≤4
        eng.submit(req("long", long_prompt, 6, 0.0, 2)).unwrap();
        let mut saw_interleave = false;
        while !eng.is_idle() {
            let decoded = eng.step().unwrap();
            let long_prefilling = eng
                .slots
                .iter()
                .flatten()
                .any(|s| s.req.id == "long" && s.prefill_remaining() > 0);
            if decoded > 0 && long_prefilling {
                saw_interleave = true;
            }
        }
        assert!(saw_interleave, "short stream must decode while the long prompt prefills");
        assert!(eng.stats.prefill_chunks > 2, "the long prompt must span several chunks");
        let mut out = eng.take_responses();
        out.sort_by(|a, b| a.id.cmp(&b.id));
        assert_eq!(out.len(), 2);
        let want_long = generate(
            &spec,
            &params,
            long_prompt,
            &GenOptions { max_tokens: 6, temperature: 0.0, seed: 2 },
        );
        let want_short = generate(
            &spec,
            &params,
            "ab",
            &GenOptions { max_tokens: 10, temperature: 0.0, seed: 1 },
        );
        assert_eq!(out[0].text, want_long, "chunked prefill must not change the stream");
        assert_eq!(out[1].text, want_short, "co-batched stream must be unaffected");
    }

    #[test]
    fn kv_exhaustion_retires_only_the_offending_slot() {
        let (spec, params) = setup();
        let model = ServeModel::dense(&spec, &params).unwrap();
        let cfg = EngineConfig { max_batch: 2, kv_page: 4, ..EngineConfig::default() };
        let mut eng = Engine::new(&model, &cfg).unwrap();
        // victim grows for 20 tokens; the survivor's whole projection
        // (7-token prompt + 5 tokens → 11 positions, 3 pages/layer) is
        // covered by pages it acquires within the first three steps
        eng.submit(req("victim", "ab", 20, 0.0, 1)).unwrap();
        eng.submit(req("survivor", "abcdefg", 5, 0.0, 2)).unwrap();
        for _ in 0..3 {
            eng.step().unwrap();
        }
        assert_eq!(eng.active(), 2);
        // injected accounting slip: freeze the budget at what is in use,
        // so the next page take — the victim crossing into its second
        // page — hits the checked exhaustion error
        let (in_use, _, _) = eng.kv_pages();
        eng.debug_set_page_budget(in_use);
        let mut out = eng.run().unwrap();
        out.sort_by(|a, b| a.id.cmp(&b.id));
        assert_eq!(out.len(), 2);
        let (survivor, victim) = (&out[0], &out[1]);
        assert_eq!(victim.id, "victim");
        assert_eq!(victim.finish, FinishReason::Error, "{:?}", victim.error);
        assert!(victim.error.as_ref().unwrap().contains("exhausted"), "{:?}", victim.error);
        assert!(victim.completion_tokens < 20, "the victim retired mid-stream");
        // the partial stream up to the failure is still the solo stream
        let solo_victim = generate(
            &spec,
            &params,
            "ab",
            &GenOptions { max_tokens: 20, temperature: 0.0, seed: 1 },
        );
        assert!(solo_victim.starts_with(&victim.text), "partial text is a solo-run prefix");
        // the survivor is untouched: finishes its budget, byte-identical
        assert_eq!(survivor.id, "survivor");
        assert_eq!(survivor.finish, FinishReason::Length);
        let solo = generate(
            &spec,
            &params,
            "abcdefg",
            &GenOptions { max_tokens: 5, temperature: 0.0, seed: 2 },
        );
        assert_eq!(survivor.text, solo, "survivor must be byte-identical to its solo run");
    }

    #[test]
    fn poisoned_token_retires_only_the_offending_slot() {
        let (spec, params) = setup();
        let model = ServeModel::dense(&spec, &params).unwrap();
        let cfg = EngineConfig { max_batch: 2, ..EngineConfig::default() };
        let mut eng = Engine::new(&model, &cfg).unwrap();
        eng.submit(req("survivor", "abcdefg", 8, 0.0, 2)).unwrap();
        eng.submit(req("victim", "ab", 12, 0.0, 1)).unwrap();
        // prefill both, then decode a few tokens co-batched
        for _ in 0..4 {
            eng.step().unwrap();
        }
        assert_eq!(eng.active(), 2, "both streams must be decoding together");
        // injected corruption: the victim's pending feed token becomes an
        // out-of-vocab id, as if mangled in flight
        assert!(eng.debug_poison_pending_token("victim"), "victim must have a pending token");
        let mut out = eng.run().unwrap();
        out.sort_by(|a, b| a.id.cmp(&b.id));
        assert_eq!(out.len(), 2);
        let (survivor, victim) = (&out[0], &out[1]);
        assert_eq!(victim.id, "victim");
        assert_eq!(victim.finish, FinishReason::Error, "{:?}", victim.error);
        assert!(victim.error.as_ref().unwrap().contains("vocab"), "{:?}", victim.error);
        assert!(victim.completion_tokens < 12, "the victim retired mid-stream");
        // everything the victim streamed before the corruption is still the
        // solo stream (the final char is the clamped render of the poisoned
        // id itself)
        let solo_victim = generate(
            &spec,
            &params,
            "ab",
            &GenOptions { max_tokens: 12, temperature: 0.0, seed: 1 },
        );
        let clean = &victim.text[..victim.text.len() - 1];
        assert!(solo_victim.starts_with(clean), "pre-poison text is a solo-run prefix");
        // the co-batched survivor is untouched: full budget, byte-identical
        assert_eq!(survivor.id, "survivor");
        assert_eq!(survivor.finish, FinishReason::Length);
        let solo = generate(
            &spec,
            &params,
            "abcdefg",
            &GenOptions { max_tokens: 8, temperature: 0.0, seed: 2 },
        );
        assert_eq!(survivor.text, solo, "survivor must be byte-identical to its solo run");
    }
}

//! The continuous-batching serving engine.
//!
//! State machine per request:
//!
//! ```text
//!   submit ──(admission control)──▶ queued ──(free slot)──▶ prefill
//!       ▲                              │                      │
//!       └── rejected (error response)  └── aborted            ▼
//!                                                          decoding ──▶ retired
//!                                                     (length | stop | abort)
//! ```
//!
//! Scheduling is *continuous*: every [`Engine::step`] advances all active
//! slots by one token in a single batched forward (`batch::decode_step`),
//! then retires finished slots and immediately admits queued requests into
//! the freed slots — new arrivals join the batch mid-flight instead of
//! waiting for a generation boundary (join-on-arrival / retire-on-EOS).
//!
//! Determinism contract: a request's token stream depends only on the
//! model weights, its own prompt/seed/temperature, and the kernel
//! determinism guarantees of `tensor::par` — never on batch composition,
//! admission order, worker thread count, or other requests' lifecycles
//! (including mid-stream aborts). `rust/tests/serve_parity.rs` and the
//! abort case in `rust/tests/failure_injection.rs` pin this down against
//! `eval::generate`.

use std::collections::{BTreeSet, VecDeque};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::data::tokenizer;
use crate::eval::generate::next_token;
use crate::util::Pcg64;

use super::batch::{decode_step, prefill_prompt, ServeModel};
use super::kv::KvPool;
use super::request::{FinishReason, ServeRequest, ServeResponse, TranscriptTee};

/// Engine sizing and output knobs.
pub struct EngineConfig {
    /// Concurrent decode slots (the continuous-batch width).
    pub max_batch: usize,
    /// Waiting-line bound; submissions beyond it are rejected.
    pub queue_cap: usize,
    /// Tee every retired request to this JSONL file.
    pub transcript: Option<std::path::PathBuf>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { max_batch: 4, queue_cap: 64, transcript: None }
    }
}

/// One in-flight request: its token tail, KV block, and sampling state.
struct Slot {
    req: ServeRequest,
    /// Prompt + generated token ids.
    tokens: Vec<i32>,
    prompt_len: usize,
    /// Tokens already fed to the model (== KV cache length). The pending
    /// token `tokens[fed]` is fed next; its logits sample `tokens[fed+1]`.
    fed: usize,
    block: usize,
    rng: Pcg64,
    stop_id: Option<i32>,
    submitted: Instant,
}

/// Aggregate engine counters (the serving metrics source).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    /// Batched decode steps executed.
    pub steps: u64,
    /// Tokens decoded across all requests (prefill excluded).
    pub decoded_tokens: u64,
    /// Prompt tokens prefilled across all requests.
    pub prefill_tokens: u64,
    /// Requests retired (any finish reason, rejections included).
    pub retired: u64,
}

/// The continuous-batching engine over a borrowed [`ServeModel`] (the
/// model is shared so several engines — e.g. serve-bench's batch-width
/// sweeps — reuse one weight resolution / CSR compression).
pub struct Engine<'m> {
    model: &'m ServeModel<'m>,
    cfg_queue_cap: usize,
    pool: KvPool,
    slots: Vec<Option<Slot>>,
    queue: VecDeque<(ServeRequest, Instant)>,
    aborts: BTreeSet<String>,
    responses: Vec<ServeResponse>,
    tee: Option<TranscriptTee>,
    pub stats: EngineStats,
}

impl<'m> Engine<'m> {
    pub fn new(model: &'m ServeModel<'m>, cfg: &EngineConfig) -> Result<Engine<'m>> {
        if cfg.max_batch == 0 {
            bail!("max_batch must be at least 1");
        }
        if cfg.queue_cap == 0 {
            bail!("queue_cap must be at least 1");
        }
        let pool = KvPool::new(&model.spec, cfg.max_batch);
        let tee = match &cfg.transcript {
            Some(p) => Some(TranscriptTee::create(p)?),
            None => None,
        };
        Ok(Engine {
            model,
            cfg_queue_cap: cfg.queue_cap,
            pool,
            slots: (0..cfg.max_batch).map(|_| None).collect(),
            queue: VecDeque::new(),
            aborts: BTreeSet::new(),
            responses: Vec::new(),
            tee,
            stats: EngineStats::default(),
        })
    }

    /// Admission control: validate and enqueue. Errors name the request
    /// and the violated bound; nothing is partially admitted.
    pub fn submit(&mut self, req: ServeRequest) -> Result<()> {
        let spec = &self.model.spec;
        let prompt = tokenizer::encode(&req.prompt);
        if prompt.is_empty() {
            bail!("request '{}': empty prompt", req.id);
        }
        if req.max_tokens == 0 {
            bail!("request '{}': max_tokens must be at least 1", req.id);
        }
        if prompt.len() + req.max_tokens > spec.seq {
            bail!(
                "request '{}': prompt ({}) + max_tokens ({}) exceeds the model context ({})",
                req.id,
                prompt.len(),
                req.max_tokens,
                spec.seq
            );
        }
        if self.queue.len() >= self.cfg_queue_cap {
            bail!("request '{}': queue full ({} waiting)", req.id, self.queue.len());
        }
        self.queue.push_back((req, Instant::now()));
        Ok(())
    }

    /// [`Engine::submit`], turning a rejection into an error response so a
    /// JSONL front end keeps serving. Returns whether it was admitted.
    pub fn submit_or_reject(&mut self, req: ServeRequest) -> bool {
        let id = req.id.clone();
        let prompt_tokens = tokenizer::encode(&req.prompt).len();
        match self.submit(req) {
            Ok(()) => true,
            Err(e) => {
                self.push_response(ServeResponse {
                    id,
                    text: String::new(),
                    prompt_tokens,
                    completion_tokens: 0,
                    finish: FinishReason::Rejected,
                    latency_ms: 0.0,
                    error: Some(format!("{e:#}")),
                });
                false
            }
        }
    }

    /// Mark a request for mid-stream abort; it retires (with its partial
    /// text) at the start of the next step, freeing its slot and KV block.
    pub fn abort(&mut self, id: &str) {
        self.aborts.insert(id.to_string());
    }

    /// Requests waiting for a slot.
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    /// Requests currently decoding.
    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    /// KV blocks available for admission.
    pub fn free_slots(&self) -> usize {
        self.pool.free_count()
    }

    /// KV bytes preallocated by the pool.
    pub fn kv_bytes(&self) -> usize {
        self.pool.bytes()
    }

    /// True when no request is queued or in flight.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active() == 0
    }

    /// Drain completed responses (retire order).
    pub fn take_responses(&mut self) -> Vec<ServeResponse> {
        std::mem::take(&mut self.responses)
    }

    /// Advance every active slot by one token (admitting queued requests
    /// first). Returns the number of tokens decoded this step — 0 means
    /// the engine is idle.
    pub fn step(&mut self) -> Result<usize> {
        self.apply_aborts()?;
        self.admit()?;
        let active: Vec<usize> =
            (0..self.slots.len()).filter(|&i| self.slots[i].is_some()).collect();
        if active.is_empty() {
            return Ok(0);
        }
        let mut feed = Vec::with_capacity(active.len());
        let mut pos = Vec::with_capacity(active.len());
        let mut block_ids = Vec::with_capacity(active.len());
        for &si in &active {
            let slot = self.slots[si].as_ref().expect("active slot");
            feed.push(slot.tokens[slot.fed]);
            pos.push(slot.fed);
            block_ids.push(slot.block);
        }
        let logits = {
            let mut blocks = self.pool.blocks_mut(&block_ids);
            decode_step(self.model, &mut blocks, &feed, &pos)
        };
        self.stats.steps += 1;
        for (bi, &si) in active.iter().enumerate() {
            let row = logits.row(bi);
            let mut finish = None;
            {
                let slot = self.slots[si].as_mut().expect("active slot");
                let next = next_token(row, slot.req.temperature, &mut slot.rng) as i32;
                slot.fed += 1;
                if slot.stop_id == Some(next) {
                    finish = Some(FinishReason::Stop);
                } else {
                    slot.tokens.push(next);
                    if slot.tokens.len() - slot.prompt_len >= slot.req.max_tokens {
                        finish = Some(FinishReason::Length);
                    }
                }
            }
            self.stats.decoded_tokens += 1;
            if let Some(reason) = finish {
                self.retire(si, reason)?;
            }
        }
        Ok(active.len())
    }

    /// Run until idle; drain the responses.
    pub fn run(&mut self) -> Result<Vec<ServeResponse>> {
        while !self.is_idle() {
            self.step()?;
        }
        Ok(self.take_responses())
    }

    /// Retire aborted requests, both queued and mid-stream.
    fn apply_aborts(&mut self) -> Result<()> {
        if self.aborts.is_empty() {
            return Ok(());
        }
        // queued: respond without ever admitting
        let aborts = std::mem::take(&mut self.aborts);
        let mut remaining = VecDeque::new();
        for (req, t) in std::mem::take(&mut self.queue) {
            if aborts.contains(&req.id) {
                let prompt_tokens = tokenizer::encode(&req.prompt).len();
                self.push_response(ServeResponse {
                    id: req.id,
                    text: String::new(),
                    prompt_tokens,
                    completion_tokens: 0,
                    finish: FinishReason::Aborted,
                    latency_ms: t.elapsed().as_secs_f64() * 1e3,
                    error: None,
                });
            } else {
                remaining.push_back((req, t));
            }
        }
        self.queue = remaining;
        // mid-stream: retire with partial text, freeing slot + KV block
        for si in 0..self.slots.len() {
            let hit = self.slots[si].as_ref().is_some_and(|s| aborts.contains(&s.req.id));
            if hit {
                self.retire(si, FinishReason::Aborted)?;
            }
        }
        Ok(())
    }

    /// Join-on-arrival: move queued requests into free slots and prefill
    /// their prompts (all but the last prompt token; the last is the first
    /// decode step's input, mirroring `eval::generate`'s first iteration).
    fn admit(&mut self) -> Result<()> {
        while !self.queue.is_empty() && self.pool.free_count() > 0 {
            let si = self
                .slots
                .iter()
                .position(|s| s.is_none())
                .context("free KV block without a free slot")?;
            let (req, submitted) = self.queue.pop_front().expect("queue checked non-empty");
            let block = self.pool.alloc().context("free_count checked > 0")?;
            let tokens = tokenizer::encode(&req.prompt);
            let prompt_len = tokens.len();
            // one position-batched pass over the prompt (minus the last
            // token, which is the first decode step's input)
            prefill_prompt(self.model, self.pool.block_mut(block), &tokens[..prompt_len - 1]);
            self.stats.prefill_tokens += (prompt_len - 1) as u64;
            let rng = Pcg64::new(req.seed, 61);
            let stop_id = req
                .stop
                .as_ref()
                .and_then(|s| tokenizer::encode(s).first().copied());
            self.slots[si] = Some(Slot {
                req,
                tokens,
                prompt_len,
                fed: prompt_len - 1,
                block,
                rng,
                stop_id,
                submitted,
            });
        }
        Ok(())
    }

    /// Retire slot `si`: build the response, tee it, free the KV block.
    fn retire(&mut self, si: usize, finish: FinishReason) -> Result<()> {
        let slot = self.slots[si].take().context("retiring an empty slot")?;
        self.pool.free(slot.block);
        let resp = ServeResponse {
            id: slot.req.id.clone(),
            text: tokenizer::decode(&slot.tokens[slot.prompt_len..]),
            prompt_tokens: slot.prompt_len,
            completion_tokens: slot.tokens.len() - slot.prompt_len,
            finish,
            latency_ms: slot.submitted.elapsed().as_secs_f64() * 1e3,
            error: None,
        };
        self.push_response(resp);
        Ok(())
    }

    fn push_response(&mut self, resp: ServeResponse) {
        self.stats.retired += 1;
        if let Some(tee) = &mut self.tee {
            if let Err(e) = tee.write(&resp) {
                crate::log_warn!("transcript tee failed: {e:#}");
            }
        }
        self.responses.push(resp);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{repo_root, Presets};
    use crate::eval::generate::{generate, GenOptions};
    use crate::model::init::init_params;

    fn setup() -> (crate::config::ModelSpec, crate::model::params::ModelParams) {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap().clone();
        let params = init_params(&spec, 23);
        (spec, params)
    }

    fn req(id: &str, prompt: &str, max_tokens: usize, temperature: f64, seed: u64) -> ServeRequest {
        ServeRequest {
            id: id.into(),
            prompt: prompt.into(),
            max_tokens,
            temperature,
            seed,
            stop: None,
        }
    }

    #[test]
    fn greedy_single_request_matches_generate() {
        let (spec, params) = setup();
        let model = ServeModel::dense(&spec, &params).unwrap();
        let mut eng = Engine::new(&model, &EngineConfig::default()).unwrap();
        eng.submit(req("r1", "abc", 12, 0.0, 1)).unwrap();
        let out = eng.run().unwrap();
        assert_eq!(out.len(), 1);
        let want = generate(
            &spec,
            &params,
            "abc",
            &GenOptions { max_tokens: 12, temperature: 0.0, seed: 1 },
        );
        assert_eq!(out[0].text, want);
        assert_eq!(out[0].completion_tokens, 12);
        assert_eq!(out[0].finish, FinishReason::Length);
        assert!(eng.is_idle());
        assert_eq!(eng.free_slots(), 4);
    }

    #[test]
    fn sampled_request_matches_generate_stream() {
        let (spec, params) = setup();
        let model = ServeModel::dense(&spec, &params).unwrap();
        let mut eng = Engine::new(&model, &EngineConfig::default()).unwrap();
        eng.submit(req("r1", "xy", 16, 1.2, 9)).unwrap();
        let out = eng.run().unwrap();
        let want = generate(
            &spec,
            &params,
            "xy",
            &GenOptions { max_tokens: 16, temperature: 1.2, seed: 9 },
        );
        assert_eq!(out[0].text, want, "seeded sampling must match eval::generate");
    }

    #[test]
    fn queue_overflow_and_context_overflow_are_rejected() {
        let (spec, params) = setup();
        let model = ServeModel::dense(&spec, &params).unwrap();
        let cfg = EngineConfig { max_batch: 1, queue_cap: 2, transcript: None };
        let mut eng = Engine::new(&model, &cfg).unwrap();
        assert!(eng.submit(req("e", "", 4, 0.0, 0)).is_err(), "empty prompt");
        assert!(eng.submit(req("z", "ab", 0, 0.0, 0)).is_err(), "zero budget");
        let too_long = eng.submit(req("l", "abcd", spec.seq, 0.0, 0)).unwrap_err().to_string();
        assert!(too_long.contains("context"), "{too_long}");
        eng.submit(req("a", "ab", 2, 0.0, 0)).unwrap();
        eng.submit(req("b", "ab", 2, 0.0, 0)).unwrap();
        assert!(eng.submit(req("c", "ab", 2, 0.0, 0)).is_err(), "queue full");
        assert!(!eng.submit_or_reject(req("d", "ab", 2, 0.0, 0)));
        let rejected: Vec<_> = eng
            .take_responses()
            .into_iter()
            .filter(|r| r.finish == FinishReason::Rejected)
            .collect();
        assert_eq!(rejected.len(), 1);
        assert!(rejected[0].error.as_ref().unwrap().contains("queue full"));
        // the two admitted requests still complete
        assert_eq!(eng.run().unwrap().len(), 2);
    }

    #[test]
    fn continuous_batching_joins_waiting_requests() {
        let (spec, params) = setup();
        let model = ServeModel::dense(&spec, &params).unwrap();
        let cfg = EngineConfig { max_batch: 2, queue_cap: 16, transcript: None };
        let mut eng = Engine::new(&model, &cfg).unwrap();
        for i in 0..5 {
            eng.submit(req(&format!("r{i}"), "the ", 6, 0.0, i)).unwrap();
        }
        // two slots, five requests: the later ones join as earlier retire
        let mut seen_join = false;
        while !eng.is_idle() {
            let before = eng.active();
            eng.step().unwrap();
            if before > 0 && eng.active() > 0 && eng.queued() < 3 {
                seen_join = true;
            }
        }
        assert!(seen_join);
        let out = eng.take_responses();
        assert_eq!(out.len(), 5);
        let want = generate(
            &spec,
            &params,
            "the ",
            &GenOptions { max_tokens: 6, temperature: 0.0, seed: 0 },
        );
        for r in &out {
            assert_eq!(r.text, want, "{}: batch composition must not change output", r.id);
        }
        assert_eq!(eng.stats.retired, 5);
        assert_eq!(eng.stats.decoded_tokens, 30);
    }

    #[test]
    fn stop_token_retires_early() {
        let (spec, params) = setup();
        let model = ServeModel::dense(&spec, &params).unwrap();
        let mut eng = Engine::new(&model, &EngineConfig::default()).unwrap();
        // find what greedy emits first, then use it as the stop char
        let first = generate(
            &spec,
            &params,
            "abc",
            &GenOptions { max_tokens: 1, temperature: 0.0, seed: 0 },
        );
        let mut r = req("s", "abc", 10, 0.0, 0);
        r.stop = Some(first.clone());
        eng.submit(r).unwrap();
        let out = eng.run().unwrap();
        assert_eq!(out[0].finish, FinishReason::Stop);
        assert_eq!(out[0].completion_tokens, 0, "stop token is not emitted");
        assert!(out[0].text.is_empty());
    }
}

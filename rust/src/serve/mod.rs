//! Serving: the payoff side of pruning, end to end.
//!
//! The pruner's whole motivation is cheaper inference; this subsystem is
//! where the pruned artifact becomes the hot path. It turns the
//! measure-only evaluation stack into a serving engine:
//!
//! * [`kv`] — paged per-request KV state: K/V storage is fixed-size
//!   position pages handed out on demand by a budgeted pool; each slot
//!   holds a block table (one `PagedKvLayer` per decoder layer) instead
//!   of a full-context buffer, admission *reserves* a request's
//!   projected page need (eviction-free deterministic backpressure) and
//!   buffers are recycled, so steady-state serving allocates nothing and
//!   resident KV bytes track actual request lengths.
//! * [`batch`] — the batched incremental decode step: every active slot
//!   advances one token per model forward, O(1) layer passes per token
//!   instead of the O(seq) full recompute in `eval::generate`. Pruned
//!   operators run through the parallel compressed kernels when serving
//!   sparse — CSR (`tensor::kernels::csr_matmul_t`) or packed n:m
//!   (`tensor::kernels::nm_matmul_t`), chosen per operator by
//!   `config::SparseFormat`.
//! * [`engine`] — continuous batching with chunked prefill: page-
//!   accounted admission control, a bounded request queue, a bounded
//!   prefill-token budget per step (long prompts warm up chunk by chunk,
//!   interleaved with the decode batch, instead of stalling it),
//!   join-on-arrival/retire-on-EOS scheduling, mid-stream abort, and
//!   per-request seeded sampling identical to `eval::generate`.
//! * [`request`] — the typed request/response pair, the JSONL wire codec
//!   behind the `serve` CLI command, and the transcript tee.
//! * [`net`] — the TCP front-end (`serve --listen`): bounded-line framing,
//!   one reader/writer thread pair per connection, a single dispatch loop
//!   owning the engine, idle/slowloris timeouts, an event-log tee, and
//!   offline replay of captured sessions.
//! * [`bench`] — the `serve-bench` core: tokens/s, p50/p99 latency and
//!   dense-vs-sparse speedups, with greedy outputs parity-checked against
//!   `eval::generate`; plus the artifact path (load time, on-disk and
//!   resident bytes vs the dense checkpoint), the paged axis
//!   (resident KV bytes vs the monolithic preallocation, prefill-stall
//!   p99 chunked vs unchunked — BENCH_paged.json), and the kernel axis
//!   (tokens/s, resident weight bytes and effective GB/s per kernel
//!   variant × quantization cell — BENCH_kernel.json).
//!
//! Compressed weights arrive either by compressing a dense checkpoint at
//! startup or — the production path — by loading a sparse artifact
//! (`ser::artifact`): `ServeModel` owns the `sparse::compile` result, so
//! an artifact-served process holds exactly one copy of each pruned
//! weight, the compressed one.
//!
//! Determinism contract (pinned by `rust/tests/serve_parity.rs` and
//! `rust/tests/paged_kv_parity.rs`): a request's output depends only on
//! the weights and its own prompt/seed/temperature — not on batch
//! composition, admission order, KV page size or page assignment,
//! prefill chunk boundaries, kernel thread count, or other requests
//! (including aborts and single-slot KV failures).

pub mod batch;
pub mod bench;
pub mod engine;
pub mod kv;
pub mod net;
pub mod request;

pub use batch::ServeModel;
pub use bench::{
    measure_sparse_format, run_artifact_bench, run_kernel_bench, run_net_bench, run_paged_bench,
    run_serve_bench, ArtifactBenchReport, BenchObs, FormatStats, KernelBenchReport, KernelBenchRow,
    NetBenchConfig, NetBenchReport, PagedBenchReport, ServeBenchConfig, ServeBenchReport,
};
pub use engine::{Engine, EngineConfig, EngineStats};
pub use net::{NetConfig, NetReport, NetServer};
pub use kv::{KvBlock, KvPage, KvPool, PagedKvLayer};
pub use request::{FinishReason, ServeRequest, ServeResponse};

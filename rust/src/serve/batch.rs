//! Batched incremental decode: one model forward step that advances every
//! active request slot by a single token.
//!
//! The math is the per-row mirror of `model::forward::layer_forward` —
//! norms and projections act on a [b, d] stack where row i belongs to slot
//! i, RoPE is applied per row at the slot's own position, and attention
//! runs per slot against its KV cache via `model::forward::attend_one`.
//! Because every operation in the substrate is row-independent with a
//! fixed per-row accumulation order, a slot's logits are bitwise identical
//! whether it decodes alone, inside any batch composition, or through the
//! full-recompute `eval::generate` path — the determinism contract the
//! serving tests pin down.
//!
//! Weights come from one of two sources, resolved once at construction:
//! * **Dense** — a borrowed `ModelParams` with per-layer bare-name maps
//!   (no per-token name formatting).
//! * **Compiled** — a `sparse::compile::CompiledLayers`, owned (artifact
//!   load: the process holds exactly one copy of each pruned weight, the
//!   compressed one) or borrowed (bench sweeps sharing one compression).
//!
//! Construction validates the full parameter set against the spec and
//! returns checked errors for malformed checkpoints; the decode hot path
//! then reads through checked lookups whose failure surfaces as a
//! `Result` the engine turns into a per-request retirement
//! (`FinishReason::Error`) — never a process panic that would kill the
//! co-batched streams.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Context, Result};

use crate::config::{FamilyKind, ModelSpec, QuantMode, SparseFormat, Sparsity};
use crate::model::forward;
use crate::model::params::ModelParams;
use crate::model::spec::{layer_param_specs, model_param_specs, param_count};
use crate::sparse::CompiledLayers;
use crate::tensor::{kernels, par, Tensor};

use super::kv::KvBlock;

/// Weights prepared for serving; see the module docs.
pub struct ServeModel<'p> {
    pub spec: ModelSpec,
    weights: Weights<'p>,
}

enum Weights<'p> {
    Dense {
        params: &'p ModelParams,
        /// Per-layer bare-name → tensor map in capture order.
        layers: Vec<BTreeMap<String, &'p Tensor>>,
    },
    Compiled(CompiledRef<'p>),
}

/// Own-or-borrow handle on a compiled model.
enum CompiledRef<'p> {
    Owned(Box<CompiledLayers>),
    Borrowed(&'p CompiledLayers),
}

impl CompiledRef<'_> {
    fn get(&self) -> &CompiledLayers {
        match self {
            CompiledRef::Owned(c) => c,
            CompiledRef::Borrowed(c) => c,
        }
    }
}

/// Resolve every layer parameter once, with checked errors (a malformed
/// checkpoint fails here, at construction, not mid-decode).
fn resolve_layers<'p>(
    spec: &ModelSpec,
    params: &'p ModelParams,
) -> Result<Vec<BTreeMap<String, &'p Tensor>>> {
    let specs = layer_param_specs(spec, None);
    (0..spec.layers)
        .map(|li| {
            specs
                .iter()
                .map(|sp| {
                    let name = format!("l{li}.{}", sp.name);
                    let t = params
                        .req(&name)
                        .with_context(|| format!("serving {}: missing layer param", spec.name()))?;
                    if t.shape() != sp.shape.as_slice() {
                        bail!(
                            "serving {}: param '{name}' has shape {:?}, expected {:?}",
                            spec.name(),
                            t.shape(),
                            sp.shape
                        );
                    }
                    Ok((sp.name.clone(), t))
                })
                .collect()
        })
        .collect()
}

impl<'p> ServeModel<'p> {
    /// Serve the dense weights as-is. Fails (instead of panicking later)
    /// when `params` does not hold every parameter of `spec` at the
    /// spec's shape — model-level params (embed, pos, final norm) are
    /// derived from `model_param_specs`, the same source of truth
    /// `CompiledLayers::validate` uses.
    pub fn dense(spec: &ModelSpec, params: &'p ModelParams) -> Result<ServeModel<'p>> {
        let layers = resolve_layers(spec, params)?;
        for gs in model_param_specs(spec).iter().filter(|s| !s.name.contains('.')) {
            let t = params
                .req(&gs.name)
                .with_context(|| format!("serving {}: missing model param", spec.name()))?;
            if t.shape() != gs.shape.as_slice() {
                bail!(
                    "serving {}: param '{}' has shape {:?}, expected {:?}",
                    spec.name(),
                    gs.name,
                    t.shape(),
                    gs.shape
                );
            }
        }
        Ok(ServeModel { spec: spec.clone(), weights: Weights::Dense { params, layers } })
    }

    /// Compress every pruned operator to CSR and serve those through the
    /// sparse decode kernels (norms/embeddings/attention stay dense).
    pub fn sparse(spec: &ModelSpec, params: &'p ModelParams) -> Result<ServeModel<'p>> {
        ServeModel::sparse_as(spec, params, SparseFormat::Csr, None)
    }

    /// Compress every pruned operator with an explicit format
    /// (`Csr` | `Nm` | per-operator `Auto`) via the shared
    /// `sparse::compile` pass and serve through the matching decode
    /// kernels. `sp` is the sparsity pattern hint the `Nm` (required) and
    /// `Auto` formats check weights against.
    pub fn sparse_as(
        spec: &ModelSpec,
        params: &ModelParams,
        format: SparseFormat,
        sp: Option<Sparsity>,
    ) -> Result<ServeModel<'p>> {
        let compiled = CompiledLayers::compress(spec, params, format, sp)?;
        Ok(ServeModel::from_compiled(compiled))
    }

    /// Serve an owned compiled model — the artifact path: the compressed
    /// operators and residual dense params here are the *only* copy of
    /// the weights the process holds.
    pub fn from_compiled(compiled: CompiledLayers) -> ServeModel<'static> {
        ServeModel {
            spec: compiled.spec.clone(),
            weights: Weights::Compiled(CompiledRef::Owned(Box::new(compiled))),
        }
    }

    /// Serve a borrowed compiled model (bench sweeps share one
    /// compression or one artifact load across engines).
    pub fn from_compiled_ref(compiled: &'p CompiledLayers) -> ServeModel<'p> {
        ServeModel {
            spec: compiled.spec.clone(),
            weights: Weights::Compiled(CompiledRef::Borrowed(compiled)),
        }
    }

    /// The compiled weights, when serving compressed.
    pub fn compiled(&self) -> Option<&CompiledLayers> {
        match &self.weights {
            Weights::Dense { .. } => None,
            Weights::Compiled(c) => Some(c.get()),
        }
    }

    pub fn is_sparse(&self) -> bool {
        self.compiled().is_some()
    }

    /// nnz fraction across the compressed operators (`None` for dense
    /// serving).
    pub fn density(&self) -> Option<f64> {
        self.compiled().map(|c| c.density())
    }

    /// Compressed bytes across the compressed operators (`None` for dense
    /// serving) — what the serve-bench storage column reports.
    pub fn storage_bytes(&self) -> Option<usize> {
        self.compiled().map(|c| c.storage_bytes())
    }

    /// Compressed vs dense bytes over the compressed operators.
    pub fn storage_ratio(&self) -> Option<f64> {
        self.compiled().map(|c| c.storage_ratio())
    }

    /// Weight bytes this model actually holds resident: the full dense
    /// parameter set, or — compiled — the compressed operators plus the
    /// residual dense params (the artifact memory-conservation number).
    pub fn resident_weight_bytes(&self) -> usize {
        match &self.weights {
            Weights::Dense { .. } => 4 * param_count(&self.spec),
            Weights::Compiled(c) => c.get().resident_bytes(),
        }
    }

    /// "dense", "csr", "nm", or "csr+nm" (mixed `Auto` dispatch).
    pub fn format_label(&self) -> &'static str {
        match self.compiled() {
            None => "dense",
            Some(c) => c.format_label(),
        }
    }

    /// Value quantization of the compiled operators (`None` for dense
    /// serving — dense weights are always f32).
    pub fn quant(&self) -> QuantMode {
        match self.compiled() {
            None => QuantMode::None,
            Some(c) => c.quant,
        }
    }

    /// Model-level residual tensor; existence is validated at
    /// construction, so a miss here is an internal invariant violation —
    /// reported as a checked error so the engine retires the request
    /// instead of the process aborting mid-batch.
    fn global(&self, name: &str) -> Result<&Tensor> {
        match &self.weights {
            Weights::Dense { params, .. } => params.get(name),
            Weights::Compiled(c) => c.get().global(name),
        }
        .ok_or_else(|| anyhow::anyhow!("internal: model param '{name}' missing post-validation"))
    }

    fn lp(&self, layer: usize, name: &str) -> Result<&Tensor> {
        match &self.weights {
            Weights::Dense { layers, .. } => layers.get(layer).and_then(|m| m.get(name)).copied(),
            Weights::Compiled(c) => c.get().residual_tensor(layer, name),
        }
        .ok_or_else(|| {
            anyhow::anyhow!("internal: layer {layer} param '{name}' missing post-validation")
        })
    }

    /// X @ Wᵀ through the compressed operator when serving compiled, the
    /// skinny dense kernel otherwise (all parallel over weight rows — the
    /// batch dimension is 1–8 at decode time). Same contract as the
    /// `linop` in `model::forward`: the dense kernel is bitwise equal to
    /// `matmul_nt`; CSR and packed n:m are value-equal (skipped zeros and
    /// padded ±0.0 terms cannot change a sum's value).
    fn linop(&self, layer: usize, name: &str, x: &Tensor) -> Result<Tensor> {
        Ok(match &self.weights {
            Weights::Dense { .. } => kernels::matmul_nt_skinny(x, self.lp(layer, name)?),
            Weights::Compiled(c) => c
                .get()
                .op(layer, name)
                .ok_or_else(|| {
                    anyhow::anyhow!("internal: operator l{layer}.{name} missing post-validation")
                })?
                .matmul_t_par(x),
        })
    }

    /// Final pre-head norm over a [b, d] stack (shared family dispatch:
    /// `model::forward::try_final_norm_with`).
    fn final_norm(&self, x: &Tensor) -> Result<Tensor> {
        forward::try_final_norm_with(&self.spec, |n| self.global(n), x)
    }

    /// Embedding row for token `tok`, bounds-checked: an out-of-range id
    /// (client-supplied or corrupted in flight) is a per-request error,
    /// never a process panic that would kill co-batched streams.
    fn embed_row<'e>(&self, embed: &'e Tensor, tok: i32) -> Result<&'e [f32]> {
        let d = self.spec.d;
        let vocab = embed.rows();
        match usize::try_from(tok).ok().filter(|&t| t < vocab) {
            Some(t) => Ok(&embed.data()[t * d..(t + 1) * d]),
            None => bail!("token id {tok} outside vocab 0..{vocab}"),
        }
    }
}

/// One decode step for a batch of slots: token `tokens[i]` is fed to KV
/// block `blocks[i]` at position `positions[i]`. Returns [b, vocab]
/// logits, row i for slot i. Errors when a block cannot hold its new
/// position (the engine grows blocks ahead of the step, so this is an
/// internal-invariant check, not a normal control path).
pub fn decode_step(
    model: &ServeModel<'_>,
    blocks: &mut [&mut KvBlock],
    tokens: &[i32],
    positions: &[usize],
) -> Result<Tensor> {
    let x = decode_hidden(model, blocks, tokens, positions)?;
    let x = model.final_norm(&x)?;
    // tied unembedding through the skinny kernel (bitwise = matmul_nt)
    Ok(kernels::matmul_nt_skinny(&x, model.global("embed")?))
}

/// Prefill one *chunk* of a prompt — `tokens` at absolute positions
/// `start..start + tokens.len()` — into a KV block that already caches
/// exactly the first `start` positions, in one position-batched pass:
/// all chunk rows go through each layer together ([p, d] stacks for
/// norms/projections/MLP, row t attending over cached rows
/// 0..=start + t), so admission costs layer-stack walks instead of
/// serial single-row forwards. No logits are computed — the final norm
/// and the [d × vocab] unembedding matmul would be discarded.
///
/// Every per-row operation is the identical arithmetic of
/// [`decode_step`] fed one token at a time, and a row only ever reads
/// cache rows below it, so the resulting cache is bitwise independent of
/// how the prompt is chunked (`start = 0` with the whole prompt is the
/// old single-shot prefill). The block must already hold pages for
/// `start + tokens.len()` positions (`KvBlock::grow_to`).
pub fn prefill_extend(
    model: &ServeModel<'_>,
    block: &mut KvBlock,
    tokens: &[i32],
    start: usize,
) -> Result<()> {
    ensure!(
        block.len() == start,
        "prefill chunk at position {start} but the block caches {} positions",
        block.len()
    );
    let p = tokens.len();
    if p == 0 {
        return Ok(());
    }
    let spec = &model.spec;
    let d = spec.d;
    let embed = model.global("embed")?;
    let mut x = Tensor::zeros(vec![p, d]);
    for (t, &tok) in tokens.iter().enumerate() {
        x.row_mut(t).copy_from_slice(model.embed_row(embed, tok)?);
    }
    if spec.family == FamilyKind::Topt {
        let pos_t = model.global("pos")?;
        for t in 0..p {
            for (xi, &pv) in x.row_mut(t).iter_mut().zip(pos_t.row(start + t)) {
                *xi += pv;
            }
        }
    }
    for li in 0..spec.layers {
        x = prefill_layer(model, li, block, &x, start)?;
    }
    Ok(())
}

/// One decoder layer over a prompt-chunk stack [p, d]: like
/// [`layer_step`] but all rows belong to one slot at positions
/// `start..start + p`, and attention row t reads only the first
/// `start + t + 1` cached positions.
fn prefill_layer(
    model: &ServeModel<'_>,
    li: usize,
    block: &mut KvBlock,
    x: &Tensor,
    start: usize,
) -> Result<Tensor> {
    let spec = &model.spec;
    let p = x.rows();
    let d = spec.d;
    let h = match spec.family {
        FamilyKind::Topt => forward::layernorm(x, model.lp(li, "ln1_g")?, model.lp(li, "ln1_b")?),
        FamilyKind::Tllama => forward::rmsnorm(x, model.lp(li, "rms1_g")?),
    };
    let mut q = model.linop(li, "wq", &h)?;
    let mut k = model.linop(li, "wk", &h)?;
    let v = {
        let mut v = model.linop(li, "wv", &h)?;
        if spec.bias {
            forward::add_bias(&mut v, model.lp(li, "bv")?);
        }
        v
    };
    if spec.bias {
        forward::add_bias(&mut q, model.lp(li, "bq")?);
        forward::add_bias(&mut k, model.lp(li, "bk")?);
    }
    if spec.family == FamilyKind::Tllama {
        for t in 0..p {
            forward::rope_row(q.row_mut(t), spec.heads, start + t);
            forward::rope_row(k.row_mut(t), spec.heads, start + t);
        }
    }
    for t in 0..p {
        block.layer_mut(li).push(k.row(t), v.row(t))?;
    }
    let mut ctx = Tensor::zeros(vec![p, d]);
    {
        let kv = block.layer(li);
        let qd = q.data();
        let heads = spec.heads;
        par::for_each_row_block(ctx.data_mut(), p, d, 1, |r0, _r1, out| {
            for (i, orow) in out.chunks_mut(d).enumerate() {
                let t = r0 + i;
                let row =
                    forward::attend_prefix(&qd[t * d..(t + 1) * d], kv, heads, start + t + 1);
                orow.copy_from_slice(&row);
            }
        });
    }
    let mut attn_out = model.linop(li, "wo", &ctx)?;
    if spec.bias {
        forward::add_bias(&mut attn_out, model.lp(li, "bo")?);
    }
    let mut x1 = x.clone();
    for (a, bv) in x1.data_mut().iter_mut().zip(attn_out.data()) {
        *a += bv;
    }
    let h2 = match spec.family {
        FamilyKind::Topt => {
            forward::layernorm(&x1, model.lp(li, "ln2_g")?, model.lp(li, "ln2_b")?)
        }
        FamilyKind::Tllama => forward::rmsnorm(&x1, model.lp(li, "rms2_g")?),
    };
    let mlp_out = mlp(model, li, p, &h2)?;
    for (a, bv) in x1.data_mut().iter_mut().zip(mlp_out.data()) {
        *a += bv;
    }
    Ok(x1)
}

/// The shared layer-stack walk: embed rows → every decoder layer (caches
/// appended) → hidden states [b, d].
fn decode_hidden(
    model: &ServeModel<'_>,
    blocks: &mut [&mut KvBlock],
    tokens: &[i32],
    positions: &[usize],
) -> Result<Tensor> {
    let spec = &model.spec;
    let b = tokens.len();
    ensure!(blocks.len() == b, "one KV block per batched token");
    ensure!(positions.len() == b, "one position per batched token");
    let d = spec.d;
    for (blk, &p) in blocks.iter().zip(positions) {
        debug_assert_eq!(blk.len(), p, "KV cache length must equal the token's position");
    }
    let embed = model.global("embed")?;
    let pos_t = match spec.family {
        FamilyKind::Topt => Some(model.global("pos")?),
        FamilyKind::Tllama => None,
    };
    let mut x = Tensor::zeros(vec![b, d]);
    for (i, &tok) in tokens.iter().enumerate() {
        x.row_mut(i).copy_from_slice(
            model.embed_row(embed, tok).with_context(|| format!("batch row {i}"))?,
        );
        if let Some(pos_t) = pos_t {
            for (xi, &pv) in x.row_mut(i).iter_mut().zip(pos_t.row(positions[i])) {
                *xi += pv;
            }
        }
    }
    for li in 0..spec.layers {
        x = layer_step(model, li, blocks, positions, &x)?;
    }
    Ok(x)
}

/// One decoder layer over the [b, d] slot stack.
fn layer_step(
    model: &ServeModel<'_>,
    li: usize,
    blocks: &mut [&mut KvBlock],
    positions: &[usize],
    x: &Tensor,
) -> Result<Tensor> {
    let spec = &model.spec;
    let b = x.rows();
    let d = spec.d;
    let h = match spec.family {
        FamilyKind::Topt => forward::layernorm(x, model.lp(li, "ln1_g")?, model.lp(li, "ln1_b")?),
        FamilyKind::Tllama => forward::rmsnorm(x, model.lp(li, "rms1_g")?),
    };
    let mut q = model.linop(li, "wq", &h)?;
    let mut k = model.linop(li, "wk", &h)?;
    let v = {
        let mut v = model.linop(li, "wv", &h)?;
        if spec.bias {
            forward::add_bias(&mut v, model.lp(li, "bv")?);
        }
        v
    };
    if spec.bias {
        forward::add_bias(&mut q, model.lp(li, "bq")?);
        forward::add_bias(&mut k, model.lp(li, "bk")?);
    }
    if spec.family == FamilyKind::Tllama {
        for i in 0..b {
            forward::rope_row(q.row_mut(i), spec.heads, positions[i]);
            forward::rope_row(k.row_mut(i), spec.heads, positions[i]);
        }
    }
    for i in 0..b {
        blocks[i].layer_mut(li).push(k.row(i), v.row(i))?;
    }
    // Attention per slot against its own cache, fanned out across slots
    // (row-block over the [b, d] context stack; each row only reads its
    // slot's cache — through its page table — so the split is free of
    // synchronization).
    let mut ctx = Tensor::zeros(vec![b, d]);
    {
        let kv_refs: Vec<&super::kv::PagedKvLayer> =
            blocks.iter().map(|blk| blk.layer(li)).collect();
        let qd = q.data();
        let heads = spec.heads;
        par::for_each_row_block(ctx.data_mut(), b, d, 1, |r0, _r1, block| {
            for (i, orow) in block.chunks_mut(d).enumerate() {
                let s = r0 + i;
                let row = forward::attend_one(&qd[s * d..(s + 1) * d], kv_refs[s], heads);
                orow.copy_from_slice(&row);
            }
        });
    }
    let mut attn_out = model.linop(li, "wo", &ctx)?;
    if spec.bias {
        forward::add_bias(&mut attn_out, model.lp(li, "bo")?);
    }
    let mut x1 = x.clone();
    for (a, bv) in x1.data_mut().iter_mut().zip(attn_out.data()) {
        *a += bv;
    }

    let h2 = match spec.family {
        FamilyKind::Topt => {
            forward::layernorm(&x1, model.lp(li, "ln2_g")?, model.lp(li, "ln2_b")?)
        }
        FamilyKind::Tllama => forward::rmsnorm(&x1, model.lp(li, "rms2_g")?),
    };
    let mlp_out = mlp(model, li, b, &h2)?;
    for (a, bv) in x1.data_mut().iter_mut().zip(mlp_out.data()) {
        *a += bv;
    }
    Ok(x1)
}

/// The family-specific MLP over a [rows, d] post-norm stack (shared by
/// the decode and prefill layer walks).
fn mlp(model: &ServeModel<'_>, li: usize, rows: usize, h2: &Tensor) -> Result<Tensor> {
    let spec = &model.spec;
    Ok(match spec.family {
        FamilyKind::Topt => {
            let mut f1 = model.linop(li, "w1", h2)?;
            if spec.bias {
                forward::add_bias(&mut f1, model.lp(li, "b1")?);
            }
            for v in f1.data_mut() {
                *v = forward::gelu(*v);
            }
            let mut f2 = model.linop(li, "w2", &f1)?;
            if spec.bias {
                forward::add_bias(&mut f2, model.lp(li, "b2")?);
            }
            f2
        }
        FamilyKind::Tllama => {
            let gate = model.linop(li, "wg", h2)?;
            let up = model.linop(li, "wu", h2)?;
            let mut hidden = Tensor::zeros(vec![rows, spec.ffn]);
            for ((hv, &g), &u) in hidden.data_mut().iter_mut().zip(gate.data()).zip(up.data()) {
                *hv = forward::silu(g) * u;
            }
            model.linop(li, "wd", &hidden)?
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{repo_root, Presets};
    use crate::model::init::init_params;

    #[test]
    fn batched_step_matches_full_forward_rows() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        for m in ["topt-s1", "tllama-s1"] {
            let spec = presets.model(m).unwrap().clone();
            let params = init_params(&spec, 17);
            let model = ServeModel::dense(&spec, &params).unwrap();
            // two sequences of different lengths decoding in one batch,
            // through a small page size so both block tables span pages
            let seqs: [Vec<i32>; 2] = [
                (0..9).map(|i| (i * 5 + 1) % 96).collect(),
                (0..5).map(|i| (i * 3 + 2) % 96).collect(),
            ];
            let page = 4;
            let budget = crate::serve::kv::KvPool::full_context_budget(&spec, page, 2);
            let mut pool = crate::serve::kv::KvPool::new(&spec, page, budget);
            let mut a = KvBlock::new(&spec, page);
            let mut c = KvBlock::new(&spec, page);
            a.grow_to(seqs[0].len(), &mut pool).unwrap();
            c.grow_to(seqs[1].len(), &mut pool).unwrap();
            // warm both caches on all but the last token (batched prefill)
            prefill_extend(&model, &mut a, &seqs[0][..seqs[0].len() - 1], 0).unwrap();
            prefill_extend(&model, &mut c, &seqs[1][..seqs[1].len() - 1], 0).unwrap();
            let mut blocks = [&mut a, &mut c];
            let toks = [seqs[0][seqs[0].len() - 1], seqs[1][seqs[1].len() - 1]];
            let pos = [seqs[0].len() - 1, seqs[1].len() - 1];
            let lg = decode_step(&model, &mut blocks, &toks, &pos).unwrap();
            for (row, seq) in [(0usize, &seqs[0]), (1, &seqs[1])] {
                let full = crate::model::forward::logits(&spec, &params, seq);
                let want = full.row(seq.len() - 1);
                for (j, (&got, &w)) in lg.row(row).iter().zip(want).enumerate() {
                    assert_eq!(got.to_bits(), w.to_bits(), "{m} slot {row} logit {j}");
                }
            }
        }
    }

    #[test]
    fn chunked_prefill_cache_is_bitwise_equal_to_single_shot() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        for m in ["topt-s1", "tllama-s1"] {
            let spec = presets.model(m).unwrap().clone();
            let params = init_params(&spec, 19);
            let model = ServeModel::dense(&spec, &params).unwrap();
            let prompt: Vec<i32> = (0..13).map(|i| (i * 7 + 5) % 96).collect();
            let page = 4;
            let mut pool = crate::serve::kv::KvPool::new(
                &spec,
                page,
                crate::serve::kv::KvPool::full_context_budget(&spec, page, 2),
            );
            // single shot
            let mut whole = KvBlock::new(&spec, page);
            whole.grow_to(prompt.len(), &mut pool).unwrap();
            prefill_extend(&model, &mut whole, &prompt, 0).unwrap();
            // chunks of 5, 5, 3
            let mut chunked = KvBlock::new(&spec, page);
            chunked.grow_to(prompt.len(), &mut pool).unwrap();
            let mut at = 0;
            for c in [5usize, 5, 3] {
                prefill_extend(&model, &mut chunked, &prompt[at..at + c], at).unwrap();
                at += c;
            }
            assert_eq!(whole.len(), chunked.len());
            for li in 0..spec.layers {
                for t in 0..prompt.len() {
                    let (kw, kc) = (whole.layer(li).k_row(t), chunked.layer(li).k_row(t));
                    let (vw, vc) = (whole.layer(li).v_row(t), chunked.layer(li).v_row(t));
                    for j in 0..spec.d {
                        assert_eq!(kw[j].to_bits(), kc[j].to_bits(), "{m} K l{li} t{t} j{j}");
                        assert_eq!(vw[j].to_bits(), vc[j].to_bits(), "{m} V l{li} t{t} j{j}");
                    }
                }
            }
            // a chunk at the wrong start position is a checked error
            let mut bad = KvBlock::new(&spec, page);
            bad.grow_to(4, &mut pool).unwrap();
            assert!(prefill_extend(&model, &mut bad, &prompt[..2], 3).is_err());
        }
    }

    #[test]
    fn dense_construction_checks_the_parameter_set() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let s1 = presets.model("topt-s1").unwrap().clone();
        let s2 = presets.model("topt-s2").unwrap().clone();
        let params = init_params(&s1, 3);
        // params for a different spec: shapes/coverage mismatch is a
        // checked construction error, not a mid-decode panic
        let err = ServeModel::dense(&s2, &params);
        assert!(err.is_err(), "mismatched spec must fail at construction");
        // a family mismatch is also checked
        let tl = presets.model("tllama-s1").unwrap().clone();
        assert!(ServeModel::dense(&tl, &params).is_err());
    }

    #[test]
    fn sparse_model_reports_density() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap().clone();
        let dense = init_params(&spec, 19);
        let params = crate::pruner::round_model_to_sparsity(
            &spec,
            &dense,
            crate::config::Sparsity::Unstructured(0.5),
        )
        .unwrap();
        let model = ServeModel::sparse(&spec, &params).unwrap();
        assert!(model.is_sparse());
        let density = model.density().unwrap();
        assert!((density - 0.5).abs() < 0.02, "density {density}");
        assert!(ServeModel::dense(&spec, &params).unwrap().density().is_none());
    }

    #[test]
    fn resident_bytes_shrink_when_compiled() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap().clone();
        let sp = crate::config::Sparsity::Semi(2, 4);
        let params =
            crate::pruner::round_model_to_sparsity(&spec, &init_params(&spec, 29), sp).unwrap();
        let dense = ServeModel::dense(&spec, &params).unwrap();
        let nm = ServeModel::sparse_as(&spec, &params, SparseFormat::Nm, Some(sp)).unwrap();
        assert_eq!(dense.resident_weight_bytes(), 4 * param_count(&spec));
        let c = nm.compiled().unwrap();
        assert_eq!(nm.resident_weight_bytes(), c.storage_bytes() + c.residual_bytes());
        assert!(
            nm.resident_weight_bytes() < dense.resident_weight_bytes(),
            "compiled {} vs dense {}",
            nm.resident_weight_bytes(),
            dense.resident_weight_bytes()
        );
        // borrowed and owned views report identically
        let borrowed = ServeModel::from_compiled_ref(c);
        assert_eq!(borrowed.resident_weight_bytes(), nm.resident_weight_bytes());
        assert_eq!(borrowed.format_label(), "nm");
    }

    #[test]
    fn nm_serve_model_is_smaller_than_csr() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap().clone();
        let sp = crate::config::Sparsity::Semi(2, 4);
        let params =
            crate::pruner::round_model_to_sparsity(&spec, &init_params(&spec, 23), sp).unwrap();
        let csr = ServeModel::sparse(&spec, &params).unwrap();
        let nm = ServeModel::sparse_as(&spec, &params, SparseFormat::Nm, Some(sp)).unwrap();
        assert_eq!(csr.format_label(), "csr");
        assert_eq!(nm.format_label(), "nm");
        assert_eq!(ServeModel::dense(&spec, &params).unwrap().format_label(), "dense");
        let (cb, nb) = (csr.storage_bytes().unwrap(), nm.storage_bytes().unwrap());
        assert!(nb < cb, "nm {nb} bytes vs csr {cb} bytes");
        assert!(nm.storage_ratio().unwrap() < csr.storage_ratio().unwrap());
        // auto on 2:4-rounded weights packs everything
        let auto = ServeModel::sparse_as(&spec, &params, SparseFormat::Auto, Some(sp)).unwrap();
        assert_eq!(auto.format_label(), "nm");
        assert_eq!(auto.storage_bytes(), nm.storage_bytes());
    }
}

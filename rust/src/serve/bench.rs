//! The serve-bench core: tokens/s and latency percentiles for the three
//! decode paths — full-recompute `eval::generate`, KV-cached dense decode,
//! and KV-cached CSR decode on pruned weights — plus a greedy-parity check
//! that every served output equals its single-request `eval::generate`
//! reference. Shared by the `serve-bench` CLI command and
//! `benches/serve_decode.rs`.

use std::collections::BTreeMap;

use anyhow::{ensure, Result};

use crate::config::{ModelSpec, Sparsity};
use crate::eval::generate::{generate, GenOptions};
use crate::metrics::stats::percentile;
use crate::metrics::TableBuilder;
use crate::model::params::ModelParams;
use crate::pruner::round_model_to_sparsity;
use crate::ser::json::Json;

use super::batch::ServeModel;
use super::engine::{Engine, EngineConfig};
use super::request::ServeRequest;

/// Bench sizing.
pub struct ServeBenchConfig {
    /// Decode budget per request.
    pub tokens: usize,
    /// Continuous-batch width for the batched paths.
    pub batch: usize,
    /// Synthetic requests for the batched paths.
    pub requests: usize,
    /// Pruning level for the CSR paths.
    pub sparsity: Sparsity,
}

impl Default for ServeBenchConfig {
    fn default() -> Self {
        ServeBenchConfig {
            tokens: 32,
            batch: 4,
            requests: 8,
            sparsity: Sparsity::Unstructured(0.5),
        }
    }
}

/// One measured decode path.
#[derive(Clone, Debug)]
pub struct PathStats {
    pub label: String,
    pub requests: usize,
    pub total_tokens: usize,
    pub wall_s: f64,
    pub tokens_per_s: f64,
    /// Per-request submit-to-retire latency percentiles.
    pub p50_ms: f64,
    pub p99_ms: f64,
}

/// Full serve-bench result.
#[derive(Clone, Debug)]
pub struct ServeBenchReport {
    pub model: String,
    pub sparsity_label: String,
    pub paths: Vec<PathStats>,
    /// KV-cached dense (batch 1) vs full-recompute tokens/s.
    pub kv_speedup: f64,
    /// CSR vs dense KV-cached decode tokens/s at the same batch width.
    pub sparse_speedup: f64,
    /// Every served greedy output equalled its `eval::generate` reference.
    pub parity_ok: bool,
}

impl ServeBenchReport {
    /// Paper-style ASCII table.
    pub fn print(&self) {
        let mut t = TableBuilder::new(
            &format!("serve-bench ({}, CSR @ {})", self.model, self.sparsity_label),
            &["path", "reqs", "tokens", "tok/s", "p50 ms", "p99 ms"],
        );
        for p in &self.paths {
            t.row(vec![
                p.label.clone(),
                p.requests.to_string(),
                p.total_tokens.to_string(),
                format!("{:.1}", p.tokens_per_s),
                format!("{:.1}", p.p50_ms),
                format!("{:.1}", p.p99_ms),
            ]);
        }
        t.print();
        println!(
            "KV-cached vs full-recompute: {:.2}x   CSR vs dense decode: {:.2}x   greedy parity: {}",
            self.kv_speedup,
            self.sparse_speedup,
            if self.parity_ok { "ok" } else { "MISMATCH" }
        );
    }

    /// JSON object for BENCH_serve.json (the CI perf-trajectory record).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("model".to_string(), Json::Str(self.model.clone()));
        m.insert("sparsity".to_string(), Json::Str(self.sparsity_label.clone()));
        m.insert("kv_speedup".to_string(), Json::Num(round3(self.kv_speedup)));
        m.insert("sparse_speedup".to_string(), Json::Num(round3(self.sparse_speedup)));
        m.insert("parity_ok".to_string(), Json::Bool(self.parity_ok));
        let mut paths = BTreeMap::new();
        for p in &self.paths {
            let mut pm = BTreeMap::new();
            pm.insert("requests".to_string(), Json::Num(p.requests as f64));
            pm.insert("total_tokens".to_string(), Json::Num(p.total_tokens as f64));
            pm.insert("tokens_per_s".to_string(), Json::Num(round3(p.tokens_per_s)));
            pm.insert("p50_ms".to_string(), Json::Num(round3(p.p50_ms)));
            pm.insert("p99_ms".to_string(), Json::Num(round3(p.p99_ms)));
            paths.insert(p.label.clone(), Json::Obj(pm));
        }
        m.insert("paths".to_string(), Json::Obj(paths));
        Json::Obj(m)
    }
}

fn round3(x: f64) -> f64 {
    (x * 1e3).round() / 1e3
}

/// Deterministic synthetic prompts (distinct so batched outputs are
/// checked against distinct references).
fn synthetic_prompts(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("req {i}: the ")).collect()
}

fn requests_for(prompts: &[String], tokens: usize) -> Vec<ServeRequest> {
    prompts
        .iter()
        .enumerate()
        .map(|(i, p)| ServeRequest {
            id: format!("r{i}"),
            prompt: p.clone(),
            max_tokens: tokens,
            temperature: 0.0,
            seed: i as u64,
            stop: None,
        })
        .collect()
}

/// Serve `requests` through a fresh engine; returns (stats, id → text).
/// Admission is just-in-time (a request is submitted only when a slot is
/// free), so `latency_ms` measures service time — comparable to the solo
/// `eval::generate` reference — rather than artificial queue wait behind
/// requests submitted upfront.
fn run_engine(
    model: &ServeModel<'_>,
    batch: usize,
    label: &str,
    requests: &[ServeRequest],
) -> Result<(PathStats, BTreeMap<String, String>)> {
    let cfg = EngineConfig { max_batch: batch, queue_cap: requests.len().max(1), transcript: None };
    let mut eng = Engine::new(model, &cfg)?;
    let start = std::time::Instant::now();
    let mut pending = requests.iter();
    let mut next = pending.next();
    let mut responses = Vec::new();
    loop {
        // top up: one queued request per free slot (admitted next step)
        while eng.free_slots() > eng.queued() {
            match next.take() {
                Some(r) => {
                    eng.submit(r.clone())?;
                    next = pending.next();
                }
                None => break,
            }
        }
        if next.is_none() && eng.is_idle() {
            break;
        }
        eng.step()?;
        responses.extend(eng.take_responses());
    }
    let wall_s = start.elapsed().as_secs_f64();
    let latencies: Vec<f64> = responses.iter().map(|r| r.latency_ms).collect();
    let total_tokens: usize = responses.iter().map(|r| r.completion_tokens).sum();
    let texts = responses.into_iter().map(|r| (r.id, r.text)).collect();
    Ok((
        PathStats {
            label: label.to_string(),
            requests: requests.len(),
            total_tokens,
            wall_s,
            tokens_per_s: total_tokens as f64 / wall_s.max(1e-12),
            p50_ms: percentile(&latencies, 50.0),
            p99_ms: percentile(&latencies, 99.0),
        },
        texts,
    ))
}

/// Measure every path and assemble the report. `dense` should be the
/// weights to serve; the CSR paths run on a copy pruned to
/// `cfg.sparsity` via magnitude rounding (weight quality is irrelevant
/// for throughput, identical outputs are still parity-checked).
pub fn run_serve_bench(
    spec: &ModelSpec,
    dense: &ModelParams,
    cfg: &ServeBenchConfig,
) -> Result<ServeBenchReport> {
    ensure!(cfg.tokens >= 1 && cfg.batch >= 1 && cfg.requests >= 1, "bench sizes must be >= 1");
    let prompts = synthetic_prompts(cfg.requests);
    let requests = requests_for(&prompts, cfg.tokens);
    let mut parity_ok = true;

    // references + full-recompute timing: eval::generate per request
    let start = std::time::Instant::now();
    let mut reference = BTreeMap::new();
    let mut ref_lat = Vec::new();
    for (r, p) in requests.iter().zip(&prompts) {
        let t0 = std::time::Instant::now();
        let text = generate(
            spec,
            dense,
            p,
            &GenOptions { max_tokens: r.max_tokens, temperature: 0.0, seed: r.seed },
        );
        ref_lat.push(t0.elapsed().as_secs_f64() * 1e3);
        reference.insert(r.id.clone(), text);
    }
    let recompute_wall = start.elapsed().as_secs_f64();
    let recompute_tokens = cfg.tokens * cfg.requests;
    let recompute = PathStats {
        label: "recompute (eval::generate)".to_string(),
        requests: cfg.requests,
        total_tokens: recompute_tokens,
        wall_s: recompute_wall,
        tokens_per_s: recompute_tokens as f64 / recompute_wall.max(1e-12),
        p50_ms: percentile(&ref_lat, 50.0),
        p99_ms: percentile(&ref_lat, 99.0),
    };

    // KV-cached dense, batch 1 and batch B (one weight resolution)
    let dense_model = ServeModel::dense(spec, dense);
    let (kv1, texts1) = run_engine(&dense_model, 1, "kv dense b=1", &requests)?;
    let (kvb, textsb) =
        run_engine(&dense_model, cfg.batch, &format!("kv dense b={}", cfg.batch), &requests)?;
    for texts in [&texts1, &textsb] {
        for (id, text) in texts {
            parity_ok &= reference.get(id) == Some(text);
        }
    }

    // CSR on pruned weights, batch 1 and batch B; parity vs the
    // full-recompute generate over the same pruned weights
    let pruned = round_model_to_sparsity(spec, dense, cfg.sparsity)?;
    let mut pruned_ref = BTreeMap::new();
    for (r, p) in requests.iter().zip(&prompts) {
        let text = generate(
            spec,
            &pruned,
            p,
            &GenOptions { max_tokens: r.max_tokens, temperature: 0.0, seed: r.seed },
        );
        pruned_ref.insert(r.id.clone(), text);
    }
    let pruned_dense_model = ServeModel::dense(spec, &pruned);
    let sparse_model = ServeModel::sparse(spec, &pruned)?;
    let (kv_pruned1, _) = run_engine(&pruned_dense_model, 1, "kv pruned-dense b=1", &requests)?;
    let (csr1, csr_texts1) = run_engine(&sparse_model, 1, "kv csr b=1", &requests)?;
    let (csrb, csr_textsb) =
        run_engine(&sparse_model, cfg.batch, &format!("kv csr b={}", cfg.batch), &requests)?;
    for texts in [&csr_texts1, &csr_textsb] {
        for (id, text) in texts {
            parity_ok &= pruned_ref.get(id) == Some(text);
        }
    }

    let kv_speedup = kv1.tokens_per_s / recompute.tokens_per_s.max(1e-12);
    let sparse_speedup = csr1.tokens_per_s / kv_pruned1.tokens_per_s.max(1e-12);
    Ok(ServeBenchReport {
        model: spec.name(),
        sparsity_label: cfg.sparsity.label(),
        paths: vec![recompute, kv1, kvb, kv_pruned1, csr1, csrb],
        kv_speedup,
        sparse_speedup,
        parity_ok,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{repo_root, Presets};
    use crate::model::init::init_params;

    #[test]
    fn smoke_report_is_consistent() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap().clone();
        let params = init_params(&spec, 29);
        let cfg = ServeBenchConfig {
            tokens: 6,
            batch: 2,
            requests: 2,
            sparsity: Sparsity::Unstructured(0.5),
        };
        let report = run_serve_bench(&spec, &params, &cfg).unwrap();
        assert!(report.parity_ok, "served outputs diverged from eval::generate");
        assert_eq!(report.paths.len(), 6);
        for p in &report.paths {
            assert_eq!(p.total_tokens, 12, "{}", p.label);
            assert!(p.tokens_per_s > 0.0);
        }
        let j = report.to_json().to_string_compact();
        let v = Json::parse(&j).unwrap();
        assert_eq!(v.get("parity_ok").unwrap().as_bool(), Some(true));
        assert!(v.get("paths").unwrap().get("kv dense b=1").is_some());
    }
}

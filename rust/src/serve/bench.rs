//! The serve-bench core: tokens/s and latency percentiles for the decode
//! paths — full-recompute `eval::generate`, KV-cached dense decode, and
//! KV-cached compressed decode on pruned weights (CSR always; packed n:m
//! side by side when the config asks for it) — plus a greedy-parity check
//! that every served output equals its single-request `eval::generate`
//! reference. Shared by the `serve-bench` CLI command and
//! `benches/serve_decode.rs`.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Result};

use crate::config::{KernelVariant, ModelSpec, QuantMode, SparseFormat, Sparsity};
use crate::eval::generate::{generate, GenOptions};
use crate::metrics::stats::{percentile, percentiles};
use crate::metrics::TableBuilder;
use crate::model::params::ModelParams;
use crate::obs::{Recorder, SharedClock};
use crate::pruner::round_model_to_sparsity;
use crate::ser::json::Json;
use crate::tensor::par;

use super::batch::ServeModel;
use super::engine::{Engine, EngineConfig};
use super::request::ServeRequest;

/// Bench sizing.
pub struct ServeBenchConfig {
    /// Decode budget per request.
    pub tokens: usize,
    /// Continuous-batch width for the batched paths.
    pub batch: usize,
    /// Synthetic requests for the batched paths.
    pub requests: usize,
    /// Pruning level for the compressed paths.
    pub sparsity: Sparsity,
    /// Compressed format axis: `Csr` measures CSR only; `Nm`/`Auto` also
    /// measure the packed n:m paths over the same pruned weights so the
    /// report shows csr-vs-nm tokens/s and storage side by side (`Nm`
    /// requires `sparsity` to be `Sparsity::Semi`; `Auto` degrades to
    /// CSR-only otherwise).
    pub format: SparseFormat,
    /// Positions per KV page (`--kv-page`) — the paged-axis geometry
    /// ([`run_paged_bench`]); the throughput paths measure at the
    /// engine's default paging so their numbers stay comparable across
    /// configs.
    pub kv_page: usize,
    /// Prefill-token budget per engine step (`--prefill-chunk`) for the
    /// paged axis.
    pub prefill_chunk: usize,
    /// Observability hooks threaded into every engine the bench builds
    /// (`--trace-out`); defaults off.
    pub obs: BenchObs,
}

impl Default for ServeBenchConfig {
    fn default() -> Self {
        ServeBenchConfig {
            tokens: 32,
            batch: 4,
            requests: 8,
            sparsity: Sparsity::Unstructured(0.5),
            format: SparseFormat::Csr,
            kv_page: 16,
            prefill_chunk: 16,
            obs: BenchObs::default(),
        }
    }
}

/// Optional clock + recorder shared by every engine a bench run
/// constructs, so one trace file covers all measured paths.
#[derive(Clone, Default)]
pub struct BenchObs {
    pub clock: Option<SharedClock>,
    pub recorder: Option<Recorder>,
}

impl BenchObs {
    fn apply(&self, cfg: &mut EngineConfig) {
        cfg.clock = self.clock.clone();
        cfg.recorder = self.recorder.clone();
    }

    /// The bench's own timestamp domain: the injected clock when one is
    /// configured, the monotonic default otherwise — so engine latencies
    /// and bench wall numbers always share a domain.
    fn clock(&self) -> SharedClock {
        self.clock.clone().unwrap_or_default()
    }
}

/// One measured decode path.
#[derive(Clone, Debug)]
pub struct PathStats {
    pub label: String,
    pub requests: usize,
    pub total_tokens: usize,
    pub wall_s: f64,
    pub tokens_per_s: f64,
    /// Per-request submit-to-retire latency percentiles.
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Peak KV bytes actually allocated by the paged pool during the
    /// run (0 for the recompute path, which keeps no cache).
    pub kv_resident_bytes: usize,
    /// Weight bytes the run streamed under the simple
    /// one-read-per-engine-step traffic model: engine steps × resident
    /// weight bytes (0 for the recompute path, which runs outside the
    /// engine). Quantized values shrink this in direct proportion to
    /// their resident footprint.
    pub weight_bytes_moved: u64,
    /// `weight_bytes_moved` per wall second, in GB/s — the effective
    /// weight bandwidth this path sustained.
    pub eff_gb_per_s: f64,
}

/// Full serve-bench result.
#[derive(Clone, Debug)]
pub struct ServeBenchReport {
    pub model: String,
    pub sparsity_label: String,
    /// The requested format axis ("csr" | "nm" | "auto").
    pub format_label: String,
    pub paths: Vec<PathStats>,
    /// KV-cached dense (batch 1) vs full-recompute tokens/s.
    pub kv_speedup: f64,
    /// CSR vs dense KV-cached decode tokens/s at the same batch width.
    pub sparse_speedup: f64,
    /// Packed n:m vs CSR decode tokens/s at batch 1 (nm paths only).
    pub nm_speedup: Option<f64>,
    /// CSR bytes / dense bytes over the compressed operators.
    pub csr_storage_ratio: f64,
    /// Packed n:m bytes / dense bytes (nm paths only).
    pub nm_storage_ratio: Option<f64>,
    /// Every served greedy output equalled its `eval::generate` reference.
    pub parity_ok: bool,
}

impl ServeBenchReport {
    /// Paper-style ASCII table.
    pub fn print(&self) {
        let mut t = TableBuilder::new(
            &format!(
                "serve-bench ({}, {} @ {})",
                self.model, self.format_label, self.sparsity_label
            ),
            &["path", "reqs", "tokens", "tok/s", "p50 ms", "p99 ms", "GB/s"],
        );
        for p in &self.paths {
            t.row(vec![
                p.label.clone(),
                p.requests.to_string(),
                p.total_tokens.to_string(),
                format!("{:.1}", p.tokens_per_s),
                format!("{:.1}", p.p50_ms),
                format!("{:.1}", p.p99_ms),
                format!("{:.2}", p.eff_gb_per_s),
            ]);
        }
        t.print();
        println!(
            "KV-cached vs full-recompute: {:.2}x   CSR vs dense decode: {:.2}x   greedy parity: {}",
            self.kv_speedup,
            self.sparse_speedup,
            if self.parity_ok { "ok" } else { "MISMATCH" }
        );
        match (self.nm_speedup, self.nm_storage_ratio) {
            (Some(spd), Some(ratio)) => println!(
                "packed n:m vs CSR decode: {spd:.2}x   storage/dense: csr {:.3}, nm {ratio:.3}",
                self.csr_storage_ratio
            ),
            _ => println!("storage/dense: csr {:.3}", self.csr_storage_ratio),
        }
    }

    /// JSON object for BENCH_serve.json / BENCH_nm.json (the CI
    /// perf-trajectory record).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("model".to_string(), Json::Str(self.model.clone()));
        m.insert("sparsity".to_string(), Json::Str(self.sparsity_label.clone()));
        m.insert("format".to_string(), Json::Str(self.format_label.clone()));
        m.insert("kv_speedup".to_string(), Json::Num(round3(self.kv_speedup)));
        m.insert("sparse_speedup".to_string(), Json::Num(round3(self.sparse_speedup)));
        m.insert("csr_storage_ratio".to_string(), Json::Num(round3(self.csr_storage_ratio)));
        if let Some(s) = self.nm_speedup {
            m.insert("nm_speedup".to_string(), Json::Num(round3(s)));
        }
        if let Some(r) = self.nm_storage_ratio {
            m.insert("nm_storage_ratio".to_string(), Json::Num(round3(r)));
        }
        m.insert("parity_ok".to_string(), Json::Bool(self.parity_ok));
        let mut paths = BTreeMap::new();
        for p in &self.paths {
            let mut pm = BTreeMap::new();
            pm.insert("requests".to_string(), Json::Num(p.requests as f64));
            pm.insert("total_tokens".to_string(), Json::Num(p.total_tokens as f64));
            pm.insert("tokens_per_s".to_string(), Json::Num(round3(p.tokens_per_s)));
            pm.insert("p50_ms".to_string(), Json::Num(round3(p.p50_ms)));
            pm.insert("p99_ms".to_string(), Json::Num(round3(p.p99_ms)));
            pm.insert("kv_resident_bytes".to_string(), Json::Num(p.kv_resident_bytes as f64));
            pm.insert(
                "weight_bytes_moved".to_string(),
                Json::Num(p.weight_bytes_moved as f64),
            );
            pm.insert("eff_gb_per_s".to_string(), Json::Num(round3(p.eff_gb_per_s)));
            paths.insert(p.label.clone(), Json::Obj(pm));
        }
        m.insert("paths".to_string(), Json::Obj(paths));
        Json::Obj(m)
    }
}

fn round3(x: f64) -> f64 {
    (x * 1e3).round() / 1e3
}

/// Deterministic synthetic prompts (distinct so batched outputs are
/// checked against distinct references). Shared with
/// `bench_support::grid::run_serve_format_grid`.
pub(crate) fn synthetic_prompts(n: usize) -> Vec<String> {
    (0..n).map(|i| format!("req {i}: the ")).collect()
}

pub(crate) fn requests_for(prompts: &[String], tokens: usize) -> Vec<ServeRequest> {
    prompts
        .iter()
        .enumerate()
        .map(|(i, p)| ServeRequest {
            id: format!("r{i}"),
            prompt: p.clone(),
            max_tokens: tokens,
            temperature: 0.0,
            seed: i as u64,
            stop: None,
        })
        .collect()
}

/// The parity oracle: id → greedy `eval::generate` text over `params`,
/// one entry per request, plus per-request wall latency in ms (the
/// full-recompute timing column). Shared by [`run_serve_bench`] and
/// `bench_support::grid::run_serve_format_grid` so the oracle options
/// can never drift between the parity gates.
pub(crate) fn greedy_references(
    spec: &ModelSpec,
    params: &ModelParams,
    requests: &[ServeRequest],
    prompts: &[String],
    clock: &SharedClock,
) -> (BTreeMap<String, String>, Vec<f64>) {
    let mut texts = BTreeMap::new();
    let mut lat_ms = Vec::new();
    for (r, p) in requests.iter().zip(prompts) {
        let t0 = clock.now_ms();
        let text = generate(
            spec,
            params,
            p,
            &GenOptions { max_tokens: r.max_tokens, temperature: 0.0, seed: r.seed },
        );
        lat_ms.push(clock.now_ms() - t0);
        texts.insert(r.id.clone(), text);
    }
    (texts, lat_ms)
}

/// The shared greedy-parity fold: every reference id must be present
/// and equal in every served texts map — sizes are compared too, so an
/// empty or partial run can never pass as `parity_ok = true`. Used by
/// every bench path (dense, per-format, artifact, format grid).
pub(crate) fn parity_against(
    reference: &BTreeMap<String, String>,
    served: &[&BTreeMap<String, String>],
) -> bool {
    served.iter().all(|texts| {
        reference.len() == texts.len()
            && reference.iter().all(|(id, want)| texts.get(id) == Some(want))
    })
}

/// Serve `requests` through a fresh engine; returns (stats, id → text).
/// Admission is just-in-time (a request is submitted only when a slot is
/// free), so `latency_ms` measures service time — comparable to the solo
/// `eval::generate` reference — rather than artificial queue wait behind
/// requests submitted upfront. Shared with the
/// `bench_support::grid` runners so every row of those tables is
/// measured under the same admission policy.
pub(crate) fn run_engine_cfg(
    model: &ServeModel<'_>,
    cfg: &EngineConfig,
    label: &str,
    requests: &[ServeRequest],
) -> Result<(PathStats, BTreeMap<String, String>)> {
    let mut eng = Engine::new(model, cfg)?;
    let clock = cfg.clock.clone().unwrap_or_default();
    let start = clock.now_ms();
    let mut pending = requests.iter();
    let mut next = pending.next();
    let mut responses = Vec::new();
    let mut kv_peak = 0usize;
    loop {
        // top up: one queued request per free slot (admitted next step)
        while eng.free_slots() > eng.queued() {
            match next.take() {
                Some(r) => {
                    eng.submit(r.clone())?;
                    next = pending.next();
                }
                None => break,
            }
        }
        if next.is_none() && eng.is_idle() {
            break;
        }
        eng.step()?;
        kv_peak = kv_peak.max(eng.kv_resident_bytes());
        responses.extend(eng.take_responses());
    }
    let wall_s = (clock.now_ms() - start) / 1e3;
    let weight_bytes_moved = eng.stats.steps * model.resident_weight_bytes() as u64;
    let latencies: Vec<f64> = responses.iter().map(|r| r.latency_ms).collect();
    let total_tokens: usize = responses.iter().map(|r| r.completion_tokens).sum();
    let texts = responses.into_iter().map(|r| (r.id, r.text)).collect();
    let qs = percentiles(&latencies, &[50.0, 99.0]);
    Ok((
        PathStats {
            label: label.to_string(),
            requests: requests.len(),
            total_tokens,
            wall_s,
            tokens_per_s: total_tokens as f64 / wall_s.max(1e-12),
            p50_ms: qs[0],
            p99_ms: qs[1],
            kv_resident_bytes: kv_peak,
            weight_bytes_moved,
            eff_gb_per_s: weight_bytes_moved as f64 / wall_s.max(1e-12) / 1e9,
        },
        texts,
    ))
}

/// [`run_engine_cfg`] at batch width `batch` with the default KV page
/// geometry.
pub(crate) fn run_engine(
    model: &ServeModel<'_>,
    batch: usize,
    label: &str,
    requests: &[ServeRequest],
    obs: &BenchObs,
) -> Result<(PathStats, BTreeMap<String, String>)> {
    let mut cfg = EngineConfig {
        max_batch: batch,
        queue_cap: requests.len().max(1),
        ..EngineConfig::default()
    };
    obs.apply(&mut cfg);
    run_engine_cfg(model, &cfg, label, requests)
}

/// One compressed format measured over one set of pruned weights: batch-1
/// and batch-B engine passes, storage footprint, greedy parity against the
/// caller's full-recompute references. The shared core of
/// [`run_serve_bench`]'s compressed paths and the
/// `bench_support::grid::run_serve_format_grid` format axis.
pub struct FormatStats {
    /// What actually got compressed ("csr" | "nm" | "csr+nm" for Auto).
    pub label: &'static str,
    pub b1: PathStats,
    pub bb: PathStats,
    pub storage_bytes: usize,
    /// Compressed bytes / dense bytes over the pruned operators.
    pub storage_ratio: f64,
    pub parity_ok: bool,
}

/// Serve `requests` through a fresh engine per batch width over `pruned`
/// weights compressed as `format`, and compare greedy outputs to
/// `reference` (id → text from `eval::generate` over the same weights).
#[allow(clippy::too_many_arguments)]
pub fn measure_sparse_format(
    spec: &ModelSpec,
    pruned: &ModelParams,
    reference: &BTreeMap<String, String>,
    requests: &[ServeRequest],
    batch: usize,
    format: SparseFormat,
    sp: Option<Sparsity>,
    obs: &BenchObs,
) -> Result<FormatStats> {
    let model = ServeModel::sparse_as(spec, pruned, format, sp)?;
    let label = model.format_label();
    let (b1, texts1) = run_engine(&model, 1, &format!("kv {label} b=1"), requests, obs)?;
    let (bb, textsb) = run_engine(&model, batch, &format!("kv {label} b={batch}"), requests, obs)?;
    let parity_ok = parity_against(reference, &[&texts1, &textsb]);
    Ok(FormatStats {
        label,
        b1,
        bb,
        storage_bytes: model.storage_bytes().unwrap_or(0),
        storage_ratio: model.storage_ratio().unwrap_or(1.0),
        parity_ok,
    })
}

/// Measure every path and assemble the report. `dense` should be the
/// weights to serve; the compressed paths run on a copy pruned to
/// `cfg.sparsity` via magnitude rounding (weight quality is irrelevant
/// for throughput, identical outputs are still parity-checked).
pub fn run_serve_bench(
    spec: &ModelSpec,
    dense: &ModelParams,
    cfg: &ServeBenchConfig,
) -> Result<ServeBenchReport> {
    ensure!(cfg.tokens >= 1 && cfg.batch >= 1 && cfg.requests >= 1, "bench sizes must be >= 1");
    if cfg.format == SparseFormat::Nm && !matches!(cfg.sparsity, Sparsity::Semi(..)) {
        bail!(
            "the nm format axis needs an n:m sparsity (e.g. 2:4), got {}",
            cfg.sparsity.label()
        );
    }
    let prompts = synthetic_prompts(cfg.requests);
    let requests = requests_for(&prompts, cfg.tokens);
    let mut parity_ok = true;

    // references + full-recompute timing: eval::generate per request
    let clock = cfg.obs.clock();
    let start = clock.now_ms();
    let (reference, ref_lat) = greedy_references(spec, dense, &requests, &prompts, &clock);
    let recompute_wall = (clock.now_ms() - start) / 1e3;
    let recompute_tokens = cfg.tokens * cfg.requests;
    let ref_qs = percentiles(&ref_lat, &[50.0, 99.0]);
    let recompute = PathStats {
        label: "recompute (eval::generate)".to_string(),
        requests: cfg.requests,
        total_tokens: recompute_tokens,
        wall_s: recompute_wall,
        tokens_per_s: recompute_tokens as f64 / recompute_wall.max(1e-12),
        p50_ms: ref_qs[0],
        p99_ms: ref_qs[1],
        kv_resident_bytes: 0,
        weight_bytes_moved: 0,
        eff_gb_per_s: 0.0,
    };

    // KV-cached dense, batch 1 and batch B (one weight resolution)
    let dense_model = ServeModel::dense(spec, dense)?;
    let (kv1, texts1) = run_engine(&dense_model, 1, "kv dense b=1", &requests, &cfg.obs)?;
    let (kvb, textsb) = run_engine(
        &dense_model,
        cfg.batch,
        &format!("kv dense b={}", cfg.batch),
        &requests,
        &cfg.obs,
    )?;
    parity_ok &= parity_against(&reference, &[&texts1, &textsb]);

    // compressed formats on pruned weights, batch 1 and batch B; parity
    // vs the full-recompute generate over the same pruned weights
    let pruned = round_model_to_sparsity(spec, dense, cfg.sparsity)?;
    let (pruned_ref, _) = greedy_references(spec, &pruned, &requests, &prompts, &clock);
    let pruned_dense_model = ServeModel::dense(spec, &pruned)?;
    let (kv_pruned1, _) =
        run_engine(&pruned_dense_model, 1, "kv pruned-dense b=1", &requests, &cfg.obs)?;
    let csr = measure_sparse_format(
        spec,
        &pruned,
        &pruned_ref,
        &requests,
        cfg.batch,
        SparseFormat::Csr,
        None,
        &cfg.obs,
    )?;
    parity_ok &= csr.parity_ok;
    // the nm axis: same pruned weights through the packed format (Auto
    // silently stays CSR-only when the sparsity has no n:m pattern)
    let nm = if cfg.format != SparseFormat::Csr && matches!(cfg.sparsity, Sparsity::Semi(..)) {
        let s = measure_sparse_format(
            spec,
            &pruned,
            &pruned_ref,
            &requests,
            cfg.batch,
            cfg.format,
            Some(cfg.sparsity),
            &cfg.obs,
        )?;
        parity_ok &= s.parity_ok;
        Some(s)
    } else {
        None
    };

    let kv_speedup = kv1.tokens_per_s / recompute.tokens_per_s.max(1e-12);
    let sparse_speedup = csr.b1.tokens_per_s / kv_pruned1.tokens_per_s.max(1e-12);
    let nm_speedup = nm.as_ref().map(|s| s.b1.tokens_per_s / csr.b1.tokens_per_s.max(1e-12));
    let nm_storage_ratio = nm.as_ref().map(|s| s.storage_ratio);
    let mut paths = vec![recompute, kv1, kvb, kv_pruned1, csr.b1.clone(), csr.bb.clone()];
    if let Some(s) = &nm {
        paths.push(s.b1.clone());
        paths.push(s.bb.clone());
    }
    Ok(ServeBenchReport {
        model: spec.name(),
        sparsity_label: cfg.sparsity.label(),
        format_label: cfg.format.label().to_string(),
        paths,
        kv_speedup,
        sparse_speedup,
        nm_speedup,
        csr_storage_ratio: csr.storage_ratio,
        nm_storage_ratio,
        parity_ok,
    })
}

/// The paged-KV axis, measured on two workloads:
///
/// * **memory** — a half-full batch of short requests on a paged engine:
///   peak resident KV bytes (pages actually touched) vs what the old
///   monolithic pool preallocated for the same engine (`slots` ×
///   full-context blocks);
/// * **prefill stall** — a long prompt joining an active decode batch:
///   per-step wall-time p99 with chunked prefill (`prefill_chunk`
///   positions per step, decode interleaved) vs the whole prompt
///   prefilled in one step (the old admission behaviour).
///
/// Greedy parity against `eval::generate` is checked on every stream of
/// both workloads, chunked and unchunked.
#[derive(Clone, Debug)]
pub struct PagedBenchReport {
    pub model: String,
    pub kv_page: usize,
    pub prefill_chunk: usize,
    /// Peak KV bytes allocated serving the half-full short batch.
    pub kv_resident_bytes: usize,
    /// Bytes the monolithic pool preallocated for the same engine.
    pub monolithic_kv_bytes: usize,
    /// Decode throughput of the chunked stall workload.
    pub tokens_per_s: f64,
    /// p99 engine-step wall ms around the long-prompt admission, chunked…
    pub chunked_step_p99_ms: f64,
    /// …vs whole-prompt-in-one-step.
    pub unchunked_step_p99_ms: f64,
    pub parity_ok: bool,
}

impl PagedBenchReport {
    /// resident / monolithic — the serving-time KV memory-conservation
    /// ratio (the weight-side counterpart is the artifact bench).
    pub fn kv_resident_ratio(&self) -> f64 {
        self.kv_resident_bytes as f64 / self.monolithic_kv_bytes.max(1) as f64
    }

    /// chunked / unchunked step p99 — how much of the prefill stall the
    /// chunking removed (lower is better).
    pub fn stall_ratio(&self) -> f64 {
        self.chunked_step_p99_ms / self.unchunked_step_p99_ms.max(1e-12)
    }

    pub fn print(&self) {
        println!(
            "paged-bench ({}, page {} × chunk {})",
            self.model, self.kv_page, self.prefill_chunk
        );
        println!(
            "  KV resident (half-full short batch): {} B vs monolithic {} B ({:.3}x)",
            self.kv_resident_bytes,
            self.monolithic_kv_bytes,
            self.kv_resident_ratio()
        );
        println!(
            "  prefill-stall step p99: chunked {:.2} ms vs unchunked {:.2} ms ({:.3}x)   \
             tok/s {:.1}   greedy parity: {}",
            self.chunked_step_p99_ms,
            self.unchunked_step_p99_ms,
            self.stall_ratio(),
            self.tokens_per_s,
            if self.parity_ok { "ok" } else { "MISMATCH" }
        );
    }

    /// JSON object for BENCH_paged.json (the CI record of resident KV
    /// bytes and the prefill-stall axis next to tokens/s).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("model".to_string(), Json::Str(self.model.clone()));
        m.insert("kv_page".to_string(), Json::Num(self.kv_page as f64));
        m.insert("prefill_chunk".to_string(), Json::Num(self.prefill_chunk as f64));
        m.insert("kv_resident_bytes".to_string(), Json::Num(self.kv_resident_bytes as f64));
        m.insert(
            "monolithic_kv_bytes".to_string(),
            Json::Num(self.monolithic_kv_bytes as f64),
        );
        m.insert("kv_resident_ratio".to_string(), Json::Num(round3(self.kv_resident_ratio())));
        m.insert("tokens_per_s".to_string(), Json::Num(round3(self.tokens_per_s)));
        m.insert(
            "prefill_stall_p99_ms".to_string(),
            Json::Num(round3(self.chunked_step_p99_ms)),
        );
        m.insert(
            "unchunked_stall_p99_ms".to_string(),
            Json::Num(round3(self.unchunked_step_p99_ms)),
        );
        m.insert("stall_ratio".to_string(), Json::Num(round3(self.stall_ratio())));
        m.insert("parity_ok".to_string(), Json::Bool(self.parity_ok));
        Json::Obj(m)
    }
}

/// The stall workload: `shorts` decode from step 0; after two warm
/// steps the long request is submitted; every step from then on is
/// timed. Returns (step p99 ms, decode tokens/s over the whole run,
/// id → text).
fn stall_run(
    model: &ServeModel<'_>,
    cfg: &EngineConfig,
    shorts: &[ServeRequest],
    long: &ServeRequest,
) -> Result<(f64, f64, BTreeMap<String, String>)> {
    let clock = cfg.clock.clone().unwrap_or_default();
    let mut eng = Engine::new(model, cfg)?;
    for r in shorts {
        eng.submit(r.clone())?;
    }
    let start = clock.now_ms();
    for _ in 0..2 {
        eng.step()?;
    }
    eng.submit(long.clone())?;
    let mut step_ms = Vec::new();
    let mut responses = eng.take_responses();
    while !eng.is_idle() {
        let t0 = clock.now_ms();
        eng.step()?;
        step_ms.push(clock.now_ms() - t0);
        responses.extend(eng.take_responses());
    }
    responses.extend(eng.take_responses());
    let wall_s = (clock.now_ms() - start) / 1e3;
    let total_tokens: usize = responses.iter().map(|r| r.completion_tokens).sum();
    let texts = responses.into_iter().map(|r| (r.id, r.text)).collect();
    Ok((percentile(&step_ms, 99.0), total_tokens as f64 / wall_s.max(1e-12), texts))
}

/// Measure the paged axis; see [`PagedBenchReport`]. Runs on the dense
/// weights — paging is a cache-layout property, independent of the
/// weight format.
pub fn run_paged_bench(
    spec: &ModelSpec,
    dense: &ModelParams,
    cfg: &ServeBenchConfig,
) -> Result<PagedBenchReport> {
    ensure!(cfg.tokens >= 1 && cfg.batch >= 1 && cfg.requests >= 1, "bench sizes must be >= 1");
    ensure!(
        cfg.tokens + 2 < spec.seq,
        "paged bench needs tokens ({}) well inside the context ({})",
        cfg.tokens,
        spec.seq
    );
    let model = ServeModel::dense(spec, dense)?;
    let slots = cfg.batch.max(2);
    let mut parity_ok = true;

    // memory workload: half-full batch of short requests
    let half_n = (slots / 2).max(1);
    let prompts = synthetic_prompts(half_n);
    let requests = requests_for(&prompts, cfg.tokens);
    let obs_clock = cfg.obs.clock();
    let (reference, _) = greedy_references(spec, dense, &requests, &prompts, &obs_clock);
    let mut mem_cfg = EngineConfig {
        max_batch: slots,
        queue_cap: half_n,
        kv_page: cfg.kv_page,
        kv_pages: None,
        prefill_chunk: cfg.prefill_chunk,
        ..EngineConfig::default()
    };
    cfg.obs.apply(&mut mem_cfg);
    let (half, texts) = run_engine_cfg(&model, &mem_cfg, "paged half-batch", &requests)?;
    parity_ok &= parity_against(&reference, &[&texts]);
    let monolithic_kv_bytes = spec.layers * 2 * 4 * spec.seq * spec.d * slots;

    // stall workload: long prompt joins slots-1 decoding shorts
    let short_n = slots - 1;
    let mut prompts = synthetic_prompts(short_n);
    let mut requests = requests_for(&prompts, cfg.tokens);
    let long_len = (spec.seq - cfg.tokens - 1).max(2);
    let long_prompt: String =
        "abcdefghijklmnopqrstuvwxyz ".chars().cycle().take(long_len).collect();
    let long = ServeRequest {
        id: "long".to_string(),
        prompt: long_prompt.clone(),
        max_tokens: cfg.tokens,
        temperature: 0.0,
        seed: 7,
        stop: None,
    };
    prompts.push(long_prompt);
    requests.push(long.clone());
    let (stall_ref, _) = greedy_references(spec, dense, &requests, &prompts, &obs_clock);
    let shorts = &requests[..short_n];
    let mut chunked_cfg = EngineConfig {
        max_batch: slots,
        queue_cap: slots,
        kv_page: cfg.kv_page,
        kv_pages: None,
        prefill_chunk: cfg.prefill_chunk,
        ..EngineConfig::default()
    };
    cfg.obs.apply(&mut chunked_cfg);
    let (chunked_p99, tok_s, chunked_texts) = stall_run(&model, &chunked_cfg, shorts, &long)?;
    // unchunked = the whole prompt in one step's budget (old behaviour)
    let unchunked_cfg = EngineConfig { prefill_chunk: spec.seq, ..chunked_cfg };
    let (unchunked_p99, _, unchunked_texts) = stall_run(&model, &unchunked_cfg, shorts, &long)?;
    parity_ok &= parity_against(&stall_ref, &[&chunked_texts, &unchunked_texts]);

    Ok(PagedBenchReport {
        model: spec.name(),
        kv_page: cfg.kv_page,
        prefill_chunk: cfg.prefill_chunk,
        kv_resident_bytes: half.kv_resident_bytes,
        monolithic_kv_bytes,
        tokens_per_s: tok_s,
        chunked_step_p99_ms: chunked_p99,
        unchunked_step_p99_ms: unchunked_p99,
        parity_ok,
    })
}

/// The artifact serving path, measured: load a sparse artifact (timed),
/// serve it at batch 1 and batch `cfg.batch`, and report the
/// memory-conservation numbers — on-disk bytes and resident weight bytes
/// against what the equivalent dense checkpoint would cost. Greedy parity
/// is checked against the compiled full-recompute forward
/// (`sparse::compiled_generate`) over the *same loaded weights*, so the
/// gate holds without ever materializing a dense pruned operator.
#[derive(Clone, Debug)]
pub struct ArtifactBenchReport {
    pub model: String,
    pub sparsity_label: String,
    /// Resolved storage format of the loaded operators.
    pub format_label: String,
    /// Wall time of `ser::artifact::load` (parse + checksum + validate).
    pub load_ms: f64,
    /// On-disk bytes of the `.fsa` payload.
    pub file_bytes: u64,
    /// On-disk bytes the dense `.fpt` checkpoint of this model costs
    /// (exact `ser::tensorfile` encoding, computed from the spec).
    pub dense_ckpt_bytes: u64,
    /// Weight bytes resident after load: compressed ops + residual dense.
    pub resident_bytes: usize,
    /// Resident bytes the dense weights would occupy (4 × param count).
    pub dense_resident_bytes: usize,
    pub paths: Vec<PathStats>,
    pub parity_ok: bool,
}

impl ArtifactBenchReport {
    /// resident / dense-resident — the serving memory-conservation ratio.
    pub fn resident_ratio(&self) -> f64 {
        self.resident_bytes as f64 / self.dense_resident_bytes.max(1) as f64
    }

    /// on-disk / dense-checkpoint — the storage-conservation ratio.
    pub fn disk_ratio(&self) -> f64 {
        self.file_bytes as f64 / self.dense_ckpt_bytes.max(1) as f64
    }

    pub fn print(&self) {
        let mut t = TableBuilder::new(
            &format!(
                "artifact-bench ({}, {} @ {})",
                self.model, self.format_label, self.sparsity_label
            ),
            &["path", "reqs", "tokens", "tok/s", "p50 ms", "p99 ms", "GB/s"],
        );
        for p in &self.paths {
            t.row(vec![
                p.label.clone(),
                p.requests.to_string(),
                p.total_tokens.to_string(),
                format!("{:.1}", p.tokens_per_s),
                format!("{:.1}", p.p50_ms),
                format!("{:.1}", p.p99_ms),
                format!("{:.2}", p.eff_gb_per_s),
            ]);
        }
        t.print();
        println!(
            "artifact load: {:.1} ms   on disk: {} B ({:.3}x dense ckpt {} B)   resident: {} B \
             ({:.3}x dense {} B)   greedy parity: {}",
            self.load_ms,
            self.file_bytes,
            self.disk_ratio(),
            self.dense_ckpt_bytes,
            self.resident_bytes,
            self.resident_ratio(),
            self.dense_resident_bytes,
            if self.parity_ok { "ok" } else { "MISMATCH" }
        );
    }

    /// JSON object for BENCH_artifact.json (the CI record of load time
    /// and on-disk size vs the dense checkpoint).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("model".to_string(), Json::Str(self.model.clone()));
        m.insert("sparsity".to_string(), Json::Str(self.sparsity_label.clone()));
        m.insert("format".to_string(), Json::Str(self.format_label.clone()));
        m.insert("load_ms".to_string(), Json::Num(round3(self.load_ms)));
        m.insert("file_bytes".to_string(), Json::Num(self.file_bytes as f64));
        m.insert("dense_ckpt_bytes".to_string(), Json::Num(self.dense_ckpt_bytes as f64));
        m.insert("disk_ratio".to_string(), Json::Num(round3(self.disk_ratio())));
        m.insert("resident_bytes".to_string(), Json::Num(self.resident_bytes as f64));
        m.insert(
            "dense_resident_bytes".to_string(),
            Json::Num(self.dense_resident_bytes as f64),
        );
        m.insert("resident_ratio".to_string(), Json::Num(round3(self.resident_ratio())));
        m.insert("parity_ok".to_string(), Json::Bool(self.parity_ok));
        let mut paths = BTreeMap::new();
        for p in &self.paths {
            let mut pm = BTreeMap::new();
            pm.insert("requests".to_string(), Json::Num(p.requests as f64));
            pm.insert("total_tokens".to_string(), Json::Num(p.total_tokens as f64));
            pm.insert("tokens_per_s".to_string(), Json::Num(round3(p.tokens_per_s)));
            pm.insert("p50_ms".to_string(), Json::Num(round3(p.p50_ms)));
            pm.insert("p99_ms".to_string(), Json::Num(round3(p.p99_ms)));
            pm.insert("kv_resident_bytes".to_string(), Json::Num(p.kv_resident_bytes as f64));
            pm.insert(
                "weight_bytes_moved".to_string(),
                Json::Num(p.weight_bytes_moved as f64),
            );
            pm.insert("eff_gb_per_s".to_string(), Json::Num(round3(p.eff_gb_per_s)));
            paths.insert(p.label.clone(), Json::Obj(pm));
        }
        m.insert("paths".to_string(), Json::Obj(paths));
        Json::Obj(m)
    }
}

/// Load `path` and measure the artifact serving path; see
/// [`ArtifactBenchReport`]. Only `tokens`, `batch` and `requests` of
/// `cfg` are used — sparsity and format come from the artifact itself.
/// `expected_model` is the caller's `--model` flag, if any, checked
/// against the artifact's sidecar.
pub fn run_artifact_bench(
    path: &std::path::Path,
    cfg: &ServeBenchConfig,
    expected_model: Option<&str>,
) -> Result<ArtifactBenchReport> {
    ensure!(cfg.tokens >= 1 && cfg.batch >= 1 && cfg.requests >= 1, "bench sizes must be >= 1");
    let clock = cfg.obs.clock();
    let t0 = clock.now_ms();
    let (compiled, meta) = crate::ser::artifact::load(path)?;
    crate::ser::artifact::check_model(&meta, expected_model)?;
    let load_ms = clock.now_ms() - t0;
    let spec = compiled.spec.clone();

    let prompts = synthetic_prompts(cfg.requests);
    let requests = requests_for(&prompts, cfg.tokens);
    // the oracle runs over the loaded weights themselves: compiled
    // full-recompute greedy generate, no dense operators anywhere
    let mut reference: BTreeMap<String, String> = BTreeMap::new();
    for (r, p) in requests.iter().zip(&prompts) {
        reference.insert(
            r.id.clone(),
            crate::sparse::compiled_generate(
                &compiled,
                p,
                &GenOptions { max_tokens: r.max_tokens, temperature: 0.0, seed: r.seed },
            ),
        );
    }
    let model = ServeModel::from_compiled_ref(&compiled);
    let label = model.format_label();
    let (b1, texts1) =
        run_engine(&model, 1, &format!("artifact {label} b=1"), &requests, &cfg.obs)?;
    let (bb, textsb) = run_engine(
        &model,
        cfg.batch,
        &format!("artifact {label} b={}", cfg.batch),
        &requests,
        &cfg.obs,
    )?;
    let parity_ok = parity_against(&reference, &[&texts1, &textsb]);
    let file_bytes = std::fs::metadata(path)?.len();
    let dense_ckpt_bytes = crate::ser::tensorfile::encoded_len(
        crate::model::spec::model_param_specs(&spec)
            .iter()
            .map(|s| (s.name.as_str(), s.shape.as_slice())),
    ) as u64;
    Ok(ArtifactBenchReport {
        model: spec.name(),
        sparsity_label: meta.sparsity.clone(),
        format_label: label.to_string(),
        load_ms,
        file_bytes,
        dense_ckpt_bytes,
        resident_bytes: compiled.resident_bytes(),
        dense_resident_bytes: 4 * crate::model::spec::param_count(&spec),
        paths: vec![b1, bb],
        parity_ok,
    })
}

// ---------------------------------------------------------------------------
// Kernel axis (`serve-bench --kernel ...`): tokens/s, resident weight bytes,
// and effective weight bandwidth per (kernel variant × quantization) cell.

/// One (kernel variant × quantization) cell of [`run_kernel_bench`].
#[derive(Clone, Debug)]
pub struct KernelBenchRow {
    pub kernel: &'static str,
    pub quant: &'static str,
    /// Resolved storage format of the compiled operators.
    pub format: String,
    /// Weight bytes resident: compressed ops + residual dense.
    pub resident_bytes: usize,
    pub stats: PathStats,
    /// This cell's served outputs equalled its compiled full-recompute
    /// references (generated under the same kernel variant).
    pub parity_ok: bool,
}

/// The BENCH_kernel.json record: every requested (kernel × quant) cell
/// measured over the same pruned weights.
#[derive(Clone, Debug)]
pub struct KernelBenchReport {
    pub model: String,
    pub sparsity_label: String,
    /// The requested format axis ("csr" | "nm" | "auto").
    pub format_label: String,
    pub rows: Vec<KernelBenchRow>,
    /// Every row's parity gate held (false for an empty grid).
    pub parity_ok: bool,
}

impl KernelBenchReport {
    pub fn print(&self) {
        let mut t = TableBuilder::new(
            &format!(
                "kernel-bench ({}, {} @ {})",
                self.model, self.format_label, self.sparsity_label
            ),
            &["kernel", "quant", "format", "tok/s", "resident B", "moved B", "GB/s", "parity"],
        );
        for r in &self.rows {
            t.row(vec![
                r.kernel.to_string(),
                r.quant.to_string(),
                r.format.clone(),
                format!("{:.1}", r.stats.tokens_per_s),
                r.resident_bytes.to_string(),
                r.stats.weight_bytes_moved.to_string(),
                format!("{:.2}", r.stats.eff_gb_per_s),
                if r.parity_ok { "ok" } else { "MISMATCH" }.to_string(),
            ]);
        }
        t.print();
        println!(
            "greedy parity vs compiled recompute (same kernels per cell): {}",
            if self.parity_ok { "ok" } else { "MISMATCH" }
        );
    }

    /// JSON object for BENCH_kernel.json (the CI record of tokens/s and
    /// bytes moved per kernel/quant cell).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("model".to_string(), Json::Str(self.model.clone()));
        m.insert("sparsity".to_string(), Json::Str(self.sparsity_label.clone()));
        m.insert("format".to_string(), Json::Str(self.format_label.clone()));
        m.insert("parity_ok".to_string(), Json::Bool(self.parity_ok));
        let mut rows = BTreeMap::new();
        for r in &self.rows {
            let mut rm = BTreeMap::new();
            rm.insert("format".to_string(), Json::Str(r.format.clone()));
            rm.insert("tokens_per_s".to_string(), Json::Num(round3(r.stats.tokens_per_s)));
            rm.insert("p50_ms".to_string(), Json::Num(round3(r.stats.p50_ms)));
            rm.insert("p99_ms".to_string(), Json::Num(round3(r.stats.p99_ms)));
            rm.insert("resident_bytes".to_string(), Json::Num(r.resident_bytes as f64));
            rm.insert(
                "weight_bytes_moved".to_string(),
                Json::Num(r.stats.weight_bytes_moved as f64),
            );
            rm.insert("eff_gb_per_s".to_string(), Json::Num(round3(r.stats.eff_gb_per_s)));
            rm.insert("parity_ok".to_string(), Json::Bool(r.parity_ok));
            rows.insert(format!("{}/{}", r.kernel, r.quant), Json::Obj(rm));
        }
        m.insert("rows".to_string(), Json::Obj(rows));
        Json::Obj(m)
    }
}

/// Measure every requested (kernel variant × quantization) cell over a
/// copy of `dense` pruned to `cfg.sparsity`: compile the pruned model
/// once per quant mode, select the kernel variant process-wide, rebuild
/// the greedy references through `sparse::compiled_generate` under that
/// same variant (so the gate checks serving == full recompute with
/// identical kernels, which holds bitwise for every variant), and serve
/// at batch `cfg.batch`. The previously selected variant is restored
/// before returning, on success and on error alike.
pub fn run_kernel_bench(
    spec: &ModelSpec,
    dense: &ModelParams,
    cfg: &ServeBenchConfig,
    kernels: &[KernelVariant],
    quants: &[QuantMode],
) -> Result<KernelBenchReport> {
    ensure!(cfg.tokens >= 1 && cfg.batch >= 1 && cfg.requests >= 1, "bench sizes must be >= 1");
    ensure!(
        !kernels.is_empty() && !quants.is_empty(),
        "kernel bench needs at least one kernel and one quant mode"
    );
    if cfg.format == SparseFormat::Nm && !matches!(cfg.sparsity, Sparsity::Semi(..)) {
        bail!(
            "the nm format axis needs an n:m sparsity (e.g. 2:4), got {}",
            cfg.sparsity.label()
        );
    }
    let prompts = synthetic_prompts(cfg.requests);
    let requests = requests_for(&prompts, cfg.tokens);
    let pruned = round_model_to_sparsity(spec, dense, cfg.sparsity)?;
    let sp = matches!(cfg.sparsity, Sparsity::Semi(..)).then_some(cfg.sparsity);
    let prev = par::kernel_variant();
    let mut rows = Vec::new();
    let mut run = || -> Result<()> {
        for &quant in quants {
            let compiled = crate::sparse::CompiledLayers::compress_quantized(
                spec, &pruned, cfg.format, sp, quant,
            )?;
            let model = ServeModel::from_compiled_ref(&compiled);
            for &kernel in kernels {
                par::set_kernel_variant(kernel)?;
                let mut reference: BTreeMap<String, String> = BTreeMap::new();
                for (r, p) in requests.iter().zip(&prompts) {
                    let opts =
                        GenOptions { max_tokens: r.max_tokens, temperature: 0.0, seed: r.seed };
                    let text = crate::sparse::compiled_generate(&compiled, p, &opts);
                    reference.insert(r.id.clone(), text);
                }
                let label = format!("{}/{}", kernel.label(), quant.label());
                let (stats, texts) = run_engine(&model, cfg.batch, &label, &requests, &cfg.obs)?;
                rows.push(KernelBenchRow {
                    kernel: kernel.label(),
                    quant: quant.label(),
                    format: model.format_label().to_string(),
                    resident_bytes: compiled.resident_bytes(),
                    stats,
                    parity_ok: parity_against(&reference, &[&texts]),
                });
            }
        }
        Ok(())
    };
    let result = run();
    if let Err(e) = par::set_kernel_variant(prev) {
        bail!("restoring kernel variant {prev:?} after the sweep: {e}");
    }
    result?;
    let parity_ok = !rows.is_empty() && rows.iter().all(|r| r.parity_ok);
    Ok(KernelBenchReport {
        model: spec.name(),
        sparsity_label: cfg.sparsity.label(),
        format_label: cfg.format.label().to_string(),
        rows,
        parity_ok,
    })
}

// ---------------------------------------------------------------------------
// Network axis (`serve-bench --net`): sustained req/s and client-observed
// stream latency under N concurrent loopback connections with churn, over
// the real `serve --listen` front-end — plus the same greedy-parity gate
// as every other bench path.

/// Sizing for the network axis.
pub struct NetBenchConfig {
    /// Concurrent client sessions.
    pub clients: usize,
    /// Requests per client, split across its connections.
    pub requests_per_client: usize,
    /// Connection churn: every client reconnects halfway through its
    /// request budget, and client 0 additionally opens a doomed
    /// connection that vanishes mid-stream (exercising
    /// abort-on-disconnect under load).
    pub churn: bool,
}

impl Default for NetBenchConfig {
    fn default() -> Self {
        NetBenchConfig { clients: 8, requests_per_client: 4, churn: true }
    }
}

/// One client's view of its completed requests.
struct NetClientResult {
    prompt: String,
    seed: u64,
    max_tokens: usize,
    finish: String,
    text: String,
}

#[derive(Default)]
struct NetClientOut {
    latencies_ms: Vec<f64>,
    results: Vec<NetClientResult>,
}

/// The BENCH_net.json record.
pub struct NetBenchReport {
    pub model: String,
    pub clients: usize,
    pub requests_per_client: usize,
    pub batch: usize,
    pub churn: bool,
    pub completed: usize,
    pub wall_s: f64,
    /// Completed requests per wall second across all clients.
    pub req_per_s: f64,
    /// Client-observed submit-to-response latency percentiles (queue wait
    /// included — this is the stream p99 a real client sees).
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub accepted_conns: u64,
    pub closed_conns: u64,
    pub aborted_by_disconnect: u64,
    pub timed_out_conns: u64,
    pub parity_ok: bool,
}

impl NetBenchReport {
    pub fn print(&self) {
        println!(
            "net-bench ({}, {} clients × {} reqs, batch {}, churn {})",
            self.model,
            self.clients,
            self.requests_per_client,
            self.batch,
            if self.churn { "on" } else { "off" }
        );
        println!(
            "  sustained {:.1} req/s   stream p50 {:.1} ms   p99 {:.1} ms   wall {:.2} s",
            self.req_per_s, self.p50_ms, self.p99_ms, self.wall_s
        );
        println!(
            "  conns: accepted={} closed={} aborted_by_disconnect={} timed_out={}",
            self.accepted_conns, self.closed_conns, self.aborted_by_disconnect, self.timed_out_conns
        );
        println!(
            "  greedy parity vs eval::generate: {}",
            if self.parity_ok { "ok" } else { "MISMATCH" }
        );
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("model".to_string(), Json::Str(self.model.clone()));
        m.insert("clients".to_string(), Json::Num(self.clients as f64));
        m.insert(
            "requests_per_client".to_string(),
            Json::Num(self.requests_per_client as f64),
        );
        m.insert("batch".to_string(), Json::Num(self.batch as f64));
        m.insert("churn".to_string(), Json::Bool(self.churn));
        m.insert("completed".to_string(), Json::Num(self.completed as f64));
        m.insert("wall_s".to_string(), Json::Num(round3(self.wall_s)));
        m.insert("req_per_s".to_string(), Json::Num(round3(self.req_per_s)));
        m.insert("stream_p50_ms".to_string(), Json::Num(round3(self.p50_ms)));
        m.insert("stream_p99_ms".to_string(), Json::Num(round3(self.p99_ms)));
        m.insert("accepted_conns".to_string(), Json::Num(self.accepted_conns as f64));
        m.insert("closed_conns".to_string(), Json::Num(self.closed_conns as f64));
        m.insert(
            "aborted_by_disconnect".to_string(),
            Json::Num(self.aborted_by_disconnect as f64),
        );
        m.insert("timed_out_conns".to_string(), Json::Num(self.timed_out_conns as f64));
        m.insert("parity_ok".to_string(), Json::Bool(self.parity_ok));
        Json::Obj(m)
    }
}

/// One client session: optionally a doomed mid-stream-disconnect
/// connection (client 0 under churn), then its request budget pipelined
/// over one or two sequential connections. Latency is measured from the
/// request's send to its response line — the stream latency a real
/// client observes, queue wait included.
fn net_client_session(
    addr: std::net::SocketAddr,
    ci: usize,
    reqs_per_client: usize,
    tokens: usize,
    churn: bool,
    clock: SharedClock,
) -> Result<NetClientOut> {
    use std::io::{BufRead, Write};
    use std::net::TcpStream;
    use std::time::Duration;

    use anyhow::Context as _;

    let mut out = NetClientOut::default();
    if churn && ci == 0 {
        // the doomed connection: a long request, then vanish unread
        let mut s = TcpStream::connect(addr)?;
        let req = ServeRequest {
            id: "doomed".into(),
            prompt: "doomed: the ".into(),
            max_tokens: tokens.max(24),
            temperature: 0.0,
            seed: 999,
            stop: None,
        };
        writeln!(s, "{}", req.to_json_line())?;
        s.flush()?;
        std::thread::sleep(Duration::from_millis(20));
        drop(s);
    }
    let conns = if churn { 2usize } else { 1 };
    let per = reqs_per_client.div_ceil(conns);
    let mut k = 0usize;
    while k < reqs_per_client {
        let take = per.min(reqs_per_client - k);
        let mut stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(Duration::from_secs(60)))?;
        let mut sent: BTreeMap<String, f64> = BTreeMap::new();
        let mut meta: BTreeMap<String, (String, u64)> = BTreeMap::new();
        for j in 0..take {
            let id = format!("c{ci}-k{}", k + j);
            let prompt = format!("req {ci}-{}: the ", k + j);
            let seed = (ci * 100 + k + j) as u64;
            let req = ServeRequest {
                id: id.clone(),
                prompt: prompt.clone(),
                max_tokens: tokens,
                temperature: 0.0,
                seed,
                stop: None,
            };
            writeln!(stream, "{}", req.to_json_line())?;
            sent.insert(id.clone(), clock.now_ms());
            meta.insert(id, (prompt, seed));
        }
        stream.flush()?;
        let mut reader = std::io::BufReader::new(stream);
        for _ in 0..take {
            let mut line = String::new();
            let n = reader.read_line(&mut line)?;
            ensure!(n > 0, "client {ci}: server closed the stream early");
            let v = Json::parse(line.trim())
                .map_err(|e| anyhow::anyhow!("client {ci}: bad response line: {e}"))?;
            let id = v.get("id").and_then(|x| x.as_str()).unwrap_or("").to_string();
            let t0 = sent
                .get(&id)
                .copied()
                .with_context(|| format!("client {ci}: response for unknown id '{id}'"))?;
            out.latencies_ms.push(clock.now_ms() - t0);
            let (prompt, seed) =
                meta.get(&id).cloned().with_context(|| format!("client {ci}: no meta for '{id}'"))?;
            out.results.push(NetClientResult {
                prompt,
                seed,
                max_tokens: tokens,
                finish: v.get("finish").and_then(|x| x.as_str()).unwrap_or("").to_string(),
                text: v.get("text").and_then(|x| x.as_str()).unwrap_or("").to_string(),
            });
        }
        k += take;
    }
    Ok(out)
}

/// Serve a loopback client fleet through the real `serve --listen` front
/// end and report sustained req/s + stream latency percentiles. Every
/// completed stream is parity-checked against solo `eval::generate`; the
/// doomed connection's request is expected to abort and is excluded (it
/// has no delivered response to check).
pub fn run_net_bench(
    spec: &ModelSpec,
    dense: &ModelParams,
    cfg: &ServeBenchConfig,
    net: &NetBenchConfig,
) -> Result<NetBenchReport> {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;
    use std::time::Duration;

    use anyhow::Context as _;

    use crate::serve::net::{NetConfig, NetServer};

    ensure!(net.clients >= 1 && net.requests_per_client >= 1, "net bench sizes must be >= 1");
    ensure!(
        cfg.tokens + 24 < spec.seq,
        "net bench needs tokens ({}) well inside the context ({})",
        cfg.tokens,
        spec.seq
    );
    let model = ServeModel::dense(spec, dense)?;
    let mut ecfg = EngineConfig {
        max_batch: cfg.batch,
        queue_cap: (net.clients * net.requests_per_client + 8).max(16),
        kv_page: cfg.kv_page,
        kv_pages: None,
        prefill_chunk: cfg.prefill_chunk,
        ..EngineConfig::default()
    };
    cfg.obs.apply(&mut ecfg);
    let ncfg = NetConfig {
        max_conns: net.clients * 2 + 4,
        conn_timeout: Duration::from_secs(10),
        ..NetConfig::default()
    };
    let server = NetServer::bind("127.0.0.1:0", ncfg)?;
    let addr = server.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let clock = cfg.obs.clock();
    let start = clock.now_ms();
    let mut wall_s = 0.0;
    let mut client_outs: Vec<NetClientOut> = Vec::new();
    let mut net_report = None;
    let (model_ref, ecfg_ref, server_ref) = (&model, &ecfg, &server);
    std::thread::scope(|s| -> Result<()> {
        let stop_server = stop.clone();
        // fp-lint: allow(det-spawn) — scoped bench server thread, joined below
        let sh = s.spawn(move || server_ref.run(model_ref, ecfg_ref, stop_server));
        let handles: Vec<_> = (0..net.clients)
            .map(|ci| {
                let (rpc, toks, churn) = (net.requests_per_client, cfg.tokens, net.churn);
                let clk = clock.clone();
                // fp-lint: allow(det-spawn) — scoped bench client fleet, joined below
                s.spawn(move || net_client_session(addr, ci, rpc, toks, churn, clk))
            })
            .collect();
        let mut client_err = None;
        for h in handles {
            match h.join() {
                Ok(Ok(o)) => client_outs.push(o),
                Ok(Err(e)) => client_err = Some(e),
                Err(_) => client_err = Some(anyhow::anyhow!("net bench client panicked")),
            }
        }
        wall_s = (clock.now_ms() - start) / 1e3;
        stop.store(true, Ordering::Relaxed);
        match sh.join() {
            Ok(r) => net_report = Some(r?),
            Err(_) => bail!("net server thread panicked"),
        }
        match client_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    })?;
    let report = net_report.context("net server produced no report")?;

    let mut parity_ok = true;
    let mut completed = 0usize;
    let mut latencies: Vec<f64> = Vec::new();
    for c in &client_outs {
        latencies.extend_from_slice(&c.latencies_ms);
        for r in &c.results {
            if r.finish != "length" {
                parity_ok = false;
                continue;
            }
            completed += 1;
            let want = generate(
                spec,
                dense,
                &r.prompt,
                &GenOptions { max_tokens: r.max_tokens, temperature: 0.0, seed: r.seed },
            );
            if want != r.text {
                parity_ok = false;
            }
        }
    }
    if completed != net.clients * net.requests_per_client {
        parity_ok = false;
    }
    let net_qs = percentiles(&latencies, &[50.0, 99.0]);

    Ok(NetBenchReport {
        model: spec.name(),
        clients: net.clients,
        requests_per_client: net.requests_per_client,
        batch: cfg.batch,
        churn: net.churn,
        completed,
        wall_s,
        req_per_s: completed as f64 / wall_s.max(1e-9),
        p50_ms: net_qs[0],
        p99_ms: net_qs[1],
        accepted_conns: report.counters.get("accepted"),
        closed_conns: report.counters.get("closed"),
        aborted_by_disconnect: report.counters.get("aborted_by_disconnect"),
        timed_out_conns: report.counters.get("timed_out"),
        parity_ok,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{repo_root, Presets};
    use crate::model::init::init_params;

    #[test]
    fn smoke_report_is_consistent() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap().clone();
        let params = init_params(&spec, 29);
        let cfg = ServeBenchConfig {
            tokens: 6,
            batch: 2,
            requests: 2,
            sparsity: Sparsity::Unstructured(0.5),
            format: SparseFormat::Csr,
            ..ServeBenchConfig::default()
        };
        let report = run_serve_bench(&spec, &params, &cfg).unwrap();
        assert!(report.parity_ok, "served outputs diverged from eval::generate");
        assert_eq!(report.paths.len(), 6);
        for p in &report.paths {
            assert_eq!(p.total_tokens, 12, "{}", p.label);
            assert!(p.tokens_per_s > 0.0);
        }
        assert!(report.nm_speedup.is_none());
        let j = report.to_json().to_string_compact();
        let v = Json::parse(&j).unwrap();
        assert_eq!(v.get("parity_ok").unwrap().as_bool(), Some(true));
        assert!(v.get("paths").unwrap().get("kv dense b=1").is_some());
        assert!(v.get("nm_speedup").is_none());
    }

    #[test]
    fn paged_bench_reports_memory_and_stall_axes() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap().clone();
        let params = init_params(&spec, 41);
        let cfg = ServeBenchConfig {
            tokens: 6,
            batch: 4,
            requests: 2,
            kv_page: 8,
            prefill_chunk: 8,
            ..ServeBenchConfig::default()
        };
        let report = run_paged_bench(&spec, &params, &cfg).unwrap();
        assert!(report.parity_ok, "paged serving diverged from eval::generate");
        assert_eq!(report.kv_page, 8);
        // the acceptance number: a half-full batch of short requests
        // must stay measurably under the monolithic preallocation
        assert!(
            report.kv_resident_bytes < report.monolithic_kv_bytes / 2,
            "resident {} vs monolithic {}",
            report.kv_resident_bytes,
            report.monolithic_kv_bytes
        );
        assert!(report.kv_resident_bytes > 0);
        assert!(report.chunked_step_p99_ms > 0.0 && report.unchunked_step_p99_ms > 0.0);
        let j = report.to_json().to_string_compact();
        let v = Json::parse(&j).unwrap();
        assert!(v.get("kv_resident_bytes").unwrap().as_f64().is_some());
        assert!(v.get("prefill_stall_p99_ms").unwrap().as_f64().is_some());
        assert_eq!(v.get("parity_ok").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn nm_axis_reports_both_formats() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap().clone();
        let params = init_params(&spec, 31);
        let cfg = ServeBenchConfig {
            tokens: 6,
            batch: 2,
            requests: 2,
            sparsity: Sparsity::Semi(2, 4),
            format: SparseFormat::Nm,
            ..ServeBenchConfig::default()
        };
        let report = run_serve_bench(&spec, &params, &cfg).unwrap();
        assert!(report.parity_ok, "served outputs diverged from eval::generate");
        assert_eq!(report.paths.len(), 8);
        let labels: Vec<&str> = report.paths.iter().map(|p| p.label.as_str()).collect();
        assert!(labels.contains(&"kv csr b=1"), "{labels:?}");
        assert!(labels.contains(&"kv nm b=1"), "{labels:?}");
        // the packed format must be strictly smaller than CSR at 2:4
        let nm_ratio = report.nm_storage_ratio.unwrap();
        let csr_ratio = report.csr_storage_ratio;
        assert!(nm_ratio < csr_ratio, "nm {nm_ratio} vs csr {csr_ratio}");
        assert!(report.nm_speedup.unwrap() > 0.0);
        let j = report.to_json().to_string_compact();
        let v = Json::parse(&j).unwrap();
        assert_eq!(v.get("format").unwrap().as_str(), Some("nm"));
        assert!(v.get("nm_speedup").unwrap().as_f64().is_some());
        assert!(v.get("paths").unwrap().get("kv nm b=2").is_some());

        // nm format without an n:m sparsity is a config error
        let bad = ServeBenchConfig {
            sparsity: Sparsity::Unstructured(0.5),
            format: SparseFormat::Nm,
            ..ServeBenchConfig::default()
        };
        assert!(run_serve_bench(&spec, &params, &bad).is_err());
    }

    #[test]
    fn artifact_bench_measures_load_and_memory() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap().clone();
        let sp = Sparsity::Semi(2, 4);
        let pruned =
            crate::pruner::round_model_to_sparsity(&spec, &init_params(&spec, 37), sp).unwrap();
        let compiled =
            crate::sparse::CompiledLayers::compress(&spec, &pruned, SparseFormat::Auto, Some(sp))
                .unwrap();
        let path = std::env::temp_dir()
            .join(format!("fp_bench_artifact_{}.fsa", std::process::id()));
        crate::ser::artifact::save(
            &path,
            &compiled,
            &crate::ser::artifact::ArtifactMeta {
                model: "topt-s1".into(),
                corpus: "c4-syn".into(),
                method: "magnitude".into(),
                sparsity: sp.label(),
                format: "auto".into(),
                quant: "none".into(),
                seed: 37,
                prune: None,
            },
        )
        .unwrap();
        let cfg = ServeBenchConfig {
            tokens: 6,
            batch: 2,
            requests: 2,
            sparsity: sp,
            format: SparseFormat::Auto,
            ..ServeBenchConfig::default()
        };
        // a wrong --model flag is rejected before any measurement
        assert!(run_artifact_bench(&path, &cfg, Some("topt-s2")).is_err());
        let report = run_artifact_bench(&path, &cfg, None).unwrap();
        assert!(report.parity_ok, "artifact serving diverged from the compiled oracle");
        assert_eq!(report.format_label, "nm");
        assert_eq!(report.paths.len(), 2);
        assert!(report.load_ms >= 0.0);
        assert_eq!(report.resident_bytes, compiled.resident_bytes());
        // a 2:4 artifact must beat the dense checkpoint on disk and the
        // dense weights in memory
        assert!(report.disk_ratio() < 1.0, "disk ratio {}", report.disk_ratio());
        assert!(report.resident_ratio() < 1.0, "resident ratio {}", report.resident_ratio());
        let j = report.to_json().to_string_compact();
        let v = Json::parse(&j).unwrap();
        assert!(v.get("load_ms").unwrap().as_f64().is_some());
        assert!(v.get("paths").unwrap().get("artifact nm b=1").is_some());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(crate::ser::artifact::meta_path(&path)).ok();
    }

    // scalar-only so the global kernel variant is never flipped under
    // the parallel test harness; the simd legs live in the
    // `quant_kernel_parity` integration binary, which serializes them
    #[test]
    fn kernel_bench_reports_quant_grid() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap().clone();
        let params = init_params(&spec, 43);
        let cfg = ServeBenchConfig {
            tokens: 6,
            batch: 2,
            requests: 2,
            sparsity: Sparsity::Semi(2, 4),
            format: SparseFormat::Auto,
            ..ServeBenchConfig::default()
        };
        let report = run_kernel_bench(
            &spec,
            &params,
            &cfg,
            &[KernelVariant::Scalar],
            &[QuantMode::None, QuantMode::F16, QuantMode::Int8],
        )
        .unwrap();
        assert!(report.parity_ok, "kernel bench diverged from compiled recompute");
        assert_eq!(report.rows.len(), 3);
        let by_quant = |q: &str| report.rows.iter().find(|r| r.quant == q).unwrap();
        // quantized values shrink the resident footprint, int8 the most
        assert!(by_quant("f16").resident_bytes < by_quant("none").resident_bytes);
        assert!(by_quant("int8").resident_bytes < by_quant("f16").resident_bytes);
        for r in &report.rows {
            assert_eq!(r.kernel, "scalar");
            assert_eq!(r.format, "nm");
            assert!(r.stats.tokens_per_s > 0.0, "{}/{}", r.kernel, r.quant);
            assert!(r.stats.weight_bytes_moved > 0, "{}/{}", r.kernel, r.quant);
            assert!(r.stats.eff_gb_per_s > 0.0, "{}/{}", r.kernel, r.quant);
        }
        // bytes moved scale with the resident footprint at equal steps,
        // so the int8 cell moves less traffic than the f32 cell
        assert!(
            by_quant("int8").stats.weight_bytes_moved < by_quant("none").stats.weight_bytes_moved
        );
        let j = report.to_json().to_string_compact();
        let v = Json::parse(&j).unwrap();
        assert_eq!(v.get("parity_ok").unwrap().as_bool(), Some(true));
        let rows = v.get("rows").unwrap();
        assert!(rows.get("scalar/int8").is_some());
        assert!(rows.get("scalar/none").unwrap().get("eff_gb_per_s").is_some());

        // an empty grid is a config error, not an empty report
        assert!(run_kernel_bench(&spec, &params, &cfg, &[], &[QuantMode::None]).is_err());
    }
}

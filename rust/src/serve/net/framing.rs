//! Bounded, resumable JSONL framing for untrusted byte streams.
//!
//! `BoundedLineReader` accumulates one newline-terminated line at a time
//! while holding at most `max_len` bytes: once a line crosses the cap its
//! payload is discarded on the fly (the remainder of the line is drained,
//! never stored) and the caller gets `LineOutcome::Oversized` instead of a
//! multi-hundred-megabyte `String`. The reader is resumable — a
//! `WouldBlock`/`TimedOut` error from the underlying stream leaves the
//! partial line buffered so the next call continues where it left off —
//! which is what lets one reader thread interleave line assembly with
//! slowloris deadline checks on a socket with a short read timeout.

use std::io::{self, BufRead};
use std::time::Duration;

use crate::obs::SharedClock;

/// Default per-line byte cap (1 MiB). Generous for JSONL requests whose
/// prompts are bounded by `seq_len` anyway, tiny next to a hostile line.
pub const DEFAULT_MAX_LINE: usize = 1 << 20;

/// One framing step's result. `Oversized`/`NotUtf8`/`TimedOut` all leave
/// the reader reset and ready for the next line.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LineOutcome {
    /// A complete line (without the trailing `\n`/`\r\n`).
    Line(String),
    /// The line exceeded `limit` bytes; `read` bytes were drained and
    /// discarded (including any still in flight past the cap).
    Oversized { limit: usize, read: usize },
    /// The line terminated but was not valid UTF-8.
    NotUtf8,
    /// A per-line deadline expired with `partial` bytes assembled
    /// (slowloris). Only produced when a deadline is configured.
    TimedOut { partial: usize },
    /// Clean end of stream with no partial line pending.
    Eof,
}

/// Stateful line assembler with a byte cap and an optional per-line
/// deadline. See the module docs for the contract.
pub struct BoundedLineReader {
    max_len: usize,
    max_line_time: Option<Duration>,
    clock: SharedClock,
    buf: Vec<u8>,
    dropped: usize,
    oversized: bool,
    /// Clock timestamp (ms) of the current line's first byte.
    line_start: Option<f64>,
}

impl BoundedLineReader {
    pub fn new(max_len: usize) -> Self {
        Self::with_deadline(max_len, None)
    }

    /// `max_line_time` bounds how long a single line may take from its
    /// first byte to its newline; `None` disables the deadline (stdin).
    /// Timestamps come from the default monotonic clock; the serving
    /// stack injects its own via [`BoundedLineReader::with_clock`].
    pub fn with_deadline(max_len: usize, max_line_time: Option<Duration>) -> Self {
        Self::with_clock(max_len, max_line_time, SharedClock::default())
    }

    /// Fully injected constructor: per-line deadlines are measured on
    /// `clock`, so `FakeClock` tests can drive slowloris timeouts without
    /// wall-clock sleeps.
    pub fn with_clock(
        max_len: usize,
        max_line_time: Option<Duration>,
        clock: SharedClock,
    ) -> Self {
        BoundedLineReader {
            max_len: max_len.max(1),
            max_line_time,
            clock,
            buf: Vec::new(),
            dropped: 0,
            oversized: false,
            line_start: None,
        }
    }

    /// True while a partial line is buffered (first byte seen, no newline
    /// yet).
    pub fn in_progress(&self) -> bool {
        self.line_start.is_some()
    }

    /// Bytes of the current partial line seen so far (buffered + drained).
    pub fn partial_len(&self) -> usize {
        self.buf.len() + self.dropped
    }

    /// True when a per-line deadline is configured and the current partial
    /// line has been in flight longer than it. Callers check this after a
    /// `WouldBlock`/`TimedOut` socket error, since `read_line` can only
    /// observe the deadline while bytes are arriving.
    pub fn deadline_exceeded(&self) -> bool {
        match (self.line_start, self.max_line_time) {
            (Some(start), Some(max)) => {
                self.clock.now_ms() - start > max.as_secs_f64() * 1000.0
            }
            _ => false,
        }
    }

    fn reset(&mut self) {
        self.buf = Vec::new();
        self.dropped = 0;
        self.oversized = false;
        self.line_start = None;
    }

    fn finish_line(&mut self) -> LineOutcome {
        if self.oversized {
            let out = LineOutcome::Oversized { limit: self.max_len, read: self.partial_len() };
            self.reset();
            return out;
        }
        let mut bytes = std::mem::take(&mut self.buf);
        self.reset();
        if bytes.last() == Some(&b'\r') {
            bytes.pop();
        }
        match String::from_utf8(bytes) {
            Ok(s) => LineOutcome::Line(s),
            Err(_) => LineOutcome::NotUtf8,
        }
    }

    fn push(&mut self, chunk: &[u8]) {
        if chunk.is_empty() {
            return;
        }
        if self.line_start.is_none() {
            self.line_start = Some(self.clock.now_ms());
        }
        if self.oversized {
            self.dropped += chunk.len();
            return;
        }
        if self.buf.len() + chunk.len() > self.max_len {
            // Cross the cap: drop everything, remember only the count.
            self.dropped = self.buf.len() + chunk.len();
            self.buf = Vec::new();
            self.oversized = true;
        } else {
            self.buf.extend_from_slice(chunk);
        }
    }

    /// Assemble the next line. Returns `Err` only for real I/O errors —
    /// `WouldBlock`/`TimedOut` pass through with the partial line kept, so
    /// the caller can retry (or act on `deadline_exceeded`).
    pub fn read_line<R: BufRead>(&mut self, r: &mut R) -> io::Result<LineOutcome> {
        loop {
            if self.deadline_exceeded() {
                let partial = self.partial_len();
                self.reset();
                return Ok(LineOutcome::TimedOut { partial });
            }
            let (used, found) = {
                let avail = match r.fill_buf() {
                    Ok(a) => a,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => return Err(e),
                };
                if avail.is_empty() {
                    if !self.in_progress() {
                        return Ok(LineOutcome::Eof);
                    }
                    // final unterminated line
                    return Ok(self.finish_line());
                }
                match avail.iter().position(|&b| b == b'\n') {
                    Some(i) => {
                        // fp-lint: allow(hot-index) — i comes from position() on this slice
                        self.push(&avail[..i]);
                        (i + 1, true)
                    }
                    None => {
                        self.push(avail);
                        (avail.len(), false)
                    }
                }
            };
            r.consume(used);
            if found {
                return Ok(self.finish_line());
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufReader, Read};

    #[test]
    fn splits_lines_and_strips_crlf() {
        let data = b"alpha\nbeta\r\n\ngamma".to_vec();
        let mut r = BufReader::new(&data[..]);
        let mut f = BoundedLineReader::new(64);
        assert_eq!(f.read_line(&mut r).unwrap(), LineOutcome::Line("alpha".into()));
        assert_eq!(f.read_line(&mut r).unwrap(), LineOutcome::Line("beta".into()));
        assert_eq!(f.read_line(&mut r).unwrap(), LineOutcome::Line(String::new()));
        // unterminated final line still comes through
        assert_eq!(f.read_line(&mut r).unwrap(), LineOutcome::Line("gamma".into()));
        assert_eq!(f.read_line(&mut r).unwrap(), LineOutcome::Eof);
    }

    #[test]
    fn oversized_line_is_discarded_and_reader_recovers() {
        let mut data = vec![b'x'; 100];
        data.push(b'\n');
        data.extend_from_slice(b"ok\n");
        let mut r = BufReader::new(&data[..]);
        let mut f = BoundedLineReader::new(16);
        assert_eq!(f.read_line(&mut r).unwrap(), LineOutcome::Oversized { limit: 16, read: 100 });
        assert_eq!(f.read_line(&mut r).unwrap(), LineOutcome::Line("ok".into()));
        assert_eq!(f.read_line(&mut r).unwrap(), LineOutcome::Eof);
    }

    #[test]
    fn invalid_utf8_is_a_typed_outcome_not_a_panic() {
        let data = b"\xff\xfe bad\nfine\n".to_vec();
        let mut r = BufReader::new(&data[..]);
        let mut f = BoundedLineReader::new(64);
        assert_eq!(f.read_line(&mut r).unwrap(), LineOutcome::NotUtf8);
        assert_eq!(f.read_line(&mut r).unwrap(), LineOutcome::Line("fine".into()));
    }

    /// Synthetic reader: yields `left` filler bytes, then `tail`, then EOF.
    /// Lets the 100 MB regression run without materialising 100 MB.
    struct BigLine {
        left: usize,
        tail: &'static [u8],
        tail_pos: usize,
    }

    impl Read for BigLine {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            if self.left > 0 {
                let n = out.len().min(self.left).min(8192);
                out[..n].fill(b'a');
                self.left -= n;
                return Ok(n);
            }
            let rest = &self.tail[self.tail_pos..];
            let n = out.len().min(rest.len());
            out[..n].copy_from_slice(&rest[..n]);
            self.tail_pos += n;
            Ok(n)
        }
    }

    #[test]
    fn hundred_megabyte_line_is_bounded_not_ballooned() {
        // Regression for the unbounded read_line allocation: a 100 MB line
        // must surface as a checked Oversized outcome while the reader
        // never buffers more than max_len bytes (push() drops the payload
        // the moment the cap is crossed), and the next line still parses.
        const HUGE: usize = 100 * 1000 * 1000;
        let src = BigLine { left: HUGE, tail: b"\n{\"prompt\":\"x\"}\n", tail_pos: 0 };
        let mut r = BufReader::new(src);
        let mut f = BoundedLineReader::new(DEFAULT_MAX_LINE);
        match f.read_line(&mut r).unwrap() {
            LineOutcome::Oversized { limit, read } => {
                assert_eq!(limit, DEFAULT_MAX_LINE);
                assert_eq!(read, HUGE);
            }
            other => panic!("expected Oversized, got {other:?}"),
        }
        assert_eq!(f.partial_len(), 0, "oversized state must reset");
        assert_eq!(f.read_line(&mut r).unwrap(), LineOutcome::Line("{\"prompt\":\"x\"}".into()));
        assert_eq!(f.read_line(&mut r).unwrap(), LineOutcome::Eof);
    }

    /// Reader that alternates: one byte, then a WouldBlock error — the
    /// socket-with-read-timeout shape the conn reader sees.
    struct Drip {
        data: Vec<u8>,
        pos: usize,
        block_next: bool,
    }

    impl Read for Drip {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            if self.block_next {
                self.block_next = false;
                return Err(io::Error::new(io::ErrorKind::WouldBlock, "drip"));
            }
            if self.pos >= self.data.len() {
                return Ok(0);
            }
            self.block_next = true;
            out[0] = self.data[self.pos];
            self.pos += 1;
            Ok(1)
        }
    }

    #[test]
    fn partial_line_survives_would_block_and_resumes() {
        let src = Drip { data: b"hi\n".to_vec(), pos: 0, block_next: false };
        // BufReader would swallow retries itself only per fill; keep raw.
        let mut r = BufReader::with_capacity(4, src);
        let mut f = BoundedLineReader::new(64);
        let mut line = None;
        for _ in 0..16 {
            match f.read_line(&mut r) {
                Ok(LineOutcome::Line(l)) => {
                    line = Some(l);
                    break;
                }
                Ok(other) => panic!("unexpected outcome {other:?}"),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => continue,
                Err(e) => panic!("unexpected error {e}"),
            }
        }
        assert_eq!(line.as_deref(), Some("hi"));
    }

    #[test]
    fn per_line_deadline_trips_on_a_drip_fed_line() {
        let src = Drip { data: vec![b'z'; 1000], pos: 0, block_next: false };
        let mut r = BufReader::with_capacity(4, src);
        let mut f = BoundedLineReader::with_deadline(64 * 1024, Some(Duration::from_millis(0)));
        // first call starts the line; with a zero deadline the next pass
        // (either inside read_line or via deadline_exceeded) must trip
        let mut timed_out = false;
        for _ in 0..64 {
            match f.read_line(&mut r) {
                Ok(LineOutcome::TimedOut { partial }) => {
                    assert!(partial >= 1);
                    timed_out = true;
                    break;
                }
                Ok(other) => panic!("unexpected outcome {other:?}"),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if f.deadline_exceeded() {
                        timed_out = true;
                        break;
                    }
                }
                Err(e) => panic!("unexpected error {e}"),
            }
        }
        assert!(timed_out, "drip-fed line must hit the per-line deadline");
    }

    #[test]
    fn fake_clock_drives_the_per_line_deadline_without_sleeping() {
        let (clock, fake) = SharedClock::fake();
        let src = Drip { data: vec![b'z'; 8], pos: 0, block_next: false };
        let mut r = BufReader::with_capacity(4, src);
        let mut f =
            BoundedLineReader::with_clock(64, Some(Duration::from_millis(250)), clock);
        // first byte starts the line at fake time 0; within the deadline
        // nothing trips
        match f.read_line(&mut r) {
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => {}
            other => panic!("expected WouldBlock, got {other:?}"),
        }
        assert!(f.in_progress());
        fake.advance_ms(250.0);
        assert!(!f.deadline_exceeded(), "deadline is strict: 250ms elapsed == limit");
        fake.advance_ms(1.0);
        assert!(f.deadline_exceeded());
        // the next read_line pass surfaces the typed outcome and resets
        match f.read_line(&mut r) {
            Ok(LineOutcome::TimedOut { partial }) => assert!(partial >= 1),
            other => panic!("expected TimedOut, got {other:?}"),
        }
        assert!(!f.in_progress(), "timeout must reset the reader");
    }
}

//! Offline replay of a `--event-log` capture: the live/replay split
//! contract.
//!
//! The tee records every complete inbound line and every delivered
//! outbound line with its connection id and a global monotonic `seq`.
//! Feeding the inbound lines — in seq order, through the same
//! id-assignment and namespacing the live dispatch uses — into a fresh
//! engine must reproduce every delivered response byte-for-byte (modulo
//! `latency_ms`, the one wall-clock field, which canonicalization strips).
//! That holds because the engine's determinism contract makes outputs
//! independent of batch composition and admission timing; replay is the
//! test that the *front end* preserved that property.
//!
//! With an injected clock (`EngineConfig::clock`, `obs::FakeClock`) even
//! `latency_ms` is deterministic, so the `_raw` variants compare lines
//! verbatim with no special-casing — the regression test in
//! `rust/tests/net_serve.rs` pins this.
//!
//! Requests that never got a delivered response (client disconnected
//! mid-stream, writer overflow) have no `out` record; replay still runs
//! their inbound lines but the contract only compares keys present in the
//! live tee.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::ser::json::Json;
use crate::serve::engine::{Engine, EngineConfig};
use crate::serve::request::ServeRequest;
use crate::serve::ServeModel;

use super::listener::unmangle_response;

/// One parsed event-log record. Line records carry `dir` + `line`;
/// lifecycle records carry `event` (+ optional `info`).
#[derive(Clone, Debug)]
pub struct LogEntry {
    pub seq: u64,
    pub conn: Option<u64>,
    pub dir: Option<String>,
    pub event: Option<String>,
    pub line: Option<String>,
    pub info: Option<String>,
}

/// Load and seq-sort an event log.
pub fn read_event_log(path: &Path) -> Result<Vec<LogEntry>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading event log {}", path.display()))?;
    let mut entries = Vec::new();
    for (i, raw) in text.lines().enumerate() {
        if raw.trim().is_empty() {
            continue;
        }
        let v = Json::parse(raw).map_err(|e| anyhow::anyhow!("event log line {}: {e}", i + 1))?;
        let seq = v
            .get("seq")
            .and_then(|s| s.as_u64())
            .with_context(|| format!("event log line {} missing seq", i + 1))?;
        let as_string = |key: &str| v.get(key).and_then(|x| x.as_str()).map(str::to_string);
        entries.push(LogEntry {
            seq,
            conn: v.get("conn").and_then(|c| c.as_u64()),
            dir: as_string("dir"),
            event: as_string("event"),
            line: as_string("line"),
            info: as_string("info"),
        });
    }
    entries.sort_by_key(|e| e.seq);
    Ok(entries)
}

/// Strip the one nondeterministic field (`latency_ms`) and re-serialize;
/// live and replay lines are compared in this form.
pub fn canonicalize_response_line(line: &str) -> Result<String> {
    let v = Json::parse(line).map_err(|e| anyhow::anyhow!("response line: {e}"))?;
    let Json::Obj(mut obj) = v else { bail!("response line must be a JSON object") };
    obj.remove("latency_ms");
    Ok(Json::Obj(obj).to_string_compact())
}

/// The inbound lines of a capture, in global arrival (seq) order, tagged
/// with their connection.
pub fn inbound_lines(entries: &[LogEntry]) -> Vec<(u64, String)> {
    entries
        .iter()
        .filter(|e| e.dir.as_deref() == Some("in"))
        .filter_map(|e| Some((e.conn?, e.line.clone()?)))
        .collect()
}

/// Delivered per-request responses keyed `c{conn}:{id}`, canonicalized.
/// Connection-level error lines (empty id) are not per-request traffic
/// and are excluded.
pub fn outbound_transcripts(entries: &[LogEntry]) -> Result<BTreeMap<String, String>> {
    outbound_transcripts_inner(entries, true)
}

/// [`outbound_transcripts`] with the lines verbatim, `latency_ms`
/// included — exact comparison for captures taken under an injected
/// clock.
pub fn outbound_transcripts_raw(entries: &[LogEntry]) -> Result<BTreeMap<String, String>> {
    outbound_transcripts_inner(entries, false)
}

fn outbound_transcripts_inner(
    entries: &[LogEntry],
    canonicalize: bool,
) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    for e in entries {
        if e.dir.as_deref() != Some("out") {
            continue;
        }
        let (Some(conn), Some(line)) = (e.conn, e.line.as_deref()) else { continue };
        let v = Json::parse(line).map_err(|e| anyhow::anyhow!("outbound line: {e}"))?;
        let id = v.get("id").and_then(|x| x.as_str()).unwrap_or("");
        if id.is_empty() {
            continue;
        }
        let rendered =
            if canonicalize { canonicalize_response_line(line)? } else { line.to_string() };
        out.insert(format!("c{conn}:{id}"), rendered);
    }
    Ok(out)
}

fn drain_into(
    engine: &mut Engine<'_>,
    owners: &mut BTreeMap<String, (u64, String)>,
    out: &mut BTreeMap<String, String>,
    canonicalize: bool,
) -> Result<()> {
    for resp in engine.take_responses() {
        let engine_id = resp.id.clone();
        if let Some((conn, client_id)) = owners.remove(&engine_id) {
            let r = unmangle_response(resp, &engine_id, &client_id);
            let line = r.to_json_line();
            let rendered = if canonicalize { canonicalize_response_line(&line)? } else { line };
            out.insert(format!("c{conn}:{client_id}"), rendered);
        }
    }
    Ok(())
}

/// Replay captured inbound lines through a fresh engine, mirroring the
/// live dispatch's id assignment (`req-{n}` for absent ids, engine ids
/// namespaced `c{conn}:{client_id}`). Returns canonicalized response
/// lines keyed like [`outbound_transcripts`].
pub fn replay_inbound(
    model: &ServeModel<'_>,
    ecfg: &EngineConfig,
    inbound: &[(u64, String)],
) -> Result<BTreeMap<String, String>> {
    replay_inbound_inner(model, ecfg, inbound, true)
}

/// [`replay_inbound`] with verbatim response lines. Pair with
/// [`outbound_transcripts_raw`] and a shared injected clock to assert
/// live and replay agree on every byte, `latency_ms` included.
pub fn replay_inbound_raw(
    model: &ServeModel<'_>,
    ecfg: &EngineConfig,
    inbound: &[(u64, String)],
) -> Result<BTreeMap<String, String>> {
    replay_inbound_inner(model, ecfg, inbound, false)
}

fn replay_inbound_inner(
    model: &ServeModel<'_>,
    ecfg: &EngineConfig,
    inbound: &[(u64, String)],
    canonicalize: bool,
) -> Result<BTreeMap<String, String>> {
    let mut engine = Engine::new(model, ecfg)?;
    let queue_cap = ecfg.queue_cap.max(1);
    let mut owners: BTreeMap<String, (u64, String)> = BTreeMap::new();
    let mut out = BTreeMap::new();
    let mut next_auto = 0u64;
    for (conn, line) in inbound {
        if line.trim().is_empty() {
            continue;
        }
        // Unparseable lines got a connection-level error live (empty id,
        // outside the per-request contract); nothing to replay.
        let Ok(mut req) = ServeRequest::from_json_line(line) else { continue };
        let client_id = if req.id.is_empty() {
            let id = format!("req-{next_auto}");
            next_auto += 1;
            id
        } else {
            req.id.clone()
        };
        // Same backpressure as live: hold admission until the queue has
        // room, stepping the engine meanwhile.
        while engine.queued() >= queue_cap {
            engine.step()?;
            drain_into(&mut engine, &mut owners, &mut out, canonicalize)?;
        }
        let engine_id = format!("c{conn}:{client_id}");
        req.id = engine_id.clone();
        owners.insert(engine_id, (*conn, client_id));
        engine.submit_or_reject(req);
    }
    while !engine.is_idle() {
        engine.step()?;
        drain_into(&mut engine, &mut owners, &mut out, canonicalize)?;
    }
    drain_into(&mut engine, &mut owners, &mut out, canonicalize)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canonicalize_strips_latency_only() {
        let line = r#"{"completion_tokens":2,"finish":"length","id":"r1","latency_ms":12.345,"prompt_tokens":3,"text":"ab"}"#;
        let canon = canonicalize_response_line(line).unwrap();
        assert!(!canon.contains("latency_ms"), "{canon}");
        assert!(canon.contains("\"id\":\"r1\""), "{canon}");
        // idempotent
        assert_eq!(canonicalize_response_line(&canon).unwrap(), canon);
    }

    #[test]
    fn log_parsing_orders_by_seq_and_splits_directions() {
        let dir = std::env::temp_dir().join(format!("fp_replay_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ev.jsonl");
        std::fs::write(
            &path,
            concat!(
                "{\"conn\":1,\"dir\":\"out\",\"line\":\"{\\\"id\\\":\\\"a\\\"}\",\"seq\":2}\n",
                "{\"conn\":1,\"dir\":\"in\",\"line\":\"{\\\"prompt\\\":\\\"x\\\"}\",\"seq\":0}\n",
                "{\"event\":\"accept\",\"conn\":1,\"seq\":1}\n",
            ),
        )
        .unwrap();
        let entries = read_event_log(&path).unwrap();
        assert_eq!(entries.len(), 3);
        assert!(entries.windows(2).all(|w| w[0].seq < w[1].seq));
        let inb = inbound_lines(&entries);
        assert_eq!(inb, vec![(1, "{\"prompt\":\"x\"}".to_string())]);
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Network front-end for the serving engine: a `std::net` TCP listener
//! speaking the same JSONL protocol as `serve`'s stdin loop, multiplexing
//! many concurrent connections onto one continuous-batching engine.
//!
//! Module map:
//! - [`framing`] — bounded, resumable line assembly for untrusted sockets;
//! - [`conn`] — per-connection reader/writer threads and tagged events;
//! - [`listener`] — the dispatch loop that owns the engine, routes
//!   responses, enforces timeouts, and tees the event log;
//! - [`replay`] — offline reproduction of a captured session (the
//!   live/replay split contract).
//!
//! The invariant the whole module defends (docs/ARCHITECTURE.md §Serving):
//! concurrency, disconnects, slow readers, and hostile bytes at the socket
//! layer must not perturb a single token of any surviving stream.

pub mod conn;
pub mod framing;
pub mod listener;
pub mod replay;

pub use conn::{ConnEvent, ConnId};
pub use framing::{BoundedLineReader, LineOutcome, DEFAULT_MAX_LINE};
pub use listener::{NetConfig, NetReport, NetServer};

//! Per-connection reader/writer threads and the tagged event stream they
//! feed into the dispatch loop.
//!
//! One reader thread per connection assembles bounded JSONL lines (see
//! `framing`) on a socket with a short read timeout, so it can interleave
//! byte intake with slowloris / idle checks. Everything it observes is
//! tagged with the connection id and pushed into one bounded `sync_channel`
//! shared by all readers — that channel IS the generalized intake: the
//! dispatch loop is the only consumer, and when it falls behind the channel
//! fills, readers block, and TCP backpressure does the rest.
//!
//! One writer thread per connection drains a bounded queue of response
//! lines. The dispatch loop only ever `try_send`s into it, so a client that
//! stops reading can fill its own queue and get disconnected — it can never
//! stall the engine step loop or any other stream.

use std::io::{BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::mpsc::{Receiver, SyncSender};
use std::time::Duration;

use crate::obs::SharedClock;

use super::framing::{BoundedLineReader, LineOutcome};

/// Server-assigned connection id, unique for the lifetime of one listener.
pub type ConnId = u64;

/// Everything the dispatch loop can learn from the socket side, tagged
/// with the owning connection.
#[derive(Debug)]
pub enum ConnEvent {
    /// A fresh connection from the accept thread.
    NewConn { conn: ConnId, stream: TcpStream, peer: String },
    /// One complete inbound line.
    Line { conn: ConnId, line: String },
    /// A line crossed the byte cap and was drained without buffering.
    Oversized { conn: ConnId, limit: usize, read: usize },
    /// A terminated line that was not valid UTF-8.
    BadUtf8 { conn: ConnId },
    /// A partial line outlived the per-line deadline (slowloris). Fatal
    /// for the connection; the reader thread has already exited.
    SlowLine { conn: ConnId, partial: usize },
    /// No bytes at all for a full timeout window and no line in progress.
    /// The dispatch loop decides whether the connection is idle enough to
    /// close (it may have responses still streaming out).
    IdleTick { conn: ConnId },
    /// EOF or a hard read error; the reader thread has exited.
    Closed { conn: ConnId, reason: &'static str },
}

/// Reader-thread body. Exits on EOF, read error, slowloris trip, or when
/// the intake channel is gone (server shut down). All deadline arithmetic
/// runs on the injected `clock` so replay tests can drive net timeouts
/// with a `FakeClock`; only the socket's polling granularity below it is
/// kernel time.
pub(crate) fn reader_loop(
    conn: ConnId,
    stream: TcpStream,
    max_line: usize,
    timeout: Duration,
    clock: SharedClock,
    tx: SyncSender<ConnEvent>,
) {
    // Short read timeout = the polling granularity for deadline checks;
    // the real per-line/idle deadlines live above it.
    let granularity = (timeout / 4).max(Duration::from_millis(5)).min(Duration::from_millis(250));
    let _ = stream.set_read_timeout(Some(granularity));
    reader_loop_on(conn, stream, max_line, timeout, clock, tx);
}

/// The transport-generic core of [`reader_loop`], unit-testable against a
/// synthetic `Read` + `FakeClock` pair (no sockets, no sleeps).
pub(crate) fn reader_loop_on<R: Read>(
    conn: ConnId,
    stream: R,
    max_line: usize,
    timeout: Duration,
    clock: SharedClock,
    tx: SyncSender<ConnEvent>,
) {
    let timeout_ms = timeout.as_secs_f64() * 1000.0;
    let mut reader = BufReader::new(stream);
    let mut frame = BoundedLineReader::with_clock(max_line, Some(timeout), clock.clone());
    let mut last_activity = clock.now_ms();
    loop {
        match frame.read_line(&mut reader) {
            Ok(LineOutcome::Line(line)) => {
                last_activity = clock.now_ms();
                if tx.send(ConnEvent::Line { conn, line }).is_err() {
                    return;
                }
            }
            Ok(LineOutcome::Oversized { limit, read }) => {
                last_activity = clock.now_ms();
                if tx.send(ConnEvent::Oversized { conn, limit, read }).is_err() {
                    return;
                }
            }
            Ok(LineOutcome::NotUtf8) => {
                last_activity = clock.now_ms();
                if tx.send(ConnEvent::BadUtf8 { conn }).is_err() {
                    return;
                }
            }
            Ok(LineOutcome::TimedOut { partial }) => {
                let _ = tx.send(ConnEvent::SlowLine { conn, partial });
                return;
            }
            Ok(LineOutcome::Eof) => {
                let _ = tx.send(ConnEvent::Closed { conn, reason: "eof" });
                return;
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                if frame.deadline_exceeded() {
                    let _ = tx.send(ConnEvent::SlowLine { conn, partial: frame.partial_len() });
                    return;
                }
                if !frame.in_progress() && clock.now_ms() - last_activity >= timeout_ms {
                    // one tick per quiet window; dispatch decides
                    last_activity = clock.now_ms();
                    if tx.send(ConnEvent::IdleTick { conn }).is_err() {
                        return;
                    }
                }
            }
            Err(_) => {
                let _ = tx.send(ConnEvent::Closed { conn, reason: "read error" });
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use std::collections::VecDeque;
    use std::io;
    use std::sync::mpsc::sync_channel;
    use std::sync::Arc;

    use crate::obs::FakeClock;

    use super::*;

    enum Step {
        Bytes(&'static [u8]),
        /// Advance the fake clock by this many ms, then return WouldBlock —
        /// the shape of a socket read timeout expiring.
        Block(f64),
    }

    struct ScriptedStream {
        fake: Arc<FakeClock>,
        script: VecDeque<Step>,
    }

    impl Read for ScriptedStream {
        fn read(&mut self, out: &mut [u8]) -> io::Result<usize> {
            match self.script.pop_front() {
                Some(Step::Bytes(b)) => {
                    out[..b.len()].copy_from_slice(b);
                    Ok(b.len())
                }
                Some(Step::Block(ms)) => {
                    self.fake.advance_ms(ms);
                    Err(io::Error::new(io::ErrorKind::WouldBlock, "scripted"))
                }
                None => Ok(0),
            }
        }
    }

    #[test]
    fn reader_loop_timeouts_replay_on_a_fake_clock() {
        let (clock, fake) = SharedClock::fake();
        let script = VecDeque::from([
            // a full quiet window with no line in progress → IdleTick
            Step::Block(300.0),
            // a short quiet gap → nothing
            Step::Block(100.0),
            // a complete line → Line (resets the idle window)
            Step::Bytes(b"{\"x\":1}\n"),
            // a partial line, then stalled past the per-line deadline →
            // SlowLine with the partial byte count, and the reader exits
            Step::Bytes(b"partial"),
            Step::Block(300.0),
        ]);
        let stream = ScriptedStream { fake: fake.clone(), script };
        let (tx, rx) = sync_channel(8);
        reader_loop_on(7, stream, 1024, Duration::from_millis(250), clock, tx);
        let events: Vec<ConnEvent> = rx.try_iter().collect();
        assert_eq!(events.len(), 3, "IdleTick, Line, SlowLine");
        assert!(matches!(events[0], ConnEvent::IdleTick { conn: 7 }));
        match &events[1] {
            ConnEvent::Line { conn: 7, line } => assert_eq!(line, "{\"x\":1}"),
            other => panic!("expected Line, got {other:?}"),
        }
        assert!(matches!(events[2], ConnEvent::SlowLine { conn: 7, partial: 7 }));
    }
}

/// Writer-thread body: drain queued response lines, flushing once per
/// drained burst. Exits when the queue sender is dropped (connection
/// closed) or the socket errors.
pub(crate) fn writer_loop(stream: TcpStream, rx: Receiver<String>) {
    let mut w = std::io::BufWriter::new(stream);
    while let Ok(line) = rx.recv() {
        if writeln!(w, "{line}").is_err() {
            return;
        }
        while let Ok(more) = rx.try_recv() {
            if writeln!(w, "{more}").is_err() {
                return;
            }
        }
        if w.flush().is_err() {
            return;
        }
    }
}

//! TCP JSONL listener: multiplexes many client connections onto one
//! continuous-batching [`Engine`].
//!
//! Thread layout (no async runtime — auditable, deterministic idioms):
//!
//! ```text
//!   accept thread ──┐
//!   reader thread 1 ─┼─▶ bounded intake channel ─▶ dispatch loop (owns Engine)
//!   reader thread 2 ─┘                                 │
//!        ...                                           ├─▶ writer thread 1 (bounded)
//!                                                      └─▶ writer thread 2 (bounded)
//! ```
//!
//! The dispatch loop is the only thread that touches the engine, the
//! connection table, and the event log, so requests are admitted in intake
//! order and every tee line gets one monotonic sequence number. Responses
//! are routed to the owning connection by `try_send` into that
//! connection's bounded writer queue — a slow reader overflows its own
//! queue and is disconnected without ever blocking a step. Engine ids are
//! namespaced `c{conn}:{client_id}` so two connections using the same
//! request id cannot collide.

use std::collections::{BTreeMap, BTreeSet};
use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::metrics::{Counters, Snapshot};
use crate::obs::{Recorder, SharedClock};
use crate::ser::json::Json;
use crate::serve::engine::{Engine, EngineConfig, EngineStats};
use crate::serve::request::{FinishReason, ServeRequest, ServeResponse};
use crate::serve::ServeModel;

use super::conn::{self, ConnEvent, ConnId};
use super::framing::DEFAULT_MAX_LINE;

/// How many tagged events the intake channel buffers before readers block
/// (and TCP backpressure reaches the clients).
const INTAKE_CAP: usize = 1024;
/// Events drained per dispatch iteration before the engine gets a step.
const INTAKE_BURST: usize = 64;

/// Network front-end knobs (`serve --listen ...`).
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Concurrent connection cap; extra connections get one rejection
    /// line and are closed.
    pub max_conns: usize,
    /// Idle timeout and per-line (slowloris) deadline.
    pub conn_timeout: Duration,
    /// Per-line byte cap (see `BoundedLineReader`).
    pub max_line: usize,
    /// Response lines buffered per connection before a non-reading client
    /// is disconnected.
    pub write_buf: usize,
    /// Raw-JSONL tee of every inbound/outbound line plus lifecycle
    /// events, with connection id and monotonic sequence (`--event-log`).
    pub event_log: Option<PathBuf>,
    /// Failure-injection / load-shaping hook: sleep this long after every
    /// engine step. Lets tests pin down "mid-stream" deterministically;
    /// `None` in production.
    #[doc(hidden)]
    pub step_delay: Option<Duration>,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            max_conns: 64,
            conn_timeout: Duration::from_secs(30),
            max_line: DEFAULT_MAX_LINE,
            write_buf: 64,
            event_log: None,
            step_delay: None,
        }
    }
}

/// What one listener run did — engine stats plus socket-layer counters
/// and the final KV page accounting (tests assert pages drain to zero).
#[derive(Clone, Debug)]
pub struct NetReport {
    pub engine: EngineStats,
    pub counters: Counters,
    pub kv_in_use_pages: usize,
    pub kv_reserved_pages: usize,
    /// The exit-time stats surface: engine counters/gauges/histograms
    /// merged with the socket counters — the same shape the live
    /// `{"type":"stats"}` control request returns.
    pub snapshot: Snapshot,
}

impl NetReport {
    pub fn summary(&self) -> String {
        format!(
            "steps={} decoded={} retired={} | {}",
            self.engine.steps,
            self.engine.decoded_tokens,
            self.engine.retired,
            self.counters.summary()
        )
    }
}

/// The raw-JSONL tee. One JSON object per line; `seq` is monotonic across
/// the whole session, so offline replay can reconstruct global intake
/// order exactly.
struct EventLog {
    out: std::io::BufWriter<std::fs::File>,
    seq: u64,
    /// Timestamp source — the engine's clock, so `t_ms` here, trace
    /// events, and response `latency_ms` share one domain (a fake clock
    /// pins all three at once).
    clock: SharedClock,
}

impl EventLog {
    fn create(path: &std::path::Path, clock: SharedClock) -> Result<EventLog> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating event log {}", path.display()))?;
        Ok(EventLog { out: std::io::BufWriter::new(file), seq: 0, clock })
    }

    fn write(&mut self, mut obj: BTreeMap<String, Json>) {
        obj.insert("seq".to_string(), Json::Num(self.seq as f64));
        obj.insert("t_ms".to_string(), Json::Num((self.clock.now_ms() * 1e3).round() / 1e3));
        self.seq += 1;
        let _ = writeln!(self.out, "{}", Json::Obj(obj).to_string_compact());
        let _ = self.out.flush();
    }

    fn line(&mut self, conn: ConnId, dir: &str, line: &str) {
        let mut obj = BTreeMap::new();
        obj.insert("conn".to_string(), Json::Num(conn as f64));
        obj.insert("dir".to_string(), Json::Str(dir.to_string()));
        obj.insert("line".to_string(), Json::Str(line.to_string()));
        self.write(obj);
    }

    fn event(&mut self, event: &str, conn: Option<ConnId>, info: &str) {
        let mut obj = BTreeMap::new();
        obj.insert("event".to_string(), Json::Str(event.to_string()));
        if let Some(c) = conn {
            obj.insert("conn".to_string(), Json::Num(c as f64));
        }
        if !info.is_empty() {
            obj.insert("info".to_string(), Json::Str(info.to_string()));
        }
        self.write(obj);
    }
}

/// Per-connection dispatch-side state. The writer thread owns its half of
/// the socket via a clone; dropping `writer_tx` is how the connection's
/// outbound side winds down.
struct ConnState {
    stream: TcpStream,
    writer_tx: SyncSender<String>,
    /// Engine ids submitted on this connection and not yet retired.
    in_flight: BTreeSet<String>,
}

/// A parsed request the engine queue had no room for. Held (not dropped,
/// not rejected) while the intake pauses — exactly the backpressure the
/// blocking stdin path gets for free, which keeps live and replay
/// admission behavior identical.
struct PendingSubmit {
    conn: ConnId,
    req: ServeRequest,
    client_id: String,
}

/// A bound TCP front-end. `bind` then `run`; `run` owns the calling
/// thread until `stop` is raised and the engine drains.
pub struct NetServer {
    listener: TcpListener,
    cfg: NetConfig,
}

impl NetServer {
    pub fn bind(addr: &str, cfg: NetConfig) -> Result<NetServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding listener on {addr}"))?;
        Ok(NetServer { listener, cfg })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// Serve until `stop` is set AND all work is drained. Returns the
    /// run's report. The engine lives on the calling thread; only socket
    /// I/O happens on helper threads.
    pub fn run(
        &self,
        model: &ServeModel<'_>,
        ecfg: &EngineConfig,
        stop: Arc<AtomicBool>,
    ) -> Result<NetReport> {
        let engine = Engine::new(model, ecfg)?;
        // One timestamp domain for the whole front end: the event log
        // and the conn spans read the engine's clock.
        let clock = ecfg.clock.clone().unwrap_or_default();
        let log = match &self.cfg.event_log {
            Some(path) => Some(EventLog::create(path, clock.clone())?),
            None => None,
        };
        let (intake_tx, intake_rx) = mpsc::sync_channel::<ConnEvent>(INTAKE_CAP);

        // Accept thread: nonblocking accepts, polled so it can observe
        // `stop` (a blocking accept would pin the thread forever).
        let accept_listener = self.listener.try_clone()?;
        accept_listener.set_nonblocking(true)?;
        let accept_tx = intake_tx.clone();
        let accept_stop = stop.clone();
        let accept_handle = thread::spawn(move || {
            let mut next_conn: ConnId = 1;
            while !accept_stop.load(Ordering::Relaxed) {
                match accept_listener.accept() {
                    Ok((stream, peer)) => {
                        let conn = next_conn;
                        next_conn += 1;
                        let ev = ConnEvent::NewConn { conn, stream, peer: peer.to_string() };
                        if accept_tx.send(ev).is_err() {
                            return;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        thread::sleep(Duration::from_millis(5));
                    }
                    Err(_) => thread::sleep(Duration::from_millis(5)),
                }
            }
        });

        let mut d = Dispatch {
            engine,
            cfg: &self.cfg,
            queue_cap: ecfg.queue_cap.max(1),
            intake: intake_tx,
            conns: BTreeMap::new(),
            owners: BTreeMap::new(),
            pending: None,
            next_auto: 0,
            counters: Counters::new(),
            log,
            rec: ecfg.recorder.clone(),
            clock,
        };
        let result = d.run_loop(&intake_rx, &stop);
        // Unblock and join the accept thread regardless of how the loop
        // ended: raise `stop` (it polls every few ms) and drop the intake
        // receiver so a send blocked on a full channel errors out instead
        // of pinning the thread.
        stop.store(true, Ordering::Relaxed);
        drop(intake_rx);
        accept_handle.join().ok();
        result?;

        let (in_use, reserved, _) = d.engine.kv_pages();
        let mut snapshot = d.engine.snapshot();
        snapshot.counters.merge(&d.counters);
        Ok(NetReport {
            engine: d.engine.stats,
            counters: d.counters,
            kv_in_use_pages: in_use,
            kv_reserved_pages: reserved,
            snapshot,
        })
    }
}

struct Dispatch<'c, 'm> {
    engine: Engine<'m>,
    cfg: &'c NetConfig,
    queue_cap: usize,
    /// Kept alive so reader threads can always clone a sender from the
    /// dispatch side when connections are registered.
    intake: SyncSender<ConnEvent>,
    conns: BTreeMap<ConnId, ConnState>,
    /// engine id → (connection, client-visible id); the routing table.
    owners: BTreeMap<String, (ConnId, String)>,
    pending: Option<PendingSubmit>,
    next_auto: u64,
    counters: Counters,
    log: Option<EventLog>,
    rec: Option<Recorder>,
    /// The engine's timestamp domain, handed to every reader thread so
    /// per-line/idle deadlines replay under a fake clock.
    clock: SharedClock,
}

impl Dispatch<'_, '_> {
    fn run_loop(&mut self, rx: &Receiver<ConnEvent>, stop: &AtomicBool) -> Result<()> {
        loop {
            // Re-try the held submission first: intake stays paused until
            // the engine queue has room again (per-connection FIFO and
            // global arrival order are both preserved).
            if let Some(p) = self.pending.take() {
                if !self.conns.contains_key(&p.conn) {
                    // owner vanished while we waited; drop silently —
                    // there is no one left to answer.
                } else if self.engine.queued() < self.queue_cap {
                    self.submit_now(p.conn, p.req, p.client_id);
                } else {
                    self.pending = Some(p);
                }
            }

            let mut budget = INTAKE_BURST;
            while self.pending.is_none() && budget > 0 {
                let ev = if self.engine.is_idle() && budget == INTAKE_BURST {
                    // Nothing to step: block briefly instead of spinning.
                    match rx.recv_timeout(Duration::from_millis(20)) {
                        Ok(ev) => ev,
                        Err(_) => break,
                    }
                } else {
                    match rx.try_recv() {
                        Ok(ev) => ev,
                        Err(_) => break,
                    }
                };
                budget -= 1;
                self.on_event(ev);
            }

            if !self.engine.is_idle() {
                self.engine.step()?;
                if let Some(delay) = self.cfg.step_delay {
                    thread::sleep(delay);
                }
            }
            self.route_responses();

            if stop.load(Ordering::Relaxed) && self.engine.is_idle() && self.pending.is_none() {
                let leftover: Vec<ConnId> = self.conns.keys().copied().collect();
                for conn in leftover {
                    self.close_conn(conn, "server shutdown");
                }
                return Ok(());
            }
        }
    }

    fn on_event(&mut self, ev: ConnEvent) {
        match ev {
            ConnEvent::NewConn { conn, stream, peer } => self.on_new_conn(conn, stream, &peer),
            ConnEvent::Line { conn, line } => self.on_line(conn, line),
            ConnEvent::Oversized { conn, limit, read } => {
                if !self.conns.contains_key(&conn) {
                    return;
                }
                self.counters.incr("oversized_lines");
                self.tee_event("oversized", Some(conn), &format!("read {read} bytes"));
                self.error_line(
                    conn,
                    format!("request line exceeds the {limit} byte cap ({read} bytes); discarded"),
                );
            }
            ConnEvent::BadUtf8 { conn } => {
                if !self.conns.contains_key(&conn) {
                    return;
                }
                self.counters.incr("bad_lines");
                self.error_line(conn, "request line is not valid UTF-8".to_string());
            }
            ConnEvent::SlowLine { conn, partial } => {
                if !self.conns.contains_key(&conn) {
                    return;
                }
                self.counters.incr("timed_out");
                self.error_line(
                    conn,
                    format!(
                        "request line stalled after {partial} bytes (per-line timeout {:?})",
                        self.cfg.conn_timeout
                    ),
                );
                self.close_conn(conn, "slowloris timeout");
            }
            ConnEvent::IdleTick { conn } => {
                let idle = match self.conns.get(&conn) {
                    Some(st) => st.in_flight.is_empty(),
                    None => return,
                };
                let pending_here =
                    self.pending.as_ref().map(|p| p.conn == conn).unwrap_or(false);
                if idle && !pending_here {
                    self.counters.incr("timed_out");
                    self.error_line(
                        conn,
                        format!("connection idle for {:?}; closing", self.cfg.conn_timeout),
                    );
                    self.close_conn(conn, "idle timeout");
                }
            }
            ConnEvent::Closed { conn, reason } => self.close_conn(conn, reason),
        }
    }

    fn on_new_conn(&mut self, conn: ConnId, stream: TcpStream, peer: &str) {
        if self.conns.len() >= self.cfg.max_conns {
            self.counters.incr("rejected_conns");
            self.tee_event("reject", Some(conn), peer);
            let resp = rejection_response(
                String::new(),
                format!("server at capacity ({} connections)", self.cfg.max_conns),
            );
            let mut s = &stream;
            let _ = writeln!(s, "{}", resp.to_json_line());
            let _ = stream.shutdown(Shutdown::Both);
            return;
        }
        let (read_half, write_half) = match (stream.try_clone(), stream.try_clone()) {
            (Ok(r), Ok(w)) => (r, w),
            _ => {
                self.counters.incr("rejected_conns");
                let _ = stream.shutdown(Shutdown::Both);
                return;
            }
        };
        self.counters.incr("accepted");
        self.tee_event("accept", Some(conn), peer);
        let (writer_tx, writer_rx) = mpsc::sync_channel::<String>(self.cfg.write_buf.max(1));
        thread::spawn(move || conn::writer_loop(write_half, writer_rx));
        let reader_tx = self.intake.clone();
        let max_line = self.cfg.max_line;
        let timeout = self.cfg.conn_timeout;
        let reader_clock = self.clock.clone();
        thread::spawn(move || {
            conn::reader_loop(conn, read_half, max_line, timeout, reader_clock, reader_tx)
        });
        self.conns.insert(conn, ConnState { stream, writer_tx, in_flight: BTreeSet::new() });
        if let Some(r) = &self.rec {
            r.begin("conn", &format!("c{conn}"), vec![("peer", Json::Str(peer.to_string()))]);
        }
    }

    fn on_line(&mut self, conn: ConnId, line: String) {
        if !self.conns.contains_key(&conn) {
            return; // stragglers from a connection closed this iteration
        }
        if line.trim().is_empty() {
            return;
        }
        self.tee_in(conn, &line);
        // Control requests (`{"type": ...}`) are answered here, before
        // request parsing — the request whitelist rejects a `type` key,
        // and replay skips these lines for the same reason.
        if let Some(kind) = control_type(&line) {
            self.counters.incr("control_requests");
            if kind == "stats" {
                self.counters.incr("stats_requests");
                let reply = self.stats_line();
                self.respond_line(conn, reply);
            } else {
                self.error_line(conn, format!("unknown control request type '{kind}'"));
            }
            return;
        }
        self.counters.incr("requests_in");
        match ServeRequest::from_json_line_checked(&line, self.cfg.max_line) {
            Ok(req) => {
                let client_id = if req.id.is_empty() {
                    let id = format!("req-{}", self.next_auto);
                    self.next_auto += 1;
                    id
                } else {
                    req.id.clone()
                };
                if self.engine.queued() >= self.queue_cap {
                    self.pending = Some(PendingSubmit { conn, req, client_id });
                } else {
                    self.submit_now(conn, req, client_id);
                }
            }
            Err(e) => {
                self.counters.incr("bad_lines");
                self.error_line(conn, format!("bad request line: {e:#}"));
            }
        }
    }

    fn submit_now(&mut self, conn: ConnId, mut req: ServeRequest, client_id: String) {
        let engine_id = format!("c{conn}:{client_id}");
        req.id = engine_id.clone();
        self.owners.insert(engine_id.clone(), (conn, client_id));
        if self.engine.submit_or_reject(req) {
            if let Some(st) = self.conns.get_mut(&conn) {
                st.in_flight.insert(engine_id);
            }
        }
        // On rejection the engine has already queued a Rejected response;
        // route_responses delivers it through the owners entry.
    }

    fn route_responses(&mut self) {
        for resp in self.engine.take_responses() {
            let engine_id = resp.id.clone();
            let Some((conn, client_id)) = self.owners.remove(&engine_id) else {
                self.counters.incr("responses_dropped");
                continue;
            };
            if let Some(st) = self.conns.get_mut(&conn) {
                st.in_flight.remove(&engine_id);
            }
            let client_resp = unmangle_response(resp, &engine_id, &client_id);
            self.respond_line(conn, client_resp.to_json_line());
        }
    }

    /// Deliver one outbound line: `try_send` into the connection's writer
    /// queue, tee on success. A full queue means the client stopped
    /// reading — it is disconnected rather than allowed to stall anyone.
    fn respond_line(&mut self, conn: ConnId, line: String) {
        enum Sent {
            Ok,
            Overflow,
            Gone,
        }
        let sent = match self.conns.get(&conn) {
            Some(st) => match st.writer_tx.try_send(line.clone()) {
                Ok(()) => Sent::Ok,
                Err(TrySendError::Full(_)) => Sent::Overflow,
                Err(TrySendError::Disconnected(_)) => Sent::Gone,
            },
            None => Sent::Gone,
        };
        match sent {
            Sent::Ok => {
                self.counters.incr("responses_out");
                self.tee_out(conn, &line);
            }
            Sent::Overflow => {
                self.counters.incr("write_overflow");
                self.close_conn(conn, "write buffer overflow (client not reading)");
            }
            Sent::Gone => {
                self.counters.incr("responses_dropped");
            }
        }
    }

    /// Connection-level typed error (empty id): parse failures, timeouts,
    /// oversized lines. The connection usually survives; fatal cases call
    /// `close_conn` right after.
    fn error_line(&mut self, conn: ConnId, msg: String) {
        let resp = rejection_response(String::new(), msg);
        self.respond_line(conn, resp.to_json_line());
    }

    fn close_conn(&mut self, conn: ConnId, reason: &str) {
        let Some(st) = self.conns.remove(&conn) else { return };
        // Read side down now (unblocks the reader thread); the writer
        // drains its queue and closes the socket when its sender drops.
        let _ = st.stream.shutdown(Shutdown::Read);
        drop(st.writer_tx);
        let aborted = st.in_flight.len();
        for engine_id in &st.in_flight {
            self.engine.abort(engine_id);
        }
        if aborted > 0 {
            self.counters.add("aborted_by_disconnect", aborted as u64);
        }
        self.counters.incr("closed");
        self.tee_event("close", Some(conn), reason);
        if let Some(r) = &self.rec {
            r.end(
                "conn",
                &format!("c{conn}"),
                vec![
                    ("reason", Json::Str(reason.to_string())),
                    ("aborted", Json::Num(aborted as f64)),
                ],
            );
        }
    }

    fn tee_in(&mut self, conn: ConnId, line: &str) {
        if let Some(log) = &mut self.log {
            log.line(conn, "in", line);
        }
    }

    fn tee_out(&mut self, conn: ConnId, line: &str) {
        if let Some(log) = &mut self.log {
            log.line(conn, "out", line);
        }
    }

    fn tee_event(&mut self, event: &str, conn: Option<ConnId>, info: &str) {
        if let Some(log) = &mut self.log {
            log.event(event, conn, info);
        }
    }

    /// The `{"type":"stats"}` reply: the engine snapshot merged with the
    /// front end's socket counters and connection gauge. Read-only — the
    /// engine is neither stepped nor mutated, so co-batched streams are
    /// not perturbed.
    fn stats_line(&self) -> String {
        let mut snap = self.engine.snapshot();
        snap.counters.merge(&self.counters);
        snap.gauge("open_conns", self.conns.len() as f64);
        snap.gauge("dropped_events", self.engine.dropped_events() as f64);
        let mut obj = BTreeMap::new();
        obj.insert("type".to_string(), Json::Str("stats".to_string()));
        obj.insert("stats".to_string(), snap.to_json());
        Json::Obj(obj).to_string_compact()
    }
}

/// A control line is a JSON object carrying a `"type"` key — requests
/// never have one (the request parser's key whitelist rejects it). The
/// substring pre-filter keeps the common request path from parsing the
/// line twice.
fn control_type(line: &str) -> Option<String> {
    if !line.contains("\"type\"") {
        return None;
    }
    let v = Json::parse(line).ok()?;
    Some(v.get("type")?.as_str()?.to_string())
}

fn rejection_response(id: String, error: String) -> ServeResponse {
    ServeResponse {
        id,
        text: String::new(),
        prompt_tokens: 0,
        completion_tokens: 0,
        finish: FinishReason::Rejected,
        latency_ms: 0.0,
        error: Some(error),
    }
}

/// Restore the client-visible id on a retired response (and scrub the
/// namespaced engine id out of any engine-generated error text).
pub(crate) fn unmangle_response(
    mut resp: ServeResponse,
    engine_id: &str,
    client_id: &str,
) -> ServeResponse {
    resp.id = client_id.to_string();
    if let Some(err) = &mut resp.error {
        if err.contains(engine_id) {
            *err = err.replace(engine_id, client_id);
        }
    }
    resp
}

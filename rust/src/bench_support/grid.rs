//! The method × sparsity × model grid runner behind the table benches
//! (paper Tables 1/2/4/5/6/7: rows = method@sparsity, columns = models).

use anyhow::Result;

use crate::config::{PruneOptions, Sparsity};
use crate::metrics::csv::CsvWriter;
use crate::metrics::TableBuilder;
use crate::pruner::scheduler::Method;

use super::Lab;

/// Grid description for one paper table.
pub struct GridSpec {
    pub title: String,
    /// Model preset names = table columns.
    pub models: Vec<String>,
    /// (method, sparsity or None for dense) = table rows.
    pub rows: Vec<(Method, Option<Sparsity>)>,
    /// Corpus trained on AND evaluated against (the paper trains once and
    /// evaluates per corpus; our substrate trains per corpus).
    pub eval_corpus: String,
    /// CSV basename under artifacts/bench_out/.
    pub csv: String,
}

/// Default row set matching the paper's tables: dense, then
/// {SparseGPT, Wanda, FISTAPruner} × {50%, 2:4}.
pub fn paper_rows() -> Vec<(Method, Option<Sparsity>)> {
    use crate::baselines::BaselineKind::*;
    vec![
        (Method::Dense, None),
        (Method::Baseline(SparseGpt), Some(Sparsity::Unstructured(0.5))),
        (Method::Baseline(Wanda), Some(Sparsity::Unstructured(0.5))),
        (Method::Fista, Some(Sparsity::Unstructured(0.5))),
        (Method::Baseline(SparseGpt), Some(Sparsity::Semi(2, 4))),
        (Method::Baseline(Wanda), Some(Sparsity::Semi(2, 4))),
        (Method::Fista, Some(Sparsity::Semi(2, 4))),
    ]
}

/// Run the grid: train/load each model, prune per row, evaluate perplexity.
/// Prints the paper-style table and writes a CSV; returns (row label,
/// model, ppl) triples for callers that assert on ordering.
pub fn run_grid(lab: &mut Lab, grid: &GridSpec) -> Result<Vec<(String, String, f64)>> {
    let mut header: Vec<&str> = vec!["Method", "Sparsity"];
    let model_cols: Vec<String> = grid.models.clone();
    for m in &model_cols {
        header.push(m.as_str());
    }
    let mut table = TableBuilder::new(&grid.title, &header);
    let csv_path = lab.bench_out().join(&grid.csv);
    let mut csv = CsvWriter::create(&csv_path, &["method", "sparsity", "model", "ppl"])?;

    let calib_n = lab.calib_samples();
    let mut triples = Vec::new();
    // Evaluate column-by-column so each model trains once.
    let mut cells: Vec<Vec<String>> =
        vec![vec![String::new(); model_cols.len()]; grid.rows.len()];
    for (ci, model) in model_cols.iter().enumerate() {
        let dense = lab.trained(model, &grid.eval_corpus)?;
        let calib = lab.calib(&grid.eval_corpus, calib_n, lab.presets.calib_seed)?;
        for (ri, (method, sp)) in grid.rows.iter().enumerate() {
            let ppl = match (method, sp) {
                (Method::Dense, _) => lab.ppl(model, &dense, &grid.eval_corpus)?,
                (m, Some(sp)) => {
                    let opts = PruneOptions { sparsity: *sp, ..lab.default_prune_options() };
                    let (pruned, report) = lab.prune(model, &dense, &calib, *m, &opts)?;
                    crate::log_info!("{}", report.summary());
                    lab.ppl(model, &pruned, &grid.eval_corpus)?
                }
                _ => anyhow::bail!("non-dense row needs a sparsity"),
            };
            let row_label = method.name().to_string();
            let sp_label = sp.map(|s| s.label()).unwrap_or_else(|| "0%".into());
            csv.write_row(&[row_label.as_str(), sp_label.as_str(), model, &format!("{ppl:.4}")])?;
            cells[ri][ci] = TableBuilder::f(ppl);
            triples.push((format!("{row_label}@{sp_label}"), model.clone(), ppl));
        }
    }
    for (ri, (method, sp)) in grid.rows.iter().enumerate() {
        let mut row = vec![
            pretty_name(method).to_string(),
            sp.map(|s| s.label()).unwrap_or_else(|| "0%".into()),
        ];
        row.extend(cells[ri].iter().cloned());
        table.row(row);
    }
    table.print();
    println!("csv: {}", csv_path.display());
    Ok(triples)
}

fn pretty_name(m: &Method) -> &'static str {
    match m {
        Method::Dense => "Dense",
        Method::Fista => "FISTAPruner",
        Method::Baseline(crate::baselines::BaselineKind::SparseGpt) => "SparseGPT",
        Method::Baseline(crate::baselines::BaselineKind::Wanda) => "Wanda",
        Method::Baseline(crate::baselines::BaselineKind::Magnitude) => "Magnitude",
    }
}

//! The method × sparsity × model grid runner behind the table benches
//! (paper Tables 1/2/4/5/6/7: rows = method@sparsity, columns = models),
//! plus the serve-format grid: the same pruned weights measured through
//! every compressed decode format (CSR vs packed n:m) side by side.

use anyhow::Result;

use crate::config::{PruneOptions, SparseFormat, Sparsity};
use crate::metrics::csv::CsvWriter;
use crate::metrics::TableBuilder;
use crate::pruner::scheduler::Method;

use super::Lab;

/// Grid description for one paper table.
pub struct GridSpec {
    pub title: String,
    /// Model preset names = table columns.
    pub models: Vec<String>,
    /// (method, sparsity or None for dense) = table rows.
    pub rows: Vec<(Method, Option<Sparsity>)>,
    /// Corpus trained on AND evaluated against (the paper trains once and
    /// evaluates per corpus; our substrate trains per corpus).
    pub eval_corpus: String,
    /// CSV basename under artifacts/bench_out/.
    pub csv: String,
}

/// Default row set matching the paper's tables: dense, then
/// {SparseGPT, Wanda, FISTAPruner} × {50%, 2:4}.
pub fn paper_rows() -> Vec<(Method, Option<Sparsity>)> {
    use crate::baselines::BaselineKind::*;
    vec![
        (Method::Dense, None),
        (Method::Baseline(SparseGpt), Some(Sparsity::Unstructured(0.5))),
        (Method::Baseline(Wanda), Some(Sparsity::Unstructured(0.5))),
        (Method::fista(), Some(Sparsity::Unstructured(0.5))),
        (Method::Baseline(SparseGpt), Some(Sparsity::Semi(2, 4))),
        (Method::Baseline(Wanda), Some(Sparsity::Semi(2, 4))),
        (Method::fista(), Some(Sparsity::Semi(2, 4))),
    ]
}

/// Run the grid: train/load each model, prune per row, evaluate perplexity.
/// Prints the paper-style table and writes a CSV; returns (row label,
/// model, ppl) triples for callers that assert on ordering.
pub fn run_grid(lab: &mut Lab, grid: &GridSpec) -> Result<Vec<(String, String, f64)>> {
    let mut header: Vec<&str> = vec!["Method", "Sparsity"];
    let model_cols: Vec<String> = grid.models.clone();
    for m in &model_cols {
        header.push(m.as_str());
    }
    let mut table = TableBuilder::new(&grid.title, &header);
    let csv_path = lab.bench_out().join(&grid.csv);
    let mut csv = CsvWriter::create(&csv_path, &["method", "sparsity", "model", "ppl"])?;

    let calib_n = lab.calib_samples();
    let mut triples = Vec::new();
    // Evaluate column-by-column so each model trains once.
    let mut cells: Vec<Vec<String>> =
        vec![vec![String::new(); model_cols.len()]; grid.rows.len()];
    for (ci, model) in model_cols.iter().enumerate() {
        let dense = lab.trained(model, &grid.eval_corpus)?;
        let calib = lab.calib(&grid.eval_corpus, calib_n, lab.presets.calib_seed)?;
        for (ri, (method, sp)) in grid.rows.iter().enumerate() {
            let ppl = match (method, sp) {
                (Method::Dense, _) => lab.ppl(model, &dense, &grid.eval_corpus)?,
                (m, Some(sp)) => {
                    let opts = PruneOptions { sparsity: *sp, ..lab.default_prune_options() };
                    let (pruned, report) = lab.prune(model, &dense, &calib, *m, &opts)?;
                    crate::log_info!("{}", report.summary());
                    lab.ppl(model, &pruned, &grid.eval_corpus)?
                }
                _ => anyhow::bail!("non-dense row needs a sparsity"),
            };
            let row_label = method.name().to_string();
            let sp_label = sp.map(|s| s.label()).unwrap_or_else(|| "0%".into());
            csv.write_row(&[row_label.as_str(), sp_label.as_str(), model, &format!("{ppl:.4}")])?;
            cells[ri][ci] = TableBuilder::f(ppl);
            triples.push((format!("{row_label}@{sp_label}"), model.clone(), ppl));
        }
    }
    for (ri, (method, sp)) in grid.rows.iter().enumerate() {
        let mut row = vec![
            pretty_name(method).to_string(),
            sp.map(|s| s.label()).unwrap_or_else(|| "0%".into()),
        ];
        row.extend(cells[ri].iter().cloned());
        table.row(row);
    }
    table.print();
    println!("csv: {}", csv_path.display());
    Ok(triples)
}

/// One row of [`run_serve_format_grid`] output.
#[derive(Clone, Debug)]
pub struct ServeFormatRow {
    /// Requested format axis value ("csr" | "nm" | "auto"), or
    /// "artifact" for the load-from-disk row.
    pub format: String,
    /// What actually got compressed ("csr" | "nm" | "csr+nm").
    pub resolved: String,
    pub tokens_per_s_b1: f64,
    pub tokens_per_s_bb: f64,
    pub storage_bytes: usize,
    pub storage_ratio: f64,
    /// Artifact row only: wall ms of `ser::artifact::load`.
    pub load_ms: Option<f64>,
    /// Artifact row only: resident weight bytes after load (compressed
    /// ops + residual dense params).
    pub resident_bytes: Option<usize>,
    pub parity_ok: bool,
}

/// The serve-format grid: prune `dense` to `sparsity` once, then measure
/// the same pruned weights through each format's decode kernels — rows =
/// formats, columns = tokens/s at batch 1 / batch `batch`, storage, and
/// greedy parity vs `eval::generate`. When `artifact` names a path, an
/// extra row compiles the pruned weights once, writes the sparse
/// artifact there, and measures the full disk round-trip: load time,
/// resident weight bytes, and serving parity from the *loaded* operators
/// — the startup-cost column of the memory-conservation claim. The
/// csr-vs-nm side-by-side behind `benches/serve_decode.rs`; callers gate
/// on each row's `parity_ok`.
#[allow(clippy::too_many_arguments)]
pub fn run_serve_format_grid(
    spec: &crate::config::ModelSpec,
    dense: &crate::model::params::ModelParams,
    formats: &[SparseFormat],
    sparsity: Sparsity,
    tokens: usize,
    batch: usize,
    requests: usize,
    csv_path: &std::path::Path,
    artifact: Option<&std::path::Path>,
) -> Result<Vec<ServeFormatRow>> {
    use crate::serve::bench::{
        greedy_references, measure_sparse_format, requests_for, synthetic_prompts, BenchObs,
    };

    let pruned = crate::pruner::round_model_to_sparsity(spec, dense, sparsity)?;
    let prompts = synthetic_prompts(requests);
    let reqs = requests_for(&prompts, tokens);
    let clock = crate::obs::SharedClock::default();
    let (reference, _) = greedy_references(spec, &pruned, &reqs, &prompts, &clock);

    let mut table = TableBuilder::new(
        &format!("serve formats ({} @ {})", spec.name(), sparsity.label()),
        &[
            "format",
            "tok/s b=1",
            &format!("tok/s b={batch}"),
            "bytes",
            "vs dense",
            "load ms",
            "parity",
        ],
    );
    let mut csv = CsvWriter::create(
        csv_path,
        &[
            "format",
            "resolved",
            "tokens_per_s_b1",
            "tokens_per_s_bb",
            "storage_bytes",
            "storage_ratio",
            "load_ms",
            "resident_bytes",
            "parity",
        ],
    )?;
    let mut rows = Vec::new();
    for &fmt in formats {
        let sp_hint = match fmt {
            SparseFormat::Csr => None,
            _ => Some(sparsity),
        };
        let stats = measure_sparse_format(
            spec,
            &pruned,
            &reference,
            &reqs,
            batch,
            fmt,
            sp_hint,
            &BenchObs::default(),
        )?;
        rows.push(ServeFormatRow {
            format: fmt.label().to_string(),
            resolved: stats.label.to_string(),
            tokens_per_s_b1: stats.b1.tokens_per_s,
            tokens_per_s_bb: stats.bb.tokens_per_s,
            storage_bytes: stats.storage_bytes,
            storage_ratio: stats.storage_ratio,
            load_ms: None,
            resident_bytes: None,
            parity_ok: stats.parity_ok,
        });
    }
    if let Some(path) = artifact {
        rows.push(artifact_row(spec, &pruned, &reference, &reqs, batch, sparsity, path)?);
    }
    for row in &rows {
        table.row(vec![
            if row.format == "artifact" {
                format!("artifact({})", row.resolved)
            } else {
                row.resolved.clone()
            },
            format!("{:.1}", row.tokens_per_s_b1),
            format!("{:.1}", row.tokens_per_s_bb),
            row.storage_bytes.to_string(),
            format!("{:.3}", row.storage_ratio),
            row.load_ms.map(|ms| format!("{ms:.1}")).unwrap_or_else(|| "-".into()),
            if row.parity_ok { "ok".into() } else { "MISMATCH".into() },
        ]);
        csv.write_row(&[
            row.format.clone(),
            row.resolved.clone(),
            format!("{:.2}", row.tokens_per_s_b1),
            format!("{:.2}", row.tokens_per_s_bb),
            row.storage_bytes.to_string(),
            format!("{:.4}", row.storage_ratio),
            row.load_ms.map(|ms| format!("{ms:.3}")).unwrap_or_default(),
            row.resident_bytes.map(|b| b.to_string()).unwrap_or_default(),
            row.parity_ok.to_string(),
        ])?;
    }
    table.print();
    println!("csv: {}", csv_path.display());
    Ok(rows)
}

/// The artifact row: compile (Auto) → save → timed load → serve from the
/// loaded operators, parity-gated against the same `eval::generate`
/// references as the in-memory rows.
fn artifact_row(
    spec: &crate::config::ModelSpec,
    pruned: &crate::model::params::ModelParams,
    reference: &std::collections::BTreeMap<String, String>,
    reqs: &[crate::serve::ServeRequest],
    batch: usize,
    sparsity: Sparsity,
    path: &std::path::Path,
) -> Result<ServeFormatRow> {
    use crate::ser::artifact::{self, ArtifactMeta};
    use crate::serve::bench::{run_engine, BenchObs};
    use crate::serve::ServeModel;

    let compiled =
        crate::sparse::CompiledLayers::compress(spec, pruned, SparseFormat::Auto, Some(sparsity))?;
    artifact::save(
        path,
        &compiled,
        &ArtifactMeta {
            model: spec.name(),
            corpus: "bench".into(),
            method: "magnitude".into(),
            sparsity: sparsity.label(),
            format: "auto".into(),
            quant: "none".into(),
            seed: 0,
            prune: None,
        },
    )?;
    drop(compiled);
    #[allow(clippy::disallowed_methods)]
    // fp-lint: allow(clock) — offline grid timing column, never served
    let t0 = std::time::Instant::now();
    let (loaded, _meta) = artifact::load(path)?;
    let load_ms = t0.elapsed().as_secs_f64() * 1e3;
    let model = ServeModel::from_compiled_ref(&loaded);
    // same engine loop (and admission + parity policy) as the
    // in-memory rows
    let obs = BenchObs::default();
    let (b1, texts1) = run_engine(&model, 1, "artifact b=1", reqs, &obs)?;
    let (bb, textsb) = run_engine(&model, batch, &format!("artifact b={batch}"), reqs, &obs)?;
    let parity_ok = crate::serve::bench::parity_against(reference, &[&texts1, &textsb]);
    Ok(ServeFormatRow {
        format: "artifact".into(),
        resolved: loaded.format_label().to_string(),
        tokens_per_s_b1: b1.tokens_per_s,
        tokens_per_s_bb: bb.tokens_per_s,
        storage_bytes: loaded.storage_bytes(),
        storage_ratio: loaded.storage_ratio(),
        load_ms: Some(load_ms),
        resident_bytes: Some(loaded.resident_bytes()),
        parity_ok,
    })
}

/// One row of [`run_paged_kv_grid`] output.
#[derive(Clone, Debug)]
pub struct PagedKvRow {
    /// Positions per KV page (`spec.seq` ⇒ the monolithic layout).
    pub kv_page: usize,
    pub tokens_per_s: f64,
    /// Peak KV bytes actually allocated during the run.
    pub kv_resident_bytes: usize,
    /// Worst-case bytes of the page budget (what monolithic preallocates).
    pub kv_capacity_bytes: usize,
    pub parity_ok: bool,
}

/// The paged-KV grid: the same dense weights and request set served
/// through each page size side by side — rows = page sizes (pass
/// `spec.seq` for the monolithic-equivalent row), columns = tokens/s,
/// peak resident KV bytes, worst-case capacity bytes, and greedy parity
/// vs `eval::generate`. The page-size axis behind
/// `benches/serve_decode.rs`; callers gate on each row's `parity_ok`
/// (streams must be bitwise independent of the page layout).
#[allow(clippy::too_many_arguments)]
pub fn run_paged_kv_grid(
    spec: &crate::config::ModelSpec,
    dense: &crate::model::params::ModelParams,
    pages: &[usize],
    prefill_chunk: usize,
    tokens: usize,
    batch: usize,
    requests: usize,
    csv_path: &std::path::Path,
) -> Result<Vec<PagedKvRow>> {
    use crate::serve::bench::{
        greedy_references, requests_for, run_engine_cfg, synthetic_prompts,
    };
    use crate::serve::{EngineConfig, KvPage, KvPool, ServeModel};

    let prompts = synthetic_prompts(requests);
    let reqs = requests_for(&prompts, tokens);
    let clock = crate::obs::SharedClock::default();
    let (reference, _) = greedy_references(spec, dense, &reqs, &prompts, &clock);
    let model = ServeModel::dense(spec, dense)?;

    let mut table = TableBuilder::new(
        &format!("paged KV ({}, batch {batch})", spec.name()),
        &["page", "tok/s", "resident B", "capacity B", "parity"],
    );
    let mut csv = CsvWriter::create(
        csv_path,
        &["kv_page", "tokens_per_s", "kv_resident_bytes", "kv_capacity_bytes", "parity"],
    )?;
    let mut rows = Vec::new();
    for &page in pages {
        let cfg = EngineConfig {
            max_batch: batch,
            queue_cap: requests.max(1),
            kv_page: page,
            kv_pages: None,
            prefill_chunk,
            ..EngineConfig::default()
        };
        let (stats, texts) =
            run_engine_cfg(&model, &cfg, &format!("paged p={page} b={batch}"), &reqs)?;
        let parity_ok = crate::serve::bench::parity_against(&reference, &[&texts]);
        let capacity =
            KvPool::full_context_budget(spec, page, batch) * KvPage::bytes_for(page, spec.d);
        rows.push(PagedKvRow {
            kv_page: page,
            tokens_per_s: stats.tokens_per_s,
            kv_resident_bytes: stats.kv_resident_bytes,
            kv_capacity_bytes: capacity,
            parity_ok,
        });
    }
    for row in &rows {
        table.row(vec![
            if row.kv_page >= spec.seq {
                format!("{} (monolithic)", row.kv_page)
            } else {
                row.kv_page.to_string()
            },
            format!("{:.1}", row.tokens_per_s),
            row.kv_resident_bytes.to_string(),
            row.kv_capacity_bytes.to_string(),
            if row.parity_ok { "ok".into() } else { "MISMATCH".into() },
        ]);
        csv.write_row(&[
            row.kv_page.to_string(),
            format!("{:.2}", row.tokens_per_s),
            row.kv_resident_bytes.to_string(),
            row.kv_capacity_bytes.to_string(),
            row.parity_ok.to_string(),
        ])?;
    }
    table.print();
    println!("csv: {}", csv_path.display());
    Ok(rows)
}

pub struct NetClientRow {
    /// Concurrent loopback client sessions.
    pub clients: usize,
    pub req_per_s: f64,
    pub stream_p99_ms: f64,
    pub aborted_by_disconnect: u64,
    pub parity_ok: bool,
}

/// The network-concurrency grid: the same dense weights served through
/// the real `serve --listen` front-end at increasing client counts (with
/// connection churn and one mid-stream disconnect per run), rows =
/// client counts, columns = sustained req/s, client-observed stream p99,
/// and greedy parity vs `eval::generate`. The `--net` axis behind
/// `benches/serve_decode.rs`; callers gate on each row's `parity_ok`
/// (socket-layer concurrency must not perturb a single token).
pub fn run_net_client_grid(
    spec: &crate::config::ModelSpec,
    dense: &crate::model::params::ModelParams,
    client_counts: &[usize],
    tokens: usize,
    batch: usize,
    requests_per_client: usize,
    csv_path: &std::path::Path,
) -> Result<Vec<NetClientRow>> {
    use crate::serve::bench::{run_net_bench, NetBenchConfig, ServeBenchConfig};

    let mut table = TableBuilder::new(
        &format!("net front-end ({}, batch {batch}, churn on)", spec.name()),
        &["clients", "req/s", "stream p99 ms", "aborted", "parity"],
    );
    let mut csv = CsvWriter::create(
        csv_path,
        &["clients", "req_per_s", "stream_p99_ms", "aborted_by_disconnect", "parity"],
    )?;
    let mut rows = Vec::new();
    for &clients in client_counts {
        let cfg = ServeBenchConfig { tokens, batch, requests: 1, ..ServeBenchConfig::default() };
        let net = NetBenchConfig { clients, requests_per_client, churn: true };
        let report = run_net_bench(spec, dense, &cfg, &net)?;
        rows.push(NetClientRow {
            clients,
            req_per_s: report.req_per_s,
            stream_p99_ms: report.p99_ms,
            aborted_by_disconnect: report.aborted_by_disconnect,
            parity_ok: report.parity_ok,
        });
    }
    for row in &rows {
        table.row(vec![
            row.clients.to_string(),
            format!("{:.1}", row.req_per_s),
            format!("{:.1}", row.stream_p99_ms),
            row.aborted_by_disconnect.to_string(),
            if row.parity_ok { "ok".into() } else { "MISMATCH".into() },
        ]);
        csv.write_row(&[
            row.clients.to_string(),
            format!("{:.2}", row.req_per_s),
            format!("{:.2}", row.stream_p99_ms),
            row.aborted_by_disconnect.to_string(),
            row.parity_ok.to_string(),
        ])?;
    }
    table.print();
    println!("csv: {}", csv_path.display());
    Ok(rows)
}

fn pretty_name(m: &Method) -> &'static str {
    match m {
        Method::Dense => "Dense",
        Method::Solver(crate::config::SolverKind::Fista) => "FISTAPruner",
        Method::Solver(crate::config::SolverKind::Admm) => "ADMM",
        Method::Solver(crate::config::SolverKind::FrankWolfe) => "Frank-Wolfe",
        Method::Baseline(crate::baselines::BaselineKind::SparseGpt) => "SparseGPT",
        Method::Baseline(crate::baselines::BaselineKind::Wanda) => "Wanda",
        Method::Baseline(crate::baselines::BaselineKind::Magnitude) => "Magnitude",
    }
}

//! Shared experiment harness for the benches and examples: a `Lab` that
//! caches corpora, trained checkpoints and (when artifacts exist) a PJRT
//! session, plus the method×sparsity grid runner that regenerates the
//! paper's tables.
//!
//! The Lab degrades gracefully: on a clean checkout with no
//! `artifacts/manifest.json` (or a build without the `xla-pjrt` feature)
//! it runs entirely on the native multithreaded kernels — pruning uses
//! `Engine::Native`, evaluation uses the native forward pass, and only
//! training (which needs the `train_{model}` artifact) is unavailable.
//!
//! Environment knobs (all optional):
//!   FP_BENCH_FAST=1     — shrink models/steps/items for smoke runs
//!   FP_TRAIN_STEPS=N    — override training steps
//!   FP_CALIB=N          — override calibration sample count
//!   FP_EVAL_WINDOWS=N   — override perplexity window count
//!   FP_THREADS=N        — native kernel thread count (0 = auto)

pub mod grid;

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::config::{repo_root, Engine, ModelSpec, Presets, PruneOptions, TrainOptions};
use crate::data::{sampler::calibration_windows, Corpus};
use crate::eval::perplexity::{perplexity, perplexity_native};
use crate::model::params::ModelParams;
use crate::pruner::scheduler::{prune_model, Method};
use crate::pruner::PruneReport;
use crate::runtime::{Manifest, Session};
use crate::train::ensure_checkpoint;

pub use grid::{
    run_grid, run_net_client_grid, run_paged_kv_grid, run_serve_format_grid, GridSpec,
    NetClientRow, PagedKvRow, ServeFormatRow,
};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// True when FP_BENCH_FAST=1 (CI smoke mode).
pub fn fast_mode() -> bool {
    std::env::var("FP_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Experiment context shared by benches/examples.
pub struct Lab {
    pub root: PathBuf,
    pub presets: Presets,
    session: Option<Session>,
    corpora: BTreeMap<String, Corpus>,
    checkpoints: BTreeMap<String, ModelParams>,
}

impl Lab {
    /// Build a Lab. Never fails for missing artifacts — the session is
    /// simply absent then and everything runs on the native path.
    pub fn new() -> Result<Lab> {
        crate::util::logging::init();
        let root = repo_root()?;
        let presets = Presets::load(&root)?;
        if let Some(n) = std::env::var("FP_THREADS").ok().and_then(|v| v.parse().ok()) {
            crate::tensor::par::set_threads(n);
        }
        let session = match Manifest::load(&crate::config::paths::artifacts_dir(&root)) {
            Ok(m) => match Session::new(Arc::new(m)) {
                Ok(s) => Some(s),
                Err(e) => {
                    crate::log_warn!("PJRT session unavailable ({e:#}); native-only mode");
                    None
                }
            },
            Err(e) => {
                crate::log_warn!("artifacts unavailable ({e:#}); native-only mode");
                None
            }
        };
        Ok(Lab { root, presets, session, corpora: BTreeMap::new(), checkpoints: BTreeMap::new() })
    }

    /// Lab for artifact-dependent tests/benches, or `None` (with a note on
    /// stderr) when the XLA path is unavailable and the caller should skip.
    pub fn try_with_artifacts() -> Option<Lab> {
        match Lab::new() {
            Ok(lab) if lab.has_artifacts() => Some(lab),
            Ok(_) => {
                eprintln!("skipping: artifacts/PJRT backend unavailable");
                None
            }
            Err(e) => {
                eprintln!("skipping: {e:#}");
                None
            }
        }
    }

    /// True when the XLA artifact path is usable.
    pub fn has_artifacts(&self) -> bool {
        self.session.is_some()
    }

    pub fn session(&self) -> Option<&Session> {
        self.session.as_ref()
    }

    /// The session, or a descriptive error for callers that require it.
    pub fn require_session(&self) -> Result<&Session> {
        self.session.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "this path needs the XLA artifacts (run `make artifacts` and build with the \
                 xla-pjrt feature); the native engine covers pruning and evaluation without them"
            )
        })
    }

    /// The solver engine this environment supports best.
    pub fn default_engine(&self) -> Engine {
        if self.has_artifacts() {
            Engine::Xla
        } else {
            Engine::Native
        }
    }

    /// Prune options wired for this environment (engine picked by
    /// `default_engine`, everything else default).
    pub fn default_prune_options(&self) -> PruneOptions {
        PruneOptions { engine: self.default_engine(), ..Default::default() }
    }

    /// Generate (and cache) a corpus by preset name.
    pub fn corpus(&mut self, name: &str) -> Result<&Corpus> {
        if !self.corpora.contains_key(name) {
            let cfg = self.presets.corpus(name)?.clone();
            self.corpora.insert(name.to_string(), Corpus::generate(&cfg));
        }
        Ok(&self.corpora[name])
    }

    /// Default training steps (env-overridable; /4 in fast mode).
    pub fn train_steps(&self) -> usize {
        let base = env_usize("FP_TRAIN_STEPS", self.presets.train.steps);
        if fast_mode() {
            (base / 4).max(20)
        } else {
            base
        }
    }

    /// Calibration sample count (env-overridable; /4 in fast mode).
    pub fn calib_samples(&self) -> usize {
        let base = env_usize("FP_CALIB", self.presets.calib_nsamples);
        if fast_mode() {
            (base / 4).max(8)
        } else {
            base
        }
    }

    /// Perplexity window count.
    pub fn eval_windows(&self) -> usize {
        env_usize("FP_EVAL_WINDOWS", if fast_mode() { 32 } else { 128 })
    }

    /// Train-or-load the canonical checkpoint for (model, train corpus).
    /// Fails without artifacts unless a cached checkpoint exists.
    pub fn trained(&mut self, model: &str, corpus: &str) -> Result<ModelParams> {
        let key = format!("{model}@{corpus}@{}", self.train_steps());
        if let Some(p) = self.checkpoints.get(&key) {
            return Ok(p.clone());
        }
        let steps = self.train_steps();
        let spec = self.presets.model(model)?.clone();
        self.corpus(corpus)?;
        let c = &self.corpora[corpus];
        let opts = TrainOptions {
            steps,
            lr: self.presets.train.lr,
            warmup: self.presets.train.warmup.min(steps / 4),
            seed: self.presets.train.seed,
        };
        let params =
            ensure_checkpoint(&self.root, self.session.as_ref(), &self.presets, &spec, c, &opts)?;
        self.checkpoints.insert(key, params.clone());
        Ok(params)
    }

    /// `trained`, falling back to deterministic random initialization when
    /// no checkpoint can be produced (perf/scaling benches where weight
    /// quality is irrelevant).
    pub fn trained_or_init(&mut self, model: &str, corpus: &str) -> Result<ModelParams> {
        match self.trained(model, corpus) {
            Ok(p) => Ok(p),
            Err(e) => {
                crate::log_warn!("using untrained weights for {model} ({e:#})");
                let spec = self.presets.model(model)?.clone();
                Ok(crate::model::init::init_params(&spec, self.presets.train.seed))
            }
        }
    }

    /// Calibration windows from a corpus train split.
    pub fn calib(&mut self, corpus: &str, n: usize, seed: u64) -> Result<Vec<Vec<i32>>> {
        let seq = self.presets.seq_len;
        self.corpus(corpus)?;
        Ok(calibration_windows(&self.corpora[corpus], n, seq, seed))
    }

    /// Prune with a method and options.
    pub fn prune(
        &mut self,
        model: &str,
        params: &ModelParams,
        calib: &[Vec<i32>],
        method: Method,
        opts: &PruneOptions,
    ) -> Result<(ModelParams, PruneReport)> {
        let spec = self.presets.model(model)?.clone();
        if matches!(opts.engine, Engine::Xla) && self.session.is_none() {
            bail!("Engine::Xla requested but artifacts are unavailable; use Engine::Native");
        }
        prune_model(self.session.as_ref(), &self.presets, &spec, params, calib, method, opts)
    }

    /// Held-out perplexity (artifact scorer when available, else native).
    pub fn ppl(&mut self, model: &str, params: &ModelParams, corpus: &str) -> Result<f64> {
        let spec = self.presets.model(model)?.clone();
        let max_w = self.eval_windows();
        self.corpus(corpus)?;
        let c = &self.corpora[corpus];
        match &self.session {
            Some(s) => perplexity(s, &self.presets, &spec, params, c, max_w),
            None => perplexity_native(&spec, params, c, max_w),
        }
    }

    /// Zero-shot probe mean accuracy (artifact scorer when available).
    pub fn zeroshot(
        &mut self,
        model: &str,
        params: &ModelParams,
        corpus: &str,
        items: usize,
        seed: u64,
    ) -> Result<(Vec<crate::eval::zeroshot::TaskResult>, f64)> {
        let spec = self.presets.model(model)?.clone();
        self.corpus(corpus)?;
        let c = &self.corpora[corpus];
        match &self.session {
            Some(s) => {
                crate::eval::zeroshot::run_all_tasks(s, &self.presets, &spec, params, c, items, seed)
            }
            None => Ok(crate::eval::zeroshot::run_all_tasks_native(&spec, params, c, items, seed)),
        }
    }

    pub fn spec(&self, model: &str) -> Result<&ModelSpec> {
        self.presets.model(model)
    }

    /// Where bench outputs (csv) go.
    pub fn bench_out(&self) -> PathBuf {
        self.root.join("artifacts/bench_out")
    }
}

//! Shared experiment harness for the benches and examples: a `Lab` that
//! caches corpora, trained checkpoints and a PJRT session, plus the
//! method×sparsity grid runner that regenerates the paper's tables.
//!
//! Environment knobs (all optional):
//!   FP_BENCH_FAST=1     — shrink models/steps/items for smoke runs
//!   FP_TRAIN_STEPS=N    — override training steps
//!   FP_CALIB=N          — override calibration sample count
//!   FP_EVAL_WINDOWS=N   — override perplexity window count

pub mod grid;

use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;

use crate::config::{repo_root, ModelSpec, Presets, PruneOptions, TrainOptions};
use crate::data::{sampler::calibration_windows, Corpus};
use crate::eval::perplexity::perplexity;
use crate::model::params::ModelParams;
use crate::pruner::scheduler::{prune_model, Method};
use crate::pruner::PruneReport;
use crate::runtime::{Manifest, Session};
use crate::train::ensure_checkpoint;

pub use grid::{run_grid, GridSpec};

fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// True when FP_BENCH_FAST=1 (CI smoke mode).
pub fn fast_mode() -> bool {
    std::env::var("FP_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
}

/// Experiment context shared by benches/examples.
pub struct Lab {
    pub root: PathBuf,
    pub presets: Presets,
    pub session: Session,
    corpora: BTreeMap<String, Corpus>,
    checkpoints: BTreeMap<String, ModelParams>,
}

impl Lab {
    pub fn new() -> Result<Lab> {
        crate::util::logging::init();
        let root = repo_root()?;
        let presets = Presets::load(&root)?;
        let session = Session::new(Arc::new(Manifest::load_default()?))?;
        Ok(Lab { root, presets, session, corpora: BTreeMap::new(), checkpoints: BTreeMap::new() })
    }

    /// Generate (and cache) a corpus by preset name.
    pub fn corpus(&mut self, name: &str) -> Result<&Corpus> {
        if !self.corpora.contains_key(name) {
            let cfg = self.presets.corpus(name)?.clone();
            self.corpora.insert(name.to_string(), Corpus::generate(&cfg));
        }
        Ok(&self.corpora[name])
    }

    /// Default training steps (env-overridable; /4 in fast mode).
    pub fn train_steps(&self) -> usize {
        let base = env_usize("FP_TRAIN_STEPS", self.presets.train.steps);
        if fast_mode() {
            (base / 4).max(20)
        } else {
            base
        }
    }

    /// Calibration sample count (env-overridable; /4 in fast mode).
    pub fn calib_samples(&self) -> usize {
        let base = env_usize("FP_CALIB", self.presets.calib_nsamples);
        if fast_mode() {
            (base / 4).max(8)
        } else {
            base
        }
    }

    /// Perplexity window count.
    pub fn eval_windows(&self) -> usize {
        env_usize("FP_EVAL_WINDOWS", if fast_mode() { 32 } else { 128 })
    }

    /// Train-or-load the canonical checkpoint for (model, train corpus).
    pub fn trained(&mut self, model: &str, corpus: &str) -> Result<ModelParams> {
        let key = format!("{model}@{corpus}@{}", self.train_steps());
        if let Some(p) = self.checkpoints.get(&key) {
            return Ok(p.clone());
        }
        let steps = self.train_steps();
        let spec = self.presets.model(model)?.clone();
        self.corpus(corpus)?;
        let c = &self.corpora[corpus];
        let opts = TrainOptions {
            steps,
            lr: self.presets.train.lr,
            warmup: self.presets.train.warmup.min(steps / 4),
            seed: self.presets.train.seed,
        };
        let params = ensure_checkpoint(&self.root, &self.session, &self.presets, &spec, c, &opts)?;
        self.checkpoints.insert(key, params.clone());
        Ok(params)
    }

    /// Calibration windows from a corpus train split.
    pub fn calib(&mut self, corpus: &str, n: usize, seed: u64) -> Result<Vec<Vec<i32>>> {
        let seq = self.presets.seq_len;
        self.corpus(corpus)?;
        Ok(calibration_windows(&self.corpora[corpus], n, seq, seed))
    }

    /// Prune with a method and options.
    pub fn prune(
        &mut self,
        model: &str,
        params: &ModelParams,
        calib: &[Vec<i32>],
        method: Method,
        opts: &PruneOptions,
    ) -> Result<(ModelParams, PruneReport)> {
        let spec = self.presets.model(model)?.clone();
        prune_model(&self.session, &self.presets, &spec, params, calib, method, opts)
    }

    /// Held-out perplexity.
    pub fn ppl(&mut self, model: &str, params: &ModelParams, corpus: &str) -> Result<f64> {
        let spec = self.presets.model(model)?.clone();
        let max_w = self.eval_windows();
        self.corpus(corpus)?;
        let c = &self.corpora[corpus];
        perplexity(&self.session, &self.presets, &spec, params, c, max_w)
    }

    pub fn spec(&self, model: &str) -> Result<&ModelSpec> {
        self.presets.model(model)
    }

    /// Where bench outputs (csv) go.
    pub fn bench_out(&self) -> PathBuf {
        self.root.join("artifacts/bench_out")
    }
}

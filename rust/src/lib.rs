//! FISTAPruner: convex-optimization-based layer-wise post-training pruning
//! for transformer language models.
//!
//! Reproduction of Zhao et al., *"A Convex-optimization-based Layer-wise
//! Post-training Pruner for Large Language Models"* (2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: calibration capture, Gram
//!   accumulation, the adaptive-λ outer loop (paper Algorithm 1), the
//!   intra-layer error-correction replay (paper §3.1), the parallel
//!   decoder-layer scheduler (paper §3.4), baselines (SparseGPT, Wanda,
//!   magnitude), the training / evaluation substrate, and the PJRT runtime
//!   that executes the AOT artifacts.
//! * **L2 (python/compile/model.py)** — JAX graphs (FISTA solve, Gram
//!   chunks, model forward/score/train), lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the FISTA hot
//!   loop and Gram accumulation.
//!
//! Python never runs on the request path: after `make artifacts` the rust
//! binary is self-contained.

pub mod util;
pub mod ser;
pub mod config;
pub mod tensor;
pub mod linalg;
pub mod data;
pub mod model;
pub mod runtime;
pub mod pruner;
pub mod sparse;
pub mod baselines;
pub mod train;
pub mod eval;
pub mod metrics;
pub mod testing;
pub mod bench_support;
pub mod cli;

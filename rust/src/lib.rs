//! FISTAPruner: convex-optimization-based layer-wise post-training pruning
//! for transformer language models.
//!
//! Reproduction of Zhao et al., *"A Convex-optimization-based Layer-wise
//! Post-training Pruner for Large Language Models"* (2024) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordinator: calibration capture, Gram
//!   accumulation, the adaptive-λ outer loop (paper Algorithm 1), the
//!   intra-layer error-correction replay (paper §3.1), the parallel
//!   decoder-layer scheduler (paper §3.4), baselines (SparseGPT, Wanda,
//!   magnitude), the training / evaluation substrate, and the PJRT runtime
//!   that executes the AOT artifacts.
//! * **L2 (python/compile/model.py)** — JAX graphs (FISTA solve, Gram
//!   chunks, model forward/score/train), lowered once to HLO text.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for the FISTA hot
//!   loop and Gram accumulation.
//!
//! Python never runs on the request path: after `make artifacts` the rust
//! binary is self-contained.
//!
//! # Compute backends
//!
//! Every solver-facing operation goes through one of two backends behind
//! the `pruner::engine::SolverEngine` trait:
//!
//! * **Native** (always available) — the multithreaded, cache-blocked
//!   kernel layer in `tensor::{par, kernels, ops}`: row-block parallel
//!   matmuls, a fused three-way Gram product, a fused FISTA iteration, and
//!   a native activation-capture path hooked into the model forward. All
//!   kernels are deterministic with respect to the thread count (see
//!   `tensor::par`), which is what makes the scheduler's parallel modes
//!   bit-exact across worker counts.
//! * **XLA** (`xla-pjrt` cargo feature + `make artifacts`) — the AOT
//!   artifacts executed through PJRT; `runtime::Session`/`ExecutorPool`
//!   manage clients and the device-fleet worker pool.
//!
//! A clean checkout builds and runs the whole pruning + evaluation stack
//! (`cargo build --release && cargo test -q`, `cargo run --release
//! --example quickstart`) on the native backend alone; the XLA path layers
//! on top without changing any caller.
//!
//! # Pipeline at a glance
//!
//! calibration corpus → `model::embed` → per-layer capture
//! (`pruner::unit`) → Gram statistics (`tensor::kernels::gram3` or the
//! `gram_{n}` artifact) → warm start (`baselines`) → Algorithm 1
//! (`pruner::lambda` over `pruner::fista`) → exact-sparsity rounding
//! (`pruner::rounding`) → report (`pruner::report`) → evaluation
//! (`eval::perplexity`, `eval::zeroshot`) and sparse inference (`sparse`).
//!
//! The pruned artifact is then the hot path of the serving stack
//! (`serve`): KV-cached incremental decode with continuous batching over
//! dense or CSR weights, behind the `serve` / `serve-bench` CLI commands.

#![cfg_attr(feature = "simd", feature(portable_simd))]

pub mod util;
pub mod ser;
pub mod config;
pub mod tensor;
pub mod linalg;
pub mod data;
pub mod model;
pub mod runtime;
pub mod pruner;
pub mod sparse;
pub mod serve;
pub mod baselines;
pub mod train;
pub mod eval;
pub mod metrics;
pub mod obs;
pub mod testing;
pub mod bench_support;
pub mod cli;

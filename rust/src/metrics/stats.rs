//! Summary statistics for repeated runs (paper §4.4 reports mean ± std).

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// (mean, sample standard deviation).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let m = mean(xs);
    if xs.len() < 2 {
        return (m, 0.0);
    }
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    (m, var.sqrt())
}

/// Linearly-interpolated percentile of unsorted samples, `p` in [0, 100]
/// (p50/p99 serving-latency reporting). NaN samples are filtered out —
/// a poisoned latency can neither panic the sort (`f64::total_cmp`, the
/// same fix as the rounding comparators) nor leak into the result — and
/// the result is NaN only when no finite-ordered sample remains.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    percentiles(xs, &[p])[0]
}

/// Several percentiles over one sort. `percentile` re-sorts per call,
/// which the bench report paths paid twice (p50 + p99) per latency
/// vector; this filters NaNs and sorts once, then interpolates every
/// requested quantile against the shared sorted buffer.
pub fn percentiles(xs: &[f64], ps: &[f64]) -> Vec<f64> {
    let mut s: Vec<f64> = xs.iter().copied().filter(|v| !v.is_nan()).collect();
    s.sort_by(f64::total_cmp);
    ps.iter().map(|&p| percentile_sorted(&s, p)).collect()
}

/// Percentile over already-sorted (`f64::total_cmp`), NaN-free samples.
pub fn percentile_sorted(s: &[f64], p: f64) -> f64 {
    if s.is_empty() {
        return f64::NAN;
    }
    let rank = (p.clamp(0.0, 100.0) / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Geometric mean (perplexities combine multiplicatively).
pub fn geomean(xs: &[f64]) -> f64 {
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_known() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn singleton_has_zero_std() {
        let (m, s) = mean_std(&[3.0]);
        assert_eq!(m, 3.0);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_known_values() {
        let xs = [4.0, 1.0, 3.0, 2.0]; // sorted: 1 2 3 4
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
        assert!(percentile(&[], 50.0).is_nan());
    }

    #[test]
    fn percentile_survives_nan_samples() {
        // regression: partial_cmp().expect(..) used to panic here
        let xs = [4.0, f64::NAN, 1.0, 3.0, f64::NAN, 2.0];
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12, "NaNs are filtered, not sorted");
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!(percentile(&[f64::NAN, f64::NAN], 50.0).is_nan(), "all-NaN has no percentile");
        // ±0.0 and infinities stay totally ordered under total_cmp
        assert_eq!(percentile(&[f64::INFINITY, -0.0, 0.0], 0.0), -0.0);
    }

    #[test]
    fn percentiles_match_percentile_with_one_sort() {
        let xs = [9.0, f64::NAN, 1.0, 5.0, 3.0, 7.0];
        let qs = percentiles(&xs, &[0.0, 25.0, 50.0, 99.0, 100.0]);
        for (i, p) in [0.0, 25.0, 50.0, 99.0, 100.0].iter().enumerate() {
            assert_eq!(qs[i], percentile(&xs, *p), "p{p}");
        }
        assert!(percentiles(&[], &[50.0])[0].is_nan());
        assert!(percentiles(&[f64::NAN], &[50.0])[0].is_nan());
        assert!(percentiles(&xs, &[]).is_empty());
    }
}

//! Summary statistics for repeated runs (paper §4.4 reports mean ± std).

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// (mean, sample standard deviation).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let m = mean(xs);
    if xs.len() < 2 {
        return (m, 0.0);
    }
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    (m, var.sqrt())
}

/// Geometric mean (perplexities combine multiplicatively).
pub fn geomean(xs: &[f64]) -> f64 {
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_known() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn singleton_has_zero_std() {
        let (m, s) = mean_std(&[3.0]);
        assert_eq!(m, 3.0);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }
}

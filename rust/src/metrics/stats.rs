//! Summary statistics for repeated runs (paper §4.4 reports mean ± std).

pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// (mean, sample standard deviation).
pub fn mean_std(xs: &[f64]) -> (f64, f64) {
    let m = mean(xs);
    if xs.len() < 2 {
        return (m, 0.0);
    }
    let var = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64;
    (m, var.sqrt())
}

/// Linearly-interpolated percentile of unsorted samples, `p` in [0, 100]
/// (p50/p99 serving-latency reporting). NaN for an empty slice.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).expect("percentile over NaN-free samples"));
    let rank = (p.clamp(0.0, 100.0) / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        s[lo] + (rank - lo as f64) * (s[hi] - s[lo])
    }
}

/// Geometric mean (perplexities combine multiplicatively).
pub fn geomean(xs: &[f64]) -> f64 {
    (xs.iter().map(|x| x.ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std_known() {
        let (m, s) = mean_std(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]);
        assert!((m - 5.0).abs() < 1e-12);
        assert!((s - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn singleton_has_zero_std() {
        let (m, s) = mean_std(&[3.0]);
        assert_eq!(m, 3.0);
        assert_eq!(s, 0.0);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn percentile_known_values() {
        let xs = [4.0, 1.0, 3.0, 2.0]; // sorted: 1 2 3 4
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
        assert!((percentile(&xs, 25.0) - 1.75).abs() < 1e-12);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
        assert!(percentile(&[], 50.0).is_nan());
    }
}

//! A point-in-time stats surface: counters + gauges + histograms.
//!
//! This is what the serve engine exports live (the `{"type":"stats"}`
//! control request on the TCP front-end) and what the CLI commands dump
//! at exit. Everything inside serializes with sorted keys, so snapshots
//! diff cleanly and tests can pin exact shapes.

use std::collections::BTreeMap;

use crate::ser::json::Json;

use super::counters::Counters;
use super::histogram::Histogram;

#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    pub counters: Counters,
    gauges: BTreeMap<&'static str, f64>,
    hists: BTreeMap<&'static str, Histogram>,
}

impl Snapshot {
    pub fn new() -> Self {
        Snapshot::default()
    }

    /// Set an instantaneous level (queue depth, KV pages in use, ...).
    pub fn gauge(&mut self, name: &'static str, value: f64) {
        self.gauges.insert(name, value);
    }

    pub fn gauge_value(&self, name: &str) -> Option<f64> {
        self.gauges.get(name).copied()
    }

    /// Attach a distribution (merged into any histogram already under
    /// `name`, so shards fold in cleanly).
    pub fn hist(&mut self, name: &'static str, h: Histogram) {
        match self.hists.get_mut(name) {
            Some(existing) => existing.merge(&h),
            None => {
                self.hists.insert(name, h);
            }
        }
    }

    pub fn hist_ref(&self, name: &str) -> Option<&Histogram> {
        self.hists.get(name)
    }

    /// `{"counters": {...}, "gauges": {...}, "histograms": {name:
    /// {...buckets..., "p50": x, "p99": y}}}` — quantiles precomputed so
    /// consumers need no bucket math.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("counters".to_string(), self.counters.to_json());
        let mut g = BTreeMap::new();
        for (&k, &v) in &self.gauges {
            g.insert(k.to_string(), Json::Num(v));
        }
        m.insert("gauges".to_string(), Json::Obj(g));
        let mut hs = BTreeMap::new();
        for (&k, h) in &self.hists {
            let mut obj = match h.to_json() {
                Json::Obj(o) => o,
                _ => unreachable!("Histogram::to_json returns an object"),
            };
            if !h.is_empty() {
                for (label, q) in [("p50", 50.0), ("p90", 90.0), ("p99", 99.0)] {
                    obj.insert(label.to_string(), Json::Num(round3(h.quantile(q))));
                }
            }
            hs.insert(k.to_string(), Json::Obj(obj));
        }
        m.insert("histograms".to_string(), Json::Obj(hs));
        Json::Obj(m)
    }

    /// One-line report footer.
    pub fn summary(&self) -> String {
        let mut parts = Vec::new();
        let counters = self.counters.summary();
        if !counters.is_empty() {
            parts.push(counters);
        }
        for (k, v) in &self.gauges {
            parts.push(format!("{k}={v}"));
        }
        for (k, h) in &self.hists {
            if h.is_empty() {
                parts.push(format!("{k}[n=0]"));
            } else {
                parts.push(format!(
                    "{k}[n={} p50={:.3} p99={:.3}]",
                    h.count(),
                    h.quantile(50.0),
                    h.quantile(99.0)
                ));
            }
        }
        parts.join(" ")
    }
}

fn round3(x: f64) -> f64 {
    (x * 1e3).round() / 1e3
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_has_the_three_sections_with_quantiles() {
        let mut s = Snapshot::new();
        s.counters.incr("steps");
        s.gauge("queued", 2.0);
        let mut h = Histogram::new();
        for _ in 0..10 {
            h.record(4.0);
        }
        s.hist("decode_batch", h);
        let j = s.to_json();
        assert_eq!(j.get("counters").unwrap().get("steps").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("gauges").unwrap().get("queued").unwrap().as_f64(), Some(2.0));
        let hist = j.get("histograms").unwrap().get("decode_batch").unwrap();
        assert_eq!(hist.get("count").unwrap().as_f64(), Some(10.0));
        assert_eq!(hist.get("p50").unwrap().as_f64(), Some(4.0));
        assert_eq!(hist.get("p99").unwrap().as_f64(), Some(4.0));
    }

    #[test]
    fn hist_merges_shards_under_one_name() {
        let mut s = Snapshot::new();
        let mut a = Histogram::new();
        a.record(1.0);
        let mut b = Histogram::new();
        b.record(2.0);
        s.hist("step_ms", a);
        s.hist("step_ms", b);
        assert_eq!(s.hist_ref("step_ms").unwrap().count(), 2);
    }

    #[test]
    fn summary_reads_like_a_log_line() {
        let mut s = Snapshot::new();
        s.counters.incr("retired");
        s.gauge("active", 3.0);
        s.hist("step_ms", Histogram::new());
        let line = s.summary();
        assert!(line.contains("retired=1"), "{line}");
        assert!(line.contains("active=3"), "{line}");
        assert!(line.contains("step_ms[n=0]"), "{line}");
    }
}

//! CSV output for bench results (consumed by EXPERIMENTS.md tables).

use std::io::Write;
use std::path::Path;

use anyhow::Result;

/// Incremental CSV writer with quoting for commas/quotes.
pub struct CsvWriter {
    file: std::fs::File,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> Result<CsvWriter> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = CsvWriter { file: std::fs::File::create(path)? };
        w.write_row(header)?;
        Ok(w)
    }

    pub fn write_row<S: AsRef<str>>(&mut self, cells: &[S]) -> Result<()> {
        let line = cells.iter().map(|c| quote(c.as_ref())).collect::<Vec<_>>().join(",");
        writeln!(self.file, "{line}")?;
        Ok(())
    }
}

fn quote(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_quotes() {
        let path = std::env::temp_dir().join("fp_csv_test.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.write_row(&["1", "x,y"]).unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\n1,\"x,y\"\n");
        std::fs::remove_file(&path).ok();
    }
}

//! Log-bucketed histogram for latency/size distributions.
//!
//! Buckets are quarter-octaves: sample `x > 0` lands in bucket
//! `floor(log2(x) * 4)`, so bucket boundaries are powers of 2^¼
//! (≈ 19% relative resolution) and the index range covers every finite
//! positive f64 in an `i32`. Non-positive samples are counted in a
//! dedicated `zeros` bucket (log buckets cannot hold them), NaNs are
//! ignored. Counts saturate instead of wrapping. Merging is exact
//! bucket-wise addition, so per-shard histograms fold into a global one
//! without re-observing samples.
//!
//! JSON shape is insertion-order independent (sorted keys throughout) —
//! pinned by the round-trip tests below.

use std::collections::BTreeMap;

use anyhow::{Context, Result};

use crate::ser::json::Json;

/// Sub-buckets per octave (power of 2).
const SUBS: f64 = 4.0;

#[derive(Clone, Debug)]
pub struct Histogram {
    /// Quarter-octave bucket index → count, positive samples only.
    buckets: BTreeMap<i32, u64>,
    /// Samples `<= 0` or non-finite (a log scale has no bucket for
    /// them; min/max still see them).
    zeros: u64,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            buckets: BTreeMap::new(),
            zeros: 0,
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }
}

fn bucket_index(x: f64) -> i32 {
    // x > 0 and finite here; the product stays well inside i32
    (x.log2() * SUBS).floor() as i32
}

fn bucket_lo(i: i32) -> f64 {
    (i as f64 / SUBS).exp2()
}

impl Histogram {
    pub fn new() -> Self {
        Histogram::default()
    }

    pub fn record(&mut self, x: f64) {
        if x.is_nan() {
            return;
        }
        self.count = self.count.saturating_add(1);
        if x.is_finite() {
            self.sum += x;
        }
        self.min = self.min.min(x);
        self.max = self.max.max(x);
        if x > 0.0 && x.is_finite() {
            let c = self.buckets.entry(bucket_index(x)).or_insert(0);
            *c = c.saturating_add(1);
        } else {
            self.zeros = self.zeros.saturating_add(1);
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        self.sum / self.count as f64
    }

    /// Fold `other` into `self` (exact on counts, saturating at u64).
    pub fn merge(&mut self, other: &Histogram) {
        self.count = self.count.saturating_add(other.count);
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
        self.zeros = self.zeros.saturating_add(other.zeros);
        for (&i, &n) in &other.buckets {
            let c = self.buckets.entry(i).or_insert(0);
            *c = c.saturating_add(n);
        }
    }

    /// Estimated quantile, `q` in [0, 100]: the geometric midpoint of
    /// the bucket holding the target rank, clamped to the observed
    /// [min, max]. Exact to one bucket (≤ ~19% relative error); NaN on
    /// an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        if q <= 0.0 {
            return self.min;
        }
        if q >= 100.0 {
            return self.max;
        }
        let target = (q / 100.0) * self.count as f64;
        let mut cum = self.zeros as f64;
        if cum >= target && self.zeros > 0 {
            // everything at or below zero collapses into one bucket
            return self.min.min(0.0);
        }
        for (&i, &n) in &self.buckets {
            cum += n as f64;
            if cum >= target {
                let mid = (bucket_lo(i) * bucket_lo(i + 1)).sqrt();
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Stable JSON: scalar fields plus `"buckets": {"<index>": count}`.
    /// `min`/`max` are omitted when empty (NaN is not JSON).
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("count".to_string(), Json::Num(self.count as f64));
        m.insert("zeros".to_string(), Json::Num(self.zeros as f64));
        m.insert("sum".to_string(), Json::Num(self.sum));
        if self.count > 0 {
            m.insert("min".to_string(), Json::Num(self.min));
            m.insert("max".to_string(), Json::Num(self.max));
        }
        let mut b = BTreeMap::new();
        for (&i, &n) in &self.buckets {
            b.insert(i.to_string(), Json::Num(n as f64));
        }
        m.insert("buckets".to_string(), Json::Obj(b));
        Json::Obj(m)
    }

    /// Inverse of [`to_json`](Histogram::to_json).
    pub fn from_json(v: &Json) -> Result<Histogram> {
        let mut h = Histogram::new();
        h.count = v.get("count").and_then(|x| x.as_u64()).context("histogram: count")?;
        h.zeros = v.get("zeros").and_then(|x| x.as_u64()).unwrap_or(0);
        h.sum = v.get("sum").and_then(|x| x.as_f64()).unwrap_or(0.0);
        if h.count > 0 {
            h.min = v.get("min").and_then(|x| x.as_f64()).context("histogram: min")?;
            h.max = v.get("max").and_then(|x| x.as_f64()).context("histogram: max")?;
        }
        if let Some(Json::Obj(b)) = v.get("buckets") {
            for (k, n) in b {
                let i: i32 = k.parse().with_context(|| format!("histogram bucket key {k}"))?;
                h.buckets.insert(i, n.as_u64().context("histogram bucket count")?);
            }
        }
        Ok(h)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_histogram_round_trips_and_has_nan_quantiles() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert!(h.quantile(50.0).is_nan());
        assert!(h.mean().is_nan());
        let j = h.to_json().to_string_compact();
        assert_eq!(j, "{\"buckets\":{},\"count\":0,\"sum\":0,\"zeros\":0}");
        let back = Histogram::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.count(), 0);
        assert!(back.quantile(99.0).is_nan());
    }

    #[test]
    fn single_bucket_quantiles_are_exactish() {
        let mut h = Histogram::new();
        for _ in 0..100 {
            h.record(10.0);
        }
        assert_eq!(h.count(), 100);
        // one bucket: every quantile clamps to the only observed value
        assert_eq!(h.quantile(1.0), 10.0);
        assert_eq!(h.quantile(50.0), 10.0);
        assert_eq!(h.quantile(99.0), 10.0);
        assert_eq!(h.mean(), 10.0);
    }

    #[test]
    fn quantiles_track_a_spread_within_bucket_resolution() {
        let mut h = Histogram::new();
        for i in 1..=1000 {
            h.record(i as f64);
        }
        let p50 = h.quantile(50.0);
        let p99 = h.quantile(99.0);
        // quarter-octave buckets: ≤ ~19% relative error
        assert!((p50 / 500.0 - 1.0).abs() < 0.2, "p50 {p50}");
        assert!((p99 / 990.0 - 1.0).abs() < 0.2, "p99 {p99}");
        assert_eq!(h.quantile(0.0), 1.0);
        assert_eq!(h.quantile(100.0), 1000.0);
    }

    #[test]
    fn zeros_negatives_and_nans_are_handled() {
        let mut h = Histogram::new();
        h.record(0.0);
        h.record(-2.0);
        h.record(f64::NAN);
        h.record(4.0);
        assert_eq!(h.count(), 3, "NaN is ignored");
        assert_eq!(h.quantile(1.0), -2.0, "the sub-zero bucket reports min");
        assert_eq!(h.quantile(100.0), 4.0);
    }

    #[test]
    fn saturating_counts_never_wrap() {
        let mut h = Histogram::new();
        h.count = u64::MAX - 1;
        h.zeros = u64::MAX - 1;
        h.record(0.0);
        h.record(0.0);
        h.record(0.0);
        assert_eq!(h.count(), u64::MAX);
        assert_eq!(h.zeros, u64::MAX);
        let mut other = Histogram::new();
        other.record(1.0);
        other.record(1.0);
        h.merge(&other);
        assert_eq!(h.count(), u64::MAX, "merge saturates too");
    }

    #[test]
    fn merge_equals_observing_everything_in_one_histogram() {
        let (mut a, mut b, mut all) = (Histogram::new(), Histogram::new(), Histogram::new());
        for i in 1..=50 {
            a.record(i as f64);
            all.record(i as f64);
        }
        for i in 51..=100 {
            b.record(i as f64 * 0.5);
            all.record(i as f64 * 0.5);
        }
        a.merge(&b);
        assert_eq!(a.to_json().to_string_compact(), all.to_json().to_string_compact());
        assert_eq!(a.quantile(50.0), all.quantile(50.0));
    }

    #[test]
    fn json_is_stable_across_insertion_order_and_round_trips() {
        // exactly-representable values: `sum` must match bit-for-bit
        // regardless of accumulation order
        let xs = [3.0, 700.0, 0.25, 42.0, 42.0, 0.0];
        let mut fwd = Histogram::new();
        let mut rev = Histogram::new();
        for x in xs {
            fwd.record(x);
        }
        for x in xs.iter().rev() {
            rev.record(*x);
        }
        let j = fwd.to_json().to_string_compact();
        assert_eq!(j, rev.to_json().to_string_compact(), "insertion order must not leak");
        let back = Histogram::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.to_json().to_string_compact(), j, "round trip is lossless");
        assert_eq!(back.count(), fwd.count());
        assert_eq!(back.quantile(50.0), fwd.quantile(50.0));
    }
}

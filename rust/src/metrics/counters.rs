//! Named monotonic counters for serving-path accounting (connections
//! accepted/rejected/timed out, requests aborted by disconnect, ...).
//! Deliberately tiny: a sorted map of static names so reports and tests
//! read stable, alphabetical output.

use std::collections::BTreeMap;

use crate::ser::json::Json;

#[derive(Clone, Debug, Default)]
pub struct Counters {
    map: BTreeMap<&'static str, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Counters::default()
    }

    pub fn incr(&mut self, name: &'static str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &'static str, n: u64) {
        *self.map.entry(name).or_insert(0) += n;
    }

    /// Current value; unseen names read as zero.
    pub fn get(&self, name: &str) -> u64 {
        self.map.get(name).copied().unwrap_or(0)
    }

    pub fn iter(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.map.iter().map(|(&k, &v)| (k, v))
    }

    /// `a=1 b=2 ...` — for log lines and report footers.
    pub fn summary(&self) -> String {
        let parts: Vec<String> = self.map.iter().map(|(k, v)| format!("{k}={v}")).collect();
        parts.join(" ")
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        for (k, v) in &self.map {
            obj.insert((*k).to_string(), Json::Num(*v as f64));
        }
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_reads_back() {
        let mut c = Counters::new();
        c.incr("accepted");
        c.incr("accepted");
        c.add("aborted_by_disconnect", 3);
        assert_eq!(c.get("accepted"), 2);
        assert_eq!(c.get("aborted_by_disconnect"), 3);
        assert_eq!(c.get("never"), 0);
        assert_eq!(c.summary(), "aborted_by_disconnect=3 accepted=2");
    }

    #[test]
    fn json_shape_is_stable() {
        let mut c = Counters::new();
        c.incr("b");
        c.incr("a");
        assert_eq!(c.to_json().to_string_compact(), "{\"a\":1,\"b\":1}");
    }
}

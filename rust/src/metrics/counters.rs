//! Named monotonic counters for serving-path accounting (connections
//! accepted/rejected/timed out, requests aborted by disconnect, ...).
//! Deliberately tiny: a sorted map of static names so reports and tests
//! read stable, alphabetical output.

use std::collections::BTreeMap;

use crate::ser::json::Json;

#[derive(Clone, Debug, Default)]
pub struct Counters {
    map: BTreeMap<&'static str, u64>,
}

impl Counters {
    pub fn new() -> Self {
        Counters::default()
    }

    pub fn incr(&mut self, name: &'static str) {
        self.add(name, 1);
    }

    pub fn add(&mut self, name: &'static str, n: u64) {
        *self.map.entry(name).or_insert(0) += n;
    }

    /// Current value; unseen names read as zero.
    pub fn get(&self, name: &str) -> u64 {
        self.map.get(name).copied().unwrap_or(0)
    }

    /// Fold `other` into `self` (shared names add). Used to combine the
    /// engine's and the net front-end's accounting into one snapshot.
    pub fn merge(&mut self, other: &Counters) {
        for (k, v) in other.iter() {
            self.add(k, v);
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        self.map.iter().map(|(&k, &v)| (k, v))
    }

    /// `a=1 b=2 ...` — for log lines and report footers.
    pub fn summary(&self) -> String {
        let parts: Vec<String> = self.map.iter().map(|(k, v)| format!("{k}={v}")).collect();
        parts.join(" ")
    }

    pub fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        for (k, v) in &self.map {
            obj.insert((*k).to_string(), Json::Num(*v as f64));
        }
        Json::Obj(obj)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_and_reads_back() {
        let mut c = Counters::new();
        c.incr("accepted");
        c.incr("accepted");
        c.add("aborted_by_disconnect", 3);
        assert_eq!(c.get("accepted"), 2);
        assert_eq!(c.get("aborted_by_disconnect"), 3);
        assert_eq!(c.get("never"), 0);
        assert_eq!(c.summary(), "aborted_by_disconnect=3 accepted=2");
    }

    #[test]
    fn json_shape_is_stable() {
        let mut c = Counters::new();
        c.incr("b");
        c.incr("a");
        assert_eq!(c.to_json().to_string_compact(), "{\"a\":1,\"b\":1}");
    }

    #[test]
    fn json_is_insertion_order_independent() {
        let mut fwd = Counters::new();
        fwd.incr("x");
        fwd.add("y", 2);
        let mut rev = Counters::new();
        rev.add("y", 2);
        rev.incr("x");
        assert_eq!(fwd.to_json().to_string_compact(), rev.to_json().to_string_compact());
        assert_eq!(fwd.summary(), rev.summary());
    }

    #[test]
    fn empty_counters_have_empty_shapes() {
        let c = Counters::new();
        assert_eq!(c.summary(), "");
        assert_eq!(c.to_json().to_string_compact(), "{}");
        assert_eq!(c.iter().count(), 0);
    }

    #[test]
    fn merge_adds_shared_names_and_imports_new_ones() {
        let mut a = Counters::new();
        a.add("requests_in", 3);
        a.incr("stats_requests");
        let mut b = Counters::new();
        b.add("requests_in", 2);
        b.incr("closed");
        a.merge(&b);
        assert_eq!(a.get("requests_in"), 5);
        assert_eq!(a.get("stats_requests"), 1);
        assert_eq!(a.get("closed"), 1);
        // merging an empty map is a no-op
        let before = a.to_json().to_string_compact();
        a.merge(&Counters::new());
        assert_eq!(a.to_json().to_string_compact(), before);
    }
}

//! Reporting: ASCII tables (the paper-style bench output), CSV writers,
//! summary statistics, and the aggregation types behind the live stats
//! surface (`obs`): counters, log-bucketed histograms, snapshots.

pub mod counters;
pub mod csv;
pub mod histogram;
pub mod snapshot;
pub mod stats;
pub mod table;

pub use counters::Counters;
pub use histogram::Histogram;
pub use snapshot::Snapshot;
pub use stats::{mean, mean_std, percentile, percentile_sorted, percentiles};
pub use table::TableBuilder;

//! Reporting: ASCII tables (the paper-style bench output), CSV writers,
//! and summary statistics.

pub mod counters;
pub mod csv;
pub mod stats;
pub mod table;

pub use counters::Counters;
pub use stats::{mean, mean_std, percentile};
pub use table::TableBuilder;

//! ASCII tables shaped like the paper's (method × model, value cells).

/// Builds aligned text tables with a header row.
pub struct TableBuilder {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TableBuilder {
    pub fn new(title: &str, header: &[&str]) -> Self {
        TableBuilder {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    /// Format a float cell like the paper (2 decimal places).
    pub fn f(x: f64) -> String {
        format!("{x:.2}")
    }

    /// 4-decimal accuracy cell (paper Table 3 style).
    pub fn acc(x: f64) -> String {
        format!("{x:.4}")
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:>w$} | ", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = TableBuilder::new("T", &["Method", "s1", "s2"]);
        t.row(vec!["Dense".into(), TableBuilder::f(27.66), TableBuilder::f(22.0)]);
        t.row(vec!["FISTAPruner".into(), "33.54".into(), "28.89".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("FISTAPruner"));
        assert!(s.contains("27.66"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[1].len(), lines[3].len(), "rows must align");
    }

    #[test]
    #[should_panic]
    fn wrong_width_panics() {
        let mut t = TableBuilder::new("T", &["a", "b"]);
        t.row(vec!["x".into()]);
    }
}

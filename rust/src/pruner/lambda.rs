//! Algorithm 1: the adaptive-λ outer loop (paper §3.3 / §3.4).
//!
//! Each round runs the layer solver from the current best solution, rounds
//! to the exact target sparsity (eq. 8), and measures
//!   E_total = ‖W*_{K+1} X* − WX‖,  E_round = E_total − ‖W*_K X* − WX‖.
//! A high E_round/E_total means the solve under-sparsified (λ too small); a
//! low ratio means λ can be reduced to chase output error (paper §3.3). λ is
//! bisected on [0, λ_hi] against the threshold ξ. We bisect in *log space*
//! (geometric midpoint, floor 1e-8): the paper specifies "the bisection
//! method on [0, 10⁶]" with λ₀ = 10⁻⁵, which is only consistent if the
//! bisection is logarithmic — an arithmetic midpoint would jump to 5·10⁵
//! on the first round and never revisit small λ. Documented deviation.
//!
//! The loop is solver-agnostic (the *algorithm* axis, `LayerSolver`): any
//! solver whose effective sparsity grows monotonically with λ plugs in —
//! FISTA and ADMM through the ℓ₁ penalty directly, Frank-Wolfe through its
//! shrinking ℓ₁-ball radius τ(λ). With `FistaSolver` the λ/iterate sequence
//! is bitwise identical to the pre-refactor loop (pinned by
//! rust/tests/solver_parity.rs).
//!
//! Termination: `patience` (= paper T) consecutive non-improving rounds,
//! or improvement ratio (E_best − E_total)/E_best < ε (paper §3.4).

use anyhow::Result;

use crate::config::Sparsity;
use crate::tensor::Tensor;

use super::engine::SolverEngine;
use super::objective::ErrorModel;
use super::report::RoundStat;
use super::rounding::round_to_sparsity;
use super::solver::LayerSolver;

/// Tuner configuration (paper symbols in comments).
#[derive(Clone, Debug)]
pub struct TuneCfg {
    /// λ₀ (paper §4.1: 1e-5).
    pub lambda_init: f64,
    /// Upper end of the bisection interval (paper: 1e6).
    pub lambda_hi: f64,
    /// ξ — threshold on E_round/E_total (paper: 0.3).
    pub xi: f64,
    /// T — consecutive non-improving rounds before stopping (paper: 3).
    pub patience: usize,
    /// ε — improvement-ratio stop (paper: 1e-6 OPT / 1e-3 LLaMA).
    pub eps: f64,
    /// Hard cap on tuning rounds (not in the paper; guards runtime).
    pub max_rounds: usize,
}

impl TuneCfg {
    pub fn from_presets(p: &crate::config::Presets, family: crate::config::FamilyKind) -> TuneCfg {
        TuneCfg {
            lambda_init: p.prune.lambda_init,
            lambda_hi: p.prune.lambda_hi,
            xi: p.prune.xi,
            patience: p.prune.patience,
            eps: p.eps_for(family),
            max_rounds: p.prune.max_rounds,
        }
    }
}

/// Outcome of Algorithm 1 for one operator.
#[derive(Clone, Debug)]
pub struct TuneResult {
    /// W*_best — satisfies the target sparsity exactly.
    pub w: Tensor,
    /// E_best = ‖W*_best X* − W X‖_F.
    pub e_total: f64,
    /// Final λ.
    pub lambda: f64,
    /// Tuning rounds executed.
    pub rounds: usize,
    /// Total inner solver iterations across rounds (perf accounting).
    pub iters: usize,
    /// Per-round convergence telemetry, in execution order (one entry
    /// per round; flows up into `OpReport::rounds_detail`).
    pub history: Vec<RoundStat>,
}

const LAMBDA_FLOOR: f64 = 1e-8;

/// Algorithm 1 (paper, verbatim structure): returns the best rounded W*.
pub fn tune_lambda(
    engine: &dyn SolverEngine,
    solver: &dyn LayerSolver,
    em: &ErrorModel,
    w0: &Tensor,
    sparsity: Sparsity,
    cfg: &TuneCfg,
) -> Result<TuneResult> {
    // W*_best ← round(W*_0); E_best ← ‖W*_best X* − WX‖.
    // (The warm start comes from a baseline pruner and is already sparse;
    // rounding is then a no-op, but guarantees the invariant regardless.)
    let mut w_best = round_to_sparsity(w0, sparsity);
    let mut e_best = em.error(engine, &w_best)?;

    let mut lam = cfg.lambda_init;
    let mut lo = 0.0f64;
    let mut hi = cfg.lambda_hi;
    let mut t = 0usize; // consecutive non-improving rounds
    let mut rounds = 0usize;
    let mut total_iters = 0usize;
    let mut final_lambda = lam;
    let mut history = Vec::new();

    while rounds < cfg.max_rounds {
        rounds += 1;
        // W*_K ← Solver(WX, X*, λ, W*_best, K)
        let run = solver.solve(engine, &em.a, &em.b, &w_best, lam, em.l)?;
        let w_k = run.w;
        total_iters += run.iters;
        // W*_{K+1} ← round(W*_K)
        let w_k1 = round_to_sparsity(&w_k, sparsity);
        let e_total = em.error(engine, &w_k1)?;
        let e_solver = em.error(engine, &w_k)?;
        let e_round = (e_total - e_solver).max(0.0);
        history.push(RoundStat {
            round: rounds,
            lambda: lam,
            objective: e_total,
            residual: crate::tensor::ops::frob_dist(&w_k, &w_k1),
            support: w_k1.data().iter().filter(|&&x| x != 0.0).count(),
            iters: run.iters,
            e_round,
            primal: run.primal,
            dual: run.dual,
            gap: run.gap,
        });

        let mut e_stop = f64::INFINITY;
        if e_total < e_best {
            e_stop = (e_best - e_total) / e_best.max(1e-30);
            w_best = w_k1;
            e_best = e_total;
            t = 0;
        } else {
            t += 1;
        }
        final_lambda = lam;

        // Bisection update on the E_round/E_total ratio (paper §3.3).
        let ratio = if e_total > 0.0 { (e_round / e_total).clamp(0.0, 1.0) } else { 0.0 };
        if ratio > cfg.xi {
            lo = lam; // under-sparsified → increase λ
        } else {
            hi = lam; // sparse enough → chase output error with smaller λ
        }
        lam = (lo.max(LAMBDA_FLOOR) * hi.max(LAMBDA_FLOOR)).sqrt();

        if t >= cfg.patience || e_stop < cfg.eps {
            break;
        }
    }

    Ok(TuneResult {
        w: w_best,
        e_total: e_best,
        lambda: final_lambda,
        rounds,
        iters: total_iters,
        history,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruner::engine::NativeEngine;
    use crate::pruner::rounding::satisfies_sparsity;
    use crate::pruner::solver::FistaSolver;
    use crate::tensor::ops;
    use crate::util::Pcg64;

    fn fixture(seed: u64, m: usize, n: usize, p: usize) -> (NativeEngine, ErrorModel, Tensor) {
        let mut rng = Pcg64::seeded(seed);
        let w = Tensor::from_vec(vec![m, n], rng.normal_vec(m * n, 1.0));
        let x = Tensor::from_vec(vec![n, p], rng.normal_vec(n * p, 0.6));
        let engine = NativeEngine::default();
        let em = ErrorModel::build(&engine, &w, &x, &x).unwrap();
        (engine, em, w)
    }

    fn cfg() -> TuneCfg {
        TuneCfg { lambda_init: 1e-5, lambda_hi: 1e6, xi: 0.3, patience: 3, eps: 1e-6, max_rounds: 10 }
    }

    #[test]
    fn output_satisfies_sparsity_and_beats_magnitude_warm_start() {
        let (engine, em, w) = fixture(1, 16, 32, 128);
        let sp = Sparsity::Unstructured(0.5);
        let warm = round_to_sparsity(&w, sp); // magnitude pruning as warm start
        let e_warm = em.error(&engine, &warm).unwrap();
        let res = tune_lambda(&engine, &FistaSolver, &em, &warm, sp, &cfg()).unwrap();
        assert!(satisfies_sparsity(&res.w, sp));
        assert!(res.e_total <= e_warm + 1e-9, "tuner must never regress: {} vs {e_warm}", res.e_total);
        assert!(res.e_total < e_warm * 0.999, "tuner should improve on magnitude warm start");
        assert!(res.rounds >= 1);
    }

    #[test]
    fn semi_structured_pattern_holds() {
        let (engine, em, w) = fixture(2, 8, 32, 96);
        let sp = Sparsity::Semi(2, 4);
        let warm = round_to_sparsity(&w, sp);
        let res = tune_lambda(&engine, &FistaSolver, &em, &warm, sp, &cfg()).unwrap();
        assert!(satisfies_sparsity(&res.w, sp));
        assert!(res.e_total <= em.error(&engine, &warm).unwrap() + 1e-9);
    }

    #[test]
    fn respects_max_rounds() {
        let (engine, em, w) = fixture(3, 8, 16, 64);
        let sp = Sparsity::Unstructured(0.5);
        let mut c = cfg();
        c.max_rounds = 2;
        c.patience = 100;
        c.eps = 0.0;
        let res =
            tune_lambda(&engine, &FistaSolver, &em, &round_to_sparsity(&w, sp), sp, &c).unwrap();
        assert_eq!(res.rounds, 2);
    }

    #[test]
    fn zero_sparsity_returns_near_dense() {
        let (engine, em, w) = fixture(4, 8, 16, 64);
        let sp = Sparsity::Unstructured(0.0);
        let res = tune_lambda(&engine, &FistaSolver, &em, &w, sp, &cfg()).unwrap();
        // with no sparsity requirement the best solution tracks the dense W
        let rel = ops::frob_dist(&res.w, &w) / w.frob_norm();
        assert!(rel < 0.2, "rel {rel}");
    }

    #[test]
    fn round_history_carries_solver_telemetry() {
        let (engine, em, w) = fixture(5, 8, 16, 64);
        let sp = Sparsity::Unstructured(0.5);
        let warm = round_to_sparsity(&w, sp);
        let res = tune_lambda(&engine, &FistaSolver, &em, &warm, sp, &cfg()).unwrap();
        assert_eq!(res.history.len(), res.rounds);
        assert_eq!(res.iters, res.history.iter().map(|h| h.iters).sum::<usize>());
        for h in &res.history {
            assert!(h.primal.is_finite() && h.dual.is_finite());
            assert!(h.gap >= 0.0 && h.e_round >= 0.0);
        }
    }

    #[test]
    fn error_reduction_property() {
        crate::testing::check("tuner never regresses vs warm start", 8, |g| {
            let m = 4 * g.int(1, 4);
            let n = 8 * g.int(1, 4);
            let p = 64;
            let mut rng = Pcg64::seeded(g.rng.next_u64());
            let w = Tensor::from_vec(vec![m, n], rng.normal_vec(m * n, 1.0));
            let x = Tensor::from_vec(vec![n, p], rng.normal_vec(n * p, 0.6));
            let engine = NativeEngine::default();
            let em = ErrorModel::build(&engine, &w, &x, &x).unwrap();
            let sp = Sparsity::Unstructured(g.f32_in(0.2, 0.7) as f64);
            let warm = round_to_sparsity(&w, sp);
            let e_warm = em.error(&engine, &warm).unwrap();
            let res = tune_lambda(&engine, &FistaSolver, &em, &warm, sp, &cfg()).unwrap();
            if !satisfies_sparsity(&res.w, sp) {
                return Err("sparsity violated".into());
            }
            if res.e_total > e_warm + 1e-6 {
                return Err(format!("regressed: {} vs {e_warm}", res.e_total));
            }
            Ok(())
        });
    }
}

//! Native FISTA iterations on the Gram form — the rust mirror of
//! python/compile/kernels/ref.py::fista_solve_ref (paper eqs. 5a–5d,
//! stopping criterion eq. 7).
//!
//! Production runs use the `fista_{m}x{n}` artifact (Pallas kernel inside
//! an XLA while-loop); this implementation is the cross-language oracle
//! and the `Engine::Native` fallback.

use crate::tensor::{kernels, Tensor};

/// Elementwise SoftShrinkage_ρ (paper's proximal operator).
pub fn soft_shrink(w: &Tensor, rho: f32) -> Tensor {
    Tensor::from_vec(
        w.shape().to_vec(),
        w.data()
            .iter()
            .map(|&x| {
                if x > rho {
                    x - rho
                } else if x < -rho {
                    x + rho
                } else {
                    0.0
                }
            })
            .collect(),
    )
}

/// Run up to `iters` FISTA iterations minimizing
/// ½·tr(W A Wᵀ) − ⟨W, B⟩ + λ Σᵢ ‖W_{i,:}‖₁  (the Gram form of paper eq. 4).
///
/// Returns (W_K = last proximal point, iterations actually run).
///
/// The whole 5a–5d update is two fused kernel passes per iteration — one
/// gradient GEMM into a reused buffer (`kernels::matmul_sub_into`) and one
/// elementwise sweep (`kernels::fista_step`) that performs the gradient
/// step, the SoftShrinkage prox, the Nesterov combination and the eq. (7)
/// stopping norm in a single pass over the data. No per-iteration tensor
/// allocations (only `fista_step`'s m-element reduction partials), and
/// results are identical for any kernel thread count.
pub fn fista_solve(
    a: &Tensor,
    b: &Tensor,
    w0: &Tensor,
    lam: f64,
    l_max: f64,
    iters: usize,
    tol: f64,
) -> (Tensor, usize) {
    let inv_l = (1.0 / l_max) as f32;
    let thresh = (lam / l_max) as f32;
    let mut w_k = w0.clone();
    let mut w23 = w0.clone();
    let mut grad = Tensor::zeros(w0.shape().to_vec());
    let mut t = 1.0f64;
    let mut k = 0;
    while k < iters {
        let t_next = 0.5 * (1.0 + (1.0 + 4.0 * t * t).sqrt());
        let coef = ((t - 1.0) / t_next) as f32;
        // (5a) gradient at the extrapolated point: grad = W_k·A − B
        kernels::matmul_sub_into(&mut grad, &w_k, a, b);
        // (5a cont.), (5b), (5d) and the eq. (7) norm in one fused sweep;
        // w23 receives the prox point, w_k the next Nesterov iterate.
        let diff2 = kernels::fista_step(&grad, &mut w_k, &mut w23, inv_l, thresh, coef);
        t = t_next;
        k += 1;
        if diff2.sqrt() < tol {
            break;
        }
    }
    (w23, k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{matmul, matmul_nt, quad_obj};
    use crate::util::Pcg64;

    fn setup(seed: u64, m: usize, n: usize, p: usize) -> (Tensor, Tensor, Tensor, f64) {
        let mut rng = Pcg64::seeded(seed);
        let w_dense = Tensor::from_vec(vec![m, n], rng.normal_vec(m * n, 1.0));
        let x = Tensor::from_vec(vec![n, p], rng.normal_vec(n * p, 0.5));
        let a = matmul_nt(&x, &x);
        let b = matmul(&w_dense, &a); // X* = X case: B = W A
        let l = crate::linalg::power_iteration(&a, 64, 1.02);
        (w_dense, a, b, l)
    }

    #[test]
    fn lam_zero_recovers_dense_weights() {
        // With λ=0 and X*=X, the minimizer of ½‖WX − W₀X‖² is W₀.
        let (w_dense, a, b, l) = setup(1, 8, 16, 64);
        let w0 = Tensor::zeros(vec![8, 16]);
        let (w, _k) = fista_solve(&a, &b, &w0, 0.0, l, 400, 1e-9);
        let err = crate::tensor::ops::frob_dist(&w, &w_dense) / w_dense.frob_norm();
        assert!(err < 0.05, "relative err {err}");
    }

    #[test]
    fn objective_decreases() {
        let (_, a, b, l) = setup(2, 12, 24, 96);
        let w0 = Tensor::zeros(vec![12, 24]);
        let lam = 0.1;
        let obj = |w: &Tensor| {
            0.5 * quad_obj(&a, &b, w)
                + lam * w.data().iter().map(|&x| x.abs() as f64).sum::<f64>()
        };
        let (w5, _) = fista_solve(&a, &b, &w0, lam, l, 5, 0.0);
        let (w20, _) = fista_solve(&a, &b, &w0, lam, l, 20, 0.0);
        let (w80, _) = fista_solve(&a, &b, &w0, lam, l, 80, 0.0);
        assert!(obj(&w20) <= obj(&w5) + 1e-3);
        assert!(obj(&w80) <= obj(&w20) + 1e-3);
    }

    #[test]
    fn larger_lambda_gives_sparser_solutions() {
        let (_, a, b, l) = setup(3, 8, 16, 64);
        let w0 = Tensor::zeros(vec![8, 16]);
        let mut prev_nnz = usize::MAX;
        for lam in [0.01, 1.0, 100.0] {
            let (w, _) = fista_solve(&a, &b, &w0, lam, l, 100, 1e-9);
            let nnz = w.data().iter().filter(|&&x| x != 0.0).count();
            assert!(nnz <= prev_nnz, "λ={lam}: nnz {nnz} > previous {prev_nnz}");
            prev_nnz = nnz;
        }
        assert!(prev_nnz < 8 * 16, "large λ must produce zeros");
    }

    #[test]
    fn early_stop_on_tolerance() {
        let (_, a, b, l) = setup(4, 8, 16, 64);
        let w0 = Tensor::zeros(vec![8, 16]);
        let (_, k) = fista_solve(&a, &b, &w0, 0.0, l, 10_000, 1e-4);
        assert!(k < 10_000, "should stop early, ran {k}");
    }

    #[test]
    fn soft_shrink_cases() {
        let w = Tensor::from_vec(vec![5], vec![-2.0, -0.5, 0.0, 0.5, 2.0]);
        let s = soft_shrink(&w, 1.0);
        assert_eq!(s.data(), &[-1.0, 0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn matches_proximal_definition_property() {
        // prox point must satisfy the subgradient optimality of eq. (6):
        // |w23 - w13| <= thresh where w23 = 0, else w23 = w13 ∓ thresh.
        crate::testing::check("soft shrink optimality", 30, |g| {
            let n = g.int(1, 64);
            let x = Tensor::from_vec(vec![n], g.vec_normal(n, 2.0));
            let rho = g.f32_in(0.0, 1.5);
            let y = soft_shrink(&x, rho);
            for (&xi, &yi) in x.data().iter().zip(y.data()) {
                if yi == 0.0 {
                    if xi.abs() > rho + 1e-6 {
                        return Err(format!("zeroed |{xi}| > rho {rho}"));
                    }
                } else if (yi.abs() + rho - xi.abs()).abs() > 1e-5 || yi.signum() != xi.signum() {
                    return Err(format!("shrink wrong: {xi} -> {yi} (rho {rho})"));
                }
            }
            Ok(())
        });
    }
}

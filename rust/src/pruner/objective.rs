//! The Gram-form output-error model (DESIGN.md §3.1).
//!
//! Everything Algorithm 1 needs about an operator reduces to
//!   A = X*(X*)ᵀ, B = W·C with C = X(X*)ᵀ, c = ‖WX‖² = tr(W D Wᵀ),
//! so the error ‖W* X* − W X‖_F = sqrt(tr(W* A W*ᵀ) − 2⟨W*,B⟩ + c) is
//! computable for any candidate W* without touching the p-sized
//! activations again. This is what lets one compiled artifact set serve
//! every calibration size (and the paper's 40GB-for-70B memory story).

use anyhow::Result;

use crate::tensor::Tensor;

use super::engine::SolverEngine;

/// Per-operator error model: Gram matrices + the constant term.
pub struct ErrorModel {
    /// A = X*(X*)ᵀ — pruned-path input Gram.
    pub a: Tensor,
    /// B = W·C — the linear term of the objective.
    pub b: Tensor,
    /// c = ‖WX‖²_F — constant completing the squared error.
    pub c: f64,
    /// L = λ_max(A) — FISTA step-size constant.
    pub l: f64,
}

impl ErrorModel {
    /// Assemble from activations: `xd`/`xs` are [n, p] dense / pruned-path
    /// inputs (columns = calibration tokens), `w` the dense weight [m, n].
    pub fn build(engine: &dyn SolverEngine, w: &Tensor, xd: &Tensor, xs: &Tensor) -> Result<ErrorModel> {
        let (a, c_gram, d) = engine.gram(xd, xs)?;
        let (b, c_norm) = engine.prep(w, &c_gram, &d)?;
        let l = engine.power(&a)?;
        Ok(ErrorModel { a, b, c: c_norm, l })
    }

    /// ‖W* X* − W X‖²_F for a candidate (clamped at 0 against f32 noise).
    pub fn sq_error(&self, engine: &dyn SolverEngine, w: &Tensor) -> Result<f64> {
        let quad = engine.obj(&self.a, &self.b, w)?;
        Ok((quad + self.c).max(0.0))
    }

    /// ‖W* X* − W X‖_F.
    pub fn error(&self, engine: &dyn SolverEngine, w: &Tensor) -> Result<f64> {
        Ok(self.sq_error(engine, w)?.sqrt())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruner::engine::NativeEngine;
    use crate::tensor::ops;
    use crate::util::Pcg64;

    #[test]
    fn error_matches_direct_computation() {
        let mut rng = Pcg64::seeded(7);
        let (m, n, p) = (12, 16, 200);
        let w = Tensor::from_vec(vec![m, n], rng.normal_vec(m * n, 1.0));
        let xd = Tensor::from_vec(vec![n, p], rng.normal_vec(n * p, 0.7));
        // xs = xd + small perturbation (a "pruned path" input)
        let noise = Tensor::from_vec(vec![n, p], rng.normal_vec(n * p, 0.05));
        let xs = ops::add_scaled(&xd, &noise, 1.0);
        let engine = NativeEngine::default();
        let em = ErrorModel::build(&engine, &w, &xd, &xs).unwrap();

        let cand = Tensor::from_vec(vec![m, n], rng.normal_vec(m * n, 1.0));
        let direct = ops::frob_dist(&ops::matmul(&cand, &xs), &ops::matmul(&w, &xd));
        let via_gram = em.error(&engine, &cand).unwrap();
        assert!(
            (via_gram - direct).abs() < 2e-2 * direct,
            "gram {via_gram} vs direct {direct}"
        );
    }

    #[test]
    fn dense_weight_has_zero_error_when_paths_match() {
        let mut rng = Pcg64::seeded(8);
        let (m, n, p) = (8, 8, 100);
        let w = Tensor::from_vec(vec![m, n], rng.normal_vec(m * n, 1.0));
        let x = Tensor::from_vec(vec![n, p], rng.normal_vec(n * p, 1.0));
        let engine = NativeEngine::default();
        let em = ErrorModel::build(&engine, &w, &x, &x).unwrap();
        let e = em.error(&engine, &w).unwrap();
        let scale = ops::matmul(&w, &x).frob_norm();
        assert!(e < 1e-2 * scale, "error {e} vs scale {scale}");
    }

    #[test]
    fn l_bounds_gram_spectrum() {
        let mut rng = Pcg64::seeded(9);
        let x = Tensor::from_vec(vec![16, 100], rng.normal_vec(1600, 1.0));
        let w = Tensor::from_vec(vec![4, 16], rng.normal_vec(64, 1.0));
        let engine = NativeEngine::default();
        let em = ErrorModel::build(&engine, &w, &x, &x).unwrap();
        assert!(em.l > 0.0);
        // L ≥ max diagonal entry of A (a cheap lower bound on λ_max)
        let max_diag = (0..16).map(|i| em.a.at2(i, i)).fold(0.0f32, f32::max);
        assert!(em.l >= max_diag as f64 * 0.99);
    }
}

//! Full-model pruning: decoder layers as independent pruning units
//! (paper §3.4), scheduled sequentially (pruned activations propagate
//! between layers, the paper's evaluation pipeline) or in parallel across
//! the PJRT worker pool (the paper's multi-device pruning claim — each
//! unit then consumes the dense layer input).

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::baselines::BaselineKind;
use crate::config::{ModelSpec, Presets, PruneMode, PruneOptions};
use crate::model::embed::embed_windows;
use crate::model::params::ModelParams;
use crate::runtime::{ExecutorPool, Manifest, Session};
use crate::tensor::Tensor;

use super::report::PruneReport;
use super::unit::{prune_unit, UnitResult};

/// The pruning method a run executes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// No pruning (evaluation convenience).
    Dense,
    /// FISTAPruner (the paper's method, Algorithm 1).
    Fista,
    /// A baseline one-shot pruner.
    Baseline(BaselineKind),
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        match s {
            "dense" => Ok(Method::Dense),
            "fista" | "fistapruner" => Ok(Method::Fista),
            other => Ok(Method::Baseline(BaselineKind::parse(other)?)),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Dense => "dense",
            Method::Fista => "fista",
            Method::Baseline(k) => k.name(),
        }
    }
}

/// Prune a model on calibration windows (each ≥ seq tokens).
///
/// Returns the pruned parameters and a per-op report. `session` is used
/// for sequential mode; parallel mode spins up `opts.workers` pool workers
/// with their own sessions.
pub fn prune_model(
    session: &Session,
    presets: &Presets,
    spec: &ModelSpec,
    params: &ModelParams,
    calib_windows: &[Vec<i32>],
    method: Method,
    opts: &PruneOptions,
) -> Result<(ModelParams, PruneReport)> {
    let t0 = Instant::now();
    let mut out = params.clone();
    let (x0, valids) = embed_windows(spec, params, calib_windows, presets.capture_batch)?;

    let mut report = PruneReport {
        model: spec.name(),
        method: method.name().to_string(),
        sparsity_label: opts.sparsity.label(),
        ..Default::default()
    };

    if matches!(method, Method::Dense) {
        report.elapsed = t0.elapsed();
        return Ok((out, report));
    }

    match opts.mode {
        PruneMode::Sequential => {
            let mut xd = x0.clone();
            let mut xs = x0;
            for layer in 0..spec.layers {
                let layer_tensors: Vec<Tensor> =
                    out.layer_tensors(spec, layer).into_iter().cloned().collect();
                let res = prune_unit(
                    session, presets, spec, &method, opts, layer, &layer_tensors, &xd, &xs, &valids,
                )
                .with_context(|| format!("pruning layer {layer}"))?;
                apply_unit(&mut out, layer, &res)?;
                crate::log_debug!("layer {layer}: {} ops pruned", res.pruned.len());
                xd = res.y_dense;
                xs = res.y_pruned;
                report.layers.push(res.report);
            }
        }
        PruneMode::Parallel => {
            // Pass 1 (cheap): dense layer inputs for every layer.
            let mut inputs: Vec<Vec<Tensor>> = Vec::with_capacity(spec.layers);
            let mut cur = x0;
            for layer in 0..spec.layers {
                inputs.push(cur.clone());
                let layer_tensors: Vec<Tensor> =
                    out.layer_tensors(spec, layer).into_iter().cloned().collect();
                let res = prune_unit(
                    session,
                    presets,
                    spec,
                    &Method::Dense,
                    opts,
                    layer,
                    &layer_tensors,
                    &cur,
                    &cur,
                    &valids,
                )?;
                cur = res.y_dense;
            }
            // Pass 2: independent units over the worker pool.
            let manifest = Arc::new(Manifest::load(&session.manifest().dir)?);
            let pool = ExecutorPool::new(manifest, opts.workers.max(1))?;
            let presets_arc = Arc::new(presets.clone());
            let spec_arc = Arc::new(spec.clone());
            let opts_arc = Arc::new(opts.clone());
            let (tx, rx) = std::sync::mpsc::channel::<(usize, Result<UnitResult>)>();
            for layer in 0..spec.layers {
                let layer_tensors: Vec<Tensor> =
                    out.layer_tensors(spec, layer).into_iter().cloned().collect();
                let xin = inputs[layer].clone();
                let valids = valids.clone();
                let (p, s, o) = (presets_arc.clone(), spec_arc.clone(), opts_arc.clone());
                let tx = tx.clone();
                pool.submit(move |session| {
                    let res = prune_unit(
                        session, &p, &s, &method, &o, layer, &layer_tensors, &xin, &xin, &valids,
                    );
                    let _ = tx.send((layer, res));
                });
            }
            drop(tx);
            let mut results: Vec<(usize, UnitResult)> = Vec::with_capacity(spec.layers);
            for (layer, res) in rx.iter() {
                results.push((layer, res.with_context(|| format!("pruning layer {layer}"))?));
            }
            results.sort_by_key(|(l, _)| *l);
            for (layer, res) in results {
                apply_unit(&mut out, layer, &res)?;
                report.layers.push(res.report);
            }
        }
    }

    // Post-condition: every pruned operator satisfies the target pattern.
    for layer in 0..spec.layers {
        for op in crate::model::ops::pruned_ops(spec) {
            let w = out.req(&format!("l{layer}.{}", op.name))?;
            debug_assert!(
                super::rounding::satisfies_sparsity(w, opts.sparsity),
                "sparsity violated at l{layer}.{}",
                op.name
            );
        }
    }

    report.elapsed = t0.elapsed();
    Ok((out, report))
}

fn apply_unit(params: &mut ModelParams, layer: usize, res: &UnitResult) -> Result<()> {
    for (name, w) in &res.pruned {
        params.set(&format!("l{layer}.{name}"), w.clone())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse() {
        assert_eq!(Method::parse("fista").unwrap(), Method::Fista);
        assert_eq!(Method::parse("dense").unwrap(), Method::Dense);
        assert_eq!(Method::parse("wanda").unwrap(), Method::Baseline(BaselineKind::Wanda));
        assert!(Method::parse("nope").is_err());
    }
}

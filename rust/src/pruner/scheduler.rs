//! Full-model pruning: decoder layers as independent pruning units
//! (paper §3.4), scheduled sequentially (pruned activations propagate
//! between layers, the paper's evaluation pipeline) or in parallel across
//! a worker fleet (the paper's multi-device pruning claim — each unit
//! then consumes the dense layer input).
//!
//! Parallel mode has two backends sharing one shape:
//! * `Engine::Xla` — the PJRT `ExecutorPool` (one session per worker
//!   thread, jobs over a shared queue).
//! * `Engine::Native` — scoped worker threads over the same layer queue,
//!   no session required; inner kernels run inline per worker (the
//!   `tensor::par` nesting guard), so results are identical for any
//!   worker count.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::baselines::BaselineKind;
use crate::config::{Engine, ModelSpec, Presets, PruneMode, PruneOptions, SolverKind};
use crate::model::embed::embed_windows;
use crate::model::params::ModelParams;
use crate::runtime::{ExecutorPool, Manifest, Session};
use crate::tensor::{par, Tensor};

use super::report::PruneReport;
use super::unit::{prune_unit, UnitResult};

/// The pruning method a run executes. The algorithm axis is explicit:
/// `Solver(kind)` runs Algorithm 1 with the named `LayerSolver` (FISTA is
/// the paper's choice; ADMM and Frank-Wolfe are drop-in comparators).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// No pruning (evaluation convenience).
    Dense,
    /// Algorithm 1 with the given layer solver (`--solver` on the CLI).
    Solver(SolverKind),
    /// A baseline one-shot pruner.
    Baseline(BaselineKind),
}

impl Method {
    /// The paper's default: Algorithm 1 driven by FISTA.
    pub fn fista() -> Method {
        Method::Solver(SolverKind::Fista)
    }

    pub fn parse(s: &str) -> Result<Method> {
        // Every accepted spelling is listed here explicitly — no
        // fall-through to the baseline parser, so a typo ("fistta") gets
        // one error naming every valid method instead of a confusing
        // baseline-specific message.
        match s {
            "dense" => Ok(Method::Dense),
            "fista" | "fistapruner" => Ok(Method::Solver(SolverKind::Fista)),
            "admm" => Ok(Method::Solver(SolverKind::Admm)),
            "fw" | "frankwolfe" | "frank-wolfe" => Ok(Method::Solver(SolverKind::FrankWolfe)),
            "magnitude" => Ok(Method::Baseline(BaselineKind::Magnitude)),
            "wanda" => Ok(Method::Baseline(BaselineKind::Wanda)),
            "sparsegpt" => Ok(Method::Baseline(BaselineKind::SparseGpt)),
            other => bail!(
                "unknown method '{other}' (methods: dense, fista, admm, fw, magnitude, \
                 wanda, sparsegpt; solvers for --solver: fista, admm, fw)"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Dense => "dense",
            Method::Solver(k) => k.name(),
            Method::Baseline(k) => k.name(),
        }
    }
}

/// Prune a model on calibration windows (each ≥ seq tokens).
///
/// Returns the pruned parameters and a per-op report. `session` backs the
/// XLA engine and capture artifacts; pass `None` to run fully natively
/// (requires `opts.engine == Engine::Native`). `opts.threads` configures
/// the native kernel fan-out, `opts.workers` the layer/op-level overlap.
pub fn prune_model(
    session: Option<&Session>,
    presets: &Presets,
    spec: &ModelSpec,
    params: &ModelParams,
    calib_windows: &[Vec<i32>],
    method: Method,
    opts: &PruneOptions,
) -> Result<(ModelParams, PruneReport)> {
    #[allow(clippy::disallowed_methods)]
    // fp-lint: allow(clock) — offline prune wall-time report, never served
    let t0 = Instant::now();
    // Explicit run option beats the presets default; 0 leaves the current
    // global setting (auto unless FP_THREADS / a previous run set it).
    let threads = if opts.threads != 0 { opts.threads } else { presets.fista.threads };
    if threads != 0 {
        par::set_threads(threads);
    }
    if matches!(opts.engine, Engine::Xla) && session.is_none() {
        bail!("Engine::Xla needs a PJRT session; pass one or use Engine::Native");
    }
    let mut out = params.clone();
    let (x0, valids) = embed_windows(spec, params, calib_windows, presets.capture_batch)?;

    let mut report = PruneReport {
        model: spec.name(),
        method: method.name().to_string(),
        sparsity_label: opts.sparsity.label(),
        ..Default::default()
    };

    if matches!(method, Method::Dense) {
        report.elapsed = t0.elapsed();
        return Ok((out, report));
    }

    match opts.mode {
        PruneMode::Sequential => {
            let mut xd = x0.clone();
            let mut xs = x0;
            for layer in 0..spec.layers {
                let layer_tensors: Vec<Tensor> =
                    out.layer_tensors(spec, layer).into_iter().cloned().collect();
                let res = prune_unit(
                    session, presets, spec, &method, opts, layer, &layer_tensors, &xd, &xs,
                    &valids,
                )
                .with_context(|| format!("pruning layer {layer}"))?;
                apply_unit(&mut out, layer, &res)?;
                crate::log_debug!("layer {layer}: {} ops pruned", res.pruned.len());
                xd = res.y_dense;
                xs = res.y_pruned;
                report.layers.push(res.report);
            }
        }
        PruneMode::Parallel => {
            // Pass 1 (cheap): dense layer inputs for every layer. The unit
            // recognizes xd ≡ xs and performs a single capture per layer.
            let mut inputs: Vec<Vec<Tensor>> = Vec::with_capacity(spec.layers);
            let mut cur = x0;
            for layer in 0..spec.layers {
                inputs.push(cur.clone());
                let layer_tensors: Vec<Tensor> =
                    out.layer_tensors(spec, layer).into_iter().cloned().collect();
                let res = prune_unit(
                    session,
                    presets,
                    spec,
                    &Method::Dense,
                    opts,
                    layer,
                    &layer_tensors,
                    &cur,
                    &cur,
                    &valids,
                )?;
                cur = res.y_dense;
            }
            // Pass 2: independent units over a worker fleet.
            let layer_tensor_sets: Vec<Vec<Tensor>> = (0..spec.layers)
                .map(|layer| out.layer_tensors(spec, layer).into_iter().cloned().collect())
                .collect();
            let results = match opts.engine {
                Engine::Xla => run_units_pjrt(
                    session.expect("checked above"),
                    presets,
                    spec,
                    &method,
                    opts,
                    layer_tensor_sets,
                    inputs,
                    &valids,
                )?,
                Engine::Native => run_units_native(
                    presets,
                    spec,
                    &method,
                    opts,
                    &layer_tensor_sets,
                    &inputs,
                    &valids,
                )?,
            };
            for (layer, res) in results {
                apply_unit(&mut out, layer, &res)?;
                report.layers.push(res.report);
            }
        }
    }

    // Post-condition: every pruned operator satisfies the target pattern.
    for layer in 0..spec.layers {
        for op in crate::model::ops::pruned_ops(spec) {
            let w = out.req(&format!("l{layer}.{}", op.name))?;
            debug_assert!(
                super::rounding::satisfies_sparsity(w, opts.sparsity),
                "sparsity violated at l{layer}.{}",
                op.name
            );
        }
    }

    report.elapsed = t0.elapsed();
    Ok((out, report))
}

/// Parallel units over the PJRT worker pool (each worker owns a session).
#[allow(clippy::too_many_arguments)]
fn run_units_pjrt(
    session: &Session,
    presets: &Presets,
    spec: &ModelSpec,
    method: &Method,
    opts: &PruneOptions,
    layer_tensor_sets: Vec<Vec<Tensor>>,
    inputs: Vec<Vec<Tensor>>,
    valids: &[usize],
) -> Result<Vec<(usize, UnitResult)>> {
    let manifest = Arc::new(Manifest::load(&session.manifest().dir)?);
    let pool = ExecutorPool::new(manifest, opts.workers.max(1))?;
    let presets_arc = Arc::new(presets.clone());
    let spec_arc = Arc::new(spec.clone());
    let opts_arc = Arc::new(opts.clone());
    let method = *method;
    let (tx, rx) = std::sync::mpsc::channel::<(usize, Result<UnitResult>)>();
    for (layer, (layer_tensors, xin)) in
        layer_tensor_sets.into_iter().zip(inputs.into_iter()).enumerate()
    {
        let valids = valids.to_vec();
        let (p, s, o) = (presets_arc.clone(), spec_arc.clone(), opts_arc.clone());
        let tx = tx.clone();
        pool.submit(move |session| {
            let res = prune_unit(
                Some(session), &p, &s, &method, &o, layer, &layer_tensors, &xin, &xin, &valids,
            );
            let _ = tx.send((layer, res));
        });
    }
    drop(tx);
    let mut results: Vec<(usize, UnitResult)> = Vec::with_capacity(spec.layers);
    for (layer, res) in rx.iter() {
        results.push((layer, res.with_context(|| format!("pruning layer {layer}"))?));
    }
    results.sort_by_key(|(l, _)| *l);
    Ok(results)
}

/// Parallel units over native scoped workers: a shared atomic layer queue,
/// `opts.workers` threads, no sessions. Kernels inside each worker run
/// inline (nesting guard), except with a single worker, which keeps the
/// full kernel fan-out.
fn run_units_native(
    presets: &Presets,
    spec: &ModelSpec,
    method: &Method,
    opts: &PruneOptions,
    layer_tensor_sets: &[Vec<Tensor>],
    inputs: &[Vec<Tensor>],
    valids: &[usize],
) -> Result<Vec<(usize, UnitResult)>> {
    let layers = spec.layers;
    let n_workers = opts.workers.max(1).min(layers.max(1));
    if n_workers <= 1 {
        let mut results = Vec::with_capacity(layers);
        for layer in 0..layers {
            let res = prune_unit(
                None,
                presets,
                spec,
                method,
                opts,
                layer,
                &layer_tensor_sets[layer],
                &inputs[layer],
                &inputs[layer],
                valids,
            )
            .with_context(|| format!("pruning layer {layer}"))?;
            results.push((layer, res));
        }
        return Ok(results);
    }
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<(usize, Result<UnitResult>)>> = Mutex::new(Vec::with_capacity(layers));
    std::thread::scope(|s| {
        for _ in 0..n_workers {
            // fp-lint: allow(det-spawn) — scoped layer workers; results re-sorted by index
            s.spawn(|| {
                par::enter_worker(|| loop {
                    let layer = next.fetch_add(1, Ordering::Relaxed);
                    if layer >= layers {
                        break;
                    }
                    let res = prune_unit(
                        None,
                        presets,
                        spec,
                        method,
                        opts,
                        layer,
                        &layer_tensor_sets[layer],
                        &inputs[layer],
                        &inputs[layer],
                        valids,
                    );
                    results.lock().expect("results poisoned").push((layer, res));
                })
            });
        }
    });
    let mut collected: Vec<(usize, UnitResult)> = Vec::with_capacity(layers);
    for (layer, res) in results.into_inner().expect("results poisoned") {
        collected.push((layer, res.with_context(|| format!("pruning layer {layer}"))?));
    }
    collected.sort_by_key(|(l, _)| *l);
    Ok(collected)
}

fn apply_unit(params: &mut ModelParams, layer: usize, res: &UnitResult) -> Result<()> {
    for (name, w) in &res.pruned {
        params.set(&format!("l{layer}.{name}"), w.clone())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse() {
        assert_eq!(Method::parse("fista").unwrap(), Method::fista());
        assert_eq!(Method::parse("admm").unwrap(), Method::Solver(SolverKind::Admm));
        assert_eq!(Method::parse("fw").unwrap(), Method::Solver(SolverKind::FrankWolfe));
        assert_eq!(Method::parse("dense").unwrap(), Method::Dense);
        assert_eq!(Method::parse("wanda").unwrap(), Method::Baseline(BaselineKind::Wanda));
        assert!(Method::parse("nope").is_err());
        // typos get the full method list, not a baseline-specific error
        let err = Method::parse("fistta").unwrap_err().to_string();
        assert!(err.contains("magnitude") && err.contains("sparsegpt") && err.contains("admm"),
            "error should list every valid method: {err}");
    }
}

//! The *algorithm* axis of the pruner: a `LayerSolver` turns one
//! (A, B, warm start, λ) Gram-form problem into a sparse-ish iterate.
//!
//! This is orthogonal to the *execution* axis (`SolverEngine`: Native vs
//! XLA): Algorithm 1 (`lambda::tune_lambda`) drives any `LayerSolver`
//! through the same λ bisection / rounding / error-correction loop, and a
//! solver may delegate its hot loop to the engine (FISTA does) or run on
//! the native kernels directly (ADMM, Frank-Wolfe).
//!
//! All three solvers minimize the same objective
//!     f(W) = ½·tr(W A Wᵀ) − ⟨W, B⟩ + λ‖W‖₁
//! (Frank-Wolfe in its constrained form: min f₀ over ‖W‖₁ ≤ τ(λ), with
//! τ shrinking as λ grows so Algorithm 1's bisection applies unchanged).
//! Per-solve telemetry is normalized into [`SolverRun`]; the convergence
//! semantics of `dual`/`gap` are per-solver and documented on each
//! implementation (see also docs/ARCHITECTURE.md).
//!
//! Determinism contract: every solver is bitwise thread-count invariant —
//! they only compose kernels from `tensor::{kernels, ops, par}` that
//! follow the row-block determinism rules.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::config::{AdmmCfg, FwCfg, Presets, SolverKind};
use crate::tensor::{kernels, ops, Tensor};

use super::admm::admm_solve_full;
use super::engine::SolverEngine;
use super::fista::soft_shrink;

/// One solver invocation's outcome (one tuning round of Algorithm 1).
pub struct SolverRun {
    /// The iterate handed to the rounding step (need not be exactly
    /// feasible for the target sparsity — Algorithm 1 rounds it).
    pub w: Tensor,
    /// Inner iterations actually run.
    pub iters: usize,
    /// Penalized primal objective ½tr(W A Wᵀ) − ⟨W,B⟩ + λ‖W‖₁ at `w`
    /// (reported in the same form for every solver, so the `trace` CLI
    /// and `ablation_solver` bench compare like with like).
    pub primal: f64,
    /// Solver-specific dual-side value; see each implementation.
    pub dual: f64,
    /// Solver-specific convergence gap (0 ⇒ converged); see each
    /// implementation.
    pub gap: f64,
}

/// A layer-wise solver for the Gram-form objective. Implementations must
/// be `Send + Sync` (one solver instance is shared across the pruning
/// unit's operator-overlap threads) and thread-count invariant.
pub trait LayerSolver: Send + Sync {
    /// Short label used in reports, traces, and CLI tables.
    fn name(&self) -> &'static str;

    /// Minimize ½tr(W A Wᵀ) − ⟨W,B⟩ + λ‖W‖₁ from warm start `w0`.
    /// `l` is L = λ_max(A) (the engine's power-iteration output).
    fn solve(
        &self,
        engine: &dyn SolverEngine,
        a: &Tensor,
        b: &Tensor,
        w0: &Tensor,
        lam: f64,
        l: f64,
    ) -> Result<SolverRun>;
}

/// Construct the solver for a [`SolverKind`] with its convergence presets.
pub fn build(kind: SolverKind, presets: &Presets) -> Box<dyn LayerSolver> {
    match kind {
        SolverKind::Fista => Box::new(FistaSolver),
        SolverKind::Admm => Box::new(AdmmSolver { cfg: presets.solvers.admm.clone() }),
        SolverKind::FrankWolfe => Box::new(FrankWolfeSolver { cfg: presets.solvers.fw.clone() }),
    }
}

fn l1_norm(w: &Tensor) -> f64 {
    w.data().iter().map(|&x| x.abs() as f64).sum()
}

fn primal_value(engine: &dyn SolverEngine, a: &Tensor, b: &Tensor, w: &Tensor, lam: f64) -> Result<f64> {
    // engine.obj = tr(W A Wᵀ) − 2⟨W,B⟩, so ½·obj = the quadratic part.
    Ok(0.5 * engine.obj(a, b, w)? + lam * l1_norm(w))
}

// ---------------------------------------------------------------------
// FISTA
// ---------------------------------------------------------------------

/// The paper's solver: delegates the fused proximal-gradient loop to the
/// execution engine (`engine.fista`), so `--solver fista` is exactly the
/// pre-refactor pipeline — the returned `w` is bitwise identical (pinned
/// by rust/tests/solver_parity.rs). Telemetry semantics: `gap` is the
/// prox fixed-point residual ‖W − prox_{λ/L}(W − ∇f(W)/L)‖_F (the eq. 7
/// criterion evaluated at the returned point; 0 at an exact minimizer)
/// and `dual` = primal − gap, a convergence surrogate rather than a true
/// dual value. Computing them touches only fresh buffers, never `w`.
pub struct FistaSolver;

impl LayerSolver for FistaSolver {
    fn name(&self) -> &'static str {
        "fista"
    }

    fn solve(
        &self,
        engine: &dyn SolverEngine,
        a: &Tensor,
        b: &Tensor,
        w0: &Tensor,
        lam: f64,
        l: f64,
    ) -> Result<SolverRun> {
        let (w, iters) = engine.fista(a, b, w0, lam, l)?;
        let primal = primal_value(engine, a, b, &w, lam)?;
        let gap = if l > 0.0 {
            let mut grad = Tensor::zeros(w.shape().to_vec());
            kernels::matmul_sub_into(&mut grad, &w, a, b);
            let step = ops::add_scaled(&w, &grad, -(1.0 / l) as f32);
            let prox = soft_shrink(&step, (lam / l) as f32);
            ops::frob_dist(&w, &prox)
        } else {
            0.0
        };
        Ok(SolverRun { w, iters, primal, dual: primal - gap, gap })
    }
}

// ---------------------------------------------------------------------
// ADMM
// ---------------------------------------------------------------------

/// ADMM splitting (see `pruner::admm`): ρ = `rho_factor`·L. Telemetry
/// semantics: `gap` is the primal residual ‖W − Z‖_F (feasibility of the
/// W = Z split) and `dual` the dual residual ρ‖Z_K − Z_{K−1}‖_F
/// (stationarity); both → 0 at convergence.
pub struct AdmmSolver {
    pub cfg: AdmmCfg,
}

impl LayerSolver for AdmmSolver {
    fn name(&self) -> &'static str {
        "admm"
    }

    fn solve(
        &self,
        engine: &dyn SolverEngine,
        a: &Tensor,
        b: &Tensor,
        w0: &Tensor,
        lam: f64,
        l: f64,
    ) -> Result<SolverRun> {
        let rho = (self.cfg.rho_factor * l).max(1e-12);
        let out = admm_solve_full(a, b, w0, lam, rho, self.cfg.max_iters, self.cfg.stop_tol)?;
        let primal = primal_value(engine, a, b, &out.w, lam)?;
        Ok(SolverRun {
            w: out.w,
            iters: out.iters,
            primal,
            dual: out.dual_res,
            gap: out.primal_res,
        })
    }
}

// ---------------------------------------------------------------------
// Frank-Wolfe
// ---------------------------------------------------------------------

/// Frank-Wolfe with away steps over the ℓ₁ ball (the "Don't Be Greedy,
/// Just Relax!" formulation of the same layer-wise objective).
///
/// The penalty λ‖W‖₁ is traded for the constraint ‖W‖₁ ≤ τ with
/// τ = ‖W₀‖₁ / (1 + λ): larger λ ⇒ smaller ball ⇒ sparser iterate, so
/// Algorithm 1's log-space λ bisection sweeps the radius unchanged.
///
/// Per iteration: the LMO over the ℓ₁ ball returns the vertex
/// s = −τ·sign(g_{i*})·e_{i*} at i* = argmax |g| (first index wins —
/// deterministic); the away atom is the active atom most aligned with the
/// gradient. Whichever direction has the larger projected decrease is
/// taken with an exact quadratic line search (the curvature tr(d A dᵀ)
/// collapses to scalar lookups because every atom is a single coordinate
/// or the warm-start matrix). Telemetry semantics: `gap` is the FW
/// duality gap ⟨∇f, W − s⟩ (an upper bound on f(W) − f(W*) over the
/// ball; stopping criterion) and `dual` = primal − gap (a lower bound on
/// the constrained optimum, shifted by the reported λ‖W‖₁ term).
pub struct FrankWolfeSolver {
    pub cfg: FwCfg,
}

const FW_INIT_ATOM: u64 = u64::MAX;

impl LayerSolver for FrankWolfeSolver {
    fn name(&self) -> &'static str {
        "fw"
    }

    fn solve(
        &self,
        engine: &dyn SolverEngine,
        a: &Tensor,
        b: &Tensor,
        w0: &Tensor,
        lam: f64,
        _l: f64,
    ) -> Result<SolverRun> {
        let (m, n) = (w0.rows(), w0.cols());
        if a.rows() != a.cols() || a.rows() != n {
            bail!("FW: A {:?} incompatible with W0 {:?}", a.shape(), w0.shape());
        }
        if b.shape() != w0.shape() {
            bail!("FW: B {:?} != W0 {:?}", b.shape(), w0.shape());
        }
        if !lam.is_finite() || lam < 0.0 {
            bail!("FW: lambda must be finite and >= 0, got {lam}");
        }
        let l1_w0 = l1_norm(w0);
        let tau = l1_w0 / (1.0 + lam);
        if !tau.is_finite() || tau <= 0.0 {
            // Degenerate ball (all-zero warm start or huge λ): the only
            // feasible point is 0.
            let w = Tensor::zeros(vec![m, n]);
            let primal = primal_value(engine, a, b, &w, lam)?;
            return Ok(SolverRun { w, iters: 0, primal, dual: primal, gap: 0.0 });
        }

        // Scale the warm start onto the ball boundary — the "init atom".
        let scale = (tau / l1_w0) as f32;
        let init_atom = Tensor::from_vec(
            vec![m, n],
            w0.data().iter().map(|&x| x * scale).collect(),
        );
        let mut w = init_atom.clone();
        // ⟨a₀, a₀·A⟩ and ⟨a₀, B⟩, fixed for the whole solve.
        let a0a = ops::matmul(&init_atom, a);
        let a0_a_a0 = ops::dot(&init_atom, &a0a);
        let a0_dot_b = ops::dot(&init_atom, b);

        // Active set: atom id → convex weight. Coordinate vertex ±τ·e_i
        // has id 2i (+) / 2i+1 (−); the init atom is FW_INIT_ATOM.
        let mut atoms: BTreeMap<u64, f64> = BTreeMap::new();
        atoms.insert(FW_INIT_ATOM, 1.0);

        let mut grad = Tensor::zeros(vec![m, n]);
        let mut iters = 0usize;
        let mut gap = 0.0f64;
        for _ in 0..self.cfg.max_iters {
            // ∇f₀(W) = W·A − B.
            kernels::matmul_sub_into(&mut grad, &w, a, b);
            let g = grad.data();

            // LMO: s = −τ·sign(g_{i*})·e_{i*}, i* = argmax |g| (first wins).
            let mut bi = 0usize;
            let mut bv = -1.0f32;
            for (i, &gi) in g.iter().enumerate() {
                let ag = gi.abs();
                if ag > bv {
                    bv = ag;
                    bi = i;
                }
            }
            let s_val: f64 = if g[bi] > 0.0 { -tau } else { tau };
            let gw = ops::dot(&grad, &w);
            gap = gw - s_val * g[bi] as f64;
            if gap <= self.cfg.gap_tol * gw.abs().max(1.0) {
                break;
            }
            iters += 1;

            // Away atom: the active atom most aligned with the gradient.
            let mut away_id = FW_INIT_ATOM;
            let mut away_score = f64::NEG_INFINITY;
            let mut init_dot_g = 0.0f64;
            for &id in atoms.keys() {
                let score = if id == FW_INIT_ATOM {
                    init_dot_g = ops::dot(&grad, &init_atom);
                    init_dot_g
                } else {
                    let idx = (id >> 1) as usize;
                    let val = if id & 1 == 1 { -tau } else { tau };
                    val * g[idx] as f64
                };
                if score > away_score {
                    away_score = score;
                    away_id = id;
                }
            }
            let away_gain = away_score - gw;

            // Shared curvature term ⟨W, W·A⟩ = ⟨W, ∇f₀ + B⟩.
            let w_dot_wa = gw + ops::dot(&w, b);

            let alpha = atoms[&away_id];
            // α ≥ 1 only through float drift with a single effective atom;
            // the away direction is then degenerate, so fall back to FW.
            let use_away = away_gain > gap && atoms.len() > 1 && alpha < 1.0 - 1e-9;
            if use_away {
                // d = W − a; curvature tr(d A dᵀ).
                let gamma_max = alpha / (1.0 - alpha);
                let curv = if away_id == FW_INIT_ATOM {
                    w_dot_wa - 2.0 * (init_dot_g + a0_dot_b) + a0_a_a0
                } else {
                    let idx = (away_id >> 1) as usize;
                    let val = if away_id & 1 == 1 { -tau } else { tau };
                    let c = idx % n;
                    let wa_rc = g[idx] as f64 + b.data()[idx] as f64;
                    w_dot_wa - 2.0 * val * wa_rc + val * val * a.at2(c, c) as f64
                };
                let gamma = if curv > 0.0 {
                    (away_gain / curv).clamp(0.0, gamma_max)
                } else {
                    gamma_max
                };
                if !gamma.is_finite() || gamma <= 0.0 {
                    break; // no progress possible in this direction
                }
                // W ← (1+γ)W − γ·a.
                let gf = gamma as f32;
                for x in w.data_mut() {
                    *x *= 1.0 + gf;
                }
                if away_id == FW_INIT_ATOM {
                    for (x, &a0) in w.data_mut().iter_mut().zip(init_atom.data()) {
                        *x -= gf * a0;
                    }
                } else {
                    let idx = (away_id >> 1) as usize;
                    let val = if away_id & 1 == 1 { -tau } else { tau };
                    w.data_mut()[idx] -= gf * val as f32;
                }
                let drop = gamma >= gamma_max * (1.0 - 1e-12);
                for (id, wt) in atoms.iter_mut() {
                    *wt *= 1.0 + gamma;
                    if *id == away_id {
                        *wt -= gamma;
                    }
                }
                if drop {
                    atoms.remove(&away_id);
                }
            } else {
                // d = s − W; curvature collapses onto the vertex entry.
                let c = bi % n;
                let wa_bi = g[bi] as f64 + b.data()[bi] as f64;
                let curv = s_val * s_val * a.at2(c, c) as f64 - 2.0 * s_val * wa_bi + w_dot_wa;
                let gamma = if curv > 0.0 { (gap / curv).clamp(0.0, 1.0) } else { 1.0 };
                if gamma <= 0.0 {
                    break;
                }
                // W ← (1−γ)W + γ·s.
                let gf = gamma as f32;
                for x in w.data_mut() {
                    *x *= 1.0 - gf;
                }
                w.data_mut()[bi] += gf * s_val as f32;
                let s_id = (bi as u64) << 1 | u64::from(s_val < 0.0);
                if gamma >= 1.0 {
                    atoms.clear();
                } else {
                    for wt in atoms.values_mut() {
                        *wt *= 1.0 - gamma;
                    }
                }
                *atoms.entry(s_id).or_insert(0.0) += gamma;
            }
            atoms.retain(|_, wt| *wt > 1e-12);
        }

        let primal = primal_value(engine, a, b, &w, lam)?;
        Ok(SolverRun { w, iters, primal, dual: primal - gap, gap })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruner::engine::NativeEngine;
    use crate::tensor::ops::{matmul, matmul_nt};
    use crate::util::Pcg64;

    fn setup(seed: u64, m: usize, n: usize, p: usize) -> (Tensor, Tensor, Tensor, f64) {
        let mut rng = Pcg64::seeded(seed);
        let w = Tensor::from_vec(vec![m, n], rng.normal_vec(m * n, 1.0));
        let x = Tensor::from_vec(vec![n, p], rng.normal_vec(n * p, 0.5));
        let a = matmul_nt(&x, &x);
        let b = matmul(&w, &a);
        let l = crate::linalg::power_iteration(&a, 64, 1.02);
        (w, a, b, l)
    }

    #[test]
    fn fista_solver_matches_engine_fista_bitwise() {
        let (w, a, b, l) = setup(1, 8, 16, 64);
        let engine = NativeEngine::default();
        let (direct, k_direct) = engine.fista(&a, &b, &w, 0.05, l).unwrap();
        let run = FistaSolver.solve(&engine, &a, &b, &w, 0.05, l).unwrap();
        assert_eq!(run.iters, k_direct);
        assert_eq!(run.w.data(), direct.data(), "FistaSolver must not perturb the iterate");
        assert!(run.primal.is_finite() && run.gap >= 0.0);
    }

    #[test]
    fn all_solvers_return_finite_telemetry() {
        let (w, a, b, l) = setup(2, 8, 16, 64);
        let engine = NativeEngine::default();
        let presets = crate::config::Presets::load(&crate::config::repo_root().unwrap()).unwrap();
        for kind in [SolverKind::Fista, SolverKind::Admm, SolverKind::FrankWolfe] {
            let solver = build(kind, &presets);
            let run = solver.solve(&engine, &a, &b, &w, 0.1, l).unwrap();
            assert_eq!(run.w.shape(), w.shape());
            assert!(run.primal.is_finite(), "{}: primal", solver.name());
            assert!(run.dual.is_finite(), "{}: dual", solver.name());
            assert!(run.gap.is_finite() && run.gap >= 0.0, "{}: gap", solver.name());
        }
    }

    #[test]
    fn fw_larger_lambda_gives_smaller_ball() {
        let (w, a, b, _l) = setup(3, 8, 16, 64);
        let engine = NativeEngine::default();
        let solver = FrankWolfeSolver { cfg: FwCfg::default() };
        let mut prev_l1 = f64::INFINITY;
        for lam in [1e-4, 1.0, 1e3] {
            let run = solver.solve(&engine, &a, &b, &w, lam, 0.0).unwrap();
            let l1 = run.w.data().iter().map(|&x| x.abs() as f64).sum::<f64>();
            assert!(l1 <= prev_l1 + 1e-6, "λ={lam}: ‖W‖₁ {l1} > previous {prev_l1}");
            // iterates stay inside the τ(λ) ball (up to f32 accumulation)
            let tau = w.data().iter().map(|&x| x.abs() as f64).sum::<f64>() / (1.0 + lam);
            assert!(l1 <= tau * 1.001 + 1e-6, "λ={lam}: ‖W‖₁ {l1} outside ball τ={tau}");
            prev_l1 = l1;
        }
    }

    #[test]
    fn fw_zero_warm_start_returns_zeros() {
        let (_w, a, b, _l) = setup(4, 8, 16, 64);
        let engine = NativeEngine::default();
        let solver = FrankWolfeSolver { cfg: FwCfg::default() };
        let w0 = Tensor::zeros(vec![8, 16]);
        let run = solver.solve(&engine, &a, &b, &w0, 0.1, 0.0).unwrap();
        assert!(run.w.data().iter().all(|&x| x == 0.0));
        assert_eq!(run.iters, 0);
    }

    #[test]
    fn fw_reduces_objective_from_warm_start() {
        let (w, a, b, _l) = setup(5, 12, 24, 96);
        let engine = NativeEngine::default();
        let solver = FrankWolfeSolver { cfg: FwCfg { max_iters: 200, gap_tol: 1e-7 } };
        let lam = 0.01;
        // f₀ at the scaled warm start (the FW start point) vs at the end
        let l1_w0 = w.data().iter().map(|&x| x.abs() as f64).sum::<f64>();
        let scale = (l1_w0 / (1.0 + lam) / l1_w0) as f32;
        let start = Tensor::from_vec(
            w.shape().to_vec(),
            w.data().iter().map(|&x| x * scale).collect(),
        );
        let f0 = 0.5 * ops::quad_obj(&a, &b, &start);
        let run = solver.solve(&engine, &a, &b, &w, lam, 0.0).unwrap();
        let f1 = 0.5 * ops::quad_obj(&a, &b, &run.w);
        assert!(f1 <= f0 + 1e-6, "FW must not increase f₀: {f1} vs {f0}");
        assert!(run.iters > 0);
    }
}

//! FISTAPruner core (the paper's contribution):
//!
//! * `rounding`  — eq. (8): exact-sparsity rounding (s% unstructured, n:m).
//! * `engine`    — solver backends: XLA artifacts (production) and a
//!   native-rust reference; both expose FISTA / Gram / power / objective.
//! * `fista`     — native FISTA iterations (paper eqs. 5a–5d), the oracle
//!   the artifact path is tested against.
//! * `objective` — Gram-form output error ‖W X* − WX‖_F (DESIGN.md §3.1).
//! * `lambda`    — Algorithm 1: adaptive λ bisection on E_round/E_total.
//! * `unit`      — a decoder layer as a pruning unit: sequential operator
//!   pruning with intra-layer error correction (paper §3.1, Fig. 2).
//! * `scheduler` — full-model pruning; parallel decoder-layer dispatch
//!   over the PJRT worker pool (paper §3.4).
//! * `report`    — per-op/per-layer diagnostics for EXPERIMENTS.md.

pub mod admm;
pub mod engine;
pub mod fista;
pub mod lambda;
pub mod objective;
pub mod report;
pub mod rounding;
pub mod scheduler;
pub mod unit;

pub use engine::{NativeEngine, SolverEngine, XlaEngine};
pub use lambda::{tune_lambda, TuneCfg, TuneResult};
pub use report::{LayerReport, OpReport, PruneReport, RoundStat};
pub use rounding::{round_model_to_sparsity, round_to_sparsity, satisfies_sparsity};
pub use scheduler::{prune_model, Method};

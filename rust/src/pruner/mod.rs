//! FISTAPruner core (the paper's contribution):
//!
//! Two orthogonal axes (see docs/ARCHITECTURE.md "The two-axis solver
//! split"): the *algorithm* axis (`solver::LayerSolver` — FISTA, ADMM,
//! Frank-Wolfe) and the *execution* axis (`engine::SolverEngine` — XLA
//! artifacts vs native kernels). Algorithm 1 composes one of each.
//!
//! * `rounding`  — eq. (8): exact-sparsity rounding (s% unstructured, n:m).
//! * `engine`    — execution backends: XLA artifacts (production) and a
//!   native-rust reference; both expose FISTA / Gram / power / objective.
//! * `solver`    — the `LayerSolver` trait + FISTA/ADMM/Frank-Wolfe
//!   implementations (the algorithm axis).
//! * `fista`     — native FISTA iterations (paper eqs. 5a–5d), the oracle
//!   the artifact path is tested against.
//! * `admm`      — ADMM splitting on the same objective (comparator).
//! * `objective` — Gram-form output error ‖W X* − WX‖_F (DESIGN.md §3.1).
//! * `lambda`    — Algorithm 1: adaptive λ bisection on E_round/E_total,
//!   solver-agnostic.
//! * `unit`      — a decoder layer as a pruning unit: sequential operator
//!   pruning with intra-layer error correction (paper §3.1, Fig. 2).
//! * `scheduler` — full-model pruning; parallel decoder-layer dispatch
//!   over the PJRT worker pool (paper §3.4).
//! * `report`    — per-op/per-layer diagnostics for EXPERIMENTS.md.

pub mod admm;
pub mod engine;
pub mod fista;
pub mod lambda;
pub mod objective;
pub mod report;
pub mod rounding;
pub mod scheduler;
pub mod solver;
pub mod unit;

pub use engine::{NativeEngine, SolverEngine, XlaEngine};
pub use lambda::{tune_lambda, TuneCfg, TuneResult};
pub use report::{LayerReport, OpReport, PruneReport, RoundStat};
pub use rounding::{round_model_to_sparsity, round_to_sparsity, satisfies_sparsity};
pub use scheduler::{prune_model, Method};
pub use solver::{build as build_solver, AdmmSolver, FistaSolver, FrankWolfeSolver, LayerSolver, SolverRun};

//! Structured diagnostics from a pruning run (feeds EXPERIMENTS.md and the
//! `prune` CLI output).

use std::time::Duration;

/// One outer tuning round of Algorithm 1, for convergence diagnostics
/// (the `trace` CLI's per-layer convergence table; `prune --trace-out`
/// emits one `solver_round` event per entry).
#[derive(Clone, Debug)]
pub struct RoundStat {
    /// 1-based round index within the operator's tuning loop.
    pub round: usize,
    /// λ this round's solver call used.
    pub lambda: f64,
    /// E_total = ‖round(W*_K) X* − WX‖_F after this round.
    pub objective: f64,
    /// ‖W*_K − round(W*_K)‖_F — distance of the solver iterate to the
    /// sparse feasible set (small ⇒ the solve landed near-feasible).
    pub residual: f64,
    /// Nonzeros in the rounded iterate.
    pub support: usize,
    /// Inner solver iterations spent this round.
    pub iters: usize,
    /// E_round = E_total − E_solver, the rounding penalty Algorithm 1
    /// bisects on (paper §3.3).
    pub e_round: f64,
    /// Penalized primal objective at the solver iterate (pre-rounding).
    pub primal: f64,
    /// Solver-specific dual-side value (see `pruner::solver`).
    pub dual: f64,
    /// Solver-specific convergence gap; 0 ⇒ converged.
    pub gap: f64,
}

/// Per-operator outcome.
#[derive(Clone, Debug)]
pub struct OpReport {
    pub layer: usize,
    pub op: String,
    /// ‖W* X* − WX‖_F after tuning.
    pub error: f64,
    /// Relative error ‖W* X* − WX‖ / ‖WX‖.
    pub rel_error: f64,
    pub lambda: f64,
    pub rounds: usize,
    /// Total inner solver iterations across tuning rounds.
    pub iters: usize,
    /// Which `LayerSolver` produced this operator ("" for dense passes
    /// and one-shot baselines, which have no inner solver).
    pub solver: String,
    pub sparsity: f64,
    pub elapsed: Duration,
    /// Per-round convergence history (empty when telemetry is off or the
    /// solver path does not tune λ).
    pub rounds_detail: Vec<RoundStat>,
}

/// Per-layer rollup.
#[derive(Clone, Debug, Default)]
pub struct LayerReport {
    pub layer: usize,
    pub ops: Vec<OpReport>,
    pub elapsed: Duration,
}

/// Whole-model pruning report.
#[derive(Clone, Debug, Default)]
pub struct PruneReport {
    pub model: String,
    pub method: String,
    pub sparsity_label: String,
    pub layers: Vec<LayerReport>,
    pub elapsed: Duration,
}

impl PruneReport {
    /// Mean relative operator error (a cheap overall quality signal).
    pub fn mean_rel_error(&self) -> f64 {
        let errs: Vec<f64> =
            self.layers.iter().flat_map(|l| l.ops.iter().map(|o| o.rel_error)).collect();
        crate::metrics::mean(&errs)
    }

    /// Achieved weight sparsity across all pruned operators.
    pub fn mean_sparsity(&self) -> f64 {
        let sp: Vec<f64> =
            self.layers.iter().flat_map(|l| l.ops.iter().map(|o| o.sparsity)).collect();
        crate::metrics::mean(&sp)
    }

    /// Total inner solver iterations (FISTA/ADMM/FW) across all operators.
    pub fn total_solver_iters(&self) -> usize {
        self.layers.iter().flat_map(|l| l.ops.iter().map(|o| o.iters)).sum()
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "{} {} {}: rel_err {:.4}, sparsity {:.3}, {} solver iters, {:.1}s",
            self.model,
            self.method,
            self.sparsity_label,
            self.mean_rel_error(),
            self.mean_sparsity(),
            self.total_solver_iters(),
            self.elapsed.as_secs_f64()
        )
    }

    /// Provenance blob for the sparse-artifact sidecar
    /// (`ser::artifact::ArtifactMeta::prune`): what produced these
    /// weights and how well the optimization converged.
    pub fn provenance_json(&self) -> crate::ser::Json {
        use crate::ser::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("method".to_string(), Json::Str(self.method.clone()));
        m.insert("sparsity".to_string(), Json::Str(self.sparsity_label.clone()));
        // mean() of an empty report is NaN, which is not valid JSON
        for (key, v) in [
            ("mean_rel_error", self.mean_rel_error()),
            ("mean_sparsity", self.mean_sparsity()),
        ] {
            if v.is_finite() {
                m.insert(key.to_string(), Json::Num(v));
            }
        }
        m.insert("solver_iters".to_string(), Json::Num(self.total_solver_iters() as f64));
        m.insert("elapsed_s".to_string(), Json::Num(self.elapsed.as_secs_f64()));
        Json::Obj(m)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rollups() {
        let op = |layer, err, sp| OpReport {
            layer,
            op: "wq".into(),
            error: err,
            rel_error: err / 10.0,
            lambda: 1e-5,
            rounds: 2,
            iters: 40,
            solver: "fista".into(),
            sparsity: sp,
            elapsed: Duration::from_millis(5),
            rounds_detail: Vec::new(),
        };
        let rep = PruneReport {
            model: "topt-s1".into(),
            method: "fista".into(),
            sparsity_label: "50%".into(),
            layers: vec![
                LayerReport { layer: 0, ops: vec![op(0, 1.0, 0.5), op(0, 2.0, 0.5)], elapsed: Duration::ZERO },
                LayerReport { layer: 1, ops: vec![op(1, 3.0, 0.5)], elapsed: Duration::ZERO },
            ],
            elapsed: Duration::from_secs(1),
        };
        assert!((rep.mean_rel_error() - 0.2).abs() < 1e-12);
        assert!((rep.mean_sparsity() - 0.5).abs() < 1e-12);
        assert_eq!(rep.total_solver_iters(), 120);
        assert!(rep.summary().contains("topt-s1"));
        assert!(rep.summary().contains("solver iters"));
        let prov = rep.provenance_json().to_string_compact();
        assert!(prov.contains("solver_iters"));
    }
}

//! ADMM solver extension — the alternating-direction comparator the paper
//! discusses (§2: Boža 2024 uses ADMM for weight updates; the paper argues
//! FISTA's convex formulation is more stable). Solving the same Gram-form
//! objective with ADMM lets the `ablation_solver` bench measure that claim
//! on our substrate.
//!
//! Splitting:  min_W ½tr(W A Wᵀ) − ⟨W,B⟩ + λΣ‖Z‖₁  s.t. W = Z
//!
//!   W-step: (A + ρI) solve    W = (B + ρ(Z − U)) (A + ρI)⁻¹
//!   Z-step: SoftShrink_{λ/ρ}(W + U)
//!   U-step: U += W − Z
//!
//! The W-step factors (A + ρI) once per solve (Cholesky), so K iterations
//! cost one factorization + K triangular-solve passes.

use anyhow::{Context, Result};

use crate::linalg::{cholesky, solve_lower, solve_upper};
use crate::tensor::{ops, Tensor};

use super::fista::soft_shrink;

/// ADMM on the Gram form. Returns (Z_K — the sparse iterate, iterations).
pub fn admm_solve(
    a: &Tensor,
    b: &Tensor,
    w0: &Tensor,
    lam: f64,
    rho: f64,
    iters: usize,
    tol: f64,
) -> Result<(Tensor, usize)> {
    let (m, n) = (w0.rows(), w0.cols());
    assert_eq!(a.rows(), n);
    // Factor (A + ρI) = L Lᵀ once.
    let mut a_rho = a.clone();
    for j in 0..n {
        let v = a_rho.at2(j, j) + rho as f32;
        a_rho.set2(j, j, v);
    }
    let l = cholesky(&a_rho).context("ADMM: A + rho I not PD (rho too small?)")?;

    let mut z = w0.clone();
    let mut u = Tensor::zeros(vec![m, n]);
    let mut w = w0.clone();
    let mut k = 0;
    while k < iters {
        // W-step: solve W (A + ρI) = B + ρ(Z − U), i.e. per row r:
        // (A + ρI) wᵣ = bᵣ + ρ(zᵣ − uᵣ)  (A symmetric)
        for r in 0..m {
            let rhs: Vec<f32> = (0..n)
                .map(|j| b.at2(r, j) + rho as f32 * (z.at2(r, j) - u.at2(r, j)))
                .collect();
            let y = solve_lower(&l, &rhs);
            let x = solve_upper(&l, &y);
            w.row_mut(r).copy_from_slice(&x);
        }
        // Z-step (prox) and U-step (dual ascent).
        let wu = ops::add_scaled(&w, &u, 1.0);
        let z_next = soft_shrink(&wu, (lam / rho) as f32);
        let primal_res = ops::frob_dist(&w, &z_next);
        for ((ui, &wi), &zi) in u.data_mut().iter_mut().zip(w.data()).zip(z_next.data()) {
            *ui += wi - zi;
        }
        z = z_next;
        k += 1;
        if primal_res < tol {
            break;
        }
    }
    Ok((z, k))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruner::fista::fista_solve;
    use crate::tensor::ops::{matmul, matmul_nt, quad_obj};
    use crate::util::Pcg64;

    fn setup(seed: u64, m: usize, n: usize, p: usize) -> (Tensor, Tensor, Tensor, f64) {
        let mut rng = Pcg64::seeded(seed);
        let w = Tensor::from_vec(vec![m, n], rng.normal_vec(m * n, 1.0));
        let x = Tensor::from_vec(vec![n, p], rng.normal_vec(n * p, 0.5));
        let a = matmul_nt(&x, &x);
        let b = matmul(&w, &a);
        let l = crate::linalg::power_iteration(&a, 64, 1.02);
        (w, a, b, l)
    }

    #[test]
    fn reaches_comparable_objective_to_fista() {
        let (_w, a, b, l_max) = setup(1, 12, 24, 96);
        let lam = 0.5;
        let w0 = Tensor::zeros(vec![12, 24]);
        let obj = |w: &Tensor| {
            0.5 * quad_obj(&a, &b, w)
                + lam * w.data().iter().map(|&x| x.abs() as f64).sum::<f64>()
        };
        let (w_admm, _) = admm_solve(&a, &b, &w0, lam, l_max * 0.1, 200, 1e-7).unwrap();
        let (w_fista, _) = fista_solve(&a, &b, &w0, lam, l_max, 200, 1e-9);
        let (oa, of) = (obj(&w_admm), obj(&w_fista));
        assert!(
            (oa - of).abs() < 0.05 * of.abs().max(1.0),
            "ADMM obj {oa} vs FISTA obj {of}"
        );
    }

    #[test]
    fn produces_exact_zeros() {
        let (_w, a, b, l_max) = setup(2, 8, 16, 64);
        let w0 = Tensor::zeros(vec![8, 16]);
        let (z, _) = admm_solve(&a, &b, &w0, l_max * 0.5, l_max * 0.1, 100, 1e-7).unwrap();
        let zeros = z.data().iter().filter(|&&x| x == 0.0).count();
        assert!(zeros > 0, "large λ must sparsify");
    }

    #[test]
    fn early_stop() {
        let (_w, a, b, l_max) = setup(3, 8, 16, 64);
        let w0 = Tensor::zeros(vec![8, 16]);
        let (_, k) = admm_solve(&a, &b, &w0, 0.0, l_max * 0.1, 10_000, 1e-5).unwrap();
        assert!(k < 10_000, "ran {k}");
    }
}

//! ADMM solver for the Gram-form objective — the alternating-direction
//! comparator the paper discusses (§2: Boža 2024 uses ADMM for weight
//! updates; the paper argues FISTA's convex formulation is more stable).
//! Promoted from bench-only status: `pruner::solver::AdmmSolver` runs it
//! inside Algorithm 1, so malformed inputs must surface as errors (not
//! panics inside the scheduler's worker threads).
//!
//! Splitting:  min_W ½tr(W A Wᵀ) − ⟨W,B⟩ + λΣ‖Z‖₁  s.t. W = Z
//!
//!   W-step: (A + ρI) solve    W = (B + ρ(Z − U)) (A + ρI)⁻¹
//!   Z-step: SoftShrink_{λ/ρ}(W + U)
//!   U-step: U += W − Z
//!
//! The W-step factors (A + ρI) once per solve (Cholesky); K iterations
//! then cost K triangular-solve passes. Rows are independent given the
//! factor, so the pass fans out row-block over `tensor::par` — each row is
//! computed purely from its global index, which keeps results bitwise
//! identical for any thread count (the same contract every native kernel
//! follows). The per-iteration RHS buffer and the in-place triangular
//! solves (`linalg::cholesky_solve_into`) are allocation-free inside the
//! loop.

use anyhow::{bail, Context, Result};

use crate::linalg::{cholesky, cholesky_solve_into};
use crate::tensor::{ops, par, Tensor};

use super::fista::soft_shrink;

/// Full ADMM outcome: the sparse iterate plus the final residual pair
/// (`pruner::solver` reports them as the per-round gap/dual telemetry).
pub struct AdmmOut {
    /// Z_K — the sparse iterate.
    pub w: Tensor,
    /// Iterations actually run.
    pub iters: usize,
    /// Final primal residual ‖W − Z‖_F (feasibility of the split).
    pub primal_res: f64,
    /// Final dual residual ρ‖Z_K − Z_{K−1}‖_F (stationarity).
    pub dual_res: f64,
}

/// ADMM on the Gram form. Returns (Z_K — the sparse iterate, iterations).
pub fn admm_solve(
    a: &Tensor,
    b: &Tensor,
    w0: &Tensor,
    lam: f64,
    rho: f64,
    iters: usize,
    tol: f64,
) -> Result<(Tensor, usize)> {
    let out = admm_solve_full(a, b, w0, lam, rho, iters, tol)?;
    Ok((out.w, out.iters))
}

/// ADMM with residual reporting; see [`admm_solve`] for the plain variant.
pub fn admm_solve_full(
    a: &Tensor,
    b: &Tensor,
    w0: &Tensor,
    lam: f64,
    rho: f64,
    iters: usize,
    tol: f64,
) -> Result<AdmmOut> {
    let (m, n) = (w0.rows(), w0.cols());
    if a.rows() != a.cols() {
        bail!("ADMM: Gram matrix A must be square, got {:?}", a.shape());
    }
    if a.rows() != n {
        bail!("ADMM: A is {:?} but W has {n} columns", a.shape());
    }
    if b.shape() != w0.shape() {
        bail!("ADMM: B {:?} != W0 {:?}", b.shape(), w0.shape());
    }
    if !rho.is_finite() || rho <= 0.0 {
        bail!("ADMM: rho must be a positive finite number, got {rho}");
    }
    if !lam.is_finite() || lam < 0.0 {
        bail!("ADMM: lambda must be finite and >= 0, got {lam}");
    }
    // Factor (A + ρI) = L Lᵀ once.
    let mut a_rho = a.clone();
    for j in 0..n {
        let v = a_rho.at2(j, j) + rho as f32;
        a_rho.set2(j, j, v);
    }
    let l = cholesky(&a_rho).context("ADMM: A + rho I not PD (rho too small?)")?;

    let mut z = w0.clone();
    let mut u = Tensor::zeros(vec![m, n]);
    let mut w = w0.clone();
    // Hoisted per-iteration scratch: the full RHS matrix B + ρ(Z − U).
    let mut rhs = Tensor::zeros(vec![m, n]);
    let mut k = 0;
    let mut primal_res = f64::INFINITY;
    let mut dual_res = f64::INFINITY;
    while k < iters {
        // W-step: solve W (A + ρI) = B + ρ(Z − U), i.e. per row r:
        // (A + ρI) wᵣ = bᵣ + ρ(zᵣ − uᵣ)  (A symmetric).
        for (((ri, &bi), &zi), &ui) in
            rhs.data_mut().iter_mut().zip(b.data()).zip(z.data()).zip(u.data())
        {
            *ri = bi + rho as f32 * (zi - ui);
        }
        let rhs_data = rhs.data();
        par::for_each_row_block(w.data_mut(), m, n, 1, |r0, _r1, block| {
            for (i, wrow) in block.chunks_mut(n).enumerate() {
                let r = r0 + i;
                cholesky_solve_into(&l, &rhs_data[r * n..(r + 1) * n], wrow);
            }
        });
        // Z-step (prox) and U-step (dual ascent).
        let wu = ops::add_scaled(&w, &u, 1.0);
        let z_next = soft_shrink(&wu, (lam / rho) as f32);
        primal_res = ops::frob_dist(&w, &z_next);
        dual_res = rho * ops::frob_dist(&z_next, &z);
        for ((ui, &wi), &zi) in u.data_mut().iter_mut().zip(w.data()).zip(z_next.data()) {
            *ui += wi - zi;
        }
        z = z_next;
        k += 1;
        if primal_res < tol {
            break;
        }
    }
    Ok(AdmmOut { w: z, iters: k, primal_res, dual_res })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruner::fista::fista_solve;
    use crate::tensor::ops::{matmul, matmul_nt, quad_obj};
    use crate::util::Pcg64;

    fn setup(seed: u64, m: usize, n: usize, p: usize) -> (Tensor, Tensor, Tensor, f64) {
        let mut rng = Pcg64::seeded(seed);
        let w = Tensor::from_vec(vec![m, n], rng.normal_vec(m * n, 1.0));
        let x = Tensor::from_vec(vec![n, p], rng.normal_vec(n * p, 0.5));
        let a = matmul_nt(&x, &x);
        let b = matmul(&w, &a);
        let l = crate::linalg::power_iteration(&a, 64, 1.02);
        (w, a, b, l)
    }

    #[test]
    fn reaches_comparable_objective_to_fista() {
        let (_w, a, b, l_max) = setup(1, 12, 24, 96);
        let lam = 0.5;
        let w0 = Tensor::zeros(vec![12, 24]);
        let obj = |w: &Tensor| {
            0.5 * quad_obj(&a, &b, w)
                + lam * w.data().iter().map(|&x| x.abs() as f64).sum::<f64>()
        };
        let (w_admm, _) = admm_solve(&a, &b, &w0, lam, l_max * 0.1, 200, 1e-7).unwrap();
        let (w_fista, _) = fista_solve(&a, &b, &w0, lam, l_max, 200, 1e-9);
        let (oa, of) = (obj(&w_admm), obj(&w_fista));
        assert!(
            (oa - of).abs() < 0.05 * of.abs().max(1.0),
            "ADMM obj {oa} vs FISTA obj {of}"
        );
    }

    #[test]
    fn produces_exact_zeros() {
        let (_w, a, b, l_max) = setup(2, 8, 16, 64);
        let w0 = Tensor::zeros(vec![8, 16]);
        let (z, _) = admm_solve(&a, &b, &w0, l_max * 0.5, l_max * 0.1, 100, 1e-7).unwrap();
        let zeros = z.data().iter().filter(|&&x| x == 0.0).count();
        assert!(zeros > 0, "large λ must sparsify");
    }

    #[test]
    fn early_stop() {
        let (_w, a, b, l_max) = setup(3, 8, 16, 64);
        let w0 = Tensor::zeros(vec![8, 16]);
        let (_, k) = admm_solve(&a, &b, &w0, 0.0, l_max * 0.1, 10_000, 1e-5).unwrap();
        assert!(k < 10_000, "ran {k}");
    }

    #[test]
    fn rejects_malformed_inputs_without_panicking() {
        let (_w, a, b, l_max) = setup(4, 8, 16, 64);
        let w_bad = Tensor::zeros(vec![8, 12]); // cols != a.rows()
        assert!(admm_solve(&a, &b, &w_bad, 0.1, l_max * 0.1, 10, 1e-6).is_err());
        let b_bad = Tensor::zeros(vec![4, 16]); // shape != w0
        let w0 = Tensor::zeros(vec![8, 16]);
        assert!(admm_solve(&a, &b_bad, &w0, 0.1, l_max * 0.1, 10, 1e-6).is_err());
        let a_rect = Tensor::zeros(vec![16, 12]); // non-square Gram
        assert!(admm_solve(&a_rect, &b, &w0, 0.1, l_max * 0.1, 10, 1e-6).is_err());
        assert!(admm_solve(&a, &b, &w0, 0.1, 0.0, 10, 1e-6).is_err()); // rho
        assert!(admm_solve(&a, &b, &w0, -1.0, l_max * 0.1, 10, 1e-6).is_err()); // lam
    }

    #[test]
    fn thread_count_does_not_change_results() {
        let (_w, a, b, l_max) = setup(5, 16, 24, 96);
        let w0 = Tensor::zeros(vec![16, 24]);
        let run = |threads: usize| {
            par::set_threads(threads);
            let out = admm_solve(&a, &b, &w0, 0.3, l_max * 0.1, 50, 0.0).unwrap().0;
            par::set_threads(0);
            out
        };
        let t1 = run(1);
        let t4 = run(4);
        assert_eq!(t1.data(), t4.data(), "ADMM W-step must be thread-count invariant");
    }

    #[test]
    fn residuals_shrink_with_iterations() {
        let (_w, a, b, l_max) = setup(6, 8, 16, 64);
        let w0 = Tensor::zeros(vec![8, 16]);
        let short = admm_solve_full(&a, &b, &w0, 0.2, l_max * 0.1, 5, 0.0).unwrap();
        let long = admm_solve_full(&a, &b, &w0, 0.2, l_max * 0.1, 200, 0.0).unwrap();
        assert!(long.primal_res <= short.primal_res * 1.01 + 1e-9);
        assert!(long.primal_res.is_finite() && long.dual_res.is_finite());
    }
}

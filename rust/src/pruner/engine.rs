//! Solver backends: the XLA artifact path (production) and the native
//! reference. Both implement `SolverEngine`, so Algorithm 1 and the
//! pruning unit are backend-agnostic; parity between the two is asserted
//! in rust/tests/engine_parity.rs.

use anyhow::{bail, Result};

use crate::config::FistaCfg;
use crate::runtime::session::{Arg, Session};
use crate::tensor::{kernels, ops, Tensor};

/// Backend-agnostic per-matrix solver operations.
pub trait SolverEngine {
    /// Gram accumulation over [n, p] activations (any p):
    /// returns (A = Xs Xsᵀ, C = Xd Xsᵀ, D = Xd Xdᵀ).
    fn gram(&self, xd: &Tensor, xs: &Tensor) -> Result<(Tensor, Tensor, Tensor)>;

    /// Per-op prep: (B = W·C, c = tr(W D Wᵀ)).
    fn prep(&self, w: &Tensor, c: &Tensor, d: &Tensor) -> Result<(Tensor, f64)>;

    /// L = λ_max(A) (with safety factor).
    fn power(&self, a: &Tensor) -> Result<f64>;

    /// FISTA solve from warm start; returns (W_K, iterations run).
    fn fista(&self, a: &Tensor, b: &Tensor, w0: &Tensor, lam: f64, l: f64) -> Result<(Tensor, usize)>;

    /// quad(A,B,W) = tr(W A Wᵀ) − 2⟨W,B⟩.
    fn obj(&self, a: &Tensor, b: &Tensor, w: &Tensor) -> Result<f64>;
}

// ---------------------------------------------------------------------
// Native reference engine
// ---------------------------------------------------------------------

/// Pure-rust engine (no artifacts needed). Mirrors the L2 graphs, running
/// on the multithreaded blocked kernels in `tensor::kernels`: the Gram
/// triple is one fused pass, `prep` never materializes W·D, and the FISTA
/// loop reuses its gradient buffer across iterations.
pub struct NativeEngine {
    pub cfg: FistaCfg,
}

impl NativeEngine {
    /// Engine over explicit solver constants. Thread-count plumbing lives
    /// in `prune_model` (PruneOptions::threads beats FistaCfg::threads);
    /// the engine itself never mutates process-global state.
    pub fn new(cfg: FistaCfg) -> NativeEngine {
        NativeEngine { cfg }
    }
}

impl Default for NativeEngine {
    fn default() -> Self {
        NativeEngine {
            cfg: FistaCfg {
                max_iters: 20,
                power_iters: 64,
                power_safety: 1.02,
                stop_tol: 1e-6,
                threads: 0,
            },
        }
    }
}

impl SolverEngine for NativeEngine {
    fn gram(&self, xd: &Tensor, xs: &Tensor) -> Result<(Tensor, Tensor, Tensor)> {
        if xd.shape() != xs.shape() {
            bail!("gram: xd {:?} != xs {:?}", xd.shape(), xs.shape());
        }
        Ok(kernels::gram3(xd, xs))
    }

    fn prep(&self, w: &Tensor, c: &Tensor, d: &Tensor) -> Result<(Tensor, f64)> {
        let b = ops::matmul(w, c);
        Ok((b, kernels::quad_form(w, d)))
    }

    fn power(&self, a: &Tensor) -> Result<f64> {
        Ok(crate::linalg::power_iteration(a, self.cfg.power_iters, self.cfg.power_safety))
    }

    fn fista(&self, a: &Tensor, b: &Tensor, w0: &Tensor, lam: f64, l: f64) -> Result<(Tensor, usize)> {
        Ok(super::fista::fista_solve(a, b, w0, lam, l, self.cfg.max_iters, self.cfg.stop_tol))
    }

    fn obj(&self, a: &Tensor, b: &Tensor, w: &Tensor) -> Result<f64> {
        Ok(ops::quad_obj(a, b, w))
    }
}

// ---------------------------------------------------------------------
// XLA artifact engine
// ---------------------------------------------------------------------

/// Production engine: all solver math runs in the AOT artifacts through a
/// PJRT session (Pallas FISTA kernel, Gram matmul kernel, fused prep).
pub struct XlaEngine<'s> {
    session: &'s Session,
}

impl<'s> XlaEngine<'s> {
    pub fn new(session: &'s Session) -> Self {
        XlaEngine { session }
    }

    pub fn session(&self) -> &Session {
        self.session
    }

    /// Slice [n, p] activations into zero-padded gram_chunk columns.
    fn chunked(&self, x: &Tensor, chunk: usize) -> Vec<Tensor> {
        let (n, p) = (x.rows(), x.cols());
        let mut out = Vec::with_capacity(p.div_ceil(chunk));
        for c0 in (0..p).step_by(chunk) {
            let c1 = (c0 + chunk).min(p);
            let mut buf = vec![0f32; n * chunk];
            for r in 0..n {
                let src = &x.data()[r * p + c0..r * p + c1];
                buf[r * chunk..r * chunk + (c1 - c0)].copy_from_slice(src);
            }
            out.push(Tensor::from_vec(vec![n, chunk], buf));
        }
        out
    }
}

impl SolverEngine for XlaEngine<'_> {
    fn gram(&self, xd: &Tensor, xs: &Tensor) -> Result<(Tensor, Tensor, Tensor)> {
        if xd.shape() != xs.shape() {
            bail!("gram: xd {:?} != xs {:?}", xd.shape(), xs.shape());
        }
        let n = xd.rows();
        let chunk = self.session.manifest().gram_chunk;
        let name = format!("gram_{n}");
        let mut acc: Option<(Tensor, Tensor, Tensor)> = None;
        for (cd, cs) in self.chunked(xd, chunk).iter().zip(self.chunked(xs, chunk).iter()) {
            let out = self.session.run(&name, &[Arg::T(cd), Arg::T(cs)])?;
            let [a, c, d] = <[Tensor; 3]>::try_from(out).map_err(|_| anyhow::anyhow!("gram arity"))?;
            acc = Some(match acc {
                None => (a, c, d),
                Some((pa, pc, pd)) => (
                    ops::add_scaled(&pa, &a, 1.0),
                    ops::add_scaled(&pc, &c, 1.0),
                    ops::add_scaled(&pd, &d, 1.0),
                ),
            });
        }
        acc.ok_or_else(|| anyhow::anyhow!("gram: empty activations"))
    }

    fn prep(&self, w: &Tensor, c: &Tensor, d: &Tensor) -> Result<(Tensor, f64)> {
        let name = format!("prep_{}x{}", w.rows(), w.cols());
        let out = self.session.run(&name, &[Arg::T(w), Arg::T(c), Arg::T(d)])?;
        let [b, cn] = <[Tensor; 2]>::try_from(out).map_err(|_| anyhow::anyhow!("prep arity"))?;
        Ok((b, cn.first() as f64))
    }

    fn power(&self, a: &Tensor) -> Result<f64> {
        let name = format!("power_{}", a.rows());
        let out = self.session.run(&name, &[Arg::T(a)])?;
        Ok(out[0].first() as f64)
    }

    fn fista(&self, a: &Tensor, b: &Tensor, w0: &Tensor, lam: f64, l: f64) -> Result<(Tensor, usize)> {
        let name = format!("fista_{}x{}", w0.rows(), w0.cols());
        let out = self.session.run(
            &name,
            &[Arg::T(a), Arg::T(b), Arg::T(w0), Arg::Scalar(lam as f32), Arg::Scalar(l as f32)],
        )?;
        let [w, k] = <[Tensor; 2]>::try_from(out).map_err(|_| anyhow::anyhow!("fista arity"))?;
        Ok((w, k.first() as usize))
    }

    fn obj(&self, a: &Tensor, b: &Tensor, w: &Tensor) -> Result<f64> {
        let name = format!("obj_{}x{}", w.rows(), w.cols());
        let out = self.session.run(&name, &[Arg::T(a), Arg::T(b), Arg::T(w)])?;
        Ok(out[0].first() as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    #[test]
    fn xla_gram_chunks_equal_native_gram() {
        let Some(session) = crate::testing::try_session() else { return };
        let xla = XlaEngine::new(&session);
        let native = NativeEngine::default();
        let mut rng = Pcg64::seeded(11);
        // p deliberately NOT a multiple of gram_chunk to exercise padding
        let (n, p) = (64, 700);
        let xd = Tensor::from_vec(vec![n, p], rng.normal_vec(n * p, 1.0));
        let xs = Tensor::from_vec(vec![n, p], rng.normal_vec(n * p, 1.0));
        let (a1, c1, d1) = xla.gram(&xd, &xs).unwrap();
        let (a2, c2, d2) = native.gram(&xd, &xs).unwrap();
        for (x, y) in [(&a1, &a2), (&c1, &c2), (&d1, &d2)] {
            assert!(ops::frob_dist(x, y) < 1e-2 * y.frob_norm().max(1.0));
        }
    }

    #[test]
    fn xla_fista_matches_native() {
        let Some(session) = crate::testing::try_session() else { return };
        let xla = XlaEngine::new(&session);
        let native = NativeEngine::default();
        let mut rng = Pcg64::seeded(12);
        let (m, n, p) = (64, 64, 256);
        let w = Tensor::from_vec(vec![m, n], rng.normal_vec(m * n, 1.0));
        let x = Tensor::from_vec(vec![n, p], rng.normal_vec(n * p, 0.5));
        let (a, c, d) = native.gram(&x, &x).unwrap();
        let (b, _) = native.prep(&w, &c, &d).unwrap();
        let l = native.power(&a).unwrap();
        let w0 = Tensor::zeros(vec![m, n]);
        let (w_xla, k_xla) = xla.fista(&a, &b, &w0, 0.05, l).unwrap();
        let (w_nat, k_nat) = native.fista(&a, &b, &w0, 0.05, l).unwrap();
        assert_eq!(k_xla, k_nat, "iteration counts must agree");
        assert!(
            ops::frob_dist(&w_xla, &w_nat) < 1e-3 * w_nat.frob_norm().max(1.0),
            "dist {}",
            ops::frob_dist(&w_xla, &w_nat)
        );
        let _ = d;
    }

    #[test]
    fn xla_prep_and_obj_match_native() {
        let Some(session) = crate::testing::try_session() else { return };
        let xla = XlaEngine::new(&session);
        let native = NativeEngine::default();
        let mut rng = Pcg64::seeded(13);
        let (m, n) = (256, 64);
        let w = Tensor::from_vec(vec![m, n], rng.normal_vec(m * n, 1.0));
        let x = Tensor::from_vec(vec![n, 300], rng.normal_vec(n * 300, 0.5));
        let (a, c, d) = native.gram(&x, &x).unwrap();
        let (b_x, cn_x) = xla.prep(&w, &c, &d).unwrap();
        let (b_n, cn_n) = native.prep(&w, &c, &d).unwrap();
        assert!(ops::frob_dist(&b_x, &b_n) < 1e-2 * b_n.frob_norm());
        assert!((cn_x - cn_n).abs() < 1e-2 * cn_n.abs());
        let o_x = xla.obj(&a, &b_n, &w).unwrap();
        let o_n = native.obj(&a, &b_n, &w).unwrap();
        assert!((o_x - o_n).abs() < 1e-2 * o_n.abs().max(1.0), "{o_x} vs {o_n}");
    }
}

//! The rounding step, paper eq. (8): FISTA's near-zero values are snapped
//! to exact zeros so the matrix meets the target sparsity pattern exactly.
//!
//! * Unstructured s%: zero the s% entries of smallest |·| in the matrix
//!   (paper: "the s% elements with the smallest absolute values in W*_K").
//! * n:m semi-structured: in every group of m consecutive entries of a
//!   row, keep the n of largest |·| (paper §2 / eq. 8). A row length that
//!   is not a multiple of m leaves a *tail group* of `cols % m` entries;
//!   it is treated as a smaller group — keep the `min(n, len)` of largest
//!   |·| — rather than aborting mid-prune. `satisfies_sparsity` accepts
//!   the same tail-group rule.
//!
//! All magnitude comparisons use `f32::total_cmp` on |·|, so the selection
//! is deterministic (no order-dependence from incomparable NaNs) and NaN
//! weights — a signal of an upstream solver problem — sort as the largest
//! magnitudes and are never silently chosen over finite entries.

use crate::config::{ModelSpec, Sparsity};
use crate::model::params::ModelParams;
use crate::tensor::Tensor;

/// Return a copy of `w` rounded to the exact sparsity pattern.
pub fn round_to_sparsity(w: &Tensor, sp: Sparsity) -> Tensor {
    let mut out = w.clone();
    round_in_place(&mut out, sp);
    out
}

/// Round every pruned operator of a model to `sp` — the quick way to
/// build a sparse fixture (serve-bench, parity tests) without a full
/// prune run; weight *quality* is magnitude-only, the *pattern* is exact.
pub fn round_model_to_sparsity(
    spec: &ModelSpec,
    params: &ModelParams,
    sp: Sparsity,
) -> anyhow::Result<ModelParams> {
    let mut out = params.clone();
    for li in 0..spec.layers {
        for op in crate::model::ops::pruned_ops(spec) {
            let name = format!("l{li}.{}", op.name);
            out.set(&name, round_to_sparsity(out.req(&name)?, sp))?;
        }
    }
    Ok(out)
}

/// In-place variant.
pub fn round_in_place(w: &mut Tensor, sp: Sparsity) {
    match sp {
        Sparsity::Unstructured(s) => round_unstructured(w, s),
        Sparsity::Semi(n, m) => round_semi(w, n, m),
    }
}

fn round_unstructured(w: &mut Tensor, s: f64) {
    let len = w.len();
    let k = ((len as f64) * s).floor() as usize;
    if k == 0 {
        return;
    }
    // Quickselect the k-th smallest |value| via an index permutation.
    // total_cmp keeps the selection deterministic even with NaN inputs
    // (NaN sorts above every finite magnitude, so it is never zeroed in
    // place of a finite entry).
    let data = w.data_mut();
    let mut idx: Vec<u32> = (0..len as u32).collect();
    let (smallest, _, _) = idx.select_nth_unstable_by(k - 1, |&a, &b| {
        data[a as usize].abs().total_cmp(&data[b as usize].abs())
    });
    for &i in smallest.iter() {
        data[i as usize] = 0.0;
    }
    data[idx[k - 1] as usize] = 0.0; // the pivot itself is the k-th smallest
}

fn round_semi(w: &mut Tensor, n: usize, m: usize) {
    assert!(n <= m && m > 0, "degenerate {n}:{m} pattern (Sparsity::parse rejects these)");
    let rows = w.rows();
    let cols = w.cols();
    let data = w.data_mut();
    let mut order: Vec<usize> = Vec::with_capacity(m);
    for r in 0..rows {
        let row = &mut data[r * cols..(r + 1) * cols];
        // chunks_mut yields the ragged tail (cols % m entries) as a final
        // smaller group: keep the min(n, len) of largest |·| there too.
        for grp in row.chunks_mut(m) {
            let keep = n.min(grp.len());
            if keep == grp.len() {
                continue;
            }
            order.clear();
            order.extend(0..grp.len());
            order.sort_unstable_by(|&a, &b| grp[a].abs().total_cmp(&grp[b].abs()));
            for &i in &order[..grp.len() - keep] {
                grp[i] = 0.0;
            }
        }
    }
}

/// Check a matrix satisfies the sparsity pattern (used by tests, the
/// scheduler's post-conditions, and `sparse::NmMatrix::from_dense`). The
/// n:m check applies the same tail-group rule as [`round_in_place`]: a
/// final group of `cols % m` entries may hold at most `min(n, len)`
/// nonzeros (trivially at most `len`, so the bound below covers it).
pub fn satisfies_sparsity(w: &Tensor, sp: Sparsity) -> bool {
    match sp {
        Sparsity::Unstructured(s) => {
            let need = ((w.len() as f64) * s).floor() as usize;
            w.data().iter().filter(|&&x| x == 0.0).count() >= need
        }
        Sparsity::Semi(n, m) => {
            if m == 0 {
                return false;
            }
            let cols = w.cols();
            w.data()
                .chunks(cols)
                .all(|row| row.chunks(m).all(|g| g.iter().filter(|&&x| x != 0.0).count() <= n))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn randw(seed: u64, m: usize, n: usize) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        Tensor::from_vec(vec![m, n], rng.normal_vec(m * n, 1.0))
    }

    #[test]
    fn unstructured_exact_count() {
        for s in [0.1, 0.25, 0.5, 0.8] {
            let w = round_to_sparsity(&randw(1, 16, 24), Sparsity::Unstructured(s));
            let zeros = w.data().iter().filter(|&&x| x == 0.0).count();
            assert_eq!(zeros, ((16 * 24) as f64 * s).floor() as usize, "s={s}");
            assert!(satisfies_sparsity(&w, Sparsity::Unstructured(s)));
        }
    }

    #[test]
    fn unstructured_keeps_largest() {
        let w = Tensor::from_vec(vec![1, 4], vec![0.1, -5.0, 0.2, 3.0]);
        let r = round_to_sparsity(&w, Sparsity::Unstructured(0.5));
        assert_eq!(r.data(), &[0.0, -5.0, 0.0, 3.0]);
    }

    #[test]
    fn semi_2_4_per_group() {
        let w = randw(2, 8, 32);
        let r = round_to_sparsity(&w, Sparsity::Semi(2, 4));
        assert!(satisfies_sparsity(&r, Sparsity::Semi(2, 4)));
        // overall rate is exactly 50%
        assert!((r.sparsity() - 0.5).abs() < 1e-9);
        // kept entries are the group-wise largest
        for r_i in 0..8 {
            for g in (0..32).step_by(4) {
                let orig: Vec<f32> = (0..4).map(|j| w.at2(r_i, g + j).abs()).collect();
                let mut sorted = orig.clone();
                sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
                for j in 0..4 {
                    if r.at2(r_i, g + j) != 0.0 {
                        assert!(orig[j] >= sorted[2] - 1e-9);
                    }
                }
            }
        }
    }

    #[test]
    fn semi_1_4_and_4_4() {
        let w = randw(3, 4, 16);
        let r14 = round_to_sparsity(&w, Sparsity::Semi(1, 4));
        assert!((r14.sparsity() - 0.75).abs() < 1e-9);
        let r44 = round_to_sparsity(&w, Sparsity::Semi(4, 4));
        assert_eq!(&r44, &w, "4:4 must be identity");
    }

    #[test]
    fn semi_ragged_tail_is_a_smaller_group() {
        // cols = 10, m = 4: two full groups + a tail of 2. This used to
        // abort with an assert mid-prune; now the tail keeps min(n, 2).
        let w = randw(7, 3, 10);
        let r = round_to_sparsity(&w, Sparsity::Semi(2, 4));
        assert!(satisfies_sparsity(&r, Sparsity::Semi(2, 4)));
        for row in 0..3 {
            // full groups keep exactly 2 (random data: no exact zeros)
            for g in [0usize, 4] {
                let kept = (0..4).filter(|&j| r.at2(row, g + j) != 0.0).count();
                assert_eq!(kept, 2, "row {row} group {g}");
            }
            // the tail group of 2 keeps min(n, 2) = 2 → untouched
            for j in 8..10 {
                assert_eq!(r.at2(row, j), w.at2(row, j), "row {row} tail col {j}");
            }
        }
        // a 1:4 pattern prunes the tail down to its largest entry
        let r14 = round_to_sparsity(&w, Sparsity::Semi(1, 4));
        assert!(satisfies_sparsity(&r14, Sparsity::Semi(1, 4)));
        for row in 0..3 {
            let kept = (8..10).filter(|&j| r14.at2(row, j) != 0.0).count();
            assert_eq!(kept, 1, "row {row} tail");
        }
    }

    #[test]
    fn nan_inputs_round_deterministically() {
        // Regression: partial_cmp(..).unwrap_or(Equal) made the selection
        // order-dependent with NaN present. total_cmp sorts NaN above all
        // finite magnitudes, so the k smallest *finite* entries are zeroed
        // and the NaN (an upstream-solver red flag) survives visibly.
        let w = Tensor::from_vec(vec![1, 8], vec![0.1, f32::NAN, -0.2, 3.0, 0.05, -4.0, 0.3, 1.0]);
        let r = round_to_sparsity(&w, Sparsity::Unstructured(0.5));
        assert_eq!(r.data().iter().filter(|&&x| x == 0.0).count(), 4);
        for j in [0usize, 2, 4, 6] {
            assert_eq!(r.data()[j], 0.0, "entry {j} is among the 4 smallest |·|");
        }
        assert!(r.data()[1].is_nan(), "NaN must survive, not displace a finite entry");
        assert_eq!(r.data()[3], 3.0);
        assert_eq!(r.data()[5], -4.0);
        assert_eq!(r.data()[7], 1.0);

        // same contract for the n:m path, group by group
        let w = Tensor::from_vec(vec![1, 8], vec![0.1, f32::NAN, -0.2, 3.0, 0.05, -4.0, 0.3, 1.0]);
        let r = round_to_sparsity(&w, Sparsity::Semi(2, 4));
        assert!(r.data()[1].is_nan());
        assert_eq!(&r.data()[..1], &[0.0]);
        assert_eq!(&r.data()[2..4], &[0.0, 3.0]);
        assert_eq!(&r.data()[4..], &[0.0, -4.0, 0.0, 1.0]);
    }

    #[test]
    fn idempotent() {
        let w = randw(4, 10, 20);
        let once = round_to_sparsity(&w, Sparsity::Unstructured(0.5));
        let twice = round_to_sparsity(&once, Sparsity::Unstructured(0.5));
        assert_eq!(once, twice);
    }

    #[test]
    fn property_random_shapes_and_rates() {
        crate::testing::check("rounding meets sparsity", 25, |g| {
            let m = g.int(1, 24);
            let n = 4 * g.int(1, 16);
            let w = Tensor::from_vec(vec![m, n], g.vec_normal(m * n, 1.0));
            let sp = if g.bool() {
                Sparsity::Unstructured(g.f32_in(0.05, 0.9) as f64)
            } else {
                Sparsity::Semi(1 + g.int(0, 2), 4)
            };
            let r = round_to_sparsity(&w, sp);
            if !satisfies_sparsity(&r, sp) {
                return Err(format!("pattern violated for {m}x{n} {sp:?}"));
            }
            // rounding must only zero entries, never alter survivors
            for (a, b) in w.data().iter().zip(r.data()) {
                if *b != 0.0 && a != b {
                    return Err("survivor entry changed".into());
                }
            }
            Ok(())
        });
    }
}

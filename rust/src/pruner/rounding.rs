//! The rounding step, paper eq. (8): FISTA's near-zero values are snapped
//! to exact zeros so the matrix meets the target sparsity pattern exactly.
//!
//! * Unstructured s%: zero the s% entries of smallest |·| in the matrix
//!   (paper: "the s% elements with the smallest absolute values in W*_K").
//! * n:m semi-structured: in every group of m consecutive entries of a
//!   row, keep the n of largest |·| (paper §2 / eq. 8).

use crate::config::{ModelSpec, Sparsity};
use crate::model::params::ModelParams;
use crate::tensor::Tensor;

/// Return a copy of `w` rounded to the exact sparsity pattern.
pub fn round_to_sparsity(w: &Tensor, sp: Sparsity) -> Tensor {
    let mut out = w.clone();
    round_in_place(&mut out, sp);
    out
}

/// Round every pruned operator of a model to `sp` — the quick way to
/// build a sparse fixture (serve-bench, parity tests) without a full
/// prune run; weight *quality* is magnitude-only, the *pattern* is exact.
pub fn round_model_to_sparsity(
    spec: &ModelSpec,
    params: &ModelParams,
    sp: Sparsity,
) -> anyhow::Result<ModelParams> {
    let mut out = params.clone();
    for li in 0..spec.layers {
        for op in crate::model::ops::pruned_ops(spec) {
            let name = format!("l{li}.{}", op.name);
            out.set(&name, round_to_sparsity(out.req(&name)?, sp))?;
        }
    }
    Ok(out)
}

/// In-place variant.
pub fn round_in_place(w: &mut Tensor, sp: Sparsity) {
    match sp {
        Sparsity::Unstructured(s) => round_unstructured(w, s),
        Sparsity::Semi(n, m) => round_semi(w, n, m),
    }
}

fn round_unstructured(w: &mut Tensor, s: f64) {
    let len = w.len();
    let k = ((len as f64) * s).floor() as usize;
    if k == 0 {
        return;
    }
    // Quickselect the k-th smallest |value| via an index permutation.
    let data = w.data_mut();
    let mut idx: Vec<u32> = (0..len as u32).collect();
    let (smallest, _, _) = idx.select_nth_unstable_by(k - 1, |&a, &b| {
        data[a as usize]
            .abs()
            .partial_cmp(&data[b as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    for &i in smallest.iter() {
        data[i as usize] = 0.0;
    }
    data[idx[k - 1] as usize] = 0.0; // the pivot itself is the k-th smallest
}

fn round_semi(w: &mut Tensor, n: usize, m: usize) {
    assert!(n <= m && m > 0);
    let cols = w.cols();
    assert_eq!(cols % m, 0, "row length {cols} not divisible by group size {m}");
    let rows = w.rows();
    let data = w.data_mut();
    let drop = m - n;
    let mut order: Vec<usize> = vec![0; m];
    for r in 0..rows {
        let row = &mut data[r * cols..(r + 1) * cols];
        for g in (0..cols).step_by(m) {
            let grp = &mut row[g..g + m];
            for (i, o) in order.iter_mut().enumerate() {
                *o = i;
            }
            order.sort_unstable_by(|&a, &b| {
                grp[a].abs().partial_cmp(&grp[b].abs()).unwrap_or(std::cmp::Ordering::Equal)
            });
            for &i in &order[..drop] {
                grp[i] = 0.0;
            }
        }
    }
}

/// Check a matrix satisfies the sparsity pattern (used by tests and the
/// scheduler's post-conditions).
pub fn satisfies_sparsity(w: &Tensor, sp: Sparsity) -> bool {
    match sp {
        Sparsity::Unstructured(s) => {
            let need = ((w.len() as f64) * s).floor() as usize;
            w.data().iter().filter(|&&x| x == 0.0).count() >= need
        }
        Sparsity::Semi(n, m) => {
            let cols = w.cols();
            if cols % m != 0 {
                return false;
            }
            w.data()
                .chunks(cols)
                .all(|row| row.chunks(m).all(|g| g.iter().filter(|&&x| x != 0.0).count() <= n))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn randw(seed: u64, m: usize, n: usize) -> Tensor {
        let mut rng = Pcg64::seeded(seed);
        Tensor::from_vec(vec![m, n], rng.normal_vec(m * n, 1.0))
    }

    #[test]
    fn unstructured_exact_count() {
        for s in [0.1, 0.25, 0.5, 0.8] {
            let w = round_to_sparsity(&randw(1, 16, 24), Sparsity::Unstructured(s));
            let zeros = w.data().iter().filter(|&&x| x == 0.0).count();
            assert_eq!(zeros, ((16 * 24) as f64 * s).floor() as usize, "s={s}");
            assert!(satisfies_sparsity(&w, Sparsity::Unstructured(s)));
        }
    }

    #[test]
    fn unstructured_keeps_largest() {
        let w = Tensor::from_vec(vec![1, 4], vec![0.1, -5.0, 0.2, 3.0]);
        let r = round_to_sparsity(&w, Sparsity::Unstructured(0.5));
        assert_eq!(r.data(), &[0.0, -5.0, 0.0, 3.0]);
    }

    #[test]
    fn semi_2_4_per_group() {
        let w = randw(2, 8, 32);
        let r = round_to_sparsity(&w, Sparsity::Semi(2, 4));
        assert!(satisfies_sparsity(&r, Sparsity::Semi(2, 4)));
        // overall rate is exactly 50%
        assert!((r.sparsity() - 0.5).abs() < 1e-9);
        // kept entries are the group-wise largest
        for r_i in 0..8 {
            for g in (0..32).step_by(4) {
                let orig: Vec<f32> = (0..4).map(|j| w.at2(r_i, g + j).abs()).collect();
                let mut sorted = orig.clone();
                sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
                for j in 0..4 {
                    if r.at2(r_i, g + j) != 0.0 {
                        assert!(orig[j] >= sorted[2] - 1e-9);
                    }
                }
            }
        }
    }

    #[test]
    fn semi_1_4_and_4_4() {
        let w = randw(3, 4, 16);
        let r14 = round_to_sparsity(&w, Sparsity::Semi(1, 4));
        assert!((r14.sparsity() - 0.75).abs() < 1e-9);
        let r44 = round_to_sparsity(&w, Sparsity::Semi(4, 4));
        assert_eq!(&r44, &w, "4:4 must be identity");
    }

    #[test]
    fn idempotent() {
        let w = randw(4, 10, 20);
        let once = round_to_sparsity(&w, Sparsity::Unstructured(0.5));
        let twice = round_to_sparsity(&once, Sparsity::Unstructured(0.5));
        assert_eq!(once, twice);
    }

    #[test]
    fn property_random_shapes_and_rates() {
        crate::testing::check("rounding meets sparsity", 25, |g| {
            let m = g.int(1, 24);
            let n = 4 * g.int(1, 16);
            let w = Tensor::from_vec(vec![m, n], g.vec_normal(m * n, 1.0));
            let sp = if g.bool() {
                Sparsity::Unstructured(g.f32_in(0.05, 0.9) as f64)
            } else {
                Sparsity::Semi(1 + g.int(0, 2), 4)
            };
            let r = round_to_sparsity(&w, sp);
            if !satisfies_sparsity(&r, sp) {
                return Err(format!("pattern violated for {m}x{n} {sp:?}"));
            }
            // rounding must only zero entries, never alter survivors
            for (a, b) in w.data().iter().zip(r.data()) {
                if *b != 0.0 && a != b {
                    return Err("survivor entry changed".into());
                }
            }
            Ok(())
        });
    }
}

//! The pruning unit: one decoder layer, pruned operator-by-operator in
//! topological order with intra-layer error correction (paper §3.1, Fig. 2).
//!
//! For each operator W the unit needs two activation matrices:
//!   X  — the operator input on the *dense* path (the target WX), and
//!   X* — the input on the *pruned* path (what W* will actually see).
//! X comes from one capture of the layer under dense weights; X* is
//! re-captured under the current partially-pruned weights whenever the
//! next operator reads a capture point downstream of a pruned operator.
//! With error correction disabled (the Fig. 4a ablation) X* ≡ X and both
//! come from a single capture — exactly eq. (1) instead of eq. (2).
//!
//! Capture runs on either backend: the `capture_{model}` XLA artifact when
//! a PJRT session is supplied, or the native forward pass (hooked through
//! `model::forward::layer_forward_mapped`) when it is not — so the whole
//! unit is self-contained on the native engine.
//!
//! Operators that share a capture point (q/k/v; the SwiGLU gate/up pair)
//! are solved concurrently on the native engine when `opts.workers > 1`:
//! their solves read the same X/X* and are independent, so overlapping
//! them is exact, not an approximation.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::baselines::{self, BaselineKind};
use crate::config::{Engine, FamilyKind, ModelSpec, Presets, PruneOptions, WarmStart};
use crate::model::forward::layer_forward_mapped;
use crate::model::ops::{pruned_ops, CaptureKey, PrunedOp};
use crate::model::spec::layer_param_specs;
use crate::runtime::session::{Arg, Session};
use crate::tensor::{ops, par, Tensor};

use super::engine::{NativeEngine, SolverEngine, XlaEngine};
use super::lambda::{tune_lambda, TuneCfg};
use super::objective::ErrorModel;
use super::report::{LayerReport, OpReport, RoundStat};
use super::scheduler::Method;
use super::solver::{self, LayerSolver};

/// Result of pruning one layer.
pub struct UnitResult {
    /// (bare op name, pruned weight) for every pruned operator.
    pub pruned: Vec<(String, Tensor)>,
    /// Layer outputs under dense weights (input to the next dense layer).
    pub y_dense: Vec<Tensor>,
    /// Layer outputs under pruned weights (input to the next pruned layer).
    pub y_pruned: Vec<Tensor>,
    pub report: LayerReport,
}

/// Captured activations of one layer: X matrices per capture key + y.
struct Captures {
    /// Indexed by CaptureKey::output_index(): [n_key, p] matrices.
    acts: Vec<Tensor>,
    /// Per-batch [cb, s, d] layer outputs.
    y: Vec<Tensor>,
}

/// What one operator solve produced (collected before mutating the layer).
struct SolveOut {
    w_star: Tensor,
    lambda: f64,
    rounds: usize,
    iters: usize,
    error: f64,
    /// ‖WX‖ from the error model's constant term (relative-error scale).
    scale: f64,
    elapsed: std::time::Duration,
    /// Per-round convergence telemetry (solver path only; empty for
    /// baselines and dense).
    history: Vec<RoundStat>,
}

/// Prune one decoder layer.
///
/// `layer_params` must be in capture-artifact order (layer_param_specs);
/// `xd/xs_batches` are [cb, s, d] layer inputs on the dense/pruned paths;
/// `valid_rows[i]` is the number of real (unpadded) rows in batch i.
/// `session` is required for `Engine::Xla`; `Engine::Native` ignores it
/// and runs capture + solve entirely on the native kernels.
#[allow(clippy::too_many_arguments)]
pub fn prune_unit(
    session: Option<&Session>,
    presets: &Presets,
    spec: &ModelSpec,
    method: &Method,
    opts: &PruneOptions,
    layer: usize,
    layer_params: &[Tensor],
    xd_batches: &[Tensor],
    xs_batches: &[Tensor],
    valid_rows: &[usize],
) -> Result<UnitResult> {
    #[allow(clippy::disallowed_methods)]
    // fp-lint: allow(clock) — offline prune timing report, never served
    let t_layer = Instant::now();
    let native;
    let xla;
    let (engine, cap_session): (&dyn SolverEngine, Option<&Session>) = match opts.engine {
        Engine::Xla => {
            let Some(s) = session else {
                bail!("Engine::Xla needs a PJRT session (artifacts); use Engine::Native otherwise")
            };
            xla = XlaEngine::new(s);
            (&xla, Some(s))
        }
        Engine::Native => {
            native = NativeEngine::new(presets.fista.clone());
            (&native, None)
        }
    };

    let mut cur: Vec<Tensor> = layer_params.to_vec();
    let param_names: Vec<String> =
        layer_param_specs(spec, None).iter().map(|s| s.name.clone()).collect();
    let op_index = |name: &str| -> usize {
        param_names.iter().position(|n| n == name).expect("op in layer params")
    };
    // The scheduler's parallel pass 1 feeds the same batches as both paths;
    // detecting that saves two identical captures per layer.
    let same_input = std::ptr::eq(xd_batches.as_ptr(), xs_batches.as_ptr())
        && xd_batches.len() == xs_batches.len();

    // One dense capture: targets WX (and the dense-path layer output).
    let dense_caps = run_capture(cap_session, spec, layer_params, xd_batches, valid_rows)?;

    let mut report = LayerReport { layer, ..Default::default() };
    if matches!(method, Method::Dense) {
        let y_pruned = if same_input {
            dense_caps.y.clone()
        } else {
            run_capture(cap_session, spec, layer_params, xs_batches, valid_rows)?.y
        };
        report.elapsed = t_layer.elapsed();
        return Ok(UnitResult { pruned: Vec::new(), y_dense: dense_caps.y, y_pruned, report });
    }

    // Correction on: X* starts as the pruned-path capture under the still-
    // dense current layer, re-captured after downstream mutations. When
    // both paths feed identical batches (parallel mode) the initial star
    // capture would equal the dense one — `None` falls back to X below, so
    // the duplicate capture is skipped and recomputed only once ops have
    // actually been pruned. Correction off: X* ≡ X (single capture, eq. 1).
    let correction = opts.error_correction;
    let mut star_caps: Option<Captures> = if correction && !same_input {
        Some(run_capture(cap_session, spec, &cur, xs_batches, valid_rows)?)
    } else {
        None
    };

    let tune_cfg = {
        let mut c = TuneCfg::from_presets(presets, spec.family);
        if let Some(r) = opts.max_rounds {
            c.max_rounds = r;
        }
        c
    };
    let warm_kind = match (opts.warm_start, spec.family) {
        (WarmStart::SparseGpt, _) | (WarmStart::Auto, FamilyKind::Topt) => Some(BaselineKind::SparseGpt),
        (WarmStart::Wanda, _) | (WarmStart::Auto, FamilyKind::Tllama) => Some(BaselineKind::Wanda),
        (WarmStart::Dense, _) => None,
    };
    // Algorithm axis: build the layer solver once; it is shared (Sync)
    // across the operator-overlap threads below.
    let layer_solver: Option<Box<dyn LayerSolver>> = match method {
        Method::Solver(k) => Some(solver::build(*k, presets)),
        _ => None,
    };
    let solver_name: &str = layer_solver.as_ref().map(|s| s.name()).unwrap_or("");

    // Solve one operator against its (X, X*) pair — pure w.r.t. the layer
    // state, so same-capture-point operators can run concurrently.
    let solve_one = |engine: &dyn SolverEngine, op: &PrunedOp, w: &Tensor, xd: &Tensor, xs: &Tensor| -> Result<SolveOut> {
        #[allow(clippy::disallowed_methods)]
        // fp-lint: allow(clock) — offline prune timing report, never served
        let t_op = Instant::now();
        if w.shape() != [op.m, op.n] {
            bail!("op {} shape {:?} != ({}, {})", op.name, w.shape(), op.m, op.n);
        }
        let em = ErrorModel::build(engine, w, xd, xs)
            .with_context(|| format!("layer {layer} op {}", op.name))?;
        let (w_star, lambda, rounds, iters, history) = match method {
            Method::Dense => unreachable!("dense handled above"),
            Method::Baseline(kind) => {
                (baselines::prune_matrix(*kind, w, &em.a, opts.sparsity)?, 0.0, 0, 0, Vec::new())
            }
            Method::Solver(_) => {
                let w0 = match warm_kind {
                    Some(kind) => baselines::prune_matrix(kind, w, &em.a, opts.sparsity)?,
                    None => w.clone(),
                };
                let ls = layer_solver.as_deref().expect("solver built for Method::Solver");
                let res = tune_lambda(engine, ls, &em, &w0, opts.sparsity, &tune_cfg)?;
                (res.w, res.lambda, res.rounds, res.iters, res.history)
            }
        };
        let error = em.error(engine, &w_star)?;
        let scale = em.c.max(0.0).sqrt();
        Ok(SolveOut {
            w_star,
            lambda,
            rounds,
            iters,
            error,
            scale,
            elapsed: t_op.elapsed(),
            history,
        })
    };

    let mut pruned: Vec<(String, Tensor)> = Vec::new();
    let mut dirty = false; // ops pruned since the last X* capture

    // Group consecutive operators sharing a capture point: q/k/v, o, the
    // MLP in pair/single, the MLP out. Groups preserve the paper's
    // intra-layer order; within a group the solves are independent.
    let all_ops = pruned_ops(spec);
    let mut groups: Vec<Vec<PrunedOp>> = Vec::new();
    for op in all_ops {
        match groups.last_mut() {
            Some(g) if g[0].capture == op.capture => g.push(op),
            _ => groups.push(vec![op]),
        }
    }

    for group in &groups {
        // Re-capture X* when moving to a new capture point after mutations
        // (consecutive groups always differ in capture key).
        if correction && dirty {
            star_caps = Some(run_capture(cap_session, spec, &cur, xs_batches, valid_rows)?);
        }
        let key = group[0].capture.output_index();
        let xd = &dense_caps.acts[key];
        let xs = match (&star_caps, correction) {
            (Some(star), true) => &star.acts[key],
            _ => xd,
        };

        // Overlap only when nothing upstream is already fanned out (the
        // parallel-mode layer workers would otherwise double-subscribe).
        let overlap = matches!(opts.engine, Engine::Native)
            && opts.workers > 1
            && group.len() > 1
            && !par::in_worker();
        let outs: Vec<Result<SolveOut>> = if overlap {
            // Native-engine overlap: one worker per operator, each with its
            // own engine; inner kernels run inline (par nesting guard), so
            // results match the sequential path exactly.
            std::thread::scope(|s| {
                let handles: Vec<_> = group
                    .iter()
                    .map(|op| {
                        let w = &cur[op_index(op.name)];
                        let cfg = presets.fista.clone();
                        let solve_one = &solve_one;
                        // fp-lint: allow(det-spawn) — scoped solver fan-out, joined in order
                        s.spawn(move || {
                            par::enter_worker(|| {
                                let eng = NativeEngine { cfg };
                                solve_one(&eng, op, w, xd, xs)
                            })
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| match h.join() {
                        Ok(r) => r,
                        Err(_) => Err(anyhow::anyhow!("operator solve thread panicked")),
                    })
                    .collect()
            })
        } else {
            group.iter().map(|op| solve_one(engine, op, &cur[op_index(op.name)], xd, xs)).collect()
        };

        for (op, out) in group.iter().zip(outs) {
            let out = out?;
            let scale = out.scale;
            report.ops.push(OpReport {
                layer,
                op: op.name.to_string(),
                error: out.error,
                rel_error: if scale > 0.0 { out.error / scale } else { 0.0 },
                lambda: out.lambda,
                rounds: out.rounds,
                iters: out.iters,
                solver: solver_name.to_string(),
                sparsity: out.w_star.sparsity(),
                elapsed: out.elapsed,
                rounds_detail: out.history,
            });
            cur[op_index(op.name)] = out.w_star.clone();
            pruned.push((op.name.to_string(), out.w_star));
            dirty = true;
        }
    }

    // Final pruned-path capture → the next layer's x* input.
    let final_caps = run_capture(cap_session, spec, &cur, xs_batches, valid_rows)?;
    report.elapsed = t_layer.elapsed();
    Ok(UnitResult { pruned, y_dense: dense_caps.y, y_pruned: final_caps.y, report })
}

/// Capture one layer's activations over all batches: dispatches to the
/// `capture_{model}` artifact (session supplied) or the native forward.
fn run_capture(
    session: Option<&Session>,
    spec: &ModelSpec,
    layer_params: &[Tensor],
    batches: &[Tensor],
    valid_rows: &[usize],
) -> Result<Captures> {
    match session {
        Some(s) => run_capture_artifact(s, spec, layer_params, batches, valid_rows),
        None => run_capture_native(spec, layer_params, batches, valid_rows),
    }
}

/// Run the layer-generic capture artifact over all batches, harvesting
/// X matrices ([n, p], columns = valid calibration tokens) per capture key.
fn run_capture_artifact(
    session: &Session,
    spec: &ModelSpec,
    layer_params: &[Tensor],
    batches: &[Tensor],
    valid_rows: &[usize],
) -> Result<Captures> {
    let name = format!("capture_{}", spec.name());
    let seq = spec.seq;
    let p_total: usize = valid_rows.iter().map(|&v| v * seq).sum();
    let dims = [spec.d, spec.d, spec.d, spec.ffn]; // attn_in, o_in, mlp_in, mlp2_in
    let mut acts: Vec<Tensor> = dims.iter().map(|&n| Tensor::zeros(vec![n, p_total])).collect();
    let mut y = Vec::with_capacity(batches.len());
    let mut col0 = 0usize;
    for (batch, &valid) in batches.iter().zip(valid_rows) {
        let mut args: Vec<Arg<'_>> = Vec::with_capacity(1 + layer_params.len());
        args.push(Arg::T(batch));
        for p in layer_params {
            args.push(Arg::T(p));
        }
        let mut out = session.run(&name, &args)?;
        if out.len() != 5 {
            bail!("capture returned {} outputs", out.len());
        }
        let y_b = out.pop().expect("y");
        for (k, act) in out.into_iter().enumerate() {
            // act: [cb, s, n] — scatter valid rows' tokens into X columns.
            let n = dims[k];
            let x = &mut acts[k];
            let xdata = x.data_mut();
            let adata = act.data();
            for r in 0..valid {
                for t in 0..seq {
                    let col = col0 + r * seq + t;
                    let src = &adata[(r * seq + t) * n..(r * seq + t + 1) * n];
                    for (d_i, &v) in src.iter().enumerate() {
                        xdata[d_i * p_total + col] = v;
                    }
                }
            }
        }
        y.push(y_b);
        col0 += valid * seq;
    }
    Ok(Captures { acts, y })
}

/// Activations captured from one sequence's native layer forward.
struct RowCapture {
    /// Indexed by CaptureKey::output_index(); [s, n_key] operator inputs.
    caps: [Option<Tensor>; 4],
    /// [s, d] layer output.
    y: Tensor,
}

/// Native capture: run the rust layer forward per valid sequence with a
/// capturing `linop`, in parallel across sequences, then scatter the
/// captured inputs into the same [n, p] column layout the artifact path
/// produces.
fn run_capture_native(
    spec: &ModelSpec,
    layer_params: &[Tensor],
    batches: &[Tensor],
    valid_rows: &[usize],
) -> Result<Captures> {
    let specs = layer_param_specs(spec, None);
    if layer_params.len() != specs.len() {
        bail!("native capture: {} layer params, spec has {}", layer_params.len(), specs.len());
    }
    let map: BTreeMap<&str, &Tensor> =
        specs.iter().zip(layer_params).map(|(s, t)| (s.name.as_str(), t)).collect();

    let (seq, d) = (spec.seq, spec.d);
    let p_total: usize = valid_rows.iter().map(|&v| v * seq).sum();
    let dims = [spec.d, spec.d, spec.d, spec.ffn];
    let mut acts: Vec<Tensor> = dims.iter().map(|&n| Tensor::zeros(vec![n, p_total])).collect();
    let mut y = Vec::with_capacity(batches.len());
    let mut col0 = 0usize;
    for (batch, &valid) in batches.iter().zip(valid_rows) {
        if batch.shape().len() != 3 || batch.shape()[1] != seq || batch.shape()[2] != d {
            bail!("native capture: batch shape {:?} != [cb, {seq}, {d}]", batch.shape());
        }
        let cb = batch.shape()[0];
        if valid > cb {
            bail!("native capture: {valid} valid rows in a batch of {cb}");
        }
        let bdata = batch.data();
        let mut rows: Vec<Option<RowCapture>> = (0..valid).map(|_| None).collect();
        par::for_each_row_block(&mut rows, valid, 1, 1, |r0, _r1, slots| {
            for (i, slot) in slots.iter_mut().enumerate() {
                let r = r0 + i;
                let x = Tensor::from_vec(
                    vec![seq, d],
                    bdata[r * seq * d..(r + 1) * seq * d].to_vec(),
                );
                *slot = Some(capture_row(spec, &map, &x));
            }
        });
        let mut y_b = Tensor::zeros(vec![cb, seq, d]);
        for (r, slot) in rows.into_iter().enumerate() {
            let rc = slot.expect("row capture filled");
            for (k, cap) in rc.caps.iter().enumerate() {
                let cap = cap.as_ref().expect("capture key visited by layer forward");
                let n = dims[k];
                debug_assert_eq!(cap.shape(), [seq, n]);
                let xdata = acts[k].data_mut();
                let cdata = cap.data();
                for t in 0..seq {
                    let col = col0 + r * seq + t;
                    for d_i in 0..n {
                        xdata[d_i * p_total + col] = cdata[t * n + d_i];
                    }
                }
            }
            y_b.data_mut()[r * seq * d..(r + 1) * seq * d].copy_from_slice(rc.y.data());
        }
        y.push(y_b);
        col0 += valid * seq;
    }
    Ok(Captures { acts, y })
}

/// One sequence through the layer, capturing the four operator inputs.
fn capture_row(spec: &ModelSpec, map: &BTreeMap<&str, &Tensor>, x: &Tensor) -> RowCapture {
    let mut caps: [Option<Tensor>; 4] = [None, None, None, None];
    let y = layer_forward_mapped(spec, map, x, |name, w, input| {
        let key = match name {
            "wq" => Some(CaptureKey::AttnIn), // shared by wk/wv
            "wo" => Some(CaptureKey::OIn),
            "w1" | "wg" => Some(CaptureKey::MlpIn), // wu shares wg's input
            "w2" | "wd" => Some(CaptureKey::Mlp2In),
            _ => None,
        };
        if let Some(k) = key {
            caps[k.output_index()] = Some(input.clone());
        }
        ops::matmul_nt(input, w.expect("capture map holds every dense layer param"))
    });
    RowCapture { caps, y }
}

#[cfg(test)]
mod tests {
    // prune_unit is exercised end-to-end in rust/tests/ (pipeline +
    // scheduler-parity tests); unit tests here cover the capture scatter
    // logic (native and, when artifacts exist, artifact vs native parity).
    use super::*;
    use crate::config::repo_root;
    use crate::model::init::init_params;

    fn setup(model: &str) -> (Presets, ModelSpec, crate::model::ModelParams, Vec<Tensor>, Vec<usize>) {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model(model).unwrap().clone();
        let params = init_params(&spec, 5);
        let windows: Vec<Vec<i32>> = (0..4).map(|i| vec![(i * 7 % 96) as i32; spec.seq]).collect();
        let (batches, valids) =
            crate::model::embed::embed_windows(&spec, &params, &windows, presets.capture_batch)
                .unwrap();
        (presets, spec, params, batches, valids)
    }

    #[test]
    fn dense_unit_roundtrip_produces_consistent_outputs_native() {
        for model in ["topt-s1", "tllama-s1"] {
            let (presets, spec, params, batches, valids) = setup(model);
            let layer_tensors: Vec<Tensor> =
                params.layer_tensors(&spec, 0).into_iter().cloned().collect();
            let opts = PruneOptions { engine: Engine::Native, ..Default::default() };
            let res = prune_unit(
                None, &presets, &spec, &Method::Dense, &opts, 0, &layer_tensors, &batches,
                &batches, &valids,
            )
            .unwrap();
            assert!(res.pruned.is_empty());
            assert_eq!(res.y_dense.len(), res.y_pruned.len());
            for (a, b) in res.y_dense.iter().zip(&res.y_pruned) {
                assert_eq!(a.shape(), b.shape());
                assert!(ops::frob_dist(a, b) < 1e-5, "{model}");
            }
        }
    }

    #[test]
    fn native_capture_matches_native_forward() {
        // The captured AttnIn of layer 0 must equal the layer's normed
        // input; y must equal layer_forward on each valid row.
        let (_presets, spec, params, batches, valids) = setup("topt-s1");
        let layer_tensors: Vec<Tensor> =
            params.layer_tensors(&spec, 0).into_iter().cloned().collect();
        let caps = run_capture_native(&spec, &layer_tensors, &batches, &valids).unwrap();
        let p_total: usize = valids.iter().map(|&v| v * spec.seq).sum();
        assert_eq!(caps.acts[0].shape(), &[spec.d, p_total]);
        assert_eq!(caps.acts[3].shape(), &[spec.ffn, p_total]);
        let (seq, d) = (spec.seq, spec.d);
        let x0 = Tensor::from_vec(vec![seq, d], batches[0].data()[..seq * d].to_vec());
        let y0 = crate::model::forward::layer_forward(&spec, &params, 0, &x0, |_n, w, inp| {
            ops::matmul_nt(inp, w)
        });
        let got = &caps.y[0].data()[..seq * d];
        for (a, b) in got.iter().zip(y0.data()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn artifact_capture_matches_native_capture() {
        let Some(session) = crate::testing::try_session() else { return };
        let (_presets, spec, params, batches, valids) = setup("topt-s1");
        let layer_tensors: Vec<Tensor> =
            params.layer_tensors(&spec, 0).into_iter().cloned().collect();
        let art = run_capture_artifact(&session, &spec, &layer_tensors, &batches, &valids).unwrap();
        let nat = run_capture_native(&spec, &layer_tensors, &batches, &valids).unwrap();
        for k in 0..4 {
            let rel = ops::frob_dist(&art.acts[k], &nat.acts[k])
                / nat.acts[k].frob_norm().max(1.0);
            assert!(rel < 5e-3, "capture key {k}: rel {rel}");
        }
    }
}

//! The pruning unit: one decoder layer, pruned operator-by-operator in
//! topological order with intra-layer error correction (paper §3.1, Fig. 2).
//!
//! For each operator W the unit needs two activation matrices:
//!   X  — the operator input on the *dense* path (the target WX), and
//!   X* — the input on the *pruned* path (what W* will actually see).
//! X comes from one capture of the layer under dense weights; X* is
//! re-captured under the current partially-pruned weights whenever the
//! next operator reads a capture point downstream of a pruned operator.
//! With error correction disabled (the Fig. 4a ablation) X* ≡ X and both
//! come from a single capture — exactly eq. (1) instead of eq. (2).

use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::baselines::{self, BaselineKind};
use crate::config::{Engine, FamilyKind, ModelSpec, Presets, PruneOptions, WarmStart};
use crate::model::ops::{pruned_ops, CaptureKey};
use crate::runtime::session::{Arg, Session};
use crate::tensor::Tensor;

use super::engine::{NativeEngine, SolverEngine, XlaEngine};
use super::lambda::{tune_lambda, TuneCfg};
use super::objective::ErrorModel;
use super::report::{LayerReport, OpReport};
use super::scheduler::Method;

/// Result of pruning one layer.
pub struct UnitResult {
    /// (bare op name, pruned weight) for every pruned operator.
    pub pruned: Vec<(String, Tensor)>,
    /// Layer outputs under dense weights (input to the next dense layer).
    pub y_dense: Vec<Tensor>,
    /// Layer outputs under pruned weights (input to the next pruned layer).
    pub y_pruned: Vec<Tensor>,
    pub report: LayerReport,
}

/// Captured activations of one layer: X matrices per capture key + y.
struct Captures {
    /// Indexed by CaptureKey::output_index(): [n_key, p] matrices.
    acts: Vec<Tensor>,
    /// Per-batch [cb, s, d] layer outputs.
    y: Vec<Tensor>,
}

/// Prune one decoder layer.
///
/// `layer_params` must be in capture-artifact order (layer_param_specs);
/// `xd/xs_batches` are [cb, s, d] layer inputs on the dense/pruned paths;
/// `valid_rows[i]` is the number of real (unpadded) rows in batch i.
#[allow(clippy::too_many_arguments)]
pub fn prune_unit(
    session: &Session,
    presets: &Presets,
    spec: &ModelSpec,
    method: &Method,
    opts: &PruneOptions,
    layer: usize,
    layer_params: &[Tensor],
    xd_batches: &[Tensor],
    xs_batches: &[Tensor],
    valid_rows: &[usize],
) -> Result<UnitResult> {
    let t_layer = Instant::now();
    let native;
    let xla;
    let engine: &dyn SolverEngine = match opts.engine {
        Engine::Xla => {
            xla = XlaEngine::new(session);
            &xla
        }
        Engine::Native => {
            native = NativeEngine { cfg: presets.fista.clone() };
            &native
        }
    };

    let mut cur: Vec<Tensor> = layer_params.to_vec();
    let param_names: Vec<String> = crate::model::spec::layer_param_specs(spec, None)
        .iter()
        .map(|s| s.name.clone())
        .collect();
    let op_index = |name: &str| -> usize {
        param_names.iter().position(|n| n == name).expect("op in layer params")
    };

    // One dense capture: targets WX (and the dense-path layer output).
    let dense_caps = run_capture(session, spec, layer_params, xd_batches, valid_rows)?;
    // Correction on: X* starts as the pruned-path capture under the still-
    // dense current layer. Correction off: X* = X (single capture, eq. 1).
    let correction = opts.error_correction && !matches!(method, Method::Dense);
    let mut star_caps = if correction {
        run_capture(session, spec, &cur, xs_batches, valid_rows)?
    } else {
        run_capture(session, spec, layer_params, xs_batches, valid_rows)?
    };

    let tune_cfg = {
        let mut c = TuneCfg::from_presets(presets, spec.family);
        if let Some(r) = opts.max_rounds {
            c.max_rounds = r;
        }
        c
    };
    let warm_kind = match (opts.warm_start, spec.family) {
        (WarmStart::SparseGpt, _) | (WarmStart::Auto, FamilyKind::Topt) => Some(BaselineKind::SparseGpt),
        (WarmStart::Wanda, _) | (WarmStart::Auto, FamilyKind::Tllama) => Some(BaselineKind::Wanda),
        (WarmStart::Dense, _) => None,
    };

    let mut report = LayerReport { layer, ..Default::default() };
    let mut pruned: Vec<(String, Tensor)> = Vec::new();
    let mut dirty = false; // ops pruned since the last X* capture
    let mut last_key = CaptureKey::AttnIn;

    if !matches!(method, Method::Dense) {
        for op in pruned_ops(spec) {
            let t_op = Instant::now();
            // Re-capture X* when moving to a new capture point after mutations.
            if correction && dirty && op.capture != last_key {
                // (dirty stays true: the next op prunes again regardless)
                star_caps = run_capture(session, spec, &cur, xs_batches, valid_rows)?;
            }
            last_key = op.capture;

            let w = &cur[op_index(op.name)];
            if w.shape() != [op.m, op.n] {
                bail!("op {} shape {:?} != ({}, {})", op.name, w.shape(), op.m, op.n);
            }
            let xd = &dense_caps.acts[op.capture.output_index()];
            let xs = if correction { &star_caps.acts[op.capture.output_index()] } else { xd };
            let em = ErrorModel::build(engine, w, xd, xs)
                .with_context(|| format!("layer {layer} op {}", op.name))?;

            let (w_star, lambda, rounds, fista_iters) = match method {
                Method::Dense => unreachable!(),
                Method::Baseline(kind) => {
                    (baselines::prune_matrix(*kind, w, &em.a, opts.sparsity)?, 0.0, 0, 0)
                }
                Method::Fista => {
                    let w0 = match warm_kind {
                        Some(kind) => baselines::prune_matrix(kind, w, &em.a, opts.sparsity)?,
                        None => w.clone(),
                    };
                    let res = tune_lambda(engine, &em, &w0, opts.sparsity, &tune_cfg)?;
                    (res.w, res.lambda, res.rounds, res.fista_iters)
                }
            };

            let error = em.error(engine, &w_star)?;
            let scale = em.c.max(0.0).sqrt();
            report.ops.push(OpReport {
                layer,
                op: op.name.to_string(),
                error,
                rel_error: if scale > 0.0 { error / scale } else { 0.0 },
                lambda,
                rounds,
                fista_iters,
                sparsity: w_star.sparsity(),
                elapsed: t_op.elapsed(),
            });
            cur[op_index(op.name)] = w_star.clone();
            pruned.push((op.name.to_string(), w_star));
            dirty = true;
        }
    }

    // Final pruned-path capture → the next layer's x* input.
    let final_caps = run_capture(session, spec, &cur, xs_batches, valid_rows)?;
    report.elapsed = t_layer.elapsed();
    Ok(UnitResult { pruned, y_dense: dense_caps.y, y_pruned: final_caps.y, report })
}

/// Run the layer-generic capture artifact over all batches, harvesting
/// X matrices ([n, p], columns = valid calibration tokens) per capture key.
fn run_capture(
    session: &Session,
    spec: &ModelSpec,
    layer_params: &[Tensor],
    batches: &[Tensor],
    valid_rows: &[usize],
) -> Result<Captures> {
    let name = format!("capture_{}", spec.name());
    let seq = spec.seq;
    let p_total: usize = valid_rows.iter().map(|&v| v * seq).sum();
    let dims = [spec.d, spec.d, spec.d, spec.ffn]; // attn_in, o_in, mlp_in, mlp2_in
    let mut acts: Vec<Tensor> = dims.iter().map(|&n| Tensor::zeros(vec![n, p_total])).collect();
    let mut y = Vec::with_capacity(batches.len());
    let mut col0 = 0usize;
    for (batch, &valid) in batches.iter().zip(valid_rows) {
        let mut args: Vec<Arg<'_>> = Vec::with_capacity(1 + layer_params.len());
        args.push(Arg::T(batch));
        for p in layer_params {
            args.push(Arg::T(p));
        }
        let mut out = session.run(&name, &args)?;
        if out.len() != 5 {
            bail!("capture returned {} outputs", out.len());
        }
        let y_b = out.pop().expect("y");
        for (k, act) in out.into_iter().enumerate() {
            // act: [cb, s, n] — scatter valid rows' tokens into X columns.
            let n = dims[k];
            let x = &mut acts[k];
            let xdata = x.data_mut();
            let adata = act.data();
            for r in 0..valid {
                for t in 0..seq {
                    let col = col0 + r * seq + t;
                    let src = &adata[(r * seq + t) * n..(r * seq + t + 1) * n];
                    for (d_i, &v) in src.iter().enumerate() {
                        xdata[d_i * p_total + col] = v;
                    }
                }
            }
        }
        y.push(y_b);
        col0 += valid * seq;
    }
    Ok(Captures { acts, y })
}

#[cfg(test)]
mod tests {
    // prune_unit is exercised end-to-end in rust/tests/ (pipeline tests);
    // unit tests here cover the capture scatter logic via a dense run.
    use super::*;
    use crate::config::repo_root;
    use crate::model::init::init_params;
    use crate::runtime::Manifest;
    use std::sync::Arc;

    #[test]
    fn dense_unit_roundtrip_produces_consistent_outputs() {
        let root = repo_root().unwrap();
        let presets = Presets::load(&root).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 5);
        let session = Session::new(Arc::new(Manifest::load_default().unwrap())).unwrap();
        let windows: Vec<Vec<i32>> = (0..4).map(|i| vec![(i * 7 % 96) as i32; spec.seq]).collect();
        let (batches, valids) =
            crate::model::embed::embed_windows(spec, &params, &windows, presets.capture_batch).unwrap();
        let layer_tensors: Vec<Tensor> =
            params.layer_tensors(spec, 0).into_iter().cloned().collect();
        let opts = PruneOptions::default();
        let res = prune_unit(
            &session, &presets, spec, &Method::Dense, &opts, 0, &layer_tensors, &batches, &batches,
            &valids,
        )
        .unwrap();
        assert!(res.pruned.is_empty());
        assert_eq!(res.y_dense.len(), res.y_pruned.len());
        // dense and "pruned" paths are identical when nothing was pruned
        for (a, b) in res.y_dense.iter().zip(&res.y_pruned) {
            assert_eq!(a.shape(), b.shape());
            assert!(crate::tensor::ops::frob_dist(a, b) < 1e-5);
        }
    }
}

//! Small shared utilities: deterministic RNG, logging, timing, progress.
//!
//! The offline image vendors only the `xla` crate's dependency closure, so
//! these are hand-built substrates for `rand`, `env_logger` etc. (see
//! DESIGN.md §2).

pub mod logging;
pub mod progress;
pub mod rng;
pub mod timer;

pub use rng::Pcg64;
pub use timer::Stopwatch;

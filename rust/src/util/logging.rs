//! Minimal leveled logger (env_logger substrate; offline image has no
//! logging crates). Controlled by `FISTAPRUNER_LOG` = error|warn|info|debug.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static MAX_LEVEL: AtomicU8 = AtomicU8::new(2); // Info by default
static INIT: std::sync::Once = std::sync::Once::new();

/// Initialize the level from `FISTAPRUNER_LOG` (idempotent).
pub fn init() {
    INIT.call_once(|| {
        if let Ok(v) = std::env::var("FISTAPRUNER_LOG") {
            let lvl = match v.to_ascii_lowercase().as_str() {
                "error" => Level::Error,
                "warn" => Level::Warn,
                "info" => Level::Info,
                "debug" => Level::Debug,
                _ => Level::Info,
            };
            MAX_LEVEL.store(lvl as u8, Ordering::Relaxed);
        }
    });
}

pub fn set_level(lvl: Level) {
    MAX_LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(lvl: Level) -> bool {
    init();
    (lvl as u8) <= MAX_LEVEL.load(Ordering::Relaxed)
}

pub fn log(lvl: Level, module: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(lvl) {
        return;
    }
    // wall-clock timestamps are presentation only, never fed back into logic
    #[allow(clippy::disallowed_methods)]
    let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let tag = match lvl {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{:>10}.{:03} {tag} {module}] {msg}", t.as_secs(), t.subsec_millis());
}

#[macro_export]
macro_rules! log_error { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_warn { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_info { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, module_path!(), format_args!($($a)*)) } }
#[macro_export]
macro_rules! log_debug { ($($a:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, module_path!(), format_args!($($a)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_ordering() {
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn enabled_respects_level() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Debug));
        set_level(Level::Info);
    }
}

//! PCG64 (XSL-RR 128/64) pseudo-random generator.
//!
//! Deterministic across platforms, seedable per experiment (the paper's
//! §4.4 seed-sensitivity study varies only the calibration-sampling seed).

/// Permuted congruential generator, 128-bit state / 64-bit output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seeded generator; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64) | 0xda3e_39cb_94b9_5bdb) | 1;
        let mut rng = Pcg64 { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience constructor on the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire).
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (bound.wrapping_neg() % bound) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.next_f64();
            if u > 1e-300 {
                let v = self.next_f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Vector of N(0, std²) f32 samples.
    pub fn normal_vec(&mut self, len: usize, std: f32) -> Vec<f32> {
        (0..len).map(|_| (self.normal() as f32) * std).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample from an unnormalized discrete distribution.
    pub fn sample_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut r = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<u64> = {
            let mut r = Pcg64::seeded(7);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Pcg64::seeded(7);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::seeded(1);
        let mut b = Pcg64::seeded(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg64::new(1, 0);
        let mut b = Pcg64::new(1, 1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg64::seeded(3);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Pcg64::seeded(5);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn below_hits_all_residues() {
        let mut r = Pcg64::seeded(11);
        let mut seen = [false; 5];
        for _ in 0..1_000 {
            seen[r.below(5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seeded(9);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg64::seeded(13);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<u32>>());
    }
}

//! One-line progress reporting for long-running commands (train, prune).

use std::io::Write;
use std::time::Instant;

/// Prints `label i/total (rate/s, eta)` on a single updating line.
pub struct Progress {
    label: String,
    total: usize,
    done: usize,
    start: Instant,
    enabled: bool,
}

impl Progress {
    #[allow(clippy::disallowed_methods)] // terminal progress display only
    pub fn new(label: &str, total: usize) -> Self {
        Progress {
            label: label.to_string(),
            total,
            done: 0,
            start: Instant::now(),
            enabled: std::env::var("FISTAPRUNER_NO_PROGRESS").is_err(),
        }
    }

    pub fn inc(&mut self) {
        self.step(self.done + 1);
    }

    pub fn step(&mut self, done: usize) {
        self.done = done;
        if !self.enabled {
            return;
        }
        let el = self.start.elapsed().as_secs_f64();
        let rate = if el > 0.0 { self.done as f64 / el } else { 0.0 };
        let eta = if rate > 0.0 { (self.total.saturating_sub(self.done)) as f64 / rate } else { 0.0 };
        let mut out = std::io::stderr().lock();
        let _ = write!(
            out,
            "\r{} {}/{} ({:.1}/s, eta {:.0}s)   ",
            self.label, self.done, self.total, rate, eta
        );
        let _ = out.flush();
    }

    pub fn finish(&mut self) {
        if self.enabled {
            let mut out = std::io::stderr().lock();
            let _ = writeln!(
                out,
                "\r{} {}/{} done in {:.1}s          ",
                self.label,
                self.done,
                self.total,
                self.start.elapsed().as_secs_f64()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn progress_counts() {
        std::env::set_var("FISTAPRUNER_NO_PROGRESS", "1");
        let mut p = Progress::new("test", 3);
        p.inc();
        p.inc();
        assert_eq!(p.done, 2);
        p.finish();
    }
}

//! Wall-clock timing helpers for the benches and EXPERIMENTS.md §Perf.
//!
//! Raw `Instant::now` is sanctioned here: these helpers measure offline
//! bench wall time and never feed serving logic (which must run on the
//! injectable `obs::Clock` — see the clippy `disallowed-methods` mirror
//! of fp-lint's `clock` rule).
#![allow(clippy::disallowed_methods)]

use std::time::{Duration, Instant};

/// A simple stopwatch that accumulates named phases.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
    last: Instant,
    phases: Vec<(String, Duration)>,
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

impl Stopwatch {
    pub fn new() -> Self {
        let now = Instant::now();
        Stopwatch { start: now, last: now, phases: Vec::new() }
    }

    /// Record the time since the previous lap under `name`.
    pub fn lap(&mut self, name: &str) -> Duration {
        let now = Instant::now();
        let d = now - self.last;
        self.last = now;
        self.phases.push((name.to_string(), d));
        d
    }

    pub fn total(&self) -> Duration {
        self.last - self.start
    }

    pub fn phases(&self) -> &[(String, Duration)] {
        &self.phases
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for (name, d) in &self.phases {
            s.push_str(&format!("{name}: {:.3}s  ", d.as_secs_f64()));
        }
        s.push_str(&format!("total: {:.3}s", self.total().as_secs_f64()));
        s
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Robust repeated measurement: runs `f` `reps` times, returns seconds per
/// rep (median). Used by the custom bench harness (criterion substrate).
pub fn measure(reps: usize, mut f: impl FnMut()) -> f64 {
    assert!(reps > 0);
    let mut times: Vec<f64> = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn laps_accumulate() {
        let mut sw = Stopwatch::new();
        std::thread::sleep(Duration::from_millis(2));
        sw.lap("a");
        std::thread::sleep(Duration::from_millis(2));
        sw.lap("b");
        assert_eq!(sw.phases().len(), 2);
        assert!(sw.total() >= Duration::from_millis(4));
        assert!(sw.report().contains("a:"));
    }

    #[test]
    fn measure_returns_positive() {
        let t = measure(3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t >= 0.0);
    }
}

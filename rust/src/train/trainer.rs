//! Trainer: drives the `train_{model}` artifact (AdamW causal-LM step
//! with warmup-cosine learning rate) to produce the real trained models
//! the pruning experiments operate on. Checkpoints cache under
//! artifacts/checkpoints/ so benches re-use trained models.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::{ModelSpec, Presets, TrainOptions};
use crate::data::{batches::train_batch, Corpus};
use crate::model::init::init_params;
use crate::model::params::ModelParams;
use crate::runtime::session::{Arg, Session};
use crate::ser::checkpoint::{self, CheckpointMeta};
use crate::tensor::Tensor;
use crate::util::{progress::Progress, Pcg64};

/// Outcome of a training run.
pub struct TrainResult {
    pub params: ModelParams,
    pub losses: Vec<f64>,
    pub final_loss: f64,
}

/// Warmup-then-cosine learning rate (a standard LM schedule).
pub fn lr_at(step: usize, opts: &TrainOptions) -> f64 {
    let s = step as f64;
    if step < opts.warmup {
        return opts.lr * (s + 1.0) / opts.warmup as f64;
    }
    let total = (opts.steps.max(opts.warmup + 1) - opts.warmup) as f64;
    let t = ((s - opts.warmup as f64) / total).clamp(0.0, 1.0);
    opts.lr * (0.5 * (1.0 + (std::f64::consts::PI * t).cos())).max(0.02)
}

/// Train from scratch on the corpus train split.
pub fn train(
    session: &Session,
    presets: &Presets,
    spec: &ModelSpec,
    corpus: &Corpus,
    opts: &TrainOptions,
) -> Result<TrainResult> {
    let name = format!("train_{}", spec.name());
    let tb = presets.train_batch;
    let seq = spec.seq;
    let mut params = init_params(spec, opts.seed);
    let n = params.tensors().len();
    let mut m: Vec<Tensor> =
        params.specs().iter().map(|s| Tensor::zeros(s.shape.clone())).collect();
    let mut v: Vec<Tensor> =
        params.specs().iter().map(|s| Tensor::zeros(s.shape.clone())).collect();
    let mut rng = Pcg64::new(opts.seed, 41);
    let train_tokens = corpus.train_slice();
    if train_tokens.len() < (seq + 1) * tb {
        bail!("corpus '{}' too small to train on", corpus.name);
    }

    let tok_dims = [tb, seq + 1];
    let mut losses = Vec::with_capacity(opts.steps);
    let mut prog = Progress::new(&format!("train {}", spec.name()), opts.steps);
    for step in 0..opts.steps {
        let batch = train_batch(train_tokens, tb, seq, &mut rng);
        let lr = lr_at(step, opts);
        let t_in = (step + 1) as f32; // Adam bias-correction time index
        let mut args: Vec<Arg<'_>> = Vec::with_capacity(3 * n + 3);
        for t in params.tensors() {
            args.push(Arg::T(t));
        }
        for t in &m {
            args.push(Arg::T(t));
        }
        for t in &v {
            args.push(Arg::T(t));
        }
        args.push(Arg::Scalar(t_in));
        args.push(Arg::Scalar(lr as f32));
        args.push(Arg::I32(&batch, &tok_dims));
        let mut out = session.run(&name, &args).with_context(|| format!("train step {step}"))?;
        if out.len() != 3 * n + 1 {
            bail!("train artifact returned {} outputs, expected {}", out.len(), 3 * n + 1);
        }
        let loss = out.pop().expect("loss").first() as f64;
        if !loss.is_finite() {
            bail!("training diverged at step {step} (loss = {loss})");
        }
        v = out.split_off(2 * n);
        m = out.split_off(n);
        params.replace_all(out)?;
        losses.push(loss);
        prog.step(step + 1);
    }
    prog.finish();
    let tail = &losses[losses.len().saturating_sub(20)..];
    let final_loss = crate::metrics::mean(tail);
    Ok(TrainResult { params, losses, final_loss })
}

/// Train-or-load: returns a cached checkpoint when one exists for this
/// (model, corpus, steps, seed) tuple. The session is only needed when a
/// fresh training run is required — cached checkpoints load without one,
/// so artifact-free builds can still consume previously trained models.
pub fn ensure_checkpoint(
    root: &Path,
    session: Option<&Session>,
    presets: &Presets,
    spec: &ModelSpec,
    corpus: &Corpus,
    opts: &TrainOptions,
) -> Result<ModelParams> {
    let path = checkpoint::default_path(
        &crate::config::paths::out_dir(root),
        &spec.name(),
        &corpus.name,
        opts.steps,
        opts.seed,
    );
    if checkpoint::exists(&path) {
        let (params, meta) = checkpoint::load(&path)?;
        checkpoint::check_model(&meta, &spec.name())?;
        crate::log_info!("loaded checkpoint {} (loss {:.3})", path.display(), meta.final_loss);
        return Ok(params);
    }
    let Some(session) = session else {
        bail!(
            "no cached checkpoint at {} and no PJRT session to train one \
             (training runs the `train_{}` artifact)",
            path.display(),
            spec.name()
        )
    };
    crate::log_info!("training {} on {} for {} steps", spec.name(), corpus.name, opts.steps);
    let res = train(session, presets, spec, corpus, opts)?;
    checkpoint::save(
        &path,
        &res.params,
        &CheckpointMeta {
            model: spec.name(),
            corpus: corpus.name.clone(),
            steps: opts.steps,
            final_loss: res.final_loss,
            seed: opts.seed,
        },
    )?;
    Ok(res.params)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::repo_root;

    #[test]
    fn lr_schedule_shape() {
        let opts = TrainOptions { steps: 100, lr: 1e-3, warmup: 10, seed: 0 };
        assert!(lr_at(0, &opts) < lr_at(5, &opts));
        assert!((lr_at(9, &opts) - 1e-3).abs() < 1e-4);
        assert!(lr_at(50, &opts) < lr_at(10, &opts));
        assert!(lr_at(99, &opts) > 0.0);
    }

    #[test]
    fn loss_decreases_over_short_run() {
        let Some(session) = crate::testing::try_session() else { return };
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let corpus = Corpus::generate(presets.corpus("ptb-syn").unwrap());
        let opts = TrainOptions { steps: 30, lr: 1e-3, warmup: 5, seed: 7 };
        let res = train(&session, &presets, spec, &corpus, &opts).unwrap();
        let first = crate::metrics::mean(&res.losses[..5]);
        let last = crate::metrics::mean(&res.losses[res.losses.len() - 5..]);
        assert!(last < first - 0.1, "loss should drop: first {first:.3} last {last:.3}");
    }
}

//! Training substrate: drives the AOT `train_{model}` artifact (AdamW
//! causal-LM step) from rust to produce the real trained models the
//! pruning experiments operate on.

pub mod trainer;

pub use trainer::{ensure_checkpoint, train, TrainResult};

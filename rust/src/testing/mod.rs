//! Mini property-based testing framework (proptest substrate).
//!
//! `check` runs a property over `cases` random inputs drawn from a
//! generator; on failure it retries with a simple halving shrink of the
//! failing seed's size parameter and reports the smallest reproduction.

use crate::util::Pcg64;

/// Load the default artifact manifest, or `None` (with a note on stderr)
/// when `artifacts/manifest.json` is absent. Artifact-dependent tests use
/// this to skip gracefully on a clean checkout, where only the native
/// kernel path is available.
pub fn try_manifest() -> Option<crate::runtime::Manifest> {
    match crate::runtime::Manifest::load_default() {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!("skipping artifact-dependent test: {e:#}");
            None
        }
    }
}

/// Create a PJRT session over the default artifacts, or `None` (with a
/// note on stderr) when the artifacts or the `xla-pjrt` backend are
/// unavailable.
pub fn try_session() -> Option<crate::runtime::Session> {
    let m = try_manifest()?;
    match crate::runtime::Session::new(std::sync::Arc::new(m)) {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping XLA-dependent test: {e:#}");
            None
        }
    }
}

/// Size-parameterized random input generator.
pub struct Gen<'a> {
    pub rng: &'a mut Pcg64,
    /// Size hint in [0, 1]: generators should scale dimensions with it.
    pub size: f64,
}

impl<'a> Gen<'a> {
    /// Integer in [lo, hi], biased toward lo as size shrinks.
    pub fn int(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        let span = ((hi - lo) as f64 * self.size).round() as usize;
        lo + self.rng.below(span as u64 + 1) as usize
    }

    /// Multiple-of-32 dimension in [32, cap] (artifact-friendly shapes).
    pub fn dim32(&mut self, cap: usize) -> usize {
        32 * self.int(1, (cap / 32).max(1))
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.rng.next_f32() * (hi - lo)
    }

    pub fn vec_normal(&mut self, len: usize, std: f32) -> Vec<f32> {
        self.rng.normal_vec(len, std)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }
}

/// Run `prop` over `cases` random inputs. Panics with the failing seed and
/// the smallest failing size found by halving.
pub fn check<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Gen<'_>) -> Result<(), String>,
{
    let base_seed = 0xF15A_u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut failure: Option<(f64, String)> = None;
        {
            let mut rng = Pcg64::new(seed, 77);
            let mut g = Gen { rng: &mut rng, size: 1.0 };
            if let Err(msg) = prop(&mut g) {
                failure = Some((1.0, msg));
            }
        }
        if let Some((_, first_msg)) = failure {
            // Shrink: replay the same seed at smaller sizes.
            let mut smallest = (1.0, first_msg);
            let mut size = 0.5;
            while size > 0.05 {
                let mut rng = Pcg64::new(seed, 77);
                let mut g = Gen { rng: &mut rng, size };
                if let Err(msg) = prop(&mut g) {
                    smallest = (size, msg);
                    size /= 2.0;
                } else {
                    break;
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, smallest size {:.2}): {}",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assert two f32 slices are close (atol + rtol), reporting the worst index.
pub fn assert_allclose(a: &[f32], b: &[f32], atol: f64, rtol: f64) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length {} vs {}", a.len(), b.len()));
    }
    let mut worst = (0usize, 0.0f64);
    for (i, (&x, &y)) in a.iter().zip(b).enumerate() {
        let err = (x as f64 - y as f64).abs();
        let bound = atol + rtol * (y as f64).abs();
        if err > bound && err > worst.1 {
            worst = (i, err);
        }
    }
    if worst.1 > 0.0 {
        return Err(format!(
            "allclose failed at index {} ({} vs {}), err {:.3e}",
            worst.0, a[worst.0], b[worst.0], worst.1
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs() {
        check("commutative add", 50, |g| {
            let x = g.f32_in(-10.0, 10.0);
            let y = g.f32_in(-10.0, 10.0);
            if (x + y - (y + x)).abs() < 1e-6 {
                Ok(())
            } else {
                Err("add not commutative".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_seed() {
        check("always fails", 3, |_g| Err("nope".into()));
    }

    #[test]
    fn allclose_reports_worst() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 0.0).is_ok());
        let e = assert_allclose(&[1.0, 3.0], &[1.0, 2.0], 1e-6, 0.0).unwrap_err();
        assert!(e.contains("index 1"));
    }

    #[test]
    fn dim32_is_multiple_of_32() {
        let mut rng = Pcg64::seeded(1);
        let mut g = Gen { rng: &mut rng, size: 1.0 };
        for _ in 0..100 {
            assert_eq!(g.dim32(256) % 32, 0);
        }
    }
}

//! Portable-SIMD (`core::simd`) bodies of the decode-critical kernels
//! (`--features simd`, nightly). Selected at runtime through
//! `par::set_kernel_variant(KernelVariant::Simd)`; the dispatchers live
//! in [`super::kernels`].
//!
//! # Determinism contract
//!
//! Every body here follows the `tensor::par` rules — work splits by
//! contiguous output rows and each element is computed whole, in a fixed
//! order, by exactly one worker — so SIMD results are **bitwise
//! independent of the thread count**, same as the scalar variant.
//!
//! SIMD results are *not* bitwise equal to the scalar oracle: the inner
//! dot products accumulate eight f32 lanes that are reduced once at the
//! end of the row (plus a scalar tail for lengths not divisible by 8),
//! which reorders the floating-point additions. The parity suite
//! (`tests/quant_kernel_parity.rs`) pins the variants together within a
//! relative tolerance of ~1e-5 per element on unit-scale inputs.
//!
//! Sparse gathers (`x[indices[k]]`) are performed scalar into a lane
//! buffer — on current CPUs a hardware gather is microcoded to the same
//! loads, and keeping the portable API surface to `from_array` /
//! `from_slice` / `splat` / `reduce_sum` avoids the churn-prone corners
//! of `core::simd`. Quantized payloads dequantize through
//! [`ValueDecode::load8`] straight into lanes, so quantized weights never
//! round-trip through a dense f32 buffer.

use core::simd::f32x8;
use core::simd::num::SimdFloat;

use super::kernels::{min_rows_for, unscratch};
use super::par;
use super::quant::ValueDecode;
use super::Tensor;

/// Lane count of the working vector type.
pub const LANES: usize = 8;

/// Eight-lane dot product of two equal-length slices: SIMD main loop,
/// scalar tail, one lane reduction. Fixed order — thread-count invariant.
#[inline]
fn dot8(a: &[f32], b: &[f32]) -> f32 {
    let len = a.len().min(b.len());
    let mut acc = f32x8::splat(0.0);
    let mut t = 0usize;
    while t + LANES <= len {
        let av = f32x8::from_slice(&a[t..t + LANES]);
        let bv = f32x8::from_slice(&b[t..t + LANES]);
        acc += av * bv;
        t += LANES;
    }
    let mut sum = acc.reduce_sum();
    while t < len {
        sum += a[t] * b[t];
        t += 1;
    }
    sum
}

/// SIMD body of [`super::kernels::matvec`].
pub fn matvec(a: &Tensor, x: &[f32]) -> Vec<f32> {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(n, x.len());
    let ad = a.data();
    let mut out = vec![0f32; m];
    par::for_each_row_block(&mut out, m, 1, min_rows_for(2 * n), |r0, _r1, block| {
        for (i, o) in block.iter_mut().enumerate() {
            let row = &ad[(r0 + i) * n..(r0 + i + 1) * n];
            *o = dot8(row, x);
        }
    });
    out
}

/// SIMD body of [`super::kernels::matmul_nt_skinny`].
pub fn matmul_nt_skinny(a: &Tensor, b: &Tensor) -> Tensor {
    let (s, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_nt_skinny inner dims: {k} vs {k2}");
    let (ad, bd) = (a.data(), b.data());
    let mut scratch = vec![0f32; n * s];
    par::for_each_row_block(&mut scratch, n, s, min_rows_for(2 * s * k), |j0, j1, block| {
        for j in j0..j1 {
            let brow = &bd[j * k..(j + 1) * k];
            let orow = &mut block[(j - j0) * s..(j - j0 + 1) * s];
            for (t, o) in orow.iter_mut().enumerate() {
                *o = dot8(&ad[t * k..(t + 1) * k], brow);
            }
        }
    });
    unscratch(scratch, n, s)
}

/// One CSR row's accumulation: value lanes via [`ValueDecode::load8`],
/// scalar index gathers into a lane buffer, scalar tail.
#[inline]
fn csr_row_acc<V: ValueDecode>(
    values: &V,
    indices: &[u32],
    a: usize,
    b: usize,
    r: usize,
    x: &[f32],
) -> f32 {
    let mut acc = f32x8::splat(0.0);
    let mut k = a;
    while k + LANES <= b {
        let vals = f32x8::from_array(values.load8(k, r));
        let mut xs = [0f32; LANES];
        for (i, slot) in xs.iter_mut().enumerate() {
            *slot = x[indices[k + i] as usize];
        }
        acc += vals * f32x8::from_array(xs);
        k += LANES;
    }
    let mut sum = acc.reduce_sum();
    while k < b {
        sum += values.get(k, r) * x[indices[k] as usize];
        k += 1;
    }
    sum
}

/// SIMD body of [`super::kernels::csr_matvec`].
pub fn csr_matvec<V: ValueDecode>(
    indptr: &[u32],
    indices: &[u32],
    values: &V,
    rows: usize,
    x: &[f32],
) -> Vec<f32> {
    debug_assert_eq!(indptr.len(), rows + 1, "indptr length");
    let nnz = indptr.last().map(|&e| e as usize).unwrap_or(0);
    let nnz_per_row = nnz / rows.max(1);
    let mut out = vec![0f32; rows];
    let min_rows = min_rows_for(2 * nnz_per_row.max(1));
    par::for_each_row_block(&mut out, rows, 1, min_rows, |r0, _r1, block| {
        for (i, o) in block.iter_mut().enumerate() {
            let r = r0 + i;
            *o = csr_row_acc(values, indices, indptr[r] as usize, indptr[r + 1] as usize, r, x);
        }
    });
    out
}

/// SIMD body of [`super::kernels::csr_matmul_t`].
pub fn csr_matmul_t<V: ValueDecode>(
    indptr: &[u32],
    indices: &[u32],
    values: &V,
    rows: usize,
    cols: usize,
    x: &Tensor,
) -> Tensor {
    let (s, n) = (x.rows(), x.cols());
    assert_eq!(n, cols, "csr_matmul_t inner dims: {n} vs {cols}");
    debug_assert_eq!(indptr.len(), rows + 1, "indptr length");
    let xd = x.data();
    let nnz = indptr.last().map(|&e| e as usize).unwrap_or(0);
    let nnz_per_row = nnz / rows.max(1);
    let mut scratch = vec![0f32; rows * s];
    par::for_each_row_block(
        &mut scratch,
        rows,
        s,
        min_rows_for(2 * s * nnz_per_row.max(1)),
        |r0, r1, block| {
            for r in r0..r1 {
                let (a, b) = (indptr[r] as usize, indptr[r + 1] as usize);
                let orow = &mut block[(r - r0) * s..(r - r0 + 1) * s];
                for (t, o) in orow.iter_mut().enumerate() {
                    let xrow = &xd[t * n..(t + 1) * n];
                    *o = csr_row_acc(values, indices, a, b, r, xrow);
                }
            }
        },
    );
    unscratch(scratch, rows, s)
}

/// One packed-n:m row's accumulation against one dense x row. Walks the
/// row's flat value stream in eight-value chunks; the group of flat slot
/// `k` is `k / n`, so the x gather index is `(k / n) * m + indices[k]`.
#[inline]
fn nm_row_acc<V: ValueDecode>(
    values: &V,
    indices: &[u8],
    row_base: usize,
    span: usize,
    r: usize,
    n: usize,
    m: usize,
    xrow: &[f32],
) -> f32 {
    let mut acc = f32x8::splat(0.0);
    let mut k = 0usize;
    while k + LANES <= span {
        let vals = f32x8::from_array(values.load8(row_base + k, r));
        let mut xs = [0f32; LANES];
        for (i, slot) in xs.iter_mut().enumerate() {
            let kl = k + i;
            *slot = xrow[(kl / n) * m + indices[row_base + kl] as usize];
        }
        acc += vals * f32x8::from_array(xs);
        k += LANES;
    }
    let mut sum = acc.reduce_sum();
    while k < span {
        sum += values.get(row_base + k, r) * xrow[(k / n) * m + indices[row_base + k] as usize];
        k += 1;
    }
    sum
}

/// SIMD body of [`super::kernels::nm_matvec`].
#[allow(clippy::too_many_arguments)]
pub fn nm_matvec<V: ValueDecode>(
    values: &V,
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &[f32],
) -> Vec<f32> {
    let groups = cols / m;
    let span = groups * n;
    debug_assert_eq!(indices.len(), rows * span, "packed n:m geometry");
    debug_assert_eq!(x.len(), cols, "nm_matvec inner dims");
    let mut out = vec![0f32; rows];
    let min_rows = min_rows_for(2 * span);
    par::for_each_row_block(&mut out, rows, 1, min_rows, |r0, _r1, block| {
        for (i, o) in block.iter_mut().enumerate() {
            let r = r0 + i;
            *o = nm_row_acc(values, indices, r * span, span, r, n, m, x);
        }
    });
    out
}

/// SIMD body of [`super::kernels::nm_matmul_t`].
#[allow(clippy::too_many_arguments)]
pub fn nm_matmul_t<V: ValueDecode>(
    values: &V,
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &Tensor,
) -> Tensor {
    let (s, xc) = (x.rows(), x.cols());
    assert_eq!(xc, cols, "nm_matmul_t inner dims: {xc} vs {cols}");
    let groups = cols / m;
    let span = groups * n;
    debug_assert_eq!(indices.len(), rows * span, "packed n:m geometry");
    let xd = x.data();
    let mut scratch = vec![0f32; rows * s];
    par::for_each_row_block(
        &mut scratch,
        rows,
        s,
        min_rows_for(2 * s * span),
        |r0, r1, block| {
            for r in r0..r1 {
                let orow = &mut block[(r - r0) * s..(r - r0 + 1) * s];
                for (t, o) in orow.iter_mut().enumerate() {
                    let xrow = &xd[t * cols..(t + 1) * cols];
                    *o = nm_row_acc(values, indices, r * span, span, r, n, m, xrow);
                }
            }
        },
    );
    unscratch(scratch, rows, s)
}

/// SIMD body of [`super::kernels::nm_matmul`].
#[allow(clippy::too_many_arguments)]
pub fn nm_matmul<V: ValueDecode>(
    values: &V,
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &Tensor,
) -> Tensor {
    let (s, xc) = (x.rows(), x.cols());
    assert_eq!(xc, cols, "nm_matmul inner dims: {xc} vs {cols}");
    let groups = cols / m;
    let span = groups * n;
    debug_assert_eq!(indices.len(), rows * span, "packed n:m geometry");
    let xd = x.data();
    let mut out = Tensor::zeros(vec![s, rows]);
    par::for_each_row_block(
        out.data_mut(),
        s,
        rows,
        min_rows_for(2 * rows * span),
        |t0, t1, block| {
            for t in t0..t1 {
                let xrow = &xd[t * cols..(t + 1) * cols];
                let orow = &mut block[(t - t0) * rows..(t - t0 + 1) * rows];
                for (r, o) in orow.iter_mut().enumerate() {
                    *o = nm_row_acc(values, indices, r * span, span, r, n, m, xrow);
                }
            }
        },
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::kernels;
    use crate::util::Pcg64;

    fn randt(rng: &mut Pcg64, shape: Vec<usize>) -> Tensor {
        let len = shape.iter().product();
        Tensor::from_vec(shape, rng.normal_vec(len, 1.0))
    }

    fn close(a: f32, b: f32) -> bool {
        (a - b).abs() <= 1e-4 * a.abs().max(b.abs()).max(1.0)
    }

    #[test]
    fn simd_dense_bodies_match_scalar_oracle() {
        let mut rng = Pcg64::seeded(61);
        for n in [1usize, 7, 8, 9, 16, 17, 64] {
            let a = randt(&mut rng, vec![13, n]);
            let x: Vec<f32> = rng.normal_vec(n, 1.0);
            let want = kernels::matvec_scalar(&a, &x);
            let got = matvec(&a, &x);
            for (g, w) in got.iter().zip(&want) {
                assert!(close(*g, *w), "n={n}: {g} vs {w}");
            }
            for s in [1usize, 3] {
                let sk = randt(&mut rng, vec![s, n]);
                let want = kernels::matmul_nt_skinny_scalar(&sk, &a);
                let got = matmul_nt_skinny(&sk, &a);
                assert_eq!(got.shape(), want.shape());
                for (g, w) in got.data().iter().zip(want.data()) {
                    assert!(close(*g, *w), "n={n} s={s}: {g} vs {w}");
                }
            }
        }
    }

    #[test]
    fn simd_sparse_bodies_match_scalar_oracle() {
        let mut rng = Pcg64::seeded(62);
        let (rows, cols, s) = (21, 24, 3);
        let mut w = randt(&mut rng, vec![rows, cols]);
        for v in w.data_mut() {
            if *v > 0.3 {
                *v = 0.0;
            }
        }
        let (mut indptr, mut indices, mut values) = (vec![0u32], Vec::new(), Vec::new());
        for i in 0..rows {
            for (j, &v) in w.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        let x = randt(&mut rng, vec![s, cols]);
        let vref: &[f32] = &values;
        let want = kernels::csr_matmul_t_scalar(&indptr, &indices, &values, rows, cols, &x);
        let got = csr_matmul_t(&indptr, &indices, &vref, rows, cols, &x);
        for (g, w) in got.data().iter().zip(want.data()) {
            assert!(close(*g, *w), "{g} vs {w}");
        }
        let ywant = kernels::csr_matvec_scalar(&indptr, &indices, &values, rows, x.row(0));
        let ygot = csr_matvec(&indptr, &indices, &vref, rows, x.row(0));
        for (g, w) in ygot.iter().zip(&ywant) {
            assert!(close(*g, *w), "{g} vs {w}");
        }
    }
}

//! The shared worker abstraction behind every native kernel: deterministic
//! row-block parallelism over scoped threads.
//!
//! Design rules that every kernel in this crate follows:
//!
//! 1. **Work is split by output rows.** Each worker owns a contiguous,
//!    disjoint row range of the output buffer, so no synchronization is
//!    needed beyond the scope join.
//! 2. **Results are independent of the thread count.** Per-row arithmetic
//!    never depends on which worker computes the row, and reductions are
//!    materialized as per-row (or fixed-size-chunk) partials that are then
//!    summed in a fixed order on the calling thread. A run with 1 thread
//!    and a run with 16 threads produce bitwise-identical tensors — this
//!    is what makes the scheduler's worker-count-invariance tests possible
//!    and keeps every experiment reproducible.
//! 3. **No nested fan-out.** When a higher layer (the prune scheduler's
//!    layer workers, or the intra-layer op overlap) already runs inside a
//!    worker, inner kernels execute inline on the current thread. The
//!    thread-local guard below enforces this automatically.
//!
//! The thread count is process-global: 0 (the default) means "use
//! `std::thread::available_parallelism`", 1 forces the deterministic
//! single-thread fallback (which never spawns), and any other value caps
//! the fan-out. It is configured from `PruneOptions::threads`,
//! `FistaCfg::threads`, or the `FP_THREADS` environment variable (read by
//! the bench `Lab`).

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

use crate::config::KernelVariant;

static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Which kernel implementation family the dispatchers in
/// `tensor::kernels` select (0 = scalar, 1 = simd). Process-global like
/// the thread count; every variant honors the determinism rules above, so
/// thread-count invariance holds *per variant* (scalar and SIMD results
/// are value-close, not bitwise equal — SIMD reduces lane partials in a
/// different order; see `tensor::kernels`).
static KERNEL_VARIANT: AtomicUsize = AtomicUsize::new(0);

/// Select the process-global kernel variant. `Simd` is only accepted in
/// builds with the `simd` cargo feature; without it this returns a checked
/// error instead of silently running scalar code under a "simd" label.
pub fn set_kernel_variant(v: KernelVariant) -> anyhow::Result<()> {
    match v {
        KernelVariant::Scalar => {
            KERNEL_VARIANT.store(0, Ordering::Relaxed);
            Ok(())
        }
        #[cfg(feature = "simd")]
        KernelVariant::Simd => {
            KERNEL_VARIANT.store(1, Ordering::Relaxed);
            Ok(())
        }
        #[cfg(not(feature = "simd"))]
        KernelVariant::Simd => anyhow::bail!(
            "kernel variant 'simd' requires a build with --features simd \
             (rebuild, or use --kernel scalar)"
        ),
    }
}

/// The currently selected kernel variant.
pub fn kernel_variant() -> KernelVariant {
    if KERNEL_VARIANT.load(Ordering::Relaxed) == 1 {
        KernelVariant::Simd
    } else {
        KernelVariant::Scalar
    }
}

thread_local! {
    static IN_WORKER: Cell<bool> = Cell::new(false);
}

/// Set the process-global kernel thread count (0 = auto).
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

/// The configured thread count (0 = auto).
pub fn configured_threads() -> usize {
    THREADS.load(Ordering::Relaxed)
}

/// The thread count kernels will actually fan out to right now: 1 inside
/// a worker (no nested parallelism), the configured count otherwise, with
/// 0 resolved against the machine's available parallelism.
pub fn effective_threads() -> usize {
    if in_worker() {
        return 1;
    }
    match THREADS.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    }
}

/// True when the current thread is already a kernel/scheduler worker.
pub fn in_worker() -> bool {
    IN_WORKER.with(|c| c.get())
}

/// Run `f` with the nested-parallelism guard set: any kernel `f` calls
/// executes inline instead of fanning out again.
pub fn enter_worker<R>(f: impl FnOnce() -> R) -> R {
    IN_WORKER.with(|c| c.set(true));
    let out = f();
    IN_WORKER.with(|c| c.set(false));
    out
}

/// How many chunks to split `rows` into, given at least `min_rows` of work
/// per chunk. Returns 1 (run inline) for small problems or when
/// parallelism is disabled/nested.
pub fn plan(rows: usize, min_rows: usize) -> usize {
    if rows == 0 {
        return 1;
    }
    let t = effective_threads();
    if t <= 1 {
        return 1;
    }
    t.min(rows.div_ceil(min_rows.max(1))).max(1)
}

/// Split `out` (a buffer of `rows` rows × `row_stride` elements) into
/// contiguous row blocks and run `f(row_start, row_end, block)` on each,
/// in parallel when worthwhile. `f` must compute rows purely from their
/// global index so results are identical for any split.
pub fn for_each_row_block<T: Send>(
    out: &mut [T],
    rows: usize,
    row_stride: usize,
    min_rows: usize,
    f: impl Fn(usize, usize, &mut [T]) + Sync,
) {
    debug_assert_eq!(out.len(), rows * row_stride, "buffer/row geometry mismatch");
    let nt = plan(rows, min_rows);
    if nt <= 1 {
        f(0, rows, out);
        return;
    }
    let per = rows.div_ceil(nt);
    std::thread::scope(|s| {
        let f = &f;
        let mut rest = out;
        let mut r0 = 0usize;
        while r0 < rows {
            let r1 = (r0 + per).min(rows);
            let (head, tail) = std::mem::take(&mut rest).split_at_mut((r1 - r0) * row_stride);
            rest = tail;
            s.spawn(move || enter_worker(|| f(r0, r1, head)));
            r0 = r1;
        }
    });
}

/// Deterministic parallel reduction: computes `f(row)` for every row into
/// a per-row partial and sums the partials in row order. The sum is
/// independent of the thread count by construction.
pub fn sum_rows(rows: usize, min_rows: usize, f: impl Fn(usize) -> f64 + Sync) -> f64 {
    if rows == 0 {
        return 0.0;
    }
    let mut partials = vec![0f64; rows];
    for_each_row_block(&mut partials, rows, 1, min_rows, |r0, _r1, out| {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(r0 + i);
        }
    });
    // fp-lint: allow(f32-reduce) — f64 partials summed in fixed block order
    partials.iter().sum()
}

/// Elements per virtual row when reducing over a flat buffer; fixed so the
/// partial grouping (and therefore the result) never depends on the
/// thread count.
pub const FLAT_CHUNK: usize = 8192;

/// Deterministic parallel reduction over a flat range `0..len`:
/// `f(start, end)` must return the partial for that element span.
pub fn sum_flat(len: usize, f: impl Fn(usize, usize) -> f64 + Sync) -> f64 {
    let rows = len.div_ceil(FLAT_CHUNK);
    sum_rows(rows, 4, |r| {
        let start = r * FLAT_CHUNK;
        f(start, (start + FLAT_CHUNK).min(len))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    // The thread count is process-global and these tests mutate it, so they
    // serialize among themselves (other tests are thread-count-agnostic by
    // the determinism rule above).
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

    fn locked() -> std::sync::MutexGuard<'static, ()> {
        LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn plan_respects_limits() {
        let _g = locked();
        set_threads(4);
        assert_eq!(plan(0, 8), 1);
        assert_eq!(plan(3, 8), 1); // too little work
        assert!(plan(1024, 8) <= 4);
        set_threads(1);
        assert_eq!(plan(1024, 8), 1);
        set_threads(0);
    }

    #[test]
    fn row_blocks_cover_every_row_once() {
        let _g = locked();
        set_threads(3);
        let rows = 17;
        let mut out = vec![0u32; rows * 2];
        for_each_row_block(&mut out, rows, 2, 1, |r0, r1, block| {
            for (i, pair) in block.chunks_mut(2).enumerate() {
                pair[0] = (r0 + i) as u32;
                pair[1] = (r1 - r0) as u32;
            }
        });
        for r in 0..rows {
            assert_eq!(out[2 * r], r as u32, "row {r} written by wrong block");
            assert!(out[2 * r + 1] > 0);
        }
        set_threads(0);
    }

    #[test]
    fn reductions_are_thread_count_invariant() {
        let _g = locked();
        let f = |r: usize| ((r * 2654435761) % 1000) as f64 * 1e-3;
        set_threads(1);
        let one = sum_rows(1000, 1, f);
        set_threads(7);
        let many = sum_rows(1000, 1, f);
        set_threads(0);
        assert_eq!(one.to_bits(), many.to_bits(), "partial sums must be order-stable");
    }

    #[test]
    fn nested_calls_run_inline() {
        let _g = locked();
        set_threads(8);
        enter_worker(|| {
            assert!(in_worker());
            assert_eq!(effective_threads(), 1);
            assert_eq!(plan(10_000, 1), 1);
        });
        assert!(!in_worker());
        set_threads(0);
    }

    #[test]
    fn kernel_variant_defaults_to_scalar() {
        let _g = locked();
        assert_eq!(kernel_variant(), KernelVariant::Scalar);
        set_kernel_variant(KernelVariant::Scalar).unwrap();
        // selecting simd in a build without the feature is a checked error,
        // not a silent scalar run under a "simd" label
        #[cfg(not(feature = "simd"))]
        {
            let err = set_kernel_variant(KernelVariant::Simd).unwrap_err().to_string();
            assert!(err.contains("--features simd"), "{err}");
            assert_eq!(kernel_variant(), KernelVariant::Scalar);
        }
    }

    #[test]
    fn sum_flat_covers_entire_range() {
        let _g = locked();
        set_threads(4);
        let len = 3 * FLAT_CHUNK + 11;
        let total = sum_flat(len, |a, b| (b - a) as f64);
        set_threads(0);
        assert_eq!(total as usize, len);
    }
}

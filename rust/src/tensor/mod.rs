//! Dense f32 tensors with row-major layout, plus the native kernel stack.
//!
//! This is the coordinator-side tensor substrate: weights, activations and
//! Gram matrices live here between backend calls. The module splits in
//! three:
//!
//! * [`par`] — the worker abstraction: deterministic row-block
//!   parallelism over scoped threads, with a process-global thread count
//!   and a nested-fan-out guard shared by every native kernel and the
//!   prune scheduler.
//! * [`kernels`] — the multithreaded cache-blocked kernels (matmul
//!   family, fused Gram accumulation, the fused FISTA update, quadratic
//!   forms).
//! * [`ops`] — the stable general-purpose facade over `kernels` used by
//!   baselines, the model forward, tests, and the solver engines.
//!
//! When the `xla-pjrt` feature is enabled the request-path hot loops can
//! run in AOT artifacts instead; `ops`/`kernels` remain the reference
//! implementation both paths are tested against.

pub mod kernels;
pub mod ops;
pub mod par;
pub mod quant;
#[cfg(feature = "simd")]
pub mod simd;

use std::fmt;

/// A dense, row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)?;
        }
        Ok(())
    }
}

impl Tensor {
    /// All-zero tensor of the given shape.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let len = shape.iter().product();
        Tensor { shape, data: vec![0.0; len] }
    }

    /// Wrap a row-major buffer; panics if the shape does not match.
    pub fn from_vec(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    /// Rank-0 tensor holding one value.
    pub fn scalar(x: f32) -> Self {
        Tensor { shape: vec![], data: vec![x] }
    }

    /// The dimension sizes, outermost first.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total number of elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when the tensor holds no elements.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// The flat row-major element buffer.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable access to the flat row-major element buffer.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume the tensor, returning its buffer.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows for a 2-D tensor.
    pub fn rows(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "rows() needs 2-D, got {:?}", self.shape);
        self.shape[0]
    }

    /// Number of columns for a 2-D tensor.
    pub fn cols(&self) -> usize {
        assert_eq!(self.shape.len(), 2, "cols() needs 2-D, got {:?}", self.shape);
        self.shape[1]
    }

    /// Element (i, j) of a 2-D tensor.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.shape[1] + j]
    }

    /// Set element (i, j) of a 2-D tensor.
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.shape[1] + j] = v;
    }

    /// Row i of a 2-D tensor as a contiguous slice.
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    /// Mutable row i of a 2-D tensor.
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Reshape without copying (len must match).
    pub fn reshaped(mut self, shape: Vec<usize>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// The first element (scalar artifact outputs).
    pub fn first(&self) -> f32 {
        self.data[0]
    }

    /// Fraction of exactly-zero entries.
    pub fn sparsity(&self) -> f64 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().filter(|&&x| x == 0.0).count() as f64 / self.data.len() as f64
    }

    /// Frobenius norm (f64 accumulation).
    pub fn frob_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Largest absolute element (0 for an empty tensor).
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &x| m.max(x.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let t = Tensor::from_vec(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::from_vec(vec![2, 2], vec![1., 2., 3.]);
    }

    #[test]
    fn sparsity_counts_zeros() {
        let t = Tensor::from_vec(vec![4], vec![0., 1., 0., 2.]);
        assert!((t.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn frob_norm() {
        let t = Tensor::from_vec(vec![2], vec![3., 4.]);
        assert!((t.frob_norm() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::from_vec(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).reshaped(vec![3, 2]);
        assert_eq!(t.shape(), &[3, 2]);
        assert_eq!(t.at2(2, 1), 6.0);
    }
}

//! Native tensor ops: the stable facade over the multithreaded blocked
//! kernels in [`super::kernels`].
//!
//! These back the warm-start baselines (SparseGPT/Wanda), the native FISTA
//! solver, B = W·C in the pruning unit, and the native capture path. Every
//! function here is deterministic with respect to the kernel thread count
//! (see `tensor::par`), so callers can change `FP_THREADS` /
//! `PruneOptions::threads` freely without perturbing results.

use super::{kernels, Tensor};

/// C = A @ B for A[m,k], B[k,n] (cache-blocked, row-parallel).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    kernels::matmul(a, b)
}

/// C = A @ Bᵀ for A[m,k], B[n,k] — rows dot rows (contiguous, fast).
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    kernels::matmul_nt(a, b)
}

/// B = Aᵀ (2-D transpose).
pub fn transpose(a: &Tensor) -> Tensor {
    kernels::transpose(a)
}

/// y = A @ x for A[m,n], x[n].
pub fn matvec(a: &Tensor, x: &[f32]) -> Vec<f32> {
    kernels::matvec(a, x)
}

/// out = a − b (elementwise).
pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    kernels::zip_map(a, b, |x, y| x - y)
}

/// out = a + s·b (axpy).
pub fn add_scaled(a: &Tensor, b: &Tensor, s: f32) -> Tensor {
    kernels::zip_map(a, b, move |x, y| x + s * y)
}

/// ⟨a, b⟩ (flattened dot product, f64 accumulation).
pub fn dot(a: &Tensor, b: &Tensor) -> f64 {
    kernels::dot(a, b)
}

/// ‖a − b‖_F.
pub fn frob_dist(a: &Tensor, b: &Tensor) -> f64 {
    kernels::sq_dist(a, b).sqrt()
}

/// tr(W A Wᵀ) − 2⟨W, B⟩: the Gram form of ‖WX* − W₀X‖² − ‖W₀X‖².
pub fn quad_obj(a: &Tensor, b: &Tensor, w: &Tensor) -> f64 {
    kernels::quad_obj(a, b, w)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn randt(rng: &mut Pcg64, shape: Vec<usize>) -> Tensor {
        let len = shape.iter().product();
        Tensor::from_vec(shape, rng.normal_vec(len, 1.0))
    }

    fn naive_matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k, n) = (a.rows(), a.cols(), b.cols());
        let mut out = Tensor::zeros(vec![m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for kk in 0..k {
                    acc += a.at2(i, kk) * b.at2(kk, j);
                }
                out.set2(i, j, acc);
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg64::seeded(1);
        for (m, k, n) in [(3, 4, 5), (64, 64, 64), (65, 33, 17), (1, 128, 1)] {
            let a = randt(&mut rng, vec![m, k]);
            let b = randt(&mut rng, vec![k, n]);
            let got = matmul(&a, &b);
            let want = naive_matmul(&a, &b);
            assert!(frob_dist(&got, &want) < 1e-3 * (want.frob_norm() + 1.0), "{m}x{k}x{n}");
        }
    }

    #[test]
    fn matmul_nt_matches_matmul() {
        let mut rng = Pcg64::seeded(2);
        let a = randt(&mut rng, vec![20, 30]);
        let b = randt(&mut rng, vec![25, 30]);
        let got = matmul_nt(&a, &b);
        let want = matmul(&a, &transpose(&b));
        assert!(frob_dist(&got, &want) < 1e-3);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Pcg64::seeded(3);
        let a = randt(&mut rng, vec![7, 13]);
        assert_eq!(transpose(&transpose(&a)), a);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Pcg64::seeded(4);
        let a = randt(&mut rng, vec![9, 6]);
        let x = rng.normal_vec(6, 1.0);
        let xv = Tensor::from_vec(vec![6, 1], x.clone());
        let want = matmul(&a, &xv);
        let got = matvec(&a, &x);
        for i in 0..9 {
            assert!((got[i] - want.at2(i, 0)).abs() < 1e-4);
        }
    }

    #[test]
    fn quad_obj_matches_direct() {
        // quad_obj(A,B,W) with A = X Xᵀ, B = W0 X Xᵀ must equal
        // ‖W X − W0 X‖² − ‖W0 X‖².
        let mut rng = Pcg64::seeded(5);
        let w0 = randt(&mut rng, vec![4, 6]);
        let w = randt(&mut rng, vec![4, 6]);
        let x = randt(&mut rng, vec![6, 50]);
        let a = matmul_nt(&x, &x);
        let b = matmul(&w0, &a);
        let wx = matmul(&w, &x);
        let w0x = matmul(&w0, &x);
        let direct = frob_dist(&wx, &w0x).powi(2) - w0x.frob_norm().powi(2);
        let got = quad_obj(&a, &b, &w);
        assert!((got - direct).abs() < 1e-2 * direct.abs().max(1.0), "{got} vs {direct}");
    }

    #[test]
    fn add_scaled_and_sub() {
        let a = Tensor::from_vec(vec![3], vec![1., 2., 3.]);
        let b = Tensor::from_vec(vec![3], vec![1., 1., 1.]);
        assert_eq!(add_scaled(&a, &b, 2.0).data(), &[3., 4., 5.]);
        assert_eq!(sub(&a, &b).data(), &[0., 1., 2.]);
    }
}

//! Quantized value storage for compiled sparse operators: IEEE f16 and
//! per-row absmax int8, decoded back to f32 *in registers* inside the
//! kernels (`tensor::kernels::*_q`), so the bytes that cross the memory
//! bus per decoded token shrink 2× (f16) or ~4× (int8) while every
//! accumulation still happens in f32.
//!
//! Only the kept *values* of a sparse operator are quantized; the sparsity
//! pattern (indices) stays exact, and zeros introduced by n:m group
//! padding quantize to exact ±0.0 in both modes, so quantization never
//! perturbs the pattern.
//!
//! Error contract (pinned by `tests/quant_kernel_parity.rs`):
//! * f16 is exact for values that are representable in half precision
//!   (including every small integer and ±0.0), and round-to-nearest-even
//!   otherwise — worst-case relative error 2⁻¹¹ for normal values.
//! * int8 stores `round(v / scale)` clamped to [-127, 127] with
//!   `scale = row_absmax / 127`, so per-element absolute error is at most
//!   `row_absmax / 127` (half that in the usual rounding case).
//!
//! No external `half` crate: the f16 conversions below are self-contained
//! bit manipulations handling normals, subnormals, infinities, and NaN.

use anyhow::{bail, Result};

use crate::config::QuantMode;

/// f32 → IEEE 754 binary16 bits, round-to-nearest-even; overflow → ±inf,
/// NaN payloads collapse to a quiet NaN.
pub fn f32_to_f16(v: f32) -> u16 {
    let bits = v.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let mant = bits & 0x007f_ffff;
    if exp == 0xff {
        // inf / NaN; keep a NaN payload bit so NaN stays NaN
        return sign | 0x7c00 | if mant != 0 { 0x0200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow → ±inf
    }
    if e <= 0 {
        // subnormal half (or underflow to zero)
        if e < -10 {
            return sign;
        }
        let m = mant | 0x0080_0000; // implicit leading 1
        let shift = (14 - e) as u32; // 14..=24
        let half = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let up = rem > halfway || (rem == halfway && half & 1 == 1);
        let rounded = if up { half + 1 } else { half };
        return sign | rounded as u16;
    }
    // normal half; mantissa rounding may carry into the exponent, which the
    // plain add handles (and can correctly roll into inf)
    let half = ((e as u32) << 10) | (mant >> 13);
    let rem = mant & 0x1fff;
    let up = rem > 0x1000 || (rem == 0x1000 && half & 1 == 1);
    let rounded = if up { half + 1 } else { half };
    sign | rounded as u16
}

/// IEEE 754 binary16 bits → f32 (exact; every f16 value is representable).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h as u32) & 0x8000) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let mant = (h & 0x03ff) as u32;
    if exp == 0x1f {
        return f32::from_bits(sign | 0x7f80_0000 | (mant << 13));
    }
    if exp == 0 {
        if mant == 0 {
            return f32::from_bits(sign);
        }
        // subnormal half: renormalize into an f32 normal
        let mut m = mant;
        let mut e = 113u32; // 127 - 14
        while m & 0x0400 == 0 {
            m <<= 1;
            e -= 1;
        }
        m &= 0x03ff;
        return f32::from_bits(sign | (e << 23) | (m << 13));
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (mant << 13))
}

/// Quantized value payload of one sparse operator. Indexing is by flat
/// value position `k` plus the owning row (int8 needs the row's scale);
/// callers always know both, since every kernel walks values row by row.
#[derive(Clone, Debug)]
pub enum QuantValues {
    /// 2 bytes/value, no side data.
    F16(Vec<u16>),
    /// 1 byte/value + one f32 scale per row (`scale = row_absmax / 127`).
    Int8 { q: Vec<i8>, scales: Vec<f32> },
}

impl QuantValues {
    /// Quantize `values` to f16.
    pub fn f16(values: &[f32]) -> QuantValues {
        QuantValues::F16(values.iter().map(|&v| f32_to_f16(v)).collect())
    }

    /// Quantize `values` to per-row absmax int8. `row_starts` is an
    /// indptr-style boundary array (`row_starts[r]..row_starts[r+1]` is
    /// row r's value span); an all-zero row gets scale 0.0.
    pub fn int8(values: &[f32], row_starts: &[usize]) -> Result<QuantValues> {
        if row_starts.is_empty() || *row_starts.last().unwrap() != values.len() {
            bail!(
                "int8 quantization row boundaries do not cover the {} values",
                values.len()
            );
        }
        let rows = row_starts.len() - 1;
        let mut q = Vec::with_capacity(values.len());
        let mut scales = Vec::with_capacity(rows);
        for r in 0..rows {
            let (a, b) = (row_starts[r], row_starts[r + 1]);
            if b < a || b > values.len() {
                bail!("int8 quantization row {r} has invalid span {a}..{b}");
            }
            let absmax = values[a..b].iter().fold(0f32, |m, &v| m.max(v.abs()));
            let scale = if absmax > 0.0 { absmax / 127.0 } else { 0.0 };
            scales.push(scale);
            for &v in &values[a..b] {
                let qi = if scale > 0.0 {
                    (v / scale).round().clamp(-127.0, 127.0)
                } else {
                    0.0
                };
                q.push(qi as i8);
            }
        }
        Ok(QuantValues::Int8 { q, scales })
    }

    /// Quantize per `mode`; `QuantMode::None` is not representable here and
    /// is a caller bug (the unquantized path keeps its `Vec<f32>`).
    pub fn quantize(mode: QuantMode, values: &[f32], row_starts: &[usize]) -> Result<QuantValues> {
        match mode {
            QuantMode::F16 => Ok(QuantValues::f16(values)),
            QuantMode::Int8 => QuantValues::int8(values, row_starts),
            QuantMode::None => bail!("QuantMode::None has no quantized payload"),
        }
    }

    pub fn len(&self) -> usize {
        match self {
            QuantValues::F16(h) => h.len(),
            QuantValues::Int8 { q, .. } => q.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn mode(&self) -> QuantMode {
        match self {
            QuantValues::F16(_) => QuantMode::F16,
            QuantValues::Int8 { .. } => QuantMode::Int8,
        }
    }

    /// Resident bytes of the value payload (what replaces `4 * len` f32).
    pub fn bytes(&self) -> usize {
        match self {
            QuantValues::F16(h) => 2 * h.len(),
            QuantValues::Int8 { q, scales } => q.len() + 4 * scales.len(),
        }
    }

    /// Dequantize value `k`, which belongs to row `row`.
    #[inline]
    pub fn get(&self, k: usize, row: usize) -> f32 {
        match self {
            QuantValues::F16(h) => f16_to_f32(h[k]),
            QuantValues::Int8 { q, scales } => q[k] as f32 * scales[row],
        }
    }

    /// Dequantize the whole payload back to f32 (tests / dense export).
    pub fn dequantize(&self, row_starts: &[usize]) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len());
        match self {
            QuantValues::F16(h) => out.extend(h.iter().map(|&x| f16_to_f32(x))),
            QuantValues::Int8 { q, scales } => {
                for r in 0..scales.len() {
                    for k in row_starts[r]..row_starts[r + 1] {
                        out.push(q[k] as f32 * scales[r]);
                    }
                }
            }
        }
        out
    }
}

/// Uniform "read value k of row r as f32" access for kernels that are
/// generic over the value payload: plain f32 slices and both quantized
/// payloads implement it, so one monomorphized kernel body serves all
/// three. `load8` exists so the SIMD bodies can fill a lane group in one
/// call (specialized to a straight copy for f32).
pub trait ValueDecode: Sync {
    /// Value `k` (flat position), owned by `row`, as f32.
    fn get(&self, k: usize, row: usize) -> f32;

    /// Values `k..k+8` of `row` as f32 (callers guarantee in-bounds).
    #[inline]
    fn load8(&self, k: usize, row: usize) -> [f32; 8] {
        let mut out = [0f32; 8];
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = self.get(k + i, row);
        }
        out
    }
}

impl ValueDecode for &[f32] {
    #[inline]
    fn get(&self, k: usize, _row: usize) -> f32 {
        self[k]
    }

    #[inline]
    fn load8(&self, k: usize, _row: usize) -> [f32; 8] {
        let mut out = [0f32; 8];
        out.copy_from_slice(&self[k..k + 8]);
        out
    }
}

/// Borrowed f16 payload view implementing [`ValueDecode`].
#[derive(Clone, Copy)]
pub struct F16Values<'a>(pub &'a [u16]);

impl ValueDecode for F16Values<'_> {
    #[inline]
    fn get(&self, k: usize, _row: usize) -> f32 {
        f16_to_f32(self.0[k])
    }
}

/// Borrowed int8 payload view implementing [`ValueDecode`].
#[derive(Clone, Copy)]
pub struct Int8Values<'a> {
    pub q: &'a [i8],
    pub scales: &'a [f32],
}

impl ValueDecode for Int8Values<'_> {
    #[inline]
    fn get(&self, k: usize, row: usize) -> f32 {
        self.q[k] as f32 * self.scales[row]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f16_round_trips_representable_values() {
        // every value here is exactly representable in binary16
        for v in [
            0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 1024.0, -1024.0, 65504.0, -65504.0, 0.25,
            1.5, 3.140625,
        ] {
            let back = f16_to_f32(f32_to_f16(v));
            assert_eq!(back.to_bits(), v.to_bits(), "{v} -> {back}");
        }
    }

    #[test]
    fn f16_handles_edge_cases() {
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xfc00);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // overflow saturates to inf
        assert_eq!(f32_to_f16(1e6), 0x7c00);
        assert_eq!(f32_to_f16(-1e6), 0xfc00);
        // deep underflow flushes to signed zero
        assert_eq!(f16_to_f32(f32_to_f16(1e-30)).to_bits(), 0f32.to_bits());
        assert_eq!(f16_to_f32(f32_to_f16(-1e-30)).to_bits(), (-0f32).to_bits());
        // subnormal halves round-trip (smallest positive f16 = 2^-24)
        let tiny = 2f32.powi(-24);
        assert_eq!(f16_to_f32(f32_to_f16(tiny)), tiny);
        let sub = 3.0 * 2f32.powi(-24);
        assert_eq!(f16_to_f32(f32_to_f16(sub)), sub);
    }

    #[test]
    fn f16_exhaustive_bits_round_trip() {
        // every finite f16 bit pattern survives f16 -> f32 -> f16 exactly
        for h in 0u16..=0xffff {
            let exp = (h >> 10) & 0x1f;
            if exp == 0x1f {
                continue; // inf/NaN handled above
            }
            assert_eq!(f32_to_f16(f16_to_f32(h)), h, "bits {h:#06x}");
        }
    }

    #[test]
    fn f16_rounds_to_nearest() {
        // 1 + 2^-11 is exactly halfway between 1.0 and the next f16; RNE
        // ties to the even mantissa (1.0)
        let halfway = 1.0 + 2f32.powi(-11);
        assert_eq!(f16_to_f32(f32_to_f16(halfway)), 1.0);
        // just above the halfway point rounds up
        let above = 1.0 + 2f32.powi(-11) + 2f32.powi(-20);
        assert_eq!(f16_to_f32(f32_to_f16(above)), 1.0 + 2f32.powi(-10));
    }

    #[test]
    fn int8_error_is_bounded_by_absmax_over_127() {
        let values: Vec<f32> =
            (0..37).map(|i| ((i * 2654435761u64 as usize) % 2000) as f32 / 100.0 - 10.0).collect();
        let starts = vec![0, 10, 10, 25, 37]; // includes an empty row
        let qv = QuantValues::int8(&values, &starts).unwrap();
        assert_eq!(qv.len(), values.len());
        let deq = qv.dequantize(&starts);
        for r in 0..4 {
            let absmax = values[starts[r]..starts[r + 1]]
                .iter()
                .fold(0f32, |m, &v| m.max(v.abs()));
            let bound = absmax / 127.0 + 1e-6;
            for k in starts[r]..starts[r + 1] {
                assert!(
                    (deq[k] - values[k]).abs() <= bound,
                    "row {r} value {k}: {} vs {} (bound {bound})",
                    deq[k],
                    values[k]
                );
                assert_eq!(qv.get(k, r).to_bits(), deq[k].to_bits());
            }
        }
    }

    #[test]
    fn int8_keeps_exact_zeros_and_rejects_bad_spans() {
        let qv = QuantValues::int8(&[0.0, 0.0, 5.0, -5.0], &[0, 2, 4]).unwrap();
        let deq = qv.dequantize(&[0, 2, 4]);
        assert_eq!(deq[0].to_bits(), 0f32.to_bits());
        assert_eq!(deq[1].to_bits(), 0f32.to_bits());
        assert_eq!(deq[2], 5.0);
        assert_eq!(deq[3], -5.0);
        assert!(QuantValues::int8(&[1.0, 2.0], &[0, 1]).is_err());
        assert!(QuantValues::int8(&[1.0], &[]).is_err());
    }

    #[test]
    fn bytes_and_modes_report_payload_sizes() {
        let values = vec![1.0f32; 16];
        let f16 = QuantValues::f16(&values);
        assert_eq!(f16.mode(), QuantMode::F16);
        assert_eq!(f16.bytes(), 32);
        let i8v = QuantValues::int8(&values, &[0, 8, 16]).unwrap();
        assert_eq!(i8v.mode(), QuantMode::Int8);
        assert_eq!(i8v.bytes(), 16 + 8);
        assert!(!f16.is_empty());
        // int8 is at least 2x smaller than the 64-byte f32 payload
        assert!(i8v.bytes() * 2 <= 4 * values.len());
    }

    #[test]
    fn value_decode_load8_matches_get() {
        let values: Vec<f32> = (0..16).map(|i| i as f32 * 0.25 - 2.0).collect();
        let f32v: &[f32] = &values;
        let eight = f32v.load8(4, 0);
        for (i, &e) in eight.iter().enumerate() {
            assert_eq!(e, values[4 + i]);
        }
        let h: Vec<u16> = values.iter().map(|&v| f32_to_f16(v)).collect();
        let f16v = F16Values(&h);
        let eight = f16v.load8(8, 0);
        for (i, &e) in eight.iter().enumerate() {
            assert_eq!(e, f16v.get(8 + i, 0));
        }
        let starts = vec![0usize, values.len()];
        let qv = QuantValues::int8(&values, &starts).unwrap();
        let (q, scales) = match &qv {
            QuantValues::Int8 { q, scales } => (q.as_slice(), scales.as_slice()),
            _ => unreachable!(),
        };
        let i8v = Int8Values { q, scales };
        let eight = i8v.load8(0, 0);
        for (i, &e) in eight.iter().enumerate() {
            assert_eq!(e, i8v.get(i, 0));
        }
    }
}

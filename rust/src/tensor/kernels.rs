//! Multithreaded, cache-blocked native kernels.
//!
//! Every hot native-path operation lives here: the blocked matmul family,
//! the fused three-way Gram product, the fused FISTA iteration update, and
//! the quadratic-form reductions that back the pruning objective. All
//! kernels fan out over [`super::par`] and therefore inherit its
//! guarantees: contiguous per-row ownership, no nested fan-out, and
//! results that are bitwise independent of the thread count.
//!
//! `tensor::ops` re-exposes the general-purpose subset with the original
//! signatures; the fused solver kernels (`matmul_sub_into`, `fista_step`,
//! `gram3`, `quad_form`) are called directly by `pruner::fista` and
//! `pruner::engine`.
//!
//! # Kernel variants
//!
//! The decode-critical kernels (`matvec`, `matmul_nt_skinny`, the CSR and
//! packed n:m families) are *dispatchers*: they select between the scalar
//! reference bodies (`*_scalar`, always built — the parity oracle) and
//! the portable-SIMD bodies in [`super::simd`] (`--features simd`) based
//! on the process-global [`par::kernel_variant`]. Each variant is
//! independently bitwise thread-count-invariant (fixed per-element
//! accumulation order); scalar and SIMD results are value-close but *not*
//! bitwise equal, because the SIMD bodies accumulate eight-lane partials
//! that are reduced once per element (tolerance pinned by
//! `tests/quant_kernel_parity.rs`).
//!
//! The `*_q` entry points run the same bodies over quantized value
//! payloads ([`super::quant::QuantValues`]), dequantizing in registers
//! through the [`ValueDecode`] trait — one generic body per kernel serves
//! f32, f16, and int8 values.

use super::par;
use super::quant::{F16Values, Int8Values, QuantValues, ValueDecode};
use super::Tensor;

/// Cache tile edge for the blocked loops (f32: 64×64 tile = 16 KiB).
pub const BLOCK: usize = 64;

/// Rough per-chunk work floor (flops) below which fan-out is not worth a
/// thread spawn.
const MIN_CHUNK_FLOPS: usize = 1 << 18;

/// Elementwise-chunk floor for memory-bound kernels.
const MIN_ELEMS: usize = 1 << 14;

pub(crate) fn min_rows_for(per_row_flops: usize) -> usize {
    (MIN_CHUNK_FLOPS / per_row_flops.max(1)).max(1)
}

/// Re-lay a [rows, s] scratch into the [s, rows] result, with the free
/// reinterpretation fast path for s == 1 ([rows, 1] and [1, rows] share
/// the same flat layout). Shared by every skinny decode kernel body.
pub(crate) fn unscratch(scratch: Vec<f32>, rows: usize, s: usize) -> Tensor {
    if s == 1 {
        return Tensor::from_vec(vec![1, rows], scratch);
    }
    let mut out = Tensor::zeros(vec![s, rows]);
    let od = out.data_mut();
    for r in 0..rows {
        for t in 0..s {
            od[t * rows + r] = scratch[r * s + t];
        }
    }
    out
}

// ---------------------------------------------------------------------
// Matmul family
// ---------------------------------------------------------------------

/// C = A @ B for A[m,k], B[k,n] — row-block parallel, k-tiled per block,
/// with a cheap skip for zero A entries (pruned weights).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (k2, n) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut out = Tensor::zeros(vec![m, n]);
    let (ad, bd) = (a.data(), b.data());
    par::for_each_row_block(
        out.data_mut(),
        m,
        n,
        min_rows_for(2 * k * n),
        |r0, r1, block| matmul_rows(ad, bd, block, r0, r1, k, n, None),
    );
    out
}

/// out = W @ A − B for W[m,k], A[k,n], B[m,n] — the FISTA gradient
/// (paper eq. 5a), fused so no intermediate W·A tensor is materialized.
pub fn matmul_sub_into(out: &mut Tensor, w: &Tensor, a: &Tensor, b: &Tensor) {
    let (m, k) = (w.rows(), w.cols());
    let (k2, n) = (a.rows(), a.cols());
    assert_eq!(k, k2, "matmul_sub inner dims: {k} vs {k2}");
    assert_eq!(b.shape(), [m, n], "matmul_sub bias shape");
    assert_eq!(out.shape(), [m, n], "matmul_sub out shape");
    let (wd, ad, bd) = (w.data(), a.data(), b.data());
    par::for_each_row_block(
        out.data_mut(),
        m,
        n,
        min_rows_for(2 * k * n),
        |r0, r1, block| matmul_rows(wd, ad, block, r0, r1, k, n, Some(bd)),
    );
}

/// Shared inner loop: block rows [r0, r1) of `out` get A[r,:] @ B, on top
/// of either zeros or `-neg[r,:]`. Per-row accumulation order is fixed
/// (ascending k tiles), so any row split yields identical results.
#[allow(clippy::too_many_arguments)]
fn matmul_rows(
    ad: &[f32],
    bd: &[f32],
    out: &mut [f32],
    r0: usize,
    r1: usize,
    k: usize,
    n: usize,
    neg: Option<&[f32]>,
) {
    if let Some(neg) = neg {
        for i in r0..r1 {
            let orow = &mut out[(i - r0) * n..(i - r0 + 1) * n];
            for (o, &v) in orow.iter_mut().zip(&neg[i * n..(i + 1) * n]) {
                *o = -v;
            }
        }
    }
    for i0 in (r0..r1).step_by(BLOCK) {
        let i1 = (i0 + BLOCK).min(r1);
        for k0 in (0..k).step_by(BLOCK) {
            let k1 = (k0 + BLOCK).min(k);
            for i in i0..i1 {
                let arow = &ad[i * k..(i + 1) * k];
                let orow = &mut out[(i - r0) * n..(i - r0 + 1) * n];
                for kk in k0..k1 {
                    let av = arow[kk];
                    if av == 0.0 {
                        continue; // sparse weights: skip zero rows cheaply
                    }
                    let brow = &bd[kk * n..(kk + 1) * n];
                    for (o, &bv) in orow.iter_mut().zip(brow) {
                        *o += av * bv;
                    }
                }
            }
        }
    }
}

/// C = A @ Bᵀ for A[m,k], B[n,k] — rows dot rows (contiguous, fast),
/// row-block parallel.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_nt inner dims: {k} vs {k2}");
    let mut out = Tensor::zeros(vec![m, n]);
    let (ad, bd) = (a.data(), b.data());
    par::for_each_row_block(
        out.data_mut(),
        m,
        n,
        min_rows_for(2 * k * n),
        |r0, r1, block| {
            for i in r0..r1 {
                let arow = &ad[i * k..(i + 1) * k];
                let orow = out_row(block, i - r0, n);
                for (j, o) in orow.iter_mut().enumerate() {
                    let brow = &bd[j * k..(j + 1) * k];
                    let mut acc = 0.0f32;
                    for (&x, &y) in arow.iter().zip(brow) {
                        acc += x * y;
                    }
                    *o = acc;
                }
            }
        },
    );
    out
}

fn out_row(block: &mut [f32], local_row: usize, n: usize) -> &mut [f32] {
    &mut block[local_row * n..(local_row + 1) * n]
}

/// C = A @ Bᵀ for a *skinny* A [s, k] (s = a decode batch, 1–8 rows):
/// [`matmul_nt`] splits work by output rows and would run s-wide, so this
/// variant parallelizes over B's rows into a [n, s] scratch instead and
/// re-lays it out once (free for s == 1). Dispatches on the selected
/// [`par::kernel_variant`]; in the scalar oracle every element is the
/// same ascending-k dot product as `matmul_nt`, so results are bitwise
/// equal to the wide route.
pub fn matmul_nt_skinny(a: &Tensor, b: &Tensor) -> Tensor {
    #[cfg(feature = "simd")]
    if par::kernel_variant() == crate::config::KernelVariant::Simd {
        return super::simd::matmul_nt_skinny(a, b);
    }
    matmul_nt_skinny_scalar(a, b)
}

/// Scalar reference body of [`matmul_nt_skinny`].
pub fn matmul_nt_skinny_scalar(a: &Tensor, b: &Tensor) -> Tensor {
    let (s, k) = (a.rows(), a.cols());
    let (n, k2) = (b.rows(), b.cols());
    assert_eq!(k, k2, "matmul_nt_skinny inner dims: {k} vs {k2}");
    let (ad, bd) = (a.data(), b.data());
    let mut scratch = vec![0f32; n * s];
    par::for_each_row_block(&mut scratch, n, s, min_rows_for(2 * s * k), |j0, j1, block| {
        for j in j0..j1 {
            let brow = &bd[j * k..(j + 1) * k];
            let orow = &mut block[(j - j0) * s..(j - j0 + 1) * s];
            for (t, o) in orow.iter_mut().enumerate() {
                let arow = &ad[t * k..(t + 1) * k];
                let mut acc = 0.0f32;
                for (&x, &y) in arow.iter().zip(brow) {
                    acc += x * y;
                }
                *o = acc;
            }
        }
    });
    unscratch(scratch, n, s)
}

/// B = Aᵀ (2-D transpose), tiled and parallel over output rows.
pub fn transpose(a: &Tensor) -> Tensor {
    let (m, n) = (a.rows(), a.cols());
    let mut out = Tensor::zeros(vec![n, m]);
    let ad = a.data();
    par::for_each_row_block(out.data_mut(), n, m, BLOCK, |j0, j1, block| {
        for jb in (j0..j1).step_by(BLOCK) {
            let jb1 = (jb + BLOCK).min(j1);
            for i0 in (0..m).step_by(BLOCK) {
                let i1 = (i0 + BLOCK).min(m);
                for j in jb..jb1 {
                    let orow = &mut block[(j - j0) * m..(j - j0 + 1) * m];
                    for i in i0..i1 {
                        orow[i] = ad[i * n + j];
                    }
                }
            }
        }
    });
    out
}

/// y = A @ x for A[m,n], x[n] — parallel over output rows. Dispatches on
/// the selected [`par::kernel_variant`]; [`matvec_scalar`] is the oracle.
pub fn matvec(a: &Tensor, x: &[f32]) -> Vec<f32> {
    #[cfg(feature = "simd")]
    if par::kernel_variant() == crate::config::KernelVariant::Simd {
        return super::simd::matvec(a, x);
    }
    matvec_scalar(a, x)
}

/// Scalar reference body of [`matvec`].
pub fn matvec_scalar(a: &Tensor, x: &[f32]) -> Vec<f32> {
    let (m, n) = (a.rows(), a.cols());
    assert_eq!(n, x.len());
    let ad = a.data();
    let mut out = vec![0f32; m];
    par::for_each_row_block(&mut out, m, 1, min_rows_for(2 * n), |r0, _r1, block| {
        for (i, o) in block.iter_mut().enumerate() {
            let row = &ad[(r0 + i) * n..(r0 + i + 1) * n];
            // fp-lint: allow(f32-reduce) — serial per-row dot, fixed iteration order
            *o = row.iter().zip(x).map(|(&p, &q)| p * q).sum();
        }
    });
    out
}

// ---------------------------------------------------------------------
// CSR decode kernels (the sparse serving hot path)
// ---------------------------------------------------------------------

/// y = W x for a CSR matrix W (`rows` rows given by `indptr`/`indices`/
/// `values`) and dense x — the sparse decode matvec. Row-block parallel
/// over W's rows like [`matvec`]; per-row accumulation walks the row's
/// nonzeros in ascending column order, so the result is independent of
/// the thread count. Dispatches on the selected [`par::kernel_variant`];
/// [`csr_matvec_scalar`] is the oracle.
pub fn csr_matvec(
    indptr: &[u32],
    indices: &[u32],
    values: &[f32],
    rows: usize,
    x: &[f32],
) -> Vec<f32> {
    csr_matvec_dispatch(indptr, indices, &values, rows, x)
}

/// [`csr_matvec`] over a quantized value payload (f16 or per-row-scaled
/// int8), dequantized in registers.
pub fn csr_matvec_q(
    indptr: &[u32],
    indices: &[u32],
    values: &QuantValues,
    rows: usize,
    x: &[f32],
) -> Vec<f32> {
    match values {
        QuantValues::F16(h) => csr_matvec_dispatch(indptr, indices, &F16Values(h), rows, x),
        QuantValues::Int8 { q, scales } => {
            csr_matvec_dispatch(indptr, indices, &Int8Values { q, scales }, rows, x)
        }
    }
}

fn csr_matvec_dispatch<V: ValueDecode>(
    indptr: &[u32],
    indices: &[u32],
    values: &V,
    rows: usize,
    x: &[f32],
) -> Vec<f32> {
    #[cfg(feature = "simd")]
    if par::kernel_variant() == crate::config::KernelVariant::Simd {
        return super::simd::csr_matvec(indptr, indices, values, rows, x);
    }
    csr_matvec_gen(indptr, indices, values, rows, x)
}

/// Scalar reference body of [`csr_matvec`] (f32 values).
pub fn csr_matvec_scalar(
    indptr: &[u32],
    indices: &[u32],
    values: &[f32],
    rows: usize,
    x: &[f32],
) -> Vec<f32> {
    csr_matvec_gen(indptr, indices, &values, rows, x)
}

/// The shared scalar body, generic over the value payload: f32 slices and
/// quantized views run the identical per-row left-to-right accumulation,
/// so the quantized scalar kernel is value-equal to "dequantize to dense,
/// then run the f32 kernel".
fn csr_matvec_gen<V: ValueDecode>(
    indptr: &[u32],
    indices: &[u32],
    values: &V,
    rows: usize,
    x: &[f32],
) -> Vec<f32> {
    debug_assert_eq!(indptr.len(), rows + 1, "indptr length");
    let nnz = indptr.last().map(|&e| e as usize).unwrap_or(0);
    let nnz_per_row = nnz / rows.max(1);
    let mut out = vec![0f32; rows];
    let min_rows = min_rows_for(2 * nnz_per_row.max(1));
    par::for_each_row_block(&mut out, rows, 1, min_rows, |r0, _r1, block| {
        for (i, o) in block.iter_mut().enumerate() {
            let r = r0 + i;
            let (a, b) = (indptr[r] as usize, indptr[r + 1] as usize);
            let mut acc = 0f32;
            for k in a..b {
                acc += values.get(k, r) * x[indices[k] as usize];
            }
            *o = acc;
        }
    });
    out
}

/// out = X @ Wᵀ for a CSR W and a skinny dense X [s, cols] → [s, rows].
///
/// At decode time the batch dimension `s` is small (1–8 concurrent
/// requests), so the parallel split runs over W's rows instead: each
/// worker fills a contiguous stripe of a [rows, s] scratch, which is then
/// re-laid-out once into the [s, rows] result (skipped when s == 1).
/// In the scalar oracle, per-element accumulation order matches
/// `CsrMatrix::matmul_t` exactly. Dispatches on [`par::kernel_variant`].
pub fn csr_matmul_t(
    indptr: &[u32],
    indices: &[u32],
    values: &[f32],
    rows: usize,
    cols: usize,
    x: &Tensor,
) -> Tensor {
    csr_matmul_t_dispatch(indptr, indices, &values, rows, cols, x)
}

/// [`csr_matmul_t`] over a quantized value payload.
pub fn csr_matmul_t_q(
    indptr: &[u32],
    indices: &[u32],
    values: &QuantValues,
    rows: usize,
    cols: usize,
    x: &Tensor,
) -> Tensor {
    match values {
        QuantValues::F16(h) => {
            csr_matmul_t_dispatch(indptr, indices, &F16Values(h), rows, cols, x)
        }
        QuantValues::Int8 { q, scales } => {
            csr_matmul_t_dispatch(indptr, indices, &Int8Values { q, scales }, rows, cols, x)
        }
    }
}

fn csr_matmul_t_dispatch<V: ValueDecode>(
    indptr: &[u32],
    indices: &[u32],
    values: &V,
    rows: usize,
    cols: usize,
    x: &Tensor,
) -> Tensor {
    #[cfg(feature = "simd")]
    if par::kernel_variant() == crate::config::KernelVariant::Simd {
        return super::simd::csr_matmul_t(indptr, indices, values, rows, cols, x);
    }
    csr_matmul_t_gen(indptr, indices, values, rows, cols, x)
}

/// Scalar reference body of [`csr_matmul_t`] (f32 values).
pub fn csr_matmul_t_scalar(
    indptr: &[u32],
    indices: &[u32],
    values: &[f32],
    rows: usize,
    cols: usize,
    x: &Tensor,
) -> Tensor {
    csr_matmul_t_gen(indptr, indices, &values, rows, cols, x)
}

fn csr_matmul_t_gen<V: ValueDecode>(
    indptr: &[u32],
    indices: &[u32],
    values: &V,
    rows: usize,
    cols: usize,
    x: &Tensor,
) -> Tensor {
    let (s, n) = (x.rows(), x.cols());
    assert_eq!(n, cols, "csr_matmul_t inner dims: {n} vs {cols}");
    debug_assert_eq!(indptr.len(), rows + 1, "indptr length");
    let xd = x.data();
    let nnz = indptr.last().map(|&e| e as usize).unwrap_or(0);
    let nnz_per_row = nnz / rows.max(1);
    let mut scratch = vec![0f32; rows * s];
    par::for_each_row_block(
        &mut scratch,
        rows,
        s,
        min_rows_for(2 * s * nnz_per_row.max(1)),
        |r0, r1, block| {
            for r in r0..r1 {
                let (a, b) = (indptr[r] as usize, indptr[r + 1] as usize);
                let orow = &mut block[(r - r0) * s..(r - r0 + 1) * s];
                for (t, o) in orow.iter_mut().enumerate() {
                    let xrow = &xd[t * n..(t + 1) * n];
                    let mut acc = 0f32;
                    for k in a..b {
                        acc += values.get(k, r) * xrow[indices[k] as usize];
                    }
                    *o = acc;
                }
            }
        },
    );
    unscratch(scratch, rows, s)
}

// ---------------------------------------------------------------------
// Packed n:m decode kernels (semi-structured serving hot path)
// ---------------------------------------------------------------------
//
// Storage contract (see `sparse::NmMatrix`): the weight is [rows, cols]
// with cols = G·m groups per row; `values` holds exactly n slots per
// (row, group) in ascending in-group index order — rows·G·n entries,
// flat layout [row][group][slot] — and `indices[k] ∈ 0..m` is the column
// offset of values[k] inside its group, so the column is `g·m +
// indices[k]`. Groups with fewer than n nonzeros are padded with value
// 0.0 at unused in-group positions; the padded multiply adds an exact
// ±0.0 and cannot change any partial sum's value. Decode is branch-free:
// group g of row r always lives at slot (r·G + g)·n — constant-time
// addressing, no indptr indirection, and u8 index loads (¼ the index
// traffic of CSR at 2:4).
//
// Accumulation per output element walks groups in ascending order, slots
// in ascending order — fixed per element, so every kernel below is
// bitwise independent of the thread count (the same `par` contract as
// the CSR kernels) and value-equal to the dense `matmul_nt` route.

/// y = W x for a packed n:m matrix W — the semi-structured decode matvec.
/// Row-block parallel over W's rows like [`csr_matvec`]. Dispatches on
/// [`par::kernel_variant`]; [`nm_matvec_scalar`] is the oracle.
#[allow(clippy::too_many_arguments)]
pub fn nm_matvec(
    values: &[f32],
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &[f32],
) -> Vec<f32> {
    nm_matvec_dispatch(&values, indices, rows, cols, n, m, x)
}

/// [`nm_matvec`] over a quantized value payload.
#[allow(clippy::too_many_arguments)]
pub fn nm_matvec_q(
    values: &QuantValues,
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &[f32],
) -> Vec<f32> {
    match values {
        QuantValues::F16(h) => nm_matvec_dispatch(&F16Values(h), indices, rows, cols, n, m, x),
        QuantValues::Int8 { q, scales } => {
            nm_matvec_dispatch(&Int8Values { q, scales }, indices, rows, cols, n, m, x)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn nm_matvec_dispatch<V: ValueDecode>(
    values: &V,
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &[f32],
) -> Vec<f32> {
    #[cfg(feature = "simd")]
    if par::kernel_variant() == crate::config::KernelVariant::Simd {
        return super::simd::nm_matvec(values, indices, rows, cols, n, m, x);
    }
    nm_matvec_gen(values, indices, rows, cols, n, m, x)
}

/// Scalar reference body of [`nm_matvec`] (f32 values).
#[allow(clippy::too_many_arguments)]
pub fn nm_matvec_scalar(
    values: &[f32],
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &[f32],
) -> Vec<f32> {
    nm_matvec_gen(&values, indices, rows, cols, n, m, x)
}

#[allow(clippy::too_many_arguments)]
fn nm_matvec_gen<V: ValueDecode>(
    values: &V,
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &[f32],
) -> Vec<f32> {
    let groups = cols / m;
    debug_assert_eq!(indices.len(), rows * groups * n, "packed n:m geometry");
    debug_assert_eq!(x.len(), cols, "nm_matvec inner dims");
    let mut out = vec![0f32; rows];
    let min_rows = min_rows_for(2 * groups * n);
    par::for_each_row_block(&mut out, rows, 1, min_rows, |r0, _r1, block| {
        for (i, o) in block.iter_mut().enumerate() {
            let r = r0 + i;
            let row_base = r * groups * n;
            let mut acc = 0f32;
            for g in 0..groups {
                let base = row_base + g * n;
                let xg = &x[g * m..(g + 1) * m];
                for s in 0..n {
                    acc += values.get(base + s, r) * xg[indices[base + s] as usize];
                }
            }
            *o = acc;
        }
    });
    out
}

/// out = X @ Wᵀ for a packed n:m W and a *skinny* dense X [s, cols] →
/// [s, rows] — the batched decode kernel. Mirrors [`csr_matmul_t`]: the
/// batch dimension is 1–8 at decode time, so the parallel split runs
/// over W's rows into a [rows, s] scratch re-laid-out once (free for
/// s == 1). In the scalar oracle, per-element accumulation order matches
/// [`nm_matvec`]. Dispatches on [`par::kernel_variant`].
#[allow(clippy::too_many_arguments)]
pub fn nm_matmul_t(
    values: &[f32],
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &Tensor,
) -> Tensor {
    nm_matmul_t_dispatch(&values, indices, rows, cols, n, m, x)
}

/// [`nm_matmul_t`] over a quantized value payload.
#[allow(clippy::too_many_arguments)]
pub fn nm_matmul_t_q(
    values: &QuantValues,
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &Tensor,
) -> Tensor {
    match values {
        QuantValues::F16(h) => nm_matmul_t_dispatch(&F16Values(h), indices, rows, cols, n, m, x),
        QuantValues::Int8 { q, scales } => {
            nm_matmul_t_dispatch(&Int8Values { q, scales }, indices, rows, cols, n, m, x)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn nm_matmul_t_dispatch<V: ValueDecode>(
    values: &V,
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &Tensor,
) -> Tensor {
    #[cfg(feature = "simd")]
    if par::kernel_variant() == crate::config::KernelVariant::Simd {
        return super::simd::nm_matmul_t(values, indices, rows, cols, n, m, x);
    }
    nm_matmul_t_gen(values, indices, rows, cols, n, m, x)
}

/// Scalar reference body of [`nm_matmul_t`] (f32 values).
#[allow(clippy::too_many_arguments)]
pub fn nm_matmul_t_scalar(
    values: &[f32],
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &Tensor,
) -> Tensor {
    nm_matmul_t_gen(&values, indices, rows, cols, n, m, x)
}

#[allow(clippy::too_many_arguments)]
fn nm_matmul_t_gen<V: ValueDecode>(
    values: &V,
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &Tensor,
) -> Tensor {
    let (s, xc) = (x.rows(), x.cols());
    assert_eq!(xc, cols, "nm_matmul_t inner dims: {xc} vs {cols}");
    let groups = cols / m;
    debug_assert_eq!(indices.len(), rows * groups * n, "packed n:m geometry");
    let xd = x.data();
    let mut scratch = vec![0f32; rows * s];
    par::for_each_row_block(
        &mut scratch,
        rows,
        s,
        min_rows_for(2 * s * groups * n),
        |r0, r1, block| {
            for r in r0..r1 {
                let row_base = r * groups * n;
                let orow = &mut block[(r - r0) * s..(r - r0 + 1) * s];
                for (t, o) in orow.iter_mut().enumerate() {
                    let xrow = &xd[t * cols..(t + 1) * cols];
                    let mut acc = 0f32;
                    for g in 0..groups {
                        let base = row_base + g * n;
                        let xg = &xrow[g * m..(g + 1) * m];
                        for sl in 0..n {
                            acc += values.get(base + sl, r) * xg[indices[base + sl] as usize];
                        }
                    }
                    *o = acc;
                }
            }
        },
    );
    unscratch(scratch, rows, s)
}

/// out = X @ Wᵀ for a packed n:m W and a *wide* dense X [s, cols] →
/// [s, rows] — the full-sequence forward kernel (`sparse::sparse_logits`
/// with s = sequence length). Here the output rows are plentiful, so the
/// split runs over X's rows directly (no scratch transpose). In the
/// scalar oracle each element accumulates in the identical ascending
/// group/slot order as [`nm_matmul_t`], so the two kernels are bitwise
/// equal element for element and both independent of the thread count.
/// Dispatches on [`par::kernel_variant`].
#[allow(clippy::too_many_arguments)]
pub fn nm_matmul(
    values: &[f32],
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &Tensor,
) -> Tensor {
    nm_matmul_dispatch(&values, indices, rows, cols, n, m, x)
}

/// [`nm_matmul`] over a quantized value payload.
#[allow(clippy::too_many_arguments)]
pub fn nm_matmul_q(
    values: &QuantValues,
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &Tensor,
) -> Tensor {
    match values {
        QuantValues::F16(h) => nm_matmul_dispatch(&F16Values(h), indices, rows, cols, n, m, x),
        QuantValues::Int8 { q, scales } => {
            nm_matmul_dispatch(&Int8Values { q, scales }, indices, rows, cols, n, m, x)
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn nm_matmul_dispatch<V: ValueDecode>(
    values: &V,
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &Tensor,
) -> Tensor {
    #[cfg(feature = "simd")]
    if par::kernel_variant() == crate::config::KernelVariant::Simd {
        return super::simd::nm_matmul(values, indices, rows, cols, n, m, x);
    }
    nm_matmul_gen(values, indices, rows, cols, n, m, x)
}

/// Scalar reference body of [`nm_matmul`] (f32 values).
#[allow(clippy::too_many_arguments)]
pub fn nm_matmul_scalar(
    values: &[f32],
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &Tensor,
) -> Tensor {
    nm_matmul_gen(&values, indices, rows, cols, n, m, x)
}

#[allow(clippy::too_many_arguments)]
fn nm_matmul_gen<V: ValueDecode>(
    values: &V,
    indices: &[u8],
    rows: usize,
    cols: usize,
    n: usize,
    m: usize,
    x: &Tensor,
) -> Tensor {
    let (s, xc) = (x.rows(), x.cols());
    assert_eq!(xc, cols, "nm_matmul inner dims: {xc} vs {cols}");
    let groups = cols / m;
    debug_assert_eq!(indices.len(), rows * groups * n, "packed n:m geometry");
    let xd = x.data();
    let mut out = Tensor::zeros(vec![s, rows]);
    par::for_each_row_block(
        out.data_mut(),
        s,
        rows,
        min_rows_for(2 * rows * groups * n),
        |t0, t1, block| {
            for t in t0..t1 {
                let xrow = &xd[t * cols..(t + 1) * cols];
                let orow = &mut block[(t - t0) * rows..(t - t0 + 1) * rows];
                for (r, o) in orow.iter_mut().enumerate() {
                    let row_base = r * groups * n;
                    let mut acc = 0f32;
                    for g in 0..groups {
                        let base = row_base + g * n;
                        let xg = &xrow[g * m..(g + 1) * m];
                        for sl in 0..n {
                            acc += values.get(base + sl, r) * xg[indices[base + sl] as usize];
                        }
                    }
                    *o = acc;
                }
            }
        },
    );
    out
}

// ---------------------------------------------------------------------
// Fused Gram accumulation
// ---------------------------------------------------------------------

/// The three Gram products of one operator in a single pass:
/// A = Xs·Xsᵀ, C = Xd·Xsᵀ, D = Xd·Xdᵀ for Xd, Xs of shape [n, p].
///
/// Row i of all three outputs is computed together so each Xs/Xd row is
/// streamed from memory once per (i, j) pair instead of three times —
/// the native half of the `gram_{n}` artifact contract.
pub fn gram3(xd: &Tensor, xs: &Tensor) -> (Tensor, Tensor, Tensor) {
    assert_eq!(xd.shape(), xs.shape(), "gram3 needs matching activations");
    let (n, p) = (xd.rows(), xd.cols());
    let (xdd, xsd) = (xd.data(), xs.data());
    // Packed row layout: [A_i | C_i | D_i], unpacked below. Packing keeps
    // the parallel dispatch a single contiguous row-block split.
    let mut packed = vec![0f32; n * 3 * n];
    par::for_each_row_block(
        &mut packed,
        n,
        3 * n,
        min_rows_for(6 * n * p),
        |r0, r1, block| {
            for i in r0..r1 {
                let xsi = &xsd[i * p..(i + 1) * p];
                let xdi = &xdd[i * p..(i + 1) * p];
                let row = &mut block[(i - r0) * 3 * n..(i - r0 + 1) * 3 * n];
                let (arow, rest) = row.split_at_mut(n);
                let (crow, drow) = rest.split_at_mut(n);
                for j in 0..n {
                    let xsj = &xsd[j * p..(j + 1) * p];
                    let xdj = &xdd[j * p..(j + 1) * p];
                    let (mut sa, mut sc, mut sd) = (0f32, 0f32, 0f32);
                    for t in 0..p {
                        sa += xsi[t] * xsj[t];
                        sc += xdi[t] * xsj[t];
                        sd += xdi[t] * xdj[t];
                    }
                    arow[j] = sa;
                    crow[j] = sc;
                    drow[j] = sd;
                }
            }
        },
    );
    let mut a = Tensor::zeros(vec![n, n]);
    let mut c = Tensor::zeros(vec![n, n]);
    let mut d = Tensor::zeros(vec![n, n]);
    for i in 0..n {
        let row = &packed[i * 3 * n..(i + 1) * 3 * n];
        a.row_mut(i).copy_from_slice(&row[..n]);
        c.row_mut(i).copy_from_slice(&row[n..2 * n]);
        d.row_mut(i).copy_from_slice(&row[2 * n..]);
    }
    (a, c, d)
}

// ---------------------------------------------------------------------
// Fused FISTA update
// ---------------------------------------------------------------------

/// One fused FISTA iteration tail (paper eqs. 5a–5d) over the whole
/// matrix in a single pass:
///
/// given `grad` = W_k·A − B, per element
///   w13  = w_k − (1/L)·grad              (5a, gradient step)
///   prox = SoftShrink_{λ/L}(w13)         (5b, proximal step)
///   next = prox + coef·(prox − w_k)      (5d, Nesterov combination)
///
/// writes `prox` into `w23`, `next` into `w_k`, and returns
/// ‖next − w_k‖²_F accumulated as deterministic per-row partials.
pub fn fista_step(
    grad: &Tensor,
    w_k: &mut Tensor,
    w23: &mut Tensor,
    inv_l: f32,
    thresh: f32,
    coef: f32,
) -> f64 {
    assert_eq!(grad.shape(), w_k.shape());
    assert_eq!(grad.shape(), w23.shape());
    let (m, n) = (w_k.rows(), w_k.cols());
    let gd = grad.data();
    let mut partials = vec![0f64; m];
    let nt = par::plan(m, (MIN_ELEMS / n.max(1)).max(1));
    if nt <= 1 {
        fista_step_rows(gd, w_k.data_mut(), w23.data_mut(), &mut partials, 0, m, n, inv_l, thresh, coef);
    } else {
        let per = m.div_ceil(nt);
        let wkd = w_k.data_mut();
        let w23d = w23.data_mut();
        std::thread::scope(|s| {
            let mut wk_rest = wkd;
            let mut w23_rest = w23d;
            let mut part_rest = partials.as_mut_slice();
            let mut r0 = 0usize;
            while r0 < m {
                let r1 = (r0 + per).min(m);
                let rows = r1 - r0;
                let (wk_h, wk_t) = std::mem::take(&mut wk_rest).split_at_mut(rows * n);
                wk_rest = wk_t;
                let (w23_h, w23_t) = std::mem::take(&mut w23_rest).split_at_mut(rows * n);
                w23_rest = w23_t;
                let (p_h, p_t) = std::mem::take(&mut part_rest).split_at_mut(rows);
                part_rest = p_t;
                // fp-lint: allow(det-spawn) — scoped fan-out over fixed row blocks, joined at scope end
                s.spawn(move || {
                    par::enter_worker(|| {
                        fista_step_rows(gd, wk_h, w23_h, p_h, r0, r1, n, inv_l, thresh, coef)
                    })
                });
                r0 = r1;
            }
        });
    }
    // fp-lint: allow(f32-reduce) — f64 partials summed in fixed block order
    partials.iter().sum()
}

#[allow(clippy::too_many_arguments)]
fn fista_step_rows(
    gd: &[f32],
    wk: &mut [f32],
    w23: &mut [f32],
    partials: &mut [f64],
    r0: usize,
    r1: usize,
    n: usize,
    inv_l: f32,
    thresh: f32,
    coef: f32,
) {
    for row in 0..(r1 - r0) {
        let gbase = (r0 + row) * n;
        let mut acc = 0f64;
        for j in 0..n {
            let g = gd[gbase + j];
            let wkv = wk[row * n + j];
            let w13 = wkv + (-inv_l) * g;
            let prox = if w13 > thresh {
                w13 - thresh
            } else if w13 < -thresh {
                w13 + thresh
            } else {
                0.0
            };
            let next = prox + coef * (prox - wkv);
            let d = (next - wkv) as f64;
            acc += d * d;
            w23[row * n + j] = prox;
            wk[row * n + j] = next;
        }
        partials[row] = acc;
    }
}

// ---------------------------------------------------------------------
// Quadratic-form reductions
// ---------------------------------------------------------------------

/// tr(W G Wᵀ) for W[m,n], G[n,n], without materializing W·G. Used for the
/// prep constant c = ‖W X‖² = tr(W D Wᵀ).
pub fn quad_form(w: &Tensor, g: &Tensor) -> f64 {
    let (m, n) = (w.rows(), w.cols());
    assert_eq!(g.shape(), [n, n], "quad_form needs square G");
    let gd = g.data();
    par::sum_rows(m, min_rows_for(2 * n * n), |r| {
        let wr = w.row(r);
        let t = row_times_square(wr, gd, n);
        // fp-lint: allow(f32-reduce) — serial f64 per-row accumulation inside sum_rows
        t.iter().zip(wr).map(|(&x, &y)| (x as f64) * (y as f64)).sum()
    })
}

/// tr(W A Wᵀ) − 2⟨W, B⟩: the Gram form of ‖WX* − W₀X‖² − ‖W₀X‖², fused
/// per output row (one A-row sweep, no W·A allocation).
pub fn quad_obj(a: &Tensor, b: &Tensor, w: &Tensor) -> f64 {
    let (m, n) = (w.rows(), w.cols());
    assert_eq!(a.shape(), [n, n], "quad_obj needs square A");
    assert_eq!(b.shape(), [m, n], "quad_obj B shape");
    let ad = a.data();
    par::sum_rows(m, min_rows_for(2 * n * n), |r| {
        let wr = w.row(r);
        let t = row_times_square(wr, ad, n);
        // fp-lint: allow(f32-reduce) — serial f64 per-row accumulation inside sum_rows
        let quad: f64 = t.iter().zip(wr).map(|(&x, &y)| (x as f64) * (y as f64)).sum();
        // fp-lint: allow(f32-reduce) — serial f64 per-row accumulation inside sum_rows
        let lin: f64 = wr.iter().zip(b.row(r)).map(|(&x, &y)| (x as f64) * (y as f64)).sum();
        quad - 2.0 * lin
    })
}

/// t = w_r @ G for a square row-major G (zero entries of w_r skipped).
fn row_times_square(wr: &[f32], gd: &[f32], n: usize) -> Vec<f32> {
    let mut t = vec![0f32; n];
    for (k, &wv) in wr.iter().enumerate() {
        if wv == 0.0 {
            continue;
        }
        let grow = &gd[k * n..(k + 1) * n];
        for (o, &gv) in t.iter_mut().zip(grow) {
            *o += wv * gv;
        }
    }
    t
}

// ---------------------------------------------------------------------
// Elementwise + flat reductions
// ---------------------------------------------------------------------

/// out[i] = f(a[i], b[i]) with parallel fixed-size chunking.
pub fn zip_map(a: &Tensor, b: &Tensor, f: impl Fn(f32, f32) -> f32 + Sync) -> Tensor {
    assert_eq!(a.shape(), b.shape());
    let len = a.len();
    let mut out = Tensor::zeros(a.shape().to_vec());
    let (ad, bd) = (a.data(), b.data());
    par::for_each_row_block(out.data_mut(), len, 1, MIN_ELEMS, |i0, _i1, block| {
        for (k, o) in block.iter_mut().enumerate() {
            *o = f(ad[i0 + k], bd[i0 + k]);
        }
    });
    out
}

/// ⟨a, b⟩ with f64 accumulation over fixed chunks (thread-count stable).
pub fn dot(a: &Tensor, b: &Tensor) -> f64 {
    assert_eq!(a.shape(), b.shape());
    let (ad, bd) = (a.data(), b.data());
    par::sum_flat(ad.len(), |s, e| {
        // fp-lint: allow(f32-reduce) — serial f64 accumulation over a fixed chunk
        ad[s..e].iter().zip(&bd[s..e]).map(|(&x, &y)| (x as f64) * (y as f64)).sum()
    })
}

/// ‖a − b‖²_F with f64 accumulation over fixed chunks.
pub fn sq_dist(a: &Tensor, b: &Tensor) -> f64 {
    assert_eq!(a.shape(), b.shape());
    let (ad, bd) = (a.data(), b.data());
    par::sum_flat(ad.len(), |s, e| {
        ad[s..e]
            .iter()
            .zip(&bd[s..e])
            .map(|(&x, &y)| {
                let d = (x - y) as f64;
                d * d
            })
            // fp-lint: allow(f32-reduce) — serial f64 accumulation over a fixed chunk
            .sum()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg64;

    fn randt(rng: &mut Pcg64, shape: Vec<usize>) -> Tensor {
        let len = shape.iter().product();
        Tensor::from_vec(shape, rng.normal_vec(len, 1.0))
    }

    #[test]
    fn gram3_matches_individual_products() {
        let mut rng = Pcg64::seeded(41);
        for (n, p) in [(5, 17), (33, 70), (64, 256)] {
            let xd = randt(&mut rng, vec![n, p]);
            let xs = randt(&mut rng, vec![n, p]);
            let (a, c, d) = gram3(&xd, &xs);
            let a2 = matmul_nt(&xs, &xs);
            let c2 = matmul_nt(&xd, &xs);
            let d2 = matmul_nt(&xd, &xd);
            for (got, want) in [(&a, &a2), (&c, &c2), (&d, &d2)] {
                assert!(sq_dist(got, want).sqrt() < 1e-3 * want.frob_norm().max(1.0), "{n}x{p}");
            }
        }
    }

    #[test]
    fn matmul_sub_matches_two_step() {
        let mut rng = Pcg64::seeded(42);
        let w = randt(&mut rng, vec![9, 13]);
        let a = randt(&mut rng, vec![13, 13]);
        let b = randt(&mut rng, vec![9, 13]);
        let mut out = Tensor::zeros(vec![9, 13]);
        matmul_sub_into(&mut out, &w, &a, &b);
        let want = zip_map(&matmul(&w, &a), &b, |x, y| x - y);
        assert!(sq_dist(&out, &want).sqrt() < 1e-3);
    }

    #[test]
    fn fista_step_matches_unfused_reference() {
        let mut rng = Pcg64::seeded(43);
        let (m, n) = (21, 37);
        let grad = randt(&mut rng, vec![m, n]);
        let w0 = randt(&mut rng, vec![m, n]);
        let (inv_l, thresh, coef) = (0.25f32, 0.1f32, 0.6f32);

        let mut w_k = w0.clone();
        let mut w23 = Tensor::zeros(vec![m, n]);
        let diff2 = fista_step(&grad, &mut w_k, &mut w23, inv_l, thresh, coef);

        // unfused reference: the five-step original
        let w13 = zip_map(&w0, &grad, |x, g| x + (-inv_l) * g);
        let prox = Tensor::from_vec(
            vec![m, n],
            w13.data()
                .iter()
                .map(|&x| {
                    if x > thresh {
                        x - thresh
                    } else if x < -thresh {
                        x + thresh
                    } else {
                        0.0
                    }
                })
                .collect(),
        );
        let next = Tensor::from_vec(
            vec![m, n],
            prox.data().iter().zip(w0.data()).map(|(&p, &c)| p + coef * (p - c)).collect(),
        );
        assert_eq!(w23, prox, "prox point must match the unfused steps exactly");
        assert_eq!(w_k, next, "Nesterov point must match the unfused steps exactly");
        let want = sq_dist(&next, &w0);
        assert!((diff2 - want).abs() < 1e-6 * want.max(1.0));
    }

    #[test]
    fn skinny_matmul_nt_matches_wide_bitwise() {
        let mut rng = Pcg64::seeded(46);
        for s in [1usize, 3, 4] {
            let a = randt(&mut rng, vec![s, 29]);
            let b = randt(&mut rng, vec![71, 29]);
            let wide = matmul_nt(&a, &b);
            let skinny = matmul_nt_skinny(&a, &b);
            assert_eq!(skinny.shape(), &[s, 71]);
            for (x, y) in skinny.data().iter().zip(wide.data()) {
                assert_eq!(x.to_bits(), y.to_bits(), "s={s}");
            }
        }
    }

    /// Toy CSR of a dense matrix (test-local; the real builder lives in
    /// `sparse::csr` and is parity-tested against these kernels there).
    fn dense_to_csr(w: &Tensor) -> (Vec<u32>, Vec<u32>, Vec<f32>) {
        let (mut indptr, mut indices, mut values) = (vec![0u32], Vec::new(), Vec::new());
        for i in 0..w.rows() {
            for (j, &v) in w.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j as u32);
                    values.push(v);
                }
            }
            indptr.push(indices.len() as u32);
        }
        (indptr, indices, values)
    }

    #[test]
    fn csr_kernels_match_dense_and_are_thread_invariant() {
        let mut rng = Pcg64::seeded(45);
        let (m, n, s) = (33, 47, 4);
        let mut w = randt(&mut rng, vec![m, n]);
        for v in w.data_mut() {
            if *v < 0.0 {
                *v = 0.0; // ~50% sparse
            }
        }
        let (indptr, indices, values) = dense_to_csr(&w);
        let x = randt(&mut rng, vec![s, n]);
        let want = matmul_nt(&x, &w);

        let got = csr_matmul_t(&indptr, &indices, &values, m, n, &x);
        assert_eq!(got.shape(), &[s, m]);
        assert!(sq_dist(&got, &want).sqrt() < 1e-4 * want.frob_norm().max(1.0));

        // single-row fast path + matvec agree with the dense route
        let x1 = Tensor::from_vec(vec![1, n], x.row(0).to_vec());
        let got1 = csr_matmul_t(&indptr, &indices, &values, m, n, &x1);
        assert_eq!(got1.shape(), &[1, m]);
        let y = csr_matvec(&indptr, &indices, &values, m, x.row(0));
        for (a, b) in y.iter().zip(got1.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        // bitwise identical across thread counts
        let baseline = {
            par::set_threads(1);
            let t = csr_matmul_t(&indptr, &indices, &values, m, n, &x);
            par::set_threads(0);
            t
        };
        for threads in [2, 5] {
            par::set_threads(threads);
            let t = csr_matmul_t(&indptr, &indices, &values, m, n, &x);
            par::set_threads(0);
            for (a, b) in t.data().iter().zip(baseline.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
    }

    /// Toy packed 2:4 encoding of a dense matrix already satisfying the
    /// pattern (test-local; the real builder lives in `sparse::nm` and is
    /// parity-tested against these kernels there).
    fn dense_to_nm(w: &Tensor, n: usize, m: usize) -> (Vec<f32>, Vec<u8>) {
        let (mut values, mut indices) = (Vec::new(), Vec::new());
        for i in 0..w.rows() {
            for grp in w.row(i).chunks(m) {
                let mut kept: Vec<usize> = (0..m).filter(|&j| grp[j] != 0.0).collect();
                let mut pad = (0..m).filter(|&j| grp[j] == 0.0);
                while kept.len() < n {
                    kept.push(pad.next().expect("group has >= m - n zeros"));
                }
                kept.sort_unstable();
                for j in kept {
                    values.push(grp[j]);
                    indices.push(j as u8);
                }
            }
        }
        (values, indices)
    }

    #[test]
    fn nm_kernels_match_dense_and_are_thread_invariant() {
        let mut rng = Pcg64::seeded(47);
        let (rows, cols, s, n, m) = (24, 32, 4, 2, 4);
        let w = crate::pruner::rounding::round_to_sparsity(
            &randt(&mut rng, vec![rows, cols]),
            crate::config::Sparsity::Semi(n, m),
        );
        let (values, indices) = dense_to_nm(&w, n, m);
        assert_eq!(values.len(), rows * (cols / m) * n);
        let x = randt(&mut rng, vec![s, cols]);
        let want = matmul_nt(&x, &w);

        let got = nm_matmul_t(&values, &indices, rows, cols, n, m, &x);
        assert_eq!(got.shape(), &[s, rows]);
        for (a, b) in got.data().iter().zip(want.data()) {
            assert_eq!(a, b, "nm_matmul_t must be value-equal to dense");
        }

        // wide kernel: bitwise equal to the skinny one element for element
        let wide = nm_matmul(&values, &indices, rows, cols, n, m, &x);
        for (a, b) in wide.data().iter().zip(got.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        // single-row fast path + matvec agree
        let x1 = Tensor::from_vec(vec![1, cols], x.row(0).to_vec());
        let got1 = nm_matmul_t(&values, &indices, rows, cols, n, m, &x1);
        assert_eq!(got1.shape(), &[1, rows]);
        let y = nm_matvec(&values, &indices, rows, cols, n, m, x.row(0));
        for (a, b) in y.iter().zip(got1.data()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }

        // bitwise identical across thread counts
        let baseline = {
            par::set_threads(1);
            let t = nm_matmul_t(&values, &indices, rows, cols, n, m, &x);
            par::set_threads(0);
            t
        };
        for threads in [2, 5] {
            par::set_threads(threads);
            let t = nm_matmul_t(&values, &indices, rows, cols, n, m, &x);
            let wide_t = nm_matmul(&values, &indices, rows, cols, n, m, &x);
            par::set_threads(0);
            for (a, b) in t.data().iter().zip(baseline.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
            for (a, b) in wide_t.data().iter().zip(baseline.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "wide threads={threads}");
            }
        }
    }

    #[test]
    fn quantized_scalar_kernels_match_dequantized_dense_route() {
        // The quantized scalar kernels accumulate the exact same f32
        // values in the exact same order as "dequantize to dense, then run
        // the f32 kernel", so the two routes are bitwise equal.
        let mut rng = Pcg64::seeded(48);
        let (mr, nc, s) = (19, 23, 3);
        let mut w = randt(&mut rng, vec![mr, nc]);
        for v in w.data_mut() {
            if *v > 0.4 {
                *v = 0.0;
            }
        }
        let (indptr, indices, values) = dense_to_csr(&w);
        let starts: Vec<usize> = indptr.iter().map(|&e| e as usize).collect();
        let x = randt(&mut rng, vec![s, nc]);
        let quants = [
            QuantValues::f16(&values),
            QuantValues::int8(&values, &starts).unwrap(),
        ];
        for qv in &quants {
            let deq = qv.dequantize(&starts);
            let want = csr_matmul_t_scalar(&indptr, &indices, &deq, mr, nc, &x);
            let got = csr_matmul_t_q(&indptr, &indices, qv, mr, nc, &x);
            for (a, b) in got.data().iter().zip(want.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{:?}", qv.mode());
            }
            let ywant = csr_matvec_scalar(&indptr, &indices, &deq, mr, x.row(0));
            let ygot = csr_matvec_q(&indptr, &indices, qv, mr, x.row(0));
            for (a, b) in ygot.iter().zip(&ywant) {
                assert_eq!(a.to_bits(), b.to_bits(), "{:?}", qv.mode());
            }
        }

        let (rows, cols, n, m) = (16, 24, 2, 4);
        let wnm = crate::pruner::rounding::round_to_sparsity(
            &randt(&mut rng, vec![rows, cols]),
            crate::config::Sparsity::Semi(n, m),
        );
        let (nmv, nmi) = dense_to_nm(&wnm, n, m);
        let stored = (cols / m) * n;
        let nm_starts: Vec<usize> = (0..=rows).map(|r| r * stored).collect();
        let xn = randt(&mut rng, vec![s, cols]);
        let quants = [
            QuantValues::f16(&nmv),
            QuantValues::int8(&nmv, &nm_starts).unwrap(),
        ];
        for qv in &quants {
            let deq = qv.dequantize(&nm_starts);
            let want = nm_matmul_t_scalar(&deq, &nmi, rows, cols, n, m, &xn);
            let got = nm_matmul_t_q(qv, &nmi, rows, cols, n, m, &xn);
            for (a, b) in got.data().iter().zip(want.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "{:?}", qv.mode());
            }
            let wide = nm_matmul_q(qv, &nmi, rows, cols, n, m, &xn);
            for (a, b) in wide.data().iter().zip(want.data()) {
                assert_eq!(a.to_bits(), b.to_bits(), "wide {:?}", qv.mode());
            }
            let ywant = nm_matvec_scalar(&deq, &nmi, rows, cols, n, m, xn.row(0));
            let ygot = nm_matvec_q(qv, &nmi, rows, cols, n, m, xn.row(0));
            for (a, b) in ygot.iter().zip(&ywant) {
                assert_eq!(a.to_bits(), b.to_bits(), "{:?}", qv.mode());
            }
        }
    }

    #[test]
    fn quad_forms_match_matmul_route() {
        let mut rng = Pcg64::seeded(44);
        let w = randt(&mut rng, vec![11, 19]);
        let g = {
            let x = randt(&mut rng, vec![19, 40]);
            matmul_nt(&x, &x)
        };
        let wg = matmul(&w, &g);
        let want = dot(&wg, &w);
        let got = quad_form(&w, &g);
        assert!((got - want).abs() < 1e-4 * want.abs().max(1.0), "{got} vs {want}");
        let b = randt(&mut rng, vec![11, 19]);
        let want_obj = want - 2.0 * dot(&w, &b);
        let got_obj = quad_obj(&g, &b, &w);
        assert!((got_obj - want_obj).abs() < 1e-4 * want_obj.abs().max(1.0));
    }
}

//! Model checkpoints: named parameter tensors + a JSON sidecar with the
//! model identity and training metadata.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::json::Json;
use super::tensorfile;
use crate::model::params::ModelParams;

/// Metadata stored next to the `.fpt` payload.
#[derive(Clone, Debug)]
pub struct CheckpointMeta {
    pub model: String,
    pub corpus: String,
    pub steps: usize,
    pub final_loss: f64,
    pub seed: u64,
}

impl CheckpointMeta {
    fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("model".into(), Json::Str(self.model.clone()));
        m.insert("corpus".into(), Json::Str(self.corpus.clone()));
        m.insert("steps".into(), Json::Num(self.steps as f64));
        m.insert("final_loss".into(), Json::Num(self.final_loss));
        // u64 as a string: a JSON number rides through f64, which silently
        // corrupts seeds above 2^53 (see Json::as_u64)
        m.insert("seed".into(), Json::Str(self.seed.to_string()));
        Json::Obj(m)
    }

    fn from_json(v: &Json) -> Result<Self> {
        Ok(CheckpointMeta {
            model: v.req("model")?.as_str().context("model")?.to_string(),
            corpus: v.req("corpus")?.as_str().context("corpus")?.to_string(),
            steps: v.req("steps")?.as_usize().context("steps")?,
            final_loss: v.req("final_loss")?.as_f64().context("final_loss")?,
            seed: v.req("seed")?.as_u64().context("seed (u64; numbers above 2^53 are rejected)")?,
        })
    }
}

fn meta_path(path: &Path) -> PathBuf {
    path.with_extension("meta.json")
}

/// Save parameters + metadata (`<path>.fpt` + `<path>.meta.json`).
pub fn save(path: &Path, params: &ModelParams, meta: &CheckpointMeta) -> Result<()> {
    let entries: Vec<(String, &crate::tensor::Tensor)> =
        params.iter().map(|(n, t)| (n.to_string(), t)).collect();
    tensorfile::write_tensors(path, &entries)?;
    std::fs::write(meta_path(path), meta.to_json().to_string_compact())?;
    Ok(())
}

/// Load a checkpoint; validates the tensor set matches the spec of `meta.model`.
pub fn load(path: &Path) -> Result<(ModelParams, CheckpointMeta)> {
    let meta_file = meta_path(path);
    let meta = CheckpointMeta::from_json(&Json::parse_file(&meta_file)?)?;
    let tensors = tensorfile::read_tensor_map(path)?;
    let params = ModelParams::from_map(&meta.model, tensors)?;
    Ok((params, meta))
}

/// True if both the payload and the sidecar exist.
pub fn exists(path: &Path) -> bool {
    path.exists() && meta_path(path).exists()
}

/// Conventional checkpoint location for a (model, corpus, steps, seed) run.
pub fn default_path(root: &Path, model: &str, corpus: &str, steps: usize, seed: u64) -> PathBuf {
    root.join("checkpoints").join(format!("{model}_{corpus}_{steps}_{seed}.fpt"))
}

/// Guard against loading a checkpoint for a different model spec.
pub fn check_model(meta: &CheckpointMeta, expected: &str) -> Result<()> {
    if meta.model != expected {
        bail!("checkpoint is for model '{}', expected '{}'", meta.model, expected);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{repo_root, Presets};
    use crate::model::init::init_params;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("fp_ckpt_{name}_{}.fpt", std::process::id()))
    }

    #[test]
    fn save_load_roundtrip() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 17);
        let meta = CheckpointMeta {
            model: "topt-s1".into(),
            corpus: "ptb-syn".into(),
            steps: 42,
            final_loss: 2.5,
            seed: 17,
        };
        let path = tmp("roundtrip");
        save(&path, &params, &meta).unwrap();
        assert!(exists(&path));
        let (back, bmeta) = load(&path).unwrap();
        assert_eq!(bmeta.steps, 42);
        assert_eq!(bmeta.corpus, "ptb-syn");
        for ((n1, t1), (_n2, t2)) in params.iter().zip(back.iter()) {
            assert_eq!(t1, t2, "mismatch at {n1}");
        }
        assert!(check_model(&bmeta, "topt-s1").is_ok());
        assert!(check_model(&bmeta, "topt-s2").is_err());
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(meta_path(&path)).ok();
    }

    #[test]
    fn missing_sidecar_fails() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 1);
        let path = tmp("nosidecar");
        let entries: Vec<(String, &crate::tensor::Tensor)> =
            params.iter().map(|(n, t)| (n.to_string(), t)).collect();
        tensorfile::write_tensors(&path, &entries).unwrap();
        assert!(!exists(&path));
        assert!(load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn seed_above_2_53_roundtrips_exactly() {
        // regression: seeds used to ride through f64 and come back wrong
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 2);
        for seed in [u64::MAX, (1u64 << 53) + 1, 0] {
            let meta = CheckpointMeta {
                model: "topt-s1".into(),
                corpus: "ptb-syn".into(),
                steps: 1,
                final_loss: 0.0,
                seed,
            };
            let path = tmp(&format!("bigseed_{seed}"));
            save(&path, &params, &meta).unwrap();
            let (_, back) = load(&path).unwrap();
            assert_eq!(back.seed, seed, "seed must not round-trip through f64");
            std::fs::remove_file(&path).ok();
            std::fs::remove_file(meta_path(&path)).ok();
        }
        // a legacy sidecar with a too-large numeric seed is rejected, not
        // silently corrupted
        let bad = Json::parse(
            r#"{"model":"m","corpus":"c","steps":1,"final_loss":0,"seed":18446744073709551615}"#,
        )
        .unwrap();
        assert!(CheckpointMeta::from_json(&bad).is_err());
        // ...while a small legacy numeric seed still loads
        let ok = Json::parse(r#"{"model":"m","corpus":"c","steps":1,"final_loss":0,"seed":7}"#)
            .unwrap();
        assert_eq!(CheckpointMeta::from_json(&ok).unwrap().seed, 7);
    }

    #[test]
    fn default_path_is_deterministic() {
        let a = default_path(Path::new("/x"), "m", "c", 10, 3);
        let b = default_path(Path::new("/x"), "m", "c", 10, 3);
        assert_eq!(a, b);
        assert_ne!(a, default_path(Path::new("/x"), "m", "c", 11, 3));
    }
}

//! `.fsa` — the binary sparse-artifact container: compressed operators
//! serialized *as compressed* (no dense round-trip) next to the residual
//! dense tensors, each record integrity-checked.
//!
//! Layout (little-endian):
//! ```text
//!   magic    "FSA1" (4 bytes)
//!   version  u32 (currently 2; this build reads 1..=2, newer is a
//!            checked error)
//!   count    u32
//!   repeat count times:
//!     name_len u32, name utf-8 bytes
//!     kind     u8  (0 = dense tensor, 1 = CSR, 2 = packed n:m,
//!                   3 = quantized CSR, 4 = quantized n:m)
//!     len      u64 payload bytes
//!     payload  (kind-specific, see below)
//!     crc      u32 (CRC-32/IEEE of the payload)
//!   ```
//! Payloads:
//! * dense — `ndim u32, dims u64 × ndim, data f32 × prod(dims)`
//! * CSR   — `rows u64, cols u64, nnz u64, indptr u32 × (rows+1),
//!   indices u32 × nnz, values f32 × nnz`
//! * n:m   — `rows u64, cols u64, n u32, m u32, slots u64,
//!   values f32 × slots, indices u8 × slots`
//! * quantized CSR (v2) — `rows u64, cols u64, nnz u64,
//!   indptr u32 × (rows+1), indices u32 × nnz, quant u8 (1 = f16,
//!   2 = int8),` then the values: f16 → `u16 × nnz`; int8 →
//!   `i8 × nnz, scales f32 × rows`
//! * quantized n:m (v2) — `rows u64, cols u64, n u32, m u32, slots u64,
//!   quant u8,` then the values (f16 → `u16 × slots`; int8 →
//!   `i8 × slots, scales f32 × rows`), `indices u8 × slots`
//!
//! Every failure mode is a checked `Err`, never a panic: wrong magic,
//! version skew, truncation (any short read, or a payload shorter/longer
//! than its declared length), per-record checksum mismatch, and
//! internally inconsistent payloads (non-monotonic `indptr`, out-of-range
//! column indices, slot-count mismatches). The high-level artifact API
//! lives in [`super::artifact`].

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::sparse::{CsrMatrix, CsrQMatrix, NmMatrix, NmQMatrix};
use crate::tensor::quant::QuantValues;
use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"FSA1";
/// Container format version this build writes. Reads accept any version
/// in `1..=VERSION`: v1 artifacts simply contain no quantized records,
/// so every v1 kind decodes unchanged.
pub const VERSION: u32 = 2;

const KIND_DENSE: u8 = 0;
const KIND_CSR: u8 = 1;
const KIND_NM: u8 = 2;
const KIND_CSR_Q: u8 = 3;
const KIND_NM_Q: u8 = 4;

/// Quant discriminator byte inside quantized payloads.
const QUANT_F16: u8 = 1;
const QUANT_INT8: u8 = 2;

/// Sanity bound on any single payload (tensors in this repo are far
/// smaller; a bigger declared length means corruption).
const MAX_PAYLOAD: u64 = 1 << 33;

/// One deserialized record.
#[derive(Clone, Debug)]
pub enum SparseRecord {
    Dense(Tensor),
    Csr(CsrMatrix),
    Nm(NmMatrix),
    CsrQ(CsrQMatrix),
    NmQ(NmQMatrix),
}

/// Borrowed record for writing (no clones of the payloads).
#[derive(Clone, Copy)]
pub enum SparseRecordRef<'a> {
    Dense(&'a Tensor),
    Csr(&'a CsrMatrix),
    Nm(&'a NmMatrix),
    CsrQ(&'a CsrQMatrix),
    NmQ(&'a NmQMatrix),
}

/// CRC-32/IEEE (reflected, poly 0xEDB88320) — the integrity check behind
/// every record.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &b in data {
        crc ^= b as u32;
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32s(out: &mut Vec<u8>, v: &[f32]) {
    // bulk-copy the f32 payload (little-endian hosts lay it out as-is)
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_u32s(out: &mut Vec<u8>, v: &[u32]) {
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_u16s(out: &mut Vec<u8>, v: &[u16]) {
    for &x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_i8s(out: &mut Vec<u8>, v: &[i8]) {
    for &x in v {
        out.push(x as u8);
    }
}

/// Quant byte + value payload, shared by both quantized record kinds.
fn put_quant_values(out: &mut Vec<u8>, values: &QuantValues) {
    match values {
        QuantValues::F16(h) => {
            out.push(QUANT_F16);
            put_u16s(out, h);
        }
        QuantValues::Int8 { q, scales } => {
            out.push(QUANT_INT8);
            put_i8s(out, q);
            put_f32s(out, scales);
        }
    }
}

fn encode_payload(rec: &SparseRecordRef<'_>) -> Result<Vec<u8>> {
    use super::cast::u32_field;
    Ok(match rec {
        SparseRecordRef::Dense(t) => {
            let mut out = Vec::with_capacity(4 + 8 * t.shape().len() + 4 * t.len());
            put_u32(&mut out, u32_field(t.shape().len(), "dense ndim")?);
            for &d in t.shape() {
                put_u64(&mut out, d as u64);
            }
            put_f32s(&mut out, t.data());
            out
        }
        SparseRecordRef::Csr(c) => {
            let mut out =
                Vec::with_capacity(24 + 4 * c.indptr.len() + 4 * c.indices.len() + 4 * c.values.len());
            put_u64(&mut out, c.rows as u64);
            put_u64(&mut out, c.cols as u64);
            put_u64(&mut out, c.nnz() as u64);
            put_u32s(&mut out, &c.indptr);
            put_u32s(&mut out, &c.indices);
            put_f32s(&mut out, &c.values);
            out
        }
        SparseRecordRef::Nm(p) => {
            let mut out = Vec::with_capacity(32 + 5 * p.values.len());
            put_u64(&mut out, p.rows as u64);
            put_u64(&mut out, p.cols as u64);
            put_u32(&mut out, u32_field(p.n, "n:m pattern n")?);
            put_u32(&mut out, u32_field(p.m, "n:m pattern m")?);
            put_u64(&mut out, p.values.len() as u64);
            put_f32s(&mut out, &p.values);
            out.extend_from_slice(&p.indices);
            out
        }
        SparseRecordRef::CsrQ(c) => {
            let mut out = Vec::with_capacity(
                25 + 4 * c.indptr.len() + 4 * c.indices.len() + c.values.bytes(),
            );
            put_u64(&mut out, c.rows as u64);
            put_u64(&mut out, c.cols as u64);
            put_u64(&mut out, c.nnz() as u64);
            put_u32s(&mut out, &c.indptr);
            put_u32s(&mut out, &c.indices);
            put_quant_values(&mut out, &c.values);
            out
        }
        SparseRecordRef::NmQ(p) => {
            let mut out = Vec::with_capacity(33 + 3 * p.indices.len() + p.values.bytes());
            put_u64(&mut out, p.rows as u64);
            put_u64(&mut out, p.cols as u64);
            put_u32(&mut out, u32_field(p.n, "n:m pattern n")?);
            put_u32(&mut out, u32_field(p.m, "n:m pattern m")?);
            put_u64(&mut out, p.values.len() as u64);
            put_quant_values(&mut out, &p.values);
            out.extend_from_slice(&p.indices);
            out
        }
    })
}

fn kind_of(rec: &SparseRecordRef<'_>) -> u8 {
    match rec {
        SparseRecordRef::Dense(_) => KIND_DENSE,
        SparseRecordRef::Csr(_) => KIND_CSR,
        SparseRecordRef::Nm(_) => KIND_NM,
        SparseRecordRef::CsrQ(_) => KIND_CSR_Q,
        SparseRecordRef::NmQ(_) => KIND_NM_Q,
    }
}

/// Write records in the order given.
pub fn write_records(path: &Path, entries: &[(String, SparseRecordRef<'_>)]) -> Result<()> {
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let file =
        std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?;
    let mut w = BufWriter::new(file);
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    w.write_all(&super::cast::u32_field(entries.len(), "record count")?.to_le_bytes())?;
    for (name, rec) in entries {
        let nb = name.as_bytes();
        w.write_all(&super::cast::u32_field(nb.len(), "record name length")?.to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&[kind_of(rec)])?;
        let payload = encode_payload(rec)?;
        w.write_all(&(payload.len() as u64).to_le_bytes())?;
        w.write_all(&payload)?;
        w.write_all(&crc32(&payload).to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Little-endian cursor over one record's payload; every read is
/// bounds-checked so a short payload is a checked error.
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
    name: &'a str,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("record '{}': payload truncated (corrupt artifact)", self.name);
        }
        // fp-lint: allow(hot-index) — range checked on the line above
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        // fp-lint: allow(hot-index) — take(1) guarantees one byte
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        // fp-lint: allow(hot-panic) — try_into on a take(4) slice is infallible
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        // fp-lint: allow(hot-panic) — try_into on a take(8) slice is infallible
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let raw = self.take(4 * n)?;
        // fp-lint: allow(hot-panic) — try_into on chunks_exact(4) is infallible
        Ok(raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        let raw = self.take(4 * n)?;
        // fp-lint: allow(hot-panic) — try_into on chunks_exact(4) is infallible
        Ok(raw.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn u16s(&mut self, n: usize) -> Result<Vec<u16>> {
        let raw = self.take(2 * n)?;
        // fp-lint: allow(hot-panic) — try_into on chunks_exact(2) is infallible
        Ok(raw.chunks_exact(2).map(|c| u16::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn i8s(&mut self, n: usize) -> Result<Vec<i8>> {
        Ok(self.take(n)?.iter().map(|&b| b as i8).collect())
    }

    fn done(&self) -> Result<()> {
        if self.i != self.b.len() {
            bail!(
                "record '{}': {} trailing payload bytes (corrupt artifact)",
                self.name,
                self.b.len() - self.i
            );
        }
        Ok(())
    }
}

fn count_checked(v: u64, what: &str, name: &str) -> Result<usize> {
    if v > MAX_PAYLOAD {
        bail!("record '{name}': implausible {what} {v} (corrupt artifact)");
    }
    Ok(v as usize)
}

/// Decode a quant byte + value payload (`len` kept values spread over
/// `rows` rows — int8 carries one f32 scale per row).
fn read_quant_values(c: &mut Cursor<'_>, len: usize, rows: usize) -> Result<QuantValues> {
    match c.u8()? {
        QUANT_F16 => Ok(QuantValues::F16(c.u16s(len)?)),
        QUANT_INT8 => {
            let q = c.i8s(len)?;
            let scales = c.f32s(rows)?;
            if scales.iter().any(|s| !s.is_finite() || *s < 0.0) {
                bail!("record '{}': invalid int8 scale (corrupt artifact)", c.name);
            }
            Ok(QuantValues::Int8 { q, scales })
        }
        other => bail!("record '{}': unknown quant mode {other} (corrupt artifact)", c.name),
    }
}

fn decode_payload(name: &str, kind: u8, payload: &[u8]) -> Result<SparseRecord> {
    let mut c = Cursor { b: payload, i: 0, name };
    match kind {
        KIND_DENSE => {
            let ndim = c.u32()? as usize;
            if ndim > 8 {
                bail!("record '{name}': ndim {ndim} (corrupt artifact)");
            }
            let mut dims = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                dims.push(count_checked(c.u64()?, "dimension", name)?);
            }
            // checked product: corrupt dims must not overflow-panic
            let len = dims
                .iter()
                .try_fold(1usize, |acc, &d| acc.checked_mul(d))
                .filter(|&l| l as u64 <= MAX_PAYLOAD)
                .with_context(|| {
                    format!("record '{name}': implausible tensor shape (corrupt artifact)")
                })?;
            let data = c.f32s(len)?;
            c.done()?;
            Ok(SparseRecord::Dense(Tensor::from_vec(dims, data)))
        }
        KIND_CSR => {
            let rows = count_checked(c.u64()?, "row count", name)?;
            let cols = count_checked(c.u64()?, "column count", name)?;
            let nnz = count_checked(c.u64()?, "nnz", name)?;
            if nnz > rows.saturating_mul(cols) {
                bail!("record '{name}': nnz {nnz} > rows*cols (corrupt artifact)");
            }
            let indptr = c.u32s(rows + 1)?;
            let indices = c.u32s(nnz)?;
            let values = c.f32s(nnz)?;
            c.done()?;
            if indptr.first() != Some(&0) || indptr.last().copied() != Some(nnz as u32) {
                bail!("record '{name}': indptr endpoints do not match nnz (corrupt artifact)");
            }
            // fp-lint: allow(hot-index) — windows(2) yields exactly two elements
            if indptr.windows(2).any(|w| w[0] > w[1]) {
                bail!("record '{name}': indptr not monotonic (corrupt artifact)");
            }
            if indices.iter().any(|&j| j as usize >= cols) {
                bail!("record '{name}': column index out of range (corrupt artifact)");
            }
            Ok(SparseRecord::Csr(CsrMatrix { rows, cols, indptr, indices, values }))
        }
        KIND_NM => {
            let rows = count_checked(c.u64()?, "row count", name)?;
            let cols = count_checked(c.u64()?, "column count", name)?;
            let n = c.u32()? as usize;
            let m = c.u32()? as usize;
            if m == 0 || n == 0 || n > m || m > 256 {
                bail!("record '{name}': degenerate {n}:{m} pattern (corrupt artifact)");
            }
            if cols % m != 0 {
                bail!("record '{name}': cols {cols} not divisible by m {m} (corrupt artifact)");
            }
            let slots = count_checked(c.u64()?, "slot count", name)?;
            // checked product: corrupt rows/cols must not overflow-panic
            let want = rows
                .checked_mul(cols / m)
                .and_then(|g| g.checked_mul(n))
                .with_context(|| {
                    format!("record '{name}': implausible n:m shape (corrupt artifact)")
                })?;
            if slots != want {
                bail!("record '{name}': slot count {slots} does not match shape (corrupt artifact)");
            }
            let values = c.f32s(slots)?;
            let indices = c.take(slots)?.to_vec();
            c.done()?;
            if indices.iter().any(|&j| j as usize >= m) {
                bail!("record '{name}': in-group index out of range (corrupt artifact)");
            }
            Ok(SparseRecord::Nm(NmMatrix { rows, cols, n, m, values, indices }))
        }
        KIND_CSR_Q => {
            let rows = count_checked(c.u64()?, "row count", name)?;
            let cols = count_checked(c.u64()?, "column count", name)?;
            let nnz = count_checked(c.u64()?, "nnz", name)?;
            if nnz > rows.saturating_mul(cols) {
                bail!("record '{name}': nnz {nnz} > rows*cols (corrupt artifact)");
            }
            let indptr = c.u32s(rows + 1)?;
            let indices = c.u32s(nnz)?;
            let values = read_quant_values(&mut c, nnz, rows)?;
            c.done()?;
            if indptr.first() != Some(&0) || indptr.last().copied() != Some(nnz as u32) {
                bail!("record '{name}': indptr endpoints do not match nnz (corrupt artifact)");
            }
            // fp-lint: allow(hot-index) — windows(2) yields exactly two elements
            if indptr.windows(2).any(|w| w[0] > w[1]) {
                bail!("record '{name}': indptr not monotonic (corrupt artifact)");
            }
            if indices.iter().any(|&j| j as usize >= cols) {
                bail!("record '{name}': column index out of range (corrupt artifact)");
            }
            Ok(SparseRecord::CsrQ(CsrQMatrix { rows, cols, indptr, indices, values }))
        }
        KIND_NM_Q => {
            let rows = count_checked(c.u64()?, "row count", name)?;
            let cols = count_checked(c.u64()?, "column count", name)?;
            let n = c.u32()? as usize;
            let m = c.u32()? as usize;
            if m == 0 || n == 0 || n > m || m > 256 {
                bail!("record '{name}': degenerate {n}:{m} pattern (corrupt artifact)");
            }
            if cols % m != 0 {
                bail!("record '{name}': cols {cols} not divisible by m {m} (corrupt artifact)");
            }
            let slots = count_checked(c.u64()?, "slot count", name)?;
            let want = rows
                .checked_mul(cols / m)
                .and_then(|g| g.checked_mul(n))
                .with_context(|| {
                    format!("record '{name}': implausible n:m shape (corrupt artifact)")
                })?;
            if slots != want {
                bail!("record '{name}': slot count {slots} does not match shape (corrupt artifact)");
            }
            let values = read_quant_values(&mut c, slots, rows)?;
            let indices = c.take(slots)?.to_vec();
            c.done()?;
            if indices.iter().any(|&j| j as usize >= m) {
                bail!("record '{name}': in-group index out of range (corrupt artifact)");
            }
            Ok(SparseRecord::NmQ(NmQMatrix { rows, cols, n, m, values, indices }))
        }
        other => bail!("record '{name}': unknown record kind {other} (corrupt artifact)"),
    }
}

fn read_exact_ctx(r: &mut impl Read, buf: &mut [u8], path: &Path, what: &str) -> Result<()> {
    r.read_exact(buf)
        .with_context(|| format!("{}: truncated reading {what}", path.display()))
}

/// Read all records, preserving file order.
pub fn read_records(path: &Path) -> Result<Vec<(String, SparseRecord)>> {
    let file = std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    read_exact_ctx(&mut r, &mut magic, path, "magic")?;
    if &magic != MAGIC {
        bail!("{}: not a sparse artifact (bad magic)", path.display());
    }
    let mut v = [0u8; 4];
    read_exact_ctx(&mut r, &mut v, path, "version")?;
    let version = u32::from_le_bytes(v);
    if !(1..=VERSION).contains(&version) {
        bail!(
            "{}: artifact version {version}, this build reads versions 1..={VERSION}; \
             re-export the artifact with a matching build",
            path.display()
        );
    }
    let mut cnt = [0u8; 4];
    read_exact_ctx(&mut r, &mut cnt, path, "record count")?;
    let count = u32::from_le_bytes(cnt) as usize;
    let mut out = Vec::with_capacity(count.min(4096));
    for _ in 0..count {
        let mut nl = [0u8; 4];
        read_exact_ctx(&mut r, &mut nl, path, "record name length")?;
        let name_len = u32::from_le_bytes(nl) as usize;
        if name_len > 1 << 16 {
            bail!("{}: record name too long (corrupt artifact)", path.display());
        }
        let mut name = vec![0u8; name_len];
        read_exact_ctx(&mut r, &mut name, path, "record name")?;
        let name = String::from_utf8(name)
            .with_context(|| format!("{}: record name not utf-8", path.display()))?;
        let mut kind = [0u8; 1];
        read_exact_ctx(&mut r, &mut kind, path, "record kind")?;
        let mut len = [0u8; 8];
        read_exact_ctx(&mut r, &mut len, path, "payload length")?;
        let payload_len = u64::from_le_bytes(len);
        if payload_len > MAX_PAYLOAD {
            bail!("{}: record '{name}' declares {payload_len} payload bytes (corrupt artifact)", path.display());
        }
        let mut payload = vec![0u8; super::cast::usize_field(payload_len, "payload length")?];
        read_exact_ctx(&mut r, &mut payload, path, "record payload")?;
        let mut crc = [0u8; 4];
        read_exact_ctx(&mut r, &mut crc, path, "record checksum")?;
        let want = u32::from_le_bytes(crc);
        let got = crc32(&payload);
        if got != want {
            bail!(
                "{}: checksum mismatch in record '{name}' (stored {want:#010x}, computed \
                 {got:#010x}) — corrupt artifact",
                path.display()
            );
        }
        // fp-lint: allow(hot-index) — kind is a [u8; 1] filled by read_exact above
        let rec = decode_payload(&name, kind[0], &payload)
            .with_context(|| path.display().to_string())?;
        out.push((name, rec));
    }
    // a corrupted (shrunk) record count would otherwise pass silently
    let mut probe = [0u8; 1];
    if r.read(&mut probe)? != 0 {
        bail!("{}: trailing data after {count} records (corrupt artifact)", path.display());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Sparsity;
    use crate::pruner::round_to_sparsity;
    use crate::util::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("fsa_test_{name}_{}.fsa", std::process::id()))
    }

    fn fixture() -> (Tensor, CsrMatrix, NmMatrix) {
        let mut rng = Pcg64::seeded(3);
        let dense = Tensor::from_vec(vec![4, 8], rng.normal_vec(32, 1.0));
        let wc = round_to_sparsity(&dense, Sparsity::Unstructured(0.5));
        let csr = CsrMatrix::from_dense(&wc).unwrap();
        let wn = round_to_sparsity(&dense, Sparsity::Semi(2, 4));
        let nm = NmMatrix::from_dense(&wn, 2, 4).unwrap();
        (dense, csr, nm)
    }

    fn write_fixture(path: &std::path::Path) -> (Tensor, CsrMatrix, NmMatrix) {
        let (dense, csr, nm) = fixture();
        write_records(
            path,
            &[
                ("a.dense".into(), SparseRecordRef::Dense(&dense)),
                ("b.csr".into(), SparseRecordRef::Csr(&csr)),
                ("c.nm".into(), SparseRecordRef::Nm(&nm)),
            ],
        )
        .unwrap();
        (dense, csr, nm)
    }

    #[test]
    fn roundtrip_all_kinds() {
        let path = tmp("roundtrip");
        let (dense, csr, nm) = write_fixture(&path);
        let back = read_records(&path).unwrap();
        assert_eq!(back.len(), 3);
        match &back[0].1 {
            SparseRecord::Dense(t) => assert_eq!(t, &dense),
            other => panic!("expected dense, got {other:?}"),
        }
        match &back[1].1 {
            SparseRecord::Csr(c) => {
                assert_eq!(c.indptr, csr.indptr);
                assert_eq!(c.indices, csr.indices);
                assert_eq!(c.values, csr.values);
                assert_eq!(c.to_dense(), csr.to_dense());
            }
            other => panic!("expected csr, got {other:?}"),
        }
        match &back[2].1 {
            SparseRecord::Nm(p) => {
                assert_eq!(p.values, nm.values);
                assert_eq!(p.indices, nm.indices);
                assert_eq!(p.to_dense(), nm.to_dense());
            }
            other => panic!("expected nm, got {other:?}"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn quantized_records_roundtrip_both_modes() {
        use crate::config::QuantMode;
        let (_, csr, nm) = fixture();
        for mode in [QuantMode::F16, QuantMode::Int8] {
            let cq = CsrQMatrix::from_csr(&csr, mode).unwrap();
            let nq = NmQMatrix::from_nm(&nm, mode).unwrap();
            let path = tmp(&format!("quant_{}", mode.label()));
            write_records(
                &path,
                &[
                    ("a.csrq".into(), SparseRecordRef::CsrQ(&cq)),
                    ("b.nmq".into(), SparseRecordRef::NmQ(&nq)),
                ],
            )
            .unwrap();
            let back = read_records(&path).unwrap();
            match &back[0].1 {
                SparseRecord::CsrQ(c) => {
                    assert_eq!(c.quant_mode(), mode);
                    assert_eq!(c.indptr, cq.indptr);
                    assert_eq!(c.indices, cq.indices);
                    assert_eq!(c.to_dense(), cq.to_dense(), "{mode:?}: values must be bitwise");
                }
                other => panic!("expected csrq, got {other:?}"),
            }
            match &back[1].1 {
                SparseRecord::NmQ(p) => {
                    assert_eq!(p.quant_mode(), mode);
                    assert_eq!(p.indices, nq.indices);
                    assert_eq!(p.to_dense(), nq.to_dense(), "{mode:?}: values must be bitwise");
                }
                other => panic!("expected nmq, got {other:?}"),
            }
            std::fs::remove_file(&path).ok();
        }
    }

    #[test]
    fn reads_v1_artifacts() {
        // a v1 file is byte-identical to a v2 file holding only v1 kinds,
        // modulo the version field
        let path = tmp("v1");
        write_fixture(&path);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4..8].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        assert_eq!(read_records(&path).unwrap().len(), 3);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_unknown_quant_mode() {
        // hand-crafted CSR_Q record with quant byte 9: rows=1, cols=2,
        // nnz=1, indptr [0,1], indices [0]
        let mut payload = Vec::new();
        put_u64(&mut payload, 1);
        put_u64(&mut payload, 2);
        put_u64(&mut payload, 1);
        put_u32s(&mut payload, &[0, 1]);
        put_u32s(&mut payload, &[0]);
        payload.push(9);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&3u32.to_le_bytes());
        bytes.extend_from_slice(b"bad");
        bytes.push(KIND_CSR_Q);
        bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&payload);
        bytes.extend_from_slice(&crc32(&payload).to_le_bytes());
        let path = tmp("badquant");
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", read_records(&path).unwrap_err());
        assert!(err.contains("unknown quant mode 9"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic_and_version() {
        let path = tmp("magic");
        write_fixture(&path);
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        let err = read_records(&path).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
        // version skew: patch the version field
        bytes[0] = b'F';
        bytes[4..8].copy_from_slice(&99u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", read_records(&path).unwrap_err());
        assert!(err.contains("version 99"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_truncation_and_bitflips() {
        let path = tmp("corrupt");
        write_fixture(&path);
        let bytes = std::fs::read(&path).unwrap();
        // truncate at several depths: header, mid-record, final checksum
        for keep in [3usize, 10, bytes.len() / 2, bytes.len() - 2] {
            std::fs::write(&path, &bytes[..keep]).unwrap();
            let err = format!("{:#}", read_records(&path).unwrap_err());
            assert!(err.contains("truncated") || err.contains("corrupt"), "keep {keep}: {err}");
        }
        // flip one byte inside the first record's payload: the first
        // record starts after the 12-byte header with name "a.dense"
        // (4 + 7 bytes), kind (1) and length (8) — payload starts at 32.
        let mut flipped = bytes.clone();
        flipped[36] ^= 0x40;
        std::fs::write(&path, &flipped).unwrap();
        let err = read_records(&path).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_inconsistent_payloads() {
        // a CSR record whose indices point past cols
        let csr = CsrMatrix {
            rows: 1,
            cols: 2,
            indptr: vec![0, 1],
            indices: vec![5],
            values: vec![1.0],
        };
        let path = tmp("inconsistent");
        write_records(&path, &[("bad".into(), SparseRecordRef::Csr(&csr))]).unwrap();
        let err = format!("{:#}", read_records(&path).unwrap_err());
        assert!(err.contains("out of range"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_overflowing_shapes_without_panicking() {
        // dims that pass the per-value bound but overflow usize when
        // multiplied must be a checked error, not a multiply panic
        let mut payload = Vec::new();
        put_u32(&mut payload, 2);
        put_u64(&mut payload, 1u64 << 33);
        put_u64(&mut payload, 1u64 << 33);
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&3u32.to_le_bytes());
        bytes.extend_from_slice(b"big");
        bytes.push(KIND_DENSE);
        bytes.extend_from_slice(&(payload.len() as u64).to_le_bytes());
        bytes.extend_from_slice(&payload);
        bytes.extend_from_slice(&crc32(&payload).to_le_bytes());
        let path = tmp("overflow");
        std::fs::write(&path, &bytes).unwrap();
        let err = format!("{:#}", read_records(&path).unwrap_err());
        assert!(err.contains("implausible"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn crc32_matches_known_vector() {
        // IEEE check value for "123456789"
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }
}

//! Sparse model artifacts: a compiled pruned model
//! (`sparse::compile::CompiledLayers`) persisted as a `.fsa` container
//! (see [`super::sparsefile`] for the binary layout and integrity
//! checks) plus a `.meta.json` sidecar recording the model spec name,
//! sparsity target, storage format and prune provenance.
//!
//! This is the durable form of the paper's "substantial memory
//! conservation": the pruner writes the artifact once, straight from its
//! output (`prune --emit-sparse`), and every consumer
//! (`serve --artifact`, `serve-bench --artifact`, `eval --artifact`)
//! loads compressed operators directly — O(nnz) I/O, no dense
//! checkpoint round-trip, no recompress-at-startup, and never more than
//! one copy of any pruned weight in memory.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::{repo_root, Presets, QuantMode, SparseFormat, Sparsity};
use crate::sparse::{CompiledLayers, SparseOp};
use crate::tensor::Tensor;

use super::json::Json;
use super::sparsefile::{self, SparseRecord, SparseRecordRef};

/// Provenance + identity stored in the `.meta.json` sidecar.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub model: String,
    pub corpus: String,
    /// Pruning method that produced the weights ("fista", "wanda", ...).
    pub method: String,
    /// Sparsity target label ("50%", "2:4"), `Sparsity::parse`-able.
    pub sparsity: String,
    /// Requested storage format axis ("csr" | "nm" | "auto").
    pub format: String,
    /// Value quantization axis ("none" | "f16" | "int8"). v1 sidecars
    /// predate the field and default to "none".
    pub quant: String,
    pub seed: u64,
    /// Optional structured prune diagnostics
    /// (`pruner::PruneReport::provenance_json`).
    pub prune: Option<Json>,
}

impl ArtifactMeta {
    fn to_json(&self, compiled: &CompiledLayers) -> Json {
        let mut m = BTreeMap::new();
        m.insert("artifact_version".into(), Json::Num(sparsefile::VERSION as f64));
        m.insert("model".into(), Json::Str(self.model.clone()));
        m.insert("corpus".into(), Json::Str(self.corpus.clone()));
        m.insert("method".into(), Json::Str(self.method.clone()));
        m.insert("sparsity".into(), Json::Str(self.sparsity.clone()));
        m.insert("format".into(), Json::Str(self.format.clone()));
        m.insert("quant".into(), Json::Str(self.quant.clone()));
        // u64 must not round-trip through f64 (see ser::json::Json::as_u64)
        m.insert("seed".into(), Json::Str(self.seed.to_string()));
        if let Some(p) = &self.prune {
            m.insert("prune".into(), p.clone());
        }
        let (csr, nm) = compiled.format_counts();
        let mut stats = BTreeMap::new();
        stats.insert("ops".into(), Json::Num(compiled.op_count() as f64));
        stats.insert("csr_ops".into(), Json::Num(csr as f64));
        stats.insert("nm_ops".into(), Json::Num(nm as f64));
        stats.insert("nnz".into(), Json::Num(compiled.nnz() as f64));
        stats.insert("density".into(), Json::Num(compiled.density()));
        stats.insert("storage_bytes".into(), Json::Num(compiled.storage_bytes() as f64));
        stats.insert("resident_bytes".into(), Json::Num(compiled.resident_bytes() as f64));
        m.insert("stats".into(), Json::Obj(stats));
        Json::Obj(m)
    }

    fn from_json(v: &Json) -> Result<ArtifactMeta> {
        let version = super::cast::u32_field(
            v.req("artifact_version")?.as_usize().context("artifact_version")?,
            "artifact_version",
        )?;
        if !(1..=sparsefile::VERSION).contains(&version) {
            bail!(
                "artifact sidecar version {version}, this build reads versions 1..={}",
                sparsefile::VERSION
            );
        }
        Ok(ArtifactMeta {
            model: v.req("model")?.as_str().context("model")?.to_string(),
            corpus: v.req("corpus")?.as_str().context("corpus")?.to_string(),
            method: v.req("method")?.as_str().context("method")?.to_string(),
            sparsity: v.req("sparsity")?.as_str().context("sparsity")?.to_string(),
            format: v.req("format")?.as_str().context("format")?.to_string(),
            // v1 sidecars predate the quant axis: f32 values
            quant: match v.get("quant") {
                Some(q) => q.as_str().context("quant")?.to_string(),
                None => "none".to_string(),
            },
            seed: v.req("seed")?.as_u64().context("seed (u64)")?,
            prune: v.get("prune").cloned(),
        })
    }
}

/// Sidecar location next to the `.fsa` payload.
pub fn meta_path(path: &Path) -> PathBuf {
    path.with_extension("meta.json")
}

/// Guard against driving an artifact under the wrong `--model` flag —
/// shared by every CLI artifact entry point (eval, serve, serve-bench).
/// `expected = None` (flag not given) accepts any artifact.
pub fn check_model(meta: &ArtifactMeta, expected: Option<&str>) -> Result<()> {
    if let Some(m) = expected {
        if m != meta.model {
            bail!("artifact is for model '{}', --model says '{m}'", meta.model);
        }
    }
    Ok(())
}

/// True if both the payload and the sidecar exist.
pub fn exists(path: &Path) -> bool {
    path.exists() && meta_path(path).exists()
}

/// Save a compiled model: `<path>` (binary records) + `<path>.meta.json`.
/// Compressed operators are serialized as compressed — the dense form of
/// a pruned weight is never materialized on either side.
pub fn save(path: &Path, compiled: &CompiledLayers, meta: &ArtifactMeta) -> Result<()> {
    if meta.quant != compiled.quant.label() {
        bail!(
            "sidecar declares quant '{}' but the compiled model is '{}'",
            meta.quant,
            compiled.quant.label()
        );
    }
    let mut entries: Vec<(String, SparseRecordRef<'_>)> = Vec::new();
    for (name, op) in compiled.iter_ops() {
        let rec = match op {
            SparseOp::Csr(c) => SparseRecordRef::Csr(c),
            SparseOp::Nm(p) => SparseRecordRef::Nm(p),
            SparseOp::CsrQ(c) => SparseRecordRef::CsrQ(c),
            SparseOp::NmQ(p) => SparseRecordRef::NmQ(p),
        };
        entries.push((name, rec));
    }
    for (name, t) in compiled.iter_residual() {
        entries.push((name, SparseRecordRef::Dense(t)));
    }
    sparsefile::write_records(path, &entries)?;
    std::fs::write(meta_path(path), meta.to_json(compiled).to_string_compact())
        .with_context(|| format!("write {}", meta_path(path).display()))?;
    Ok(())
}

/// Load a sparse artifact back into a validated [`CompiledLayers`]. All
/// failure modes — missing sidecar, unknown model, version skew,
/// truncation, checksum mismatch, missing/extra/mis-shaped records — are
/// checked errors.
pub fn load(path: &Path) -> Result<(CompiledLayers, ArtifactMeta)> {
    let sidecar = meta_path(path);
    let meta = ArtifactMeta::from_json(&Json::parse_file(&sidecar)?)
        .with_context(|| format!("artifact sidecar {}", sidecar.display()))?;
    let presets = Presets::load(&repo_root()?)?;
    let spec = presets
        .model(&meta.model)
        .with_context(|| format!("artifact names unknown model '{}'", meta.model))?
        .clone();
    let format = SparseFormat::parse(&meta.format)
        .with_context(|| format!("artifact sidecar {}", sidecar.display()))?;
    let quant = QuantMode::parse(&meta.quant)
        .with_context(|| format!("artifact sidecar {}", sidecar.display()))?;
    let sparsity = Sparsity::parse(&meta.sparsity).ok();

    let mut ops: Vec<BTreeMap<String, SparseOp>> =
        (0..spec.layers).map(|_| BTreeMap::new()).collect();
    let mut layer_residual: Vec<BTreeMap<String, Tensor>> =
        (0..spec.layers).map(|_| BTreeMap::new()).collect();
    let mut globals: BTreeMap<String, Tensor> = BTreeMap::new();
    for (name, rec) in sparsefile::read_records(path)? {
        let split = crate::sparse::compile::split_layer_name(&name);
        match rec {
            SparseRecord::Csr(c) => place_op(&mut ops, &name, split, SparseOp::Csr(c))?,
            SparseRecord::Nm(p) => place_op(&mut ops, &name, split, SparseOp::Nm(p))?,
            SparseRecord::CsrQ(c) => place_op(&mut ops, &name, split, SparseOp::CsrQ(c))?,
            SparseRecord::NmQ(p) => place_op(&mut ops, &name, split, SparseOp::NmQ(p))?,
            SparseRecord::Dense(t) => match split {
                Some((li, bare)) => {
                    let bare = bare.to_string();
                    let layer = layer_residual.get_mut(li).with_context(|| {
                        format!("record '{name}' names layer {li} beyond the model")
                    })?;
                    layer.insert(bare, t);
                }
                None => {
                    globals.insert(name.clone(), t);
                }
            },
        }
    }
    let compiled =
        CompiledLayers::from_parts(spec, format, sparsity, quant, ops, layer_residual, globals)
            .with_context(|| format!("validating {}", path.display()))?;
    Ok((compiled, meta))
}

fn place_op(
    ops: &mut [BTreeMap<String, SparseOp>],
    name: &str,
    split: Option<(usize, &str)>,
    op: SparseOp,
) -> Result<()> {
    let Some((li, bare)) = split else {
        bail!("compressed record '{name}' is not a layer operator");
    };
    let layer = ops
        .get_mut(li)
        .with_context(|| format!("record '{name}' names layer {li} beyond the model"))?;
    layer.insert(bare.to_string(), op);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::init::init_params;
    use crate::pruner::round_model_to_sparsity;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("fp_artifact_{name}_{}.fsa", std::process::id()))
    }

    fn compiled_fixture(format: SparseFormat, sp: Sparsity) -> CompiledLayers {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap().clone();
        let params = round_model_to_sparsity(&spec, &init_params(&spec, 11), sp).unwrap();
        CompiledLayers::compress(&spec, &params, format, Some(sp)).unwrap()
    }

    fn meta_fixture(format: &str, sparsity: &str) -> ArtifactMeta {
        ArtifactMeta {
            model: "topt-s1".into(),
            corpus: "c4-syn".into(),
            method: "magnitude".into(),
            sparsity: sparsity.into(),
            format: format.into(),
            quant: "none".into(),
            seed: u64::MAX,
            prune: None,
        }
    }

    #[test]
    fn save_load_roundtrip_preserves_everything() {
        for (format, sp, label) in [
            (SparseFormat::Csr, Sparsity::Unstructured(0.5), "50%"),
            (SparseFormat::Auto, Sparsity::Semi(2, 4), "2:4"),
        ] {
            let c = compiled_fixture(format, sp);
            let path = tmp(&format!("rt_{}", format.label()));
            save(&path, &c, &meta_fixture(format.label(), label)).unwrap();
            assert!(exists(&path));
            let (back, meta) = load(&path).unwrap();
            assert_eq!(meta.model, "topt-s1");
            assert_eq!(meta.seed, u64::MAX, "u64 seed must round-trip exactly");
            assert_eq!(meta.sparsity, label);
            assert_eq!(back.op_count(), c.op_count());
            assert_eq!(back.nnz(), c.nnz());
            assert_eq!(back.storage_bytes(), c.storage_bytes());
            assert_eq!(back.resident_bytes(), c.resident_bytes());
            assert_eq!(back.format_counts(), c.format_counts());
            // compiled forwards agree bitwise
            let tokens: Vec<i32> = (0..12).map(|i| (i * 5 + 1) % 96).collect();
            let a = crate::sparse::compiled_logits(&c, &tokens);
            let b = crate::sparse::compiled_logits(&back, &tokens);
            for (x, y) in a.data().iter().zip(b.data()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            std::fs::remove_file(&path).ok();
            std::fs::remove_file(meta_path(&path)).ok();
        }
    }

    #[test]
    fn quantized_artifacts_roundtrip_end_to_end() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap().clone();
        let sp = Sparsity::Semi(2, 4);
        let params = round_model_to_sparsity(&spec, &init_params(&spec, 11), sp).unwrap();
        for quant in [QuantMode::F16, QuantMode::Int8] {
            let c = CompiledLayers::compress_quantized(
                &spec,
                &params,
                SparseFormat::Auto,
                Some(sp),
                quant,
            )
            .unwrap();
            let mut meta = meta_fixture("auto", "2:4");
            meta.quant = quant.label().into();
            let path = tmp(&format!("quant_{}", quant.label()));
            save(&path, &c, &meta).unwrap();
            let (back, meta) = load(&path).unwrap();
            assert_eq!(meta.quant, quant.label());
            assert_eq!(back.quant, quant);
            assert_eq!(back.nnz(), c.nnz());
            assert_eq!(back.storage_bytes(), c.storage_bytes());
            // quantized compiled forwards agree bitwise across the disk trip
            let tokens: Vec<i32> = (0..12).map(|i| (i * 5 + 1) % 96).collect();
            let a = crate::sparse::compiled_logits(&c, &tokens);
            let b = crate::sparse::compiled_logits(&back, &tokens);
            for (x, y) in a.data().iter().zip(b.data()) {
                assert_eq!(x.to_bits(), y.to_bits());
            }
            std::fs::remove_file(&path).ok();
            std::fs::remove_file(meta_path(&path)).ok();
        }
        // sidecar/compiled quant mismatch is a checked save error
        let c = compiled_fixture(SparseFormat::Csr, Sparsity::Unstructured(0.5));
        let mut meta = meta_fixture("csr", "50%");
        meta.quant = "int8".into();
        let err = save(&tmp("mismatch"), &c, &meta).unwrap_err().to_string();
        assert!(err.contains("quant 'int8'"), "{err}");
    }

    #[test]
    fn v1_sidecar_without_quant_field_reads_as_none() {
        let c = compiled_fixture(SparseFormat::Csr, Sparsity::Unstructured(0.5));
        let path = tmp("v1_sidecar");
        save(&path, &c, &meta_fixture("csr", "50%")).unwrap();
        // rewrite the sidecar the way a v1 build laid it out: version 1,
        // no quant key (the .fsa payload must be patched to v1 too)
        let sidecar = meta_path(&path);
        let text = std::fs::read_to_string(&sidecar).unwrap();
        let text = text
            .replace("\"artifact_version\":2", "\"artifact_version\":1")
            .replace("\"quant\":\"none\",", "");
        std::fs::write(&sidecar, text).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4..8].copy_from_slice(&1u32.to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let (back, meta) = load(&path).unwrap();
        assert_eq!(meta.quant, "none");
        assert_eq!(back.quant, QuantMode::None);
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&sidecar).ok();
    }

    #[test]
    fn missing_sidecar_and_wrong_model_fail() {
        let c = compiled_fixture(SparseFormat::Csr, Sparsity::Unstructured(0.5));
        let path = tmp("nosidecar");
        save(&path, &c, &meta_fixture("csr", "50%")).unwrap();
        std::fs::remove_file(meta_path(&path)).unwrap();
        assert!(!exists(&path));
        assert!(load(&path).is_err());
        // wrong model in the sidecar: records no longer match the spec
        let mut meta = meta_fixture("csr", "50%");
        meta.model = "tllama-s1".into();
        save(&path, &c, &meta).unwrap();
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(err.contains("missing") || err.contains("unexpected"), "{err}");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(meta_path(&path)).ok();
    }

    #[test]
    fn sidecar_version_skew_is_rejected() {
        let c = compiled_fixture(SparseFormat::Csr, Sparsity::Unstructured(0.5));
        let path = tmp("sidecar_skew");
        save(&path, &c, &meta_fixture("csr", "50%")).unwrap();
        let sidecar = meta_path(&path);
        let text = std::fs::read_to_string(&sidecar).unwrap();
        std::fs::write(&sidecar, text.replace("\"artifact_version\":2", "\"artifact_version\":9"))
            .unwrap();
        let err = format!("{:#}", load(&path).unwrap_err());
        assert!(err.contains("version 9"), "{err}");
        std::fs::remove_file(&path).ok();
        std::fs::remove_file(&sidecar).ok();
    }
}

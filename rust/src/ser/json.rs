//! A small, strict JSON parser and writer.
//!
//! Substrate for `serde_json` (not vendored in the offline image). Covers
//! the full JSON grammar needed by configs/presets.json and
//! artifacts/manifest.json: objects, arrays, strings with escapes, numbers
//! (incl. scientific notation), booleans, null. Keys keep insertion order.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Self::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?)
    }

    // ---- typed accessors ----

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Object field or error (for required config keys).
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key).ok_or_else(|| anyhow::anyhow!("missing json key '{key}'"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    /// Exact u64 accessor. JSON numbers ride through `f64`, which silently
    /// corrupts integers above 2^53 — so u64 fields (seeds) are written as
    /// *strings* and read back here. Accepts a numeric value only when it
    /// is a non-negative integer *strictly below* 2^53 (every such integer
    /// is exactly representable; 2^53 itself is ambiguous with 2^53 + 1,
    /// which rounds onto it); anything else (including a legacy too-large
    /// `Num`) is `None`, which callers surface as a checked error rather
    /// than a corrupted value.
    pub fn as_u64(&self) -> Option<u64> {
        const EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
        match self {
            Json::Str(s) => s.parse().ok(),
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 && *x < EXACT => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|_| self.err("bad utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).map_err(|_| self.err("bad number"))?;
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s\"q",null,true],"o":{"n":-3}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parses_presets_file() {
        // presets.json lives at the repository root, not the crate root
        let path = crate::config::repo_root().unwrap().join("configs/presets.json");
        let v = Json::parse_file(&path).unwrap();
        assert!(v.get("families").is_some());
        assert_eq!(v.get("vocab_size").unwrap().as_usize(), Some(96));
    }
}

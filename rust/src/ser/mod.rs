//! Serialization substrates: a JSON parser/writer (serde is not available
//! in the offline image), a binary tensor/checkpoint format, and the
//! sparse-artifact container that persists compiled pruned models
//! (compressed operators + residual dense params) without a dense
//! round-trip.

pub mod artifact;
pub(crate) mod cast;
pub mod checkpoint;
pub mod json;
pub mod sparsefile;
pub mod tensorfile;

pub use json::Json;

//! Serialization substrates: a JSON parser/writer (serde is not available
//! in the offline image) and a binary tensor/checkpoint format.

pub mod checkpoint;
pub mod json;
pub mod tensorfile;

pub use json::Json;

//! Checked width conversions for the binary container encoders.
//!
//! Every fixed-width header field in the `.fpt` / sparse-artifact layouts
//! is narrower than `usize` on 64-bit hosts, so a plain `as` cast would
//! silently truncate an oversized count and write a self-inconsistent —
//! but checksummed-as-valid — file. These helpers turn that corruption
//! into a typed encode-time error naming the field.

use anyhow::{anyhow, Result};

/// `usize` → a u32 on-disk field; errors past `u32::MAX` instead of
/// wrapping.
pub(crate) fn u32_field(v: usize, what: &str) -> Result<u32> {
    u32::try_from(v).map_err(|_| anyhow!("{what} {v} exceeds the format's u32 field"))
}

/// A declared u64 on-disk length → an in-memory `usize`; errors on
/// 32-bit hosts reading a file produced on a larger machine.
pub(crate) fn usize_field(v: u64, what: &str) -> Result<usize> {
    usize::try_from(v).map_err(|_| anyhow!("{what} {v} does not fit this platform's usize"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u32_field_accepts_the_exact_boundary_and_rejects_one_past_it() {
        assert_eq!(u32_field(0, "count").unwrap(), 0);
        assert_eq!(u32_field(u32::MAX as usize, "count").unwrap(), u32::MAX);
        let err = u32_field(u32::MAX as usize + 1, "record count").unwrap_err();
        assert!(err.to_string().contains("record count"), "{err}");
        assert!(err.to_string().contains("4294967296"), "{err}");
    }

    #[test]
    #[cfg(target_pointer_width = "64")]
    fn usize_field_round_trips_on_64_bit() {
        assert_eq!(usize_field(u64::from(u32::MAX) + 1, "payload").unwrap(), 1 << 32);
    }
}

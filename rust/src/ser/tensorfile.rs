//! `.fpt` — a minimal binary multi-tensor container (npz substrate).
//!
//! Layout (little-endian):
//! ```text
//!   magic   "FPT1" (4 bytes)
//!   count   u32
//!   repeat count times:
//!     name_len u32, name utf-8 bytes
//!     ndim     u32, dims u64 × ndim
//!     data     f32 × prod(dims)
//! ```
//! Used for model checkpoints, optimizer state and cached Gram matrices.

use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"FPT1";

/// Write named tensors; entries are written in the order given.
pub fn write_tensors(path: &Path, entries: &[(String, &Tensor)]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path).with_context(|| format!("create {}", path.display()))?);
    w.write_all(MAGIC)?;
    w.write_all(&super::cast::u32_field(entries.len(), "tensor count")?.to_le_bytes())?;
    for (name, t) in entries {
        let nb = name.as_bytes();
        w.write_all(&super::cast::u32_field(nb.len(), "tensor name length")?.to_le_bytes())?;
        w.write_all(nb)?;
        w.write_all(&super::cast::u32_field(t.shape().len(), "tensor ndim")?.to_le_bytes())?;
        for &d in t.shape() {
            w.write_all(&(d as u64).to_le_bytes())?;
        }
        // bulk-write the f32 payload
        let data = t.data();
        let bytes = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
        w.write_all(bytes)?;
    }
    w.flush()?;
    Ok(())
}

/// Read all tensors, preserving insertion order in the returned Vec and
/// providing a name index.
pub fn read_tensors(path: &Path) -> Result<Vec<(String, Tensor)>> {
    let mut r = BufReader::new(std::fs::File::open(path).with_context(|| format!("open {}", path.display()))?);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not an FPT1 file", path.display());
    }
    let count = read_u32(&mut r)? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 1 << 20 {
            bail!("corrupt tensorfile: name too long");
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).context("tensor name not utf-8")?;
        let ndim = read_u32(&mut r)? as usize;
        if ndim > 8 {
            bail!("corrupt tensorfile: ndim {ndim}");
        }
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            dims.push(u64::from_le_bytes(b) as usize);
        }
        let len: usize = dims.iter().product();
        if len > 1 << 30 {
            bail!("corrupt tensorfile: tensor too large");
        }
        let mut data = vec![0f32; len];
        let bytes = unsafe { std::slice::from_raw_parts_mut(data.as_mut_ptr() as *mut u8, len * 4) };
        r.read_exact(bytes)?;
        out.push((name, Tensor::from_vec(dims, data)));
    }
    Ok(out)
}

/// Read into a name → tensor map.
pub fn read_tensor_map(path: &Path) -> Result<BTreeMap<String, Tensor>> {
    Ok(read_tensors(path)?.into_iter().collect())
}

/// Exact on-disk byte count [`write_tensors`] would produce for tensors
/// of the given names and shapes, without materializing them — the
/// dense-checkpoint baseline the artifact benches compare against.
pub fn encoded_len<'a>(entries: impl Iterator<Item = (&'a str, &'a [usize])>) -> usize {
    8 + entries
        .map(|(name, shape)| 4 + name.len() + 4 + 8 * shape.len() + 4 * shape.iter().product::<usize>())
        .sum::<usize>()
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("fpt_test");
        let path = dir.join("t.fpt");
        let a = Tensor::from_vec(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::from_vec(vec![4], vec![-1., 0., 1., 2.]);
        write_tensors(&path, &[("a".into(), &a), ("b".into(), &b)]).unwrap();
        let want_len = encoded_len(
            [("a", &[2usize, 3][..]), ("b", &[4usize][..])].into_iter(),
        );
        assert_eq!(std::fs::metadata(&path).unwrap().len() as usize, want_len);
        let back = read_tensors(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[0].0, "a");
        assert_eq!(back[0].1.shape(), &[2, 3]);
        assert_eq!(back[0].1.data(), a.data());
        assert_eq!(back[1].1.data(), b.data());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("fpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.fpt");
        std::fs::write(&path, b"NOPE....").unwrap();
        assert!(read_tensors(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}

//! Magnitude pruning: keep the largest-|w| entries. The classical
//! baseline — identical to the rounding step applied to the dense weights.

use crate::config::Sparsity;
use crate::pruner::rounding::round_to_sparsity;
use crate::tensor::Tensor;

pub fn prune(w: &Tensor, sp: Sparsity) -> Tensor {
    round_to_sparsity(w, sp)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_largest() {
        let w = Tensor::from_vec(vec![2, 2], vec![0.1, 2.0, -3.0, 0.2]);
        let p = prune(&w, Sparsity::Unstructured(0.5));
        assert_eq!(p.data(), &[0.0, 2.0, -3.0, 0.0]);
    }
}

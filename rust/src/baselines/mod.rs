//! Baseline one-shot pruners the paper compares against (§4.1):
//! magnitude, Wanda (Sun et al. 2023), SparseGPT (Frantar & Alistarh 2023).
//!
//! They double as warm starts for FISTA (paper §4.1: SparseGPT for OPT,
//! Wanda for LLaMA). All operate per weight matrix on the same Gram
//! statistics the FISTAPruner unit already accumulates (H = X Xᵀ).

pub mod magnitude;
pub mod sparsegpt;
pub mod wanda;

use anyhow::Result;

use crate::config::Sparsity;
use crate::tensor::Tensor;

/// Which baseline pruner to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BaselineKind {
    Magnitude,
    Wanda,
    SparseGpt,
}

impl BaselineKind {
    pub fn parse(s: &str) -> Result<BaselineKind> {
        match s {
            "magnitude" => Ok(BaselineKind::Magnitude),
            "wanda" => Ok(BaselineKind::Wanda),
            "sparsegpt" => Ok(BaselineKind::SparseGpt),
            other => anyhow::bail!("unknown baseline '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BaselineKind::Magnitude => "magnitude",
            BaselineKind::Wanda => "wanda",
            BaselineKind::SparseGpt => "sparsegpt",
        }
    }
}

/// Prune one weight matrix with the chosen baseline.
///
/// `h` is the input Gram matrix X Xᵀ of the operator (n×n); magnitude
/// ignores it, Wanda uses its diagonal, SparseGPT uses the full matrix.
pub fn prune_matrix(kind: BaselineKind, w: &Tensor, h: &Tensor, sp: Sparsity) -> Result<Tensor> {
    match kind {
        BaselineKind::Magnitude => Ok(magnitude::prune(w, sp)),
        BaselineKind::Wanda => Ok(wanda::prune(w, h, sp)),
        BaselineKind::SparseGpt => sparsegpt::prune(w, h, sp),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruner::rounding::satisfies_sparsity;
    use crate::tensor::ops::matmul_nt;
    use crate::util::Pcg64;

    #[test]
    fn all_baselines_meet_sparsity_patterns() {
        let mut rng = Pcg64::seeded(21);
        let w = Tensor::from_vec(vec![16, 32], rng.normal_vec(512, 1.0));
        let x = Tensor::from_vec(vec![32, 128], rng.normal_vec(32 * 128, 1.0));
        let h = matmul_nt(&x, &x);
        for kind in [BaselineKind::Magnitude, BaselineKind::Wanda, BaselineKind::SparseGpt] {
            for sp in [Sparsity::Unstructured(0.5), Sparsity::Semi(2, 4)] {
                let p = prune_matrix(kind, &w, &h, sp).unwrap();
                assert!(satisfies_sparsity(&p, sp), "{kind:?} {sp:?}");
            }
        }
    }

    #[test]
    fn parse_names() {
        assert_eq!(BaselineKind::parse("wanda").unwrap(), BaselineKind::Wanda);
        assert!(BaselineKind::parse("obs").is_err());
    }
}

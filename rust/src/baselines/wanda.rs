//! Wanda (Sun et al. 2023): prune by |W_ij| · ‖X_j‖₂ with no weight update.
//!
//! ‖X_j‖₂ is the ℓ₂ norm of feature j across calibration tokens —
//! exactly sqrt(diag(X Xᵀ)), so the score comes free from the Gram
//! pipeline. Comparison groups follow the Wanda paper: per output row for
//! unstructured sparsity, per (row, m-group) for n:m.

use crate::config::Sparsity;
use crate::tensor::Tensor;

/// Prune `w` [m, n] given the input Gram `h` = X Xᵀ [n, n].
pub fn prune(w: &Tensor, h: &Tensor, sp: Sparsity) -> Tensor {
    let (m, n) = (w.rows(), w.cols());
    assert_eq!(h.rows(), n);
    let feat_norm: Vec<f32> = (0..n).map(|j| h.at2(j, j).max(0.0).sqrt()).collect();
    let mut out = w.clone();
    match sp {
        Sparsity::Unstructured(s) => {
            let k = ((n as f64) * s).floor() as usize;
            if k == 0 {
                return out;
            }
            for i in 0..m {
                let row = out.row_mut(i);
                let mut idx: Vec<usize> = (0..n).collect();
                idx.sort_unstable_by(|&a, &b| {
                    let sa = row[a].abs() * feat_norm[a];
                    let sb = row[b].abs() * feat_norm[b];
                    sa.partial_cmp(&sb).unwrap_or(std::cmp::Ordering::Equal)
                });
                for &j in &idx[..k] {
                    row[j] = 0.0;
                }
            }
        }
        Sparsity::Semi(keep, grp) => {
            assert_eq!(n % grp, 0);
            let drop = grp - keep;
            for i in 0..m {
                let row = out.row_mut(i);
                for g in (0..n).step_by(grp) {
                    let mut idx: Vec<usize> = (0..grp).collect();
                    idx.sort_unstable_by(|&a, &b| {
                        let sa = row[g + a].abs() * feat_norm[g + a];
                        let sb = row[g + b].abs() * feat_norm[g + b];
                        sa.partial_cmp(&sb).unwrap_or(std::cmp::Ordering::Equal)
                    });
                    for &j in &idx[..drop] {
                        row[g + j] = 0.0;
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pruner::rounding::satisfies_sparsity;
    use crate::util::Pcg64;

    #[test]
    fn activation_norms_matter() {
        // Two equal weights; the one fed by the high-norm feature survives.
        let w = Tensor::from_vec(vec![1, 2], vec![1.0, 1.0]);
        let h = Tensor::from_vec(vec![2, 2], vec![100.0, 0.0, 0.0, 1.0]);
        let p = prune(&w, &h, Sparsity::Unstructured(0.5));
        assert_eq!(p.data(), &[1.0, 0.0]);
    }

    #[test]
    fn per_row_sparsity_is_exact() {
        let mut rng = Pcg64::seeded(5);
        let w = Tensor::from_vec(vec![6, 20], rng.normal_vec(120, 1.0));
        let x = Tensor::from_vec(vec![20, 64], rng.normal_vec(20 * 64, 1.0));
        let h = crate::tensor::ops::matmul_nt(&x, &x);
        let p = prune(&w, &h, Sparsity::Unstructured(0.5));
        for i in 0..6 {
            let zeros = p.row(i).iter().filter(|&&v| v == 0.0).count();
            assert_eq!(zeros, 10, "row {i}");
        }
        assert!(satisfies_sparsity(&p, Sparsity::Unstructured(0.5)));
    }

    #[test]
    fn semi_structured_groups() {
        let mut rng = Pcg64::seeded(6);
        let w = Tensor::from_vec(vec![4, 16], rng.normal_vec(64, 1.0));
        let x = Tensor::from_vec(vec![16, 32], rng.normal_vec(512, 1.0));
        let h = crate::tensor::ops::matmul_nt(&x, &x);
        let p = prune(&w, &h, Sparsity::Semi(2, 4));
        assert!(satisfies_sparsity(&p, Sparsity::Semi(2, 4)));
    }
}

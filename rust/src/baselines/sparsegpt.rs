//! SparseGPT (Frantar & Alistarh 2023): OBS-based one-shot pruning with
//! error compensation, following the reference implementation:
//!
//! 1. H = X Xᵀ + percdamp·mean(diag H)·I
//! 2. Hinv = chol_upper(H⁻¹) — the upper Cholesky factor U with
//!    H⁻¹ = Uᵀ U; the OBS denominators are d_j = U[j,j].
//! 3. Sweep columns left→right in blocks of `BLOCK`. Within a block:
//!    mask selection by saliency w²/d² (block-global quantile for
//!    unstructured; per (row, m-group) for n:m), then per pruned entry
//!    propagate err = w_j/d_j into the remaining columns via U's row.
//! 4. After each block, lazily update the columns right of the block.

use anyhow::{Context, Result};

use crate::config::Sparsity;
use crate::linalg::{cholesky, cholesky_inverse};
use crate::tensor::{ops, Tensor};

const BLOCK: usize = 128;
const PERCDAMP: f64 = 0.01;

/// Prune `w` [m, n] given the input Gram `h` = X Xᵀ [n, n].
pub fn prune(w: &Tensor, h: &Tensor, sp: Sparsity) -> Result<Tensor> {
    let (m, n) = (w.rows(), w.cols());
    assert_eq!(h.rows(), n, "H must be n×n");

    // Damping: percdamp × mean diagonal (dead features get identity rows,
    // matching the reference's W[:, dead] = 0 handling implicitly).
    let mean_diag: f64 =
        (0..n).map(|j| h.at2(j, j) as f64).sum::<f64>() / n as f64;
    let damp = (PERCDAMP * mean_diag).max(1e-8) as f32;
    let mut hd = h.clone();
    for j in 0..n {
        let v = hd.at2(j, j) + damp;
        hd.set2(j, j, v);
    }

    // U with H⁻¹ = Uᵀ U (upper Cholesky of the inverse).
    let hinv = cholesky_inverse(&hd).context("inverting damped Hessian")?;
    let u = upper_cholesky(&hinv).context("upper Cholesky of H⁻¹")?;

    let mut w = w.clone();
    let mut mask = vec![false; m * n]; // true = pruned
    for i1 in (0..n).step_by(BLOCK) {
        let i2 = (i1 + BLOCK).min(n);
        select_mask(&w, &u, sp, i1, i2, &mut mask);
        // Err rows for the lazy tail update: err[r][j-i1]
        let mut errs = Tensor::zeros(vec![m, i2 - i1]);
        for j in i1..i2 {
            let d = u.at2(j, j);
            for r in 0..m {
                let wj = w.at2(r, j);
                let q = if mask[r * n + j] { 0.0 } else { wj };
                let err = (wj - q) / d;
                errs.set2(r, j - i1, err);
                w.set2(r, j, q);
                if err != 0.0 {
                    // in-block compensation: W[r, j+1..i2] -= err * U[j, j+1..i2]
                    for jj in (j + 1)..i2 {
                        let v = w.at2(r, jj) - err * u.at2(j, jj);
                        w.set2(r, jj, v);
                    }
                }
            }
        }
        // Lazy tail update: W[:, i2..] -= Err @ U[i1..i2, i2..]
        if i2 < n {
            let u_tail = slice_cols(&u, i1, i2, i2, n);
            let delta = ops::matmul(&errs, &u_tail);
            for r in 0..m {
                for (jj, dv) in delta.row(r).iter().enumerate() {
                    let v = w.at2(r, i2 + jj) - dv;
                    w.set2(r, i2 + jj, v);
                }
            }
        }
    }
    // Compensation can leave |values| < f32 ulps in pruned slots; enforce.
    for (i, &is_pruned) in mask.iter().enumerate() {
        if is_pruned {
            w.data_mut()[i] = 0.0;
        }
    }
    Ok(w)
}

/// Saliency-based mask selection for columns [i1, i2).
fn select_mask(w: &Tensor, u: &Tensor, sp: Sparsity, i1: usize, i2: usize, mask: &mut [bool]) {
    let (m, n) = (w.rows(), w.cols());
    let sal = |r: usize, j: usize| {
        let d = u.at2(j, j);
        let v = w.at2(r, j) / d;
        v * v
    };
    match sp {
        Sparsity::Unstructured(s) => {
            // Block-global quantile (reference: sort of the flattened block).
            let mut all: Vec<f32> = Vec::with_capacity(m * (i2 - i1));
            for r in 0..m {
                for j in i1..i2 {
                    all.push(sal(r, j));
                }
            }
            let k = ((all.len() as f64) * s).floor() as usize;
            if k == 0 {
                return;
            }
            let kth = {
                let mut tmp = all.clone();
                let (_, kth, _) = tmp.select_nth_unstable_by(k - 1, |a, b| {
                    a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
                });
                *kth
            };
            let mut pruned = 0usize;
            'outer: for r in 0..m {
                for j in i1..i2 {
                    if sal(r, j) <= kth {
                        mask[r * n + j] = true;
                        pruned += 1;
                        if pruned == k {
                            break 'outer; // ties: stop at exact count
                        }
                    }
                }
            }
        }
        Sparsity::Semi(keep, grp) => {
            debug_assert_eq!(i1 % grp, 0);
            let drop = grp - keep;
            for r in 0..m {
                for g in (i1..i2).step_by(grp) {
                    let hi = (g + grp).min(i2);
                    let mut idx: Vec<usize> = (g..hi).collect();
                    idx.sort_unstable_by(|&a, &b| {
                        sal(r, a).partial_cmp(&sal(r, b)).unwrap_or(std::cmp::Ordering::Equal)
                    });
                    for &j in idx.iter().take(drop.min(idx.len())) {
                        mask[r * n + j] = true;
                    }
                }
            }
        }
    }
}

/// Upper Cholesky factor U with A = Uᵀ U (via lower factor of A, U = Lᵀ).
fn upper_cholesky(a: &Tensor) -> Result<Tensor> {
    let l = cholesky(a)?;
    Ok(ops::transpose(&l))
}

/// Copy block A[r0..r1, c0..c1].
fn slice_cols(a: &Tensor, r0: usize, r1: usize, c0: usize, c1: usize) -> Tensor {
    let n = a.cols();
    let mut out = Tensor::zeros(vec![r1 - r0, c1 - c0]);
    for r in r0..r1 {
        let src = &a.data()[r * n + c0..r * n + c1];
        out.row_mut(r - r0).copy_from_slice(src);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{magnitude, wanda};
    use crate::pruner::rounding::satisfies_sparsity;
    use crate::util::Pcg64;

    fn fixture(seed: u64, m: usize, n: usize, p: usize) -> (Tensor, Tensor, Tensor) {
        let mut rng = Pcg64::seeded(seed);
        let w = Tensor::from_vec(vec![m, n], rng.normal_vec(m * n, 1.0));
        // correlated features: x = base + per-feature noise
        let base = Tensor::from_vec(vec![1, p], rng.normal_vec(p, 1.0));
        let mut xd = Vec::with_capacity(n * p);
        for _ in 0..n {
            let scale = 0.3 + rng.next_f32() * 2.0;
            for t in 0..p {
                xd.push(base.data()[t] * scale + rng.normal() as f32 * 0.5);
            }
        }
        let x = Tensor::from_vec(vec![n, p], xd);
        let h = ops::matmul_nt(&x, &x);
        (w, x, h)
    }

    #[test]
    fn meets_sparsity_exactly() {
        let (w, _x, h) = fixture(1, 24, 32, 160);
        for sp in [Sparsity::Unstructured(0.5), Sparsity::Unstructured(0.25), Sparsity::Semi(2, 4)] {
            let p = prune(&w, &h, sp).unwrap();
            assert!(satisfies_sparsity(&p, sp), "{sp:?}");
        }
    }

    #[test]
    fn weight_update_beats_mask_only_baselines() {
        // The OBS compensation should give lower output error than
        // magnitude and Wanda on correlated inputs.
        let (w, x, h) = fixture(2, 24, 32, 200);
        let sp = Sparsity::Unstructured(0.5);
        let wx = ops::matmul(&w, &x);
        let err = |wp: &Tensor| ops::frob_dist(&ops::matmul(wp, &x), &wx);
        let e_sgpt = err(&prune(&w, &h, sp).unwrap());
        let e_mag = err(&magnitude::prune(&w, sp));
        let e_wanda = err(&wanda::prune(&w, &h, sp));
        assert!(e_sgpt < e_mag, "sparsegpt {e_sgpt} !< magnitude {e_mag}");
        assert!(e_sgpt < e_wanda, "sparsegpt {e_sgpt} !< wanda {e_wanda}");
    }

    #[test]
    fn multi_block_sweep() {
        // n > BLOCK exercises the lazy tail update.
        let (w, x, h) = fixture(3, 8, 160, 400);
        let sp = Sparsity::Unstructured(0.5);
        let p = prune(&w, &h, sp).unwrap();
        assert!(satisfies_sparsity(&p, sp));
        // still better than magnitude
        let wx = ops::matmul(&w, &x);
        let err = |wp: &Tensor| ops::frob_dist(&ops::matmul(wp, &x), &wx);
        assert!(err(&p) < err(&magnitude::prune(&w, sp)));
    }
}

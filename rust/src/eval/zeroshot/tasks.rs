//! The seven synthetic zero-shot probes (Table 3 analogs).
//!
//! Each probe is a binary-choice continuation task: take a real held-out
//! window, corrupt its final `SUFFIX` tokens with a task-specific
//! transformation, and ask whether the model assigns lower NLL to the true
//! suffix than to the corrupted one. The seven corruption types span a
//! difficulty range like the original seven LM-Harness tasks (DESIGN.md §2):
//! a pruned model that preserves relative sequence likelihoods keeps its
//! accuracy; a damaged one decays toward chance (0.5).

use crate::data::{tokenizer::VOCAB_SIZE, Corpus};
use crate::util::Pcg64;

/// Length of the scored/corrupted continuation region.
pub const SUFFIX: usize = 16;

/// One binary-choice item: two windows sharing a prefix.
pub struct Item {
    pub true_window: Vec<i32>,
    pub distractor_window: Vec<i32>,
}

/// A probe task: name + its items.
pub struct Task {
    pub name: &'static str,
    pub items: Vec<Item>,
}

/// All seven probes over `n_items` held-out windows each.
pub fn build_tasks(corpus: &Corpus, seq: usize, n_items: usize, seed: u64) -> Vec<Task> {
    let kinds: [(&'static str, CorruptFn); 7] = [
        ("arc_e-syn", corrupt_uniform),      // uniform random chars (easy)
        ("arc_c-syn", corrupt_unigram),      // corpus-unigram chars (harder)
        ("wino-syn", corrupt_swap_words),    // swap two suffix words
        ("boolq-syn", corrupt_other_window), // suffix from elsewhere
        ("rte-syn", corrupt_reverse),        // reversed suffix
        ("qnli-syn", corrupt_shuffle),       // shuffled suffix chars
        ("wnli-syn", corrupt_single_flip),   // one char flipped (hardest)
    ];
    let held = corpus.heldout_slice();
    let win = seq + 1;
    assert!(held.len() > win * 2, "held-out split too small");
    // Unigram table for corrupt_unigram.
    let mut unigram = vec![1.0f64; VOCAB_SIZE];
    for &t in held.iter().take(20_000) {
        unigram[t as usize] += 1.0;
    }
    kinds
        .iter()
        .enumerate()
        .map(|(k, (name, f))| {
            let mut rng = Pcg64::new(seed ^ (k as u64 + 1), 53);
            let items = (0..n_items)
                .map(|_| {
                    let start = rng.below((held.len() - win) as u64) as usize;
                    let true_window = held[start..start + win].to_vec();
                    let mut distractor_window = true_window.clone();
                    f(&mut distractor_window[win - SUFFIX..], held, &unigram, &mut rng);
                    Item { true_window, distractor_window }
                })
                .collect();
            Task { name, items }
        })
        .collect()
}

type CorruptFn = fn(&mut [i32], &[i32], &[f64], &mut Pcg64);

fn corrupt_uniform(sfx: &mut [i32], _held: &[i32], _uni: &[f64], rng: &mut Pcg64) {
    for t in sfx.iter_mut() {
        *t = rng.below(VOCAB_SIZE as u64) as i32;
    }
}

fn corrupt_unigram(sfx: &mut [i32], _held: &[i32], uni: &[f64], rng: &mut Pcg64) {
    for t in sfx.iter_mut() {
        *t = rng.sample_weighted(uni) as i32;
    }
}

fn corrupt_swap_words(sfx: &mut [i32], _held: &[i32], _uni: &[f64], rng: &mut Pcg64) {
    // Swap two halves of the suffix (crude "word-order" corruption), then
    // flip a couple of chars so the bag-of-chars differs too.
    let mid = sfx.len() / 2;
    let (a, b) = sfx.split_at_mut(mid);
    for (x, y) in a.iter_mut().zip(b.iter_mut()) {
        std::mem::swap(x, y);
    }
    for _ in 0..2 {
        let i = rng.below(sfx.len() as u64) as usize;
        sfx[i] = rng.below(VOCAB_SIZE as u64) as i32;
    }
}

fn corrupt_other_window(sfx: &mut [i32], held: &[i32], _uni: &[f64], rng: &mut Pcg64) {
    let start = rng.below((held.len() - sfx.len()) as u64) as usize;
    sfx.copy_from_slice(&held[start..start + sfx.len()]);
}

fn corrupt_reverse(sfx: &mut [i32], _held: &[i32], _uni: &[f64], _rng: &mut Pcg64) {
    sfx.reverse();
}

fn corrupt_shuffle(sfx: &mut [i32], _held: &[i32], _uni: &[f64], rng: &mut Pcg64) {
    rng.shuffle(sfx);
}

fn corrupt_single_flip(sfx: &mut [i32], _held: &[i32], _uni: &[f64], rng: &mut Pcg64) {
    let i = rng.below(sfx.len() as u64) as usize;
    let old = sfx[i];
    let mut new = old;
    while new == old {
        new = rng.below(VOCAB_SIZE as u64) as i32;
    }
    sfx[i] = new;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::CorpusCfg;

    fn corpus() -> Corpus {
        Corpus::generate(&CorpusCfg {
            name: "t".into(),
            seed: 3,
            word_vocab: 150,
            zipf_s: 1.0,
            noise: 0.0,
            sentence_len: (3, 8),
            chars: 100_000,
        })
    }

    #[test]
    fn seven_tasks_with_items() {
        let tasks = build_tasks(&corpus(), 64, 20, 1);
        assert_eq!(tasks.len(), 7);
        for t in &tasks {
            assert_eq!(t.items.len(), 20);
            for item in &t.items {
                assert_eq!(item.true_window.len(), 65);
                assert_eq!(item.distractor_window.len(), 65);
                // prefix shared, suffix differs
                assert_eq!(item.true_window[..65 - SUFFIX], item.distractor_window[..65 - SUFFIX]);
                assert_ne!(item.true_window[65 - SUFFIX..], item.distractor_window[65 - SUFFIX..]);
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let c = corpus();
        let a = build_tasks(&c, 64, 5, 9);
        let b = build_tasks(&c, 64, 5, 9);
        for (x, y) in a.iter().zip(&b) {
            for (i, j) in x.items.iter().zip(&y.items) {
                assert_eq!(i.distractor_window, j.distractor_window);
            }
        }
    }

    #[test]
    fn single_flip_differs_in_exactly_one_position() {
        let tasks = build_tasks(&corpus(), 64, 10, 2);
        let wnli = tasks.iter().find(|t| t.name == "wnli-syn").unwrap();
        for item in &wnli.items {
            let diff = item
                .true_window
                .iter()
                .zip(&item.distractor_window)
                .filter(|(a, b)| a != b)
                .count();
            assert_eq!(diff, 1);
        }
    }
}

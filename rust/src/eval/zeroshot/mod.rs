pub mod harness;
pub mod tasks;
pub use harness::run_all_tasks;

//! The seven synthetic zero-shot probes (paper Table 3 analogs): task
//! construction in `tasks`, scoring (artifact and native backends) in
//! `harness`.

pub mod harness;
pub mod tasks;

pub use harness::{run_all_tasks, run_all_tasks_native, TaskResult};

//! Zero-shot scoring harness: batch both candidates of every item through
//! the score artifact with a suffix-only mask and report per-task accuracy
//! (paper Table 3: per-task + mean). `run_all_tasks_native` is the
//! artifact-free twin on the native forward pass, items scored in
//! parallel.

use anyhow::Result;

use crate::config::{ModelSpec, Presets};
use crate::data::Corpus;
use crate::eval::perplexity::score_per_window;
use crate::model::params::ModelParams;
use crate::runtime::Session;

use super::tasks::{build_tasks, Task, SUFFIX};

/// Accuracy of one task: fraction of items whose true suffix scores a
/// strictly lower NLL than the distractor suffix.
#[derive(Clone, Debug)]
pub struct TaskResult {
    pub name: &'static str,
    pub accuracy: f64,
    pub items: usize,
}

/// Run all seven probes; returns per-task results + the mean accuracy.
pub fn run_all_tasks(
    session: &Session,
    presets: &Presets,
    spec: &ModelSpec,
    params: &ModelParams,
    corpus: &Corpus,
    n_items: usize,
    seed: u64,
) -> Result<(Vec<TaskResult>, f64)> {
    let tasks = build_tasks(corpus, spec.seq, n_items, seed);
    let mut results = Vec::with_capacity(tasks.len());
    for task in &tasks {
        results.push(score_task(session, presets, spec, params, task)?);
    }
    let mean = crate::metrics::mean(&results.iter().map(|r| r.accuracy).collect::<Vec<_>>());
    Ok((results, mean))
}

/// Artifact-free probes: the native forward pass scores every item's true
/// and distractor suffix; items fan out over the kernel worker threads.
pub fn run_all_tasks_native(
    spec: &ModelSpec,
    params: &ModelParams,
    corpus: &Corpus,
    n_items: usize,
    seed: u64,
) -> (Vec<TaskResult>, f64) {
    let tasks = build_tasks(corpus, spec.seq, n_items, seed);
    let t0 = spec.seq - SUFFIX;
    let results: Vec<TaskResult> = tasks
        .iter()
        .map(|task| {
            let mut nll = vec![0f64; task.items.len() * 2];
            crate::tensor::par::for_each_row_block(
                &mut nll,
                task.items.len(),
                2,
                1,
                |i0, _i1, out| {
                    for (k, pair) in out.chunks_mut(2).enumerate() {
                        let item = &task.items[i0 + k];
                        pair[0] =
                            crate::model::forward::nll_from(spec, params, &item.true_window, t0);
                        pair[1] = crate::model::forward::nll_from(
                            spec,
                            params,
                            &item.distractor_window,
                            t0,
                        );
                    }
                },
            );
            let correct = nll.chunks_exact(2).filter(|pair| pair[0] < pair[1]).count();
            TaskResult {
                name: task.name,
                accuracy: correct as f64 / task.items.len() as f64,
                items: task.items.len(),
            }
        })
        .collect();
    let mean = crate::metrics::mean(&results.iter().map(|r| r.accuracy).collect::<Vec<_>>());
    (results, mean)
}

fn score_task(
    session: &Session,
    presets: &Presets,
    spec: &ModelSpec,
    params: &ModelParams,
    task: &Task,
) -> Result<TaskResult> {
    // Interleave true/distractor windows so one batched pass scores both.
    let mut windows = Vec::with_capacity(task.items.len() * 2);
    for item in &task.items {
        windows.push(item.true_window.clone());
        windows.push(item.distractor_window.clone());
    }
    let t0 = spec.seq - SUFFIX;
    let nll = score_per_window(session, presets, spec, params, &windows, Some(t0))?;
    let correct = nll
        .chunks_exact(2)
        .filter(|pair| pair[0] < pair[1])
        .count();
    Ok(TaskResult {
        name: task.name,
        accuracy: correct as f64 / task.items.len() as f64,
        items: task.items.len(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::repo_root;
    use crate::model::init::init_params;

    #[test]
    fn native_random_model_is_near_chance_overall() {
        // An untrained model has no preference for true text on the harder
        // probes; overall accuracy must sit well below a trained model's.
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 13);
        let corpus = Corpus::generate(presets.corpus("ptb-syn").unwrap());
        let (results, mean) = run_all_tasks_native(spec, &params, &corpus, 24, 1);
        assert_eq!(results.len(), 7);
        assert!((0.2..0.8).contains(&mean), "untrained mean {mean} should be near chance");
    }

    #[test]
    fn artifact_random_model_is_near_chance_overall() {
        let Some(session) = crate::testing::try_session() else { return };
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 13);
        let corpus = Corpus::generate(presets.corpus("ptb-syn").unwrap());
        let (results, mean) =
            run_all_tasks(&session, &presets, spec, &params, &corpus, 24, 1).unwrap();
        assert_eq!(results.len(), 7);
        assert!((0.2..0.8).contains(&mean), "untrained mean {mean} should be near chance");
    }
}

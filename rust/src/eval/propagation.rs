//! Layer-wise error-propagation diagnostic (paper Fig. 2's motivation):
//! measure ‖y_pruned − y_dense‖/‖y_dense‖ at the output of every decoder
//! layer, for a pruned model. Error correction should flatten this curve;
//! without it the relative error compounds layer over layer.

use anyhow::Result;

use crate::config::{ModelSpec, Presets};
use crate::model::embed::embed_windows;
use crate::model::params::ModelParams;
use crate::runtime::session::{Arg, Session};
use crate::tensor::Tensor;

/// Relative output deviation after each layer: vec[layer] =
/// ‖y*_ℓ − y_ℓ‖_F / ‖y_ℓ‖_F over the probe windows.
pub fn layer_errors(
    session: &Session,
    presets: &Presets,
    spec: &ModelSpec,
    dense: &ModelParams,
    pruned: &ModelParams,
    windows: &[Vec<i32>],
) -> Result<Vec<f64>> {
    let cb = presets.capture_batch;
    let (mut xd, valids) = embed_windows(spec, dense, windows, cb)?;
    let (mut xs, _) = embed_windows(spec, pruned, windows, cb)?;
    let name = format!("capture_{}", spec.name());
    let mut out = Vec::with_capacity(spec.layers);
    for layer in 0..spec.layers {
        let run = |params: &ModelParams, batches: &[Tensor]| -> Result<Vec<Tensor>> {
            let tensors = params.layer_tensors(spec, layer);
            let mut ys = Vec::with_capacity(batches.len());
            for b in batches {
                let mut args: Vec<Arg<'_>> = vec![Arg::T(b)];
                for t in &tensors {
                    args.push(Arg::T(t));
                }
                let res = session.run(&name, &args)?;
                ys.push(res.into_iter().last().expect("y"));
            }
            Ok(ys)
        };
        let yd = run(dense, &xd)?;
        let ys = run(pruned, &xs)?;
        // relative deviation over valid rows only
        let (mut num, mut den) = (0f64, 0f64);
        for ((a, b), &valid) in yd.iter().zip(&ys).zip(&valids) {
            let row_elems = valid * spec.seq * spec.d;
            let (da, db) = (&a.data()[..row_elems], &b.data()[..row_elems]);
            for (&x, &y) in da.iter().zip(db) {
                let d = (x - y) as f64;
                num += d * d;
                den += (x as f64) * (x as f64);
            }
        }
        out.push((num / den.max(1e-30)).sqrt());
        xd = yd;
        xs = ys;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::repo_root;
    use crate::model::init::init_params;

    #[test]
    fn identical_models_have_zero_error() {
        let Some(session) = crate::testing::try_session() else { return };
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 31);
        let windows: Vec<Vec<i32>> = (0..4).map(|i| vec![(i * 3) as i32; spec.seq]).collect();
        let errs =
            layer_errors(&session, &presets, spec, &params, &params, &windows).unwrap();
        assert_eq!(errs.len(), spec.layers);
        assert!(errs.iter().all(|&e| e < 1e-6), "{errs:?}");
    }

    #[test]
    fn pruned_model_error_grows_with_depth() {
        let Some(session) = crate::testing::try_session() else { return };
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let dense = init_params(spec, 32);
        let mut pruned = dense.clone();
        // magnitude-prune every operator at 60% (no compensation → visible error)
        for layer in 0..spec.layers {
            for op in crate::model::ops::pruned_ops(spec) {
                let nm = format!("l{layer}.{}", op.name);
                let w = crate::pruner::round_to_sparsity(
                    pruned.req(&nm).unwrap(),
                    crate::config::Sparsity::Unstructured(0.6),
                );
                pruned.set(&nm, w).unwrap();
            }
        }
        let windows: Vec<Vec<i32>> = (0..4).map(|i| vec![(i * 5 + 1) as i32; spec.seq]).collect();
        let errs = layer_errors(&session, &presets, spec, &dense, &pruned, &windows).unwrap();
        assert!(errs[0] > 1e-4, "layer 0 should deviate: {errs:?}");
        assert!(errs[spec.layers - 1] >= errs[0] * 0.5, "deep layers should not shrink error to zero: {errs:?}");
    }
}

//! Text generation over the native forward pass — a qualitative check
//! that pruned models still produce corpus-like text, and the demo behind
//! the `generate` CLI command.

use crate::config::ModelSpec;
use crate::data::tokenizer;
use crate::model::forward::logits;
use crate::model::params::ModelParams;
use crate::util::Pcg64;

/// Sampling options.
pub struct GenOptions {
    pub max_tokens: usize,
    /// 0 = greedy; otherwise softmax temperature.
    pub temperature: f64,
    pub seed: u64,
}

impl Default for GenOptions {
    fn default() -> Self {
        GenOptions { max_tokens: 128, temperature: 0.8, seed: 0 }
    }
}

/// Generate a continuation of `prompt`.
pub fn generate(spec: &ModelSpec, params: &ModelParams, prompt: &str, opts: &GenOptions) -> String {
    generate_with(spec.seq, prompt, opts, |ctx| logits(spec, params, ctx))
}

/// The shared generation loop: sliding window of `seq` context tokens,
/// `Pcg64::new(seed, 61)` sampling stream, one `next_token` draw per
/// generated token. `logits_fn` maps the current context window to a
/// [len, vocab] logits tensor — [`generate`] plugs in the dense forward,
/// `sparse::compiled_generate` the compressed one, so the two paths
/// cannot drift apart (they are each other's parity oracle in the
/// serving tests).
pub fn generate_with<F>(seq: usize, prompt: &str, opts: &GenOptions, mut logits_fn: F) -> String
where
    F: FnMut(&[i32]) -> crate::tensor::Tensor,
{
    let mut tokens = tokenizer::encode(prompt);
    assert!(!tokens.is_empty(), "empty prompt");
    let mut rng = Pcg64::new(opts.seed, 61);
    let start = tokens.len();
    for _ in 0..opts.max_tokens {
        // sliding window: keep the last seq tokens as context
        let ctx_start = tokens.len().saturating_sub(seq);
        let lg = logits_fn(&tokens[ctx_start..]);
        let row = lg.row(lg.rows() - 1);
        let next = next_token(row, opts.temperature, &mut rng);
        tokens.push(next as i32);
    }
    tokenizer::decode(&tokens[start..])
}

/// Pick the next token from a logits row: argmax at temperature ≤ 0, else
/// seeded softmax sampling. Shared by [`generate`] and the serving engine
/// so a served request with the same seed draws the identical stream
/// (`Pcg64::new(seed, 61)`, one draw per sampled token).
pub fn next_token(row: &[f32], temperature: f64, rng: &mut Pcg64) -> usize {
    if temperature <= 0.0 {
        row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
    } else {
        sample_softmax(row, temperature, rng)
    }
}

fn sample_softmax(row: &[f32], temperature: f64, rng: &mut Pcg64) -> usize {
    let max = row.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v)) as f64;
    let weights: Vec<f64> =
        row.iter().map(|&v| ((v as f64 - max) / temperature).exp()).collect();
    rng.sample_weighted(&weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{repo_root, Presets};
    use crate::model::init::init_params;

    #[test]
    fn generates_requested_length() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 21);
        let opts = GenOptions { max_tokens: 16, temperature: 1.0, seed: 4 };
        let out = generate(spec, &params, "hello ", &opts);
        assert_eq!(out.len(), 16);
    }

    #[test]
    fn greedy_is_deterministic() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 22);
        let opts = GenOptions { max_tokens: 12, temperature: 0.0, seed: 1 };
        let a = generate(spec, &params, "abc", &opts);
        let b = generate(spec, &params, "abc", &opts);
        assert_eq!(a, b);
    }

    #[test]
    fn seeds_vary_sampling() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 23);
        let a = generate(spec, &params, "xy", &GenOptions { max_tokens: 24, temperature: 1.5, seed: 1 });
        let b = generate(spec, &params, "xy", &GenOptions { max_tokens: 24, temperature: 1.5, seed: 2 });
        assert_ne!(a, b);
    }
}

//! Evaluation: perplexity over held-out corpora and the zero-shot probe
//! suite (the paper's Tables 1–7 metrics).

pub mod generate;
pub mod perplexity;
pub mod propagation;
pub mod zeroshot;

pub use perplexity::perplexity;

//! Perplexity evaluation over held-out corpus windows: the
//! `score_{model}` artifact (masked per-sequence NLL; DESIGN.md §5) when a
//! PJRT session is available, or the native forward pass otherwise.

use anyhow::{bail, Result};

use crate::config::{ModelSpec, Presets};
use crate::data::{batches::pack, sampler::eval_windows, Corpus};
use crate::model::params::ModelParams;
use crate::runtime::session::{Arg, Session};
use crate::tensor::par;

/// exp(total NLL / total tokens) over up to `max_windows` non-overlapping
/// held-out windows.
pub fn perplexity(
    session: &Session,
    presets: &Presets,
    spec: &ModelSpec,
    params: &ModelParams,
    corpus: &Corpus,
    max_windows: usize,
) -> Result<f64> {
    let windows = eval_windows(corpus, spec.seq + 1, max_windows);
    if windows.is_empty() {
        bail!("held-out split of '{}' has no full windows", corpus.name);
    }
    let (nll, tokens) = score_windows(session, presets, spec, params, &windows)?;
    Ok((nll / tokens).exp())
}

/// Artifact-free perplexity: identical window selection, scored by the
/// native forward pass, windows evaluated in parallel over the kernel
/// worker abstraction.
pub fn perplexity_native(
    spec: &ModelSpec,
    params: &ModelParams,
    corpus: &Corpus,
    max_windows: usize,
) -> Result<f64> {
    let windows = eval_windows(corpus, spec.seq + 1, max_windows);
    if windows.is_empty() {
        bail!("held-out split of '{}' has no full windows", corpus.name);
    }
    let mut nlls = vec![0f64; windows.len()];
    par::for_each_row_block(&mut nlls, windows.len(), 1, 1, |r0, _r1, out| {
        for (i, o) in out.iter_mut().enumerate() {
            *o = crate::model::forward::nll(spec, params, &windows[r0 + i]);
        }
    });
    let total: f64 = nlls.iter().sum();
    Ok((total / (windows.len() * spec.seq) as f64).exp())
}

/// Perplexity through a compiled sparse model
/// (`sparse::compile::CompiledLayers`, e.g. loaded from a sparse
/// artifact): identical window selection to [`perplexity_native`], scored
/// by the compressed forward — the dense pruned operators are never
/// materialized.
pub fn perplexity_compiled(
    compiled: &crate::sparse::CompiledLayers,
    corpus: &Corpus,
    max_windows: usize,
) -> Result<f64> {
    let spec = &compiled.spec;
    let windows = eval_windows(corpus, spec.seq + 1, max_windows);
    if windows.is_empty() {
        bail!("held-out split of '{}' has no full windows", corpus.name);
    }
    let mut nlls = vec![0f64; windows.len()];
    par::for_each_row_block(&mut nlls, windows.len(), 1, 1, |r0, _r1, out| {
        for (i, o) in out.iter_mut().enumerate() {
            *o = crate::sparse::compiled_nll(compiled, &windows[r0 + i]);
        }
    });
    let total: f64 = nlls.iter().sum();
    Ok((total / (windows.len() * spec.seq) as f64).exp())
}

/// Sum of masked NLL and token count over arbitrary windows (also used by
/// the zero-shot harness with custom masks).
pub fn score_windows(
    session: &Session,
    presets: &Presets,
    spec: &ModelSpec,
    params: &ModelParams,
    windows: &[Vec<i32>],
) -> Result<(f64, f64)> {
    let mut total_nll = 0f64;
    let mut total_tokens = 0f64;
    for nll_row in score_per_window(session, presets, spec, params, windows, None)? {
        total_nll += nll_row;
        total_tokens += spec.seq as f64;
    }
    Ok((total_nll, total_tokens))
}

/// Per-window masked NLL. `suffix_mask_from` = Some(t0) restricts scoring
/// to positions ≥ t0 (the zero-shot continuation region); None scores all.
pub fn score_per_window(
    session: &Session,
    presets: &Presets,
    spec: &ModelSpec,
    params: &ModelParams,
    windows: &[Vec<i32>],
    suffix_mask_from: Option<usize>,
) -> Result<Vec<f64>> {
    let name = format!("score_{}", spec.name());
    let cb = presets.capture_batch;
    let seq = spec.seq;
    let mut packed = pack(windows, cb, seq);
    if let Some(t0) = suffix_mask_from {
        for b in &mut packed {
            for r in 0..b.rows {
                for t in 0..t0.min(seq) {
                    b.mask[r * seq + t] = 0.0;
                }
            }
        }
    }
    let param_tensors = params.tensors();
    let tok_dims = [cb, seq + 1];
    let mut out = Vec::with_capacity(windows.len());
    for b in &packed {
        let mut args: Vec<Arg<'_>> = Vec::with_capacity(param_tensors.len() + 2);
        for t in param_tensors {
            args.push(Arg::T(t));
        }
        args.push(Arg::I32(&b.tokens, &tok_dims));
        let mask = crate::tensor::Tensor::from_vec(vec![cb, seq], b.mask.clone());
        args.push(Arg::T(&mask));
        let res = session.run(&name, &args)?;
        let nll = &res[0];
        if nll.len() != cb {
            bail!("score returned {} rows, expected {cb}", nll.len());
        }
        for r in 0..b.rows {
            out.push(nll.data()[r] as f64);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::repo_root;
    use crate::model::init::init_params;

    #[test]
    fn native_random_model_scores_near_uniform() {
        // An untrained model must score close to ln(vocab) per token.
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 11);
        let corpus = Corpus::generate(presets.corpus("ptb-syn").unwrap());
        let ppl = perplexity_native(spec, &params, &corpus, 16).unwrap();
        let uniform = spec.vocab as f64;
        assert!(ppl > 0.3 * uniform && ppl < 3.0 * uniform, "ppl {ppl} vs uniform {uniform}");
    }

    #[test]
    fn compiled_perplexity_matches_native_on_pruned_weights() {
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let pruned = crate::pruner::round_model_to_sparsity(
            spec,
            &init_params(spec, 13),
            crate::config::Sparsity::Unstructured(0.5),
        )
        .unwrap();
        let corpus = Corpus::generate(presets.corpus("ptb-syn").unwrap());
        let native = perplexity_native(spec, &pruned, &corpus, 8).unwrap();
        let compiled = crate::sparse::CompiledLayers::compress(
            spec,
            &pruned,
            crate::config::SparseFormat::Csr,
            None,
        )
        .unwrap();
        let sparse = perplexity_compiled(&compiled, &corpus, 8).unwrap();
        assert!(
            (native - sparse).abs() < 1e-6 * native,
            "native {native} vs compiled {sparse}"
        );
    }

    #[test]
    fn artifact_random_model_scores_near_uniform() {
        let Some(session) = crate::testing::try_session() else { return };
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 11);
        let corpus = Corpus::generate(presets.corpus("ptb-syn").unwrap());
        let ppl = perplexity(&session, &presets, spec, &params, &corpus, 16).unwrap();
        let native = perplexity_native(spec, &params, &corpus, 16).unwrap();
        let uniform = spec.vocab as f64;
        assert!(ppl > 0.3 * uniform && ppl < 3.0 * uniform, "ppl {ppl} vs uniform {uniform}");
        assert!((ppl - native).abs() < 0.05 * native, "artifact {ppl} vs native {native}");
    }

    #[test]
    fn suffix_mask_reduces_scored_tokens() {
        let Some(session) = crate::testing::try_session() else { return };
        let presets = Presets::load(&repo_root().unwrap()).unwrap();
        let spec = presets.model("topt-s1").unwrap();
        let params = init_params(spec, 11);
        let corpus = Corpus::generate(presets.corpus("ptb-syn").unwrap());
        let windows = eval_windows(&corpus, spec.seq + 1, 4);
        let full = score_per_window(&session, &presets, spec, &params, &windows, None).unwrap();
        let sfx =
            score_per_window(&session, &presets, spec, &params, &windows, Some(spec.seq - 8))
                .unwrap();
        for (f, s) in full.iter().zip(&sfx) {
            assert!(s < f, "suffix-masked NLL {s} must be below full {f}");
            assert!(*s > 0.0);
        }
    }
}

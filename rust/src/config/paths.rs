//! Repository-root and artifact-path discovery.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

/// Locate the repository root: `FISTAPRUNER_ROOT` env var, else walk up
/// from the current directory (and from the executable) until a directory
/// containing `configs/presets.json` is found.
pub fn repo_root() -> Result<PathBuf> {
    if let Ok(root) = std::env::var("FISTAPRUNER_ROOT") {
        let p = PathBuf::from(root);
        if p.join("configs/presets.json").exists() {
            return Ok(p);
        }
        bail!("FISTAPRUNER_ROOT={} does not contain configs/presets.json", p.display());
    }
    let mut candidates: Vec<PathBuf> = Vec::new();
    if let Ok(cwd) = std::env::current_dir() {
        candidates.push(cwd);
    }
    if let Ok(exe) = std::env::current_exe() {
        if let Some(dir) = exe.parent() {
            candidates.push(dir.to_path_buf());
        }
    }
    // Compiled-in fallback (tests, benches).
    candidates.push(PathBuf::from(env!("CARGO_MANIFEST_DIR")));
    for start in candidates {
        let mut cur: Option<&Path> = Some(start.as_path());
        while let Some(dir) = cur {
            if dir.join("configs/presets.json").exists() {
                return Ok(dir.to_path_buf());
            }
            cur = dir.parent();
        }
    }
    bail!("could not locate repository root (configs/presets.json)")
}

/// `<root>/artifacts`, where aot.py writes HLO text + manifest.json.
pub fn artifacts_dir(root: &Path) -> PathBuf {
    root.join("artifacts")
}

/// Scratch outputs (checkpoints, bench csv) — gitignored.
pub fn out_dir(root: &Path) -> PathBuf {
    root.join("artifacts")
}

/// `<root>/artifacts/sparse`, where `prune --emit-sparse` writes compiled
/// sparse artifacts (`.fsa` + `.meta.json`) when no path is given.
pub fn sparse_artifacts_dir(root: &Path) -> PathBuf {
    root.join("artifacts/sparse")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn finds_root() {
        let root = repo_root().unwrap();
        assert!(root.join("configs/presets.json").exists());
        assert!(artifacts_dir(&root).ends_with("artifacts"));
    }
}

//! Configuration system: typed views over configs/presets.json (the single
//! source of truth shared with python/compile/shapes.py) plus run-level
//! option structs for pruning / training / evaluation.

pub mod paths;
pub mod presets;
pub mod run;

pub use paths::repo_root;
pub use presets::{AdmmCfg, CorpusCfg, FamilyKind, FistaCfg, FwCfg, ModelSpec, Presets, SolverPresets};
pub use run::{
    Engine, KernelVariant, PruneMode, PruneOptions, QuantMode, SolverKind, SparseFormat, Sparsity,
    TrainOptions, WarmStart,
};

//! Typed view of configs/presets.json.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::ser::json::Json;

/// Architectural family (paper: OPT vs LLaMA column groups).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FamilyKind {
    /// OPT-style: LayerNorm, learned positions, GELU 4× MLP, biases.
    Topt,
    /// LLaMA-style: RMSNorm, RoPE, SwiGLU, no biases.
    Tllama,
}

impl FamilyKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "topt" => Ok(FamilyKind::Topt),
            "tllama" => Ok(FamilyKind::Tllama),
            other => bail!("unknown model family '{other}'"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            FamilyKind::Topt => "topt",
            FamilyKind::Tllama => "tllama",
        }
    }
}

/// Fully-resolved model configuration (mirror of python shapes.ModelCfg).
#[derive(Clone, Debug)]
pub struct ModelSpec {
    pub family: FamilyKind,
    pub size: String,
    pub d: usize,
    pub layers: usize,
    pub heads: usize,
    pub ffn: usize,
    pub vocab: usize,
    pub seq: usize,
    pub bias: bool,
}

impl ModelSpec {
    pub fn name(&self) -> String {
        format!("{}-{}", self.family.name(), self.size)
    }

    pub fn head_dim(&self) -> usize {
        self.d / self.heads
    }
}

/// FISTA solver constants (paper §3.2 / §4.1).
#[derive(Clone, Debug)]
pub struct FistaCfg {
    pub max_iters: usize,
    pub power_iters: usize,
    pub power_safety: f64,
    pub stop_tol: f64,
    /// Native kernel thread count for solver math (0 = auto). Applied by
    /// `prune_model`; an explicit `PruneOptions::threads` wins over this
    /// presets default. See `tensor::par`.
    pub threads: usize,
}

/// ADMM convergence constants (`--solver admm`).
#[derive(Clone, Debug)]
pub struct AdmmCfg {
    /// Inner ADMM iterations per tuning round.
    pub max_iters: usize,
    /// ρ = rho_factor · L (the standard 0.1·λ_max heuristic).
    pub rho_factor: f64,
    /// Stop when the primal residual ‖W − Z‖_F drops below this.
    pub stop_tol: f64,
}

impl Default for AdmmCfg {
    fn default() -> Self {
        AdmmCfg { max_iters: 100, rho_factor: 0.1, stop_tol: 1e-6 }
    }
}

/// Frank-Wolfe convergence constants (`--solver fw`).
#[derive(Clone, Debug)]
pub struct FwCfg {
    /// LMO / away-step iterations per tuning round.
    pub max_iters: usize,
    /// Stop when the duality gap ⟨∇f, W − s⟩ falls below
    /// gap_tol · max(1, |⟨∇f, W⟩|).
    pub gap_tol: f64,
}

impl Default for FwCfg {
    fn default() -> Self {
        FwCfg { max_iters: 120, gap_tol: 1e-5 }
    }
}

/// Per-solver convergence presets (the optional "solvers" section; code
/// defaults apply field-by-field for backwards-compatible presets files).
#[derive(Clone, Debug, Default)]
pub struct SolverPresets {
    pub admm: AdmmCfg,
    pub fw: FwCfg,
}

/// Synthetic-corpus generator parameters (WikiText/PTB/C4 analogs).
#[derive(Clone, Debug)]
pub struct CorpusCfg {
    pub name: String,
    pub seed: u64,
    pub word_vocab: usize,
    pub zipf_s: f64,
    pub noise: f64,
    pub sentence_len: (usize, usize),
    pub chars: usize,
}

/// Adaptive-λ tuner defaults (paper Algorithm 1 / §3.3 / §4.1).
#[derive(Clone, Debug)]
pub struct PruneDefaults {
    pub lambda_init: f64,
    pub lambda_hi: f64,
    pub xi: f64,
    pub max_rounds: usize,
    pub patience: usize,
    pub eps_topt: f64,
    pub eps_tllama: f64,
}

/// Trainer defaults for the in-repo substrate models.
#[derive(Clone, Debug)]
pub struct TrainDefaults {
    pub steps: usize,
    pub lr: f64,
    pub warmup: usize,
    pub weight_decay: f64,
    pub seed: u64,
}

/// The whole presets file.
#[derive(Clone, Debug)]
pub struct Presets {
    pub vocab_size: usize,
    pub seq_len: usize,
    pub capture_batch: usize,
    pub train_batch: usize,
    pub gram_chunk: usize,
    pub fista: FistaCfg,
    pub solvers: SolverPresets,
    pub models: BTreeMap<String, ModelSpec>,
    pub corpora: BTreeMap<String, CorpusCfg>,
    pub calib_nsamples: usize,
    pub calib_corpus: String,
    pub calib_seed: u64,
    pub prune: PruneDefaults,
    pub train: TrainDefaults,
}

impl Presets {
    pub fn load(root: &Path) -> Result<Presets> {
        let v = Json::parse_file(&root.join("configs/presets.json"))?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Presets> {
        let vocab_size = v.req("vocab_size")?.as_usize().context("vocab_size")?;
        let seq_len = v.req("seq_len")?.as_usize().context("seq_len")?;
        let fista_v = v.req("fista")?;
        let fista = FistaCfg {
            max_iters: fista_v.req("max_iters")?.as_usize().context("max_iters")?,
            power_iters: fista_v.req("power_iters")?.as_usize().context("power_iters")?,
            power_safety: fista_v.req("power_safety")?.as_f64().context("power_safety")?,
            stop_tol: fista_v.req("stop_tol")?.as_f64().context("stop_tol")?,
            // optional for backwards-compatible presets files
            threads: fista_v.get("threads").and_then(|v| v.as_usize()).unwrap_or(0),
        };
        // The whole "solvers" section is optional (same backwards-compat
        // contract as fista.threads): absent keys take the code defaults.
        let solvers = {
            let base = SolverPresets::default();
            let sv = v.get("solvers");
            let admm_v = sv.and_then(|s| s.get("admm"));
            let fw_v = sv.and_then(|s| s.get("fw"));
            SolverPresets {
                admm: AdmmCfg {
                    max_iters: admm_v
                        .and_then(|a| a.get("max_iters"))
                        .and_then(|x| x.as_usize())
                        .unwrap_or(base.admm.max_iters),
                    rho_factor: admm_v
                        .and_then(|a| a.get("rho_factor"))
                        .and_then(|x| x.as_f64())
                        .unwrap_or(base.admm.rho_factor),
                    stop_tol: admm_v
                        .and_then(|a| a.get("stop_tol"))
                        .and_then(|x| x.as_f64())
                        .unwrap_or(base.admm.stop_tol),
                },
                fw: FwCfg {
                    max_iters: fw_v
                        .and_then(|f| f.get("max_iters"))
                        .and_then(|x| x.as_usize())
                        .unwrap_or(base.fw.max_iters),
                    gap_tol: fw_v
                        .and_then(|f| f.get("gap_tol"))
                        .and_then(|x| x.as_f64())
                        .unwrap_or(base.fw.gap_tol),
                },
            }
        };
        let mut models = BTreeMap::new();
        for (fam_name, fam) in v.req("families")?.as_obj().context("families")? {
            let family = FamilyKind::parse(fam_name)?;
            let bias = fam.req("bias")?.as_bool().context("bias")?;
            for (size, sv) in fam.req("sizes")?.as_obj().context("sizes")? {
                let spec = ModelSpec {
                    family,
                    size: size.clone(),
                    d: sv.req("d")?.as_usize().context("d")?,
                    layers: sv.req("layers")?.as_usize().context("layers")?,
                    heads: sv.req("heads")?.as_usize().context("heads")?,
                    ffn: sv.req("ffn")?.as_usize().context("ffn")?,
                    vocab: vocab_size,
                    seq: seq_len,
                    bias,
                };
                if spec.d % spec.heads != 0 {
                    bail!("{}: d={} not divisible by heads={}", spec.name(), spec.d, spec.heads);
                }
                models.insert(spec.name(), spec);
            }
        }
        let mut corpora = BTreeMap::new();
        for (name, cv) in v.req("corpora")?.as_obj().context("corpora")? {
            let sl = cv.req("sentence_len")?.as_arr().context("sentence_len")?;
            corpora.insert(
                name.clone(),
                CorpusCfg {
                    name: name.clone(),
                    seed: cv.req("seed")?.as_f64().context("seed")? as u64,
                    word_vocab: cv.req("word_vocab")?.as_usize().context("word_vocab")?,
                    zipf_s: cv.req("zipf_s")?.as_f64().context("zipf_s")?,
                    noise: cv.req("noise")?.as_f64().context("noise")?,
                    sentence_len: (
                        sl[0].as_usize().context("sentence_len[0]")?,
                        sl[1].as_usize().context("sentence_len[1]")?,
                    ),
                    chars: cv.req("chars")?.as_usize().context("chars")?,
                },
            );
        }
        let cal = v.req("calibration")?;
        let pd = v.req("prune_defaults")?;
        let td = v.req("train_defaults")?;
        Ok(Presets {
            vocab_size,
            seq_len,
            capture_batch: v.req("capture_batch")?.as_usize().context("capture_batch")?,
            train_batch: v.req("train_batch")?.as_usize().context("train_batch")?,
            gram_chunk: v.req("gram_chunk")?.as_usize().context("gram_chunk")?,
            fista,
            solvers,
            models,
            corpora,
            calib_nsamples: cal.req("nsamples")?.as_usize().context("nsamples")?,
            calib_corpus: cal.req("corpus")?.as_str().context("corpus")?.to_string(),
            calib_seed: cal.req("seed")?.as_f64().context("seed")? as u64,
            prune: PruneDefaults {
                lambda_init: pd.req("lambda_init")?.as_f64().context("lambda_init")?,
                lambda_hi: pd.req("lambda_hi")?.as_f64().context("lambda_hi")?,
                xi: pd.req("xi")?.as_f64().context("xi")?,
                max_rounds: pd.req("max_rounds")?.as_usize().context("max_rounds")?,
                patience: pd.req("patience")?.as_usize().context("patience")?,
                eps_topt: pd.req("eps_topt")?.as_f64().context("eps_topt")?,
                eps_tllama: pd.req("eps_tllama")?.as_f64().context("eps_tllama")?,
            },
            train: TrainDefaults {
                steps: td.req("steps")?.as_usize().context("steps")?,
                lr: td.req("lr")?.as_f64().context("lr")?,
                warmup: td.req("warmup")?.as_usize().context("warmup")?,
                weight_decay: td.req("weight_decay")?.as_f64().context("weight_decay")?,
                seed: td.req("seed")?.as_f64().context("seed")? as u64,
            },
        })
    }

    /// Look up `topt-s1`-style names.
    pub fn model(&self, name: &str) -> Result<&ModelSpec> {
        self.models
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown model '{name}' (have: {:?})", self.models.keys().collect::<Vec<_>>()))
    }

    pub fn corpus(&self, name: &str) -> Result<&CorpusCfg> {
        self.corpora
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("unknown corpus '{name}' (have: {:?})", self.corpora.keys().collect::<Vec<_>>()))
    }

    /// Per-family λ-tuner stop threshold ε (paper §4.1).
    pub fn eps_for(&self, family: FamilyKind) -> f64 {
        match family {
            FamilyKind::Topt => self.prune.eps_topt,
            FamilyKind::Tllama => self.prune.eps_tllama,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::paths::repo_root;

    #[test]
    fn loads_presets() {
        let p = Presets::load(&repo_root().unwrap()).unwrap();
        assert_eq!(p.vocab_size, 96);
        assert!(p.models.contains_key("topt-s1"));
        assert!(p.models.contains_key("tllama-s3"));
        let m = p.model("topt-s3").unwrap();
        assert_eq!(m.d, 128);
        assert_eq!(m.ffn, 512);
        assert!(m.bias);
        let l = p.model("tllama-s2").unwrap();
        assert!(!l.bias);
        assert_eq!(p.corpus("ptb-syn").unwrap().word_vocab, 900);
        assert!(p.model("nope").is_err());
    }

    #[test]
    fn solver_presets_load_with_defaults() {
        let p = Presets::load(&repo_root().unwrap()).unwrap();
        // values from configs/presets.json
        assert!(p.solvers.admm.max_iters >= 1);
        assert!(p.solvers.admm.rho_factor > 0.0);
        assert!(p.solvers.fw.max_iters >= 1);
        assert!(p.solvers.fw.gap_tol > 0.0);
        // a presets file without a "solvers" section takes code defaults
        let mut v = Json::parse_file(&repo_root().unwrap().join("configs/presets.json")).unwrap();
        if let Json::Obj(m) = &mut v {
            m.remove("solvers");
        }
        let p2 = Presets::from_json(&v).unwrap();
        assert_eq!(p2.solvers.admm.max_iters, AdmmCfg::default().max_iters);
        assert_eq!(p2.solvers.fw.max_iters, FwCfg::default().max_iters);
    }

    #[test]
    fn eps_is_per_family() {
        let p = Presets::load(&repo_root().unwrap()).unwrap();
        assert!(p.eps_for(FamilyKind::Topt) < p.eps_for(FamilyKind::Tllama));
    }
}

//! Run-level option structs: sparsity patterns, engines, prune/train options.

use anyhow::{bail, Result};

/// Target sparsity pattern (paper §2: unstructured s% or n:m semi-structured).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Sparsity {
    /// Unstructured: zero the given fraction of entries per matrix.
    Unstructured(f64),
    /// n:m — at most n *non-zero* entries per group of m consecutive
    /// entries in a row (the paper's notation: "2:4" keeps 2 of 4).
    Semi(usize, usize),
}

impl Sparsity {
    /// Parse "0.5", "50%", or "2:4". Degenerate targets fail *here* with a
    /// clear message instead of surfacing later as panics deep inside the
    /// rounding hot loop: `m == 0` (empty groups), `n == 0` (an all-zero
    /// matrix is not a pruning target), `n > m` (keeps more than the group
    /// holds), fractions outside [0, 1), and non-finite fractions.
    pub fn parse(s: &str) -> Result<Sparsity> {
        if let Some((n, m)) = s.split_once(':') {
            let n: usize = n.trim().parse()?;
            let m: usize = m.trim().parse()?;
            if m == 0 {
                bail!("invalid n:m sparsity '{s}': group size m must be >= 1");
            }
            if n == 0 {
                bail!("invalid n:m sparsity '{s}': keeping 0 of {m} zeroes every weight");
            }
            if n > m {
                bail!("invalid n:m sparsity '{s}': cannot keep {n} of a {m}-entry group");
            }
            return Ok(Sparsity::Semi(n, m));
        }
        let v = s.trim_end_matches('%');
        let mut x: f64 = v.parse()?;
        if s.contains('%') {
            x /= 100.0;
        }
        if !x.is_finite() {
            bail!("sparsity fraction must be finite: '{s}'");
        }
        if !(0.0..1.0).contains(&x) {
            bail!("sparsity fraction must be in [0,1): '{s}'");
        }
        Ok(Sparsity::Unstructured(x))
    }

    /// Overall fraction of zeros this pattern implies.
    pub fn rate(&self) -> f64 {
        match self {
            Sparsity::Unstructured(s) => *s,
            Sparsity::Semi(n, m) => 1.0 - (*n as f64) / (*m as f64),
        }
    }

    /// Human label, chosen so `Sparsity::parse(&self.label())` round-trips
    /// (CLI flags, bench CSVs and serve-bench JSON all echo labels back
    /// into `parse`): "2:4" ⇄ `Semi(2, 4)`, "50%"/"62.5%" ⇄
    /// `Unstructured(0.5/0.625)`.
    pub fn label(&self) -> String {
        match self {
            Sparsity::Unstructured(s) => {
                let pct = s * 100.0;
                if (pct - pct.round()).abs() < 1e-9 {
                    format!("{pct:.0}%")
                } else {
                    format!("{pct}%")
                }
            }
            Sparsity::Semi(n, m) => format!("{n}:{m}"),
        }
    }
}

/// Storage format for compressed (pruned) weight operators.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SparseFormat {
    /// Generic compressed-sparse-row: any pattern, u32 column indices.
    Csr,
    /// Packed n:m semi-structured: per (row, m-group) exactly n values +
    /// u8 in-group indices. Requires the weight to satisfy the n:m
    /// pattern (constant-time group addressing, ~¼ the index memory of
    /// CSR at 2:4).
    Nm,
    /// Per-operator choice: `Nm` when the weight satisfies the run's
    /// `Sparsity::Semi` pattern (and the row length divides into full
    /// m-groups), `Csr` otherwise.
    Auto,
}

impl SparseFormat {
    pub fn parse(s: &str) -> Result<SparseFormat> {
        match s {
            "csr" => Ok(SparseFormat::Csr),
            "nm" => Ok(SparseFormat::Nm),
            "auto" => Ok(SparseFormat::Auto),
            other => bail!("unknown sparse format '{other}' (csr|nm|auto)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            SparseFormat::Csr => "csr",
            SparseFormat::Nm => "nm",
            SparseFormat::Auto => "auto",
        }
    }
}

/// Which implementation of the decode-critical kernels runs: the scalar
/// reference (always built, the parity oracle) or the portable-SIMD
/// variant (`simd` cargo feature). Selected process-globally through
/// `tensor::par::set_kernel_variant`; every variant is independently
/// bitwise thread-count-invariant (see `tensor::par`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KernelVariant {
    /// Scalar f32 loops — the oracle the SIMD path is tested against.
    Scalar,
    /// `core::simd` lane-parallel inner loops (`--features simd`).
    Simd,
}

impl KernelVariant {
    pub fn parse(s: &str) -> Result<KernelVariant> {
        match s {
            "scalar" => Ok(KernelVariant::Scalar),
            "simd" => Ok(KernelVariant::Simd),
            other => bail!("unknown kernel variant '{other}' (scalar|simd)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            KernelVariant::Scalar => "scalar",
            KernelVariant::Simd => "simd",
        }
    }
}

/// Quantized storage mode for compiled sparse artifact values. The sparse
/// pattern (indices) is always exact; quantization applies to the kept
/// values only and is decoded in registers inside the kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QuantMode {
    /// f32 values as-is (the default; byte-identical to pre-quant builds).
    None,
    /// IEEE half precision: 2 bytes/value, exact for representable values.
    F16,
    /// Per-row absmax int8: 1 byte/value + one f32 scale per row; element
    /// error ≤ row absmax / 127.
    Int8,
}

impl QuantMode {
    pub fn parse(s: &str) -> Result<QuantMode> {
        match s {
            "none" => Ok(QuantMode::None),
            "f16" => Ok(QuantMode::F16),
            "int8" => Ok(QuantMode::Int8),
            other => bail!("unknown quant mode '{other}' (none|f16|int8)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            QuantMode::None => "none",
            QuantMode::F16 => "f16",
            QuantMode::Int8 => "int8",
        }
    }
}

/// Which engine executes the FISTA/Gram hot loops.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Engine {
    /// AOT artifacts via PJRT (the production path).
    Xla,
    /// Pure-rust reference (tests, environments without artifacts).
    Native,
}

impl Engine {
    pub fn parse(s: &str) -> Result<Engine> {
        match s {
            "xla" => Ok(Engine::Xla),
            "native" => Ok(Engine::Native),
            other => bail!("unknown engine '{other}' (xla|native)"),
        }
    }
}

/// Which convex-optimization algorithm solves the layer-wise Gram-form
/// objective (the *algorithm* axis; `Engine` is the orthogonal *execution*
/// axis). See `pruner::solver::LayerSolver`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolverKind {
    /// FISTA proximal gradient (the paper's method, eqs. 5a–5d).
    Fista,
    /// ADMM splitting (the ALPS-style comparator).
    Admm,
    /// Frank-Wolfe over the ℓ₁ ball with away steps.
    FrankWolfe,
}

impl SolverKind {
    pub fn parse(s: &str) -> Result<SolverKind> {
        match s {
            "fista" | "fistapruner" => Ok(SolverKind::Fista),
            "admm" => Ok(SolverKind::Admm),
            "fw" | "frankwolfe" | "frank-wolfe" => Ok(SolverKind::FrankWolfe),
            other => bail!("unknown solver '{other}' (fista|admm|fw)"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SolverKind::Fista => "fista",
            SolverKind::Admm => "admm",
            SolverKind::FrankWolfe => "fw",
        }
    }
}

/// Inter-layer propagation mode (paper §3.4: units are independent, so
/// layers can be pruned in parallel; sequential propagates pruned
/// activations between layers like the SparseGPT evaluation pipeline).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PruneMode {
    Sequential,
    Parallel,
}

impl PruneMode {
    pub fn parse(s: &str) -> Result<PruneMode> {
        match s {
            "sequential" => Ok(PruneMode::Sequential),
            "parallel" => Ok(PruneMode::Parallel),
            other => bail!("unknown mode '{other}' (sequential|parallel)"),
        }
    }
}

/// Warm-start source for the FISTA iterations (paper §4.1: SparseGPT for
/// OPT, Wanda for LLaMA).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WarmStart {
    Auto,
    SparseGpt,
    Wanda,
    Dense,
}

impl WarmStart {
    pub fn parse(s: &str) -> Result<WarmStart> {
        match s {
            "auto" => Ok(WarmStart::Auto),
            "sparsegpt" => Ok(WarmStart::SparseGpt),
            "wanda" => Ok(WarmStart::Wanda),
            "dense" => Ok(WarmStart::Dense),
            other => bail!("unknown warm start '{other}'"),
        }
    }
}

/// Everything a pruning run needs beyond the model + calibration data.
#[derive(Clone, Debug)]
pub struct PruneOptions {
    pub sparsity: Sparsity,
    pub engine: Engine,
    /// Layer-wise solver algorithm (recorded for provenance; the scheduler
    /// takes the authoritative kind from `Method::Solver`).
    pub solver: SolverKind,
    pub mode: PruneMode,
    pub warm_start: WarmStart,
    /// Intra-layer error correction (paper §3.1); off = Fig. 4a ablation.
    pub error_correction: bool,
    /// Scheduler workers: parallel-mode layer units, and (when > 1) the
    /// sequential-mode intra-layer operator overlap on the native engine.
    pub workers: usize,
    /// Native kernel threads (0 = auto). Applied process-globally at the
    /// start of `prune_model`; see `tensor::par` for the determinism
    /// guarantees that make this safe.
    pub threads: usize,
    /// Override Algorithm 1's max tuning rounds (None = presets value).
    pub max_rounds: Option<usize>,
    pub seed: u64,
}

impl Default for PruneOptions {
    fn default() -> Self {
        PruneOptions {
            sparsity: Sparsity::Unstructured(0.5),
            engine: Engine::Xla,
            solver: SolverKind::Fista,
            mode: PruneMode::Sequential,
            warm_start: WarmStart::Auto,
            error_correction: true,
            workers: 1,
            threads: 0,
            max_rounds: None,
            seed: 0,
        }
    }
}

/// Trainer options for the substrate models.
#[derive(Clone, Debug)]
pub struct TrainOptions {
    pub steps: usize,
    pub lr: f64,
    pub warmup: usize,
    pub seed: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sparsity() {
        assert_eq!(Sparsity::parse("0.5").unwrap(), Sparsity::Unstructured(0.5));
        assert_eq!(Sparsity::parse("30%").unwrap(), Sparsity::Unstructured(0.3));
        assert_eq!(Sparsity::parse("2:4").unwrap(), Sparsity::Semi(2, 4));
        assert!(Sparsity::parse("4:2").is_err());
        assert!(Sparsity::parse("1.5").is_err());
    }

    #[test]
    fn rates() {
        assert!((Sparsity::Semi(2, 4).rate() - 0.5).abs() < 1e-12);
        assert!((Sparsity::Unstructured(0.3).rate() - 0.3).abs() < 1e-12);
    }

    #[test]
    fn labels() {
        assert_eq!(Sparsity::Semi(2, 4).label(), "2:4");
        assert_eq!(Sparsity::Unstructured(0.5).label(), "50%");
        assert_eq!(Sparsity::Unstructured(0.625).label(), "62.5%");
    }

    #[test]
    fn parse_label_round_trip() {
        let cases = [
            Sparsity::Semi(2, 4),
            Sparsity::Semi(1, 2),
            Sparsity::Semi(4, 8),
            Sparsity::Unstructured(0.5),
            Sparsity::Unstructured(0.625),
            Sparsity::Unstructured(0.9),
        ];
        for s in cases {
            let back = Sparsity::parse(&s.label()).unwrap();
            assert_eq!(back, s, "label {:?} did not round-trip", s.label());
        }
        // and labels are stable through a second cycle
        for s in cases {
            assert_eq!(Sparsity::parse(&s.label()).unwrap().label(), s.label());
        }
    }

    #[test]
    fn parse_accepts_whitespace_in_nm() {
        assert_eq!(Sparsity::parse("2 : 4").unwrap(), Sparsity::Semi(2, 4));
        assert!(Sparsity::parse("0:4").is_err());
        assert!(Sparsity::parse(":4").is_err());
    }

    #[test]
    fn parse_rejects_degenerate_targets_with_clear_errors() {
        // every degenerate target fails at parse time, not as a panic
        // deep inside the rounding loop, and says why
        let err = Sparsity::parse("4:2").unwrap_err().to_string();
        assert!(err.contains("cannot keep 4"), "{err}");
        let err = Sparsity::parse("2:0").unwrap_err().to_string();
        assert!(err.contains("m must be >= 1"), "{err}");
        let err = Sparsity::parse("0:0").unwrap_err().to_string();
        assert!(err.contains("m must be >= 1"), "{err}");
        let err = Sparsity::parse("1.5").unwrap_err().to_string();
        assert!(err.contains("[0,1)"), "{err}");
        let err = Sparsity::parse("150%").unwrap_err().to_string();
        assert!(err.contains("[0,1)"), "{err}");
        let err = Sparsity::parse("-0.1").unwrap_err().to_string();
        assert!(err.contains("[0,1)"), "{err}");
        let err = Sparsity::parse("NaN").unwrap_err().to_string();
        assert!(err.contains("finite"), "{err}");
        let err = Sparsity::parse("inf").unwrap_err().to_string();
        assert!(err.contains("finite"), "{err}");
        // 100% would zero everything — rejected like any fraction >= 1
        assert!(Sparsity::parse("100%").is_err());
        // boundary values that must stay valid
        assert_eq!(Sparsity::parse("0").unwrap(), Sparsity::Unstructured(0.0));
        assert_eq!(Sparsity::parse("0.99").unwrap(), Sparsity::Unstructured(0.99));
        assert_eq!(Sparsity::parse("1:1").unwrap(), Sparsity::Semi(1, 1));
    }

    #[test]
    fn solver_kind_parse_and_name() {
        assert_eq!(SolverKind::parse("fista").unwrap(), SolverKind::Fista);
        assert_eq!(SolverKind::parse("admm").unwrap(), SolverKind::Admm);
        assert_eq!(SolverKind::parse("fw").unwrap(), SolverKind::FrankWolfe);
        assert_eq!(SolverKind::parse("frank-wolfe").unwrap(), SolverKind::FrankWolfe);
        for k in [SolverKind::Fista, SolverKind::Admm, SolverKind::FrankWolfe] {
            assert_eq!(SolverKind::parse(k.name()).unwrap(), k);
        }
        let err = SolverKind::parse("ista").unwrap_err().to_string();
        assert!(err.contains("fista|admm|fw"), "{err}");
    }

    #[test]
    fn kernel_variant_parse_and_label() {
        for (s, v) in [("scalar", KernelVariant::Scalar), ("simd", KernelVariant::Simd)] {
            assert_eq!(KernelVariant::parse(s).unwrap(), v);
            assert_eq!(v.label(), s);
        }
        let err = KernelVariant::parse("avx512").unwrap_err().to_string();
        assert!(err.contains("scalar|simd"), "{err}");
    }

    #[test]
    fn quant_mode_parse_and_label() {
        for (s, q) in
            [("none", QuantMode::None), ("f16", QuantMode::F16), ("int8", QuantMode::Int8)]
        {
            assert_eq!(QuantMode::parse(s).unwrap(), q);
            assert_eq!(q.label(), s);
        }
        let err = QuantMode::parse("int4").unwrap_err().to_string();
        assert!(err.contains("none|f16|int8"), "{err}");
    }

    #[test]
    fn sparse_format_parse_and_label() {
        for (s, f) in
            [("csr", SparseFormat::Csr), ("nm", SparseFormat::Nm), ("auto", SparseFormat::Auto)]
        {
            assert_eq!(SparseFormat::parse(s).unwrap(), f);
            assert_eq!(f.label(), s);
        }
        assert!(SparseFormat::parse("dense").is_err());
    }
}

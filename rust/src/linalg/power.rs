//! Power iteration for the largest eigenvalue of a PSD Gram matrix —
//! the FISTA step-size constant L = λ_max(X* X*ᵀ) (paper eq. 5a).
//!
//! Mirrors python/compile/model.py::power_l so the native fallback and the
//! `power_{n}` artifact agree (tested in rust/tests/runtime_parity.rs).

use crate::tensor::{ops::matvec, Tensor};

/// λ_max(A)·safety for symmetric PSD A.
///
/// Power iteration converges from below, so `safety` (default 1.02 in
/// configs/presets.json) keeps 1/L a valid descent step.
pub fn power_iteration(a: &Tensor, iters: usize, safety: f64) -> f64 {
    let n = a.rows();
    assert_eq!(n, a.cols());
    let mut v = vec![1.0f32 / (n as f32).sqrt(); n];
    for _ in 0..iters {
        let av = matvec(a, &v);
        let norm = av.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        if norm < 1e-30 {
            return 1e-12 * safety; // zero matrix
        }
        for (vi, &ai) in v.iter_mut().zip(&av) {
            *vi = (ai as f64 / norm) as f32;
        }
    }
    let av = matvec(a, &v);
    // fp-lint: allow(f32-reduce) — serial f64 accumulation in iteration order
    let rayleigh: f64 = v.iter().zip(&av).map(|(&x, &y)| (x as f64) * (y as f64)).sum();
    rayleigh.max(1e-12) * safety
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::matmul_nt;
    use crate::util::Pcg64;

    #[test]
    fn diagonal_matrix() {
        let a = Tensor::from_vec(vec![3, 3], vec![2., 0., 0., 0., 5., 0., 0., 0., 1.]);
        let l = power_iteration(&a, 100, 1.0);
        assert!((l - 5.0).abs() < 1e-3, "{l}");
    }

    #[test]
    fn upper_bounds_gram_spectrum() {
        let mut rng = Pcg64::seeded(4);
        let x = Tensor::from_vec(vec![24, 100], rng.normal_vec(2400, 1.0));
        let a = matmul_nt(&x, &x);
        let l = power_iteration(&a, 64, 1.02);
        // Validate against many random Rayleigh quotients.
        for _ in 0..50 {
            let v = rng.normal_vec(24, 1.0);
            let av = matvec(&a, &v);
            let num: f64 = v.iter().zip(&av).map(|(&a, &b)| (a as f64) * (b as f64)).sum();
            let den: f64 = v.iter().map(|&a| (a as f64) * (a as f64)).sum();
            assert!(num / den <= l * 1.001, "rayleigh {} > L {}", num / den, l);
        }
    }

    #[test]
    fn zero_matrix_guard() {
        let a = Tensor::zeros(vec![4, 4]);
        assert!(power_iteration(&a, 10, 1.02) > 0.0);
    }
}

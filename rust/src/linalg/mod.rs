//! Dense linear algebra for the baselines and native solver fallback:
//! Cholesky factorization / inversion (SparseGPT's Hessian pipeline) and
//! power iteration (FISTA step-size constant when running natively).

pub mod cholesky;
pub mod power;

pub use cholesky::{cholesky, cholesky_inverse, cholesky_solve_into, solve_lower, solve_upper};
pub use power::power_iteration;

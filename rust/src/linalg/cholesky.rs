//! Cholesky factorization and PSD inversion.
//!
//! SparseGPT (Frantar & Alistarh 2023) needs the inverse Hessian
//! H⁻¹ = (X Xᵀ + εI)⁻¹ and, per processed block, the Cholesky of the
//! remaining submatrix. Our baseline follows the reference implementation:
//! one upfront Cholesky-based inversion, then the OBS column sweep uses the
//! Cholesky factor of H⁻¹ (see baselines/sparsegpt.rs).

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// Lower-triangular L with A = L Lᵀ. Fails on non-PD input.
pub fn cholesky(a: &Tensor) -> Result<Tensor> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "cholesky needs square input");
    let mut l = Tensor::zeros(vec![n, n]);
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a.at2(i, j) as f64;
            for k in 0..j {
                sum -= (l.at2(i, k) as f64) * (l.at2(j, k) as f64);
            }
            if i == j {
                if sum <= 0.0 {
                    bail!("matrix not positive definite at pivot {i} (sum={sum:.3e})");
                }
                l.set2(i, j, sum.sqrt() as f32);
            } else {
                l.set2(i, j, (sum / l.at2(j, j) as f64) as f32);
            }
        }
    }
    Ok(l)
}

/// Solve L y = b for lower-triangular L.
pub fn solve_lower(l: &Tensor, b: &[f32]) -> Vec<f32> {
    let n = l.rows();
    assert_eq!(b.len(), n);
    let mut y = vec![0.0f32; n];
    for i in 0..n {
        let mut sum = b[i] as f64;
        for k in 0..i {
            sum -= (l.at2(i, k) as f64) * (y[k] as f64);
        }
        y[i] = (sum / l.at2(i, i) as f64) as f32;
    }
    y
}

/// Solve Lᵀ x = y for lower-triangular L.
pub fn solve_upper(l: &Tensor, y: &[f32]) -> Vec<f32> {
    let n = l.rows();
    assert_eq!(y.len(), n);
    let mut x = vec![0.0f32; n];
    for i in (0..n).rev() {
        let mut sum = y[i] as f64;
        for k in (i + 1)..n {
            sum -= (l.at2(k, i) as f64) * (x[k] as f64);
        }
        x[i] = (sum / l.at2(i, i) as f64) as f32;
    }
    x
}

/// Solve (L Lᵀ) x = rhs into `out` with zero allocations: forward
/// substitution writes y into `out`, then backward substitution finishes
/// in place. `out` may alias neither `l` nor `rhs`. Hot-loop variant of
/// `solve_lower` + `solve_upper` for callers (the ADMM W-step) that solve
/// many right-hand sides against one factorization.
pub fn cholesky_solve_into(l: &Tensor, rhs: &[f32], out: &mut [f32]) {
    let n = l.rows();
    debug_assert_eq!(rhs.len(), n);
    debug_assert_eq!(out.len(), n);
    for i in 0..n {
        let mut sum = rhs[i] as f64;
        for k in 0..i {
            sum -= (l.at2(i, k) as f64) * (out[k] as f64);
        }
        out[i] = (sum / l.at2(i, i) as f64) as f32;
    }
    for i in (0..n).rev() {
        let mut sum = out[i] as f64;
        for k in (i + 1)..n {
            sum -= (l.at2(k, i) as f64) * (out[k] as f64);
        }
        out[i] = (sum / l.at2(i, i) as f64) as f32;
    }
}

/// A⁻¹ for symmetric positive-definite A, via Cholesky solves per column.
pub fn cholesky_inverse(a: &Tensor) -> Result<Tensor> {
    let n = a.rows();
    let l = cholesky(a)?;
    let mut inv = Tensor::zeros(vec![n, n]);
    let mut e = vec![0.0f32; n];
    for j in 0..n {
        e[j] = 1.0;
        let y = solve_lower(&l, &e);
        let x = solve_upper(&l, &y);
        for i in 0..n {
            inv.set2(i, j, x[i]);
        }
        e[j] = 0.0;
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::ops::{matmul, matmul_nt, transpose};
    use crate::util::Pcg64;

    fn random_spd(rng: &mut Pcg64, n: usize, jitter: f32) -> Tensor {
        let x = Tensor::from_vec(vec![n, n + 4], rng.normal_vec(n * (n + 4), 1.0));
        let mut a = matmul_nt(&x, &x);
        for i in 0..n {
            let v = a.at2(i, i) + jitter;
            a.set2(i, i, v);
        }
        a
    }

    #[test]
    fn factor_reconstructs() {
        let mut rng = Pcg64::seeded(1);
        let a = random_spd(&mut rng, 16, 0.1);
        let l = cholesky(&a).unwrap();
        let back = matmul(&l, &transpose(&l));
        assert!(crate::tensor::ops::frob_dist(&back, &a) < 1e-2 * a.frob_norm());
    }

    #[test]
    fn inverse_is_inverse() {
        let mut rng = Pcg64::seeded(2);
        let a = random_spd(&mut rng, 12, 0.5);
        let inv = cholesky_inverse(&a).unwrap();
        let prod = matmul(&a, &inv);
        for i in 0..12 {
            for j in 0..12 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((prod.at2(i, j) - want).abs() < 1e-3, "({i},{j}) = {}", prod.at2(i, j));
            }
        }
    }

    #[test]
    fn triangular_solves() {
        let mut rng = Pcg64::seeded(3);
        let a = random_spd(&mut rng, 8, 0.5);
        let l = cholesky(&a).unwrap();
        let b: Vec<f32> = rng.normal_vec(8, 1.0);
        let y = solve_lower(&l, &b);
        let x = solve_upper(&l, &y);
        // L Lᵀ x = b  ⇒  A x = b
        let ax = crate::tensor::ops::matvec(&a, &x);
        for i in 0..8 {
            assert!((ax[i] - b[i]).abs() < 1e-3);
        }
    }

    #[test]
    fn in_place_solve_matches_two_pass_solve() {
        let mut rng = Pcg64::seeded(4);
        let a = random_spd(&mut rng, 12, 0.5);
        let l = cholesky(&a).unwrap();
        let b: Vec<f32> = rng.normal_vec(12, 1.0);
        let y = solve_lower(&l, &b);
        let x = solve_upper(&l, &y);
        let mut out = vec![0.0f32; 12];
        cholesky_solve_into(&l, &b, &mut out);
        assert_eq!(out, x, "in-place solve must be bitwise identical");
    }

    #[test]
    fn rejects_indefinite() {
        let a = Tensor::from_vec(vec![2, 2], vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }
}
